"""AOT pipeline: every entry point lowers to parseable HLO text and the
manifest schema matches what rust/src/runtime expects."""

import json
import os
import subprocess
import sys

import jax
import pytest

from compile import aot

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Run via the module CLI exactly as `make artifacts` does.
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return out


def test_manifest_schema(built):
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    arts = manifest["artifacts"]
    assert len(arts) == 5
    names = {a["name"] for a in arts}
    assert f"vowel_mlp_step_b{aot.MLP_B}" in names
    for a in arts:
        assert (built / a["file"]).exists()
        assert a["outputs"] >= 1
        for arg in a["args"]:
            assert arg["dtype"] in ("f32", "i32")
            assert all(isinstance(d, int) and d > 0 for d in arg["shape"])


def test_hlo_text_is_wellformed(built):
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    for a in manifest["artifacts"]:
        text = (built / a["file"]).read_text()
        assert text.startswith("HloModule"), a["name"]
        assert "ENTRY" in text, a["name"]
        # return_tuple=True: root is a tuple of `outputs` elements.
        assert "tuple(" in text or a["outputs"] == 1


def test_entry_points_trace():
    """Every entry traces and lowers in-process (no subprocess needed)."""
    entries = aot.kernel_entries() + [aot.mlp_fwd_entry(), aot.mlp_step_entry()]
    for name, fn, specs, n_out in entries:
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert n_out >= 1
