"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps block-grid shapes (P, Q, k, B); allclose is the core
signal — if these fail, nothing downstream (AOT artifacts, rust runtime
agreement) can be trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import feedback, ptc_forward, sigma_grad
from compile.kernels.ref import (
    dense_equivalent,
    feedback_ref,
    ptc_forward_ref,
    sigma_grad_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand_blocks(seed, p, q, k, b, unitary=True):
    rng = np.random.default_rng(seed)
    if unitary:
        a = rng.normal(size=(p, q, k, k)).astype(np.float32)
        u = np.linalg.qr(a)[0].astype(np.float32)
        a2 = rng.normal(size=(p, q, k, k)).astype(np.float32)
        v = np.linalg.qr(a2)[0].astype(np.float32)
    else:
        u = rng.normal(size=(p, q, k, k)).astype(np.float32)
        v = rng.normal(size=(p, q, k, k)).astype(np.float32)
    s = rng.normal(size=(p, q, k)).astype(np.float32)
    x = rng.normal(size=(q, k, b)).astype(np.float32)
    dy = rng.normal(size=(p, k, b)).astype(np.float32)
    return map(jnp.asarray, (u, s, v, x, dy))


shape_strategy = st.tuples(
    st.integers(1, 3),  # P
    st.integers(1, 3),  # Q
    st.sampled_from([2, 4, 9]),  # k
    st.integers(1, 20),  # B
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 2**31 - 1))
def test_ptc_forward_matches_ref(shape, seed):
    p, q, k, b = shape
    u, s, v, x, _ = rand_blocks(seed, p, q, k, b)
    got = ptc_forward(u, s, v, x)
    want = ptc_forward_ref(u, s, v, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 2**31 - 1))
def test_sigma_grad_matches_ref(shape, seed):
    p, q, k, b = shape
    u, s, v, x, dy = rand_blocks(seed, p, q, k, b)
    got = sigma_grad(u, v, x, dy)
    want = sigma_grad_ref(u, v, x, dy)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    del s


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 2**31 - 1))
def test_feedback_matches_ref(shape, seed):
    p, q, k, b = shape
    u, s, v, x, dy = rand_blocks(seed, p, q, k, b)
    got = feedback(u, s, v, dy)
    want = feedback_ref(u, s, v, dy)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    del x


def test_forward_equals_dense_matmul():
    """The blocked kernel realizes exactly W·x for W = blocks of U diag(s) V*."""
    p, q, k, b = 2, 3, 4, 7
    u, s, v, x, _ = rand_blocks(0, p, q, k, b)
    w = dense_equivalent(u, s, v)
    xd = np.asarray(x).reshape(q * k, b)
    want = np.asarray(w) @ xd
    got = np.asarray(ptc_forward(u, s, v, x)).reshape(p * k, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sigma_grad_matches_autodiff():
    """Eq. 5 equals jax.grad of ||forward||-style losses w.r.t. s."""
    p, q, k, b = 2, 2, 4, 5
    u, s, v, x, dy = rand_blocks(1, p, q, k, b)

    def loss(s_):
        y = ptc_forward_ref(u, s_, v, x)
        return jnp.sum(y * dy)  # linear probe so dL/dy = dy

    want = jax.grad(loss)(s)
    got = sigma_grad(u, v, x, dy)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_feedback_is_dense_wt_dy():
    p, q, k, b = 3, 2, 4, 6
    u, s, v, _, dy = rand_blocks(2, p, q, k, b)
    w = dense_equivalent(u, s, v)
    want = np.asarray(w).T @ np.asarray(dy).reshape(p * k, b)
    got = np.asarray(feedback(u, s, v, dy)).reshape(q * k, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sign_flip_cancels_in_sigma_grad():
    """The Ĩ invariance (§3.4.1): flipping matched signs of U columns and V*
    rows leaves the Eq. 5 gradient unchanged."""
    p, q, k, b = 1, 1, 4, 5
    u, s, v, x, dy = rand_blocks(3, p, q, k, b)
    flips = jnp.asarray([1.0, -1.0, -1.0, 1.0], dtype=jnp.float32)
    u2 = u * flips[None, None, None, :]  # flip columns of U
    v2 = v * flips[None, None, :, None]  # flip matching rows of V*
    g1 = sigma_grad(u, v, x, dy)
    g2 = sigma_grad(u2, v2, x, dy)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)
    del s


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtype_passthrough(dtype):
    p, q, k, b = 1, 2, 2, 3
    u, s, v, x, _ = rand_blocks(4, p, q, k, b)
    y = ptc_forward(u.astype(dtype), s.astype(dtype), v.astype(dtype), x.astype(dtype))
    assert y.dtype == jnp.float32
    assert y.shape == (p, k, b)
