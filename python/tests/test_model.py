"""L2 correctness: subspace-MLP forward/backward and the AOT entry points."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

DIMS = (8, 16, 16, 4)
K = 4
B = 16


def make_params(seed=0):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(DIMS) - 1)
    return [
        M.init_layer(keys[i], DIMS[i + 1], DIMS[i], K) for i in range(len(DIMS) - 1)
    ]


def make_batch(seed=1):
    key = jax.random.PRNGKey(seed)
    kx, kl = jax.random.split(key)
    x = jax.random.normal(kx, (DIMS[0], B), jnp.float32)
    labels = jax.random.randint(kl, (B,), 0, DIMS[-1], jnp.int32)
    return x, labels


def test_forward_shapes():
    params = make_params()
    x, _ = make_batch()
    logits = M.mlp_forward(params, DIMS, x)
    assert logits.shape == (DIMS[-1], B)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_shapes():
    params = make_params()
    x, labels = make_batch()
    loss, logits, sgrads, bgrads = M.train_step(params, DIMS, x, labels)
    assert loss.shape == ()
    assert logits.shape == (DIMS[-1], B)
    assert len(sgrads) == len(params)
    for lp, g in zip(params, sgrads):
        assert g.shape == lp.s.shape
    for li, g in enumerate(bgrads):
        assert g.shape == (DIMS[li + 1],)


def test_explicit_backward_matches_autodiff():
    """The hand-written Eq.5 backward must equal jax.grad w.r.t. (s, bias)."""
    params = make_params(2)
    x, labels = make_batch(3)

    # jax.grad cannot differentiate through interpret-mode pallas grid
    # accumulation, so the reference forward (same math) defines the loss.
    from compile.kernels.ref import ptc_forward_ref

    def ref_forward(svals, biases):
        h = x
        for li, lp in enumerate(params):
            q, k = lp.u.shape[1], lp.u.shape[2]
            xp = M.to_panels(h, q, k)
            y = ptc_forward_ref(lp.u, svals[li], lp.v, xp)
            h = M.from_panels(y, DIMS[li + 1]) + biases[li][: DIMS[li + 1], None]
            if li + 1 < len(params):
                h = jax.nn.relu(h)
        return h

    def loss_fn(svals, biases):
        return M.softmax_xent(ref_forward(svals, biases), labels)

    svals = [lp.s for lp in params]
    biases = [lp.bias for lp in params]
    want_s, want_b = jax.grad(loss_fn, argnums=(0, 1))(svals, biases)
    loss, _, got_s, got_b = M.train_step(params, DIMS, x, labels)
    assert np.isfinite(float(loss))
    for w, g in zip(want_s, got_s):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-6)
    for li, (w, g) in enumerate(zip(want_b, got_b)):
        # train_step reports bias grads over the un-padded features only.
        np.testing.assert_allclose(g[: DIMS[li + 1]], w[: DIMS[li + 1]], rtol=1e-4, atol=1e-6)


def test_sigma_descent_reduces_loss():
    """A few SGD steps on Σ alone must reduce the loss (learnability §3.4)."""
    params = make_params(4)
    x, labels = make_batch(5)
    first = None
    lr = 0.5
    for _ in range(30):
        loss, _, sgrads, bgrads = M.train_step(params, DIMS, x, labels)
        if first is None:
            first = float(loss)
        params = [
            M.LayerParams(
                u=lp.u,
                s=lp.s - lr * g,
                v=lp.v,
                bias=lp.bias.at[: gb.shape[0]].add(-lr * gb),
            )
            for lp, g, gb in zip(params, sgrads, bgrads)
        ]
    last = float(loss)
    assert last < first * 0.7, f"sigma-only descent failed: {first} -> {last}"


def test_panels_roundtrip():
    x = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
    xp = M.to_panels(x, 2, 4)
    assert xp.shape == (2, 4, 3)
    back = M.from_panels(xp, 8)
    np.testing.assert_array_equal(back, x)
    # Padding path.
    xp2 = M.to_panels(x, 3, 4)
    assert xp2.shape == (3, 4, 3)
    np.testing.assert_array_equal(M.from_panels(xp2, 8), x)
