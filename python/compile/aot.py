"""AOT compiler: lower the L2 entry points to HLO **text** artifacts.

HLO text — never ``lowered.compile()`` output or ``.serialize()`` protos —
is the interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla`` crate
links) rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per entry point plus ``manifest.json``
describing argument shapes/dtypes and output arity, which
``rust/src/runtime`` consumes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Export shape set. k=9 is the paper's block size; the standalone kernel
# artifacts use a 2×2 block grid with B=18 (two k-wide WDM column groups).
# The MLP artifacts are the Vowel subspace model (8-16-16-4, k=4) at B=16,
# matching examples/end_to_end.rs.
KERNEL_P, KERNEL_Q, KERNEL_K, KERNEL_B = 2, 2, 9, 18
MLP_DIMS = (8, 16, 16, 4)
MLP_K = 4
MLP_B = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def kernel_entries():
    p, q, k, b = KERNEL_P, KERNEL_Q, KERNEL_K, KERNEL_B
    u, s, v = f32(p, q, k, k), f32(p, q, k), f32(p, q, k, k)
    x, dy = f32(q, k, b), f32(p, k, b)
    from .kernels import feedback, ptc_forward, sigma_grad

    return [
        (
            f"ptc_forward_p{p}_q{q}_k{k}_b{b}",
            lambda u, s, v, x: (ptc_forward(u, s, v, x),),
            [u, s, v, x],
            1,
        ),
        (
            f"sigma_grad_p{p}_q{q}_k{k}_b{b}",
            lambda u, v, x, dy: (sigma_grad(u, v, x, dy),),
            [u, v, x, dy],
            1,
        ),
        (
            f"feedback_p{p}_q{q}_k{k}_b{b}",
            lambda u, s, v, dy: (feedback(u, s, v, dy),),
            [u, s, v, dy],
            1,
        ),
    ]


def mlp_arg_specs():
    """Flat (u, s, v, bias) per layer then x [in, B] (and labels for step)."""
    dims, k, b = MLP_DIMS, MLP_K, MLP_B
    args = []
    for li in range(len(dims) - 1):
        p = -(-dims[li + 1] // k)
        q = -(-dims[li] // k)
        args += [f32(p, q, k, k), f32(p, q, k), f32(p, q, k, k), f32(p * k)]
    args.append(f32(dims[0], b))
    return args


def unflatten_params(flat):
    params = []
    for i in range(0, len(flat), 4):
        params.append(M.LayerParams(u=flat[i], s=flat[i + 1], v=flat[i + 2], bias=flat[i + 3]))
    return params


def mlp_fwd_entry():
    def fn(*flat_args):
        params = unflatten_params(flat_args[:-1])
        return (M.mlp_forward(params, MLP_DIMS, flat_args[-1]),)

    return (f"vowel_mlp_fwd_b{MLP_B}", fn, mlp_arg_specs(), 1)


def mlp_step_entry():
    n_layers = len(MLP_DIMS) - 1

    def fn(*flat_args):
        params = unflatten_params(flat_args[:-2])
        x, labels = flat_args[-2], flat_args[-1]
        loss, logits, sgrads, bgrads = M.train_step(params, MLP_DIMS, x, labels)
        return (loss, logits, *sgrads, *bgrads)

    args = mlp_arg_specs() + [i32(MLP_B)]
    return (f"vowel_mlp_step_b{MLP_B}", fn, args, 2 + 2 * n_layers)


def dtype_name(d):
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = kernel_entries() + [mlp_fwd_entry(), mlp_step_entry()]
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, specs, n_out in entries:
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "args": [
                    {"shape": list(s.shape), "dtype": dtype_name(s.dtype)} for s in specs
                ],
                "outputs": n_out,
            }
        )
        print(f"  wrote {fname} ({len(text)} chars, {len(specs)} args, {n_out} outputs)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
