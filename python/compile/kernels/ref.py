"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Shapes (all float32 unless noted):
  u : [P, Q, k, k]   per-block singular-vector matrix U
  s : [P, Q, k]      per-block singular values Σ (signed)
  v : [P, Q, k, k]   per-block V* matrix (stored as V*, applied directly)
  x : [Q, k, B]      input column panels, one k-row panel per block column
  dy: [P, k, B]      upstream gradient panels
  y : [P, k, B]      output panels:  y_p = Σ_q U_pq diag(s_pq) V*_pq x_q
  g : [P, Q, k]      σ-gradients (Eq. 5):
                     g_pq = Σ_b (U_pqᵀ dy_p) ⊙ (V*_pq x_q)
"""

import jax.numpy as jnp


def ptc_forward_ref(u, s, v, x):
    """Blocked photonic projection: y[p] = sum_q U[p,q] @ (s[p,q] * (V*[p,q] @ x[q]))."""
    # vx[p,q] = V*[p,q] @ x[q]   -> [P, Q, k, B]
    vx = jnp.einsum("pqij,qjb->pqib", v, x)
    sv = s[..., None] * vx
    # y[p] = sum_q U[p,q] @ sv[p,q]
    return jnp.einsum("pqij,pqjb->pib", u, sv)


def sigma_grad_ref(u, v, x, dy):
    """Eq. 5 reciprocity gradient: g[p,q,i] = sum_b (Uᵀ dy)[i,b] * (V* x)[i,b]."""
    ut_dy = jnp.einsum("pqji,pjb->pqib", u, dy)  # U^T applied to dy panel
    vx = jnp.einsum("pqij,qjb->pqib", v, x)
    return jnp.sum(ut_dy * vx, axis=-1)


def feedback_ref(u, s, v, dy):
    """Error feedback dx[q] = sum_p W[p,q]ᵀ dy[p] = V*ᵀ diag(s) Uᵀ dy."""
    ut_dy = jnp.einsum("pqji,pjb->pqib", u, dy)
    s_ut = s[..., None] * ut_dy
    # V*ᵀ = V; dx[q] = sum_p V[p,q]ᵀ… einsum with v transposed on (i,j).
    return jnp.einsum("pqij,pqib->qjb", v, s_ut)


def dense_equivalent(u, s, v):
    """Realized dense weight for cross-checking: W_pq = U diag(s) V* per block."""
    w_blocks = jnp.einsum("pqij,pqj,pqjl->pqil", u, s, v)
    p, q, k, _ = w_blocks.shape
    return w_blocks.transpose(0, 2, 1, 3).reshape(p * k, q * k)
