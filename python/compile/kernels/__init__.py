"""L1: Pallas photonic-tensor-core kernels + the pure-jnp oracle."""

from .ptc import feedback, ptc_forward, sigma_grad
from . import ref

__all__ = ["ptc_forward", "sigma_grad", "feedback", "ref"]
