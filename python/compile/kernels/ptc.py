"""L1 Pallas kernels: the blocked photonic-tensor-core hot-spot.

One grid step = one PTC (one k×k block): BlockSpec stages that block's U, Σ,
V* plus the k-row input panel into VMEM and accumulates the k-row output
panel — the HBM↔VMEM schedule standing in for the photonic system's
WDM-parallel PTC array with local buffers (DESIGN.md §Hardware-Adaptation).

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and correctness (vs `ref.py`) is the property under test; real
TPU performance is assessed structurally in DESIGN.md §Perf.

Shapes match ref.py:
  u [P,Q,k,k] · s [P,Q,k] · v [P,Q,k,k] · x [Q,k,B] → y [P,k,B]
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls.


def _fwd_kernel(u_ref, s_ref, v_ref, x_ref, o_ref):
    """y_p += U_pq @ (s_pq ⊙ (V*_pq @ x_q)); q is the fast grid axis."""
    q = pl.program_id(1)
    u = u_ref[0, 0]
    s = s_ref[0, 0]
    v = v_ref[0, 0]
    x = x_ref[0]
    vx = jnp.dot(v, x, preferred_element_type=jnp.float32)
    y = jnp.dot(u, s[:, None] * vx, preferred_element_type=jnp.float32)

    @pl.when(q == 0)
    def _init():
        o_ref[0] = y

    @pl.when(q != 0)
    def _acc():
        o_ref[0] += y


@functools.partial(jax.jit, static_argnames=())
def ptc_forward(u, s, v, x):
    """Blocked projection y[P,k,B] = Σ_q U_pq diag(s_pq) V*_pq x_q."""
    p, q, k, _ = u.shape
    b = x.shape[-1]
    return pl.pallas_call(
        _fwd_kernel,
        grid=(p, q),
        in_specs=[
            pl.BlockSpec((1, 1, k, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, k, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, k, b), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, k, b), jnp.float32),
        interpret=INTERPRET,
    )(u, s, v, x)


def _sigma_grad_kernel(u_ref, v_ref, x_ref, dy_ref, g_ref):
    """Eq. 5: g_pq = Σ_b (U_pqᵀ dy_p) ⊙ (V*_pq x_q) — 2 reciprocal passes
    plus one Hadamard-reduce, exactly the on-chip procedure of Fig. 6."""
    u = u_ref[0, 0]
    v = v_ref[0, 0]
    x = x_ref[0]
    dy = dy_ref[0]
    ut_dy = jnp.dot(u.T, dy, preferred_element_type=jnp.float32)
    vx = jnp.dot(v, x, preferred_element_type=jnp.float32)
    g_ref[0, 0] = jnp.sum(ut_dy * vx, axis=-1)


def sigma_grad(u, v, x, dy):
    """In-situ subspace gradient g[P,Q,k] (Eq. 5)."""
    p, q, k, _ = u.shape
    b = x.shape[-1]
    return pl.pallas_call(
        _sigma_grad_kernel,
        grid=(p, q),
        in_specs=[
            pl.BlockSpec((1, 1, k, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, k, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, k, b), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, k), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((p, q, k), jnp.float32),
        interpret=INTERPRET,
    )(u, v, x, dy)


def _feedback_kernel(u_ref, s_ref, v_ref, dy_ref, o_ref):
    """dx_q += V*ᵀ diag(s) Uᵀ dy_p; p is the fast grid axis."""
    i = pl.program_id(1)  # p index (fast)
    u = u_ref[0, 0]
    s = s_ref[0, 0]
    v = v_ref[0, 0]
    dy = dy_ref[0]
    ut_dy = jnp.dot(u.T, dy, preferred_element_type=jnp.float32)
    dx = jnp.dot(v.T, s[:, None] * ut_dy, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        o_ref[0] = dx

    @pl.when(i != 0)
    def _acc():
        o_ref[0] += dx


def feedback(u, s, v, dy):
    """Error feedback dx[Q,k,B] = Σ_p W_pqᵀ dy_p via the reciprocal mesh."""
    p, q, k, _ = u.shape
    b = dy.shape[-1]
    return pl.pallas_call(
        _feedback_kernel,
        grid=(q, p),
        in_specs=[
            pl.BlockSpec((1, 1, k, k), lambda j, i: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda j, i: (i, j, 0)),
            pl.BlockSpec((1, 1, k, k), lambda j, i: (i, j, 0, 0)),
            pl.BlockSpec((1, k, b), lambda j, i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, b), lambda j, i: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, k, b), jnp.float32),
        interpret=INTERPRET,
    )(u, s, v, dy)
