"""L2: the subspace-ONN compute graph in JAX, built on the L1 kernels.

A model layer is the blocked SVD-operator projection of §3.1: the dense
weight lives only as U[P,Q,k,k] · Σ[P,Q,k] · V*[P,Q,k,k]. U/V* are *trace
constants passed as inputs* (mapped once by PM, frozen during SL), Σ and
biases are the trainable subspace — so the exported train-step artifact
returns exactly the reciprocity gradients of Eq. 5 and nothing else,
matching what the hardware can measure.

`train_step` writes the backward pass out explicitly with the kernels
(sigma_grad + feedback), mirroring rust's `PtcMesh::{sigma_grad, feedback}`
rather than relying on jax autodiff; tests check it against `jax.grad`.
"""

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .kernels import feedback, ptc_forward, sigma_grad


class LayerParams(NamedTuple):
    """One blocked projection layer. Trainable: s, bias. Frozen: u, v."""

    u: jax.Array  # [P, Q, k, k]
    s: jax.Array  # [P, Q, k]
    v: jax.Array  # [P, Q, k, k]
    bias: jax.Array  # [P·k]


def init_layer(key, out_features: int, in_features: int, k: int) -> LayerParams:
    """Random-unitary init (what fab + IC gives you) with SVD-scaled Σ."""
    p = -(-out_features // k)
    q = -(-in_features // k)
    ku, kv, ks = jax.random.split(key, 3)

    def rand_unitaries(kk):
        a = jax.random.normal(kk, (p, q, k, k), dtype=jnp.float32)
        qm, _ = jnp.linalg.qr(a)
        return qm.astype(jnp.float32)

    bound = (6.0 / in_features) ** 0.5
    s = jax.random.uniform(ks, (p, q, k), jnp.float32, -bound, bound)
    return LayerParams(
        u=rand_unitaries(ku), s=s, v=rand_unitaries(kv), bias=jnp.zeros((p * k,), jnp.float32)
    )


def to_panels(x, q: int, k: int):
    """[in, B] → [Q, k, B], zero-padding the feature dim to Q·k."""
    n, b = x.shape
    pad = q * k - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, b), x.dtype)], axis=0)
    return x.reshape(q, k, b)


def from_panels(y, out_features: int):
    """[P, k, B] → [out, B], cropping padding rows."""
    p, k, b = y.shape
    return y.reshape(p * k, b)[:out_features]


def layer_forward(lp: LayerParams, x, out_features: int):
    """One projection layer: panels → PTC kernel → bias. Returns (y, vx_panels_input)."""
    q, k = lp.u.shape[1], lp.u.shape[2]
    xp = to_panels(x, q, k)
    y = ptc_forward(lp.u, lp.s, lp.v, xp)
    y = from_panels(y, out_features) + lp.bias[:out_features, None]
    return y, xp


def mlp_forward(params: Sequence[LayerParams], dims: Sequence[int], x):
    """Subspace MLP forward: ReLU between layers, raw logits at the end.

    `x` is [dims[0], B]; returns logits [dims[-1], B].
    """
    h = x
    for li, lp in enumerate(params):
        h, _ = layer_forward(lp, h, dims[li + 1])
        if li + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def softmax_xent(logits, labels):
    """Mean cross-entropy; logits [C, B], labels int32 [B]."""
    logp = jax.nn.log_softmax(logits, axis=0)
    b = labels.shape[0]
    picked = logp[labels, jnp.arange(b)]
    return -jnp.mean(picked)


def train_step(params: Sequence[LayerParams], dims: Sequence[int], x, labels):
    """Forward + explicit reciprocity backward (Eq. 5).

    Returns (loss, logits, [σ-grad per layer], [bias-grad per layer]) — the
    full per-iteration gradient packet the rust coordinator consumes.
    """
    # Forward, caching input panels and pre-activations.
    h = x
    panels = []
    preacts = []
    for li, lp in enumerate(params):
        y, xp = layer_forward(lp, h, dims[li + 1])
        panels.append(xp)
        preacts.append(y)
        h = jax.nn.relu(y) if li + 1 < len(params) else y

    logits = h
    loss = softmax_xent(logits, labels)
    b = labels.shape[0]
    # dL/dlogits of mean CE: (softmax − onehot)/B.
    probs = jax.nn.softmax(logits, axis=0)
    onehot = jax.nn.one_hot(labels, logits.shape[0], axis=0, dtype=jnp.float32)
    dy = (probs - onehot) / b

    sigma_grads = []
    bias_grads = []
    for li in reversed(range(len(params))):
        lp = params[li]
        p, q, k = lp.s.shape
        out_f = dims[li + 1]
        bias_grads.append(jnp.sum(dy, axis=1))
        # Pad dy rows to P·k panels.
        pad = p * k - dy.shape[0]
        dyp = jnp.concatenate([dy, jnp.zeros((pad, dy.shape[1]), dy.dtype)], axis=0) if pad else dy
        dyp = dyp.reshape(p, k, -1)
        sigma_grads.append(sigma_grad(lp.u, lp.v, panels[li], dyp))
        if li > 0:
            dxp = feedback(lp.u, lp.s, lp.v, dyp)
            dx = dxp.reshape(q * k, -1)[: dims[li]]
            # Backprop through the ReLU between layer li-1 and li.
            dy = dx * (preacts[li - 1][: dims[li]] > 0)
        del out_f
    sigma_grads.reverse()
    bias_grads.reverse()
    return loss, logits, sigma_grads, bias_grads
