//! Quickstart: the three hardware-restricted primitives on one mesh.
//!
//! Builds an 18×18 photonic mesh of 9×9 PTCs under the paper's full noise
//! model, then walks the L2ight stages on it:
//!   1. identity calibration (ZOO to the sign-flip identity Ĩ),
//!   2. parallel mapping of a random target matrix (ZCD + OSP),
//!   3. a few first-order Σ-descent steps against a regression loss,
//! printing fidelity after each. Runs in seconds.
//!
//!   cargo run --release --example quickstart

use l2ight::linalg::{matmul, Mat};
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::stages::ic::{calibrate_mesh, IcConfig};
use l2ight::stages::pm::{map_mesh, PmConfig};
use l2ight::util::{fmt_sig, Rng};

fn main() {
    let mut rng = Rng::new(7);
    let (n, k) = (18usize, 9usize);
    println!("== L2ight quickstart: {n}x{n} mesh of {k}x{k} PTCs, paper noise ==\n");
    let mut mesh = PtcMesh::new(n, n, k, NoiseModel::PAPER, &mut rng);

    // --- Stage 1: identity calibration -----------------------------------
    let before: f64 = mesh
        .ptcs
        .iter_mut()
        .map(|p| {
            let (u, v) = p.identity_mse();
            (u + v) / 2.0
        })
        .sum::<f64>()
        / mesh.ptcs.len() as f64;
    let ic = calibrate_mesh(&mut mesh, &IcConfig::default());
    println!(
        "IC : mean |U|-identity MSE {} -> {}  ({} ZO queries over {} blocks)",
        fmt_sig(before, 3),
        fmt_sig(ic.mean_mse(), 3),
        ic.queries,
        ic.blocks
    );

    // --- Stage 2: parallel mapping ----------------------------------------
    let target = Mat::randn(n, n, 0.5, &mut rng);
    let pm = map_mesh(&mut mesh, &target, &PmConfig::default());
    println!(
        "PM : normalized matrix distance init {} -> after ZO+OSP {}  ({} queries)",
        fmt_sig(pm.err_init, 3),
        fmt_sig(pm.err_osp, 3),
        pm.queries
    );

    // --- Stage 3: subspace (Σ-only) descent -------------------------------
    // Regress the mapped mesh onto a *different* matrix by moving only Σ —
    // the restricted-subspace learnability the paper trades for efficiency.
    let new_target = Mat::randn(n, n, 0.5, &mut rng);
    let x = Mat::randn(n, 32, 1.0, &mut rng);
    let y_want = matmul(&new_target, &x);
    let lr = 0.02f32;
    let mut first = 0.0;
    let mut last = 0.0;
    for it in 0..60 {
        let y = mesh.forward(&x);
        let dy = y.sub(&y_want);
        let loss = dy.fro_norm_sq() / y_want.fro_norm_sq();
        if it == 0 {
            first = loss;
        }
        last = loss;
        let g = mesh.sigma_grad(&x, &dy, None, 1.0);
        let mut sigma = mesh.sigma_flat();
        for (s, gi) in sigma.iter_mut().zip(&g) {
            *s -= lr * gi;
        }
        mesh.set_sigma_flat(&sigma);
    }
    println!(
        "SL : Σ-only regression onto a fresh target, rel loss {} -> {} in 60 steps",
        fmt_sig(first as f64, 3),
        fmt_sig(last as f64, 3)
    );

    let stats = mesh.stats;
    println!(
        "\nhardware cost so far: {} PTC calls, {} accumulation steps",
        stats.total_energy(),
        stats.total_steps()
    );
    println!("done.");
}
