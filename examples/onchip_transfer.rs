//! In-situ transferability in the restricted subspace (paper §4.3.2 /
//! Fig. 14): map a model pretrained on a CIFAR-100-like task, freeze the
//! inherited unitaries, and adapt to a CIFAR-10-like task by training the
//! singular values only — versus subspace training from scratch.
//!
//! The two synthetic tasks share class templates (same `template_seed`), so
//! the source really contains features of the target, the property the
//! paper's transfer result relies on.
//!
//!   cargo run --release --example onchip_transfer

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::pm::{copy_aux_params, map_model, PmConfig};
use l2ight::stages::sl::{train, OptKind, SlConfig};
use l2ight::util::{fmt_sig, Rng};
use l2ight::zoo::ZoConfig;

fn main() {
    let t0 = std::time::Instant::now();
    let shared_templates = 0x7ea_c4e5;
    // Source task: more classes, same underlying feature family.
    let (src_train, src_test) = SynthSpec::new(DatasetKind::FashionLike, 512, 256)
        .with_classes(20)
        .with_seeds(shared_templates, 1)
        .generate();
    // Target task: 10 of the same template family.
    let (dst_train, dst_test) = SynthSpec::new(DatasetKind::FashionLike, 384, 256)
        .with_classes(10)
        .with_seeds(shared_templates, 2)
        .generate();

    println!("== on-chip subspace transfer: 20-class source -> 10-class target ==\n");

    // Pretrain digitally on the source task (the offline model).
    let mut rng = Rng::new(3);
    let mut digital = build_model(ModelArch::CnnL, EngineKind::Digital, 20, 0.35, &mut rng);
    let pre_cfg = SlConfig {
        epochs: 8,
        batch: 32,
        opt: OptKind::Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 },
        eval_every: 0,
        ..SlConfig::default()
    };
    let pre = train(&mut digital, &src_train, &src_test, &pre_cfg);
    println!("source pretrain (digital, 20-class): acc {:.3}", pre.final_test_acc);

    // Map onto the chip; swap the classifier head for the 10-class target
    // by building a 10-class photonic model and mapping the *backbone*
    // layers; the head starts fresh (standard transfer practice).
    let kind = EngineKind::Photonic { k: 9, noise: NoiseModel::PAPER };
    let mut transfer = build_model(ModelArch::CnnL, kind, 20, 0.35, &mut rng);
    let pm_cfg = PmConfig {
        zo: ZoConfig { iters: 20, ..PmConfig::default().zo },
        alternations: 2,
        ..PmConfig::default()
    };
    let pm = map_model(&mut transfer, &mut digital, &pm_cfg);
    copy_aux_params(&mut transfer, &mut digital);
    println!("parallel mapping: rel err {}", fmt_sig(pm.err_osp, 3));
    // 20-class head over a 10-class task: labels 0..10 are a subset, so the
    // model is directly usable; Σ-training will adapt the head.

    // Transfer: train Σ only on the target task (inherited unitaries fixed
    // by construction — subspace learning can't touch them).
    let sl_cfg = SlConfig {
        epochs: 10,
        batch: 32,
        opt: OptKind::AdamW { lr: 5e-4, weight_decay: 1e-2 },
        eval_every: 1,
        seed: 9,
        ..SlConfig::default()
    };
    let r_transfer = train(&mut transfer, &dst_train, &dst_test, &sl_cfg);

    // Control: identical photonic model trained from scratch on the target.
    let mut scratch = build_model(ModelArch::CnnL, kind, 20, 0.35, &mut Rng::new(77));
    let scratch_cfg = SlConfig {
        opt: OptKind::AdamW { lr: 2e-3, weight_decay: 1e-2 },
        ..sl_cfg.clone()
    };
    let r_scratch = train(&mut scratch, &dst_train, &dst_test, &scratch_cfg);

    println!("\n            acc-vs-steps (cumulative steps, test acc)");
    println!("  transfer: {:?}", fmt_curve(&r_transfer.acc_vs_steps()));
    println!("  scratch : {:?}", fmt_curve(&r_scratch.acc_vs_steps()));
    println!(
        "\nfinal: transfer {:.3} vs scratch {:.3}  (paper: transfer 1-2% higher, 3-5x fewer steps)",
        r_transfer.final_test_acc, r_scratch.final_test_acc
    );
    // Steps to reach the scratch model's final accuracy.
    let target_acc = r_scratch.final_test_acc;
    let steps_transfer = steps_to_reach(&r_transfer.acc_vs_steps(), target_acc);
    let steps_scratch = steps_to_reach(&r_scratch.acc_vs_steps(), target_acc);
    match (steps_transfer, steps_scratch) {
        (Some(a), Some(b)) => println!(
            "steps to reach scratch-final acc {:.3}: transfer {} vs scratch {} ({:.1}x fewer)",
            target_acc,
            fmt_sig(a, 3),
            fmt_sig(b, 3),
            b / a.max(1e-9)
        ),
        _ => println!("transfer curve did not cross scratch-final accuracy in this budget"),
    }
    println!("\ndone in {:.1}s", t0.elapsed().as_secs_f64());
}

fn fmt_curve(c: &[(f64, f32)]) -> Vec<String> {
    c.iter().map(|(s, a)| format!("({}, {:.3})", fmt_sig(*s, 3), a)).collect()
}

fn steps_to_reach(c: &[(f64, f32)], acc: f32) -> Option<f64> {
    c.iter().find(|(_, a)| *a >= acc).map(|(s, _)| *s)
}
