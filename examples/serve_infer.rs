//! Batched inference serving, exercised the way a deployment would:
//! concurrent clients submit single samples, the admission queue coalesces
//! them into panels, replicas execute them, and the client sees latency
//! percentiles.
//!
//! Default path — the native batched serving engine (`l2ight::serve`),
//! no artifacts required:
//!
//!   cargo run --release --example serve_infer
//!
//! Legacy PJRT path — the same service shape over the compiled artifacts
//! and the `coordinator::Batcher` (PJRT client is thread-affine, so the
//! Runtime lives on the batcher's worker thread):
//!
//!   make artifacts && cargo run --release --example serve_infer -- --pjrt

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use l2ight::coordinator::{Batcher, BatcherConfig};
use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::unitary::ReckMesh;
use l2ight::photonics::NoiseModel;
use l2ight::runtime::{default_artifact_dir, ArgValue, Runtime};
use l2ight::serve::{ServeConfig, ServeEngine};
use l2ight::util::Rng;

const DIMS: [usize; 4] = [8, 16, 16, 4];
const K: usize = 4;
const BATCH: usize = 16;
const CLIENTS: usize = 8;
const PER_CLIENT: usize = 64;

fn main() {
    if std::env::args().any(|a| a == "--pjrt") {
        run_pjrt();
    } else {
        run_native();
    }
}

/// Native path: photonic model clones behind the serve engine.
fn run_native() {
    println!("== native batched serving (l2ight::serve) ==");
    let kind = EngineKind::Photonic { k: K, noise: NoiseModel::PAPER };
    let model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(21));
    let engine = ServeEngine::start(
        model,
        (8, 1, 1),
        ServeConfig {
            replicas: 2,
            max_batch: BATCH,
            max_wait: Duration::from_millis(1),
            queue_cap: 4096,
            reload: None,
        },
    );

    let (ds, _) = SynthSpec::quick(DatasetKind::VowelLike, 512, 1).generate();
    let ds = Arc::new(ds);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..CLIENTS {
            let engine = &engine;
            let ds = Arc::clone(&ds);
            scope.spawn(move || {
                for i in 0..PER_CLIENT {
                    let sample = ds.sample((t * PER_CLIENT + i) % ds.n).to_vec();
                    let resp = engine.infer(sample).expect("serve");
                    assert_eq!(resp.output.len(), 4);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let stats = engine.shutdown();

    let total = (CLIENTS * PER_CLIENT) as u64;
    println!("\nserved {} requests in {:.1} ms", stats.served, wall.as_secs_f64() * 1e3);
    println!("throughput     : {:.0} req/s", stats.served as f64 / wall.as_secs_f64());
    println!(
        "batches        : {} (mean size {:.1}, {} coalesced >1 request)",
        stats.batches,
        stats.mean_batch(),
        stats.multi_request_batches()
    );
    println!("latency p50    : {:.2} ms", stats.percentile_ms(50.0));
    println!("latency p90    : {:.2} ms", stats.percentile_ms(90.0));
    println!("latency p99    : {:.2} ms", stats.percentile_ms(99.0));
    assert_eq!(stats.served, total, "a request went unanswered");
    assert_eq!(stats.shed, 0, "ample queue_cap must not shed");
    assert!(stats.mean_batch() > 1.5, "batching never coalesced");
    println!("done.");
}

/// Legacy path: the PJRT artifacts behind the coordinator batcher.
fn run_pjrt() {
    // Probe the artifacts up front for a friendly error; the serving
    // Runtime itself is created on the batcher's worker thread (the PJRT
    // client is thread-affine — not Send).
    match Runtime::new(&default_artifact_dir()) {
        Ok(rt) => {
            println!("== batched inference service over vowel_mlp_fwd_b{BATCH} ==");
            println!("PJRT platform: {}", rt.platform());
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e:#}); run `make artifacts` first.");
            std::process::exit(1);
        }
    }

    // Model parameters (random-unitary init — serving doesn't care).
    let mut rng = Rng::new(21);
    let mut params: Vec<Vec<f32>> = Vec::new();
    for li in 0..DIMS.len() - 1 {
        let p = DIMS[li + 1].div_ceil(K);
        let q = DIMS[li].div_ceil(K);
        let (mut u, mut v, mut s) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..p * q {
            u.extend_from_slice(&ReckMesh::random(K, &mut rng).synthesize().data);
            v.extend_from_slice(&ReckMesh::random(K, &mut rng).synthesize().data);
            for _ in 0..K {
                s.push(rng.uniform_range(-0.8, 0.8) as f32);
            }
        }
        params.push(u);
        params.push(s);
        params.push(v);
        params.push(vec![0.0; p * K]);
    }

    // The batch function: pack ≤BATCH requests into one artifact call. The
    // Runtime is constructed on the worker thread via start_with_init.
    let params = Arc::new(params);
    let init = {
        let params = Arc::clone(&params);
        move || {
            let mut rt = Runtime::new(&default_artifact_dir()).expect("runtime");
            move |inputs: &[Vec<f32>]| -> Vec<Vec<f32>> {
                let f = DIMS[0];
                let classes = DIMS[DIMS.len() - 1];
                let mut x = vec![0.0f32; f * BATCH];
                for (col, inp) in inputs.iter().enumerate() {
                    for (r, &v) in inp.iter().enumerate() {
                        x[r * BATCH + col] = v;
                    }
                }
                let mut args: Vec<ArgValue> = params.iter().map(|p| ArgValue::F32(p)).collect();
                args.push(ArgValue::F32(&x));
                let logits = rt
                    .call1_f32(&format!("vowel_mlp_fwd_b{BATCH}"), &args)
                    .expect("artifact call");
                (0..inputs.len())
                    .map(|col| (0..classes).map(|c| logits[c * BATCH + col]).collect())
                    .collect()
            }
        }
    };

    let batcher = Batcher::start_with_init(
        BatcherConfig { max_batch: BATCH, max_wait: Duration::from_millis(1) },
        init,
    );

    // Load: CLIENTS client threads, PER_CLIENT requests each.
    let (ds, _) = SynthSpec::quick(DatasetKind::VowelLike, 512, 1).generate();
    let ds = Arc::new(ds);
    let latencies = Arc::new(Mutex::new(Vec::<Duration>::new()));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..CLIENTS {
            let batcher = &batcher;
            let ds = Arc::clone(&ds);
            let latencies = Arc::clone(&latencies);
            scope.spawn(move || {
                for i in 0..PER_CLIENT {
                    let sample = ds.sample((t * PER_CLIENT + i) % ds.n).to_vec();
                    let start = Instant::now();
                    let logits = batcher.infer(sample);
                    let dt = start.elapsed();
                    assert_eq!(logits.len(), DIMS[DIMS.len() - 1]);
                    latencies.lock().unwrap().push(dt);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let stats = batcher.shutdown();

    let mut lats: Vec<f64> =
        latencies.lock().unwrap().iter().map(|d| d.as_secs_f64() * 1e3).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
    println!("\nserved {} requests in {:.1} ms", stats.requests, wall.as_secs_f64() * 1e3);
    println!("throughput     : {:.0} req/s", stats.requests as f64 / wall.as_secs_f64());
    println!(
        "batches        : {} (mean size {:.1}, max {})",
        stats.batches,
        stats.mean_batch(),
        stats.max_observed_batch
    );
    println!("latency p50    : {:.2} ms", pct(0.50));
    println!("latency p90    : {:.2} ms", pct(0.90));
    println!("latency p99    : {:.2} ms", pct(0.99));
    assert!(stats.mean_batch() > 1.5, "batching never coalesced");
    println!("done.");
}
