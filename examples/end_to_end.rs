//! End-to-end full-system driver — all three layers composing.
//!
//! Part A (AOT / PJRT path): loads `artifacts/` produced by `make artifacts`
//! (L1 Pallas kernels → L2 jax graph → HLO text), compiles them on the PJRT
//! CPU client, and runs *subspace training entirely through the compiled
//! executables* — python is not running anywhere in this process.
//!
//! Part B (native-simulator path): the full three-stage L2ight flow on a
//! CNN: digital pretraining on a synthetic MNIST-shaped task, identity
//! calibration, parallel mapping, multi-level sparse subspace learning —
//! logging the loss curve, accuracy, and the Appendix-G cost profile.
//!
//!   make artifacts && cargo run --release --example end_to_end

use l2ight::coordinator::{run_job, JobConfig, MetricSink, PjrtMlpTrainer, Protocol};
use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::ModelArch;
use l2ight::photonics::NoiseModel;
use l2ight::runtime::{default_artifact_dir, Runtime};
use l2ight::util::{fmt_sig, Rng};

fn main() {
    // ---------------- Part A: training through the PJRT artifacts --------
    println!("== Part A: subspace training through AOT/PJRT artifacts ==");
    let dir = default_artifact_dir();
    match Runtime::new(&dir) {
        Err(e) => {
            println!("  artifacts unavailable ({e:#}); run `make artifacts` first.\n");
        }
        Ok(rt) => {
            println!("  PJRT platform: {}", rt.platform());
            let mut trainer = PjrtMlpTrainer::new(rt, 11).expect("trainer");
            println!("  trainable subspace params: {}", trainer.trainable_params());
            let (train_set, test_set) = SynthSpec::quick(DatasetKind::VowelLike, 256, 128)
                .with_difficulty(0.6)
                .generate();
            let mut rng = Rng::new(5);
            let acc0 = trainer.evaluate(&test_set).expect("eval");
            println!("  random-init accuracy: {acc0:.3}");
            trainer.set_lr(5e-3);
            for epoch in 0..12 {
                let loss = trainer.train_epoch(&train_set, &mut rng).expect("epoch");
                if epoch % 3 == 2 {
                    let acc = trainer.evaluate(&test_set).expect("eval");
                    println!("  epoch {epoch:2}  loss {loss:.4}  test acc {acc:.3}");
                }
            }
            let acc1 = trainer.evaluate(&test_set).expect("eval");
            println!("  PJRT-path subspace training: acc {acc0:.3} -> {acc1:.3}\n");
            assert!(acc1 > acc0, "PJRT training must improve accuracy");
        }
    }

    // ---------------- Part B: the full three-stage flow ------------------
    println!("== Part B: full L2ight flow (native simulator, CNN-S / synthetic MNIST) ==");
    let cfg = JobConfig {
        arch: ModelArch::CnnS,
        dataset: DatasetKind::MnistLike,
        protocol: Protocol::L2ight,
        k: 9,
        noise: NoiseModel::PAPER,
        width: 1.0,
        n_train: 512,
        n_test: 256,
        pretrain_epochs: 8,
        epochs: 6,
        batch: 32,
        alpha_w: 0.6,
        alpha_c: 1.0,
        alpha_d: 0.5,
        zo_budget: 0.25,
        seed: 42,
    };
    let mut sink = MetricSink::memory();
    let t0 = std::time::Instant::now();
    let s = run_job(&cfg, &mut sink);
    println!("  completed in {:.1}s", t0.elapsed().as_secs_f64());
    println!("  params         : {} trainable Σ / {} dense-equivalent", s.trainable_params, s.total_params);
    println!("  pretrain acc   : {:.3}", s.pretrain_acc.unwrap_or(f32::NAN));
    println!("  IC mean MSE    : {}", fmt_sig(s.ic_mse.unwrap_or(f64::NAN), 3));
    println!("  PM rel error   : {}", fmt_sig(s.pm_err.unwrap_or(f64::NAN), 3));
    println!("  mapped acc     : {:.3}", s.mapped_acc.unwrap_or(f32::NAN));
    if let Some(sl) = &s.sl {
        println!("  SL loss curve  :");
        for e in &sl.epochs {
            println!(
                "    epoch {:2}  loss {:.4}  train acc {:.3}  test acc {}  (epoch energy {})",
                e.epoch,
                e.loss,
                e.train_acc,
                e.test_acc.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
                fmt_sig(e.cost.total_energy(), 3),
            );
        }
    }
    println!("  final acc      : {:.3}  (best {:.3})", s.final_acc, s.best_acc);
    println!(
        "  SL hardware    : {} PTC calls ({} fwd / {} σ-grad / {} feedback), {} steps",
        fmt_sig(s.cost.total_energy(), 4),
        fmt_sig(s.cost.fwd_energy, 4),
        fmt_sig(s.cost.wgrad_energy, 4),
        fmt_sig(s.cost.fbk_energy, 4),
        fmt_sig(s.cost.total_steps(), 4)
    );
    println!("  IC+PM queries  : {}", s.zo_queries);
    let mapped = s.mapped_acc.unwrap_or(0.0);
    assert!(
        s.final_acc >= mapped - 0.05,
        "sparse SL should not degrade the mapped model: {mapped} -> {}",
        s.final_acc
    );
    println!("\nEXPERIMENTS.md row: | end-to-end CNN-S | mapped {:.3} | final {:.3} | energy {} | steps {} |",
        mapped, s.final_acc, fmt_sig(s.cost.total_energy(), 4), fmt_sig(s.cost.total_steps(), 4));
}
