//! Integration: the full three-stage pipeline on tiny models, protocol
//! orderings, and checkpoint interplay — everything above module level
//! that doesn't need PJRT artifacts.

use l2ight::coordinator::{
    load_model_state, run_job, save_model_state, JobConfig, MetricSink, Protocol,
};
use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::ic::{calibrate_model, IcConfig};
use l2ight::stages::pm::{copy_aux_params, map_model, PmConfig};
use l2ight::stages::sl::{train, SlConfig};
use l2ight::util::Rng;

fn tiny_cfg(protocol: Protocol) -> JobConfig {
    JobConfig {
        arch: ModelArch::MlpVowel,
        dataset: DatasetKind::VowelLike,
        protocol,
        k: 4,
        noise: NoiseModel::PAPER,
        width: 0.5,
        n_train: 128,
        n_test: 64,
        pretrain_epochs: 8,
        epochs: 5,
        batch: 16,
        alpha_w: 0.6,
        alpha_c: 1.0,
        alpha_d: 0.3,
        zo_budget: 0.2,
        seed: 11,
        robustness: None,
        sharding: None,
        variation: None,
    }
}

#[test]
fn l2ight_beats_scratch_in_steps_at_same_accuracy() {
    // The core Fig. 11 claim shape: mapping first means far less SL work.
    let mut sink = MetricSink::memory();
    let full = run_job(&tiny_cfg(Protocol::L2ight), &mut sink);
    let mut scratch_cfg = tiny_cfg(Protocol::L2ightSlScratch);
    scratch_cfg.epochs = 5;
    let scratch = run_job(&scratch_cfg, &mut sink);
    assert!(
        full.best_acc >= scratch.best_acc - 0.05,
        "full flow should match or beat scratch: {} vs {}",
        full.best_acc,
        scratch.best_acc
    );
}

#[test]
fn noise_hurts_unmapped_but_mapping_recovers() {
    // Fig. 1(b)/insight (2): under PAPER noise an SVD-programmed model is
    // corrupted; PM recovers most of the pretrained accuracy.
    let mut sink = MetricSink::memory();
    let s = run_job(&tiny_cfg(Protocol::L2ight), &mut sink);
    let (Some(pre), Some(mapped)) = (s.pretrain_acc, s.mapped_acc) else {
        panic!(
            "pretrain/mapped accuracy missing; skipped stages: {:?}",
            s.skipped_stages
        );
    };
    assert!(pre > 0.5, "pretraining failed: {pre}");
    assert!(mapped > pre - 0.2, "mapping failed to recover: {pre} -> {mapped}");
}

#[test]
fn feedback_sampling_cuts_cost_without_acc_collapse() {
    let mut sink = MetricSink::memory();
    let mut dense = tiny_cfg(Protocol::L2ightSlScratch);
    dense.alpha_w = 1.0;
    dense.alpha_d = 0.0;
    let mut sparse = dense.clone();
    sparse.alpha_w = 0.5;
    let rd = run_job(&dense, &mut sink);
    let rs = run_job(&sparse, &mut sink);
    assert!(
        rs.cost.total_energy() < rd.cost.total_energy(),
        "sampling saved nothing: {} vs {}",
        rs.cost.total_energy(),
        rd.cost.total_energy()
    );
    assert!(
        rs.best_acc > rd.best_acc - 0.15,
        "sampling collapsed accuracy: {} vs {}",
        rs.best_acc,
        rd.best_acc
    );
}

#[test]
fn pipeline_survives_checkpoint_roundtrip_mid_flow() {
    // IC+PM a model, checkpoint it, restore into a fresh instance, and
    // verify SL continues from the restored state (same eval accuracy).
    let mut rng = Rng::new(21);
    let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) };
    let mut digital = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut rng);
    let (train_set, test_set) =
        SynthSpec::quick(DatasetKind::VowelLike, 96, 48).with_difficulty(0.4).generate();
    let pre_cfg = SlConfig {
        opt: l2ight::stages::sl::OptKind::Sgd { lr: 0.1, momentum: 0.9, weight_decay: 0.0 },
        ..SlConfig::quick(6, 16)
    };
    train(&mut digital, &train_set, &test_set, &pre_cfg);

    let mut chip = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
    calibrate_model(&mut chip, &IcConfig::quick());
    map_model(&mut chip, &mut digital, &PmConfig::quick());
    copy_aux_params(&mut chip, &mut digital);
    let acc_before = test_set.evaluate(&mut chip, 16);

    let path = std::env::temp_dir().join(format!("l2ight_pipe_{}.ckpt", std::process::id()));
    save_model_state(&mut chip, &path).unwrap();
    let mut restored = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(999));
    load_model_state(&mut restored, &path).unwrap();
    std::fs::remove_file(&path).ok();

    // NOTE: restored model has different *device instances* (γ, Φ_b are
    // fab-time randomness), but quant-only noise is deterministic, so the
    // restored programmed state realizes the same transfer function.
    let acc_after = test_set.evaluate(&mut restored, 16);
    assert!(
        (acc_before - acc_after).abs() < 1e-6,
        "restore changed behaviour: {acc_before} vs {acc_after}"
    );

    // And SL still trains on it.
    let r = train(&mut restored, &train_set, &test_set, &SlConfig::quick(2, 16));
    assert!(r.final_test_acc >= acc_after - 0.1);
}

#[test]
fn job_config_roundtrips_through_driver_metrics() {
    let mut sink = MetricSink::memory();
    let cfg = tiny_cfg(Protocol::L2ightSlScratch);
    run_job(&cfg, &mut sink);
    let start = sink.last("job_start").expect("job_start event");
    let recorded = start.get("config").expect("config recorded");
    let parsed = JobConfig::from_json(recorded).expect("config parses back");
    assert_eq!(parsed.protocol, cfg.protocol);
    assert_eq!(parsed.k, cfg.k);
    assert_eq!(parsed.seed, cfg.seed);
}

#[test]
fn determinism_same_seed_same_result() {
    let mut s1 = MetricSink::memory();
    let mut s2 = MetricSink::memory();
    let cfg = {
        let mut c = tiny_cfg(Protocol::L2ightSlScratch);
        c.epochs = 2;
        c
    };
    let a = run_job(&cfg, &mut s1);
    let b = run_job(&cfg, &mut s2);
    assert_eq!(a.final_acc, b.final_acc);
    assert_eq!(a.cost.total_energy(), b.cost.total_energy());
}
