//! Parallel-engine equivalence: the pooled hot paths must be numerically
//! indistinguishable from the serial engine — `threads=N` vs `threads=1`
//! bit-identical (work is partitioned by output region, never by summation
//! order), and both within 1e-6-grade tolerance of straightforward dense
//! reference formulas. Plus pool edge cases at the integration level.

use l2ight::linalg::{matmul, matmul_at_b, Mat};
use l2ight::photonics::mesh::{crop_rows, pad_rows, slice_rows};
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::sampling::{FeedbackSampler, FeedbackStrategy, Normalization};
use l2ight::util::pool::ThreadPool;
use l2ight::util::prop::{assert_close, quickcheck};
use l2ight::util::Rng;

/// Straight-line reference for the Eq. 5 subspace gradient, built from the
/// mesh's realized unitaries and plain `Mat` products.
fn sigma_grad_reference(mesh: &mut PtcMesh, x: &Mat, dy: &Mat, scale: f32) -> Vec<f32> {
    let (k, p, q) = (mesh.k, mesh.p, mesh.q);
    let xp = pad_rows(x, q * k);
    let dyp = pad_rows(dy, p * k);
    let b = x.cols;
    let mut grad = vec![0.0f32; p * q * k];
    for pi in 0..p {
        for qi in 0..q {
            let dyb = slice_rows(&dyp, pi * k, k);
            let xb = slice_rows(&xp, qi * k, k);
            let ptc = &mut mesh.ptcs[pi * q + qi];
            let (u, v) = ptc.realized_uv();
            let uty = matmul_at_b(u, &dyb);
            let vx = matmul(v, &xb);
            for i in 0..k {
                let s: f32 = (0..b).map(|c| uty[(i, c)] * vx[(i, c)]).sum();
                grad[(pi * q + qi) * k + i] = s * scale;
            }
        }
    }
    grad
}

fn random_mesh(rng: &mut Rng, size: usize) -> (PtcMesh, Mat, Mat) {
    let k = 2 + size % 5;
    let rows = k + 1 + size % 37;
    let cols = k + 1 + (size / 2) % 29;
    let b = 1 + size % 21;
    let w = Mat::randn(rows, cols, 0.5, rng);
    let mut mesh = PtcMesh::new(rows, cols, k, NoiseModel::PAPER, rng);
    mesh.program_from_dense(&w);
    let x = Mat::randn(cols, b, 1.0, rng);
    let dy = Mat::randn(rows, b, 1.0, rng);
    (mesh, x, dy)
}

#[test]
fn prop_forward_is_thread_count_invariant_and_matches_dense() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    quickcheck(
        "forward: threads=1 == threads=N == dense",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, x, _) = case;
            let mut m1 = mesh.clone();
            let mut m2 = mesh.clone();
            let y1 = m1.forward_masked_on(&serial, x, None, 1.0);
            let y2 = m2.forward_masked_on(&wide, x, None, 1.0);
            assert_close(&y1.data, &y2.data, 0.0, 0.0)
                .map_err(|e| format!("threads=1 vs threads=N: {e}"))?;
            let dense = matmul(&m1.to_dense(), x);
            assert_close(&y1.data, &dense.data, 1e-4, 1e-4)
                .map_err(|e| format!("vs dense: {e}"))
        },
    );
}

#[test]
fn prop_sigma_grad_is_thread_count_invariant_and_matches_reference() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    quickcheck(
        "sigma_grad: threads=1 == threads=N == reference",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, x, dy) = case;
            let mut m1 = mesh.clone();
            let mut m2 = mesh.clone();
            let g1 = m1.sigma_grad_on(&serial, x, dy, None, 1.5);
            let g2 = m2.sigma_grad_on(&wide, x, dy, None, 1.5);
            assert_close(&g1, &g2, 0.0, 0.0)
                .map_err(|e| format!("threads=1 vs threads=N: {e}"))?;
            let mut m3 = mesh.clone();
            let gref = sigma_grad_reference(&mut m3, x, dy, 1.5);
            assert_close(&g1, &gref, 1e-5, 1e-5).map_err(|e| format!("vs reference: {e}"))
        },
    );
}

#[test]
fn prop_feedback_is_thread_count_invariant_and_matches_wt_dy() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    quickcheck(
        "feedback: threads=1 == threads=N == Wᵀ·dy",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, _, dy) = case;
            let mut m1 = mesh.clone();
            let mut m2 = mesh.clone();
            let dx1 = m1.feedback_on(&serial, dy, None, 1.0);
            let dx2 = m2.feedback_on(&wide, dy, None, 1.0);
            assert_close(&dx1.data, &dx2.data, 0.0, 0.0)
                .map_err(|e| format!("threads=1 vs threads=N: {e}"))?;
            // Reference: pad dy to the block grid, multiply by the padded
            // realized weight transposed, crop to the true input width.
            let (k, p, q) = (m1.k, m1.p, m1.q);
            let dense = m1.to_dense();
            let wp = {
                let mut w = Mat::zeros(p * k, q * k);
                w.set_block(0, 0, &dense);
                w
            };
            let expect = crop_rows(&matmul(&wp.t(), &pad_rows(dy, p * k)), m1.cols);
            assert_close(&dx1.data, &expect.data, 1e-4, 1e-4)
                .map_err(|e| format!("vs dense Wᵀdy: {e}"))
        },
    );
}

#[test]
fn prop_masked_feedback_and_forward_thread_invariant() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(3);
    quickcheck(
        "masked paths: threads=1 == threads=N",
        |rng: &mut Rng, size: usize| {
            let (mesh, x, dy) = random_mesh(rng, size);
            let sampler = FeedbackSampler::new(FeedbackStrategy::BTopK, 0.5, Normalization::Exp);
            let mask = sampler.draw(mesh.p, mesh.q, &mesh.block_norms_sq(), rng);
            let fwd_mask: Vec<bool> = (0..mesh.p * mesh.q).map(|i| i % 3 != 0).collect();
            (mesh, x, dy, mask.keep, mask.scale, fwd_mask)
        },
        |case| {
            let (mesh, x, dy, keep, scale, fwd_mask) = case;
            let mut m1 = mesh.clone();
            let mut m2 = mesh.clone();
            let dx1 = m1.feedback_on(&serial, dy, Some(keep), *scale);
            let dx2 = m2.feedback_on(&wide, dy, Some(keep), *scale);
            assert_close(&dx1.data, &dx2.data, 0.0, 0.0)
                .map_err(|e| format!("masked feedback: {e}"))?;
            let y1 = m1.forward_masked_on(&serial, x, Some(fwd_mask), 2.0);
            let y2 = m2.forward_masked_on(&wide, x, Some(fwd_mask), 2.0);
            assert_close(&y1.data, &y2.data, 0.0, 0.0)
                .map_err(|e| format!("masked forward: {e}"))?;
            // Stats (the Appendix-G counters) must also be thread-invariant.
            if m1.stats != m2.stats {
                return Err(format!("stats diverged: {:?} vs {:?}", m1.stats, m2.stats));
            }
            Ok(())
        },
    );
}

#[test]
fn column_sampled_sigma_grad_thread_invariant() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(4);
    let mut rng = Rng::new(0xc01);
    let (mesh, x, dy) = random_mesh(&mut rng, 60);
    let col_keep: Vec<bool> = (0..x.cols).map(|c| c % 2 == 0).collect();
    let mut m1 = mesh.clone();
    let mut m2 = mesh;
    let g1 = m1.sigma_grad_on(&serial, &x, &dy, Some(&col_keep), 2.0);
    let g2 = m2.sigma_grad_on(&wide, &x, &dy, Some(&col_keep), 2.0);
    assert_close(&g1, &g2, 0.0, 0.0).unwrap();
}

#[test]
fn pool_edge_cases_through_mesh() {
    // 1 thread, more threads than blocks, and an empty-batch forward all
    // behave; a 1-block mesh exercises the degenerate grid.
    let one = ThreadPool::new(1);
    let many = ThreadPool::new(16);
    let mut rng = Rng::new(0xedce);
    let w = Mat::randn(4, 4, 0.5, &mut rng);
    let mut mesh = PtcMesh::new(4, 4, 4, NoiseModel::IDEAL, &mut rng);
    mesh.program_from_dense(&w);
    let x = Mat::randn(4, 3, 1.0, &mut rng);
    let y_one = mesh.clone().forward_masked_on(&one, &x, None, 1.0);
    let y_many = mesh.clone().forward_masked_on(&many, &x, None, 1.0);
    assert_close(&y_one.data, &y_many.data, 0.0, 0.0).unwrap();
    // Empty feedback mask ⇒ empty pooled work list per strip.
    let dy = Mat::randn(4, 3, 1.0, &mut rng);
    let mask = vec![false; 1];
    let dx = mesh.feedback_on(&many, &dy, Some(&mask), 1.0);
    assert_eq!(dx.fro_norm(), 0.0);
}
