//! Serving-path determinism and backpressure contracts.
//!
//! The batched serving engine coalesces concurrent single-sample requests
//! into column panels for `ProjEngine::forward_packed`. These tests pin
//! the three properties the engine advertises:
//!
//! 1. **Bitwise batching equivalence** — a coalesced batch produces the
//!    same bits as per-sample forwards, at every batch size, partition,
//!    and replica count (within one SIMD dispatch level). This holds
//!    because every kernel accumulates each output element in a fixed
//!    k-order independent of the panel's column count (`linalg::simd`).
//! 2. **Version atomicity under hot-reload** — a batch serves exactly one
//!    parameter version; outputs always match the version they are
//!    tagged with, bit for bit.
//! 3. **Shed-not-block** — a full admission queue rejects immediately;
//!    everything admitted is served; the accounting loop closes
//!    (`submitted == served`, every shed counted).
//!
//! Thread-count coverage: the serve path runs on `util::pool::global()`,
//! which is sized once per process from `L2IGHT_THREADS`. CI therefore
//! runs this whole binary twice — `L2IGHT_THREADS=1` and `=4` — rather
//! than varying the pool in-process (see `.github/workflows/ci.yml`,
//! serve-smoke job).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use l2ight::coordinator::save_model_state;
use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, Model, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::serve::{
    AdmissionConfig, AdmissionQueue, ReloadConfig, Replica, ServeConfig, ServeEngine, ServeError,
};
use l2ight::util::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn feature_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.normal() as f32).collect()).collect()
}

/// Per-sample reference forwards through a private replica.
fn per_sample(model: &Model, shape: (usize, usize, usize), inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let mut r = Replica::new(0, model.clone(), shape);
    inputs.iter().map(|x| r.infer_batch(&[x.as_slice()]).remove(0)).collect()
}

#[test]
fn batched_panel_forward_is_bitwise_per_sample() {
    let engines = [
        ("digital", EngineKind::Digital),
        ("photonic-k4", EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER }),
    ];
    for (name, kind) in engines {
        let model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(31));
        let shape = (8, 1, 1);
        let inputs = feature_inputs(32, 8, 0xfeed);
        let reference = per_sample(&model, shape, &inputs);

        // One big batch, and two uneven partitions of the same stream:
        // every split must reproduce the per-sample bits.
        for chunk in [32usize, 8, 5] {
            let mut r = Replica::new(0, model.clone(), shape);
            let mut got = Vec::new();
            for block in inputs.chunks(chunk) {
                let refs: Vec<&[f32]> = block.iter().map(|v| v.as_slice()).collect();
                got.extend(r.infer_batch(&refs));
            }
            for (i, (g, want)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    bits(g),
                    bits(want),
                    "{name}: request {i} diverged under batch chunk {chunk}"
                );
            }
        }
    }
}

#[test]
fn image_batches_match_per_sample_bitwise() {
    let (ds, _) = SynthSpec::quick(DatasetKind::MnistLike, 8, 1).generate();
    let model =
        build_model(ModelArch::CnnS, EngineKind::Digital, ds.classes, 0.5, &mut Rng::new(7));
    let shape = (ds.c, ds.h, ds.w);
    let inputs: Vec<Vec<f32>> = (0..6).map(|i| ds.sample(i).to_vec()).collect();
    let reference = per_sample(&model, shape, &inputs);

    let mut r = Replica::new(0, model.clone(), shape);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let got = r.infer_batch(&refs);
    for (i, (g, want)) in got.iter().zip(&reference).enumerate() {
        assert_eq!(bits(g), bits(want), "image request {i} diverged when batched");
    }
}

#[test]
fn engine_responses_are_bitwise_per_sample_at_every_replica_count() {
    let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER };
    let model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(31));
    let shape = (8, 1, 1);
    let inputs = feature_inputs(32, 8, 0xfeed);
    let reference = per_sample(&model, shape, &inputs);

    for replicas in [1usize, 2, 3] {
        let engine = ServeEngine::start(
            model.clone(),
            shape,
            ServeConfig {
                replicas,
                max_batch: 8,
                max_wait: Duration::from_millis(25),
                queue_cap: 1024,
                reload: None,
            },
        );
        // Burst-submit everything, then drain: the queue coalesces what it
        // can, and every response must still carry per-sample bits.
        let rxs: Vec<_> = inputs
            .iter()
            .map(|x| engine.submit(x.clone()).expect("queue_cap is ample"))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("engine dropped a response");
            assert_eq!(
                bits(&resp.output),
                bits(&reference[i]),
                "request {i} diverged with {replicas} replica(s), \
                 batch_seq {} size {}",
                resp.batch_seq,
                resp.batch_size
            );
        }
        let stats = engine.shutdown();
        assert_eq!(stats.submitted, 32);
        assert_eq!(stats.served, 32);
        assert_eq!(stats.shed, 0);
    }
}

#[test]
fn hot_reload_never_mixes_versions_within_a_batch() {
    // Digital engine: checkpoint restore is exact, so every response must
    // be bitwise one of the two known parameter sets — selected purely by
    // its version tag, never half-and-half.
    let m0 = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut Rng::new(11));
    let mut m1 = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut Rng::new(77));
    let shape = (8, 1, 1);
    let input: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
    let y0 = per_sample(&m0, shape, std::slice::from_ref(&input)).remove(0);
    let y1 = per_sample(&m1, shape, std::slice::from_ref(&input)).remove(0);
    assert_ne!(bits(&y0), bits(&y1), "the two parameter sets must be distinguishable");

    let ckpt = std::env::temp_dir()
        .join(format!("l2ight_serve_reload_{}.ckpt", std::process::id()));
    std::fs::remove_file(&ckpt).ok();

    let engine = ServeEngine::start(
        m0,
        shape,
        ServeConfig {
            replicas: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 4096,
            reload: Some(ReloadConfig { path: ckpt.clone(), poll: Duration::from_millis(5) }),
        },
    );

    // Keep traffic flowing; swap the checkpoint mid-stream.
    let mut swapped = false;
    let mut responses = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        let rxs: Vec<_> = (0..8)
            .map(|_| engine.submit(input.clone()).expect("queue_cap is ample"))
            .collect();
        responses.extend(rxs.into_iter().map(|rx| rx.recv().expect("response")));
        if !swapped {
            save_model_state(&mut m1, &ckpt).unwrap();
            swapped = true;
        }
        if engine.stats().reloads >= 1 && responses.iter().any(|r| r.version >= 1) {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    let stats = engine.shutdown();
    std::fs::remove_file(&ckpt).ok();
    assert!(stats.reloads >= 1, "hot-reload never happened within the deadline");
    assert!(responses.iter().any(|r| r.version == 0), "no pre-reload responses observed");
    assert!(responses.iter().any(|r| r.version >= 1), "no post-reload responses observed");

    // (a) Output bits always match the tagged version; (b) one batch id
    // never spans two versions.
    let mut batch_version: HashMap<u64, u64> = HashMap::new();
    for r in &responses {
        let want = if r.version == 0 { &y0 } else { &y1 };
        assert_eq!(
            bits(&r.output),
            bits(want),
            "batch {} (version {}) served bits from the wrong parameter set",
            r.batch_seq,
            r.version
        );
        let prev = batch_version.entry(r.batch_seq).or_insert(r.version);
        assert_eq!(*prev, r.version, "batch {} mixed parameter versions", r.batch_seq);
    }
}

#[test]
fn full_admission_queue_sheds_rather_than_blocks() {
    // Deterministic shed contract at the queue level: no workers draining,
    // so the seventh submission *must* be rejected, immediately.
    let q: AdmissionQueue<usize> = AdmissionQueue::new(AdmissionConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap: 6,
    });
    let t0 = Instant::now();
    for i in 0..6 {
        assert!(q.try_submit(i).is_ok());
    }
    assert_eq!(q.try_submit(6), Err(6), "submission over capacity must be shed");
    assert!(t0.elapsed() < Duration::from_secs(2), "try_submit blocked");
    let c = q.counters();
    assert_eq!((c.submitted, c.shed), (6, 1));
}

#[test]
fn engine_accounting_closes_under_a_shedding_burst() {
    // Engine level: with a tiny queue the burst may or may not shed
    // (workers race the submitter), but whatever happens must be
    // accounted — every Ok(submit) yields a response, every Err was a
    // Saturated shed, and the final counters close the loop.
    let model =
        build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut Rng::new(3));
    let shape = (8, 1, 1);
    let engine = ServeEngine::start(
        model,
        shape,
        ServeConfig {
            replicas: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 8,
            reload: None,
        },
    );
    let input: Vec<f32> = vec![0.25; 8];
    let mut oks = Vec::new();
    let mut sheds = 0u64;
    for _ in 0..200 {
        match engine.submit(input.clone()) {
            Ok(rx) => oks.push(rx),
            Err(ServeError::Saturated) => sheds += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let admitted = oks.len() as u64;
    for rx in oks {
        rx.recv().expect("admitted request must be served");
    }
    let stats = engine.shutdown();
    assert_eq!(stats.submitted, admitted);
    assert_eq!(stats.served, admitted);
    assert_eq!(stats.shed, sheds);
    assert!(stats.queue_high_water <= 8, "queue grew past its cap");

    // Malformed input is rejected before admission.
    let model2 =
        build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut Rng::new(3));
    let engine2 = ServeEngine::start(model2, shape, ServeConfig::default());
    assert_eq!(
        engine2.submit(vec![0.0; 3]).err(),
        Some(ServeError::BadRequest { got: 3, want: 8 })
    );
}
