//! Cross-shard equivalence: a logical layer partitioned across several
//! independently-mapped chiplet meshes must be *bitwise* indistinguishable
//! from the single-mesh engine — for every hot path (forward, feedback,
//! sigma_grad; masked, packed), at every shard count and placement policy,
//! at every thread count (within one SIMD dispatch level). On top of the
//! numerics, `MeshStats` accounting must close: energy (block-column
//! products) is partition-invariant, and latency (steps) can only go up
//! when a shard's critical path is shorter than the logical mesh's.
//!
//! The bitwise claim works because `ShardedMesh::from_mesh` carves shards
//! out of a logical `PtcMesh` by *moving* its PTCs (identical device
//! state), and every hot path walks the logical block grid in unsharded
//! order through the owner table — the kernel-call sequence is identical,
//! so within a SIMD dispatch level the floats are too.
//!
//! The level axis itself comes from CI: the shard-quick job re-runs this
//! suite once per kernel family (`L2IGHT_SIMD=scalar`, `scalar-fma`, and
//! the host `auto` level), and `ci_env_leg_pins_the_level_it_names` below
//! fails the leg if the pin silently fell back to a different family.

use l2ight::coordinator::{load_model_state, save_model_state};
use l2ight::linalg::Mat;
use l2ight::nn::{build_model, Act, EngineKind, ModelArch, ProjEngine};
use l2ight::photonics::{NoiseModel, PtcMesh, ShardPolicy, ShardedMesh};
use l2ight::profiler::CostBreakdown;
use l2ight::sampling::FeedbackMask;
use l2ight::stages::{
    calibrate_mesh, calibrate_sharded_mesh, map_mesh, map_sharded_mesh, IcConfig, PmConfig,
};
use l2ight::util::pool::ThreadPool;
use l2ight::util::prop::{assert_close, quickcheck};
use l2ight::util::Rng;

/// Shard-count × policy corners exercised by every property. `(1, Row)` is
/// the degenerate case that must reproduce the unsharded engine exactly
/// (including stats); counts above p or q clamp inside `from_mesh`.
const CONFIGS: [(usize, ShardPolicy); 6] = [
    (1, ShardPolicy::Row),
    (2, ShardPolicy::Row),
    (2, ShardPolicy::Col),
    (3, ShardPolicy::Grid),
    (4, ShardPolicy::Grid),
    (4, ShardPolicy::Col),
];

/// Same generator shape as `parallel_equivalence.rs`: block size, mesh
/// dims, and batch all sweep with `size` so block-grid edge cases (ragged
/// last row/col, single-column batches) come up quickly.
fn random_mesh(rng: &mut Rng, size: usize) -> (PtcMesh, Mat, Mat) {
    let k = 2 + size % 5;
    let rows = k + 1 + size % 37;
    let cols = k + 1 + (size / 2) % 29;
    let b = 1 + size % 21;
    let w = Mat::randn(rows, cols, 0.5, rng);
    let mut mesh = PtcMesh::new(rows, cols, k, NoiseModel::PAPER, rng);
    mesh.program_from_dense(&w);
    let x = Mat::randn(cols, b, 1.0, rng);
    let dy = Mat::randn(rows, b, 1.0, rng);
    (mesh, x, dy)
}

/// Deterministic ~70%-keep mask (salted so forward/feedback/column masks
/// within one case differ from each other).
fn pseudo_mask(n: usize, salt: usize) -> Vec<bool> {
    (0..n).map(|i| (i.wrapping_mul(2654435761) + salt.wrapping_mul(40503)) % 7 < 5).collect()
}

#[test]
fn prop_sharded_forward_is_bitwise_equal_to_unsharded() {
    let pool = ThreadPool::new(4);
    quickcheck(
        "forward/forward_masked: sharded == unsharded, bitwise",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, x, _) = case;
            let (p, q) = (mesh.p, mesh.q);
            let mut reference = mesh.clone();
            let y_dense = reference.forward_masked_on(&pool, x, None, 1.0);
            let mask = pseudo_mask(p * q, 1); // logical [p][q]
            let y_masked = reference.forward_masked_on(&pool, x, Some(&mask), 1.75);
            for &(shards, policy) in &CONFIGS {
                let mut sm = ShardedMesh::from_mesh(mesh.clone(), shards, policy);
                let ys = sm.forward_masked_on(&pool, x, None, 1.0);
                assert_close(&ys.data, &y_dense.data, 0.0, 0.0).map_err(|e| {
                    format!("unmasked, shards={shards} {}: {e}", policy.name())
                })?;
                let ysm = sm.forward_masked_on(&pool, x, Some(&mask), 1.75);
                assert_close(&ysm.data, &y_masked.data, 0.0, 0.0).map_err(|e| {
                    format!("masked, shards={shards} {}: {e}", policy.name())
                })?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_feedback_is_bitwise_equal_to_unsharded() {
    let pool = ThreadPool::new(4);
    quickcheck(
        "feedback: sharded == unsharded, bitwise",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, _, dy) = case;
            let (p, q) = (mesh.p, mesh.q);
            let mut reference = mesh.clone();
            let dx_dense = reference.feedback_on(&pool, dy, None, 1.0);
            let mask = pseudo_mask(q * p, 2); // logical [q][p] (transposed grid)
            let dx_masked = reference.feedback_on(&pool, dy, Some(&mask), 0.6);
            for &(shards, policy) in &CONFIGS {
                let mut sm = ShardedMesh::from_mesh(mesh.clone(), shards, policy);
                let dxs = sm.feedback_on(&pool, dy, None, 1.0);
                assert_close(&dxs.data, &dx_dense.data, 0.0, 0.0).map_err(|e| {
                    format!("unmasked, shards={shards} {}: {e}", policy.name())
                })?;
                let dxm = sm.feedback_on(&pool, dy, Some(&mask), 0.6);
                assert_close(&dxm.data, &dx_masked.data, 0.0, 0.0).map_err(|e| {
                    format!("masked, shards={shards} {}: {e}", policy.name())
                })?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_sigma_grad_is_bitwise_equal_to_unsharded() {
    let pool = ThreadPool::new(4);
    quickcheck(
        "sigma_grad: sharded == unsharded, bitwise",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, x, dy) = case;
            let b = x.cols;
            let mut reference = mesh.clone();
            let g_dense = reference.sigma_grad_on(&pool, x, dy, None, 1.0);
            let col_keep = pseudo_mask(b, 3);
            let g_masked = reference.sigma_grad_on(&pool, x, dy, Some(&col_keep), 2.5);
            for &(shards, policy) in &CONFIGS {
                let mut sm = ShardedMesh::from_mesh(mesh.clone(), shards, policy);
                let gs = sm.sigma_grad_on(&pool, x, dy, None, 1.0);
                assert_close(&gs, &g_dense, 0.0, 0.0).map_err(|e| {
                    format!("dense cols, shards={shards} {}: {e}", policy.name())
                })?;
                let gm = sm.sigma_grad_on(&pool, x, dy, Some(&col_keep), 2.5);
                assert_close(&gm, &g_masked, 0.0, 0.0).map_err(|e| {
                    format!("masked cols, shards={shards} {}: {e}", policy.name())
                })?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_packed_forward_is_bitwise_equal_to_unsharded() {
    // The packed (im2col-fused conv) entry point: the pack closure writes a
    // [q·k, panel] tile; rows past x.rows stay zero (pre-zeroed scratch).
    let pool = ThreadPool::new(4);
    quickcheck(
        "forward_packed: sharded == unsharded, bitwise",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, x, _) = case;
            let (p, q) = (mesh.p, mesh.q);
            let b = x.cols;
            let pack = |c0: usize, c1: usize, dst: &mut [f32]| {
                let wpan = c1 - c0;
                for r in 0..x.rows {
                    for (j, c) in (c0..c1).enumerate() {
                        dst[r * wpan + j] = x[(r, c)];
                    }
                }
            };
            let mask = pseudo_mask(p * q, 4);
            let mut reference = mesh.clone();
            let y_ref = reference.forward_packed_on(&pool, b, &pack, Some(&mask), 1.25);
            for &(shards, policy) in &CONFIGS {
                let mut sm = ShardedMesh::from_mesh(mesh.clone(), shards, policy);
                let ys = sm.forward_packed_on(&pool, b, &pack, Some(&mask), 1.25);
                assert_close(&ys.data, &y_ref.data, 0.0, 0.0).map_err(|e| {
                    format!("shards={shards} {}: {e}", policy.name())
                })?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_paths_are_thread_count_invariant_and_deterministic() {
    // threads=1 vs threads=4 bitwise on the *sharded* mesh (parallelism is
    // partitioned by output region, never by shard), and running the same
    // op twice on clones is bitwise-repeatable within a dispatch level.
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(4);
    quickcheck(
        "sharded hot paths: threads=1 == threads=4 == repeat run",
        |rng: &mut Rng, size: usize| random_mesh(rng, size),
        |case| {
            let (mesh, x, dy) = case;
            let (p, q) = (mesh.p, mesh.q);
            let fmask = pseudo_mask(p * q, 5);
            let bmask = pseudo_mask(q * p, 6);
            for &(shards, policy) in &[(2, ShardPolicy::Row), (4, ShardPolicy::Grid)] {
                let sm0 = ShardedMesh::from_mesh(mesh.clone(), shards, policy);
                let (mut a, mut b, mut c) = (sm0.clone(), sm0.clone(), sm0.clone());
                let y1 = a.forward_masked_on(&serial, x, Some(&fmask), 1.1);
                let y4 = b.forward_masked_on(&wide, x, Some(&fmask), 1.1);
                let y4r = c.forward_masked_on(&wide, x, Some(&fmask), 1.1);
                assert_close(&y1.data, &y4.data, 0.0, 0.0)
                    .map_err(|e| format!("forward 1-vs-4 ({shards}): {e}"))?;
                assert_close(&y4.data, &y4r.data, 0.0, 0.0)
                    .map_err(|e| format!("forward repeat ({shards}): {e}"))?;
                let d1 = a.feedback_on(&serial, dy, Some(&bmask), 1.0);
                let d4 = b.feedback_on(&wide, dy, Some(&bmask), 1.0);
                assert_close(&d1.data, &d4.data, 0.0, 0.0)
                    .map_err(|e| format!("feedback 1-vs-4 ({shards}): {e}"))?;
                let g1 = a.sigma_grad_on(&serial, x, dy, None, 1.0);
                let g4 = b.sigma_grad_on(&wide, x, dy, None, 1.0);
                assert_close(&g1, &g4, 0.0, 0.0)
                    .map_err(|e| format!("sigma_grad 1-vs-4 ({shards}): {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn mesh_stats_accounting_closes_across_shards() {
    let pool = ThreadPool::new(3);
    let mut rng = Rng::new(0x57a7);
    let (mesh, x, dy) = random_mesh(&mut rng, 23);
    let (p, q) = (mesh.p, mesh.q);
    let fmask = pseudo_mask(p * q, 7);
    let bmask = pseudo_mask(q * p, 8);
    let col_keep = pseudo_mask(x.cols, 9);

    // Unsharded reference ledger.
    let mut m = mesh.clone();
    let s_ref = {
        let _ = m.forward_masked_on(&pool, &x, None, 1.0);
        let _ = m.forward_masked_on(&pool, &x, Some(&fmask), 1.5);
        let _ = m.feedback_on(&pool, &dy, Some(&bmask), 1.0);
        let _ = m.sigma_grad_on(&pool, &x, &dy, Some(&col_keep), 1.0);
        m.stats
    };

    for &(shards, policy) in &CONFIGS {
        let mut sm = ShardedMesh::from_mesh(mesh.clone(), shards, policy);
        let _ = sm.forward_masked_on(&pool, &x, None, 1.0);
        let _ = sm.forward_masked_on(&pool, &x, Some(&fmask), 1.5);
        let _ = sm.feedback_on(&pool, &dy, Some(&bmask), 1.0);
        let _ = sm.sigma_grad_on(&pool, &x, &dy, Some(&col_keep), 1.0);
        let s = sm.stats();
        // Energy (block-column products) is partition-invariant: the same
        // logical blocks fire on the same columns no matter who owns them.
        assert_eq!(s.fwd_block_cols, s_ref.fwd_block_cols, "{shards} {}", policy.name());
        assert_eq!(s.feedback_block_cols, s_ref.feedback_block_cols, "{shards} {}", policy.name());
        assert_eq!(s.grad_block_cols, s_ref.grad_block_cols, "{shards} {}", policy.name());
        // Steps (latency) can only grow: each chiplet's sequential chain is
        // a subset of the logical mesh's, but fixed per-group costs repeat.
        assert!(s.fwd_steps >= s_ref.fwd_steps, "{shards} {}", policy.name());
        assert!(s.feedback_steps >= s_ref.feedback_steps, "{shards} {}", policy.name());
        assert!(s.grad_steps >= s_ref.grad_steps, "{shards} {}", policy.name());
        // And the profiler's energy roll-up closes exactly.
        assert_eq!(
            CostBreakdown::from_stats(&s).total_energy(),
            CostBreakdown::from_stats(&s_ref).total_energy()
        );
        if sm.num_shards() == 1 {
            // Degenerate sharding is the unsharded ledger, bit for bit.
            assert_eq!(s.fwd_steps, s_ref.fwd_steps);
            assert_eq!(s.feedback_steps, s_ref.feedback_steps);
            assert_eq!(s.grad_steps, s_ref.grad_steps);
        }
        // reset_stats must zero the whole fleet.
        sm.reset_stats();
        assert_eq!(sm.stats().total_energy(), 0);
        assert_eq!(sm.stats().total_steps(), 0);
    }
}

#[test]
fn engine_level_sharded_matches_photonic_bitwise() {
    // ProjEngine construction consumes the RNG identically for both kinds,
    // so same seed → same device; then every engine entry point must agree.
    let noise = NoiseModel::PAPER;
    let (out, inp) = (19, 14);
    for &(shards, policy) in &CONFIGS {
        let mut r1 = Rng::new(0xe4a1);
        let mut r2 = Rng::new(0xe4a1);
        let mut e1 = ProjEngine::new(EngineKind::Photonic { k: 4, noise }, out, inp, &mut r1);
        let mut e2 = ProjEngine::new(
            EngineKind::PhotonicSharded { k: 4, noise, shards, policy },
            out,
            inp,
            &mut r2,
        );
        assert_eq!(e1.out_features(), e2.out_features());
        assert_eq!(e1.in_features(), e2.in_features());

        let x = Mat::randn(inp, 9, 1.0, &mut Rng::new(11));
        let dy = Mat::randn(out, 9, 1.0, &mut Rng::new(12));
        let y1 = e1.forward(&x);
        let y2 = e2.forward(&x);
        assert_eq!(y1.data, y2.data, "forward, shards={shards} {}", policy.name());

        // Gathered (sampled-column) forward rides the packed path.
        let cols: Vec<Vec<f32>> = (0..x.cols)
            .step_by(2)
            .map(|c| (0..x.rows).map(|r| x.data[r * x.cols + c]).collect())
            .collect();
        let views: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let yg1 = e1.forward_gathered(&views);
        let yg2 = e2.forward_gathered(&views);
        assert_eq!(yg1.data, yg2.data, "gathered, shards={shards}");

        // Backward: feedback mask + sampled columns, then compare dx and
        // the accumulated subspace gradient.
        let (p, q, _) = e1.block_norms();
        let fb = FeedbackMask { keep: pseudo_mask(q * p, 10), p, q, scale: 1.3 };
        let col_keep = pseudo_mask(x.cols, 11);
        let dx1 = e1.backward(&x, &dy, Some(&fb), Some(&col_keep), 2.0);
        let dx2 = e2.backward(&x, &dy, Some(&fb), Some(&col_keep), 2.0);
        assert_eq!(dx1.data, dx2.data, "backward dx, shards={shards}");
        let g1 = match &e1 {
            ProjEngine::Photonic { grad_sigma, .. } => grad_sigma.clone(),
            _ => unreachable!(),
        };
        let g2 = match &e2 {
            ProjEngine::PhotonicSharded { grad_sigma, .. } => grad_sigma.clone(),
            _ => unreachable!(),
        };
        assert_eq!(g1, g2, "grad_sigma, shards={shards}");

        // Realized weight and btopk norms are logical-order invariants.
        assert_eq!(e1.dense_weight().data, e2.dense_weight().data);
        assert_eq!(e1.block_norms(), e2.block_norms());
    }
}

#[test]
fn model_level_sharded_matches_photonic_bitwise() {
    // Whole-model check: photonic projections (sharded vs not) mixed with
    // the digital layers of the zoo must produce identical activations and
    // identical stats energy.
    let noise = NoiseModel::quant_only(8);
    let mut m1 = build_model(
        ModelArch::MlpVowel,
        EngineKind::Photonic { k: 4, noise },
        4,
        0.5,
        &mut Rng::new(0x30de1),
    );
    let mut m2 = build_model(
        ModelArch::MlpVowel,
        EngineKind::PhotonicSharded { k: 4, noise, shards: 4, policy: ShardPolicy::Grid },
        4,
        0.5,
        &mut Rng::new(0x30de1),
    );
    let x = Act::from_features(Mat::randn(10, 6, 1.0, &mut Rng::new(21)), 6);
    let y1 = m1.forward(&x, false);
    let y2 = m2.forward(&x, false);
    assert_eq!(y1.mat.data, y2.mat.data, "model forward diverged under sharding");
    let s1 = m1.mesh_stats();
    let s2 = m2.mesh_stats();
    assert_eq!(s1.fwd_block_cols, s2.fwd_block_cols);
    assert_eq!(s1.total_energy(), s2.total_energy());
    assert_eq!(m1.param_counts(), m2.param_counts());
}

#[test]
fn checkpoints_are_interchangeable_between_sharded_and_unsharded() {
    // Serialization walks PTCs in logical order for both engines, so a
    // checkpoint written by one is a valid restore target for the other —
    // shard count is a deployment choice, not a model property. (quant-only
    // noise: fab randomness is re-sampled per instance, see
    // checkpoint_resume.rs.)
    let noise = NoiseModel::quant_only(8);
    let flat = EngineKind::Photonic { k: 4, noise };
    let sharded = EngineKind::PhotonicSharded { k: 4, noise, shards: 2, policy: ShardPolicy::Row };
    let x = Act::from_features(Mat::randn(8, 6, 1.0, &mut Rng::new(31)), 6);

    // Flat → sharded.
    let mut src = build_model(ModelArch::MlpVowel, flat, 4, 0.5, &mut Rng::new(41));
    let path = std::env::temp_dir()
        .join(format!("l2ight_shard_interop_{}.ckpt", std::process::id()));
    save_model_state(&mut src, &path).unwrap();
    let mut dst = build_model(ModelArch::MlpVowel, sharded, 4, 0.5, &mut Rng::new(999));
    load_model_state(&mut dst, &path).unwrap();
    assert_eq!(
        src.forward(&x, false).mat.data,
        dst.forward(&x, false).mat.data,
        "flat checkpoint restored into sharded model diverged"
    );

    // Sharded → flat (and sharded → differently-sharded).
    save_model_state(&mut dst, &path).unwrap();
    let mut back = build_model(ModelArch::MlpVowel, flat, 4, 0.5, &mut Rng::new(7));
    load_model_state(&mut back, &path).unwrap();
    let resharded_kind =
        EngineKind::PhotonicSharded { k: 4, noise, shards: 4, policy: ShardPolicy::Grid };
    let mut resharded = build_model(ModelArch::MlpVowel, resharded_kind, 4, 0.5, &mut Rng::new(8));
    load_model_state(&mut resharded, &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(src.forward(&x, false).mat.data, back.forward(&x, false).mat.data);
    assert_eq!(src.forward(&x, false).mat.data, resharded.forward(&x, false).mat.data);
}

#[test]
fn ic_stage_is_shard_count_invariant() {
    // Identity calibration streams ZO randomness per *logical* block, so
    // the post-IC device state — and the report — are bitwise identical at
    // any shard count.
    let cfg = IcConfig::quick();
    let mut rng = Rng::new(0x1c);
    let mut reference = PtcMesh::new(8, 8, 4, NoiseModel::PAPER, &mut rng);
    let r_ref = calibrate_mesh(&mut reference, &cfg);
    for &(shards, policy) in &CONFIGS {
        let mut rng = Rng::new(0x1c);
        let mut sm =
            ShardedMesh::new(8, 8, 4, NoiseModel::PAPER, shards, policy, &mut rng);
        let r = calibrate_sharded_mesh(&mut sm, &cfg);
        assert_eq!(r.mse_u, r_ref.mse_u, "shards={shards} {}", policy.name());
        assert_eq!(r.mse_v, r_ref.mse_v);
        assert_eq!(r.queries, r_ref.queries);
        assert_eq!(r.trace, r_ref.trace);
        assert_eq!(r.blocks, r_ref.blocks);
        assert_eq!(sm.sigma_flat(), reference.sigma_flat());
        assert_eq!(sm.to_dense().data, reference.to_dense().data);
    }
}

#[test]
fn pm_stage_is_shard_count_invariant() {
    // Parallel mapping: per-logical-block ZO streams + logical-order report
    // absorption → same programmed chip and same convergence trace.
    let cfg = PmConfig::quick();
    let mut wrng = Rng::new(0x9a);
    let target = Mat::randn(8, 8, 0.5, &mut wrng);
    let mut rng = Rng::new(0x9b);
    let mut reference = PtcMesh::new(8, 8, 4, NoiseModel::PAPER, &mut rng);
    let r_ref = map_mesh(&mut reference, &target, &cfg);
    for &(shards, policy) in &[(1, ShardPolicy::Row), (2, ShardPolicy::Col), (4, ShardPolicy::Grid)]
    {
        let mut rng = Rng::new(0x9b);
        let mut sm =
            ShardedMesh::new(8, 8, 4, NoiseModel::PAPER, shards, policy, &mut rng);
        let r = map_sharded_mesh(&mut sm, &target, &cfg);
        assert_eq!(r.err_init, r_ref.err_init, "shards={shards} {}", policy.name());
        assert_eq!(r.err_zo, r_ref.err_zo);
        assert_eq!(r.err_osp, r_ref.err_osp);
        assert_eq!(r.queries, r_ref.queries);
        assert_eq!(r.trace, r_ref.trace);
        assert_eq!(sm.to_dense().data, reference.to_dense().data);
        assert_eq!(sm.rel_error(&target), reference.rel_error(&target));
    }
}

#[test]
fn ci_env_leg_pins_the_level_it_names() {
    // Every bitwise claim above is scoped to one dispatch level, so the CI
    // legs that set L2IGHT_SIMD must actually run the family they name.
    use l2ight::linalg::{simd, SimdLevel};
    let Ok(raw) = std::env::var("L2IGHT_SIMD") else { return };
    let t = raw.trim();
    if t.is_empty() || t.eq_ignore_ascii_case("auto") {
        return;
    }
    match SimdLevel::parse(t) {
        Some(level) if level.available() => assert_eq!(
            simd::active(),
            level,
            "L2IGHT_SIMD={t} leg is not running the {} kernels",
            level.name()
        ),
        Some(_) => assert_eq!(simd::active(), SimdLevel::Scalar, "unavailable pin must fall back"),
        None => panic!("CI leg exports unknown L2IGHT_SIMD={t:?} — fix the strategy matrix"),
    }
}

#[test]
fn digital_engine_is_untouched_by_sharding_plumbing() {
    // The sharding axis must be a no-op for digital engines: same seed →
    // same weights → same forward/backward, with no photonic stats.
    let mut e = ProjEngine::new(EngineKind::Digital, 12, 10, &mut Rng::new(77));
    let x = Mat::randn(10, 5, 1.0, &mut Rng::new(78));
    let dy = Mat::randn(12, 5, 1.0, &mut Rng::new(79));
    let y = e.forward(&x);
    assert_eq!(y.rows, 12);
    let dx = e.backward(&x, &dy, None, None, 1.0);
    assert_eq!(dx.rows, 10);
    let (p, q, norms) = e.block_norms();
    assert_eq!((p, q, norms.len()), (1, 1, 1));
}
