//! Cross-module property tests (seeded PCG sweeps via `util::prop`):
//! invariants that must hold for *all* shapes/seeds, not just the unit-test
//! examples.

use l2ight::linalg::{matmul, Mat};
use l2ight::photonics::unitary::ReckMesh;
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::sampling::{FeedbackSampler, FeedbackStrategy, Normalization};
use l2ight::util::json::Json;
use l2ight::util::prop::{assert_close, quickcheck};
use l2ight::util::Rng;

#[test]
fn prop_random_phases_synthesize_orthogonal() {
    // ∀ random Φ: the Reck mesh realizes an orthogonal matrix.
    quickcheck(
        "reck orthogonal",
        |rng: &mut Rng, _size: usize| {
            let n = 2 + rng.below(8);
            ReckMesh::random(n, rng).synthesize()
        },
        |u: &Mat| {
            let gram = matmul(&u.t(), u);
            let eye = Mat::eye(u.rows);
            assert_close(&gram.data, &eye.data, 1e-4, 1e-4).map_err(|e| format!("gram: {e}"))
        },
    );
}

#[test]
fn prop_decompose_roundtrips() {
    // ∀ orthogonal U: decompose → synthesize reproduces U.
    quickcheck(
        "reck decompose roundtrip",
        |rng: &mut Rng, _size: usize| {
            let n = 2 + rng.below(7);
            ReckMesh::random(n, rng).synthesize()
        },
        |u: &Mat| {
            let mesh = ReckMesh::decompose(u);
            let back = mesh.synthesize();
            assert_close(&back.data, &u.data, 1e-4, 1e-4).map_err(|e| format!("roundtrip: {e}"))
        },
    );
}

#[test]
fn prop_ideal_mesh_program_forward_matches_dense() {
    // ∀ W, x (random shapes): program_from_dense then forward ≈ W·x when
    // the device is ideal.
    quickcheck(
        "mesh forward = W·x",
        |rng: &mut Rng, _size: usize| {
            let rows = 2 + rng.below(14);
            let cols = 2 + rng.below(14);
            let k = 2 + rng.below(5);
            let b = 1 + rng.below(9);
            let w = Mat::randn(rows, cols, 0.7, rng);
            let x = Mat::randn(cols, b, 1.0, rng);
            (w, x, k)
        },
        |(w, x, k): &(Mat, Mat, usize)| {
            let mut rng = Rng::new(1);
            let mut mesh = PtcMesh::new(w.rows, w.cols, *k, NoiseModel::IDEAL, &mut rng);
            mesh.program_from_dense(w);
            let got = mesh.forward(x);
            let want = matmul(w, x);
            assert_close(&got.data, &want.data, 2e-3, 2e-3)
                .map_err(|e| format!("{}x{} k={}: {e}", w.rows, w.cols, k))
        },
    );
}

#[test]
fn prop_feedback_mask_row_balance_and_fraction() {
    // ∀ (p, q, sparsity): btopk masks have identical kept-count per
    // feedback row (the load-balance guarantee of §3.4.2) and an overall
    // keep fraction within one block of the target.
    quickcheck(
        "btopk balance",
        |rng: &mut Rng, _size: usize| {
            let p = 2 + rng.below(8);
            let q = 2 + rng.below(8);
            let sparsity = 0.1 + 0.8 * rng.uniform() as f32;
            let norms: Vec<f32> = (0..p * q).map(|_| rng.uniform_f32() + 0.01).collect();
            (p, q, sparsity, norms)
        },
        |(p, q, sparsity, norms): &(usize, usize, f32, Vec<f32>)| {
            let sampler = FeedbackSampler::new(FeedbackStrategy::BTopK, *sparsity, Normalization::Exp);
            let mut rng = Rng::new(7);
            let mask = sampler.draw(*p, *q, norms, &mut rng);
            // keep is [q][p]: rows of Wᵀ are indexed by q.
            let per_row: Vec<usize> = (0..*q)
                .map(|qi| (0..*p).filter(|&pi| mask.keep[qi * p + pi]).count())
                .collect();
            let first = per_row[0];
            if !per_row.iter().all(|&c| c == first) {
                return Err(format!("imbalanced rows: {per_row:?}"));
            }
            if first == 0 {
                return Err("empty feedback row".into());
            }
            let target = ((1.0 - sparsity) * *p as f32).round().max(1.0) as usize;
            if (first as i64 - target as i64).unsigned_abs() > 1 {
                return Err(format!("keep {first} far from target {target}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_unbiased_feedback_estimator() {
    // Appendix D: E[mask ⊙ Wᵀ · c_W] = Wᵀ for uniform sampling with exp
    // normalization — check the Monte-Carlo mean converges toward W.
    let mut rng = Rng::new(99);
    let (p, q, k) = (3, 3, 3);
    let mut mesh = PtcMesh::new(p * k, q * k, k, NoiseModel::IDEAL, &mut rng);
    let w = Mat::randn(p * k, q * k, 0.7, &mut rng);
    mesh.program_from_dense(&w);
    let dy = Mat::eye(p * k); // feedback of I gives Wᵀ itself
    let truth = mesh.feedback(&dy, None, 1.0);
    let sampler = FeedbackSampler::new(FeedbackStrategy::Uniform, 0.5, Normalization::Exp);
    let norms = mesh.block_norms_sq();
    let mut mean = Mat::zeros(truth.rows, truth.cols);
    let draws = 600;
    for d in 0..draws {
        let mut r = Rng::new(1000 + d);
        let m = sampler.draw(p, q, &norms, &mut r);
        let est = mesh.feedback(&dy, Some(&m.keep), m.scale);
        for (acc, v) in mean.data.iter_mut().zip(&est.data) {
            *acc += v / draws as f32;
        }
    }
    let rel = mean.sub(&truth).fro_norm() / truth.fro_norm();
    assert!(rel < 0.12, "uniform+exp estimator biased: rel {rel}");
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    // ∀ machine-generated JSON trees: parse(dump(x)) == x.
    fn gen_tree(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 64.0).round() / 64.0),
            3 => Json::Str(format!("s{}-\"esc\\{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_tree(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(4) {
                    o.set(&format!("k{i}"), gen_tree(rng, depth - 1));
                }
                o
            }
        }
    }
    quickcheck(
        "json roundtrip",
        |rng: &mut Rng, _size: usize| gen_tree(rng, 3),
        |j: &Json| {
            let text = j.dump();
            let back = Json::parse(&text).map_err(|e| format!("parse {text}: {e:?}"))?;
            if &back != j {
                return Err(format!("mismatch: {text} vs {}", back.dump()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_osp_never_worse_than_prior_sigma() {
    // ∀ targets and unitary states: OSP's mapping loss ≤ the loss before
    // projection (it is the argmin over Σ given fixed unitaries).
    quickcheck(
        "osp optimal",
        |rng: &mut Rng, _size: usize| {
            let k = 2 + rng.below(6);
            let target = Mat::randn(k, k, 0.8, rng);
            let seed = rng.next_u64();
            (k, target, seed)
        },
        |(k, target, seed): &(usize, Mat, u64)| {
            let mut rng = Rng::new(*seed);
            let mut ptc = l2ight::photonics::ptc::Ptc::new(*k, NoiseModel::IDEAL, &mut rng);
            // Random unitaries, random prior Σ.
            use l2ight::photonics::ptc::Which;
            use l2ight::photonics::unitary::num_phases;
            let ph: Vec<f64> =
                (0..num_phases(*k)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
            ptc.set_phases(Which::U, &ph);
            let ph2: Vec<f64> =
                (0..num_phases(*k)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
            ptc.set_phases(Which::V, &ph2);
            let sig: Vec<f32> = (0..*k).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
            ptc.set_sigma(&sig);
            let before = ptc.mapping_loss(target);
            ptc.osp(target);
            let after = ptc.mapping_loss(target);
            if after <= before + 1e-5 {
                Ok(())
            } else {
                Err(format!("OSP worsened loss: {before} -> {after}"))
            }
        },
    );
}

#[test]
fn prop_augment_preserves_shape_and_finiteness() {
    use l2ight::data::Augment;
    quickcheck(
        "augment sane",
        |rng: &mut Rng, _size: usize| {
            let c = 1 + rng.below(3);
            let side = 4 + rng.below(12);
            let mut x = vec![0.0f32; c * side * side];
            rng.fill_normal(&mut x, 0.0, 1.0);
            (c, side, x, rng.next_u64())
        },
        |(c, side, x, seed): &(usize, usize, Vec<f32>, u64)| {
            let mut rng = Rng::new(*seed);
            let mut y = x.clone();
            Augment::CIFAR.apply(&mut y, *c, *side, *side, &mut rng);
            if y.len() != x.len() {
                return Err("length changed".into());
            }
            if !y.iter().all(|v| v.is_finite()) {
                return Err("non-finite values".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Serving-layer properties: admission coalescing/FIFO and stats percentiles.
// ---------------------------------------------------------------------------

#[test]
fn prop_admission_max_wait_bounds_coalescing() {
    // ∀ (max_batch, n): a consumer facing a partial batch flushes once the
    // *oldest* request has waited max_wait — it never hangs waiting for the
    // batch to fill — and a full batch flushes without touching the
    // deadline at all. Timing-sensitive, so few cases and a generous slack
    // on the upper bound (the property is "bounded", not "exact").
    use l2ight::serve::{AdmissionConfig, AdmissionQueue};
    use l2ight::util::prop::{check, PropConfig};
    use std::time::{Duration, Instant};
    check(
        "admission: max_wait bounds partial-batch latency",
        PropConfig { cases: 10, ..PropConfig::default() },
        |rng: &mut Rng, _size: usize| {
            let max_batch = 2 + rng.below(15);
            let n = 1 + rng.below(max_batch - 1); // strictly partial
            (max_batch, n)
        },
        |&(max_batch, n): &(usize, usize)| {
            let max_wait = Duration::from_millis(15);
            let q: AdmissionQueue<usize> = AdmissionQueue::new(AdmissionConfig {
                max_batch,
                max_wait,
                queue_cap: 1024,
            });
            for i in 0..n {
                q.try_submit(i).map_err(|_| "shed under capacity".to_string())?;
            }
            let t0 = Instant::now();
            let batch = q.next_batch().ok_or("queue reported closed")?;
            let waited = t0.elapsed();
            if waited > max_wait + Duration::from_millis(1500) {
                return Err(format!("partial batch held {waited:?} (max_wait {max_wait:?})"));
            }
            let got: Vec<usize> = batch.into_iter().map(|r| r.payload).collect();
            if got != (0..n).collect::<Vec<usize>>() {
                return Err(format!("partial flush not FIFO-complete: {got:?}"));
            }
            // Full batch: deadline is irrelevant, flush must be immediate
            // even with an effectively-infinite max_wait.
            let q: AdmissionQueue<usize> = AdmissionQueue::new(AdmissionConfig {
                max_batch,
                max_wait: Duration::from_secs(3600),
                queue_cap: 1024,
            });
            for i in 0..max_batch {
                q.try_submit(i).map_err(|_| "shed under capacity".to_string())?;
            }
            let t0 = Instant::now();
            let batch = q.next_batch().ok_or("queue reported closed")?;
            if t0.elapsed() > Duration::from_secs(60) {
                return Err("full batch waited on the deadline".into());
            }
            if batch.len() != max_batch {
                return Err(format!("full flush took {} of {max_batch}", batch.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_admission_fifo_within_batch_under_multi_consumer_drain() {
    // ∀ (workers, n): with several replica workers racing on next_batch,
    // every request is delivered exactly once, and *within* each batch the
    // submission order is preserved (payloads are submitted in increasing
    // order, so each batch must be strictly increasing).
    use l2ight::serve::{AdmissionConfig, AdmissionQueue};
    use l2ight::util::prop::{check, PropConfig};
    use std::time::Duration;
    check(
        "admission: exactly-once + FIFO within batch, multi-consumer",
        PropConfig { cases: 12, ..PropConfig::default() },
        |rng: &mut Rng, size: usize| {
            let workers = 2 + rng.below(3);
            let n = 20 + rng.below(10 * size + 1);
            let max_batch = 1 + rng.below(8);
            (workers, n, max_batch)
        },
        |&(workers, n, max_batch): &(usize, usize, usize)| {
            let q: AdmissionQueue<usize> = AdmissionQueue::new(AdmissionConfig {
                max_batch,
                max_wait: Duration::from_millis(1),
                queue_cap: usize::MAX,
            });
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        let mut batches: Vec<Vec<usize>> = Vec::new();
                        while let Some(batch) = q.next_batch() {
                            batches.push(batch.into_iter().map(|r| r.payload).collect());
                        }
                        batches
                    })
                })
                .collect();
            for i in 0..n {
                q.try_submit(i).map_err(|_| "unbounded queue shed".to_string())?;
            }
            q.close();
            let mut all = Vec::new();
            for h in handles {
                for batch in h.join().map_err(|_| "worker panicked".to_string())? {
                    if batch.len() > max_batch {
                        return Err(format!("batch of {} > max_batch {max_batch}", batch.len()));
                    }
                    if !batch.windows(2).all(|w| w[0] < w[1]) {
                        return Err(format!("batch not FIFO: {batch:?}"));
                    }
                    all.extend(batch);
                }
            }
            all.sort_unstable();
            if all != (0..n).collect::<Vec<usize>>() {
                return Err(format!("not exactly-once: {} of {n} delivered", all.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_serve_percentiles_monotone_bounded_and_null_safe() {
    // ∀ latency sets: percentile_ms is monotone in p and bounded by
    // [min, max]; a single sample answers every percentile; an empty set is
    // NaN everywhere and serializes as JSON null (machine-parseable file
    // even with zero traffic); injected non-finite samples also degrade to
    // null rather than emitting bare `NaN` into the JSON text.
    use l2ight::serve::ServeStats;
    quickcheck(
        "serve stats percentiles",
        |rng: &mut Rng, size: usize| {
            let n = rng.below(size + 2);
            let mut lat: Vec<f64> = (0..n).map(|_| rng.below(100_000) as f64 / 97.0).collect();
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            lat
        },
        |lat: &Vec<f64>| {
            let s = ServeStats { latency_ms: lat.clone(), ..ServeStats::default() };
            if lat.is_empty() {
                if !s.percentile_ms(50.0).is_nan() {
                    return Err("empty set must be NaN".into());
                }
                let j = s.to_json();
                for key in ["p50_ms", "p95_ms", "p99_ms"] {
                    if !matches!(j.get(key), Some(Json::Null)) {
                        return Err(format!("{key} not null for empty set"));
                    }
                }
                if Json::parse(&j.pretty()).is_err() {
                    return Err("empty snapshot JSON unparseable".into());
                }
                return Ok(());
            }
            let (lo, hi) = (lat[0], lat[lat.len() - 1]);
            let mut prev = f64::NEG_INFINITY;
            for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
                let v = s.percentile_ms(p);
                if !(lo..=hi).contains(&v) {
                    return Err(format!("p{p} = {v} outside [{lo}, {hi}]"));
                }
                if v < prev {
                    return Err(format!("p{p} = {v} < previous {prev}: not monotone"));
                }
                prev = v;
            }
            if lat.len() == 1 {
                for p in [0.0, 50.0, 100.0] {
                    if s.percentile_ms(p) != lat[0] {
                        return Err("single sample must answer every percentile".into());
                    }
                }
            }
            // Non-finite samples (e.g. a corrupted snapshot) must still
            // produce valid JSON: null, never a bare NaN token.
            let poisoned = ServeStats {
                latency_ms: vec![f64::NAN; lat.len()],
                ..ServeStats::default()
            };
            let j = poisoned.to_json();
            if !matches!(j.get("p50_ms"), Some(Json::Null)) {
                return Err("NaN percentile must serialize as null".into());
            }
            if Json::parse(&j.pretty()).is_err() {
                return Err("poisoned snapshot JSON unparseable".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_serve_collector_accounting_closes() {
    // ∀ batch sequences: served == Σ sizes, batches == Σ occupancy, and
    // every latency sample survives into the (sorted) snapshot.
    use l2ight::serve::{QueueCounters, StatsCollector};
    use std::time::Duration;
    quickcheck(
        "serve stats accounting closure",
        |rng: &mut Rng, size: usize| {
            let max_batch = 1 + rng.below(8);
            let sizes: Vec<usize> =
                (0..rng.below(size + 1)).map(|_| 1 + rng.below(max_batch + 2)).collect();
            (max_batch, sizes)
        },
        |(max_batch, sizes): &(usize, Vec<usize>)| {
            let c = StatsCollector::new(*max_batch);
            for (i, &sz) in sizes.iter().enumerate() {
                c.note_batch(sz, (0..sz).map(|j| Duration::from_micros((i * 7 + j) as u64)));
            }
            let s = c.snapshot(&QueueCounters::default());
            let total: usize = sizes.iter().sum();
            if s.served != total as u64 {
                return Err(format!("served {} != Σ sizes {total}", s.served));
            }
            if s.batches != sizes.len() as u64 {
                return Err(format!("batches {} != {}", s.batches, sizes.len()));
            }
            if s.occupancy.iter().sum::<u64>() != sizes.len() as u64 {
                return Err("occupancy histogram does not sum to batches".into());
            }
            if s.occupancy.len() != (*max_batch).max(1) {
                return Err("occupancy bin count drifted from max_batch".into());
            }
            if s.latency_ms.len() != total {
                return Err("latency samples lost".into());
            }
            if !s.latency_ms.windows(2).all(|w| w[0] <= w[1]) {
                return Err("snapshot latencies not sorted".into());
            }
            Ok(())
        },
    );
}
