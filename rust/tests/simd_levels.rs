//! SIMD level-lattice acceptance suite (ISSUE 9): every kernel family the
//! dispatch seam exposes — scalar, scalar-fma, avx2, avx512, neon — must
//!
//!   1. match the scalar reference within 1e-5 on the perf_hotpath GEMM
//!      ladder shapes (FMA contraction moves numerics at the ulp scale
//!      only),
//!   2. be bitwise self-consistent across thread counts, cache-blocking
//!      choices, and column-panel partitions (the §Blocking rules contract
//!      in `gemm.rs`: the dispatch level owns the numerics, the execution
//!      strategy never does),
//!   3. round-trip its name through `SimdLevel::parse` (reports, bench
//!      JSON, `L2IGHT_SIMD`, CI strategy matrices), and
//!   4. honor a CI env leg: when `L2IGHT_SIMD` pins an available level,
//!      `simd::active()` must actually be that level, so a typo'd matrix
//!      entry can never silently test the wrong family.
//!
//! The autotuner's disk profile is exercised end to end too: save → load →
//! the dispatch helpers serve the tuned blocking.

use l2ight::linalg::{
    conv2d_forward_packed_at, conv2d_forward_packed_with, matmul_acc_with_blocking,
    matmul_into_at, simd, tune, Conv2dShape, GemmBlocking, Mat, SimdLevel,
};
use l2ight::util::pool::ThreadPool;
use l2ight::util::prop::assert_close;
use l2ight::util::Rng;

/// Every level this host can execute, scalar included.
fn available_levels() -> Vec<SimdLevel> {
    SimdLevel::ALL.iter().copied().filter(|l| l.available()).collect()
}

/// Ladder-flavored GEMM shapes: one square acceptance size plus ragged
/// dims that exercise tails in every kernel family.
const GEMM_SHAPES: [(usize, usize, usize); 4] =
    [(64, 64, 64), (96, 128, 80), (33, 47, 29), (128, 256, 96)];

#[test]
fn every_available_level_matches_scalar_on_gemm_ladder_shapes() {
    let mut rng = Rng::new(0x51d0);
    for &(m, k, n) in &GEMM_SHAPES {
        let a = Mat::randn(m, k, 0.7, &mut rng);
        let b = Mat::randn(k, n, 0.7, &mut rng);
        let mut want = Mat::zeros(m, n);
        matmul_into_at(SimdLevel::Scalar, &a, &b, &mut want);
        for level in available_levels() {
            let mut got = Mat::zeros(m, n);
            matmul_into_at(level, &a, &b, &mut got);
            assert_close(&got.data, &want.data, 1e-5, 1e-5).unwrap_or_else(|e| {
                panic!("{} vs scalar diverged on {m}x{k}x{n}: {e}", level.name())
            });
        }
    }
}

#[test]
fn every_available_level_is_bitwise_blocking_invariant() {
    // Any blocking on the determinism-safe grid — including pathological
    // tiny tiles — must reproduce the un-blocked dispatch result bit for
    // bit, at every level. This is the tentpole contract that lets the
    // autotuner pick per-host tile sizes without a numerics review.
    let blockings = [
        GemmBlocking { mc: 8, kc: 8, nc: 16 },
        GemmBlocking { mc: 16, kc: 32, nc: 48 },
        GemmBlocking { mc: 64, kc: 256, nc: 256 },
        GemmBlocking::default(),
    ];
    let mut rng = Rng::new(0xb10c);
    let (m, k, n) = (70, 90, 110);
    let a = Mat::randn(m, k, 0.6, &mut rng);
    let b = Mat::randn(k, n, 0.6, &mut rng);
    for level in available_levels() {
        let mut want = Mat::zeros(m, n);
        matmul_into_at(level, &a, &b, &mut want);
        for blk in blockings {
            let mut got = Mat::zeros(m, n);
            matmul_acc_with_blocking(level, blk, &a, &b, &mut got);
            assert_eq!(
                got.data,
                want.data,
                "{} blocked (mc={} kc={} nc={}) != direct",
                level.name(),
                blk.mc,
                blk.kc,
                blk.nc
            );
        }
    }
}

#[test]
fn every_available_level_is_panel_and_thread_invariant_on_fused_conv() {
    // The packed-panel conv path: any column-panel width × any pool width
    // is the same bitstream within a level (panels are pure column splits
    // of an A·B product — §Blocking rules).
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    let sh = Conv2dShape {
        batch: 3,
        in_ch: 4,
        in_h: 9,
        in_w: 7,
        out_ch: 6,
        kernel: 3,
        stride: 1,
        padding: 1,
    };
    let mut rng = Rng::new(0xfa57);
    let input: Vec<f32> =
        (0..sh.batch * sh.in_ch * sh.in_h * sh.in_w).map(|_| rng.normal() as f32).collect();
    let w = Mat::randn(sh.out_ch, sh.patch_rows(), 0.7, &mut rng);
    for level in available_levels() {
        let want = conv2d_forward_packed_at(level, &serial, &w, &input, &sh);
        for panel_cols in [8usize, 33, 64, 128, 4096] {
            for pool in [&serial, &wide] {
                let got = conv2d_forward_packed_with(level, pool, panel_cols, &w, &input, &sh);
                assert_eq!(
                    got.data,
                    want.data,
                    "{} panel_cols={panel_cols} threads={} diverged",
                    level.name(),
                    pool.threads()
                );
            }
        }
    }
}

#[test]
fn level_names_round_trip_and_unknowns_are_rejected() {
    for level in SimdLevel::ALL {
        assert_eq!(SimdLevel::parse(level.name()), Some(level), "{}", level.name());
    }
    // Alias + normalization.
    assert_eq!(SimdLevel::parse("scalar_fma"), Some(SimdLevel::ScalarFma));
    assert_eq!(SimdLevel::parse("  AVX512 "), Some(SimdLevel::Avx512));
    // `auto` is a dispatch policy, not a level; junk is rejected (active()
    // turns both into warn-and-fallback, never a silent wrong family).
    assert_eq!(SimdLevel::parse("auto"), None);
    assert_eq!(SimdLevel::parse("avx1024"), None);
    assert_eq!(SimdLevel::parse(""), None);
}

#[test]
fn ci_env_leg_pins_the_level_it_names() {
    // Arms the CI strategy matrices: when a leg exports L2IGHT_SIMD=<level>
    // and the runner supports it, the whole test process must actually run
    // that family. An unavailable pin documents scalar fallback instead.
    let Ok(raw) = std::env::var("L2IGHT_SIMD") else { return };
    let t = raw.trim();
    if t.is_empty() || t.eq_ignore_ascii_case("auto") {
        return;
    }
    match SimdLevel::parse(t) {
        Some(level) if level.available() => assert_eq!(
            simd::active(),
            level,
            "L2IGHT_SIMD={t} leg is not running the {} kernels",
            level.name()
        ),
        Some(_) => assert_eq!(simd::active(), SimdLevel::Scalar, "unavailable pin must fall back"),
        None => panic!("CI leg exports unknown L2IGHT_SIMD={t:?} — fix the strategy matrix"),
    }
}

#[test]
fn tuned_profile_round_trips_through_disk_and_dispatch_helpers() {
    // save → load → identical profile; helpers always serve a valid
    // blocking whether or not a level was tuned.
    let mut p = tune::Profile::default();
    p.set_level(
        SimdLevel::Scalar,
        tune::LevelTuning {
            blocking: GemmBlocking { mc: 16, kc: 32, nc: 48 },
            panel_cols: 96,
        },
    );
    let path = std::env::temp_dir()
        .join(format!("l2ight_tune_roundtrip_{}.json", std::process::id()));
    tune::save_profile(&p, &path).unwrap();
    let q = tune::load_profile(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(q.level(SimdLevel::Scalar), p.level(SimdLevel::Scalar));
    assert_eq!(q.level(SimdLevel::Avx512), None, "untuned level must stay unset");
    for level in SimdLevel::ALL {
        assert!(tune::gemm_blocking(level).is_valid(), "{}", level.name());
        assert!(tune::panel_cols_for(level) >= 8, "{}", level.name());
    }
}
