//! Fused-conv equivalence suite: the packed-panel conv path (patch tiles
//! extracted straight into the GEMM packing buffers, no `[Cin·K², B·H'·W']`
//! intermediate) must be numerically indistinguishable from the eager
//! im2col + GEMM reference — bitwise at every pinned dispatch level in the
//! kernel lattice (scalar, scalar-fma, avx2, avx512, neon — whichever this
//! host can run) across stride/padding/batch edge cases, within 1e-5 of
//! the scalar reference across levels, and bitwise thread-count-invariant
//! (panel widths come from the autotuner profile, never from the pool).

use l2ight::linalg::{
    col2im, col2im_pooled_on, conv2d_forward_packed_at, im2col, im2col_pooled_on, matmul,
    matmul_into_at, simd, Conv2dShape, Mat, PatchExtractor, SimdLevel,
};
use l2ight::nn::act::Act;
use l2ight::nn::engine::{EngineKind, ProjEngine};
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::util::pool::ThreadPool;
use l2ight::util::prop::{assert_close, quickcheck};
use l2ight::util::Rng;

/// The edge-case shapes the satellite calls out: 1×1 kernels, padding ≥
/// kernel, non-square inputs, strides > 1, batch 1 and batch > 1.
fn edge_shapes() -> Vec<Conv2dShape> {
    let sh = |batch, in_ch, in_h, in_w, out_ch, kernel, stride, padding| Conv2dShape {
        batch,
        in_ch,
        in_h,
        in_w,
        out_ch,
        kernel,
        stride,
        padding,
    };
    vec![
        // 1×1 kernel, stride 1, no padding (im2col is a reshape).
        sh(2, 3, 4, 4, 5, 1, 1, 0),
        // 1×1 kernel with stride and padding.
        sh(1, 2, 5, 5, 3, 1, 2, 1),
        // Padding ≥ kernel (whole patch rows/cols fall outside the input).
        sh(2, 1, 3, 3, 2, 2, 1, 3),
        // Non-square input, stride 2.
        sh(3, 2, 5, 3, 4, 3, 2, 1),
        // Single-sample batch, stride 3.
        sh(1, 4, 7, 7, 6, 3, 3, 0),
        // CNN-shaped: batch past one panel's worth of columns.
        sh(5, 3, 8, 8, 7, 3, 1, 1),
    ]
}

fn random_case(sh: &Conv2dShape, rng: &mut Rng) -> (Vec<f32>, Mat) {
    let input: Vec<f32> =
        (0..sh.batch * sh.in_ch * sh.in_h * sh.in_w).map(|_| rng.normal() as f32).collect();
    let w = Mat::randn(sh.out_ch, sh.patch_rows(), 0.7, rng);
    (input, w)
}

/// Eager im2col + GEMM at a pinned dispatch level — the reference the
/// fused path must reproduce.
fn eager_forward_at(level: SimdLevel, w: &Mat, input: &[f32], sh: &Conv2dShape) -> Mat {
    let patches = im2col(input, sh);
    let mut y = Mat::zeros(w.rows, patches.cols);
    matmul_into_at(level, w, &patches, &mut y);
    y
}

#[test]
fn fused_equals_eager_bitwise_under_scalar_edge_cases() {
    let pool = ThreadPool::new(3);
    let mut rng = Rng::new(0xf05e);
    for sh in edge_shapes() {
        let (input, w) = random_case(&sh, &mut rng);
        let eager = eager_forward_at(SimdLevel::Scalar, &w, &input, &sh);
        let fused = conv2d_forward_packed_at(SimdLevel::Scalar, &pool, &w, &input, &sh);
        assert_close(&fused.data, &eager.data, 0.0, 0.0)
            .unwrap_or_else(|e| panic!("scalar fused != eager for {sh:?}: {e}"));
    }
}

#[test]
fn fused_matches_eager_at_every_available_level_within_tolerance() {
    // The full kernel-family matrix. Within a level, fused == eager
    // bitwise (same per-element accumulation order — the dispatch level,
    // not the execution strategy, owns the numerics); across levels the
    // FMA contraction moves numerics at the ulp scale only.
    let levels: Vec<SimdLevel> =
        SimdLevel::ALL.iter().copied().filter(|l| l.available()).collect();
    let pool = ThreadPool::new(3);
    let mut rng = Rng::new(0xa572);
    for sh in edge_shapes() {
        let (input, w) = random_case(&sh, &mut rng);
        let eager_s = eager_forward_at(SimdLevel::Scalar, &w, &input, &sh);
        for &level in &levels {
            let eager_v = eager_forward_at(level, &w, &input, &sh);
            let fused_v = conv2d_forward_packed_at(level, &pool, &w, &input, &sh);
            assert_close(&fused_v.data, &eager_v.data, 0.0, 0.0).unwrap_or_else(|e| {
                panic!("{} fused != {} eager for {sh:?}: {e}", level.name(), level.name())
            });
            assert_close(&fused_v.data, &eager_s.data, 1e-5, 1e-5).unwrap_or_else(|e| {
                panic!("{} fused vs scalar eager for {sh:?}: {e}", level.name())
            });
        }
    }
}

#[test]
fn prop_fused_path_identical_across_thread_counts() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    quickcheck(
        "fused conv: threads=1 == threads=N",
        |rng: &mut Rng, size: usize| {
            let sh = Conv2dShape {
                batch: 1 + size % 4,
                in_ch: 1 + size % 3,
                in_h: 2 + size % 6,
                in_w: 2 + (size / 2) % 7,
                out_ch: 1 + size % 5,
                kernel: 1 + size % 3,
                stride: 1 + size % 2,
                padding: size % 3,
            };
            let sh = Conv2dShape {
                kernel: sh.kernel.min(sh.in_h).min(sh.in_w),
                ..sh
            };
            let (input, w) = random_case(&sh, rng);
            (sh, input, w)
        },
        |case| {
            let (sh, input, w) = case;
            let level = simd::active();
            let y1 = conv2d_forward_packed_at(level, &serial, w, input, sh);
            let y2 = conv2d_forward_packed_at(level, &wide, w, input, sh);
            assert_close(&y1.data, &y2.data, 0.0, 0.0)
        },
    );
}

#[test]
fn pooled_im2col_and_col2im_match_serial_bitwise() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(4);
    let mut rng = Rng::new(0x1c01);
    for sh in edge_shapes() {
        let (input, _) = random_case(&sh, &mut rng);
        let eager = im2col(&input, &sh);
        for pool in [&serial, &wide] {
            let pooled = im2col_pooled_on(pool, &input, &sh);
            assert_close(&pooled.data, &eager.data, 0.0, 0.0)
                .unwrap_or_else(|e| panic!("im2col_pooled != im2col for {sh:?}: {e}"));
        }
        let cols = Mat::randn(sh.patch_rows(), sh.patch_cols(), 1.0, &mut rng);
        let folded = col2im(&cols, &sh);
        for pool in [&serial, &wide] {
            let pooled = col2im_pooled_on(pool, &cols, &sh);
            assert_close(&pooled, &folded, 0.0, 0.0)
                .unwrap_or_else(|e| panic!("col2im_pooled != col2im for {sh:?}: {e}"));
        }
    }
}

// ---------------------------------------------------------------------------
// Photonic mesh: packed forward vs eager forward
// ---------------------------------------------------------------------------

#[test]
fn mesh_packed_forward_equals_eager_bitwise_and_thread_invariant() {
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    let mut rng = Rng::new(0x3e54);
    let sh = Conv2dShape {
        batch: 3, in_ch: 2, in_h: 6, in_w: 5, out_ch: 5, kernel: 3, stride: 1, padding: 1,
    };
    let input: Vec<f32> =
        (0..sh.batch * sh.in_ch * sh.in_h * sh.in_w).map(|_| rng.normal() as f32).collect();
    let w = Mat::randn(sh.out_ch, sh.patch_rows(), 0.5, &mut rng);
    let mut mesh = PtcMesh::new(sh.out_ch, sh.patch_rows(), 4, NoiseModel::PAPER, &mut rng);
    mesh.program_from_dense(&w);
    let ex = PatchExtractor::new(&input, &sh);
    let pack = |c0: usize, c1: usize, dst: &mut [f32]| ex.pack_into(c0, c1, dst);
    let fwd_mask: Vec<bool> = (0..mesh.p * mesh.q).map(|i| i % 4 != 1).collect();

    // Eager reference: materialized patch matrix through forward_masked.
    let patches = im2col(&input, &sh);
    let mut m_eager = mesh.clone();
    let y_eager = m_eager.forward_masked_on(&wide, &patches, None, 1.0);

    for pool in [&serial, &wide] {
        let mut m = mesh.clone();
        let y = m.forward_packed_on(pool, sh.patch_cols(), &pack, None, 1.0);
        assert_close(&y.data, &y_eager.data, 0.0, 0.0)
            .unwrap_or_else(|e| panic!("packed != eager mesh forward: {e}"));
        // The Appendix-G counters must not depend on the execution strategy.
        assert_eq!(m.stats, m_eager.stats, "stats diverged between packed and eager");
    }

    // Masked + scaled variant, bitwise across thread counts and vs eager.
    let mut m_eager = mesh.clone();
    let y_eager = m_eager.forward_masked_on(&wide, &patches, Some(&fwd_mask), 1.5);
    for pool in [&serial, &wide] {
        let mut m = mesh.clone();
        let y = m.forward_packed_on(pool, sh.patch_cols(), &pack, Some(&fwd_mask), 1.5);
        assert_close(&y.data, &y_eager.data, 0.0, 0.0)
            .unwrap_or_else(|e| panic!("masked packed != masked eager: {e}"));
        assert_eq!(m.stats, m_eager.stats, "masked stats diverged");
    }
}

// ---------------------------------------------------------------------------
// Layer-level wiring: Conv2d uses the fused path and reproduces the eager
// engine product (both engines, at the process-wide dispatch level)
// ---------------------------------------------------------------------------

#[test]
fn conv2d_layer_forward_matches_eager_engine_product() {
    let mut rng = Rng::new(0x10a3);
    for kind in [EngineKind::Digital, EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER }] {
        let (in_ch, out_ch, kernel) = (2, 5, 3);
        let engine = ProjEngine::new(kind, out_ch, in_ch * kernel * kernel, &mut rng);
        let mut conv =
            l2ight::nn::layers::Conv2d::new(engine.clone(), in_ch, out_ch, kernel, 1, 1);
        let x = Act::from_nchw(
            &(0..2 * in_ch * 6 * 6).map(|_| rng.normal() as f32).collect::<Vec<_>>(),
            2,
            in_ch,
            6,
            6,
        );
        let y = conv.forward(&x, true);
        // Eager reference through the same engine state.
        let sh = Conv2dShape {
            batch: 2, in_ch, in_h: 6, in_w: 6, out_ch, kernel, stride: 1, padding: 1,
        };
        let patches = im2col(&x.to_nchw(), &sh);
        let mut eng = engine;
        let y_ref = eng.forward(&patches);
        assert_close(&y.mat.data, &y_ref.data, 0.0, 0.0)
            .unwrap_or_else(|e| panic!("Conv2d fused forward != eager engine ({kind:?}): {e}"));
    }
}

#[test]
fn digital_fused_masked_weights_match_eager() {
    // SWAT-U style forward weight masking must survive the fused path.
    let mut rng = Rng::new(0x5a7e);
    let sh = Conv2dShape {
        batch: 2, in_ch: 2, in_h: 5, in_w: 5, out_ch: 4, kernel: 3, stride: 1, padding: 1,
    };
    let (input, _) = random_case(&sh, &mut rng);
    let mut eng = ProjEngine::new(EngineKind::Digital, sh.out_ch, sh.patch_rows(), &mut rng);
    if let ProjEngine::Digital { fwd_mask, w, .. } = &mut eng {
        *fwd_mask = Some((0..w.data.len()).map(|i| i % 3 != 0).collect());
    }
    let patches = im2col(&input, &sh);
    let mut e1 = eng.clone();
    let y_eager = e1.forward(&patches);
    let ex = PatchExtractor::new(&input, &sh);
    let y_fused =
        eng.forward_packed(sh.patch_cols(), &|c0, c1, dst: &mut [f32]| ex.pack_into(c0, c1, dst));
    assert_close(&y_fused.data, &y_eager.data, 0.0, 0.0).unwrap();
}

/// A naive direct convolution cross-check: the fused path is not just
/// self-consistent with im2col, it computes the convolution.
#[test]
fn fused_forward_matches_direct_convolution() {
    let pool = ThreadPool::new(2);
    let mut rng = Rng::new(0xd12e);
    let sh = Conv2dShape {
        batch: 2, in_ch: 3, in_h: 5, in_w: 4, out_ch: 4, kernel: 3, stride: 2, padding: 1,
    };
    let (input, w) = random_case(&sh, &mut rng);
    let y = conv2d_forward_packed_at(simd::active(), &pool, &w, &input, &sh);
    let (oh, ow) = (sh.out_h(), sh.out_w());
    for b in 0..sh.batch {
        for oc in 0..sh.out_ch {
            for o_r in 0..oh {
                for o_c in 0..ow {
                    let mut s = 0.0f32;
                    for ic in 0..sh.in_ch {
                        for kr in 0..sh.kernel {
                            for kc in 0..sh.kernel {
                                let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                                let icol = (o_c * sh.stride + kc) as isize - sh.padding as isize;
                                if ir >= 0
                                    && (ir as usize) < sh.in_h
                                    && icol >= 0
                                    && (icol as usize) < sh.in_w
                                {
                                    s += input[((b * sh.in_ch + ic) * sh.in_h + ir as usize)
                                        * sh.in_w
                                        + icol as usize]
                                        * w[(oc, (ic * sh.kernel + kr) * sh.kernel + kc)];
                                }
                            }
                        }
                    }
                    let col = b * (oh * ow) + o_r * ow + o_c;
                    let got = y[(oc, col)];
                    assert!(
                        (got - s).abs() < 1e-4 * (1.0 + s.abs()),
                        "direct conv mismatch at b{b} oc{oc} ({o_r},{o_c}): {got} vs {s}"
                    );
                }
            }
        }
    }
    // `matmul` sanity tie-back: same thing through the plain Mat product.
    let y_ref = matmul(&w, &im2col(&input, &sh));
    assert_close(&y.data, &y_ref.data, 1e-5, 1e-5).unwrap();
}
