//! Process-variation determinism: the Monte-Carlo chip sampler and the
//! yield estimator must be pure functions of (seed, sample index) —
//! bitwise identical at every thread count and shard count, and bitwise
//! *absent* when disabled. Extends the `lifecycle_determinism` patterns
//! to the fabrication-time variation layer.

use l2ight::coordinator::{run_job, JobConfig, MetricSink, Protocol};
use l2ight::data::DatasetKind;
use l2ight::nn::ModelArch;
use l2ight::photonics::{NoiseModel, ShardPolicy, ShardingConfig};
use l2ight::robustness::{estimate_yield, VariationConfig, YieldConstraints};
use l2ight::util::pool::ThreadPool;

fn varied_cfg() -> JobConfig {
    JobConfig {
        arch: ModelArch::MlpVowel,
        dataset: DatasetKind::VowelLike,
        protocol: Protocol::L2ight,
        k: 4,
        noise: NoiseModel::quant_only(8),
        width: 0.5,
        n_train: 96,
        n_test: 48,
        pretrain_epochs: 2,
        epochs: 2,
        batch: 16,
        alpha_w: 0.6,
        alpha_c: 1.0,
        alpha_d: 0.0,
        zo_budget: 0.1,
        seed: 4242,
        robustness: None,
        sharding: None,
        variation: Some(VariationConfig {
            gamma_std: 0.01,
            coupler_std: 0.005,
            loss_db_std: 0.05,
            wdm_max_drift: 0.01,
            sample: 0,
        }),
    }
}

#[test]
fn yield_report_is_bitwise_identical_across_thread_counts() {
    // The estimator fans samples out over the pool; the report (including
    // per-sample rows and fold order) must not depend on how many workers
    // ran them.
    let cfg = varied_cfg();
    let constraints = YieldConstraints::default();
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(4);
    let a = estimate_yield(&cfg, &constraints, 4, &serial);
    let b = estimate_yield(&cfg, &constraints, 4, &wide);
    assert_eq!(
        a.to_json().dump(),
        b.to_json().dump(),
        "yield report must be bitwise thread-count-invariant"
    );
    // And re-running the same configuration reproduces it exactly.
    let c = estimate_yield(&cfg, &constraints, 4, &wide);
    assert_eq!(b.to_json().dump(), c.to_json().dump());
}

#[test]
fn varied_job_is_bitwise_identical_across_shard_counts() {
    // Variation sampling walks the logical block grid in unsharded order,
    // so the same chip instance materializes no matter how the mesh is
    // carved into chiplets — every deterministic metric must agree.
    let shardings = [
        None,
        Some(ShardingConfig { shards: 2, policy: ShardPolicy::Row }),
        Some(ShardingConfig { shards: 4, policy: ShardPolicy::Grid }),
    ];
    let mut outs = Vec::new();
    for sharding in shardings {
        let mut cfg = varied_cfg();
        cfg.sharding = sharding;
        let mut sink = MetricSink::memory();
        outs.push(run_job(&cfg, &mut sink));
    }
    let base = &outs[0];
    let v0 = base.variation.expect("variation outcome on varied job");
    let w0 = base.wdm.expect("wdm summary when wdm_max_drift > 0");
    for (i, s) in outs.iter().enumerate().skip(1) {
        assert_eq!(base.final_acc, s.final_acc, "final_acc diverged at sharding #{i}");
        assert_eq!(base.best_acc, s.best_acc, "best_acc diverged at sharding #{i}");
        assert_eq!(base.zo_queries, s.zo_queries, "zo_queries diverged at sharding #{i}");
        assert_eq!(
            base.cost.total_energy(),
            s.cost.total_energy(),
            "energy diverged at sharding #{i}"
        );
        assert_eq!(Some(v0), s.variation, "variation outcome diverged at sharding #{i}");
        assert_eq!(Some(w0), s.wdm, "wdm summary diverged at sharding #{i}");
    }
}

#[test]
fn disabled_variation_is_bitwise_neutral() {
    // variation: Some(inactive) and variation: None must produce identical
    // metrics — realization may not touch any RNG stream or overlay.
    let mut plain_cfg = varied_cfg();
    plain_cfg.variation = None;
    let mut inactive_cfg = plain_cfg.clone();
    inactive_cfg.variation = Some(VariationConfig::default());
    let mut s1 = MetricSink::memory();
    let mut s2 = MetricSink::memory();
    let plain = run_job(&plain_cfg, &mut s1);
    let inactive = run_job(&inactive_cfg, &mut s2);
    assert_eq!(plain.final_acc, inactive.final_acc);
    assert_eq!(plain.best_acc, inactive.best_acc);
    assert_eq!(plain.zo_queries, inactive.zo_queries);
    assert_eq!(plain.cost.total_energy(), inactive.cost.total_energy());
    assert!(inactive.variation.is_none(), "inactive config must not emit an outcome");
    assert!(inactive.wdm.is_none(), "no wdm sweep without a requested drift");
}

#[test]
fn distinct_samples_are_distinct_chips_with_shared_seed() {
    // `sample` indexes independent chip instances under one seed: sample 0
    // twice must agree bitwise, sample 1 must differ somewhere observable.
    let cfg = varied_cfg();
    let again = cfg.clone();
    let mut other = cfg.clone();
    other.variation = cfg.variation.map(|v| VariationConfig { sample: 1, ..v });
    let mut s1 = MetricSink::memory();
    let mut s2 = MetricSink::memory();
    let mut s3 = MetricSink::memory();
    let a = run_job(&cfg, &mut s1);
    let b = run_job(&again, &mut s2);
    let c = run_job(&other, &mut s3);
    assert_eq!(a.variation, b.variation);
    assert_eq!(a.final_acc, b.final_acc);
    assert_ne!(
        (a.variation, a.final_acc, a.cost.total_energy()),
        (c.variation, c.final_acc, c.cost.total_energy()),
        "a different sample index must realize a different chip"
    );
}
