//! Checkpoint save → load → resume: continuing training from a restored
//! checkpoint must produce exactly the same metrics as never having
//! interrupted the run.
//!
//! Noise model note: checkpoints restore *programmed* state (phases, Σ,
//! electronic params). Fab-time device randomness (γ, Φ_b) is re-sampled
//! per model instance, so bit-exact resume is asserted under quantization
//! noise, where the device instance is deterministic.

use l2ight::coordinator::{load_model_state, save_model_state};
use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::linalg::Mat;
use l2ight::nn::{build_model, Act, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::sl::{train, SlConfig};
use l2ight::util::Rng;

#[test]
fn resume_from_checkpoint_matches_uninterrupted_run() {
    let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) };
    let (train_set, test_set) =
        SynthSpec::quick(DatasetKind::VowelLike, 96, 48).with_difficulty(0.4).generate();

    // Phase 1: train, then checkpoint mid-flow.
    let mut original = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(71));
    let phase1 = SlConfig { seed: 0xa11ce, ..SlConfig::quick(2, 16) };
    train(&mut original, &train_set, &test_set, &phase1);
    let path = std::env::temp_dir()
        .join(format!("l2ight_resume_{}.ckpt", std::process::id()));
    save_model_state(&mut original, &path).unwrap();

    // Restore into a fresh instance built from a different init seed.
    let mut resumed = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(9999));
    load_model_state(&mut resumed, &path).unwrap();
    std::fs::remove_file(&path).ok();

    // The restored chip must already behave identically.
    let acc_orig = test_set.evaluate(&mut original, 16);
    let acc_resumed = test_set.evaluate(&mut resumed, 16);
    assert_eq!(acc_orig, acc_resumed, "restore changed behaviour before resuming");

    // Phase 2 on both: the uninterrupted model and the restored one see the
    // same config/seed, so every batch, mask, and update must coincide.
    let phase2 = SlConfig { seed: 0xb0b, ..SlConfig::quick(3, 16) };
    let r_orig = train(&mut original, &train_set, &test_set, &phase2);
    let r_resumed = train(&mut resumed, &train_set, &test_set, &phase2);

    assert_eq!(
        r_orig.final_test_acc, r_resumed.final_test_acc,
        "resumed run diverged from uninterrupted run"
    );
    assert_eq!(r_orig.best_test_acc, r_resumed.best_test_acc);
    assert_eq!(r_orig.cost.total_energy(), r_resumed.cost.total_energy());
    assert_eq!(r_orig.epochs.len(), r_resumed.epochs.len());
    for (a, b) in r_orig.epochs.iter().zip(&r_resumed.epochs) {
        assert_eq!(a.loss, b.loss, "epoch {} loss diverged", a.epoch);
        assert_eq!(a.train_acc, b.train_acc, "epoch {} train acc diverged", a.epoch);
    }

    // And the programmed transfer functions agree on fresh inputs.
    let x = Act::from_features(Mat::randn(8, 6, 1.0, &mut Rng::new(5)), 6);
    let y_orig = original.forward(&x, false);
    let y_resumed = resumed.forward(&x, false);
    assert_eq!(y_orig.mat.data, y_resumed.mat.data, "post-resume forward diverged");
}

#[test]
fn resume_is_not_vacuous_training_continues() {
    // Guard against the round-trip passing because nothing trains: phase 2
    // must actually move the parameters.
    let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) };
    let (train_set, test_set) =
        SynthSpec::quick(DatasetKind::VowelLike, 96, 48).with_difficulty(0.4).generate();
    let mut model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(71));
    let x = Act::from_features(Mat::randn(8, 6, 1.0, &mut Rng::new(5)), 6);
    let before = model.forward(&x, false).mat.data.clone();
    let r = train(&mut model, &train_set, &test_set, &SlConfig::quick(2, 16));
    let after = model.forward(&x, false).mat.data.clone();
    assert_ne!(before, after, "training was a no-op");
    assert!(r.final_test_acc.is_finite());
}
