//! CLI smoke: the release binary's subcommands run and print what they
//! promise. Uses the already-built binary when present; builds it otherwise
//! via CARGO_BIN_EXE (cargo provides it for integration tests).

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_l2ight")
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(bin()).args(args).output().expect("spawn l2ight");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn no_args_prints_usage() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("calibrate"));
}

#[test]
fn unknown_subcommand_fails() {
    let (_, _, ok) = run(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn help_flags_work_per_subcommand() {
    let subs =
        ["run", "matrix", "matrix-diff", "calibrate", "map", "infer", "serve-bench", "artifacts"];
    for sub in subs {
        let out = Command::new(bin()).args([sub, "--help"]).output().unwrap();
        let text = String::from_utf8_lossy(&out.stderr).to_string()
            + &String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("USAGE"), "{sub} --help missing usage");
    }
}

#[test]
fn calibrate_reports_mse_drop() {
    let (stdout, stderr, ok) =
        run(&["calibrate", "--rows", "4", "--cols", "4", "--k", "4", "--iters", "80"]);
    assert!(ok, "calibrate failed: {stderr}");
    assert!(stdout.contains("mean MSE"), "{stdout}");
}

#[test]
fn map_reports_fidelity() {
    let (stdout, stderr, ok) = run(&[
        "map", "--rows", "4", "--cols", "4", "--k", "4", "--iters", "10", "--alternations", "1",
    ]);
    assert!(ok, "map failed: {stderr}");
    assert!(stdout.contains("rel err"), "{stdout}");
}

#[test]
fn run_tiny_job_end_to_end() {
    let (stdout, stderr, ok) = run(&[
        "run",
        "--arch", "mlp",
        "--dataset", "vowel",
        "--k", "4",
        "--epochs", "1",
        "--pretrain-epochs", "2",
        "--n-train", "48",
        "--n-test", "32",
        "--zo-budget", "0.1",
        "--seed", "5",
    ]);
    assert!(ok, "run failed: {stderr}");
    assert!(stdout.contains("final acc"), "{stdout}");
    assert!(stdout.contains("PTC energy"), "{stdout}");
}

#[test]
fn matrix_list_names_rows_without_running() {
    let (stdout, stderr, ok) = run(&["matrix", "--tier", "quick", "--list"]);
    assert!(ok, "matrix --list failed: {stderr}");
    let names: Vec<&str> = stdout.lines().collect();
    assert!(names.len() >= 10, "{stdout}");
    assert!(names.iter().any(|n| n.starts_with("l2ight/")), "{stdout}");
    // Filters narrow the listing.
    let (filtered, _, ok) =
        run(&["matrix", "--tier", "quick", "--list", "--filter", "cnn-s"]);
    assert!(ok);
    assert!(filtered.lines().count() < names.len());
    assert!(filtered.lines().all(|n| n.contains("cnn-s")), "{filtered}");
}

#[test]
fn matrix_bless_flags_are_validated_before_running() {
    let (_, stderr, ok) = run(&["matrix", "--tier", "quick", "--bless"]);
    assert!(!ok);
    assert!(stderr.contains("--golden"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "matrix", "--tier", "quick", "--bless", "--golden", "g.json", "--filter", "rad/",
    ]);
    assert!(!ok);
    assert!(stderr.contains("filtered"), "{stderr}");
}

#[test]
fn matrix_rejects_unknown_tier_and_empty_filter() {
    let (_, _, ok) = run(&["matrix", "--tier", "nope", "--list"]);
    assert!(!ok);
    let (_, _, ok) = run(&["matrix", "--tier", "quick", "--list", "--filter", "zzz-no-row"]);
    assert!(!ok);
}

#[test]
fn serve_bench_closes_the_loop_and_writes_history() {
    let path = std::env::temp_dir().join(format!("l2ight_serve_{}.json", std::process::id()));
    std::fs::remove_file(&path).ok();
    let (stdout, stderr, ok) = run(&[
        "serve-bench",
        "--engine", "digital",
        "--qps", "2000",
        "--requests", "200",
        "--max-wait-ms", "2",
        "--out", path.to_str().unwrap(),
    ]);
    assert!(ok, "serve-bench failed: {stderr}");
    assert!(stdout.contains("latency p99"), "{stdout}");
    let text = std::fs::read_to_string(&path).expect("history written");
    assert!(text.contains("\"bench\": \"serve\""), "{text}");
    assert!(text.contains("\"served\""), "{text}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn run_writes_metrics_jsonl() {
    let path = std::env::temp_dir().join(format!("l2ight_cli_{}.jsonl", std::process::id()));
    std::fs::remove_file(&path).ok();
    let (_, stderr, ok) = run(&[
        "run",
        "--arch", "mlp",
        "--dataset", "vowel",
        "--k", "4",
        "--protocol", "l2ight-sl",
        "--epochs", "1",
        "--n-train", "32",
        "--n-test", "16",
        "--metrics", path.to_str().unwrap(),
    ]);
    assert!(ok, "run failed: {stderr}");
    let text = std::fs::read_to_string(&path).expect("metrics file written");
    assert!(text.lines().any(|l| l.contains("job_done")), "{text}");
    std::fs::remove_file(&path).ok();
}
