//! Lifecycle-injection determinism: drift, faults, and the recovery loop
//! must be a pure function of (seed, step) — bitwise identical at every
//! thread count, under split/resume advancement, and across whole-job
//! re-runs. Extends the `parallel_equivalence` patterns to the robustness
//! layer.

use l2ight::coordinator::{run_job, JobConfig, MetricSink, Protocol};
use l2ight::data::DatasetKind;
use l2ight::linalg::Mat;
use l2ight::nn::ModelArch;
use l2ight::photonics::{NoiseModel, PhaseOverlay, PtcMesh};
use l2ight::robustness::{DriftConfig, DriftProcess, FaultKind, FaultPlan, FaultSpec, RobustnessConfig};
use l2ight::util::pool::ThreadPool;
use l2ight::util::prop::{assert_close, quickcheck};
use l2ight::util::Rng;

#[test]
fn prop_drift_resume_is_bitwise_identical_to_straight_run() {
    // Advancing a drift process to step T in one shot vs in arbitrary
    // chunks (simulating checkpoint/resume) must land on the exact same
    // state — the per-step RNG stream is keyed by (stream, step), never by
    // call history.
    quickcheck(
        "drift: split advance == straight advance",
        |rng: &mut Rng, size: usize| {
            let m = 1 + size % 24;
            let seed = rng.next_u64();
            let stream = rng.next_u64() % 64;
            let total = 1 + size % 40;
            let split = 1 + rng.below(total.max(1));
            (m, seed, stream, total as u64, split as u64)
        },
        |case| {
            let &(m, seed, stream, total, split) = case;
            let cfg = DriftConfig::default();
            let mut straight = DriftProcess::new(cfg, seed, stream, m);
            straight.advance_to(total);
            let mut resumed = DriftProcess::new(cfg, seed, stream, m);
            resumed.advance_to(split.min(total));
            resumed.advance_to(total);
            if straight.walk != resumed.walk {
                return Err("walk diverged under split advance".to_string());
            }
            if straight.gain != resumed.gain {
                return Err("gain diverged under split advance".to_string());
            }
            if straight.overlay() != resumed.overlay() {
                return Err("overlay diverged under split advance".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn fault_plan_is_a_pure_function_of_seed() {
    let specs = [
        FaultSpec { step: 3, kind: FaultKind::StuckPhase },
        FaultSpec { step: 7, kind: FaultKind::DeadMzi },
        FaultSpec { step: 7, kind: FaultKind::StuckPhase },
    ];
    let a = FaultPlan::resolve(&specs, 0xfeed, 4, 12);
    let b = FaultPlan::resolve(&specs, 0xfeed, 4, 12);
    assert_eq!(a.events, b.events, "same seed must give identical plans");
    let c = FaultPlan::resolve(&specs, 0xbeef, 4, 12);
    assert_ne!(a.events, c.events, "different seed should move the faults");
    // Schedule semantics: nothing before the first step, everything at/after.
    assert_eq!(a.first_fired(2), None);
    assert_eq!(a.first_fired(3), Some(3));
    assert_eq!(a.first_fired(100), Some(3));
}

#[test]
fn overlaid_mesh_forward_is_thread_count_invariant() {
    // A mesh carrying drift overlays + stuck devices must stay bitwise
    // thread-invariant: injection mutates per-block programmed state before
    // the fan-out, never inside it.
    let serial = ThreadPool::new(1);
    let wide = ThreadPool::new(5);
    quickcheck(
        "overlaid forward: threads=1 == threads=N",
        |rng: &mut Rng, size: usize| {
            let k = 2 + size % 5;
            let rows = k + 1 + size % 17;
            let cols = k + 1 + (size / 2) % 13;
            let b = 1 + size % 9;
            let w = Mat::randn(rows, cols, 0.5, rng);
            let mut mesh = PtcMesh::new(rows, cols, k, NoiseModel::quant_only(8), rng);
            mesh.program_from_dense(&w);
            let seed = rng.next_u64();
            let t = 1 + (size as u64) % 11;
            // Install drift + one stuck device per block, as the runtime does.
            let n_blocks = mesh.ptcs.len();
            for (gi, ptc) in mesh.ptcs.iter_mut().enumerate() {
                let m = ptc.u_mesh.phases.len();
                let mut du = DriftProcess::new(DriftConfig::default(), seed, (2 * gi) as u64, m);
                let mut dv =
                    DriftProcess::new(DriftConfig::default(), seed, (2 * gi + 1) as u64, m);
                du.advance_to(t);
                dv.advance_to(t);
                let mut ou = du.overlay();
                let ov = dv.overlay();
                ou.stuck.push((gi % m, 0.25));
                ptc.set_overlays(Some(ou), Some(ov));
            }
            mesh.invalidate();
            assert_eq!(n_blocks, mesh.ptcs.len());
            let x = Mat::randn(cols, b, 1.0, rng);
            (mesh, x)
        },
        |case| {
            let (mesh, x) = case;
            let mut m1 = mesh.clone();
            let mut m2 = mesh.clone();
            let y1 = m1.forward_masked_on(&serial, x, None, 1.0);
            let y2 = m2.forward_masked_on(&wide, x, None, 1.0);
            assert_close(&y1.data, &y2.data, 0.0, 0.0)
                .map_err(|e| format!("threads=1 vs threads=N: {e}"))
        },
    );
}

#[test]
fn identity_overlay_leaves_forward_bitwise_unchanged() {
    let pool = ThreadPool::new(2);
    let mut rng = Rng::new(0x11fe);
    let w = Mat::randn(8, 8, 0.5, &mut rng);
    let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::PAPER, &mut rng);
    mesh.program_from_dense(&w);
    let x = Mat::randn(8, 5, 1.0, &mut rng);
    let y_plain = mesh.clone().forward_masked_on(&pool, &x, None, 1.0);
    let mut overlaid = mesh.clone();
    for ptc in &mut overlaid.ptcs {
        let mu = ptc.u_mesh.phases.len();
        let mv = ptc.v_mesh.phases.len();
        ptc.set_overlays(Some(PhaseOverlay::identity(mu)), Some(PhaseOverlay::identity(mv)));
    }
    overlaid.invalidate();
    let y_overlaid = overlaid.forward_masked_on(&pool, &x, None, 1.0);
    assert_close(&y_plain.data, &y_overlaid.data, 0.0, 0.0).unwrap();
}

fn lifecycle_cfg() -> JobConfig {
    JobConfig {
        arch: ModelArch::MlpVowel,
        dataset: DatasetKind::VowelLike,
        protocol: Protocol::L2ight,
        k: 4,
        noise: NoiseModel::quant_only(8),
        width: 0.5,
        n_train: 96,
        n_test: 48,
        pretrain_epochs: 2,
        epochs: 3,
        batch: 16,
        alpha_w: 0.6,
        alpha_c: 1.0,
        alpha_d: 0.0,
        zo_budget: 0.1,
        seed: 1234,
        robustness: Some(RobustnessConfig::lifecycle_row(true, true)),
        sharding: None,
        variation: None,
    }
}

#[test]
fn lifecycle_job_is_reproducible_across_runs() {
    let cfg = lifecycle_cfg();
    let mut s1 = MetricSink::memory();
    let mut s2 = MetricSink::memory();
    let a = run_job(&cfg, &mut s1);
    let b = run_job(&cfg, &mut s2);
    assert_eq!(a.final_acc, b.final_acc);
    assert_eq!(a.best_acc, b.best_acc);
    assert_eq!(a.zo_queries, b.zo_queries);
    assert_eq!(a.cost.total_energy(), b.cost.total_energy());
    let (mut la, mut lb) = (a.lifecycle.expect("lifecycle report"), b.lifecycle.expect("lifecycle report"));
    // Wall time is the one legitimately nondeterministic field.
    la.recovery_secs = 0.0;
    lb.recovery_secs = 0.0;
    assert_eq!(la, lb, "lifecycle counters must be seed-deterministic");
}

#[test]
fn disabled_robustness_config_is_bitwise_neutral() {
    // robustness: Some(empty) and robustness: None must produce identical
    // metrics — the hooks may not perturb any RNG stream or counter.
    let mut plain_cfg = lifecycle_cfg();
    plain_cfg.robustness = None;
    let mut empty_cfg = plain_cfg.clone();
    empty_cfg.robustness = Some(RobustnessConfig::default());
    let mut s1 = MetricSink::memory();
    let mut s2 = MetricSink::memory();
    let plain = run_job(&plain_cfg, &mut s1);
    let empty = run_job(&empty_cfg, &mut s2);
    assert_eq!(plain.final_acc, empty.final_acc);
    assert_eq!(plain.best_acc, empty.best_acc);
    assert_eq!(plain.zo_queries, empty.zo_queries);
    assert_eq!(plain.cost.total_energy(), empty.cost.total_energy());
    assert!(empty.lifecycle.is_none(), "inactive config must not emit a report");
}
