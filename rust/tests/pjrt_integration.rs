//! Integration: the AOT artifacts (L1 Pallas kernels lowered through L2 jax
//! → HLO text) must agree numerically with the native rust simulator.
//!
//! Requires `make artifacts` (skipped with a notice otherwise, so plain
//! `cargo test` works in a fresh checkout).

use l2ight::linalg::Mat;
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::runtime::{ArgValue, Runtime};
use l2ight::util::prop::assert_close;
use l2ight::util::Rng;

const P: usize = 2;
const Q: usize = 2;
const K: usize = 9;
const B: usize = 18;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = l2ight::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

/// Extract the realized (noisy) per-block U/Σ/V* of the mesh in the
/// [P,Q,k,k]/[P,Q,k] layout the artifacts expect.
fn mesh_blocks(mesh: &mut PtcMesh) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let k = mesh.k;
    let mut u = Vec::with_capacity(P * Q * k * k);
    let mut s = Vec::with_capacity(P * Q * k);
    let mut v = Vec::with_capacity(P * Q * k * k);
    for pi in 0..mesh.p {
        for qi in 0..mesh.q {
            let q_cols = mesh.q;
            let ptc = &mut mesh.ptcs[pi * q_cols + qi];
            u.extend_from_slice(&ptc.realized_u().data);
            s.extend_from_slice(&ptc.sigma);
            v.extend_from_slice(&ptc.realized_v().data);
        }
    }
    (u, s, v)
}

/// [rows, B] column-major panels [Q,k,B] from a row-major Mat.
fn to_panels(x: &Mat, q: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; q * k * x.cols];
    for r in 0..x.rows {
        let (qi, ki) = (r / k, r % k);
        for c in 0..x.cols {
            out[(qi * k + ki) * x.cols + c] = x[(r, c)];
        }
    }
    out
}

fn from_panels(y: &[f32], p: usize, k: usize, b: usize) -> Mat {
    let mut m = Mat::zeros(p * k, b);
    for r in 0..p * k {
        m.row_mut(r).copy_from_slice(&y[r * b..(r + 1) * b]);
    }
    m
}

#[test]
fn pjrt_ptc_forward_matches_native_mesh() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0xa0);
    let mut mesh = PtcMesh::new(P * K, Q * K, K, NoiseModel::PAPER, &mut rng);
    // Program something non-trivial.
    let target = Mat::randn(P * K, Q * K, 0.5, &mut rng);
    mesh.program_from_dense(&target);
    let x = Mat::randn(Q * K, B, 1.0, &mut rng);

    let native = mesh.forward(&x);
    let (u, s, v) = mesh_blocks(&mut mesh);
    let xp = to_panels(&x, Q, K);
    let out = rt
        .call1_f32(
            &format!("ptc_forward_p{P}_q{Q}_k{K}_b{B}"),
            &[ArgValue::F32(&u), ArgValue::F32(&s), ArgValue::F32(&v), ArgValue::F32(&xp)],
        )
        .expect("pjrt call");
    let pjrt = from_panels(&out, P, K, B);
    assert_close(&native.data, &pjrt.data, 1e-4, 1e-4).expect("native vs PJRT forward");
}

#[test]
fn pjrt_sigma_grad_matches_native_mesh() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0xa1);
    let mut mesh = PtcMesh::new(P * K, Q * K, K, NoiseModel::PAPER, &mut rng);
    let target = Mat::randn(P * K, Q * K, 0.5, &mut rng);
    mesh.program_from_dense(&target);
    let x = Mat::randn(Q * K, B, 1.0, &mut rng);
    let dy = Mat::randn(P * K, B, 1.0, &mut rng);

    let native = mesh.sigma_grad(&x, &dy, None, 1.0);
    let (u, _s, v) = mesh_blocks(&mut mesh);
    let xp = to_panels(&x, Q, K);
    let dyp = to_panels(&dy, P, K);
    let out = rt
        .call1_f32(
            &format!("sigma_grad_p{P}_q{Q}_k{K}_b{B}"),
            &[ArgValue::F32(&u), ArgValue::F32(&v), ArgValue::F32(&xp), ArgValue::F32(&dyp)],
        )
        .expect("pjrt call");
    // Artifact layout [P,Q,k] equals the mesh's flattened block order.
    assert_close(&native, &out, 1e-3, 1e-3).expect("native vs PJRT sigma grad");
}

#[test]
fn pjrt_feedback_matches_native_mesh() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0xa2);
    let mut mesh = PtcMesh::new(P * K, Q * K, K, NoiseModel::PAPER, &mut rng);
    let target = Mat::randn(P * K, Q * K, 0.5, &mut rng);
    mesh.program_from_dense(&target);
    let dy = Mat::randn(P * K, B, 1.0, &mut rng);

    let native = mesh.feedback(&dy, None, 1.0);
    let (u, s, v) = mesh_blocks(&mut mesh);
    let dyp = to_panels(&dy, P, K);
    let out = rt
        .call1_f32(
            &format!("feedback_p{P}_q{Q}_k{K}_b{B}"),
            &[ArgValue::F32(&u), ArgValue::F32(&s), ArgValue::F32(&v), ArgValue::F32(&dyp)],
        )
        .expect("pjrt call");
    let pjrt = from_panels(&out, Q, K, B);
    assert_close(&native.data, &pjrt.data, 1e-3, 1e-3).expect("native vs PJRT feedback");
}

#[test]
fn pjrt_mlp_step_loss_is_finite_and_shapes_match() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest().find("vowel_mlp_step_b16").expect("mlp step artifact").clone();
    let mut rng = Rng::new(0xa3);
    // Random but orthonormal-ish args are unnecessary here: the artifact is
    // pure math; we only validate plumbing + output arity + finiteness.
    let mut args_data: Vec<Vec<f32>> = Vec::new();
    let mut labels: Vec<i32> = Vec::new();
    for (i, a) in spec.args.iter().enumerate() {
        match a.dtype {
            l2ight::runtime::DType::F32 => {
                let mut v = vec![0.0f32; a.numel()];
                rng.fill_normal(&mut v, 0.0, 0.3);
                args_data.push(v);
                let _ = i;
            }
            l2ight::runtime::DType::I32 => {
                labels = (0..a.numel()).map(|j| (j % 4) as i32).collect();
                args_data.push(Vec::new());
            }
        }
    }
    let args: Vec<ArgValue> = spec
        .args
        .iter()
        .zip(&args_data)
        .map(|(a, d)| match a.dtype {
            l2ight::runtime::DType::F32 => ArgValue::F32(d),
            l2ight::runtime::DType::I32 => ArgValue::I32(&labels),
        })
        .collect();
    let out = rt.call("vowel_mlp_step_b16", &args).expect("mlp step");
    assert_eq!(out.len(), spec.outputs);
    let loss = out[0].as_f32().unwrap();
    assert_eq!(loss.len(), 1);
    assert!(loss[0].is_finite(), "loss {}", loss[0]);
    let logits = out[1].as_f32().unwrap();
    assert_eq!(logits.len(), 4 * 16);
}

#[test]
fn runtime_validates_arguments() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Wrong arity.
    assert!(rt.call("ptc_forward_p2_q2_k9_b18", &[]).is_err());
    // Wrong length.
    let short = vec![0.0f32; 3];
    let args = [
        ArgValue::F32(&short),
        ArgValue::F32(&short),
        ArgValue::F32(&short),
        ArgValue::F32(&short),
    ];
    assert!(rt.call("ptc_forward_p2_q2_k9_b18", &args).is_err());
    // Unknown artifact.
    assert!(rt.call("nope", &[]).is_err());
}
