//! Locks the scenario-matrix contract: quick-tier rows are deterministic —
//! bitwise-identical metrics at every thread count and independent of which
//! other rows run — and the golden gate catches real drift.

use std::path::PathBuf;

use l2ight::scenarios::{
    diff_reports, expand, report_json, run_matrix, write_report, GoldenOutcome, MatrixSpec,
    RowResult, Tier, Tolerances,
};
use l2ight::util::json::Json;
use l2ight::util::ThreadPool;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("l2ight_scn_{name}_{}", std::process::id()))
}

/// A cheap but representative quick-tier slice: the full three-stage flow,
/// a first-order baseline, and a ZO baseline.
fn subset_spec() -> MatrixSpec {
    MatrixSpec {
        filters: vec![
            "l2ight/mlp-vowel/vowel/quant8".to_string(),
            "rad/".to_string(),
            "flops/".to_string(),
        ],
        ..MatrixSpec::new(Tier::Quick)
    }
}

#[test]
fn quick_rows_are_bitwise_thread_invariant() {
    let rows = expand(&subset_spec());
    let names: Vec<&String> = rows.iter().map(|r| &r.name).collect();
    assert_eq!(rows.len(), 3, "filter selected {names:?}");

    // Serial outer pool: rows sequential, inner engine parallelism active.
    let serial = run_matrix(&rows, &ThreadPool::new(1));
    // Wide outer pool: rows concurrent, inner parallelism inlined.
    let wide = run_matrix(&rows, &ThreadPool::new(4));

    let rep_serial = report_json(Tier::Quick, 1, "scalar", &serial);
    let rep_wide = report_json(Tier::Quick, 4, "scalar", &wide);
    match diff_reports(&rep_wide, &rep_serial, &Tolerances::STRICT) {
        GoldenOutcome::Match { rows } => assert_eq!(rows, 3),
        GoldenOutcome::Mismatch(ds) => {
            panic!(
                "thread count changed row metrics: {:?}",
                ds.iter()
                    .map(|d| format!("{} :: {} {} vs {}", d.row, d.metric, d.got, d.want))
                    .collect::<Vec<_>>()
            );
        }
        other => panic!("unexpected outcome {other:?}"),
    }

    // The L2ight row must expose the full stage ladder.
    let l2 = &serial[0];
    assert!(l2.row.name.starts_with("l2ight/"));
    assert!(l2.summary.ic_mse.is_some());
    assert!(l2.summary.pm_err.is_some());
    assert!(l2.summary.zo_queries > 0);
    assert!(l2.summary.cost.total_energy() > 0.0);
    assert!(!l2.summary.stage_secs.is_empty());
    // Baselines report no IC/PM fidelity.
    for r in &serial[1..] {
        assert!(r.summary.ic_mse.is_none(), "{}", r.row.name);
    }
}

#[test]
fn rows_reproduce_in_isolation() {
    // A row run through a single-row matrix must equal the same row run
    // alongside others (seeds derive from (base, index), not run order).
    let all = run_matrix(&expand(&subset_spec()), &ThreadPool::new(2));
    let solo_spec = MatrixSpec {
        filters: vec!["rad/".to_string()],
        ..MatrixSpec::new(Tier::Quick)
    };
    let solo = run_matrix(&expand(&solo_spec), &ThreadPool::new(1));
    assert_eq!(solo.len(), 1);
    let joint = all.iter().find(|r| r.row.name == solo[0].row.name).unwrap();
    assert_eq!(joint.summary.final_acc, solo[0].summary.final_acc);
    assert_eq!(joint.summary.best_acc, solo[0].summary.best_acc);
    assert_eq!(joint.summary.cost.total_energy(), solo[0].summary.cost.total_energy());
    assert_eq!(joint.summary.zo_queries, solo[0].summary.zo_queries);
}

fn one_cheap_result() -> Vec<RowResult> {
    let spec = MatrixSpec {
        filters: vec!["rad/".to_string()],
        ..MatrixSpec::new(Tier::Quick)
    };
    run_matrix(&expand(&spec), &ThreadPool::new(1))
}

#[test]
fn golden_roundtrip_bless_then_gate() {
    let results = one_cheap_result();
    let report = report_json(Tier::Quick, 1, "scalar", &results);
    let path = tmp("golden.json");
    write_report(&path, &report).unwrap();

    // Freshly blessed golden matches strictly.
    let gold = l2ight::scenarios::golden::load(&path).unwrap();
    assert!(matches!(
        diff_reports(&report, &gold, &Tolerances::STRICT),
        GoldenOutcome::Match { .. }
    ));

    // Inject a metric drift into the golden and the gate must fire.
    let mut drifted = gold.clone();
    if let Json::Obj(root) = &mut drifted {
        if let Some(Json::Arr(rows)) = root.get_mut("rows") {
            if let Some(metrics) = rows[0].get("metrics") {
                let old = metrics.get("final_acc").unwrap().as_f64().unwrap();
                let mut m = metrics.clone();
                m.set("final_acc", Json::Num(old + 0.5));
                rows[0].set("metrics", m);
            }
        }
    }
    match diff_reports(&report, &drifted, &Tolerances::gate()) {
        GoldenOutcome::Mismatch(ds) => {
            assert!(ds.iter().any(|d| d.metric == "final_acc"), "{ds:?}");
        }
        other => panic!("drift not caught: {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn placeholder_golden_reports_unblessed() {
    let results = one_cheap_result();
    let report = report_json(Tier::Quick, 1, "scalar", &results);
    let mut placeholder = Json::obj();
    placeholder.set("placeholder", Json::Bool(true));
    assert!(matches!(
        diff_reports(&report, &placeholder, &Tolerances::gate()),
        GoldenOutcome::Unblessed
    ));
}
