//! Stage scheduler: turns a `JobConfig` into a full experiment run.
//!
//! For the L2ight protocol this is the paper's Figure-2 flow: digital
//! pretraining (standing in for "an offline-trained model"), identity
//! calibration, parallel mapping (+ aux-parameter transfer), then sparse
//! subspace learning. Baseline protocols reuse the same substrate with
//! their own update rules / samplers, so every row of Fig. 10/11/Table 2
//! is produced by the same code path with one enum flipped.
//!
//! `run_job` is re-entrant: every piece of randomness derives from
//! `cfg.seed` (no process-global state, one `MetricSink` per call), so the
//! scenario-matrix engine (`crate::scenarios`) can fan jobs out across the
//! shared thread pool and still get results that are independent of
//! execution order and thread count. Batches of jobs should seed each row
//! with [`job_seed`] — a pure mix of (base seed, row index) — never by
//! drawing row seeds from a shared sequential `Rng`.

use crate::baselines;
use crate::coordinator::config::{JobConfig, Protocol};
use crate::coordinator::metrics::MetricSink;
use crate::data::{Augment, Dataset, DatasetKind, SynthSpec};
use crate::nn::{build_model, EngineKind};
use crate::photonics::dispersion::WdmSummary;
use crate::profiler::CostBreakdown;
use crate::robustness::variation::analyze_wdm;
use crate::robustness::{apply_variation, LifecycleReport, LifecycleRuntime, VariationOutcome};
use crate::stages::ic::{calibrate_model, IcConfig};
use crate::stages::pm::{copy_aux_params, map_model, PmConfig};
use crate::stages::sl::{train, train_with_lifecycle, OptKind, SlConfig, SlReport};
use crate::util::json::Json;
use crate::util::Rng;
use crate::zoo::ZoConfig;

/// Derive the seed for job `index` of a batch (scenario-matrix row, bench
/// repetition, …) from one base seed. A pure SplitMix64 mix rather than a
/// shared sequential `Rng`, so a row's seed — and therefore its result —
/// depends only on `(base, index)`, never on which other rows ran or in
/// what order.
pub fn job_seed(base: u64, index: u64) -> u64 {
    let mut z = base
        .wrapping_add(0x9e3779b97f4a7c15)
        .wrapping_add(index.wrapping_mul(0xbf58476d1ce4e5b9));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Fraction of a run's best test accuracy that defines the "queries to
/// target" budget-parity metric (`JobSummary::zo_to_target_queries`).
pub const ZO_TARGET_FRACTION: f32 = 0.9;

/// Outcome of one job.
#[derive(Clone, Debug)]
pub struct JobSummary {
    pub protocol: Protocol,
    /// Trainable (subspace) and total (dense-equivalent) parameter counts.
    pub trainable_params: usize,
    pub total_params: usize,
    pub final_acc: f32,
    pub best_acc: f32,
    /// Digital pretraining accuracy (L2ight only).
    pub pretrain_acc: Option<f32>,
    /// Accuracy right after mapping, before any SL (L2ight only).
    pub mapped_acc: Option<f32>,
    /// IC quality (mean (MSEᵁ+MSEⱽ)/2) if IC ran.
    pub ic_mse: Option<f64>,
    /// PM normalized matrix distance after OSP if PM ran.
    pub pm_err: Option<f64>,
    /// SL hardware cost (PTC calls / steps).
    pub cost: CostBreakdown,
    /// ZO hardware queries (IC+PM, or the whole run for ZO protocols).
    pub zo_queries: u64,
    /// Per-epoch record of the (final) training phase.
    pub sl: Option<SlReport>,
    /// Lifecycle outcome when a `RobustnessConfig` supervised the run.
    pub lifecycle: Option<LifecycleReport>,
    /// Process-variation outcome when `cfg.variation` perturbed devices.
    pub variation: Option<VariationOutcome>,
    /// Post-training WDM dispersion sweep when `cfg.variation` asked for it.
    pub wdm: Option<WdmSummary>,
    /// ZO hardware queries spent to reach `ZO_TARGET_FRACTION`·best_acc:
    /// calibration queries (IC+PM) for L2ight, 0 for the calibration-free
    /// scratch protocols, and the first target-reaching epoch's cumulative
    /// queries for the ZO baselines (`None` if the trace never gets there).
    pub zo_to_target_queries: Option<u64>,
    /// Stages the protocol skipped (e.g. `"pretrain"` when
    /// `pretrain_epochs == 0`; baselines skip `"pretrain"/"ic"/"pm"`).
    pub skipped_stages: Vec<&'static str>,
    /// Wall time per executed stage, in run order (`("ic", secs)`, …).
    /// Diagnostic only — excluded from golden-metric comparisons.
    pub stage_secs: Vec<(&'static str, f64)>,
}

/// Build the (train, test) datasets a config asks for.
pub fn build_datasets(cfg: &JobConfig) -> (Dataset, Dataset) {
    let mut spec = SynthSpec::new(cfg.dataset, cfg.n_train, cfg.n_test);
    spec.sample_seed = cfg.seed;
    spec.generate()
}

/// Augmentation policy per dataset (paper §4.1: crop/flip/jitter on CIFAR
/// and Tiny).
pub fn augment_for(kind: DatasetKind) -> Augment {
    match kind {
        DatasetKind::Cifar10Like | DatasetKind::Cifar100Like | DatasetKind::TinyLike => {
            Augment::CIFAR
        }
        _ => Augment::NONE,
    }
}

fn classes_of(ds: &Dataset) -> usize {
    ds.classes
}

fn scaled_zo(iters: usize, budget: f32) -> usize {
    ((iters as f32 * budget).round() as usize).max(4)
}

/// Cumulative ZO queries at the first epoch whose test accuracy reaches
/// `ZO_TARGET_FRACTION`·best; `None` when no epoch in the trace got there
/// (degenerate runs — e.g. zero epochs).
fn zo_queries_to_target(r: &baselines::ZoTrainReport) -> Option<u64> {
    let target = ZO_TARGET_FRACTION * r.best_test_acc;
    r.epoch_test_acc
        .iter()
        .zip(&r.epoch_queries)
        .find(|(&a, _)| a >= target)
        .map(|(_, &q)| q)
}

fn ic_config(cfg: &JobConfig) -> IcConfig {
    let d = IcConfig::default();
    IcConfig {
        zo: ZoConfig { iters: scaled_zo(d.zo.iters, cfg.zo_budget), ..d.zo },
        seed: cfg.seed ^ 0x1c,
        ..d
    }
}

fn pm_config(cfg: &JobConfig) -> PmConfig {
    let d = PmConfig::default();
    PmConfig {
        zo: ZoConfig { iters: scaled_zo(d.zo.iters, cfg.zo_budget), ..d.zo },
        seed: cfg.seed ^ 0x97,
        ..d
    }
}

fn base_sl(cfg: &JobConfig, mapped: bool) -> SlConfig {
    SlConfig {
        epochs: cfg.epochs,
        batch: cfg.batch,
        opt: if mapped {
            OptKind::AdamW { lr: 2e-4, weight_decay: 1e-2 }
        } else {
            OptKind::AdamW { lr: 2e-3, weight_decay: 1e-2 }
        },
        augment: augment_for(cfg.dataset),
        seed: cfg.seed ^ 0x51,
        eval_every: 1,
        ..SlConfig::default()
    }
}

/// Record the wall time of the stage that just finished and restart the
/// stage clock.
fn mark_stage(summary: &mut JobSummary, clock: &mut std::time::Instant, stage: &'static str) {
    summary.stage_secs.push((stage, clock.elapsed().as_secs_f64()));
    *clock = std::time::Instant::now();
}

/// Fold a finished lifecycle runtime into the summary: recovery/probe
/// queries join the ZO budget, recovery wall time joins the stage timings
/// (never the golden-gated metrics — it's nondeterministic).
fn finish_lifecycle(
    summary: &mut JobSummary,
    sink: &mut MetricSink,
    lifecycle: Option<LifecycleRuntime>,
) {
    let Some(rt) = lifecycle else { return };
    let rep = rt.finish();
    summary.zo_queries += rep.recovery_queries + rep.probe_queries;
    summary.stage_secs.push(("recovery", rep.recovery_secs));
    sink.emit_nums(
        "lifecycle_done",
        &[
            ("trigger_step", rep.trigger_step.map(|t| t as f64).unwrap_or(-1.0)),
            ("recoveries", rep.recoveries as f64),
            ("recovered_blocks", rep.recovered_blocks as f64),
            ("dead_blocks", rep.dead_blocks as f64),
            ("recovery_queries", rep.recovery_queries as f64),
            ("probe_queries", rep.probe_queries as f64),
        ],
    );
    summary.lifecycle = Some(rep);
}

/// Run one experiment end to end, emitting progress into `sink`.
pub fn run_job(cfg: &JobConfig, sink: &mut MetricSink) -> JobSummary {
    let (train_set, test_set) = build_datasets(cfg);
    let classes = classes_of(&train_set);
    // All model-build randomness flows from one cfg.seed-derived stream;
    // stage schedules (IC/PM/SL) and batches use their own seed-xor-tagged
    // streams (see ic_config/pm_config/base_sl), so a job is a pure
    // function of its config.
    let mut model_rng = Rng::with_stream(cfg.seed, 0x10b);
    // `shards <= 1` stays on the single-mesh engine; the sharded engine is
    // bitwise-identical anyway, but only one of them should own the goldens.
    let kind = match cfg.sharding {
        Some(sc) if sc.shards > 1 => EngineKind::PhotonicSharded {
            k: cfg.k,
            noise: cfg.noise,
            shards: sc.shards,
            policy: sc.policy,
        },
        _ => EngineKind::Photonic { k: cfg.k, noise: cfg.noise },
    };
    let mut model = build_model(cfg.arch, kind, classes, cfg.width, &mut model_rng);
    // Fabrication-time process variation is realized before any stage runs:
    // the sampled chip instance is what IC/PM calibrate against and what
    // lifecycle drift/faults compose on top of (variation-first overlays).
    let variation = cfg
        .variation
        .filter(|v| v.has_variation())
        .map(|v| apply_variation(&mut model, &v, cfg.seed));
    if let Some(out) = &variation {
        sink.emit_nums(
            "variation_applied",
            &[
                ("power_penalty_db", out.power_penalty_db),
                ("blocks", out.blocks as f64),
            ],
        );
    }
    let (trainable, total) = model.param_counts();
    sink.emit(
        "job_start",
        &[
            ("config", cfg.to_json()),
            ("trainable_params", Json::Num(trainable as f64)),
            ("total_params", Json::Num(total as f64)),
        ],
    );

    let mut summary = JobSummary {
        protocol: cfg.protocol,
        trainable_params: trainable,
        total_params: total,
        final_acc: 0.0,
        best_acc: 0.0,
        pretrain_acc: None,
        mapped_acc: None,
        ic_mse: None,
        pm_err: None,
        cost: CostBreakdown::default(),
        zo_queries: 0,
        sl: None,
        lifecycle: None,
        variation,
        wdm: None,
        zo_to_target_queries: None,
        skipped_stages: Vec::new(),
        stage_secs: Vec::new(),
    };
    let mut clock = std::time::Instant::now();

    match cfg.protocol {
        Protocol::L2ight => {
            // Stage 0: digital pretraining (the paper's offline model).
            // The digital twin continues the same build stream; both builds
            // are fully determined by cfg.seed.
            let mut digital =
                build_model(cfg.arch, EngineKind::Digital, classes, cfg.width, &mut model_rng);
            if cfg.pretrain_epochs > 0 {
                let pre_cfg = SlConfig {
                    epochs: cfg.pretrain_epochs,
                    opt: OptKind::Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 },
                    eval_every: 0,
                    ..base_sl(cfg, false)
                };
                let pre = train(&mut digital, &train_set, &test_set, &pre_cfg);
                summary.pretrain_acc = Some(pre.final_test_acc);
                sink.emit_nums("pretrain_done", &[("acc", pre.final_test_acc as f64)]);
                mark_stage(&mut summary, &mut clock, "pretrain");
            } else {
                summary.skipped_stages.push("pretrain");
            }
            // Stage 1: identity calibration.
            let ic = calibrate_model(&mut model, &ic_config(cfg));
            summary.ic_mse = Some(ic.mean_mse());
            summary.zo_queries += ic.queries;
            sink.emit_nums(
                "ic_done",
                &[("mse", ic.mean_mse()), ("queries", ic.queries as f64)],
            );
            mark_stage(&mut summary, &mut clock, "ic");
            // Stage 2: parallel mapping + aux transfer.
            let pm = map_model(&mut model, &mut digital, &pm_config(cfg));
            copy_aux_params(&mut model, &mut digital);
            summary.pm_err = Some(pm.err_osp);
            summary.zo_queries += pm.queries;
            let mapped_acc = test_set.evaluate(&mut model, cfg.batch);
            summary.mapped_acc = Some(mapped_acc);
            sink.emit_nums(
                "pm_done",
                &[
                    ("err_init", pm.err_init),
                    ("err_osp", pm.err_osp),
                    ("queries", pm.queries as f64),
                    ("mapped_acc", mapped_acc as f64),
                ],
            );
            mark_stage(&mut summary, &mut clock, "pm");
            // L2ight reaches target accuracy at deployment: the mapped model
            // is already trained, so its ZO bill is exactly the calibration
            // (IC+PM) queries spent so far.
            summary.zo_to_target_queries = Some(summary.zo_queries);
            // Stage 3: sparse subspace learning (fine-tune).
            let sl_cfg = baselines::l2ight_sl_config(
                cfg.alpha_w,
                cfg.alpha_c,
                cfg.alpha_d,
                &base_sl(cfg, true),
            );
            model.reset_mesh_stats();
            // Lifecycle references are captured *after* IC/PM — the healthy
            // deployed state is what the watchdog defends.
            let mut lifecycle = cfg
                .robustness
                .as_ref()
                .filter(|rc| rc.active())
                .map(|rc| LifecycleRuntime::new(rc, &mut model, cfg.seed));
            let r = train_with_lifecycle(&mut model, &train_set, &test_set, &sl_cfg, lifecycle.as_mut());
            summary.final_acc = r.final_test_acc;
            summary.best_acc = r.best_test_acc.max(mapped_acc);
            summary.cost = r.cost;
            summary.sl = Some(r);
            mark_stage(&mut summary, &mut clock, "sl");
            finish_lifecycle(&mut summary, sink, lifecycle);
        }
        Protocol::L2ightSlScratch | Protocol::Rad | Protocol::SwatU => {
            summary.skipped_stages.extend(["pretrain", "ic", "pm"]);
            // Calibration-free first-order protocols spend no ZO queries to
            // reach their accuracy — the budget-parity metric is zero.
            summary.zo_to_target_queries = Some(0);
            let base = base_sl(cfg, false);
            let sl_cfg = match cfg.protocol {
                Protocol::L2ightSlScratch => {
                    baselines::l2ight_sl_config(cfg.alpha_w, cfg.alpha_c, cfg.alpha_d, &base)
                }
                Protocol::Rad => baselines::rad_config(cfg.alpha_c, &base),
                Protocol::SwatU => {
                    baselines::apply_swat_forward_masks(&mut model, cfg.alpha_w);
                    baselines::swat_config(cfg.alpha_w, cfg.alpha_c, &base)
                }
                _ => unreachable!(),
            };
            // Lifecycle supervision covers the subspace-learning scratch
            // protocol too; the mask-juggling baselines (SWAT-U) run clean.
            let mut lifecycle = if cfg.protocol == Protocol::L2ightSlScratch {
                cfg.robustness
                    .as_ref()
                    .filter(|rc| rc.active())
                    .map(|rc| LifecycleRuntime::new(rc, &mut model, cfg.seed))
            } else {
                None
            };
            let r = train_with_lifecycle(&mut model, &train_set, &test_set, &sl_cfg, lifecycle.as_mut());
            if cfg.protocol == Protocol::SwatU {
                baselines::clear_forward_masks(&mut model);
                summary.final_acc = test_set.evaluate(&mut model, cfg.batch);
            } else {
                summary.final_acc = r.final_test_acc;
            }
            summary.best_acc = r.best_test_acc.max(summary.final_acc);
            summary.cost = r.cost;
            summary.sl = Some(r);
            mark_stage(&mut summary, &mut clock, "sl");
            finish_lifecycle(&mut summary, sink, lifecycle);
        }
        Protocol::Flops | Protocol::MixedTrn => {
            summary.skipped_stages.extend(["pretrain", "ic", "pm"]);
            let zo_cfg = baselines::ZoTrainConfig {
                epochs: cfg.epochs,
                batch: cfg.batch,
                seed: cfg.seed ^ 0x20,
                ..Default::default()
            };
            let r = if cfg.protocol == Protocol::Flops {
                baselines::flops_train(&mut model, &train_set, &test_set, &zo_cfg)
            } else {
                baselines::mixedtrn_train(&mut model, &train_set, &test_set, &zo_cfg)
            };
            summary.final_acc = r.final_test_acc;
            summary.best_acc = r.best_test_acc;
            summary.cost = r.cost;
            summary.zo_queries = r.queries;
            summary.zo_to_target_queries = zo_queries_to_target(&r);
            mark_stage(&mut summary, &mut clock, "zo");
        }
    }

    // Post-training WDM wavelength sweep (read-only dispersion analysis of
    // the deployed programmed phases).
    if let Some(v) = cfg.variation.filter(|v| v.wdm_max_drift > 0.0) {
        let w = analyze_wdm(&mut model, v.wdm_max_drift);
        sink.emit_nums(
            "wdm_done",
            &[
                ("max_drift", w.max_drift),
                ("blocks", w.blocks as f64),
                ("worst_rel_err", w.worst_rel_err),
                ("mean_rel_err", w.mean_rel_err),
                ("worst_mse", w.worst_mse),
            ],
        );
        summary.wdm = Some(w);
        mark_stage(&mut summary, &mut clock, "wdm");
    }

    sink.emit_nums(
        "job_done",
        &[
            ("final_acc", summary.final_acc as f64),
            ("best_acc", summary.best_acc as f64),
            ("energy", summary.cost.total_energy()),
            ("steps", summary.cost.total_steps()),
            ("zo_queries", summary.zo_queries as f64),
        ],
    );
    summary
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelArch;
    use crate::photonics::NoiseModel;

    fn tiny_cfg(protocol: Protocol) -> JobConfig {
        JobConfig {
            arch: ModelArch::MlpVowel,
            dataset: DatasetKind::VowelLike,
            protocol,
            k: 4,
            noise: NoiseModel::quant_only(8),
            width: 0.5,
            n_train: 96,
            n_test: 48,
            pretrain_epochs: 6,
            epochs: 4,
            batch: 16,
            alpha_w: 0.6,
            alpha_c: 1.0,
            alpha_d: 0.0,
            zo_budget: 0.15,
            seed: 3,
            robustness: None,
            sharding: None,
            variation: None,
        }
    }

    #[test]
    fn full_l2ight_flow_runs_and_reports() {
        let mut sink = MetricSink::memory();
        let s = run_job(&tiny_cfg(Protocol::L2ight), &mut sink);
        assert!(s.pretrain_acc.is_some());
        assert!(s.ic_mse.is_some());
        assert!(s.pm_err.is_some());
        assert!(s.mapped_acc.is_some());
        assert!(s.final_acc > 0.25, "acc {}", s.final_acc);
        assert!(s.cost.total_energy() > 0.0);
        assert!(s.zo_queries > 0);
        // Mapping should land close to the pretrained model: mapped acc is
        // within reach of pretrain acc. Stages that didn't run are recorded
        // as skipped rather than panicking on a missing metric.
        let (Some(pre), Some(mapped)) = (s.pretrain_acc, s.mapped_acc) else {
            panic!("stage metrics missing; skipped stages: {:?}", s.skipped_stages);
        };
        assert!(mapped > pre - 0.25, "mapping destroyed the model: {pre} -> {mapped}");
        assert!(s.skipped_stages.is_empty());
        assert!(s.lifecycle.is_none(), "no robustness config, no lifecycle report");
        assert!(sink.last("job_done").is_some());
        assert!(sink.last("ic_done").is_some());
        let stages: Vec<&str> = s.stage_secs.iter().map(|(n, _)| *n).collect();
        assert_eq!(stages, vec!["pretrain", "ic", "pm", "sl"]);
        assert!(s.stage_secs.iter().all(|(_, t)| *t >= 0.0));
    }

    #[test]
    fn skipped_pretrain_is_recorded_not_a_panic() {
        let mut sink = MetricSink::memory();
        let mut cfg = tiny_cfg(Protocol::L2ight);
        cfg.pretrain_epochs = 0;
        let s = run_job(&cfg, &mut sink);
        assert_eq!(s.pretrain_acc, None);
        assert!(s.mapped_acc.is_some());
        assert_eq!(s.skipped_stages, vec!["pretrain"]);
        let stages: Vec<&str> = s.stage_secs.iter().map(|(n, _)| *n).collect();
        assert_eq!(stages, vec!["ic", "pm", "sl"]);
    }

    #[test]
    fn baselines_record_skipped_stages() {
        let mut sink = MetricSink::memory();
        let mut cfg = tiny_cfg(Protocol::Rad);
        cfg.epochs = 1;
        let s = run_job(&cfg, &mut sink);
        assert_eq!(s.skipped_stages, vec!["pretrain", "ic", "pm"]);
        assert_eq!(s.pretrain_acc, None);
    }

    #[test]
    fn lifecycle_closes_the_loop_and_disabled_config_is_neutral() {
        use crate::robustness::RobustnessConfig;
        let mut sink = MetricSink::memory();
        let base = {
            let mut c = tiny_cfg(Protocol::L2ight);
            c.pretrain_epochs = 2;
            c.epochs = 2;
            c
        };
        let plain = run_job(&base, &mut sink);

        // An explicitly-empty robustness config must not perturb a single
        // metric (no RNG streams or stat counters are touched).
        let mut empty = base.clone();
        empty.robustness = Some(RobustnessConfig::default());
        let neutral = run_job(&empty, &mut sink);
        assert_eq!(plain.final_acc, neutral.final_acc);
        assert_eq!(plain.best_acc, neutral.best_acc);
        assert_eq!(plain.cost, neutral.cost);
        assert_eq!(plain.zo_queries, neutral.zo_queries);
        assert!(neutral.lifecycle.is_none());

        // Faults + watchdog: the loop closes — detection fires and the
        // recovery budget is spent and accounted.
        let mut hostile = base.clone();
        hostile.robustness = Some(RobustnessConfig::lifecycle_row(true, true));
        let s = run_job(&hostile, &mut sink);
        let rep = s.lifecycle.expect("lifecycle report");
        assert_eq!(rep.faults, 2);
        assert!(rep.drift);
        assert!(rep.trigger_step.is_some(), "watchdog never fired");
        assert!(rep.probe_queries > 0);
        assert!(rep.recoveries > 0, "recovery budget unspent");
        assert!(s.zo_queries > plain.zo_queries, "recovery queries not folded in");
        assert!(s.stage_secs.iter().any(|(n, _)| *n == "recovery"));
        assert!(sink.last("lifecycle_done").is_some());

        // Same hostile config, same seed ⇒ identical deterministic outcome.
        let s2 = run_job(&hostile, &mut sink);
        assert_eq!(s.final_acc, s2.final_acc);
        assert_eq!(s.cost, s2.cost);
        let rep2 = s2.lifecycle.unwrap();
        assert_eq!(rep.trigger_step, rep2.trigger_step);
        assert_eq!(rep.recovery_queries, rep2.recovery_queries);

        // Recovery-off: detection still reported, budget untouched.
        let mut detect_only = base.clone();
        detect_only.robustness = Some(RobustnessConfig::lifecycle_row(true, false));
        let d = run_job(&detect_only, &mut sink);
        let drep = d.lifecycle.expect("lifecycle report");
        assert_eq!(drep.recoveries, 0);
        assert_eq!(drep.recovery_queries, 0);
        assert!(drep.trigger_step.is_some());
    }

    #[test]
    fn variation_and_wdm_flow_through_the_driver() {
        use crate::robustness::VariationConfig;
        let mut sink = MetricSink::memory();
        let base = {
            let mut c = tiny_cfg(Protocol::L2ightSlScratch);
            c.epochs = 2;
            c
        };
        let plain = run_job(&base, &mut sink);
        assert!(plain.variation.is_none());
        assert!(plain.wdm.is_none());

        let mut varied = base.clone();
        varied.variation = Some(VariationConfig {
            gamma_std: 0.01,
            coupler_std: 0.01,
            loss_db_std: 0.05,
            wdm_max_drift: 0.02,
            sample: 1,
        });
        let s = run_job(&varied, &mut sink);
        let out = s.variation.expect("variation outcome");
        assert!(out.blocks > 0);
        assert!(out.power_penalty_db > 0.0);
        let w = s.wdm.expect("wdm summary");
        assert!(w.blocks > 0);
        assert!(w.worst_rel_err > 0.0);
        assert!(sink.last("variation_applied").is_some());
        assert!(sink.last("wdm_done").is_some());
        assert!(s.stage_secs.iter().any(|(n, _)| *n == "wdm"));

        // Same config + seed ⇒ identical outcome: the Monte-Carlo sample is
        // a pure function of (seed, sample index).
        let s2 = run_job(&varied, &mut sink);
        assert_eq!(s.final_acc, s2.final_acc);
        assert_eq!(s.variation, s2.variation);
        assert_eq!(s.wdm, s2.wdm);

        // A different sample index is a different fabricated chip.
        let mut other = varied.clone();
        other.variation.as_mut().unwrap().sample = 2;
        let s3 = run_job(&other, &mut sink);
        assert_ne!(s.variation, s3.variation);

        // WDM-only config: sweep reported, training metrics untouched.
        let mut wdm_only = base.clone();
        wdm_only.variation =
            Some(VariationConfig { wdm_max_drift: 0.02, ..Default::default() });
        let sw = run_job(&wdm_only, &mut sink);
        assert!(sw.variation.is_none(), "wdm-only must not perturb devices");
        assert!(sw.wdm.is_some());
        assert_eq!(sw.final_acc, plain.final_acc);
        assert_eq!(sw.cost, plain.cost);
        assert_eq!(sw.zo_queries, plain.zo_queries);
    }

    #[test]
    fn zo_to_target_queries_is_protocol_aware() {
        // L2ight: the calibration bill — positive and at most the total.
        let mut sink = MetricSink::memory();
        let s = run_job(&tiny_cfg(Protocol::L2ight), &mut sink);
        let q = s.zo_to_target_queries.expect("l2ight reports calibration queries");
        assert!(q > 0 && q <= s.zo_queries, "calib {q} vs total {}", s.zo_queries);

        // Calibration-free scratch protocol: zero by definition.
        let mut cfg = tiny_cfg(Protocol::Rad);
        cfg.epochs = 1;
        assert_eq!(run_job(&cfg, &mut sink).zo_to_target_queries, Some(0));

        // ZO baseline: cumulative queries at the first epoch reaching
        // 0.9×its own best — always reached (the best epoch qualifies).
        let mut cfg = tiny_cfg(Protocol::MixedTrn);
        cfg.epochs = 2;
        cfg.n_train = 32;
        let s = run_job(&cfg, &mut sink);
        let q = s.zo_to_target_queries.expect("trace must reach 0.9×its own best");
        assert!(q > 0 && q <= s.zo_queries);
    }

    #[test]
    fn job_seed_is_pure_and_spreads() {
        assert_eq!(job_seed(42, 0), job_seed(42, 0));
        assert_ne!(job_seed(42, 0), job_seed(42, 1));
        assert_ne!(job_seed(42, 0), job_seed(43, 0));
        // Index 0 must not degenerate to the base seed itself.
        assert_ne!(job_seed(42, 0), 42);
        let seeds: std::collections::BTreeSet<u64> =
            (0..256u64).map(|i| job_seed(7, i)).collect();
        assert_eq!(seeds.len(), 256, "collisions in the first 256 rows");
    }

    #[test]
    fn scratch_and_baseline_protocols_run() {
        for p in [Protocol::L2ightSlScratch, Protocol::Rad, Protocol::SwatU] {
            let mut sink = MetricSink::memory();
            let mut cfg = tiny_cfg(p);
            cfg.epochs = 2;
            let s = run_job(&cfg, &mut sink);
            assert!(s.final_acc.is_finite());
            assert!(s.cost.total_energy() > 0.0, "{p:?} measured no cost");
            assert!(s.ic_mse.is_none());
        }
    }

    #[test]
    fn zo_protocols_count_queries() {
        let mut sink = MetricSink::memory();
        let mut cfg = tiny_cfg(Protocol::MixedTrn);
        cfg.epochs = 1;
        cfg.n_train = 32;
        let s = run_job(&cfg, &mut sink);
        assert!(s.zo_queries > 0);
        assert!(s.cost.total_energy() > 0.0);
        assert!(s.sl.is_none());
    }
}
