//! Layer-3 coordinator: the chip controller + training orchestrator.
//!
//! The paper's contribution is a *training protocol for an accelerator*, so
//! L3 owns everything around the photonic substrate:
//!
//! * [`config`]  — declarative job configs (JSON round-trip) naming the
//!   model, dataset, noise, stage schedules, and sampling sparsities;
//! * [`checkpoint`] — chip-state store: every programmed phase, Σ, and
//!   electronic parameter, serialized and restored bit-exactly;
//! * [`metrics`] — JSONL metric sink + run summaries;
//! * [`batcher`] — the inference dispatch batcher (request queue → batched
//!   PTC execution) used by the serving example;
//! * [`driver`] — the stage scheduler: pretrain → IC → PM → SL (or the
//!   requested baseline protocol), producing a `JobSummary`;
//! * [`pjrt_trainer`] — subspace training of the exported MLP entirely
//!   through the PJRT artifacts: the SL hot path with python nowhere in
//!   sight (build-time only), per the three-layer architecture.

pub mod batcher;
pub mod checkpoint;
pub mod config;
pub mod driver;
pub mod metrics;
pub mod pjrt_trainer;

pub use batcher::{Batcher, BatcherConfig, BatcherStats};
pub use checkpoint::{load_model_state, save_model_state};
pub use config::{JobConfig, Protocol};
pub use driver::{job_seed, run_job, JobSummary};
pub use metrics::MetricSink;
pub use pjrt_trainer::PjrtMlpTrainer;
