//! Subspace training driven entirely through the PJRT artifacts — the
//! demonstration that the SL hot path needs no python at runtime.
//!
//! The `vowel_mlp_step_b16` artifact (lowered once by `make artifacts` from
//! the L2 jax graph, which itself calls the L1 Pallas kernels) computes one
//! full training step: forward, loss, and the Eq. 5 reciprocity gradients
//! for Σ and biases. This trainer owns the parameter buffers, streams
//! batches through the compiled executable, and applies AdamW in rust —
//! exactly the division of labor of the paper's chip (PTC array computes,
//! electronic control updates).

use crate::data::{Dataset, Loader};
use crate::util::error::{anyhow, Result};
use crate::optim::{AdamW, Optimizer};
use crate::photonics::unitary::ReckMesh;
use crate::runtime::{ArgValue, Runtime};
use crate::util::Rng;

/// MLP topology baked into the artifacts (see python/compile/aot.py).
pub const DIMS: [usize; 4] = [8, 16, 16, 4];
pub const K: usize = 4;
pub const BATCH: usize = 16;

/// One layer's parameter buffers in artifact layout.
#[derive(Clone, Debug)]
struct LayerBuf {
    u: Vec<f32>,    // [p,q,k,k]
    s: Vec<f32>,    // [p,q,k]
    v: Vec<f32>,    // [p,q,k,k]
    bias: Vec<f32>, // [p·k]
}

/// Trainer state.
pub struct PjrtMlpTrainer {
    rt: Runtime,
    layers: Vec<LayerBuf>,
    opt: AdamW,
    step_name: String,
    fwd_name: String,
}

impl PjrtMlpTrainer {
    /// Random-unitary initialization (fab + IC state) with Kaiming-scaled Σ.
    pub fn new(rt: Runtime, seed: u64) -> Result<PjrtMlpTrainer> {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for li in 0..DIMS.len() - 1 {
            let p = DIMS[li + 1].div_ceil(K);
            let q = DIMS[li].div_ceil(K);
            let mut u = Vec::with_capacity(p * q * K * K);
            let mut v = Vec::with_capacity(p * q * K * K);
            let mut s = Vec::with_capacity(p * q * K);
            let bound = (6.0 / DIMS[li] as f32).sqrt();
            for _ in 0..p * q {
                u.extend_from_slice(&ReckMesh::random(K, &mut rng).synthesize().data);
                v.extend_from_slice(&ReckMesh::random(K, &mut rng).synthesize().data);
                for _ in 0..K {
                    s.push(rng.uniform_range(-bound as f64, bound as f64) as f32);
                }
            }
            let _ = q;
            layers.push(LayerBuf { u, s, v, bias: vec![0.0; p * K] });
        }
        let step_name = format!("vowel_mlp_step_b{BATCH}");
        let fwd_name = format!("vowel_mlp_fwd_b{BATCH}");
        for name in [&step_name, &fwd_name] {
            if rt.manifest().find(name).is_none() {
                return Err(anyhow!("artifact {name} missing — run `make artifacts`"));
            }
        }
        Ok(PjrtMlpTrainer { rt, layers, opt: AdamW::paper_scratch(), step_name, fwd_name })
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.opt.set_lr(lr);
    }

    /// Number of trainable subspace parameters (Σ + biases).
    pub fn trainable_params(&self) -> usize {
        self.layers.iter().map(|l| l.s.len() + l.bias.len()).sum()
    }

    /// Assemble one fixed-size batch in [features, BATCH] layout.
    fn batch_input(ds: &Dataset, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        assert!(idx.len() <= BATCH);
        let f = ds.sample_len();
        assert_eq!(f, DIMS[0], "dataset feature count must match artifact");
        let mut x = vec![0.0f32; f * BATCH];
        let mut labels = vec![0i32; BATCH];
        for (col, &i) in idx.iter().enumerate() {
            for (r, &v) in ds.sample(i).iter().enumerate() {
                x[r * BATCH + col] = v;
            }
            labels[col] = ds.labels[i] as i32;
        }
        // Pad by repeating the first sample (its gradient contribution is a
        // small bias for the final ragged batch only).
        for col in idx.len()..BATCH {
            for r in 0..f {
                x[r * BATCH + col] = x[r * BATCH];
            }
            labels[col] = labels[0];
        }
        (x, labels)
    }

    /// One training step on a full batch; returns the loss.
    pub fn step(&mut self, ds: &Dataset, idx: &[usize]) -> Result<f32> {
        let (x, labels) = Self::batch_input(ds, idx);
        let mut args = flat_args(&self.layers, &x);
        args.push(ArgValue::I32(&labels));
        let out = self.rt.call(&self.step_name, &args)?;
        let n = self.layers.len();
        // Outputs: loss, logits, σ-grads ×n, bias-grads ×n.
        let loss = out[0].as_f32()?[0];
        let mut key = 0usize;
        for (li, l) in self.layers.iter_mut().enumerate() {
            let sg = out[2 + li].as_f32()?;
            self.opt.step(key, &mut l.s, sg, true);
            key += 1;
            let bg = out[2 + n + li].as_f32()?;
            // Bias grads come back over the un-padded features; pad zeros.
            let mut full = vec![0.0f32; l.bias.len()];
            full[..bg.len()].copy_from_slice(bg);
            self.opt.step(key, &mut l.bias, &full, false);
            key += 1;
        }
        Ok(loss)
    }

    /// One epoch over the dataset; returns the mean loss.
    pub fn train_epoch(&mut self, ds: &Dataset, rng: &mut Rng) -> Result<f32> {
        let loader = Loader::new(ds.n, BATCH, rng);
        let mut total = 0.0f64;
        let mut n = 0usize;
        for idx in loader {
            total += self.step(ds, &idx)? as f64;
            n += 1;
        }
        Ok((total / n.max(1) as f64) as f32)
    }

    /// Classification accuracy through the forward artifact.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<f32> {
        let classes = DIMS[DIMS.len() - 1];
        let mut correct = 0usize;
        let mut i = 0usize;
        while i < ds.n {
            let hi = (i + BATCH).min(ds.n);
            let idx: Vec<usize> = (i..hi).collect();
            let (x, _) = Self::batch_input(ds, &idx);
            let args = flat_args(&self.layers, &x);
            let logits = self.rt.call1_f32(&self.fwd_name, &args)?;
            // logits layout [classes, BATCH].
            for (col, &gi) in idx.iter().enumerate() {
                let mut best = 0usize;
                let mut bv = f32::NEG_INFINITY;
                for c in 0..classes {
                    let v = logits[c * BATCH + col];
                    if v > bv {
                        bv = v;
                        best = c;
                    }
                }
                if best == ds.labels[gi] {
                    correct += 1;
                }
            }
            i = hi;
        }
        Ok(correct as f32 / ds.n.max(1) as f32)
    }
}

/// Artifact argument list: (u, s, v, bias) per layer then the input panel.
fn flat_args<'a>(layers: &'a [LayerBuf], x: &'a [f32]) -> Vec<ArgValue<'a>> {
    let mut args: Vec<ArgValue> = Vec::with_capacity(4 * layers.len() + 2);
    for l in layers {
        args.push(ArgValue::F32(&l.u));
        args.push(ArgValue::F32(&l.s));
        args.push(ArgValue::F32(&l.v));
        args.push(ArgValue::F32(&l.bias));
    }
    args.push(ArgValue::F32(x));
    args
}
