//! Declarative job configuration with JSON round-trip.
//!
//! A `JobConfig` fully determines an experiment: architecture, dataset,
//! photonic block size + noise, the three stage schedules, sampling
//! sparsities, and the training protocol (L2ight or a baseline). The CLI
//! builds one from flags; benches build them programmatically; both can be
//! saved alongside results for reproducibility.

use crate::data::DatasetKind;
use crate::nn::ModelArch;
use crate::photonics::{NoiseModel, ShardingConfig};
use crate::robustness::{RobustnessConfig, VariationConfig};
use crate::util::json::Json;

/// Which training protocol to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    /// Full three-stage flow: pretrain → IC → PM → sparse SL.
    L2ight,
    /// Subspace learning from scratch (no pretraining/mapping).
    L2ightSlScratch,
    /// FLOPS [20] full-space stochastic ZO.
    Flops,
    /// MixedTrn [17] sparse mixed ZO.
    MixedTrn,
    /// RAD [36] spatial-sampling first-order baseline.
    Rad,
    /// SWAT-U [38] sparse weight+activation baseline.
    SwatU,
}

impl Protocol {
    pub fn parse(s: &str) -> Option<Protocol> {
        Some(match s {
            "l2ight" => Protocol::L2ight,
            "l2ight-sl" | "sl-scratch" => Protocol::L2ightSlScratch,
            "flops" => Protocol::Flops,
            "mixedtrn" | "mixed-trn" => Protocol::MixedTrn,
            "rad" => Protocol::Rad,
            "swat" | "swat-u" => Protocol::SwatU,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Protocol::L2ight => "l2ight",
            Protocol::L2ightSlScratch => "l2ight-sl",
            Protocol::Flops => "flops",
            Protocol::MixedTrn => "mixedtrn",
            Protocol::Rad => "rad",
            Protocol::SwatU => "swat-u",
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub arch: ModelArch,
    pub dataset: DatasetKind,
    pub protocol: Protocol,
    /// Photonic block size (paper default 9).
    pub k: usize,
    pub noise: NoiseModel,
    /// Channel-width multiplier for the model zoo.
    pub width: f32,
    /// Train/test split sizes for the synthetic datasets.
    pub n_train: usize,
    pub n_test: usize,
    /// Pretraining epochs (digital; 0 = skip even for L2ight).
    pub pretrain_epochs: usize,
    /// SL epochs.
    pub epochs: usize,
    pub batch: usize,
    /// Sampling sparsities (keep fractions; 1.0 = dense / off).
    pub alpha_w: f32,
    pub alpha_c: f32,
    /// SMD skip probability (0 = off).
    pub alpha_d: f32,
    /// IC/PM ZO iteration budget multiplier (1.0 = paper-like default).
    pub zo_budget: f32,
    pub seed: u64,
    /// Lifecycle robustness (drift/fault injection + watchdog); `None`
    /// keeps every existing metric bitwise-unchanged.
    pub robustness: Option<RobustnessConfig>,
    /// Multi-chiplet sharding of every photonic layer; `None` (and
    /// `shards <= 1` at build time) keeps the single-mesh engine.
    pub sharding: Option<ShardingConfig>,
    /// Process-variation chip instance + WDM sweep; `None` keeps every
    /// existing metric and config dump bitwise-unchanged.
    pub variation: Option<VariationConfig>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            arch: ModelArch::MlpVowel,
            dataset: DatasetKind::VowelLike,
            protocol: Protocol::L2ight,
            k: 9,
            noise: NoiseModel::PAPER,
            width: 1.0,
            n_train: 512,
            n_test: 256,
            pretrain_epochs: 10,
            epochs: 10,
            batch: 32,
            alpha_w: 1.0,
            alpha_c: 1.0,
            alpha_d: 0.0,
            zo_budget: 1.0,
            seed: 42,
            robustness: None,
            sharding: None,
            variation: None,
        }
    }
}

impl JobConfig {
    /// Serialize to JSON (noise model flattened inline).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("arch", Json::Str(self.arch.name().into()))
            .set("dataset", Json::Str(self.dataset.name().into()))
            .set("protocol", Json::Str(self.protocol.name().into()))
            .set("k", Json::Num(self.k as f64))
            .set("width", Json::Num(self.width as f64))
            .set("n_train", Json::Num(self.n_train as f64))
            .set("n_test", Json::Num(self.n_test as f64))
            .set("pretrain_epochs", Json::Num(self.pretrain_epochs as f64))
            .set("epochs", Json::Num(self.epochs as f64))
            .set("batch", Json::Num(self.batch as f64))
            .set("alpha_w", Json::Num(self.alpha_w as f64))
            .set("alpha_c", Json::Num(self.alpha_c as f64))
            .set("alpha_d", Json::Num(self.alpha_d as f64))
            .set("zo_budget", Json::Num(self.zo_budget as f64))
            .set("seed", Json::Num(self.seed as f64));
        let mut n = Json::obj();
        n.set(
            "phase_bits",
            match self.noise.phase_bits {
                Some(b) => Json::Num(b as f64),
                None => Json::Null,
            },
        )
        .set(
            "sigma_bits",
            match self.noise.sigma_bits {
                Some(b) => Json::Num(b as f64),
                None => Json::Null,
            },
        )
        .set("gamma_std", Json::Num(self.noise.gamma_std))
        .set("crosstalk", Json::Num(self.noise.crosstalk))
        .set("phase_bias", Json::Bool(self.noise.phase_bias));
        o.set("noise", n);
        // Omitted entirely when None so baseline config dumps (which the
        // golden gate compares byte-for-byte) are unchanged.
        if let Some(rc) = &self.robustness {
            o.set("robustness", rc.to_json());
        }
        if let Some(sc) = &self.sharding {
            o.set("sharding", sc.to_json());
        }
        if let Some(vc) = &self.variation {
            o.set("variation", vc.to_json());
        }
        o
    }

    /// Parse from JSON (inverse of `to_json`; missing keys fall back to
    /// `Default`).
    pub fn from_json(j: &Json) -> Result<JobConfig, String> {
        let d = JobConfig::default();
        let arch = match j.get("arch").and_then(|v| v.as_str()) {
            Some(s) => ModelArch::parse(s).ok_or_else(|| format!("unknown arch {s}"))?,
            None => d.arch,
        };
        let dataset = match j.get("dataset").and_then(|v| v.as_str()) {
            Some(s) => DatasetKind::parse(s).ok_or_else(|| format!("unknown dataset {s}"))?,
            None => d.dataset,
        };
        let protocol = match j.get("protocol").and_then(|v| v.as_str()) {
            Some(s) => Protocol::parse(s).ok_or_else(|| format!("unknown protocol {s}"))?,
            None => d.protocol,
        };
        let num = |key: &str, dv: f64| j.get(key).and_then(|v| v.as_f64()).unwrap_or(dv);
        let noise = match j.get("noise") {
            None => d.noise,
            Some(n) => NoiseModel {
                phase_bits: n.get("phase_bits").and_then(|v| v.as_f64()).map(|b| b as u32),
                sigma_bits: n.get("sigma_bits").and_then(|v| v.as_f64()).map(|b| b as u32),
                gamma_std: n.get("gamma_std").and_then(|v| v.as_f64()).unwrap_or(0.0),
                crosstalk: n.get("crosstalk").and_then(|v| v.as_f64()).unwrap_or(0.0),
                phase_bias: n.get("phase_bias").and_then(|v| v.as_bool()).unwrap_or(false),
            },
        };
        Ok(JobConfig {
            arch,
            dataset,
            protocol,
            noise,
            k: num("k", d.k as f64) as usize,
            width: num("width", d.width as f64) as f32,
            n_train: num("n_train", d.n_train as f64) as usize,
            n_test: num("n_test", d.n_test as f64) as usize,
            pretrain_epochs: num("pretrain_epochs", d.pretrain_epochs as f64) as usize,
            epochs: num("epochs", d.epochs as f64) as usize,
            batch: num("batch", d.batch as f64) as usize,
            alpha_w: num("alpha_w", d.alpha_w as f64) as f32,
            alpha_c: num("alpha_c", d.alpha_c as f64) as f32,
            alpha_d: num("alpha_d", d.alpha_d as f64) as f32,
            zo_budget: num("zo_budget", d.zo_budget as f64) as f32,
            seed: num("seed", d.seed as f64) as u64,
            robustness: j.get("robustness").and_then(RobustnessConfig::from_json),
            sharding: j.get("sharding").and_then(ShardingConfig::from_json),
            variation: j.get("variation").and_then(VariationConfig::from_json),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_everything() {
        let cfg = JobConfig {
            arch: ModelArch::Vgg8,
            dataset: DatasetKind::Cifar10Like,
            protocol: Protocol::SwatU,
            k: 8,
            noise: NoiseModel::quant_only(6),
            width: 0.25,
            n_train: 100,
            n_test: 50,
            pretrain_epochs: 3,
            epochs: 7,
            batch: 16,
            alpha_w: 0.6,
            alpha_c: 0.5,
            alpha_d: 0.5,
            zo_budget: 0.2,
            seed: 7,
            robustness: Some(RobustnessConfig::lifecycle_row(true, true)),
            sharding: Some(ShardingConfig {
                shards: 4,
                policy: crate::photonics::ShardPolicy::Grid,
            }),
            variation: Some(VariationConfig {
                gamma_std: 0.01,
                coupler_std: 0.002,
                loss_db_std: 0.05,
                wdm_max_drift: 0.02,
                sample: 3,
            }),
        };
        let j = cfg.to_json();
        let back = JobConfig::from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(back.arch, cfg.arch);
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.protocol, cfg.protocol);
        assert_eq!(back.k, cfg.k);
        assert_eq!(back.noise, cfg.noise);
        assert_eq!(back.width, cfg.width);
        assert_eq!(back.alpha_d, cfg.alpha_d);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.robustness, cfg.robustness);
        assert_eq!(back.sharding, cfg.sharding);
        assert_eq!(back.variation, cfg.variation);
    }

    #[test]
    fn robustness_key_absent_when_disabled() {
        let cfg = JobConfig::default();
        assert!(!cfg.to_json().dump().contains("robustness"));
        let back = JobConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.robustness, None);
    }

    #[test]
    fn sharding_key_absent_when_disabled() {
        let cfg = JobConfig::default();
        assert!(!cfg.to_json().dump().contains("sharding"));
        let back = JobConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.sharding, None);
    }

    #[test]
    fn variation_key_absent_when_disabled() {
        let cfg = JobConfig::default();
        assert!(!cfg.to_json().dump().contains("variation"));
        let back = JobConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.variation, None);
    }

    #[test]
    fn missing_keys_fall_back_to_default() {
        let cfg = JobConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        let d = JobConfig::default();
        assert_eq!(cfg.k, d.k);
        assert_eq!(cfg.protocol, d.protocol);
    }

    #[test]
    fn protocol_parse_names() {
        for p in [
            Protocol::L2ight,
            Protocol::L2ightSlScratch,
            Protocol::Flops,
            Protocol::MixedTrn,
            Protocol::Rad,
            Protocol::SwatU,
        ] {
            assert_eq!(Protocol::parse(p.name()), Some(p));
        }
        assert_eq!(Protocol::parse("nope"), None);
    }
}
