//! Inference dispatch batcher: the serving-side coordination primitive.
//!
//! Photonic meshes amortize programming cost over WDM column groups, so the
//! runtime wants requests batched. `Batcher` owns a worker thread draining a
//! [`serve::admission::AdmissionQueue`](crate::serve::admission) — the same
//! deadline-aware coalescing the serving engine uses, run single-worker and
//! unbounded here (the legacy contract: callers block, nothing sheds):
//! requests accumulate until `max_batch` or `max_wait` and are executed
//! together by the user-supplied batch function; each caller gets its own
//! column back. FIFO order within the queue is preserved (a coordinator
//! invariant property-tested below).
//!
//! For a bounded, multi-replica, hot-reloading front door, use
//! [`serve::ServeEngine`](crate::serve::ServeEngine) instead.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::serve::admission::{AdmissionConfig, AdmissionQueue};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// …or when the oldest queued request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2) }
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub max_observed_batch: usize,
    /// Sum of per-request queue+execute latency, for mean computation.
    pub total_latency: Duration,
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }
}

struct BatchItem {
    input: Vec<f32>,
    resp: Sender<Vec<f32>>,
}

/// A batched-inference front door over any `Fn(batch of inputs) -> outputs`.
pub struct Batcher {
    queue: AdmissionQueue<BatchItem>,
    worker: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<BatcherStats>>,
}

impl Batcher {
    /// Start a batcher around `run_batch`: given `&[Vec<f32>]` inputs it
    /// must return one output `Vec<f32>` per input, in order.
    pub fn start<F>(cfg: BatcherConfig, run_batch: F) -> Batcher
    where
        F: FnMut(&[Vec<f32>]) -> Vec<Vec<f32>> + Send + 'static,
    {
        Self::start_with_init(cfg, move || run_batch)
    }

    /// Like [`Batcher::start`], but the batch function is *constructed on
    /// the worker thread* by `init`. Use when the executor holds non-`Send`
    /// state — e.g. a PJRT `Runtime`, whose client is thread-affine.
    pub fn start_with_init<I, F>(cfg: BatcherConfig, init: I) -> Batcher
    where
        I: FnOnce() -> F + Send + 'static,
        F: FnMut(&[Vec<f32>]) -> Vec<Vec<f32>>,
    {
        let queue: AdmissionQueue<BatchItem> = AdmissionQueue::new(AdmissionConfig {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            // Legacy contract: callers block on their response instead of
            // being shed, so admission is unbounded here.
            queue_cap: usize::MAX,
        });
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let wstats = Arc::clone(&stats);
        let wqueue = queue.clone();
        let worker = std::thread::spawn(move || {
            let mut run_batch = init();
            while let Some(batch) = wqueue.next_batch() {
                let inputs: Vec<Vec<f32>> =
                    batch.iter().map(|r| r.payload.input.clone()).collect();
                let outputs = run_batch(&inputs);
                assert_eq!(outputs.len(), batch.len(), "run_batch arity");
                let now = Instant::now();
                {
                    let mut s = wstats.lock().unwrap();
                    s.requests += batch.len() as u64;
                    s.batches += 1;
                    s.max_observed_batch = s.max_observed_batch.max(batch.len());
                    for r in &batch {
                        s.total_latency += now.duration_since(r.enqueued);
                    }
                }
                for (r, out) in batch.into_iter().zip(outputs) {
                    // Receiver may have hung up; that's the caller's choice.
                    let _ = r.payload.resp.send(out);
                }
            }
        });
        Batcher { queue, worker: Some(worker), stats }
    }

    /// Submit one request and block for its result.
    pub fn infer(&self, input: Vec<f32>) -> Vec<f32> {
        self.submit(input).recv().expect("batcher response")
    }

    /// Async-style submit: returns the response receiver immediately.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Vec<f32>> {
        let (resp_tx, resp_rx) = channel();
        let admitted = self.queue.try_submit(BatchItem { input, resp: resp_tx }).is_ok();
        assert!(admitted, "batcher running");
        resp_rx
    }

    pub fn stats(&self) -> BatcherStats {
        *self.stats.lock().unwrap()
    }

    /// Stop the worker and return final stats. Queued requests are still
    /// served before the worker exits.
    pub fn shutdown(mut self) -> BatcherStats {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        *self.stats.lock().unwrap()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_batcher(cfg: BatcherConfig) -> Batcher {
        // Identity with a batch-size marker appended.
        Batcher::start(cfg, |inputs| {
            let n = inputs.len() as f32;
            inputs.iter().map(|x| {
                let mut o = x.clone();
                o.push(n);
                o
            }).collect()
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let b = echo_batcher(BatcherConfig::default());
        let out = b.infer(vec![1.0, 2.0]);
        assert_eq!(&out[..2], &[1.0, 2.0]);
        let s = b.shutdown();
        assert_eq!(s.requests, 1);
        assert_eq!(s.batches, 1);
    }

    #[test]
    fn requests_batch_together() {
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(50) };
        let b = echo_batcher(cfg);
        // Submit 8 concurrently; they should coalesce into few batches.
        let rxs: Vec<_> = (0..8).map(|i| b.submit(vec![i as f32])).collect();
        let outs: Vec<Vec<f32>> = rxs.into_iter().map(|r| r.recv().unwrap()).collect();
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o[0], i as f32, "FIFO order broken");
        }
        let s = b.shutdown();
        assert_eq!(s.requests, 8);
        assert!(s.batches <= 2, "expected coalescing, got {} batches", s.batches);
        assert!(s.max_observed_batch >= 4);
    }

    #[test]
    fn max_batch_caps_flush_size() {
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(100) };
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = Arc::clone(&seen);
        let b = Batcher::start(cfg, move |inputs| {
            seen2.lock().unwrap().push(inputs.len());
            inputs.to_vec()
        });
        let rxs: Vec<_> = (0..7).map(|i| b.submit(vec![i as f32])).collect();
        for r in rxs {
            r.recv().unwrap();
        }
        b.shutdown();
        let sizes = seen.lock().unwrap().clone();
        assert!(sizes.iter().all(|&s| s <= 3), "batch exceeded cap: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 7);
    }

    #[test]
    fn fifo_order_is_preserved_under_load() {
        // Property: outputs arrive for each request in submission order even
        // across many flushes.
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) };
        let b = echo_batcher(cfg);
        let rxs: Vec<_> = (0..64).map(|i| b.submit(vec![i as f32])).collect();
        for (i, r) in rxs.into_iter().enumerate() {
            let o = r.recv().unwrap();
            assert_eq!(o[0], i as f32);
        }
        let s = b.shutdown();
        assert_eq!(s.requests, 64);
        assert!(s.mean_batch() >= 1.0);
    }

    #[test]
    fn shutdown_serves_already_queued_requests() {
        // Submissions that landed before shutdown still get answers.
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(200) };
        let b = echo_batcher(cfg);
        let rxs: Vec<_> = (0..6).map(|i| b.submit(vec![i as f32])).collect();
        let s = b.shutdown();
        assert_eq!(s.requests, 6);
        for (i, r) in rxs.into_iter().enumerate() {
            assert_eq!(r.recv().unwrap()[0], i as f32);
        }
    }
}
