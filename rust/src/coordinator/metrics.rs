//! Metric sink: append-only JSONL event stream plus an in-memory tail, so
//! long runs can be watched with `tail -f` and benches can post-process
//! without re-parsing stdout.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// An append-only metrics writer. `None` path = in-memory only.
pub struct MetricSink {
    file: Option<BufWriter<File>>,
    /// Most recent events (bounded ring, newest last).
    tail: Vec<Json>,
    cap: usize,
}

impl MetricSink {
    /// Sink writing to `path` (appends if it exists).
    pub fn to_file(path: &Path) -> std::io::Result<MetricSink> {
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(MetricSink { file: Some(BufWriter::new(f)), tail: Vec::new(), cap: 1024 })
    }

    /// In-memory sink (tests, benches).
    pub fn memory() -> MetricSink {
        MetricSink { file: None, tail: Vec::new(), cap: 4096 }
    }

    /// Emit one event. `fields` are (key, value) pairs; an `event` tag and
    /// a monotonic sequence number are added automatically.
    pub fn emit(&mut self, event: &str, fields: &[(&str, Json)]) {
        let mut o = Json::obj();
        o.set("event", Json::Str(event.into()));
        o.set("seq", Json::Num(self.tail.len() as f64));
        for (k, v) in fields {
            o.set(k, v.clone());
        }
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{}", o.dump());
            let _ = f.flush();
        }
        if self.tail.len() == self.cap {
            self.tail.remove(0);
        }
        self.tail.push(o);
    }

    /// Shorthand for numeric fields.
    pub fn emit_nums(&mut self, event: &str, fields: &[(&str, f64)]) {
        let owned: Vec<(&str, Json)> =
            fields.iter().map(|(k, v)| (*k, Json::Num(*v))).collect();
        self.emit(event, &owned);
    }

    /// In-memory tail of events (newest last).
    pub fn tail(&self) -> &[Json] {
        &self.tail
    }

    /// Last event with the given tag.
    pub fn last(&self, event: &str) -> Option<&Json> {
        self.tail
            .iter()
            .rev()
            .find(|e| e.get("event").and_then(|v| v.as_str()) == Some(event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_sink_records_events() {
        let mut s = MetricSink::memory();
        s.emit_nums("epoch", &[("loss", 1.5), ("acc", 0.5)]);
        s.emit_nums("epoch", &[("loss", 1.0), ("acc", 0.7)]);
        s.emit("done", &[("ok", Json::Bool(true))]);
        assert_eq!(s.tail().len(), 3);
        let last_epoch = s.last("epoch").unwrap();
        assert_eq!(last_epoch.get("acc").unwrap().as_f64(), Some(0.7));
        assert!(s.last("nope").is_none());
    }

    #[test]
    fn file_sink_appends_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("l2ight_metrics_{}.jsonl", std::process::id()));
        std::fs::remove_file(&path).ok();
        {
            let mut s = MetricSink::to_file(&path).unwrap();
            s.emit_nums("a", &[("x", 1.0)]);
            s.emit_nums("b", &[("y", 2.0)]);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let parsed = Json::parse(lines[1]).unwrap();
        assert_eq!(parsed.get("event").unwrap().as_str(), Some("b"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn tail_is_bounded() {
        let mut s = MetricSink::memory();
        s.cap = 4;
        for i in 0..10 {
            s.emit_nums("e", &[("i", i as f64)]);
        }
        assert_eq!(s.tail().len(), 4);
        assert_eq!(s.tail()[3].get("i").unwrap().as_f64(), Some(9.0));
    }
}
