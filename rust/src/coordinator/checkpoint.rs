//! Chip-state checkpoints: serialize every programmed degree of freedom of
//! a model — MZI phases, Σ values and scales, dense weights, biases, BN
//! affine + running stats — and restore them bit-exactly.
//!
//! Format: a compact binary container (magic + versioned sections of
//! little-endian f32/f64 runs). Binary rather than JSON because a VGG-8
//! mesh holds ~10⁶ phases and float round-trip via decimal text is both
//! slow and lossy.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Result as IoResult, Write};
use std::path::Path;

use crate::nn::{Layer, Model, ProjEngine};
use crate::photonics::ptc::Which;

const MAGIC: &[u8; 8] = b"L2IGHTv1";

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> IoResult<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> IoResult<Vec<f32>> {
    let mut len8 = [0u8; 8];
    r.read_exact(&mut len8)?;
    let n = u64::from_le_bytes(len8) as usize;
    let mut out = vec![0f32; n];
    let mut buf = [0u8; 4];
    for o in &mut out {
        r.read_exact(&mut buf)?;
        *o = f32::from_le_bytes(buf);
    }
    Ok(out)
}

fn write_f64s(w: &mut impl Write, xs: &[f64]) -> IoResult<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f64s(r: &mut impl Read) -> IoResult<Vec<f64>> {
    let mut len8 = [0u8; 8];
    r.read_exact(&mut len8)?;
    let n = u64::from_le_bytes(len8) as usize;
    let mut out = vec![0f64; n];
    let mut buf = [0u8; 8];
    for o in &mut out {
        r.read_exact(&mut buf)?;
        *o = f64::from_le_bytes(buf);
    }
    Ok(out)
}

/// Collect the full mutable state of a model in traversal order.
fn collect_state(model: &mut Model) -> (Vec<Vec<f64>>, Vec<Vec<f32>>) {
    let mut phases: Vec<Vec<f64>> = Vec::new();
    let mut floats: Vec<Vec<f32>> = Vec::new();
    model.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            match e {
                ProjEngine::Digital { w, .. } => floats.push(w.data.clone()),
                ProjEngine::Photonic { mesh, .. } => {
                    for ptc in &mesh.ptcs {
                        phases.push(ptc.u_mesh.phases.clone());
                        phases.push(ptc.v_mesh.phases.clone());
                        // The Reck D sign diagonals (Eq. 8) are programmed
                        // state too — extra output-side π shifters.
                        phases.push(ptc.u_mesh.d.iter().map(|&v| v as f64).collect());
                        phases.push(ptc.v_mesh.d.iter().map(|&v| v as f64).collect());
                        floats.push(ptc.sigma.clone());
                        floats.push(vec![ptc.sigma_scale]);
                    }
                }
                ProjEngine::PhotonicSharded { mesh, .. } => {
                    // Logical block order — byte-identical to the unsharded
                    // engine's serialization, so checkpoints are
                    // interchangeable across shard counts.
                    mesh.for_each_ptc_logical(|ptc| {
                        phases.push(ptc.u_mesh.phases.clone());
                        phases.push(ptc.v_mesh.phases.clone());
                        phases.push(ptc.u_mesh.d.iter().map(|&v| v as f64).collect());
                        phases.push(ptc.v_mesh.d.iter().map(|&v| v as f64).collect());
                        floats.push(ptc.sigma.clone());
                        floats.push(vec![ptc.sigma_scale]);
                    });
                }
            }
        }
        match l {
            Layer::Linear(lin) => floats.push(lin.bias.clone()),
            Layer::Conv2d(c) => floats.push(c.bias.clone()),
            Layer::BatchNorm(bn) => {
                floats.push(bn.gamma.clone());
                floats.push(bn.beta.clone());
                floats.push(bn.running_mean.clone());
                floats.push(bn.running_var.clone());
            }
            _ => {}
        }
    });
    (phases, floats)
}

/// Save the complete chip + electronic state of `model` to `path`.
///
/// Crash-safe: the bytes are written to a temporary sibling file
/// (`<path>.tmp-<pid>`), fsynced, and atomically renamed over `path`, so a
/// crash mid-save can never leave a truncated checkpoint under the final
/// name — readers see either the old complete file or the new one.
pub fn save_model_state(model: &mut Model, path: &Path) -> IoResult<()> {
    let (phases, floats) = collect_state(model);
    let tmp = path.with_extension(format!("tmp-{}", std::process::id()));
    let result = (|| -> IoResult<()> {
        let file = File::create(&tmp)?;
        let mut w = BufWriter::new(file);
        w.write_all(MAGIC)?;
        w.write_all(&(phases.len() as u64).to_le_bytes())?;
        for p in &phases {
            write_f64s(&mut w, p)?;
        }
        w.write_all(&(floats.len() as u64).to_le_bytes())?;
        for f in &floats {
            write_f32s(&mut w, f)?;
        }
        w.flush()?;
        // Durability before visibility: the rename must not land before
        // the payload does.
        w.get_ref().sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    result
}

/// Map the `UnexpectedEof` a short read produces into an `InvalidData`
/// error that names the actual problem: a truncated/corrupt checkpoint.
fn truncation(e: std::io::Error) -> std::io::Error {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "checkpoint truncated or corrupt (unexpected end of file)",
        )
    } else {
        e
    }
}

/// Restore state saved by [`save_model_state`] into a model of identical
/// topology. Errors if section counts or lengths disagree, or if the file
/// ends early (truncation is reported as `InvalidData`, not a raw EOF).
pub fn load_model_state(model: &mut Model, path: &Path) -> IoResult<()> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(truncation)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "not an L2ight checkpoint",
        ));
    }
    let mut cnt = [0u8; 8];
    r.read_exact(&mut cnt).map_err(truncation)?;
    let n_phases = u64::from_le_bytes(cnt) as usize;
    let mut phases = Vec::with_capacity(n_phases);
    for _ in 0..n_phases {
        phases.push(read_f64s(&mut r).map_err(truncation)?);
    }
    r.read_exact(&mut cnt).map_err(truncation)?;
    let n_floats = u64::from_le_bytes(cnt) as usize;
    let mut floats = Vec::with_capacity(n_floats);
    for _ in 0..n_floats {
        floats.push(read_f32s(&mut r).map_err(truncation)?);
    }

    // Walk the model in the same order, consuming sections.
    let mut pi = 0usize;
    let mut fi = 0usize;
    let mut err: Option<String> = None;
    model.for_each_layer(|l| {
        if err.is_some() {
            return;
        }
        let mut take_f32 = |expect: usize, what: &str| -> Option<Vec<f32>> {
            let v = floats.get(fi).cloned();
            fi += 1;
            match v {
                Some(v) if v.len() == expect => Some(v),
                Some(v) => {
                    err = Some(format!("{what}: expected {expect} values, got {}", v.len()));
                    None
                }
                None => {
                    err = Some(format!("{what}: checkpoint too short"));
                    None
                }
            }
        };
        if let Some(e) = l.engine_mut() {
            match e {
                ProjEngine::Digital { w, .. } => {
                    if let Some(v) = take_f32(w.data.len(), "dense weight") {
                        w.data.copy_from_slice(&v);
                    }
                }
                ProjEngine::Photonic { mesh, .. } => {
                    for ptc in &mut mesh.ptcs {
                        let (u, v) = (phases.get(pi).cloned(), phases.get(pi + 1).cloned());
                        let (du, dv) = (phases.get(pi + 2).cloned(), phases.get(pi + 3).cloned());
                        pi += 4;
                        match (u, v, du, dv) {
                            (Some(u), Some(v), Some(du), Some(dv))
                                if u.len() == ptc.u_mesh.phases.len()
                                    && v.len() == ptc.v_mesh.phases.len()
                                    && du.len() == ptc.u_mesh.d.len()
                                    && dv.len() == ptc.v_mesh.d.len() =>
                            {
                                ptc.set_phases(Which::U, &u);
                                ptc.set_phases(Which::V, &v);
                                for (dst, &sv) in ptc.u_mesh.d.iter_mut().zip(&du) {
                                    *dst = sv as f32;
                                }
                                for (dst, &sv) in ptc.v_mesh.d.iter_mut().zip(&dv) {
                                    *dst = sv as f32;
                                }
                            }
                            _ => {
                                err = Some("phase section mismatch".into());
                                return;
                            }
                        }
                        if let Some(s) = take_f32(ptc.sigma.len(), "sigma") {
                            ptc.sigma.copy_from_slice(&s);
                        }
                        if let Some(sc) = take_f32(1, "sigma scale") {
                            ptc.set_sigma_scale(sc[0]);
                        }
                    }
                    mesh.invalidate();
                }
                ProjEngine::PhotonicSharded { mesh, .. } => {
                    // Consume the same logical-order sections the unsharded
                    // arm writes; only the owning shard's cache is touched
                    // per block, and everything is invalidated at the end.
                    let nb = mesh.p * mesh.q;
                    for bi in 0..nb {
                        if err.is_some() {
                            break;
                        }
                        let ptc = mesh.ptc_logical_mut(bi);
                        let (u, v) = (phases.get(pi).cloned(), phases.get(pi + 1).cloned());
                        let (du, dv) = (phases.get(pi + 2).cloned(), phases.get(pi + 3).cloned());
                        pi += 4;
                        match (u, v, du, dv) {
                            (Some(u), Some(v), Some(du), Some(dv))
                                if u.len() == ptc.u_mesh.phases.len()
                                    && v.len() == ptc.v_mesh.phases.len()
                                    && du.len() == ptc.u_mesh.d.len()
                                    && dv.len() == ptc.v_mesh.d.len() =>
                            {
                                ptc.set_phases(Which::U, &u);
                                ptc.set_phases(Which::V, &v);
                                for (dst, &sv) in ptc.u_mesh.d.iter_mut().zip(&du) {
                                    *dst = sv as f32;
                                }
                                for (dst, &sv) in ptc.v_mesh.d.iter_mut().zip(&dv) {
                                    *dst = sv as f32;
                                }
                            }
                            _ => {
                                err = Some("phase section mismatch".into());
                                return;
                            }
                        }
                        if let Some(s) = take_f32(ptc.sigma.len(), "sigma") {
                            ptc.sigma.copy_from_slice(&s);
                        }
                        if let Some(sc) = take_f32(1, "sigma scale") {
                            ptc.set_sigma_scale(sc[0]);
                        }
                    }
                    mesh.invalidate();
                }
            }
        }
        match l {
            Layer::Linear(lin) => {
                if let Some(v) = take_f32(lin.bias.len(), "linear bias") {
                    lin.bias.copy_from_slice(&v);
                }
            }
            Layer::Conv2d(c) => {
                if let Some(v) = take_f32(c.bias.len(), "conv bias") {
                    c.bias.copy_from_slice(&v);
                }
            }
            Layer::BatchNorm(bn) => {
                for (dst, what) in [
                    (&mut bn.gamma, "bn gamma"),
                    (&mut bn.beta, "bn beta"),
                    (&mut bn.running_mean, "bn mean"),
                    (&mut bn.running_var, "bn var"),
                ] {
                    if let Some(v) = take_f32(dst.len(), what) {
                        dst.copy_from_slice(&v);
                    }
                }
            }
            _ => {}
        }
    });
    if let Some(e) = err {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e));
    }
    if pi != phases.len() || fi != floats.len() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("checkpoint/model mismatch: used {pi}/{} phase and {fi}/{} float sections",
                phases.len(), floats.len()),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{build_model, Act, EngineKind, ModelArch};
    use crate::photonics::NoiseModel;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("l2ight_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn photonic_roundtrip_is_bit_exact() {
        let mut rng = Rng::new(51);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER };
        let mut m1 = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let path = tmp("photonic");
        save_model_state(&mut m1, &path).unwrap();
        // Fresh model with different device instances + params.
        let mut rng2 = Rng::new(99);
        let mut m2 = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng2);
        load_model_state(&mut m2, &path).unwrap();
        // Programmed state must match exactly…
        let mut phases1 = Vec::new();
        m1.for_each_layer(|l| {
            if let Some(ProjEngine::Photonic { mesh, .. }) = l.engine_mut() {
                for ptc in &mesh.ptcs {
                    phases1.push((ptc.u_mesh.phases.clone(), ptc.sigma.clone()));
                }
            }
        });
        let mut i = 0;
        m2.for_each_layer(|l| {
            if let Some(ProjEngine::Photonic { mesh, .. }) = l.engine_mut() {
                for ptc in &mesh.ptcs {
                    assert_eq!(ptc.u_mesh.phases, phases1[i].0);
                    assert_eq!(ptc.sigma, phases1[i].1);
                    i += 1;
                }
            }
        });
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn digital_roundtrip_preserves_forward() {
        let mut rng = Rng::new(52);
        let mut m1 = build_model(ModelArch::CnnS, EngineKind::Digital, 10, 0.5, &mut rng);
        let path = tmp("digital");
        save_model_state(&mut m1, &path).unwrap();
        let mut rng2 = Rng::new(77);
        let mut m2 = build_model(ModelArch::CnnS, EngineKind::Digital, 10, 0.5, &mut rng2);
        load_model_state(&mut m2, &path).unwrap();
        let x = Act::from_nchw(&vec![0.3f32; 2 * 28 * 28], 2, 1, 28, 28);
        let y1 = m1.forward(&x, false);
        let y2 = m2.forward(&x, false);
        assert_eq!(y1.mat.data, y2.mat.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn topology_mismatch_is_rejected() {
        let mut rng = Rng::new(53);
        let mut m1 = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut rng);
        let path = tmp("mismatch");
        save_model_state(&mut m1, &path).unwrap();
        let mut m2 = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 1.0, &mut rng);
        assert!(load_model_state(&mut m2, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_checkpoint_is_rejected_with_clear_error() {
        let mut rng = Rng::new(56);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) };
        let mut m = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let path = tmp("truncated");
        save_model_state(&mut m, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Cut the file at several depths: inside the header, inside a phase
        // section, and just shy of the end. Every cut must fail loudly as
        // InvalidData (never a bare EOF panic or a silent partial restore).
        for cut in [4, 12, full.len() / 2, full.len() - 3] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = load_model_state(&mut m, &path)
                .expect_err(&format!("cut at {cut} bytes was accepted"));
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "cut at {cut}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_files() {
        let mut rng = Rng::new(57);
        let mut m = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut rng);
        let dir = std::env::temp_dir().join(format!("l2ight_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        // Pre-existing (old) checkpoint gets replaced wholesale.
        std::fs::write(&path, b"stale").unwrap();
        save_model_state(&mut m, &path).unwrap();
        let mut m2 = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut rng);
        load_model_state(&mut m2, &path).unwrap();
        // No temp droppings next to the final file.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != "state.ckpt")
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_is_rejected() {
        let path = tmp("magic");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        let mut rng = Rng::new(54);
        let mut m = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut rng);
        assert!(load_model_state(&mut m, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checkpoint_restores_behaviour_of_mapped_mesh() {
        // Save after programming a specific matrix; restore into a fresh
        // mesh model and verify the realized weight matches.
        let mut rng = Rng::new(55);
        let kind = EngineKind::Photonic { k: 3, noise: NoiseModel::IDEAL };
        let mut m1 = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let x = Act::from_features(Mat::randn(8, 4, 1.0, &mut rng), 4);
        let y1 = m1.forward(&x, false);
        let path = tmp("behaviour");
        save_model_state(&mut m1, &path).unwrap();
        let mut m2 = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut Rng::new(1234));
        load_model_state(&mut m2, &path).unwrap();
        let y2 = m2.forward(&x, false);
        crate::util::prop::assert_close(&y1.mat.data, &y2.mat.data, 1e-6, 1e-6).unwrap();
        std::fs::remove_file(path).ok();
    }
}
