//! Appendix-G hardware cost model.
//!
//! Two complementary views, cross-checked in tests:
//!
//! * **Measured** — `MeshStats` counters accumulated by the simulator as
//!   ops actually execute (`CostBreakdown::from_stats`), the numbers the
//!   Table 2 / Fig. 11 benches report.
//! * **Analytic** — the closed-form per-iteration estimates of Eq. 14/15
//!   given layer shapes and sampling sparsities (`LayerCost::conv2d` /
//!   `::linear`), used for scalability projections (Fig. 10) where actually
//!   simulating a 10M-parameter ONN per point would be wasteful.
//!
//! Units follow the paper: *energy* = number of PTC calls (a PTC call is one
//! k×k block times one k-column group), *steps* = the longest sequential
//! partial-product accumulation path with k adders per PTC and fully
//! parallel PTCs.

use crate::photonics::mesh::MeshStats;
use crate::util::bench::Table;
use crate::util::fmt_sig;

/// Per-pass energy/step breakdown (the paper's ℒ, ∇_Σℒ, ∇_xℒ columns).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// Forward-pass PTC calls (ℒ).
    pub fwd_energy: f64,
    /// Weight-gradient PTC calls (∇_Σℒ).
    pub wgrad_energy: f64,
    /// Error-feedback PTC calls (∇_xℒ).
    pub fbk_energy: f64,
    pub fwd_steps: f64,
    pub wgrad_steps: f64,
    pub fbk_steps: f64,
}

impl CostBreakdown {
    /// From measured simulator counters.
    pub fn from_stats(s: &MeshStats) -> CostBreakdown {
        CostBreakdown {
            fwd_energy: s.fwd_block_cols as f64,
            wgrad_energy: s.grad_block_cols as f64,
            fbk_energy: s.feedback_block_cols as f64,
            fwd_steps: s.fwd_steps as f64,
            wgrad_steps: s.grad_steps as f64,
            fbk_steps: s.feedback_steps as f64,
        }
    }

    pub fn total_energy(&self) -> f64 {
        self.fwd_energy + self.wgrad_energy + self.fbk_energy
    }

    pub fn total_steps(&self) -> f64 {
        self.fwd_steps + self.wgrad_steps + self.fbk_steps
    }

    pub fn add(&mut self, o: &CostBreakdown) {
        self.fwd_energy += o.fwd_energy;
        self.wgrad_energy += o.wgrad_energy;
        self.fbk_energy += o.fbk_energy;
        self.fwd_steps += o.fwd_steps;
        self.wgrad_steps += o.wgrad_steps;
        self.fbk_steps += o.fbk_steps;
    }

    pub fn scale(&self, s: f64) -> CostBreakdown {
        CostBreakdown {
            fwd_energy: self.fwd_energy * s,
            wgrad_energy: self.wgrad_energy * s,
            fbk_energy: self.fbk_energy * s,
            fwd_steps: self.fwd_steps * s,
            wgrad_steps: self.wgrad_steps * s,
            fbk_steps: self.fbk_steps * s,
        }
    }

    /// Energy-efficiency ratio of `self` relative to a baseline (Table 2's
    /// "Total (Ratio)" column is baseline/self).
    pub fn energy_ratio_vs(&self, baseline: &CostBreakdown) -> f64 {
        baseline.total_energy() / self.total_energy().max(1e-12)
    }

    pub fn steps_ratio_vs(&self, baseline: &CostBreakdown) -> f64 {
        baseline.total_steps() / self.total_steps().max(1e-12)
    }

    /// A Table-2-style row: [ℒ, ∇_Σℒ, ∇_xℒ, total (ratio)] for energy then
    /// steps. `unit` rescales raw counts into table units (e.g. 1e9).
    pub fn table_cells(&self, baseline: &CostBreakdown, unit: f64) -> Vec<String> {
        vec![
            fmt_sig(self.fwd_energy / unit, 3),
            fmt_sig(self.wgrad_energy / unit, 3),
            fmt_sig(self.fbk_energy / unit, 3),
            format!(
                "{} ({})",
                fmt_sig(self.total_energy() / unit, 3),
                fmt_sig(self.energy_ratio_vs(baseline), 3)
            ),
            fmt_sig(self.fwd_steps / unit, 3),
            fmt_sig(self.wgrad_steps / unit, 3),
            fmt_sig(self.fbk_steps / unit, 3),
            format!(
                "{} ({})",
                fmt_sig(self.total_steps() / unit, 3),
                fmt_sig(self.steps_ratio_vs(baseline), 3)
            ),
        ]
    }

    /// Header matching `table_cells`.
    pub fn table_header(label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            "E:L".into(),
            "E:gradS".into(),
            "E:gradX".into(),
            "E:total(ratio)".into(),
            "S:L".into(),
            "S:gradS".into(),
            "S:gradX".into(),
            "S:total(ratio)".into(),
        ]
    }
}

/// Sampling sparsities entering the analytic model (keep fractions).
#[derive(Clone, Copy, Debug)]
pub struct SparsityConfig {
    /// Feedback keep fraction α_W (1 = dense feedback).
    pub alpha_w: f64,
    /// Column keep fraction α_C (1 = all columns).
    pub alpha_c: f64,
    /// Fraction of iterations actually executed (1 − SMD skip probability).
    pub alpha_d: f64,
}

impl SparsityConfig {
    pub const DENSE: SparsityConfig = SparsityConfig { alpha_w: 1.0, alpha_c: 1.0, alpha_d: 1.0 };
}

/// Analytic per-iteration cost of one projection layer (Appendix G.1/G.2).
#[derive(Clone, Copy, Debug)]
pub struct LayerCost {
    /// Block-grid rows P = ceil(out/k).
    pub p: usize,
    /// Block-grid cols Q = ceil(in/k).
    pub q: usize,
    pub k: usize,
    /// Output columns per sample after im2col (H'·W'; 1 for linear).
    pub out_cols: usize,
    /// Input spatial size (H·W; 1 for linear) — enters the feedback cost.
    pub in_cols: usize,
}

impl LayerCost {
    /// Conv layer with `cout`×`cin`×`kk`×`kk` kernel over `h`×`w` inputs
    /// (stride `s`, padding `pad`), blocked into k×k PTCs.
    pub fn conv2d(
        cout: usize,
        cin: usize,
        kk: usize,
        h: usize,
        w: usize,
        s: usize,
        pad: usize,
        k: usize,
    ) -> LayerCost {
        let oh = (h + 2 * pad - kk) / s + 1;
        let ow = (w + 2 * pad - kk) / s + 1;
        LayerCost {
            p: cout.div_ceil(k),
            q: (cin * kk * kk).div_ceil(k),
            k,
            out_cols: oh * ow,
            in_cols: h * w,
        }
    }

    /// Fully-connected layer.
    pub fn linear(out: usize, inp: usize, k: usize) -> LayerCost {
        LayerCost { p: out.div_ceil(k), q: inp.div_ceil(k), k, out_cols: 1, in_cols: 1 }
    }

    /// Dense-equivalent parameter count of the layer.
    pub fn params(&self) -> usize {
        self.p * self.q * self.k * self.k
    }

    /// Number of MZI phases (U and V* meshes) realizing the layer.
    pub fn phases(&self) -> usize {
        self.p * self.q * self.k * (self.k - 1)
    }

    /// Per-iteration cost with batch `b` under `sp` (Eq. 14 energies; G.2
    /// steps). Matches what the simulator counts for the same shapes — see
    /// `analytic_matches_measured_dense_linear`.
    pub fn per_iteration(&self, b: usize, sp: SparsityConfig) -> CostBreakdown {
        let (p, q, k) = (self.p as f64, self.q as f64, self.k as f64);
        // Column groups: the batch·spatial columns stream through in groups
        // of k WDM channels.
        let fwd_groups = ((b * self.out_cols) as f64 / k).ceil().max(1.0);
        let kept_cols = (sp.alpha_c * (b * self.out_cols) as f64).round().max(1.0);
        let grad_groups = (kept_cols / k).ceil().max(1.0);
        let kept_fb_rows = (sp.alpha_w * p).round().max(1.0);
        CostBreakdown {
            // Forward: all P·Q blocks × column groups.
            fwd_energy: p * q * fwd_groups,
            // σ-grad: 2 reciprocal calls per block per kept column group.
            wgrad_energy: 2.0 * p * q * grad_groups,
            // Feedback: kept blocks per feedback row × column groups.
            fbk_energy: kept_fb_rows * q * fwd_groups,
            // Steps: PTCs are parallel; only accumulation depth serializes.
            fwd_steps: fwd_groups * (1.0 + q),
            wgrad_steps: 2.0 * grad_groups + 1.0,
            fbk_steps: fwd_groups * (1.0 + kept_fb_rows),
        }
        .scale(sp.alpha_d)
    }
}

/// Analytic whole-model training-cost estimate: layer costs × iterations.
pub fn training_cost(
    layers: &[LayerCost],
    batch: usize,
    iters_per_epoch: usize,
    epochs: usize,
    sp: SparsityConfig,
) -> CostBreakdown {
    let mut acc = CostBreakdown::default();
    for l in layers {
        acc.add(&l.per_iteration(batch, sp));
    }
    acc.scale((iters_per_epoch * epochs) as f64)
}

/// Forward-only inference cost (used for pricing ZO-protocol queries: one
/// ZO query = one forward pass).
pub fn forward_cost(layers: &[LayerCost], batch: usize) -> CostBreakdown {
    let mut acc = CostBreakdown::default();
    for l in layers {
        let c = l.per_iteration(batch, SparsityConfig::DENSE);
        acc.add(&CostBreakdown {
            fwd_energy: c.fwd_energy,
            fwd_steps: c.fwd_steps,
            ..Default::default()
        });
    }
    acc
}

/// Pretty-print labelled breakdowns as a Table-2-style table (first row is
/// the ratio baseline).
pub fn print_cost_table(title: &str, rows: &[(String, CostBreakdown)], unit: f64) {
    if rows.is_empty() {
        return;
    }
    let baseline = rows[0].1;
    let header = CostBreakdown::table_header("config");
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for (label, c) in rows {
        let mut cells = vec![label.clone()];
        cells.extend(c.table_cells(&baseline, unit));
        t.row(&cells);
    }
    t.print(title);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::photonics::{NoiseModel, PtcMesh};
    use crate::util::Rng;

    #[test]
    fn breakdown_totals_and_ratio() {
        let a = CostBreakdown {
            fwd_energy: 2.0,
            wgrad_energy: 3.0,
            fbk_energy: 5.0,
            fwd_steps: 1.0,
            wgrad_steps: 1.0,
            fbk_steps: 2.0,
        };
        assert_eq!(a.total_energy(), 10.0);
        assert_eq!(a.total_steps(), 4.0);
        let half = a.scale(0.5);
        assert_eq!(half.total_energy(), 5.0);
        assert!((half.energy_ratio_vs(&a) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn analytic_matches_measured_dense_linear() {
        // A dense k-blocked linear layer: analytic Eq.14 must equal the
        // simulator's measured counters for fwd + σ-grad + dense feedback.
        let (out, inp, k, b) = (8, 12, 4, 6);
        let mut rng = Rng::new(9);
        let mut mesh = PtcMesh::new(out, inp, k, NoiseModel::IDEAL, &mut rng);
        let x = Mat::randn(inp, b, 1.0, &mut rng);
        let dy = Mat::randn(out, b, 1.0, &mut rng);
        let y = mesh.forward(&x);
        assert_eq!(y.rows, out);
        let _ = mesh.sigma_grad(&x, &dy, None, 1.0);
        let _ = mesh.feedback(&dy, None, 1.0);
        let measured = CostBreakdown::from_stats(&mesh.stats);

        let analytic =
            LayerCost::linear(out, inp, k).per_iteration(b, SparsityConfig::DENSE);
        assert_eq!(measured.fwd_energy, analytic.fwd_energy, "fwd energy");
        assert_eq!(measured.wgrad_energy, analytic.wgrad_energy, "wgrad energy");
        assert_eq!(measured.fbk_energy, analytic.fbk_energy, "fbk energy");
        assert_eq!(measured.fwd_steps, analytic.fwd_steps, "fwd steps");
        assert_eq!(measured.wgrad_steps, analytic.wgrad_steps, "wgrad steps");
        assert_eq!(measured.fbk_steps, analytic.fbk_steps, "fbk steps");
    }

    #[test]
    fn feedback_sparsity_scales_feedback_energy_only() {
        let l = LayerCost::linear(18, 18, 9);
        let dense = l.per_iteration(9, SparsityConfig::DENSE);
        let half = l.per_iteration(9, SparsityConfig { alpha_w: 0.5, alpha_c: 1.0, alpha_d: 1.0 });
        assert_eq!(dense.fwd_energy, half.fwd_energy);
        assert_eq!(dense.wgrad_energy, half.wgrad_energy);
        assert!(half.fbk_energy < dense.fbk_energy);
        assert!(half.fbk_steps < dense.fbk_steps);
    }

    #[test]
    fn column_sparsity_scales_wgrad_only() {
        let l = LayerCost::conv2d(16, 16, 3, 8, 8, 1, 1, 8);
        let dense = l.per_iteration(4, SparsityConfig::DENSE);
        let cs = l.per_iteration(4, SparsityConfig { alpha_w: 1.0, alpha_c: 0.5, alpha_d: 1.0 });
        assert_eq!(dense.fwd_energy, cs.fwd_energy);
        assert!(cs.wgrad_energy < dense.wgrad_energy);
        assert_eq!(dense.fbk_energy, cs.fbk_energy);
    }

    #[test]
    fn data_sparsity_scales_everything() {
        let l = LayerCost::linear(32, 32, 8);
        let dense = l.per_iteration(8, SparsityConfig::DENSE);
        let ds = l.per_iteration(8, SparsityConfig { alpha_w: 1.0, alpha_c: 1.0, alpha_d: 0.5 });
        assert!((ds.total_energy() - dense.total_energy() * 0.5).abs() < 1e-9);
        assert!((ds.total_steps() - dense.total_steps() * 0.5).abs() < 1e-9);
    }

    #[test]
    fn conv_shapes() {
        // CONV64K3S1P1 over 32×32 with k=9: P = ceil(64/9)=8, Q = ceil(576/9)=64.
        let l = LayerCost::conv2d(64, 64, 3, 32, 32, 1, 1, 9);
        assert_eq!(l.p, 8);
        assert_eq!(l.q, 64);
        assert_eq!(l.out_cols, 32 * 32);
        assert_eq!(l.params(), 8 * 64 * 81);
    }

    #[test]
    fn forward_cost_is_fwd_only() {
        let layers = [LayerCost::linear(16, 16, 8), LayerCost::linear(16, 8, 8)];
        let c = forward_cost(&layers, 4);
        assert!(c.fwd_energy > 0.0);
        assert_eq!(c.wgrad_energy, 0.0);
        assert_eq!(c.fbk_energy, 0.0);
    }
}
