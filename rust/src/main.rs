//! `l2ight` — leader entrypoint / CLI for the on-chip-learning coordinator.
//!
//! Subcommands:
//!   run          run an experiment from flags or a JSON config
//!   yield        Monte-Carlo process-variation yield estimation
//!   matrix       run the scenario matrix and gate against golden metrics
//!   matrix-diff  compare two scenario-matrix reports
//!   calibrate    identity-calibrate a mesh and report MSE
//!   map          parallel-map a random target matrix and report fidelity
//!   infer        batched-inference smoke over the PJRT artifacts
//!   serve-bench  open-loop load against the native batched serving engine
//!   tune         autotune GEMM blocking + conv panel width for this host
//!   artifacts    list the AOT artifacts the runtime can see
//!   info         print build + environment info

use std::path::{Path, PathBuf};

use l2ight::coordinator::{run_job, JobConfig, MetricSink, Protocol};
use l2ight::data::DatasetKind;
use l2ight::linalg::{simd::SimdLevel, tune, Mat};
use l2ight::nn::{EngineKind, ModelArch};
use l2ight::photonics::{NoiseModel, PtcMesh, ShardPolicy, ShardingConfig};
use l2ight::robustness::{
    estimate_yield, DriftConfig, FaultSpec, RobustnessConfig, VariationConfig, WatchdogConfig,
    YieldConstraints,
};
use l2ight::runtime::{default_artifact_dir, Runtime};
use l2ight::scenarios::{
    diff_reports, expand, golden, report_json, run_matrix, write_report, GoldenOutcome,
    MatrixSpec, Tier, Tolerances,
};
use l2ight::serve::bench::{
    append_history, bench_run_json, print_summary, run_serve_bench, ServeBenchConfig,
};
use l2ight::stages::ic::{calibrate_mesh, IcConfig};
use l2ight::stages::pm::{map_mesh, PmConfig};
use l2ight::util::bench::{git_rev, unix_time};
use l2ight::util::cli::ArgSpec;
use l2ight::util::json::Json;
use l2ight::util::{fmt_sig, Rng};
use l2ight::zoo::ZoKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("yield") => cmd_yield(&args[1..]),
        Some("matrix") => cmd_matrix(&args[1..]),
        Some("matrix-diff") => cmd_matrix_diff(&args[1..]),
        Some("calibrate") => cmd_calibrate(&args[1..]),
        Some("map") => cmd_map(&args[1..]),
        Some("infer") => cmd_infer(&args[1..]),
        Some("serve-bench") => cmd_serve_bench(&args[1..]),
        Some("tune") => cmd_tune(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("info") => cmd_info(),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "l2ight — scalable ONN on-chip learning (NeurIPS 2021 reproduction)\n\n\
         USAGE:\n  l2ight <SUBCOMMAND> [OPTIONS]\n\n\
         SUBCOMMANDS:\n\
         \x20 run          run a training protocol (l2ight / l2ight-sl / flops / mixedtrn / rad / swat-u)\n\
         \x20 yield        Monte-Carlo process-variation yield estimation\n\
         \x20 matrix       run the scenario matrix + golden regression gate\n\
         \x20 matrix-diff  compare two scenario-matrix reports\n\
         \x20 calibrate    identity-calibrate a PTC mesh (stage 1)\n\
         \x20 map          parallel-map a target matrix (stage 2)\n\
         \x20 infer        batched inference through the PJRT artifacts\n\
         \x20 serve-bench  open-loop load against the native batched serving engine\n\
         \x20 tune         autotune GEMM blocking + conv panel width for this host\n\
         \x20 artifacts    list AOT artifacts\n\
         \x20 info         build + environment info\n\n\
         Run `l2ight <SUBCOMMAND> --help` for options."
    );
}

fn parse_or_exit(spec: &ArgSpec, args: &[String]) -> l2ight::util::cli::Args {
    match spec.parse(args) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn noise_by_name(name: &str) -> NoiseModel {
    match name {
        "ideal" => NoiseModel::IDEAL,
        "paper" => NoiseModel::PAPER,
        "quant" => NoiseModel::quant_only(8),
        "bias" => NoiseModel::bias_only(),
        other => {
            eprintln!("unknown noise model {other:?} (ideal|paper|quant|bias)");
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &[String]) -> i32 {
    let spec = ArgSpec::new("l2ight run", "run a training protocol end to end")
        .opt("config", "", "JSON config file (flags below override it)")
        .opt("protocol", "l2ight", "l2ight|l2ight-sl|flops|mixedtrn|rad|swat-u")
        .opt("arch", "mlp", "mlp|cnn-s|cnn-l|vgg8|resnet18")
        .opt("dataset", "vowel", "vowel|mnist|fashion|cifar10|cifar100|tiny")
        .opt("k", "9", "photonic block size")
        .opt("noise", "paper", "ideal|paper|quant|bias")
        .opt("width", "1.0", "channel width multiplier")
        .opt("n-train", "512", "synthetic train-set size")
        .opt("n-test", "256", "synthetic test-set size")
        .opt("pretrain-epochs", "10", "digital pretraining epochs (l2ight)")
        .opt("epochs", "10", "on-chip training epochs")
        .opt("batch", "32", "batch size")
        .opt("alpha-w", "0.6", "feedback keep fraction α_W")
        .opt("alpha-c", "1.0", "column keep fraction α_C")
        .opt("alpha-d", "0.0", "SMD skip probability α_D")
        .opt("zo-budget", "1.0", "IC/PM ZO iteration budget multiplier")
        .opt("seed", "42", "PRNG seed")
        .opt("shards", "0", "photonic mesh shards per layer (0|1 = unsharded)")
        .opt("shard-policy", "row", "shard placement: row|col|grid")
        .opt("metrics", "", "JSONL metrics output path")
        .opt("faults", "", "scheduled faults as kind@step, e.g. stuck@8,dead@12")
        .opt(
            "variation",
            "",
            "process-variation spec: sigma=|gamma=|coupler=|loss=|wdm=|sample= \
             (e.g. sigma=0.01,sample=3 or wdm=0.02)",
        )
        .flag("drift", "inject thermal phase drift + γ aging during SL")
        .flag("recovery", "enable watchdog probes + in-situ ZO recovery")
        .flag("verbose", "per-epoch progress");
    let a = parse_or_exit(&spec, args);

    let mut cfg = if a.str("config").is_empty() {
        JobConfig::default()
    } else {
        let text = match std::fs::read_to_string(a.str("config")) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot read config: {e}");
                return 2;
            }
        };
        let parsed =
            Json::parse(&text).map_err(|e| format!("{e:?}")).and_then(|j| JobConfig::from_json(&j));
        match parsed {
            Ok(c) => c,
            Err(e) => {
                eprintln!("bad config: {e}");
                return 2;
            }
        }
    };
    // Flags override.
    cfg.protocol = match Protocol::parse(a.str("protocol")) {
        Some(p) => p,
        None => {
            eprintln!("unknown protocol");
            return 2;
        }
    };
    cfg.arch = match ModelArch::parse(a.str("arch")) {
        Some(m) => m,
        None => {
            eprintln!("unknown arch");
            return 2;
        }
    };
    cfg.dataset = match DatasetKind::parse(a.str("dataset")) {
        Some(d) => d,
        None => {
            eprintln!("unknown dataset");
            return 2;
        }
    };
    cfg.k = a.usize("k");
    cfg.noise = noise_by_name(a.str("noise"));
    cfg.width = a.f64("width") as f32;
    cfg.n_train = a.usize("n-train");
    cfg.n_test = a.usize("n-test");
    cfg.pretrain_epochs = a.usize("pretrain-epochs");
    cfg.epochs = a.usize("epochs");
    cfg.batch = a.usize("batch");
    cfg.alpha_w = a.f64("alpha-w") as f32;
    cfg.alpha_c = a.f64("alpha-c") as f32;
    cfg.alpha_d = a.f64("alpha-d") as f32;
    cfg.zo_budget = a.f64("zo-budget") as f32;
    cfg.seed = a.usize("seed") as u64;
    // Sharding flags override the JSON config only when given (> 0 shards).
    if a.usize("shards") > 0 {
        let policy = match ShardPolicy::parse(a.str("shard-policy")) {
            Some(p) => p,
            None => {
                eprintln!("unknown shard policy (want row|col|grid)");
                return 2;
            }
        };
        cfg.sharding = Some(ShardingConfig { shards: a.usize("shards"), policy });
    }
    // Lifecycle flags build a RobustnessConfig; absent flags leave whatever
    // the JSON config carried (including none) untouched.
    if a.bool("drift") || a.bool("recovery") || !a.str("faults").is_empty() {
        // Malformed fault tokens are a hard error carrying the grammar —
        // a typo must never silently run a clean-chip experiment.
        let faults = if a.str("faults").is_empty() {
            Vec::new()
        } else {
            match FaultSpec::parse_list(a.str("faults")) {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("--faults: {e}");
                    return 2;
                }
            }
        };
        cfg.robustness = Some(RobustnessConfig {
            drift: a.bool("drift").then(DriftConfig::default),
            faults,
            watchdog: Some(WatchdogConfig {
                max_recoveries: if a.bool("recovery") { 4 } else { 0 },
                ..WatchdogConfig::default()
            }),
        });
    }
    if !a.str("variation").is_empty() {
        match VariationConfig::parse_spec(a.str("variation")) {
            Ok(v) => cfg.variation = Some(v),
            Err(e) => {
                eprintln!("--variation: {e}");
                return 2;
            }
        }
    }
    if a.bool("verbose") {
        l2ight::util::set_log_level(l2ight::util::Level::Debug);
    }

    let mut sink = if a.str("metrics").is_empty() {
        MetricSink::memory()
    } else {
        match MetricSink::to_file(Path::new(a.str("metrics"))) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot open metrics file: {e}");
                return 2;
            }
        }
    };

    println!(
        "running {} on {}/{} (k={}, noise={}, width={})",
        cfg.protocol.name(),
        cfg.arch.name(),
        cfg.dataset.name(),
        cfg.k,
        a.str("noise"),
        cfg.width
    );
    let t0 = std::time::Instant::now();
    let s = run_job(&cfg, &mut sink);
    println!("\n== summary ({:.1}s) ==", t0.elapsed().as_secs_f64());
    println!("protocol          {}", s.protocol.name());
    println!("params            {} trainable / {} total", s.trainable_params, s.total_params);
    if let Some(p) = s.pretrain_acc {
        println!("pretrain acc      {p:.4}");
    }
    if let Some(m) = s.ic_mse {
        println!("IC mean MSE       {}", fmt_sig(m, 3));
    }
    if let Some(e) = s.pm_err {
        println!("PM rel error      {}", fmt_sig(e, 3));
    }
    if let Some(m) = s.mapped_acc {
        println!("mapped acc        {m:.4}");
    }
    println!("final acc         {:.4}", s.final_acc);
    println!("best acc          {:.4}", s.best_acc);
    println!(
        "PTC energy        {} calls (fwd {}, σ-grad {}, feedback {})",
        fmt_sig(s.cost.total_energy(), 4),
        fmt_sig(s.cost.fwd_energy, 4),
        fmt_sig(s.cost.wgrad_energy, 4),
        fmt_sig(s.cost.fbk_energy, 4)
    );
    println!("steps             {}", fmt_sig(s.cost.total_steps(), 4));
    println!("ZO queries        {}", s.zo_queries);
    if let Some(q) = s.zo_to_target_queries {
        println!("ZO to target      {q}");
    }
    if let Some(v) = &s.variation {
        println!(
            "variation         blocks={} power_penalty={} dB",
            v.blocks,
            fmt_sig(v.power_penalty_db, 3)
        );
    }
    if let Some(w) = &s.wdm {
        println!(
            "wdm               drift={} blocks={} worst_rel_err={} mean={} worst_mse={}",
            w.max_drift,
            w.blocks,
            fmt_sig(w.worst_rel_err, 3),
            fmt_sig(w.mean_rel_err, 3),
            fmt_sig(w.worst_mse, 3)
        );
    }
    if !s.skipped_stages.is_empty() {
        println!("skipped stages    {}", s.skipped_stages.join(", "));
    }
    if let Some(l) = &s.lifecycle {
        println!(
            "lifecycle         drift={} faults={} trigger={} latency={} \
             recoveries={} recovered={} dead={} queries={}+{} probe",
            l.drift,
            l.faults,
            l.trigger_step.map_or("-".into(), |t| t.to_string()),
            l.detect_latency_steps.map_or("-".into(), |t| t.to_string()),
            l.recoveries,
            l.recovered_blocks,
            l.dead_blocks,
            l.recovery_queries,
            l.probe_queries
        );
    }
    0
}

fn cmd_yield(args: &[String]) -> i32 {
    let spec = ArgSpec::new(
        "l2ight yield",
        "Monte-Carlo yield estimation: run N fabricated-chip instances (variation samples \
         0..N) of one job and report the pass-rate under accuracy/power constraints plus \
         per-metric mean/std/worst-case",
    )
    .opt("samples", "16", "Monte-Carlo chip instances to fabricate")
    .opt(
        "sigma",
        "0.01",
        "uniform per-device σ (gamma+coupler+loss shorthand); ignored when --variation given",
    )
    .opt("variation", "", "full variation spec (see `l2ight run --help`)")
    .opt("min-acc", "0.25", "pass constraint: final accuracy at least this")
    .opt("max-power-db", "3.0", "pass constraint: power penalty at most this many dB")
    .opt("protocol", "l2ight-sl", "l2ight|l2ight-sl|flops|mixedtrn|rad|swat-u")
    .opt("arch", "mlp", "mlp|cnn-s|cnn-l|vgg8|resnet18")
    .opt("dataset", "vowel", "vowel|mnist|fashion|cifar10|cifar100|tiny")
    .opt("k", "4", "photonic block size")
    .opt("noise", "quant", "ideal|paper|quant|bias")
    .opt("width", "0.5", "channel width multiplier")
    .opt("n-train", "96", "synthetic train-set size")
    .opt("n-test", "48", "synthetic test-set size")
    .opt("pretrain-epochs", "4", "digital pretraining epochs (l2ight)")
    .opt("epochs", "3", "on-chip training epochs")
    .opt("batch", "16", "batch size")
    .opt("seed", "42", "PRNG seed (shared by every sample; only `sample` varies)")
    .opt("out", "", "write the yield report JSON here");
    let a = parse_or_exit(&spec, args);

    let protocol = match Protocol::parse(a.str("protocol")) {
        Some(p) => p,
        None => {
            eprintln!("unknown protocol");
            return 2;
        }
    };
    let arch = match ModelArch::parse(a.str("arch")) {
        Some(m) => m,
        None => {
            eprintln!("unknown arch");
            return 2;
        }
    };
    let dataset = match DatasetKind::parse(a.str("dataset")) {
        Some(d) => d,
        None => {
            eprintln!("unknown dataset");
            return 2;
        }
    };
    let variation = if a.str("variation").is_empty() {
        let s = a.f64("sigma");
        if !(s > 0.0 && s.is_finite()) {
            eprintln!("--sigma must be a positive number (got {:?})", a.str("sigma"));
            return 2;
        }
        Some(VariationConfig {
            gamma_std: s,
            coupler_std: s,
            loss_db_std: s,
            ..Default::default()
        })
    } else {
        match VariationConfig::parse_spec(a.str("variation")) {
            Ok(v) => Some(v),
            Err(e) => {
                eprintln!("--variation: {e}");
                return 2;
            }
        }
    };
    let cfg = JobConfig {
        protocol,
        arch,
        dataset,
        k: a.usize("k"),
        noise: noise_by_name(a.str("noise")),
        width: a.f64("width") as f32,
        n_train: a.usize("n-train"),
        n_test: a.usize("n-test"),
        pretrain_epochs: a.usize("pretrain-epochs"),
        epochs: a.usize("epochs"),
        batch: a.usize("batch"),
        seed: a.usize("seed") as u64,
        zo_budget: 0.15,
        variation,
        ..JobConfig::default()
    };
    let samples = a.usize("samples");
    if samples == 0 {
        eprintln!("--samples must be at least 1");
        return 2;
    }
    let constraints = YieldConstraints {
        min_acc: a.f64("min-acc"),
        max_power_penalty_db: a.f64("max-power-db"),
    };

    let pool = l2ight::util::pool::global();
    println!(
        "yield: {} chip instances of {} on {}/{} (k={}, σγ={}), {} threads",
        samples,
        cfg.protocol.name(),
        cfg.arch.name(),
        cfg.dataset.name(),
        cfg.k,
        cfg.variation.map(|v| v.gamma_std).unwrap_or(0.0),
        pool.threads()
    );
    let t0 = std::time::Instant::now();
    let rep = estimate_yield(&cfg, &constraints, samples, pool);
    println!("\n== yield ({:.1}s) ==", t0.elapsed().as_secs_f64());
    println!(
        "pass rate         {:.1}% ({}/{} chips; acc ≥ {}, penalty ≤ {} dB)",
        rep.pass_rate * 100.0,
        rep.passed,
        rep.samples,
        constraints.min_acc,
        constraints.max_power_penalty_db
    );
    let stat_line = |s: &l2ight::robustness::YieldStat| {
        format!(
            "mean {} std {} worst {}",
            fmt_sig(s.mean, 4),
            fmt_sig(s.std, 3),
            fmt_sig(s.worst, 4)
        )
    };
    println!("final acc         {}", stat_line(&rep.final_acc));
    println!("best acc          {}", stat_line(&rep.best_acc));
    println!("power penalty dB  {}", stat_line(&rep.power_penalty_db));
    match &rep.zo_to_target_queries {
        Some(s) => println!(
            "ZO to target      {} ({} of {} reached)",
            stat_line(s),
            rep.zo_target_reached,
            rep.samples
        ),
        None => println!("ZO to target      never reached"),
    }
    println!("total energy      {}", fmt_sig(rep.cost.total_energy(), 4));

    let out = a.str("out");
    if !out.is_empty() {
        if let Err(e) = std::fs::write(Path::new(out), rep.to_json().pretty() + "\n") {
            eprintln!("cannot write {out}: {e}");
            return 1;
        }
        println!("wrote {out}");
    }
    0
}

fn print_golden_diffs(diffs: &[l2ight::scenarios::GoldenDiff]) {
    eprintln!("golden gate FAILED — {} discrepancies:", diffs.len());
    for d in diffs.iter().take(25) {
        eprintln!("  {} :: {}  got {}  want {}  ({})", d.row, d.metric, d.got, d.want, d.detail);
    }
    if diffs.len() > 25 {
        eprintln!("  … and {} more", diffs.len() - 25);
    }
}

fn cmd_matrix(args: &[String]) -> i32 {
    let spec = ArgSpec::new(
        "l2ight matrix",
        "run the scenario matrix (arch x dataset x noise x sparsity x protocol) and gate \
         the metrics against a golden fixture",
    )
    .opt("tier", "quick", "quick|full")
    .opt("filter", "", "comma-separated substrings; keep rows whose name matches any")
    .opt("out", "SCENARIOS_matrix.json", "machine-readable report output path")
    .opt("golden", "", "golden fixture to diff against (e.g. golden/matrix_quick.json)")
    .opt("seed", "42", "base seed; per-row seeds derive from (seed, row index)")
    .flag("bless", "write the produced report as the new golden and exit")
    .flag("list", "print matching row names without running anything")
    .flag(
        "require-armed",
        "exit non-zero if the golden is an unblessed placeholder (CI uses this so a \
         skipped gate can never pass silently)",
    )
    .flag(
        "allow-new-families",
        "tolerate rows/metrics from the standing new-family exemption list (variation/, \
         wdm/, zo_to_target_queries) that the golden predates; blessed rows are still \
         held to tolerance",
    );
    let a = parse_or_exit(&spec, args);

    let tier = match Tier::parse(a.str("tier")) {
        Some(t) => t,
        None => {
            eprintln!("unknown tier {:?} (quick|full)", a.str("tier"));
            return 2;
        }
    };
    let filters: Vec<String> = a
        .str("filter")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let filters_active = !filters.is_empty();
    let rows = expand(&MatrixSpec { tier, base_seed: a.usize("seed") as u64, filters });
    if rows.is_empty() {
        eprintln!("no scenario rows match the filter");
        return 2;
    }
    if a.bool("list") {
        for r in &rows {
            println!("{}", r.name);
        }
        return 0;
    }
    // Validate the golden flags before paying for the run.
    if a.bool("bless") {
        if a.str("golden").is_empty() {
            eprintln!("--bless needs --golden <path>");
            return 2;
        }
        if filters_active {
            eprintln!(
                "refusing to bless from a filtered run: a partial golden would fail \
                 every unselected row in CI"
            );
            return 2;
        }
    }

    let pool = l2ight::util::pool::global();
    println!(
        "running {} scenario rows ({} tier) on {} threads, simd={}",
        rows.len(),
        tier.name(),
        pool.threads(),
        l2ight::linalg::simd::active().name()
    );
    let t0 = std::time::Instant::now();
    let results = run_matrix(&rows, pool);
    for r in &results {
        println!(
            "  {:<52} acc {:.4} best {:.4}  E {:>12}  zo {:>8}  {:.1}s",
            r.row.name,
            r.summary.final_acc,
            r.summary.best_acc,
            fmt_sig(r.summary.cost.total_energy(), 4),
            r.summary.zo_queries,
            r.wall_secs
        );
    }
    println!("matrix done in {:.1}s", t0.elapsed().as_secs_f64());

    let report = report_json(tier, pool.threads(), l2ight::linalg::simd::active().name(), &results);
    let out = a.str("out");
    if let Err(e) = write_report(Path::new(out), &report) {
        eprintln!("cannot write {out}: {e}");
        return 1;
    }
    println!("wrote {out}");

    let golden_path = a.str("golden");
    if golden_path.is_empty() {
        return 0;
    }
    if a.bool("bless") {
        return match write_report(Path::new(golden_path), &report) {
            Ok(()) => {
                println!("blessed {golden_path} ({} rows)", results.len());
                0
            }
            Err(e) => {
                eprintln!("cannot bless {golden_path}: {e}");
                1
            }
        };
    }
    if filters_active {
        // A filtered report would flag every unselected golden row as
        // missing; the gate is only meaningful over the tier's full set.
        println!("golden gate skipped (--filter active); run without --filter to gate");
        return 0;
    }
    // The standing new-family exemptions only apply when CI opts in; a
    // default invocation still demands a fully blessed golden.
    let exemptions = if a.bool("allow-new-families") {
        golden::Exemptions::current()
    } else {
        golden::Exemptions::default()
    };
    match golden::load(Path::new(golden_path)) {
        Err(e) => {
            eprintln!("cannot read golden: {e}\n(create it with --bless)");
            1
        }
        Ok(gold) => {
            let outcome =
                golden::diff_reports_with(&report, &gold, &Tolerances::gate(), &exemptions);
            match outcome {
                GoldenOutcome::Unblessed => {
                    // GitHub Actions annotation: visible on the run summary
                    // even when the gate is allowed to skip.
                    println!(
                        "::warning file={golden_path}::golden {golden_path} is an unblessed \
                         placeholder — the golden gate did not run"
                    );
                    println!(
                        "golden {golden_path} is an unblessed placeholder — gate skipped.\n\
                         bless it on the gate platform with:\n  \
                         l2ight matrix --tier {} --golden {golden_path} --bless\n\
                         (or trigger the bless-goldens job: Actions → ci → Run workflow)",
                        tier.name()
                    );
                    if a.bool("require-armed") {
                        eprintln!(
                            "--require-armed: refusing to pass with an unblessed golden \
                             ({golden_path})"
                        );
                        1
                    } else {
                        0
                    }
                }
                GoldenOutcome::Match { rows } => {
                    println!("golden gate OK — {rows} rows within tolerance");
                    0
                }
                GoldenOutcome::Mismatch(diffs) => {
                    print_golden_diffs(&diffs);
                    1
                }
            }
        }
    }
}

fn cmd_matrix_diff(args: &[String]) -> i32 {
    let spec = ArgSpec::new("l2ight matrix-diff", "compare two scenario-matrix reports")
        .pos("golden", "reference report (treated as the golden)")
        .pos("report", "report under test")
        .flag("exact", "zero tolerance on every metric (thread-invariance gate)");
    let a = parse_or_exit(&spec, args);
    let want = match golden::load(Path::new(a.str("golden"))) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let got = match golden::load(Path::new(a.str("report"))) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let tol = if a.bool("exact") { Tolerances::STRICT } else { Tolerances::gate() };
    match diff_reports(&got, &want, &tol) {
        GoldenOutcome::Unblessed => {
            eprintln!("reference report is an unblessed placeholder — nothing to compare");
            2
        }
        GoldenOutcome::Match { rows } => {
            println!(
                "reports match — {rows} rows identical{}",
                if a.bool("exact") { " (bitwise)" } else { " within tolerance" }
            );
            0
        }
        GoldenOutcome::Mismatch(diffs) => {
            print_golden_diffs(&diffs);
            1
        }
    }
}

fn cmd_calibrate(args: &[String]) -> i32 {
    let spec = ArgSpec::new("l2ight calibrate", "identity-calibrate a PTC mesh (stage 1)")
        .opt("rows", "18", "mesh rows")
        .opt("cols", "18", "mesh cols")
        .opt("k", "9", "block size")
        .opt("noise", "paper", "ideal|paper|quant|bias")
        .opt("optimizer", "zcd", "zgd|zcd|ztp")
        .opt("iters", "400", "ZO iterations")
        .opt("seed", "1", "PRNG seed");
    let a = parse_or_exit(&spec, args);
    let mut rng = Rng::new(a.usize("seed") as u64);
    let mut mesh = PtcMesh::new(
        a.usize("rows"),
        a.usize("cols"),
        a.usize("k"),
        noise_by_name(a.str("noise")),
        &mut rng,
    );
    let before: f64 = {
        let mut s = 0.0;
        for ptc in mesh.ptcs.iter_mut() {
            let (u, v) = ptc.identity_mse();
            s += (u + v) / 2.0;
        }
        s / mesh.ptcs.len() as f64
    };
    let optimizer = match &*a.str("optimizer") {
        "zgd" => ZoKind::Zgd,
        "zcd" => ZoKind::Zcd,
        "ztp" => ZoKind::Ztp,
        _ => {
            eprintln!("unknown optimizer");
            return 2;
        }
    };
    let mut cfg = IcConfig { optimizer, ..IcConfig::default() };
    cfg.zo.iters = a.usize("iters");
    let t0 = std::time::Instant::now();
    let r = calibrate_mesh(&mut mesh, &cfg);
    println!(
        "calibrated {} blocks in {:.1}s: mean MSE {} -> {} ({} queries)",
        r.blocks,
        t0.elapsed().as_secs_f64(),
        fmt_sig(before, 3),
        fmt_sig(r.mean_mse(), 3),
        r.queries
    );
    0
}

fn cmd_map(args: &[String]) -> i32 {
    let spec = ArgSpec::new("l2ight map", "parallel-map a random target matrix (stage 2)")
        .opt("rows", "18", "target rows")
        .opt("cols", "18", "target cols")
        .opt("k", "9", "block size")
        .opt("noise", "paper", "ideal|paper|quant|bias")
        .opt("iters", "75", "ZO iterations per alternation")
        .opt("alternations", "4", "U/V alternations")
        .flag("no-osp", "skip the optimal singular-value projection")
        .opt("seed", "1", "PRNG seed");
    let a = parse_or_exit(&spec, args);
    let mut rng = Rng::new(a.usize("seed") as u64);
    let mut mesh = PtcMesh::new(
        a.usize("rows"),
        a.usize("cols"),
        a.usize("k"),
        noise_by_name(a.str("noise")),
        &mut rng,
    );
    let target = Mat::randn(a.usize("rows"), a.usize("cols"), 0.5, &mut rng);
    let mut cfg = PmConfig {
        alternations: a.usize("alternations"),
        osp: !a.bool("no-osp"),
        ..PmConfig::default()
    };
    cfg.zo.iters = a.usize("iters");
    let t0 = std::time::Instant::now();
    let r = map_mesh(&mut mesh, &target, &cfg);
    println!(
        "mapped {} blocks in {:.1}s: rel err init {} -> final {} ({} queries{})",
        r.blocks,
        t0.elapsed().as_secs_f64(),
        fmt_sig(r.err_init, 3),
        fmt_sig(r.err_osp, 3),
        r.queries,
        if cfg.osp { ", with OSP" } else { "" }
    );
    0
}

fn cmd_infer(args: &[String]) -> i32 {
    let spec = ArgSpec::new("l2ight infer", "batched inference through the PJRT artifacts")
        .opt("artifacts", "", "artifact dir (default $L2IGHT_ARTIFACTS or ./artifacts)")
        .opt("requests", "64", "number of random requests")
        .opt("seed", "1", "PRNG seed");
    let a = parse_or_exit(&spec, args);
    let dir = if a.str("artifacts").is_empty() {
        default_artifact_dir()
    } else {
        PathBuf::from(a.str("artifacts"))
    };
    let rt = match Runtime::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("runtime: {e:#}");
            return 1;
        }
    };
    let mut trainer =
        match l2ight::coordinator::PjrtMlpTrainer::new(rt, a.usize("seed") as u64) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{e:#}");
                return 1;
            }
        };
    let spec_ds = l2ight::data::SynthSpec::quick(DatasetKind::VowelLike, a.usize("requests"), 1);
    let (ds, _) = spec_ds.generate();
    let t0 = std::time::Instant::now();
    let acc = trainer.evaluate(&ds).expect("evaluate");
    let dt = t0.elapsed();
    println!(
        "served {} requests in {:.1} ms ({:.1} req/s), random-init acc {:.3}",
        ds.n,
        dt.as_secs_f64() * 1e3,
        ds.n as f64 / dt.as_secs_f64(),
        acc
    );
    0
}

fn cmd_serve_bench(args: &[String]) -> i32 {
    let spec = ArgSpec::new(
        "l2ight serve-bench",
        "drive open-loop load through the batched serving engine (src/serve) and append \
         latency/occupancy/saturation stats to a BENCH_serve.json history",
    )
    .opt("arch", "mlp", "mlp|cnn-s|cnn-l|vgg8|resnet18")
    .opt("engine", "photonic", "photonic|digital")
    .opt("k", "4", "photonic block size")
    .opt("noise", "paper", "ideal|paper|quant|bias")
    .opt("width", "1.0", "model width multiplier")
    .opt("seed", "42", "model init seed")
    .opt("replicas", "2", "model replicas (concurrent batch executors)")
    .opt("max-batch", "16", "flush a batch at this many requests")
    .opt("max-wait-ms", "5", "...or when the oldest request has waited this long")
    .opt("queue-cap", "1024", "admission-queue depth beyond which requests are shed")
    .opt("qps", "1500", "open-loop arrival rate (requests per second)")
    .opt("requests", "3000", "requests per load level")
    .opt("out", "BENCH_serve.json", "history file (same schema family as BENCH_perf_hotpath)")
    .flag("sweep", "also run a 1x/2x/4x/8x QPS ladder to find saturation throughput")
    .flag(
        "quick",
        "CI smoke preset, ~2 s of load (overrides qps/requests/max-batch/max-wait-ms/\
         queue-cap/sweep)",
    );
    let a = parse_or_exit(&spec, args);

    let arch = match ModelArch::parse(a.str("arch")) {
        Some(m) => m,
        None => {
            eprintln!("unknown arch {:?} (mlp|cnn-s|cnn-l|vgg8|resnet18)", a.str("arch"));
            return 2;
        }
    };
    let (engine, engine_label) = match a.str("engine") {
        "digital" => (EngineKind::Digital, "digital".to_string()),
        "photonic" => {
            let k = a.usize("k");
            let noise_name = a.str("noise").to_string();
            (
                EngineKind::Photonic { k, noise: noise_by_name(&noise_name) },
                format!("photonic-k{k}/{noise_name}"),
            )
        }
        other => {
            eprintln!("unknown engine {other:?} (photonic|digital)");
            return 2;
        }
    };

    let mut cfg =
        if a.bool("quick") { ServeBenchConfig::quick() } else { ServeBenchConfig::default() };
    cfg.arch = arch;
    cfg.engine = engine;
    cfg.engine_label = engine_label;
    cfg.width = a.f32("width");
    cfg.seed = a.u64("seed");
    cfg.replicas = a.usize("replicas");
    if !a.bool("quick") {
        cfg.max_batch = a.usize("max-batch");
        cfg.max_wait = std::time::Duration::from_secs_f64(a.f64("max-wait-ms") / 1e3);
        cfg.queue_cap = a.usize("queue-cap");
        cfg.qps = a.f64("qps");
        cfg.requests = a.usize("requests");
        cfg.sweep = a.bool("sweep");
    }

    let pool = l2ight::util::pool::global();
    println!(
        "serve-bench: {} requests at {:.0} qps, {} replicas, {} threads, simd={}{}",
        cfg.requests,
        cfg.qps,
        cfg.replicas,
        pool.threads(),
        l2ight::linalg::simd::active().name(),
        if cfg.sweep { ", sweep" } else { "" }
    );
    let res = run_serve_bench(&cfg);
    print_summary(&cfg, &res);

    let out = a.str("out");
    match append_history(Path::new(out), bench_run_json(&cfg, &res)) {
        Ok(()) => {
            println!("\nwrote {out}");
            0
        }
        Err(e) => {
            eprintln!("cannot write {out}: {e}");
            1
        }
    }
}

fn cmd_tune(args: &[String]) -> i32 {
    let spec = ArgSpec::new(
        "l2ight tune",
        "time the perf_hotpath GEMM ladder + fused-conv microbench per available SIMD \
         level, pick cache blocking (MC/KC/NC) and the conv column-panel width, and save \
         the per-host profile that kernel dispatch consults",
    )
    .opt("out", "", "profile output path (default $L2IGHT_TUNE_PROFILE or ./l2ight_tune.json)")
    .opt(
        "bench-json",
        "BENCH_perf_hotpath.json",
        "perf history file to append the tune report to (empty string skips)",
    )
    .flag("quick", "CI smoke preset: smaller shapes, fewer candidates + reps");
    let a = parse_or_exit(&spec, args);

    let quick = a.bool("quick");
    let pool = l2ight::util::pool::global();
    println!(
        "tuning GEMM blocking + conv panel width on {} threads (active simd={}{})",
        pool.threads(),
        l2ight::linalg::simd::active().name(),
        if quick { ", quick preset" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let (profile, mut report) = tune::tune_host(quick);
    println!("tuned {:.1}s", t0.elapsed().as_secs_f64());
    for level in SimdLevel::ALL.iter().filter(|l| l.available()) {
        if let Some(t) = profile.level(*level) {
            println!(
                "  {:<10} mc={:<4} kc={:<4} nc={:<4} panel_cols={}",
                level.name(),
                t.blocking.mc,
                t.blocking.kc,
                t.blocking.nc,
                t.panel_cols
            );
        }
    }

    let out = if a.str("out").is_empty() {
        tune::profile_path()
    } else {
        PathBuf::from(a.str("out"))
    };
    if let Err(e) = tune::save_profile(&profile, &out) {
        eprintln!("cannot write profile {}: {e}", out.display());
        return 1;
    }
    println!("wrote profile {}", out.display());

    let bench_json = a.str("bench-json");
    if !bench_json.is_empty() {
        // Stamp the report like a perf_hotpath run entry so the perf
        // trajectory stays one self-describing artifact.
        report.set("git_rev", Json::Str(git_rev()));
        report.set("unix_time", Json::Num(unix_time()));
        match append_bench_run(Path::new(bench_json), report) {
            Ok(()) => println!("appended tune report to {bench_json}"),
            Err(e) => {
                eprintln!("cannot append to {bench_json}: {e}");
                return 1;
            }
        }
    }
    0
}

/// Append one run entry to a `BENCH_perf_hotpath.json`-schema history,
/// keeping the last 50 runs (same retention as the bench's own emitter).
fn append_bench_run(path: &Path, run: Json) -> std::io::Result<()> {
    let mut runs: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|src| Json::parse(&src).ok())
        .and_then(|root| root.get("runs").and_then(|r| r.as_arr()).map(|r| r.to_vec()))
        .unwrap_or_default();
    runs.push(run);
    let keep = runs.len().saturating_sub(50);
    let runs = runs.split_off(keep);
    let mut root = Json::obj();
    root.set("bench", Json::Str("perf_hotpath".to_string()));
    root.set("schema", Json::Num(1.0));
    root.set("runs", Json::Arr(runs));
    std::fs::write(path, root.pretty() + "\n")
}

fn cmd_artifacts(args: &[String]) -> i32 {
    let spec = ArgSpec::new("l2ight artifacts", "list AOT artifacts")
        .opt("artifacts", "", "artifact dir (default $L2IGHT_ARTIFACTS or ./artifacts)");
    let a = parse_or_exit(&spec, args);
    let dir = if a.str("artifacts").is_empty() {
        default_artifact_dir()
    } else {
        PathBuf::from(a.str("artifacts"))
    };
    match l2ight::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} artifacts in {}:", m.artifacts.len(), dir.display());
            for art in &m.artifacts {
                let shapes: Vec<String> =
                    art.args.iter().map(|s| format!("{:?}", s.shape)).collect();
                println!("  {:32} {} -> {} outputs", art.name, shapes.join(" "), art.outputs);
            }
            0
        }
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("l2ight {} — L2ight (NeurIPS 2021) reproduction", env!("CARGO_PKG_VERSION"));
    println!("block size default: 9 (Appendix F)");
    println!("artifact dir: {}", default_artifact_dir().display());
    match Runtime::new(&default_artifact_dir()) {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT runtime unavailable: {e:#}"),
    }
    0
}
