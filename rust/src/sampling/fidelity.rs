//! Gradient-approximation fidelity metrics (Fig. 8): how well the sampled
//! σ-gradient aligns with the true (dense) one, measured as average angular
//! similarity [5] and normalized matrix distance.

use crate::data::Dataset;
use crate::nn::{softmax_cross_entropy, Act, BackwardCtx, Model, ProjEngine};
use crate::sampling::{ColumnSampler, FeedbackSampler};
use crate::util::Rng;

/// Angular similarity of two vectors: 1 − arccos(cos θ)/π ∈ [0, 1]
/// (1 = parallel, 0.5 = orthogonal) — the metric of Fig. 8.
pub fn angular_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    let cos = (dot / (na.sqrt() * nb.sqrt()).max(1e-12)).clamp(-1.0, 1.0);
    1.0 - cos.acos() / std::f64::consts::PI
}

/// Normalized distance ‖a − b‖² / ‖a‖².
pub fn normalized_distance(truth: &[f32], approx: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&t, &e) in truth.iter().zip(approx) {
        num += ((t - e) as f64).powi(2);
        den += (t as f64).powi(2);
    }
    num / den.max(1e-12)
}

/// Flatten all Σ-gradient accumulators of a model.
fn collect_sigma_grads(model: &mut Model) -> Vec<f32> {
    let mut out = Vec::new();
    model.for_each_layer(|l| match l.engine_mut() {
        Some(ProjEngine::Photonic { grad_sigma, .. })
        | Some(ProjEngine::PhotonicSharded { grad_sigma, .. }) => {
            out.extend_from_slice(grad_sigma);
        }
        _ => {}
    });
    out
}

/// Run one forward/backward with the given samplers and return the flat
/// σ-gradient vector.
fn one_backward(
    model: &mut Model,
    x: &Act,
    labels: &[usize],
    feedback: Option<FeedbackSampler>,
    feature: ColumnSampler,
    rng_seed: u64,
) -> Vec<f32> {
    let logits = model.forward(x, true);
    let (_, dl) = softmax_cross_entropy(&logits.mat, labels);
    model.zero_grad();
    let mut ctx = BackwardCtx { feedback, feature, rng: Rng::new(rng_seed) };
    let dy = Act { mat: dl, ..logits };
    model.backward(&dy, &mut ctx);
    collect_sigma_grads(model)
}

/// Fidelity of a sampled σ-gradient vs the dense one, averaged over
/// `draws` independent mask draws on one batch.
///
/// Returns (mean angular similarity, mean normalized distance).
pub fn grad_fidelity(
    model: &mut Model,
    ds: &Dataset,
    batch_idx: &[usize],
    feedback: Option<FeedbackSampler>,
    feature: ColumnSampler,
    draws: usize,
    seed: u64,
) -> (f64, f64) {
    let (x, labels) = ds.gather(batch_idx, None);
    let truth = one_backward(model, &x, &labels, None, ColumnSampler::OFF, seed);
    let mut sim = 0.0;
    let mut dist = 0.0;
    for d in 0..draws {
        let est = one_backward(model, &x, &labels, feedback, feature, seed ^ (d as u64 + 1));
        sim += angular_similarity(&truth, &est);
        dist += normalized_distance(&truth, &est);
    }
    model.clear_caches();
    (sim / draws as f64, dist / draws as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, SynthSpec};
    use crate::nn::{build_model, EngineKind, ModelArch};
    use crate::photonics::NoiseModel;
    use crate::sampling::{FeedbackStrategy, Normalization};

    #[test]
    fn angular_similarity_bounds() {
        let a = [1.0f32, 0.0];
        assert!((angular_similarity(&a, &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!((angular_similarity(&a, &[0.0, 1.0]) - 0.5).abs() < 1e-9);
        assert!(angular_similarity(&a, &[-1.0, 0.0]) < 1e-9);
    }

    #[test]
    fn dense_sampling_is_exact() {
        let mut rng = Rng::new(61);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let mut model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let (ds, _) = SynthSpec::quick(DatasetKind::VowelLike, 32, 8).generate();
        let idx: Vec<usize> = (0..16).collect();
        let (sim, dist) =
            grad_fidelity(&mut model, &ds, &idx, None, ColumnSampler::OFF, 2, 1);
        assert!(sim > 0.999, "dense should be exact: {sim}");
        assert!(dist < 1e-9, "dense should be exact: {dist}");
    }

    #[test]
    fn sparser_feedback_is_less_faithful() {
        let mut rng = Rng::new(62);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let mut model = build_model(ModelArch::MlpVowel, kind, 4, 1.0, &mut rng);
        let (ds, _) = SynthSpec::quick(DatasetKind::VowelLike, 64, 8).generate();
        let idx: Vec<usize> = (0..32).collect();
        let fs = |drop: f32| {
            Some(FeedbackSampler::new(FeedbackStrategy::BTopK, drop, Normalization::Exp))
        };
        let (sim_mild, _) =
            grad_fidelity(&mut model, &ds, &idx, fs(0.2), ColumnSampler::OFF, 6, 2);
        let (sim_heavy, _) =
            grad_fidelity(&mut model, &ds, &idx, fs(0.8), ColumnSampler::OFF, 6, 2);
        assert!(
            sim_mild >= sim_heavy - 0.02,
            "mild sampling should align better: {sim_mild} vs {sim_heavy}"
        );
        assert!(sim_mild > 0.5, "btopk grads should be better than orthogonal");
    }
}
