//! Multi-level sparsity for efficient in-situ gradient evaluation (§3.4.2):
//!
//! * `feedback` — structured sampling of the feedback matrix Wᵀ (uniform /
//!   topk / balanced top-K) with none/exp/var normalization;
//! * `column`   — information-preserving column sampling (CS) of im2col
//!   patches, vs. the prior spatial sampling (SS) it improves on;
//! * `data`     — stochastic mini-batch dropping (SMD, [48]).

pub mod column;
pub mod fidelity;
pub mod data;
pub mod feedback;

pub use column::{ColumnSampler, FeatureSampling};
pub use fidelity::{angular_similarity, grad_fidelity, normalized_distance};
pub use data::DataSampler;
pub use feedback::{FeedbackMask, FeedbackSampler, FeedbackStrategy, Normalization};
