//! Balanced feedback sampling (§3.4.2, Fig. 7).
//!
//! The error feedback dX = Wᵀ·dY is the most expensive backward product
//! (Table 2: ∇ₓℒ dominates total steps). We sample Wᵀ with a structured
//! block mask 𝒫_W = c_W·(S_W ⊗ 1): whole k×k PTC blocks are dropped, so the
//! masked PTCs are idle (energy↓) and the partial-product accumulation
//! chain shortens (steps↓).
//!
//! Strategies (Fig. 12(a)):
//! * `Uniform` — importance-unaware random blocks; unbiased, high variance.
//! * `TopK`    — globally greedy by block norm; biased, and load-imbalanced:
//!   the feedback latency is the *longest* accumulation row of Wᵀ.
//! * `BTopK`   — the paper's balanced top-K: per row of Wᵀ (fixed q), draw
//!   the same number of blocks from a norm-guided distribution, bounding
//!   both bias and the critical path.

use crate::linalg::Mat;
use crate::util::Rng;

/// Which blocks of Wᵀ to keep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeedbackStrategy {
    Uniform,
    TopK,
    BTopK,
}

/// Gradient magnitude normalization after masking (Fig. 8(b)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalization {
    /// No rescale.
    None,
    /// Expectation-maintained: scale by 1/keep-fraction (unbiased, App. D).
    Exp,
    /// Variance-maintained: scale by 1/sqrt(keep-fraction).
    Var,
}

/// A drawn mask over the [q × p] block grid of Wᵀ plus its scale factor.
#[derive(Clone, Debug)]
pub struct FeedbackMask {
    /// keep[qi * p + pi] — row-major over Wᵀ's block grid, matching
    /// `PtcMesh::feedback`.
    pub keep: Vec<bool>,
    pub p: usize,
    pub q: usize,
    /// c_W normalization applied to the masked product.
    pub scale: f32,
}

impl FeedbackMask {
    /// Fraction of blocks kept.
    pub fn keep_fraction(&self) -> f32 {
        let kept = self.keep.iter().filter(|&&b| b).count();
        kept as f32 / self.keep.len().max(1) as f32
    }

    /// Longest accumulation row (the latency-critical path, Fig. 7):
    /// max over q of the number of kept p-blocks.
    pub fn critical_path(&self) -> usize {
        (0..self.q)
            .map(|qi| (0..self.p).filter(|&pi| self.keep[qi * self.p + pi]).count())
            .max()
            .unwrap_or(0)
    }

    /// Total kept block-products (the energy proxy).
    pub fn kept_blocks(&self) -> usize {
        self.keep.iter().filter(|&&b| b).count()
    }

    /// Apply to a dense weight (for digital-engine baselines): zero dropped
    /// blocks of W (blocks inferred from the grid) and scale the rest.
    pub fn apply_dense(&self, w: &Mat) -> Mat {
        let bk_r = w.rows.div_ceil(self.p);
        let bk_c = w.cols.div_ceil(self.q);
        let mut out = w.clone();
        for pi in 0..self.p {
            for qi in 0..self.q {
                let keep = self.keep[qi * self.p + pi];
                for r in pi * bk_r..((pi + 1) * bk_r).min(w.rows) {
                    for c in qi * bk_c..((qi + 1) * bk_c).min(w.cols) {
                        out[(r, c)] = if keep { out[(r, c)] * self.scale } else { 0.0 };
                    }
                }
            }
        }
        out
    }
}

/// Draws feedback masks for a given strategy/sparsity/normalization.
#[derive(Clone, Copy, Debug)]
pub struct FeedbackSampler {
    pub strategy: FeedbackStrategy,
    /// Dropped fraction α_W ∈ [0, 1) (paper Table 2 convention: α_W = 0.6
    /// keeps 40% of the blocks).
    pub sparsity: f32,
    pub norm: Normalization,
}

impl FeedbackSampler {
    pub fn new(strategy: FeedbackStrategy, sparsity: f32, norm: Normalization) -> Self {
        assert!((0.0..1.0).contains(&sparsity), "sparsity must be in [0,1)");
        FeedbackSampler { strategy, sparsity, norm }
    }

    /// Draw a mask for a (p, q) block grid given per-block squared Frobenius
    /// norms (row-major [p][q], as `PtcMesh::block_norms_sq` returns).
    pub fn draw(&self, p: usize, q: usize, norms_pq: &[f32], rng: &mut Rng) -> FeedbackMask {
        assert_eq!(norms_pq.len(), p * q);
        let keep_frac = 1.0 - self.sparsity;
        let mut keep = vec![false; p * q]; // [q][p] layout
        match self.strategy {
            FeedbackStrategy::Uniform => {
                let total = p * q;
                let n_keep = ((keep_frac * total as f32).round() as usize).clamp(1, total);
                for idx in rng.choose_k(total, n_keep) {
                    keep[idx] = true;
                }
            }
            FeedbackStrategy::TopK => {
                // Globally greedy: largest block norms anywhere.
                let total = p * q;
                let n_keep = ((keep_frac * total as f32).round() as usize).clamp(1, total);
                let mut idx: Vec<usize> = (0..total).collect();
                // norms are [p][q]; transpose index into the [q][p] mask.
                idx.sort_by(|&a, &b| {
                    let na = norms_pq[(a % p) * q + a / p];
                    let nb = norms_pq[(b % p) * q + b / p];
                    nb.partial_cmp(&na).unwrap()
                });
                for &i in idx.iter().take(n_keep) {
                    keep[(i / p) * p + (i % p)] = true;
                }
            }
            FeedbackStrategy::BTopK => {
                // Per q-row: same count, norm-guided sampling without
                // replacement (Efraimidis–Spirakis keys u^{1/w}).
                let per_row = ((keep_frac * p as f32).round() as usize).clamp(1, p);
                for qi in 0..q {
                    let mut keys: Vec<(f64, usize)> = (0..p)
                        .map(|pi| {
                            let w = norms_pq[pi * q + qi].max(1e-12) as f64;
                            let u = rng.uniform().max(1e-300);
                            (u.powf(1.0 / w), pi)
                        })
                        .collect();
                    keys.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                    for &(_, pi) in keys.iter().take(per_row) {
                        keep[qi * p + pi] = true;
                    }
                }
            }
        }
        let kept = keep.iter().filter(|&&b| b).count().max(1);
        let actual_keep_frac = kept as f32 / (p * q) as f32;
        let scale = match self.norm {
            Normalization::None => 1.0,
            Normalization::Exp => 1.0 / actual_keep_frac,
            Normalization::Var => 1.0 / actual_keep_frac.sqrt(),
        };
        FeedbackMask { keep, p, q, scale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn norms(p: usize, q: usize, rng: &mut Rng) -> Vec<f32> {
        (0..p * q).map(|_| rng.uniform_f32() + 0.01).collect()
    }

    #[test]
    fn btopk_is_load_balanced() {
        let mut rng = Rng::new(1);
        let (p, q) = (8, 6);
        let n = norms(p, q, &mut rng);
        let s = FeedbackSampler::new(FeedbackStrategy::BTopK, 0.5, Normalization::Exp);
        for _ in 0..20 {
            let m = s.draw(p, q, &n, &mut rng);
            // Every q-row keeps exactly the same count.
            let counts: Vec<usize> = (0..q)
                .map(|qi| (0..p).filter(|&pi| m.keep[qi * p + pi]).count())
                .collect();
            assert!(counts.windows(2).all(|w| w[0] == w[1]), "{counts:?}");
            assert_eq!(m.critical_path(), counts[0]);
        }
    }

    #[test]
    fn topk_prefers_large_norms() {
        let mut rng = Rng::new(2);
        let (p, q) = (4, 4);
        let mut n = vec![0.01f32; p * q];
        // Make blocks p=0 row huge.
        for qi in 0..q {
            n[qi] = 100.0; // p index 0, all q
        }
        let s = FeedbackSampler::new(FeedbackStrategy::TopK, 0.75, Normalization::None);
        let m = s.draw(p, q, &n, &mut rng);
        // keep count = 4; the 4 largest are p=0 blocks for each q.
        for qi in 0..q {
            assert!(m.keep[qi * p], "block (0, {qi}) should be kept");
        }
        assert_eq!(m.kept_blocks(), 4);
        // ...and topk is maximally imbalanced here in the p-dimension:
        assert_eq!(m.critical_path(), 1);
    }

    #[test]
    fn uniform_keep_count_exact() {
        let mut rng = Rng::new(3);
        let (p, q) = (5, 7);
        let n = norms(p, q, &mut rng);
        let s = FeedbackSampler::new(FeedbackStrategy::Uniform, 0.6, Normalization::Exp);
        let m = s.draw(p, q, &n, &mut rng);
        assert_eq!(m.kept_blocks(), ((0.4 * 35.0f32).round()) as usize);
        assert!((m.scale - 35.0 / m.kept_blocks() as f32).abs() < 1e-5);
    }

    #[test]
    fn normalization_factors() {
        let mut rng = Rng::new(4);
        let n = norms(4, 4, &mut rng);
        for (norm, expect) in [
            (Normalization::None, 1.0f32),
            (Normalization::Exp, 2.0),
            (Normalization::Var, 2.0f32.sqrt()),
        ] {
            let s = FeedbackSampler::new(FeedbackStrategy::BTopK, 0.5, norm);
            let m = s.draw(4, 4, &n, &mut rng);
            assert!((m.scale - expect).abs() < 1e-4, "{norm:?}: {} vs {expect}", m.scale);
        }
    }

    #[test]
    fn unbiasedness_of_uniform_exp() {
        // E[masked-and-scaled W] ≈ W elementwise (Appendix D, Claim 2).
        let mut rng = Rng::new(5);
        let w = Mat::randn(8, 8, 1.0, &mut rng);
        let s = FeedbackSampler::new(FeedbackStrategy::Uniform, 0.5, Normalization::Exp);
        let n = vec![1.0f32; 16];
        let mut acc = Mat::zeros(8, 8);
        let reps = 4000;
        for _ in 0..reps {
            let m = s.draw(4, 4, &n, &mut rng);
            acc = acc.add(&m.apply_dense(&w));
        }
        acc.scale(1.0 / reps as f32);
        let err = acc.sub(&w).fro_norm() / w.fro_norm();
        assert!(err < 0.05, "bias too large: {err}");
    }

    #[test]
    fn apply_dense_zeroes_dropped() {
        let w = Mat::from_slice(4, 4, &(0..16).map(|i| i as f32 + 1.0).collect::<Vec<_>>());
        let mask = FeedbackMask { keep: vec![true, false, false, true], p: 2, q: 2, scale: 2.0 };
        let out = mask.apply_dense(&w);
        // keep[(q=0,p=0)]=true -> top-left block scaled; keep[(q=0,p=1)]=false
        // -> bottom-left zero; keep[(q=1,p=0)]=false -> top-right zero;
        // keep[(q=1,p=1)]=true -> bottom-right scaled.
        assert_eq!(out[(0, 0)], 2.0);
        assert_eq!(out[(2, 0)], 0.0);
        assert_eq!(out[(0, 2)], 0.0);
        assert_eq!(out[(2, 2)], 22.0);
    }
}
