//! Feature sampling for gradient evaluation (§3.4.2, Fig. 9).
//!
//! * **Column sampling (CS, ours)** — drop whole columns of the im2col patch
//!   matrix X (i.e. output spatial positions), shared across the batch.
//!   Because a pixel appears in multiple patches, information is partially
//!   preserved, and the structured drop translates directly to fewer PTC
//!   calls and shorter accumulation (energy + step savings).
//! * **Spatial sampling (SS, prior RAD/SWAT)** — drop input *pixels* before
//!   im2col. After the unfold, the zeros scatter irregularly, so the dense
//!   projection engine saves nothing — it only reduces activation storage.
//!
//! For CONV1×1 the two coincide. Per the paper, CS uses no magnitude
//! rescale (α_C scaling is harmful when combined with α_W; §3.4.2).

use crate::nn::act::Act;
use crate::util::Rng;

/// Which feature-sampling technique a layer uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureSampling {
    /// No feature sampling.
    None,
    /// Column sampling with drop fraction α_C.
    Column,
    /// Spatial sampling with drop fraction α_S (prior art baseline).
    Spatial,
}

/// Draws per-layer feature masks.
#[derive(Clone, Copy, Debug)]
pub struct ColumnSampler {
    pub mode: FeatureSampling,
    /// Dropped fraction (α_C or α_S).
    pub sparsity: f32,
    /// Whether to rescale kept features by 1/keep-fraction
    /// (expectation-maintained); the paper uses `false` for CS.
    pub rescale: bool,
}

impl ColumnSampler {
    pub const OFF: ColumnSampler =
        ColumnSampler { mode: FeatureSampling::None, sparsity: 0.0, rescale: false };

    pub fn column(sparsity: f32) -> ColumnSampler {
        ColumnSampler { mode: FeatureSampling::Column, sparsity, rescale: false }
    }

    pub fn spatial(sparsity: f32, rescale: bool) -> ColumnSampler {
        ColumnSampler { mode: FeatureSampling::Spatial, sparsity, rescale }
    }

    /// Draw a keep-mask over the patch-matrix columns for a layer whose
    /// im2col output has `spatial` output positions and batch `b`
    /// (total columns b·spatial). CS masks positions *shared across batch*
    /// (negligible mask-generation overhead, §3.4.2). Returns None when off.
    pub fn draw_column_mask(&self, b: usize, spatial: usize, rng: &mut Rng) -> Option<Vec<bool>> {
        if self.mode != FeatureSampling::Column || self.sparsity <= 0.0 {
            return None;
        }
        let keep_n =
            (((1.0 - self.sparsity) * spatial as f32).round() as usize).clamp(1, spatial);
        let mut pos_keep = vec![false; spatial];
        for i in rng.choose_k(spatial, keep_n) {
            pos_keep[i] = true;
        }
        let mut mask = vec![false; b * spatial];
        for bi in 0..b {
            for s in 0..spatial {
                mask[bi * spatial + s] = pos_keep[s];
            }
        }
        Some(mask)
    }

    /// The gradient scale for kept columns (1 unless `rescale`).
    pub fn scale(&self) -> f32 {
        if self.rescale && self.mode != FeatureSampling::None && self.sparsity > 0.0 {
            1.0 / (1.0 - self.sparsity)
        } else {
            1.0
        }
    }

    /// Spatial sampling: zero dropped input *pixels* (all channels) of a
    /// cached activation, returning the sparsified copy used for gradient
    /// computation. Models RAD/SWAT-U: storage shrinks, but the zeros
    /// scatter after im2col so no step reduction is possible.
    pub fn apply_spatial(&self, x: &Act, rng: &mut Rng) -> Option<Act> {
        if self.mode != FeatureSampling::Spatial || self.sparsity <= 0.0 {
            return None;
        }
        let s = x.spatial();
        let total = x.batch * s;
        let keep_n = (((1.0 - self.sparsity) * total as f32).round() as usize).clamp(1, total);
        let mut keep = vec![false; total];
        for i in rng.choose_k(total, keep_n) {
            keep[i] = true;
        }
        let scale = if self.rescale { total as f32 / keep_n as f32 } else { 1.0 };
        let mut out = x.clone();
        for ch in 0..out.channels() {
            let row = out.mat.row_mut(ch);
            for (c, &k) in keep.iter().enumerate() {
                row[c] = if k { row[c] * scale } else { 0.0 };
            }
        }
        Some(out)
    }

    /// Activation-storage reduction fraction achieved (the "Act↓" column of
    /// Table 2): SS stores only kept pixels, CS stores kept columns.
    pub fn act_reduction(&self) -> f32 {
        match self.mode {
            FeatureSampling::None => 0.0,
            FeatureSampling::Column | FeatureSampling::Spatial => self.sparsity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn column_mask_shared_across_batch() {
        let mut rng = Rng::new(1);
        let s = ColumnSampler::column(0.5);
        let mask = s.draw_column_mask(3, 10, &mut rng).unwrap();
        assert_eq!(mask.len(), 30);
        for bi in 1..3 {
            for sp in 0..10 {
                assert_eq!(mask[sp], mask[bi * 10 + sp], "mask must be batch-shared");
            }
        }
        let kept = mask[..10].iter().filter(|&&m| m).count();
        assert_eq!(kept, 5);
    }

    #[test]
    fn off_draws_nothing() {
        let mut rng = Rng::new(2);
        assert!(ColumnSampler::OFF.draw_column_mask(2, 8, &mut rng).is_none());
        assert_eq!(ColumnSampler::OFF.scale(), 1.0);
    }

    #[test]
    fn spatial_zeroes_pixels_across_channels() {
        let mut rng = Rng::new(3);
        let s = ColumnSampler::spatial(0.5, false);
        let act = Act::from_image(Mat::from_vec(2, 8, vec![1.0; 16]), 2, 2, 2);
        let out = s.apply_spatial(&act, &mut rng).unwrap();
        // Each dropped pixel must be dropped in *both* channels.
        for col in 0..8 {
            let a = out.mat[(0, col)];
            let b = out.mat[(1, col)];
            assert_eq!(a == 0.0, b == 0.0, "channel-inconsistent drop at {col}");
        }
        let dropped = (0..8).filter(|&c| out.mat[(0, c)] == 0.0).count();
        assert_eq!(dropped, 4);
    }

    #[test]
    fn spatial_rescale_maintains_expectation() {
        let mut rng = Rng::new(4);
        let s = ColumnSampler::spatial(0.5, true);
        let act = Act::from_image(Mat::from_vec(1, 1000, vec![1.0; 1000]), 1, 1000, 1);
        let mut acc = 0.0f64;
        let reps = 200;
        for _ in 0..reps {
            let out = s.apply_spatial(&act, &mut rng).unwrap();
            acc += out.mat.data.iter().map(|&v| v as f64).sum::<f64>();
        }
        let mean = acc / (reps as f64 * 1000.0);
        assert!((mean - 1.0).abs() < 0.05, "expectation drift: {mean}");
    }

    #[test]
    fn scale_logic() {
        assert_eq!(ColumnSampler::column(0.6).scale(), 1.0);
        let cs = ColumnSampler { mode: FeatureSampling::Column, sparsity: 0.6, rescale: true };
        assert!((cs.scale() - 2.5).abs() < 1e-5);
    }
}
