//! Data-level sparsity: stochastic mini-batch dropping (SMD, §3.4.2 /
//! E2-Train [48]). Each iteration of an epoch is skipped with probability
//! α_D, which translates one-for-one into training-time and energy
//! reduction (Table 2 "+ Data Sampling", Fig. 12(c)).

use crate::util::Rng;

/// Iteration-skipping sampler.
#[derive(Clone, Copy, Debug)]
pub struct DataSampler {
    /// Skip probability α_D ∈ [0, 1).
    pub sparsity: f32,
}

impl DataSampler {
    pub const OFF: DataSampler = DataSampler { sparsity: 0.0 };

    pub fn new(sparsity: f32) -> DataSampler {
        assert!((0.0..1.0).contains(&sparsity));
        DataSampler { sparsity }
    }

    /// Whether to skip the current iteration.
    pub fn skip(&self, rng: &mut Rng) -> bool {
        self.sparsity > 0.0 && rng.bernoulli(self.sparsity as f64)
    }

    /// Expected fraction of iterations executed.
    pub fn expected_kept(&self) -> f32 {
        1.0 - self.sparsity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_never_skips() {
        let mut rng = Rng::new(1);
        assert!((0..1000).all(|_| !DataSampler::OFF.skip(&mut rng)));
    }

    #[test]
    fn skip_rate_matches() {
        let mut rng = Rng::new(2);
        let s = DataSampler::new(0.8);
        let skipped = (0..20_000).filter(|_| s.skip(&mut rng)).count();
        assert!((skipped as f64 / 20_000.0 - 0.8).abs() < 0.02);
        assert!((s.expected_kept() - 0.2).abs() < 1e-6);
    }
}
