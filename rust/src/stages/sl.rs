//! Stage 3 — Subspace Learning (§3.4): first-order on-chip training of Σ
//! with multi-level sparsity.
//!
//! The training loop is the paper's efficiency subject: per iteration it
//! runs forward (ℒ), in-situ σ-gradient acquisition via reciprocity
//! (∇_Σℒ, Eq. 5), and masked error feedback (∇_xℒ), with
//!
//! * **feedback sampling** — a `FeedbackSampler` drawn per layer per
//!   iteration masks the blocked Wᵀ (uniform / topk / btopk × norm);
//! * **column sampling** — a shared per-iteration batch-column mask enters
//!   only the σ-gradient evaluation (α_C; paper adopts exp-normalization
//!   with α_C-scaling off, §3.4.2 last note);
//! * **data sampling** — SMD [48]: skip whole iterations with prob. α_D.
//!
//! The same loop trains digital models (pretraining, RAD/SWAT-U baselines) —
//! the engines decide whether gradients are full-space or subspace.
//!
//! Threading: the loop itself stays sequential (SGD is a serial recurrence);
//! all parallelism lives below it, in the engines' mesh/GEMM hot paths on
//! the shared `util::pool` (sized by `L2IGHT_THREADS`). Results are
//! therefore independent of thread count.

use crate::data::{Augment, Dataset, Loader};
use crate::nn::{softmax_cross_entropy, Act, BackwardCtx, Model};
use crate::optim::{AdamW, LrSchedule, Optimizer, Sgd};
use crate::profiler::CostBreakdown;
use crate::sampling::{ColumnSampler, DataSampler, FeedbackSampler};
use crate::util::Rng;

/// Which optimizer drives the Σ (or dense-weight) updates.
#[derive(Clone, Copy, Debug)]
pub enum OptKind {
    /// AdamW(lr, weight_decay) — the paper's SL optimizer.
    AdamW { lr: f32, weight_decay: f32 },
    /// SGD(lr, momentum, weight_decay) — used for digital pretraining.
    Sgd { lr: f32, momentum: f32, weight_decay: f32 },
}

/// Subspace-learning (and generic training) configuration.
#[derive(Clone, Debug)]
pub struct SlConfig {
    pub epochs: usize,
    pub batch: usize,
    pub opt: OptKind,
    pub schedule: LrSchedule,
    /// Feedback-matrix sampler (None = dense feedback).
    pub feedback: Option<FeedbackSampler>,
    /// Feature sampler (CS / SS / off).
    pub feature: ColumnSampler,
    /// SMD data sampler.
    pub data: DataSampler,
    pub augment: Augment,
    pub seed: u64,
    /// Evaluate on the test set every `eval_every` epochs (0 = only final).
    pub eval_every: usize,
    /// Print a progress line per epoch.
    pub verbose: bool,
}

impl Default for SlConfig {
    fn default() -> Self {
        // Paper Appendix E, subspace learning from scratch.
        SlConfig {
            epochs: 20,
            batch: 32,
            opt: OptKind::AdamW { lr: 2e-3, weight_decay: 1e-2 },
            schedule: LrSchedule::Cosine { lr0: 0.0, eta_min: 0.0, total_steps: 0 }, // fixed up in train()
            feedback: None,
            feature: ColumnSampler::OFF,
            data: DataSampler::OFF,
            augment: Augment::NONE,
            seed: 0x51,
            eval_every: 1,
            verbose: false,
        }
    }
}

impl SlConfig {
    /// Paper setting for SL after parallel mapping: fewer epochs, lr 2e-4.
    pub fn mapped() -> SlConfig {
        SlConfig { opt: OptKind::AdamW { lr: 2e-4, weight_decay: 1e-2 }, ..Default::default() }
    }

    /// Tiny config for tests.
    pub fn quick(epochs: usize, batch: usize) -> SlConfig {
        SlConfig { epochs, batch, eval_every: 0, ..Default::default() }
    }
}

/// Per-epoch record.
#[derive(Clone, Debug)]
pub struct EpochStat {
    pub epoch: usize,
    pub loss: f32,
    pub train_acc: f32,
    /// Test accuracy if evaluated this epoch.
    pub test_acc: Option<f32>,
    /// Hardware cost accumulated *during this epoch* (photonic engines only).
    pub cost: CostBreakdown,
    /// Iterations actually executed (SMD skips excluded).
    pub iters_run: usize,
}

/// Training outcome.
#[derive(Clone, Debug, Default)]
pub struct SlReport {
    pub epochs: Vec<EpochStat>,
    pub final_test_acc: f32,
    pub best_test_acc: f32,
    /// Total hardware cost over the run.
    pub cost: CostBreakdown,
}

impl SlReport {
    /// Accuracy-vs-steps curve: (cumulative steps, test acc) at each
    /// evaluated epoch — the x/y of Fig. 12.
    pub fn acc_vs_steps(&self) -> Vec<(f64, f32)> {
        let mut out = Vec::new();
        let mut steps = 0.0;
        for e in &self.epochs {
            steps += e.cost.total_steps();
            if let Some(acc) = e.test_acc {
                out.push((steps, acc));
            }
        }
        out
    }
}

/// Train `model` on `train_set`, evaluating on `test_set`.
///
/// Works for photonic models (subspace learning — only Σ moves) and digital
/// models (full-space pretraining / baselines). Hardware cost is measured
/// from the photonic mesh counters.
pub fn train(
    model: &mut Model,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &SlConfig,
) -> SlReport {
    train_with_lifecycle(model, train_set, test_set, cfg, None)
}

/// `train` with an optional lifecycle supervisor (robustness subsystem).
///
/// Per executed iteration the runtime first advances injected drift/faults
/// (`begin_step` — lifecycle time is *executed* steps; SMD-skipped
/// iterations don't age the chip), then observes the post-step loss for
/// detection/recovery (`observe`). With `None` the loop is byte-for-byte
/// the plain `train` — no extra RNG draws, no stat traffic.
pub fn train_with_lifecycle(
    model: &mut Model,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &SlConfig,
    mut lifecycle: Option<&mut crate::robustness::LifecycleRuntime>,
) -> SlReport {
    let mut rng = Rng::with_stream(cfg.seed, 0xda7a);
    let mut opt: Box<dyn Optimizer> = match cfg.opt {
        OptKind::AdamW { lr, weight_decay } => Box::new(AdamW::new(lr, weight_decay)),
        OptKind::Sgd { lr, momentum, weight_decay } => {
            Box::new(Sgd::new(lr, momentum, weight_decay))
        }
    };
    let base_lr = match cfg.opt {
        OptKind::AdamW { lr, .. } => lr,
        OptKind::Sgd { lr, .. } => lr,
    };
    let schedule = match cfg.schedule {
        // Default marker: cosine over the actual horizon.
        LrSchedule::Cosine { total_steps: 0, .. } => LrSchedule::Cosine {
            lr0: base_lr,
            eta_min: base_lr * 1e-2,
            total_steps: cfg.epochs.max(1),
        },
        s => s,
    };

    let mut report = SlReport::default();
    model.reset_mesh_stats();
    let mut prev_stats = model.mesh_stats();

    for epoch in 0..cfg.epochs {
        let lr = schedule.at(epoch, base_lr);
        opt.set_lr(lr);
        let loader = Loader::new(train_set.n, cfg.batch, &mut rng);
        let mut epoch_loss = 0.0f64;
        let mut epoch_acc = 0.0f64;
        let mut iters_run = 0usize;
        for (it, idx) in loader.enumerate() {
            // Data-level sparsity: stochastic mini-batch dropping.
            if cfg.data.skip(&mut rng) {
                continue;
            }
            if let Some(rt) = &mut lifecycle {
                rt.begin_step(model);
            }
            let aug = if cfg.augment.is_none() { None } else { Some((&cfg.augment, &mut rng)) };
            let (x, labels) = train_set.gather(&idx, aug);
            let logits = model.forward(&x, true);
            let (loss, dlogits) = softmax_cross_entropy(&logits.mat, &labels);
            epoch_loss += loss as f64;
            epoch_acc += crate::nn::accuracy(&logits.mat, &labels) as f64;
            model.zero_grad();
            let mut ctx = BackwardCtx {
                feedback: cfg.feedback,
                feature: cfg.feature,
                rng: Rng::with_stream(cfg.seed ^ 0xbacc, (epoch * 131071 + it) as u64),
            };
            let dy = Act { mat: dlogits, ..logits };
            model.backward(&dy, &mut ctx);
            model.step(opt.as_mut());
            if let Some(rt) = &mut lifecycle {
                rt.observe(model, loss as f64);
            }
            iters_run += 1;
        }
        let denom = iters_run.max(1) as f64;
        let stats = model.mesh_stats();
        let mut delta = stats;
        // Per-epoch delta of the cumulative counters.
        delta.fwd_block_cols -= prev_stats.fwd_block_cols;
        delta.grad_block_cols -= prev_stats.grad_block_cols;
        delta.feedback_block_cols -= prev_stats.feedback_block_cols;
        delta.fwd_steps -= prev_stats.fwd_steps;
        delta.grad_steps -= prev_stats.grad_steps;
        delta.feedback_steps -= prev_stats.feedback_steps;
        prev_stats = stats;
        let cost = CostBreakdown::from_stats(&delta);

        let evaluate =
            epoch + 1 == cfg.epochs || (cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0);
        let test_acc = if evaluate {
            // Exclude eval forwards from the training cost counters.
            let acc = test_set.evaluate(model, cfg.batch);
            let post = model.mesh_stats();
            prev_stats = post;
            Some(acc)
        } else {
            None
        };
        if let Some(acc) = test_acc {
            report.best_test_acc = report.best_test_acc.max(acc);
            report.final_test_acc = acc;
        }
        if cfg.verbose {
            crate::info!(
                "epoch {epoch:3} lr {lr:.2e} loss {:.4} train-acc {:.3} test-acc {} iters {iters_run}",
                epoch_loss / denom,
                epoch_acc / denom,
                test_acc.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
            );
        }
        report.cost.add(&cost);
        report.epochs.push(EpochStat {
            epoch,
            loss: (epoch_loss / denom) as f32,
            train_acc: (epoch_acc / denom) as f32,
            test_acc,
            cost,
            iters_run,
        });
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, SynthSpec};
    use crate::nn::{build_model, EngineKind, ModelArch};
    use crate::photonics::NoiseModel;
    use crate::sampling::{FeedbackStrategy, Normalization};

    fn vowel_sets() -> (Dataset, Dataset) {
        SynthSpec::quick(DatasetKind::VowelLike, 160, 64).with_difficulty(0.4).generate()
    }

    #[test]
    fn digital_pretraining_learns() {
        let mut rng = Rng::new(31);
        let mut model = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 1.0, &mut rng);
        let (train_set, test_set) = vowel_sets();
        let cfg = SlConfig {
            epochs: 12,
            batch: 16,
            opt: OptKind::Sgd { lr: 0.1, momentum: 0.9, weight_decay: 0.0 },
            ..SlConfig::quick(12, 16)
        };
        let r = train(&mut model, &train_set, &test_set, &cfg);
        assert!(r.final_test_acc > 0.6, "digital MLP acc {}", r.final_test_acc);
        // Digital model: no photonic cost.
        assert_eq!(r.cost.total_energy(), 0.0);
    }

    #[test]
    fn subspace_learning_learns_from_scratch() {
        // The paper's key learnability claim: training Σ only (random
        // unitaries) is enough to learn a task.
        let mut rng = Rng::new(32);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let mut model = build_model(ModelArch::MlpVowel, kind, 4, 1.0, &mut rng);
        let (train_set, test_set) = vowel_sets();
        let cfg = SlConfig { epochs: 15, batch: 16, ..SlConfig::quick(15, 16) };
        let r = train(&mut model, &train_set, &test_set, &cfg);
        assert!(r.final_test_acc > 0.5, "subspace acc {}", r.final_test_acc);
        assert!(r.cost.total_energy() > 0.0, "photonic cost must be measured");
    }

    #[test]
    fn feedback_sampling_reduces_feedback_cost() {
        let mut rng = Rng::new(33);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let (train_set, test_set) = vowel_sets();
        let mut dense_model = build_model(ModelArch::MlpVowel, kind, 4, 1.0, &mut rng);
        let mut sparse_model = dense_model.clone();
        let dense_cfg = SlConfig::quick(2, 16);
        let sparse_cfg = SlConfig {
            feedback: Some(FeedbackSampler::new(
                FeedbackStrategy::BTopK,
                0.5,
                Normalization::Exp,
            )),
            ..SlConfig::quick(2, 16)
        };
        let rd = train(&mut dense_model, &train_set, &test_set, &dense_cfg);
        let rs = train(&mut sparse_model, &train_set, &test_set, &sparse_cfg);
        assert!(
            rs.cost.fbk_energy < rd.cost.fbk_energy,
            "feedback sampling must cut ∇x energy: {} vs {}",
            rs.cost.fbk_energy,
            rd.cost.fbk_energy
        );
        // Forward cost unchanged.
        assert_eq!(rs.cost.fwd_energy, rd.cost.fwd_energy);
    }

    #[test]
    fn data_sampling_skips_iterations() {
        let mut rng = Rng::new(34);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let mut model = build_model(ModelArch::MlpVowel, kind, 4, 1.0, &mut rng);
        let (train_set, test_set) = vowel_sets();
        let cfg = SlConfig { data: DataSampler::new(0.5), ..SlConfig::quick(4, 16) };
        let r = train(&mut model, &train_set, &test_set, &cfg);
        let total_iters: usize = r.epochs.iter().map(|e| e.iters_run).sum();
        let full = 4 * train_set.n.div_ceil(16);
        assert!(total_iters < full, "SMD skipped nothing: {total_iters}/{full}");
        assert!(total_iters > full / 5, "SMD skipped too much: {total_iters}/{full}");
    }

    #[test]
    fn acc_vs_steps_is_cumulative() {
        let mut rng = Rng::new(35);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let mut model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let (train_set, test_set) = vowel_sets();
        let cfg = SlConfig { eval_every: 1, ..SlConfig::quick(3, 16) };
        let r = train(&mut model, &train_set, &test_set, &cfg);
        let curve = r.acc_vs_steps();
        assert_eq!(curve.len(), 3);
        assert!(curve.windows(2).all(|w| w[1].0 > w[0].0), "steps must increase");
    }
}
