//! The three-stage L2ight learning flow (§3, Figure 2):
//!
//! * [`ic`] — **Identity Calibration**: variation-agnostic circuit state
//!   preparation (§3.2). ZOO drives every U, V* to a sign-flip identity Ĩ.
//! * [`pm`] — **Parallel Mapping**: alternate projection-based model
//!   deployment (§3.3, Algorithm 1). Per-block ZO regression onto pretrained
//!   weights plus the analytic optimal singular-value projection (OSP).
//! * [`sl`] — **Subspace Learning**: hardware-aware multi-level sparse
//!   first-order training of Σ (§3.4).
//!
//! IC and PM are deterministic, data-independent, and local to each PTC —
//! the stages parallelize over blocks with `std::thread`. SL is the
//! stochastic (and therefore cost-dominant) stage; its hot path is what the
//! runtime can optionally execute through PJRT artifacts.

pub mod ic;
pub mod pm;
pub mod sl;

pub use ic::{calibrate_mesh, calibrate_model, calibrate_sharded_mesh, IcConfig, IcReport};
pub use pm::{map_mesh, map_model, map_sharded_mesh, PmConfig, PmReport};
pub use sl::{train, train_with_lifecycle, SlConfig, SlReport};
