//! Stage 2 — Parallel Mapping (§3.3, Algorithm 1).
//!
//! Maps pretrained weights W onto the noisy meshes with high fidelity:
//! batched k×k block-wise regression `min_Φ Σ_pq ‖W̃_pq(Φ_pq) − W_pq‖²`.
//!
//! Per block (Algorithm 1):
//! 1. SVD + unitary parametrization (`PtcMesh::program_from_dense`) — the
//!    ideal initialization the noise then corrupts;
//! 2. alternate zeroth-order optimization on Φᵁ and Φⱽ (step bounded by the
//!    phase-control resolution, exponentially decayed);
//! 3. **optimal singular-value projection** (OSP, Claim 1/Eq. 4):
//!    Σ ← diag(Ĩ* U* W V Ĩ), computed with the *realized* unitaries via
//!    optical reciprocity — analytically optimal even under unknown sign
//!    flips, and nearly free (3 extra PTC passes).
//!
//! Mapping involves no stochasticity and is local per PTC → parallel across
//! blocks, like IC (both fan out over the shared `util::pool`).

use crate::linalg::Mat;
use crate::nn::{Model, ProjEngine};
use crate::photonics::ptc::{Ptc, Which};
use crate::photonics::unitary::num_phases;
use crate::photonics::{PtcMesh, ShardedMesh};
#[cfg(test)]
use crate::photonics::NoiseModel;
use crate::util::pool;
use crate::util::Rng;
use crate::zoo::{ZoConfig, ZoKind, ZoProblem, ZoReport};

/// Parallel-mapping configuration.
#[derive(Clone, Copy, Debug)]
pub struct PmConfig {
    pub optimizer: ZoKind,
    /// Per-alternation ZO schedule (iters = inner iterations per unitary).
    pub zo: ZoConfig,
    /// Outer U/V alternations (T in Algorithm 1).
    pub alternations: usize,
    /// Run the final optimal singular-value projection.
    pub osp: bool,
    pub seed: u64,
    /// Upper bound on concurrently-mapped blocks: `<= 1` forces the
    /// sequential sweep; larger values fan out over the shared pool (width
    /// set by `L2IGHT_THREADS`) as at most this many tasks.
    pub threads: usize,
}

impl Default for PmConfig {
    fn default() -> Self {
        // Paper Appendix E: 300 epochs, lr 0.1, decay 0.99, 8-bit phases.
        PmConfig {
            optimizer: ZoKind::Zcd,
            zo: ZoConfig { iters: 75, step: 0.1, decay: 0.99, step_floor: 2e-3, best_recording: true },
            alternations: 4,
            osp: true,
            seed: 0x9a99,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }
}

impl PmConfig {
    /// Few-iteration config for tests and smoke runs.
    pub fn quick() -> PmConfig {
        PmConfig {
            zo: ZoConfig { iters: 15, step: 0.1, decay: 0.97, step_floor: 2e-3, best_recording: true },
            alternations: 2,
            ..Default::default()
        }
    }
}

/// Mapping outcome; distances are the paper's normalized matrix distance
/// ‖W − W̃‖² / ‖W‖².
#[derive(Clone, Debug, Default)]
pub struct PmReport {
    /// After SVD-initialization only (what noise does to the ideal phases).
    pub err_init: f64,
    /// After ZO refinement of Φᵁ, Φⱽ.
    pub err_zo: f64,
    /// After the final OSP (Fig. 5's "significant error drop").
    pub err_osp: f64,
    /// Mean per-block regression-loss trace (Fig. 5 convergence).
    pub trace: Vec<f64>,
    pub queries: u64,
    pub blocks: usize,
}

/// Per-block ZO problem over ONE unitary's phases (the other is frozen) —
/// the alternation of Algorithm 1 lines 8-13.
struct PmProblem<'a> {
    ptc: &'a mut Ptc,
    target: &'a Mat,
    which: Which,
}

impl ZoProblem for PmProblem<'_> {
    fn dim(&self) -> usize {
        num_phases(self.ptc.k)
    }

    fn eval(&mut self, phases: &[f64]) -> f64 {
        self.ptc.set_phases(self.which, phases);
        self.ptc.mapping_loss(self.target)
    }
}

/// Map one PTC onto `target` (assumes SVD init already programmed).
/// Returns (loss trace, queries).
pub fn map_ptc(ptc: &mut Ptc, target: &Mat, cfg: &PmConfig, rng: &mut Rng) -> (Vec<f64>, u64) {
    let m = num_phases(ptc.k);
    let mut trace = Vec::new();
    let mut queries = 0u64;
    for _ in 0..cfg.alternations {
        for which in [Which::U, Which::V] {
            let init: Vec<f64> = (0..m).map(|i| ptc.phase(which, i)).collect();
            let report: ZoReport = {
                let mut prob = PmProblem { ptc, target, which };
                cfg.optimizer.run(&mut prob, &init, cfg.zo, rng)
            };
            ptc.set_phases(which, &report.best_phases);
            trace.extend_from_slice(&report.trace);
            queries += report.queries;
        }
    }
    if cfg.osp {
        ptc.osp(target);
        // OSP costs 3 PTC passes on the real chip (Claim 1 procedure).
        queries += 3;
    }
    (trace, queries)
}

/// Map a whole mesh onto a dense target matrix: SVD-parametrize, then
/// per-block parallel ZO + OSP. The mesh noise model stays active the whole
/// time — this is in-situ mapping, not offline decomposition.
pub fn map_mesh(mesh: &mut PtcMesh, target: &Mat, cfg: &PmConfig) -> PmReport {
    assert_eq!((target.rows, target.cols), (mesh.rows, mesh.cols), "map_mesh shape");
    // Algorithm 1 step 1: SVD + unitary parametrization.
    mesh.program_from_dense(target);
    let err_init = mesh.rel_error(target) as f64;

    let (k, p, q) = (mesh.k, mesh.p, mesh.q);
    // Pad the target into k-aligned blocks matching the PTC grid.
    let padded = {
        let mut w = Mat::zeros(p * k, q * k);
        for r in 0..target.rows {
            w.row_mut(r)[..target.cols].copy_from_slice(target.row(r));
        }
        w
    };
    let targets: Vec<Mat> =
        (0..p * q).map(|i| padded.block((i / q) * k, (i % q) * k, k)).collect();

    let blocks = mesh.ptcs.len();
    // Per-block fan-out over the shared pool, capped at `cfg.threads`
    // lanes; per-block RNG streams keep the result independent of thread
    // count.
    let results: Vec<(Vec<f64>, u64)> =
        pool::global().parallel_map_chunked(&mut mesh.ptcs, cfg.threads, |bi, ptc| {
            let mut rng = Rng::with_stream(cfg.seed, bi as u64);
            map_ptc(ptc, &targets[bi], cfg, &mut rng)
        });
    mesh.invalidate();

    let mut report = PmReport { err_init, blocks, ..Default::default() };
    for r in &results {
        if report.trace.len() < r.0.len() {
            report.trace.resize(r.0.len(), 0.0);
        }
        for (t, &v) in report.trace.iter_mut().zip(&r.0) {
            *t += v;
        }
        report.queries += r.1;
    }
    for t in &mut report.trace {
        *t /= blocks as f64;
    }
    report.err_zo = report.trace.last().copied().unwrap_or(err_init);
    report.err_osp = mesh.rel_error(target) as f64;
    report
}

/// Map a sharded mesh onto a dense target. Each shard is mapped
/// independently (its own PM job, as on real multi-chiplet hardware), but
/// every block's ZO RNG stream is keyed by its *logical* block index, and
/// the report is absorbed in logical block order — so both the programmed
/// device state and the report are bitwise-identical to `map_mesh` on the
/// unsharded twin at every shard count, policy, and thread count.
pub fn map_sharded_mesh(sm: &mut ShardedMesh, target: &Mat, cfg: &PmConfig) -> PmReport {
    assert_eq!((target.rows, target.cols), (sm.rows, sm.cols), "map_sharded_mesh shape");
    sm.program_from_dense(target);
    let err_init = sm.rel_error(target) as f64;

    let (k, p, q) = (sm.k, sm.p, sm.q);
    let padded = {
        let mut w = Mat::zeros(p * k, q * k);
        for r in 0..target.rows {
            w.row_mut(r)[..target.cols].copy_from_slice(target.row(r));
        }
        w
    };
    let targets: Vec<Mat> =
        (0..p * q).map(|i| padded.block((i / q) * k, (i % q) * k, k)).collect();

    let blocks = p * q;
    let mut results: Vec<(usize, (Vec<f64>, u64))> = Vec::with_capacity(blocks);
    for s in sm.shards.iter_mut() {
        let (p0, q0, qs) = (s.p0, s.q0, s.mesh.q);
        let targets = &targets;
        let shard_results: Vec<(usize, (Vec<f64>, u64))> =
            pool::global().parallel_map_chunked(&mut s.mesh.ptcs, cfg.threads, |lbi, ptc| {
                let bi = (p0 + lbi / qs) * q + (q0 + lbi % qs);
                let mut rng = Rng::with_stream(cfg.seed, bi as u64);
                (bi, map_ptc(ptc, &targets[bi], cfg, &mut rng))
            });
        results.extend(shard_results);
        s.mesh.invalidate();
    }
    results.sort_by_key(|r| r.0);

    let mut report = PmReport { err_init, blocks, ..Default::default() };
    for (_, r) in &results {
        if report.trace.len() < r.0.len() {
            report.trace.resize(r.0.len(), 0.0);
        }
        for (t, &v) in report.trace.iter_mut().zip(&r.0) {
            *t += v;
        }
        report.queries += r.1;
    }
    for t in &mut report.trace {
        *t /= blocks as f64;
    }
    report.err_zo = report.trace.last().copied().unwrap_or(err_init);
    report.err_osp = sm.rel_error(target) as f64;
    report
}

/// Map every photonic engine in `dst` onto the dense weights of the
/// corresponding engine in `src` (a pretrained digital model of identical
/// topology). Returns the aggregate report (block-weighted means).
pub fn map_model(dst: &mut Model, src: &mut Model, cfg: &PmConfig) -> PmReport {
    // Collect source weights first (stable traversal order on both models).
    let mut weights: Vec<Mat> = Vec::new();
    src.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            weights.push(e.dense_weight());
        }
    });
    let mut agg = PmReport::default();
    let mut wi = 0usize;
    let mut mesh_idx = 0u64;
    dst.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            let w = &weights[wi];
            wi += 1;
            let r = match e {
                ProjEngine::Photonic { mesh, .. } => {
                    let sub = PmConfig { seed: cfg.seed.wrapping_add(mesh_idx), ..*cfg };
                    Some(map_mesh(mesh, w, &sub))
                }
                ProjEngine::PhotonicSharded { mesh, .. } => {
                    let sub = PmConfig { seed: cfg.seed.wrapping_add(mesh_idx), ..*cfg };
                    Some(map_sharded_mesh(mesh, w, &sub))
                }
                _ => None,
            };
            if let Some(r) = r {
                let b = r.blocks as f64;
                agg.err_init += r.err_init * b;
                agg.err_zo += r.err_zo * b;
                agg.err_osp += r.err_osp * b;
                agg.queries += r.queries;
                agg.blocks += r.blocks;
                mesh_idx += 1;
            }
        }
    });
    assert_eq!(wi, weights.len(), "model topology mismatch in map_model");
    let n = agg.blocks.max(1) as f64;
    agg.err_init /= n;
    agg.err_zo /= n;
    agg.err_osp /= n;
    agg
}

/// Copy the non-projection parameters (biases, BN affine + running stats)
/// from `src` into `dst` — mapping transfers projections via the mesh, and
/// the electronically-stored parameters transfer directly.
pub fn copy_aux_params(dst: &mut Model, src: &mut Model) {
    use crate::nn::Layer;
    let mut biases: Vec<Vec<f32>> = Vec::new();
    let mut bns: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::new();
    src.for_each_layer(|l| match l {
        Layer::Linear(lin) => biases.push(lin.bias.clone()),
        Layer::Conv2d(c) => biases.push(c.bias.clone()),
        Layer::BatchNorm(bn) => bns.push((
            bn.gamma.clone(),
            bn.beta.clone(),
            bn.running_mean.clone(),
            bn.running_var.clone(),
        )),
        _ => {}
    });
    let (mut bi, mut ni) = (0usize, 0usize);
    dst.for_each_layer(|l| match l {
        Layer::Linear(lin) => {
            lin.bias.copy_from_slice(&biases[bi]);
            bi += 1;
        }
        Layer::Conv2d(c) => {
            c.bias.copy_from_slice(&biases[bi]);
            bi += 1;
        }
        Layer::BatchNorm(bn) => {
            let (g, b, m, v) = &bns[ni];
            bn.gamma.copy_from_slice(g);
            bn.beta.copy_from_slice(b);
            bn.running_mean.copy_from_slice(m);
            bn.running_var.copy_from_slice(v);
            ni += 1;
        }
        _ => {}
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{build_model, EngineKind, ModelArch};

    #[test]
    fn osp_drops_error_under_noise() {
        // The Fig. 5 effect: after ZO refinement, OSP gives a further
        // significant error drop essentially for free.
        let mut rng = Rng::new(21);
        let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::PAPER, &mut rng);
        let target = Mat::randn(8, 8, 0.5, &mut rng);
        let cfg_no_osp = PmConfig { osp: false, ..PmConfig::quick() };
        let mut mesh2 = mesh.clone();
        let r_no = map_mesh(&mut mesh2, &target, &cfg_no_osp);
        let err_no_osp = mesh2.rel_error(&target) as f64;
        let r_osp = map_mesh(&mut mesh, &target, &PmConfig::quick());
        assert!(
            r_osp.err_osp < err_no_osp,
            "OSP should reduce error: {} vs {}",
            r_osp.err_osp,
            err_no_osp
        );
        assert!(r_osp.queries > r_no.queries, "OSP costs 3 passes per block");
    }

    #[test]
    fn mapping_improves_over_init_under_bias() {
        // With unknown phase bias the SVD init is badly corrupted; ZO must
        // recover a large fraction of the fidelity.
        let mut rng = Rng::new(22);
        let mut mesh = PtcMesh::new(4, 4, 4, NoiseModel::bias_only(), &mut rng);
        let target = Mat::randn(4, 4, 0.5, &mut rng);
        let cfg = PmConfig {
            zo: ZoConfig { iters: 150, step: 0.3, decay: 0.99, step_floor: 1e-3, best_recording: true },
            alternations: 3,
            ..Default::default()
        };
        let r = map_mesh(&mut mesh, &target, &cfg);
        assert!(
            r.err_osp < r.err_init * 0.5,
            "mapping barely improved: init {} final {}",
            r.err_init,
            r.err_osp
        );
    }

    #[test]
    fn ideal_device_maps_exactly_at_init() {
        // No noise ⇒ SVD parametrization alone is already (near-)exact and
        // mapping must not break it.
        let mut rng = Rng::new(23);
        let mut mesh = PtcMesh::new(6, 6, 3, NoiseModel::IDEAL, &mut rng);
        let target = Mat::randn(6, 6, 0.5, &mut rng);
        let r = map_mesh(&mut mesh, &target, &PmConfig::quick());
        assert!(r.err_init < 1e-6, "ideal init err {}", r.err_init);
        assert!(r.err_osp < 1e-6, "ideal final err {}", r.err_osp);
    }

    #[test]
    fn rectangular_and_padded_shapes() {
        let mut rng = Rng::new(24);
        // 10×7 with k=4 → 3×2 grid with padding in both dims.
        let mut mesh = PtcMesh::new(10, 7, 4, NoiseModel::quant_only(8), &mut rng);
        let target = Mat::randn(10, 7, 0.5, &mut rng);
        let r = map_mesh(&mut mesh, &target, &PmConfig::quick());
        assert!(r.err_osp < 0.05, "padded mapping err {}", r.err_osp);
    }

    #[test]
    fn map_model_transfers_digital_to_photonic() {
        let mut rng = Rng::new(25);
        let mut digital = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 0.5, &mut rng);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) };
        let mut photonic = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let r = map_model(&mut photonic, &mut digital, &PmConfig::quick());
        assert!(r.blocks > 0);
        assert!(r.err_osp < 0.05, "model mapping err {}", r.err_osp);
        copy_aux_params(&mut photonic, &mut digital);
        // The mapped photonic model must now agree with the digital one.
        let x = crate::nn::Act::from_features(Mat::randn(8, 5, 1.0, &mut rng), 5);
        let yd = digital.forward(&x, false);
        let yp = photonic.forward(&x, false);
        let rel = yd.mat.sub(&yp.mat).fro_norm() / yd.mat.fro_norm().max(1e-9);
        assert!(rel < 0.15, "mapped model disagrees: rel {rel}");
    }
}
