//! Stage 1 — Identity Calibration (§3.2).
//!
//! After fabrication the realized U, V* are scrambled by unknown phase bias
//! Φ_b and γ-variation. The exact problem `min ‖U−I‖ + ‖V*−I‖` is unsolvable
//! under the observability constraints, so the paper minimizes the
//! |·|-surrogate whose optimum is the *sign-flip identity* Ĩ:
//!
//! `min_Φ Σ_pq ( ‖|U_pq(Φᵁ)| − I‖² + ‖|V*_pq(Φⱽ)| − I‖² )`
//!
//! (observable on chip by sweeping Σ and reading the end-to-end transfer,
//! Eq. 2). We optimize the programmed phases of both meshes jointly with a
//! zeroth-order optimizer; each `eval` is one hardware query. Blocks are
//! independent → embarrassingly parallel across PTCs, fanned out over the
//! shared compute pool (`util::pool` — one threading story with the mesh
//! hot paths). Each block forks its own RNG stream, so results are
//! independent of thread count.

use crate::photonics::ptc::{Ptc, Which};
use crate::photonics::unitary::num_phases;
use crate::photonics::{PtcMesh, ShardedMesh};
use crate::util::pool;
use crate::util::{mean, Rng};
use crate::zoo::{ZoConfig, ZoKind, ZoProblem, ZoReport};

/// Identity-calibration configuration.
#[derive(Clone, Copy, Debug)]
pub struct IcConfig {
    pub optimizer: ZoKind,
    pub zo: ZoConfig,
    pub seed: u64,
    /// Upper bound on concurrently-calibrated blocks: `<= 1` forces the
    /// sequential sweep; larger values fan out over the shared pool (width
    /// set by `L2IGHT_THREADS`) as at most this many tasks.
    pub threads: usize,
}

impl Default for IcConfig {
    fn default() -> Self {
        // Paper Appendix E: 400 epochs, lr 0.1, decay 0.99, 8-bit phases.
        IcConfig {
            optimizer: ZoKind::Zcd,
            zo: ZoConfig { iters: 400, step: 0.1, decay: 0.99, step_floor: 2e-3, best_recording: true },
            seed: 0xca11b,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }
}

impl IcConfig {
    /// Few-iteration config for tests and smoke runs.
    pub fn quick() -> IcConfig {
        IcConfig {
            zo: ZoConfig { iters: 60, step: 0.15, decay: 0.97, step_floor: 2e-3, best_recording: true },
            ..Default::default()
        }
    }
}

/// Outcome of calibrating a mesh (or a whole model).
#[derive(Clone, Debug, Default)]
pub struct IcReport {
    /// Mean MSEᵁ over blocks after calibration (Table 4's metric).
    pub mse_u: f64,
    /// Mean MSEⱽ over blocks after calibration.
    pub mse_v: f64,
    /// Mean per-block loss trace (for the Fig. 4(b) convergence plot).
    pub trace: Vec<f64>,
    /// Total ZO hardware queries over all blocks.
    pub queries: u64,
    /// Number of calibrated PTC blocks.
    pub blocks: usize,
}

impl IcReport {
    fn absorb(&mut self, r: &ZoReport, mse: (f64, f64)) {
        self.mse_u += mse.0;
        self.mse_v += mse.1;
        self.queries += r.queries;
        if self.trace.len() < r.trace.len() {
            self.trace.resize(r.trace.len(), 0.0);
        }
        for (t, &v) in self.trace.iter_mut().zip(&r.trace) {
            *t += v;
        }
        self.blocks += 1;
    }

    fn finalize(&mut self) {
        let n = self.blocks.max(1) as f64;
        self.mse_u /= n;
        self.mse_v /= n;
        for t in &mut self.trace {
            *t /= n;
        }
    }

    /// (MSEᵁ + MSEⱽ)/2, the Table 4 figure of merit.
    pub fn mean_mse(&self) -> f64 {
        (self.mse_u + self.mse_v) / 2.0
    }
}

/// The per-block ZO problem: programmed phases ↦ |·|-identity surrogate.
struct IcProblem<'a> {
    ptc: &'a mut Ptc,
    m: usize,
}

impl ZoProblem for IcProblem<'_> {
    fn dim(&self) -> usize {
        2 * self.m
    }

    fn eval(&mut self, phases: &[f64]) -> f64 {
        self.ptc.set_phases(Which::U, &phases[..self.m]);
        self.ptc.set_phases(Which::V, &phases[self.m..]);
        let (mu, mv) = self.ptc.identity_mse();
        mu + mv
    }
}

/// Calibrate a single PTC in place; returns the ZO report and final MSEs.
pub fn calibrate_ptc(ptc: &mut Ptc, cfg: &IcConfig, rng: &mut Rng) -> (ZoReport, (f64, f64)) {
    let m = num_phases(ptc.k);
    let mut init = Vec::with_capacity(2 * m);
    for i in 0..m {
        init.push(ptc.phase(Which::U, i));
    }
    for i in 0..m {
        init.push(ptc.phase(Which::V, i));
    }
    let report = {
        let mut prob = IcProblem { ptc, m };
        cfg.optimizer.run(&mut prob, &init, cfg.zo, rng)
    };
    // Program the best phases found (the optimizer leaves the device at its
    // last query point otherwise).
    ptc.set_phases(Which::U, &report.best_phases[..m]);
    ptc.set_phases(Which::V, &report.best_phases[m..]);
    let mse = ptc.identity_mse();
    (report, mse)
}

/// Calibrate all blocks of a mesh in parallel. Returns the aggregate report.
pub fn calibrate_mesh(mesh: &mut PtcMesh, cfg: &IcConfig) -> IcReport {
    // Fan the blocks out over the shared pool, capped at `cfg.threads`
    // lanes. Each block forks its own RNG stream, so the result is
    // independent of thread count.
    let results: Vec<(ZoReport, (f64, f64))> =
        pool::global().parallel_map_chunked(&mut mesh.ptcs, cfg.threads, |bi, ptc| {
            let mut rng = Rng::with_stream(cfg.seed, bi as u64);
            calibrate_ptc(ptc, cfg, &mut rng)
        });
    mesh.invalidate();
    let mut agg = IcReport::default();
    for r in &results {
        agg.absorb(&r.0, r.1);
    }
    agg.finalize();
    agg
}

/// Calibrate all blocks of a sharded mesh. Each shard is calibrated on its
/// own (the scoped-recalibration unit), but every block's ZO RNG stream is
/// keyed by its *logical* block index — so the post-IC device state is
/// bitwise-identical to `calibrate_mesh` on the unsharded twin, at every
/// shard count, policy, and thread count.
pub fn calibrate_sharded_mesh(sm: &mut ShardedMesh, cfg: &IcConfig) -> IcReport {
    let q_total = sm.q;
    let mut results: Vec<(usize, (ZoReport, (f64, f64)))> =
        Vec::with_capacity(sm.p * sm.q);
    for s in sm.shards.iter_mut() {
        let (p0, q0, qs) = (s.p0, s.q0, s.mesh.q);
        let shard_results: Vec<(usize, (ZoReport, (f64, f64)))> =
            pool::global().parallel_map_chunked(&mut s.mesh.ptcs, cfg.threads, |lbi, ptc| {
                let bi = (p0 + lbi / qs) * q_total + (q0 + lbi % qs);
                let mut rng = Rng::with_stream(cfg.seed, bi as u64);
                (bi, calibrate_ptc(ptc, cfg, &mut rng))
            });
        results.extend(shard_results);
        s.mesh.invalidate();
    }
    // Absorb in logical block order so the report sums associate exactly
    // like `calibrate_mesh`'s.
    results.sort_by_key(|r| r.0);
    let mut agg = IcReport::default();
    for (_, r) in &results {
        agg.absorb(&r.0, r.1);
    }
    agg.finalize();
    agg
}

/// Calibrate every photonic engine in a model; aggregates across meshes.
pub fn calibrate_model(model: &mut crate::nn::Model, cfg: &IcConfig) -> IcReport {
    let mut agg = IcReport::default();
    let mut traces: Vec<Vec<f64>> = Vec::new();
    let mut mesh_idx = 0u64;
    model.for_each_layer(|l| {
        let r = match l.engine_mut() {
            Some(crate::nn::ProjEngine::Photonic { mesh, .. }) => {
                let sub_cfg = IcConfig { seed: cfg.seed.wrapping_add(mesh_idx), ..*cfg };
                calibrate_mesh(mesh, &sub_cfg)
            }
            Some(crate::nn::ProjEngine::PhotonicSharded { mesh, .. }) => {
                let sub_cfg = IcConfig { seed: cfg.seed.wrapping_add(mesh_idx), ..*cfg };
                calibrate_sharded_mesh(mesh, &sub_cfg)
            }
            _ => return,
        };
        agg.mse_u += r.mse_u * r.blocks as f64;
        agg.mse_v += r.mse_v * r.blocks as f64;
        agg.queries += r.queries;
        agg.blocks += r.blocks;
        traces.push(r.trace);
        mesh_idx += 1;
    });
    let n = agg.blocks.max(1) as f64;
    agg.mse_u /= n;
    agg.mse_v /= n;
    let max_len = traces.iter().map(|t| t.len()).max().unwrap_or(0);
    agg.trace = (0..max_len)
        .map(|i| mean(&traces.iter().filter_map(|t| t.get(i).copied()).collect::<Vec<_>>()))
        .collect();
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::photonics::unitary::is_signed_identity;
    use crate::photonics::NoiseModel;

    #[test]
    fn ic_reaches_signed_identity_on_small_block() {
        let mut rng = Rng::new(11);
        // Bias-only noise: the classic post-fab scramble.
        let mut ptc = Ptc::new(4, NoiseModel::bias_only(), &mut rng);
        let before = ptc.identity_mse();
        let cfg = IcConfig {
            zo: ZoConfig { iters: 400, step: 0.3, decay: 0.995, step_floor: 1e-3, best_recording: true },
            ..IcConfig::default()
        };
        let mut ic_rng = Rng::new(1);
        let (_, after) = calibrate_ptc(&mut ptc, &cfg, &mut ic_rng);
        assert!(after.0 + after.1 < (before.0 + before.1) * 0.2, "{before:?} -> {after:?}");
        // The achievable optimum is a sign-flip identity, not I itself.
        let u = ptc.realized_u().clone();
        assert!(is_signed_identity(&u, 0.35), "not near signed identity");
    }

    #[test]
    fn mesh_calibration_improves_all_blocks() {
        let mut rng = Rng::new(12);
        let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::bias_only(), &mut rng);
        let before: f64 =
            mesh.ptcs.iter_mut().map(|p| { let m = p.identity_mse(); m.0 + m.1 }).sum();
        let cfg = IcConfig { threads: 2, ..IcConfig::quick() };
        let r = calibrate_mesh(&mut mesh, &cfg);
        assert_eq!(r.blocks, 4);
        assert!(r.queries > 0);
        let after: f64 =
            mesh.ptcs.iter_mut().map(|p| { let m = p.identity_mse(); m.0 + m.1 }).sum();
        assert!(after < before, "calibration made things worse: {before} -> {after}");
    }

    #[test]
    fn parallel_equals_sequential() {
        // Thread count must not change results (per-block RNG streams).
        let mut rng = Rng::new(13);
        let mesh0 = PtcMesh::new(8, 8, 4, NoiseModel::bias_only(), &mut rng);
        let mut m1 = mesh0.clone();
        let mut m2 = mesh0;
        let r1 = calibrate_mesh(&mut m1, &IcConfig { threads: 1, ..IcConfig::quick() });
        let r2 = calibrate_mesh(&mut m2, &IcConfig { threads: 4, ..IcConfig::quick() });
        assert_eq!(r1.queries, r2.queries);
        assert!((r1.mean_mse() - r2.mean_mse()).abs() < 1e-12);
        for (a, b) in m1.ptcs.iter().zip(&m2.ptcs) {
            assert_eq!(a.u_mesh.phases, b.u_mesh.phases);
        }
    }

    #[test]
    fn trace_is_averaged_and_monotone() {
        let mut rng = Rng::new(14);
        let mut mesh = PtcMesh::new(4, 4, 4, NoiseModel::bias_only(), &mut rng);
        let r = calibrate_mesh(&mut mesh, &IcConfig::quick());
        assert_eq!(r.trace.len(), IcConfig::quick().zo.iters);
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "best-recording mean trace must be monotone");
        }
    }
}
