//! WDM dispersion analysis (Appendix G.3).
//!
//! A k×k PTC uses k wavelengths for column-parallel processing; each phase
//! shifter's response Δφ(λ) = 2π·n_eff(λ)·L/λ drifts across the spectrum.
//! The paper argues the effect is negligible for k=9 (≤8 nm span ⇒ 1–2%
//! phase drift ⇒ ~0.5% transfer-matrix error) — this module reproduces that
//! argument quantitatively: it realizes the per-wavelength transfer
//! matrices under a linear phase-drift model and reports the worst-case
//! relative error vs the center wavelength.

use super::ptc::Ptc;
use crate::linalg::Mat;

/// Linear dispersion model: channel `c` of `k` sees phases scaled by
/// `1 + drift·t` where `t ∈ [−1, 1]` spans the WDM spectrum symmetric
/// around the center channel.
#[derive(Clone, Copy, Debug)]
pub struct DispersionModel {
    /// Maximum fractional phase drift at the spectrum edges (paper:
    /// 0.01–0.02 for an 8 nm span).
    pub max_drift: f64,
}

impl DispersionModel {
    /// The paper's conservative setting: 2% drift at the band edges.
    pub const PAPER: DispersionModel = DispersionModel { max_drift: 0.02 };

    /// Fractional drift of channel `c` out of `k`.
    pub fn drift(&self, c: usize, k: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let t = 2.0 * c as f64 / (k - 1) as f64 - 1.0; // [-1, 1]
        self.max_drift * t
    }
}

/// Per-channel analysis result.
#[derive(Clone, Debug)]
pub struct DispersionReport {
    /// Relative Frobenius error ‖W(λ_c) − W(λ_0)‖ / ‖W(λ_0)‖ per channel.
    pub rel_err: Vec<f64>,
    /// Mean squared elementwise error per channel.
    pub mse: Vec<f64>,
}

impl DispersionReport {
    pub fn worst_rel_err(&self) -> f64 {
        self.rel_err.iter().cloned().fold(0.0, f64::max)
    }

    pub fn worst_mse(&self) -> f64 {
        self.mse.iter().cloned().fold(0.0, f64::max)
    }
}

/// Model-level WDM sweep aggregate: per-block dispersion reports folded to
/// the matrix-row metrics of the `wdm/` scenario family (worst block bounds
/// the deployment risk; the mean shows whether one pathological block or
/// the whole model carries the error).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WdmSummary {
    /// Band-edge fractional phase drift the sweep was run at.
    pub max_drift: f64,
    /// Photonic blocks analyzed.
    pub blocks: usize,
    /// Max over blocks of the worst per-channel relative transfer error.
    pub worst_rel_err: f64,
    /// Mean over blocks of the worst per-channel relative transfer error.
    pub mean_rel_err: f64,
    /// Max over blocks of the worst per-channel elementwise MSE.
    pub worst_mse: f64,
}

impl WdmSummary {
    /// Fold per-block reports (in deterministic block order) into the
    /// model-level aggregate. Sequential scalar f64 — order-stable.
    pub fn from_reports(max_drift: f64, reports: &[DispersionReport]) -> WdmSummary {
        let mut s = WdmSummary { max_drift, blocks: reports.len(), ..Default::default() };
        for r in reports {
            let worst = r.worst_rel_err();
            s.worst_rel_err = s.worst_rel_err.max(worst);
            s.mean_rel_err += worst;
            s.worst_mse = s.worst_mse.max(r.worst_mse());
        }
        if !reports.is_empty() {
            s.mean_rel_err /= reports.len() as f64;
        }
        s
    }
}

/// Realize the PTC transfer at a uniformly drifted phase response (every
/// programmed phase scaled by `1 + drift`), without disturbing the PTC.
fn transfer_at_drift(ptc: &Ptc, drift: f64) -> Mat {
    let scale = 1.0 + drift;
    let u_phases: Vec<f64> = ptc.u_mesh.phases.iter().map(|p| p * scale).collect();
    let v_phases: Vec<f64> = ptc.v_mesh.phases.iter().map(|p| p * scale).collect();
    let u = ptc.u_mesh.synthesize_with(&u_phases);
    let v = ptc.v_mesh.synthesize_with(&v_phases);
    // W = U diag(Σ) V*.
    let mut sv = v;
    for (r, &s) in ptc.sigma.iter().enumerate() {
        for x in sv.row_mut(r) {
            *x *= s;
        }
    }
    crate::linalg::matmul(&u, &sv)
}

/// Analyze dispersion-induced transfer error for a programmed PTC: each
/// WDM channel sees the whole mesh at its own drifted phase response; the
/// error is measured against the center-wavelength transfer.
pub fn analyze(ptc: &Ptc, model: DispersionModel) -> DispersionReport {
    let k = ptc.k;
    let center = transfer_at_drift(ptc, 0.0);
    let norm = center.fro_norm().max(1e-12);
    let mut rel_err = Vec::with_capacity(k);
    let mut mse = Vec::with_capacity(k);
    for c in 0..k {
        let w = transfer_at_drift(ptc, model.drift(c, k));
        let d = w.sub(&center);
        rel_err.push((d.fro_norm() / norm) as f64);
        mse.push((d.fro_norm_sq() / (k * k) as f32) as f64);
    }
    DispersionReport { rel_err, mse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::photonics::unitary::num_phases;
    use crate::photonics::NoiseModel;
    use crate::util::Rng;

    fn programmed_ptc(seed: u64) -> Ptc {
        let mut rng = Rng::new(seed);
        let mut ptc = Ptc::new(9, NoiseModel::IDEAL, &mut rng);
        let phases: Vec<f64> =
            (0..num_phases(9)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
        ptc.set_phases(crate::photonics::ptc::Which::U, &phases);
        let phases2: Vec<f64> =
            (0..num_phases(9)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
        ptc.set_phases(crate::photonics::ptc::Which::V, &phases2);
        let sigma: Vec<f32> = (0..9).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        ptc.set_sigma(&sigma);
        ptc
    }

    #[test]
    fn dispersion_negligible_vs_sampling_noise() {
        // Appendix G.3's actual argument: dispersion-induced transfer error
        // is small compared to the gradient-approximation error the sparse
        // sampling already injects (normalized distance ~0.3-1.5, Fig. 8),
        // so training absorbs it. Our uniform phase-scaling model is
        // *pessimistic* (it drifts the full programmed phase, not just the
        // residual differential response the paper models at 0.5% error);
        // even so the worst channel stays well under the sampling noise.
        let ptc = programmed_ptc(71);
        let r = analyze(&ptc, DispersionModel::PAPER);
        assert!(
            r.worst_rel_err() < 0.5,
            "dispersion error should be below sampling-noise scale: {}",
            r.worst_rel_err()
        );
        assert!(r.worst_rel_err() > 0.0, "edges must drift at all");
        // At the calibrated-residual scale (0.1% drift) the paper's ~0.5%
        // transfer-error figure reproduces directly.
        let residual = analyze(&ptc, DispersionModel { max_drift: 0.001 });
        assert!(
            residual.worst_rel_err() < 0.03,
            "residual-drift error should be sub-3%: {}",
            residual.worst_rel_err()
        );
    }

    #[test]
    fn center_channel_is_exact() {
        let ptc = programmed_ptc(72);
        let r = analyze(&ptc, DispersionModel::PAPER);
        // Odd k: the middle channel sits exactly at the center wavelength.
        assert!(r.rel_err[4] < 1e-9, "center channel err {}", r.rel_err[4]);
    }

    #[test]
    fn error_grows_toward_band_edges() {
        let ptc = programmed_ptc(73);
        let r = analyze(&ptc, DispersionModel::PAPER);
        // Monotone from center to either edge.
        for c in 0..4 {
            assert!(
                r.rel_err[c] >= r.rel_err[c + 1] - 1e-12,
                "left half should decrease toward center: {:?}",
                r.rel_err
            );
        }
        for c in 5..8 {
            assert!(
                r.rel_err[c] <= r.rel_err[c + 1] + 1e-12,
                "right half should increase toward edge: {:?}",
                r.rel_err
            );
        }
    }

    #[test]
    fn error_scales_with_drift() {
        let ptc = programmed_ptc(74);
        let small = analyze(&ptc, DispersionModel { max_drift: 0.005 });
        let large = analyze(&ptc, DispersionModel { max_drift: 0.04 });
        assert!(large.worst_rel_err() > 3.0 * small.worst_rel_err());
    }

    #[test]
    fn paper_setting_k9_worst_case_is_pinned() {
        // Pin the PAPER (2% band-edge drift) worst-case against the paper's
        // ~0.5%-transfer-error claim at the 0.1% calibrated-residual scale:
        // the drift→error map is first-order linear, so the 0.001-drift
        // error must sit at ~1/20 of the 0.02-drift error, and the residual
        // error itself must land in the sub-percent decade the paper quotes.
        let ptc = programmed_ptc(75);
        let paper = analyze(&ptc, DispersionModel::PAPER).worst_rel_err();
        let residual = analyze(&ptc, DispersionModel { max_drift: 0.001 }).worst_rel_err();
        assert!(paper > 0.0 && residual > 0.0);
        let ratio = residual / paper;
        assert!(
            (0.02..=0.12).contains(&ratio),
            "linear drift scaling violated: residual/paper = {ratio}"
        );
        assert!(
            (0.0005..=0.03).contains(&residual),
            "residual-scale error should be sub-percent-decade: {residual}"
        );
    }

    #[test]
    fn worst_err_is_monotone_in_max_drift() {
        let ptc = programmed_ptc(76);
        let sweep = [0.001, 0.005, 0.01, 0.02, 0.04];
        let worst: Vec<f64> = sweep
            .iter()
            .map(|&d| analyze(&ptc, DispersionModel { max_drift: d }).worst_rel_err())
            .collect();
        for w in worst.windows(2) {
            assert!(w[1] > w[0], "worst rel err must grow with max_drift: {worst:?}");
        }
    }

    #[test]
    fn k1_mesh_sees_no_dispersion() {
        // A 1×1 PTC has no phase shifters (num_phases(1) == 0): every
        // channel realizes the same transfer, so the sweep is exactly zero.
        let mut rng = Rng::new(77);
        let mut ptc = Ptc::new(1, NoiseModel::IDEAL, &mut rng);
        ptc.set_sigma(&[0.7]);
        let r = analyze(&ptc, DispersionModel::PAPER);
        assert_eq!(r.rel_err.len(), 1);
        assert_eq!(r.worst_rel_err(), 0.0);
        assert_eq!(r.worst_mse(), 0.0);
    }

    #[test]
    fn wdm_summary_folds_block_reports() {
        let a = DispersionReport { rel_err: vec![0.1, 0.3], mse: vec![0.01, 0.02] };
        let b = DispersionReport { rel_err: vec![0.5, 0.2], mse: vec![0.04, 0.03] };
        let s = WdmSummary::from_reports(0.02, &[a, b]);
        assert_eq!(s.blocks, 2);
        assert_eq!(s.max_drift, 0.02);
        assert!((s.worst_rel_err - 0.5).abs() < 1e-12);
        assert!((s.mean_rel_err - 0.4).abs() < 1e-12);
        assert!((s.worst_mse - 0.04).abs() < 1e-12);
        let empty = WdmSummary::from_reports(0.02, &[]);
        assert_eq!(empty.blocks, 0);
        assert_eq!(empty.mean_rel_err, 0.0);
    }

    #[test]
    fn drift_is_symmetric_and_bounded() {
        let m = DispersionModel { max_drift: 0.02 };
        assert!((m.drift(0, 9) + 0.02).abs() < 1e-12);
        assert!((m.drift(8, 9) - 0.02).abs() < 1e-12);
        assert!(m.drift(4, 9).abs() < 1e-12);
        assert_eq!(m.drift(0, 1), 0.0);
    }
}
