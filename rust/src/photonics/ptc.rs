//! A single k×k photonic tensor core: W = U(Φᵁ) · diag(Σ) · V*(Φⱽ), with all
//! Appendix-A.3 non-idealities applied to the realized unitaries, and the
//! restricted operation set the paper's chip actually supports:
//! program phases/Σ, apply U, U*, V*, V (reciprocity), read coherent output.
//!
//! The realized (noisy) matrices are cached and invalidated on phase writes —
//! during subspace learning only Σ changes, so U/V* realization cost is paid
//! once, which mirrors the real chip where U/V* are static after mapping.

use super::noise::{DeviceInstance, NoiseModel};
use super::unitary::{abs_identity_mse, num_phases, ReckMesh};
use crate::linalg::{matmul, matmul_at_b, Mat};
use crate::util::Rng;

/// Which unitary of the PTC a phase belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Which {
    U,
    V,
}

/// A lifecycle perturbation applied to one mesh's *effective* phases at
/// realization time (robustness subsystem). The overlay acts after the
/// static non-idealities (Q, Γ, Ω, Φ_b): each effective phase becomes
/// `φ·gain + delta`, then stuck entries are forced to their frozen value.
/// Stuck entries model failed devices — re-programming cannot move them,
/// so recovery has to compensate through the *other* phases.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseOverlay {
    /// Additive phase drift per device (thermal walk + ambient).
    pub delta: Vec<f64>,
    /// Multiplicative gain per device (γ aging); 1.0 = no aging.
    pub gain: Vec<f64>,
    /// (device index, frozen phase) for stuck-at/dead devices.
    pub stuck: Vec<(usize, f64)>,
}

impl PhaseOverlay {
    /// Identity overlay for `m` devices.
    pub fn identity(m: usize) -> PhaseOverlay {
        PhaseOverlay { delta: vec![0.0; m], gain: vec![1.0; m], stuck: Vec::new() }
    }

    /// Whether the overlay perturbs anything at all.
    pub fn is_identity(&self) -> bool {
        self.stuck.is_empty()
            && self.delta.iter().all(|&d| d == 0.0)
            && self.gain.iter().all(|&g| g == 1.0)
    }

    /// Apply in place to a slice of effective phases.
    pub fn apply(&self, phases: &mut [f64]) {
        for (i, p) in phases.iter_mut().enumerate() {
            *p = *p * self.gain[i] + self.delta[i];
        }
        for &(idx, val) in &self.stuck {
            phases[idx] = val;
        }
    }

    /// Compose two overlays: `self` acts first, `later` second. Affine
    /// composition: gain = g₁·g₂, delta = d₁·g₂ + d₂; `self`'s stuck values
    /// pass through `later`'s affine map, and `later`'s stuck entries win
    /// because `apply` forces stuck values in order. The composition is a
    /// pure function of the two overlays (deterministic — the contract the
    /// robustness subsystem needs) and agrees with sequential application
    /// up to one f64 rounding. Used to layer lifecycle drift/faults on top
    /// of a static process-variation overlay.
    pub fn then(&self, later: &PhaseOverlay) -> PhaseOverlay {
        let m = self.delta.len();
        debug_assert_eq!(m, later.delta.len());
        let mut gain = vec![1.0; m];
        let mut delta = vec![0.0; m];
        for i in 0..m {
            gain[i] = self.gain[i] * later.gain[i];
            delta[i] = self.delta[i] * later.gain[i] + later.delta[i];
        }
        let mut stuck: Vec<(usize, f64)> = self
            .stuck
            .iter()
            .map(|&(idx, val)| (idx, val * later.gain[idx] + later.delta[idx]))
            .collect();
        stuck.extend(later.stuck.iter().copied());
        PhaseOverlay { delta, gain, stuck }
    }
}

/// One photonic tensor core.
#[derive(Clone, Debug)]
pub struct Ptc {
    pub k: usize,
    /// Programmed phases of the U mesh.
    pub u_mesh: ReckMesh,
    /// Programmed phases of the V* mesh (parametrizes V* directly).
    pub v_mesh: ReckMesh,
    /// Programmed singular values (signed; the hardware realizes |σ|·cos-coded
    /// attenuation with the sign folded into a π phase).
    pub sigma: Vec<f32>,
    /// Attenuator full-scale max|Σ|.
    pub sigma_scale: f32,
    pub noise: NoiseModel,
    u_dev: DeviceInstance,
    v_dev: DeviceInstance,
    u_real: Option<Mat>,
    v_real: Option<Mat>,
    /// Lifecycle overlays (drift/faults) applied at realization time.
    u_overlay: Option<PhaseOverlay>,
    v_overlay: Option<PhaseOverlay>,
    /// Scratch for effective-phase realization.
    scratch: Vec<f64>,
}

impl Ptc {
    /// Fabricate a PTC: programmed phases start at zero, but the sampled
    /// device instance (γ, Φ_b) makes the *realized* initial state unknown —
    /// exactly the post-manufacturing situation IC must fix (§3.2).
    pub fn new(k: usize, noise: NoiseModel, rng: &mut Rng) -> Ptc {
        let m = num_phases(k);
        Ptc {
            k,
            u_mesh: ReckMesh::identity(k),
            v_mesh: ReckMesh::identity(k),
            sigma: vec![1.0; k],
            sigma_scale: 1.0,
            noise,
            u_dev: DeviceInstance::sample(m, &noise, rng),
            v_dev: DeviceInstance::sample(m, &noise, rng),
            u_real: None,
            v_real: None,
            u_overlay: None,
            v_overlay: None,
            scratch: Vec::with_capacity(m),
        }
    }

    /// Install (or clear) lifecycle overlays for both meshes and invalidate
    /// the realization caches. `None` restores the pristine device.
    pub fn set_overlays(&mut self, u: Option<PhaseOverlay>, v: Option<PhaseOverlay>) {
        self.u_overlay = u;
        self.v_overlay = v;
        self.u_real = None;
        self.v_real = None;
    }

    /// Currently installed overlays, if any.
    pub fn overlays(&self) -> (Option<&PhaseOverlay>, Option<&PhaseOverlay>) {
        (self.u_overlay.as_ref(), self.v_overlay.as_ref())
    }

    /// Number of programmable phases (both meshes): k(k−1).
    pub fn n_phases(&self) -> usize {
        2 * num_phases(self.k)
    }

    /// Read a programmed phase.
    pub fn phase(&self, which: Which, idx: usize) -> f64 {
        match which {
            Which::U => self.u_mesh.phases[idx],
            Which::V => self.v_mesh.phases[idx],
        }
    }

    /// Write a programmed phase (invalidates the realization cache).
    pub fn set_phase(&mut self, which: Which, idx: usize, val: f64) {
        match which {
            Which::U => {
                self.u_mesh.phases[idx] = val;
                self.u_real = None;
            }
            Which::V => {
                self.v_mesh.phases[idx] = val;
                self.v_real = None;
            }
        }
    }

    /// Program a whole mesh's phases at once.
    pub fn set_phases(&mut self, which: Which, vals: &[f64]) {
        match which {
            Which::U => {
                self.u_mesh.phases.copy_from_slice(vals);
                self.u_real = None;
            }
            Which::V => {
                self.v_mesh.phases.copy_from_slice(vals);
                self.v_real = None;
            }
        }
    }

    /// Program Σ (values are clamped to the attenuator full-scale and
    /// quantized at `sigma_bits`).
    pub fn set_sigma(&mut self, sigma: &[f32]) {
        assert_eq!(sigma.len(), self.k);
        let fs = self.sigma_scale;
        for (dst, &s) in self.sigma.iter_mut().zip(sigma) {
            *dst = quantize_sigma(s.clamp(-fs, fs), fs, self.noise.sigma_bits);
        }
    }

    /// Grow the attenuator full-scale (re-quantizes nothing retroactively;
    /// called by mapping when a block needs a larger dynamic range).
    pub fn set_sigma_scale(&mut self, scale: f32) {
        self.sigma_scale = scale.max(1e-6);
    }

    /// The realized (noisy) U matrix.
    pub fn realized_u(&mut self) -> &Mat {
        if self.u_real.is_none() {
            self.u_dev.effective_phases(&self.u_mesh.phases, &self.noise, &mut self.scratch);
            // Lifecycle overlay: analog drift/faults act *after* quantization
            // and the static non-idealities, on the effective phases.
            if let Some(ov) = &self.u_overlay {
                ov.apply(&mut self.scratch);
            }
            self.u_real = Some(self.u_mesh.synthesize_with(&self.scratch.clone()));
        }
        self.u_real.as_ref().unwrap()
    }

    /// The realized (noisy) V* matrix.
    pub fn realized_v(&mut self) -> &Mat {
        if self.v_real.is_none() {
            self.v_dev.effective_phases(&self.v_mesh.phases, &self.noise, &mut self.scratch);
            if let Some(ov) = &self.v_overlay {
                ov.apply(&mut self.scratch);
            }
            self.v_real = Some(self.v_mesh.synthesize_with(&self.scratch.clone()));
        }
        self.v_real.as_ref().unwrap()
    }

    /// Realize both unitaries if needed (the batch-realization entry point —
    /// `PtcMesh` fans this out across the pool, one task per block).
    pub fn ensure_realized(&mut self) {
        if self.u_real.is_none() {
            self.realized_u();
        }
        if self.v_real.is_none() {
            self.realized_v();
        }
    }

    /// Realize both unitaries and return them together (hot-path helper:
    /// one `&mut` call yielding both borrows for Eq. 5).
    pub fn realized_uv(&mut self) -> (&Mat, &Mat) {
        self.ensure_realized();
        (self.u_real.as_ref().unwrap(), self.v_real.as_ref().unwrap())
    }

    /// Realized full transfer W̃ = U · diag(Σ) · V*.
    pub fn realized_matrix(&mut self) -> Mat {
        self.ensure_realized();
        let u = self.u_real.as_ref().unwrap();
        let v = self.v_real.as_ref().unwrap();
        // Σ·V* scaled row-by-row without cloning V* (§Perf: this runs once
        // per block per cache refill, inside the pooled batch realization).
        let mut sv = Mat::zeros(self.k, self.k);
        for (r, &s) in self.sigma.iter().enumerate() {
            let src = v.row(r);
            let dst = sv.row_mut(r);
            for (d, &x) in dst.iter_mut().zip(src) {
                *d = s * x;
            }
        }
        matmul(u, &sv)
    }

    /// Coherent forward: Y = U Σ V* X for a k×B input panel.
    pub fn forward(&mut self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.k);
        let w = self.realized_matrix();
        matmul(&w, x)
    }

    /// Reciprocal op: apply Uᵀ (= U* in the real-valued mesh) to a panel —
    /// the "shine adjoint light from the output side" primitive of Eq. 5.
    pub fn apply_ut(&mut self, y: &Mat) -> Mat {
        assert_eq!(y.rows, self.k);
        matmul_at_b(self.realized_u(), y)
    }

    /// Apply V* to a panel (the input-side projection of Eq. 5).
    pub fn apply_v(&mut self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.k);
        matmul(self.realized_v(), x)
    }

    /// Optimal singular-value projection (Claim 1, Eq. 4):
    /// Σ_opt = diag(Uᵀ W V) evaluated with the *realized* (noisy) unitaries,
    /// i.e. exactly what the reciprocal chip measures. Writes Σ in place and
    /// returns the projected values.
    pub fn osp(&mut self, target: &Mat) -> Vec<f32> {
        assert_eq!((target.rows, target.cols), (self.k, self.k));
        let v = self.realized_v().clone();
        let u = self.realized_u().clone();
        let k = self.k;
        let mut sig = vec![0.0f32; k];
        for (i, si) in sig.iter_mut().enumerate() {
            // σᵢ = uᵢᵀ · W · v*ᵢ where uᵢ = column i of U, v*ᵢ = row i of V*.
            let mut acc = 0.0f32;
            for a in 0..k {
                let ua = u[(a, i)];
                if ua == 0.0 {
                    continue;
                }
                let wrow = target.row(a);
                let vrow = v.row(i);
                let mut dot = 0.0f32;
                for b in 0..k {
                    dot += wrow[b] * vrow[b];
                }
                acc += ua * dot;
            }
            *si = acc;
        }
        // Grow the full-scale if the projection exceeds it, then program.
        let maxabs = sig.iter().fold(0.0f32, |m, s| m.max(s.abs()));
        if maxabs > self.sigma_scale {
            self.set_sigma_scale(maxabs);
        }
        self.set_sigma(&sig);
        self.sigma.clone()
    }

    /// IC quality metrics: (MSEᵁ, MSEⱽ) against the |·| identity (§3.2).
    pub fn identity_mse(&mut self) -> (f64, f64) {
        let mu = abs_identity_mse(&self.realized_u().clone());
        let mv = abs_identity_mse(&self.realized_v().clone());
        (mu, mv)
    }

    /// Regression error ‖W̃ − W‖² for parallel mapping.
    pub fn mapping_loss(&mut self, target: &Mat) -> f64 {
        self.realized_matrix().sub(target).fro_norm_sq() as f64
    }
}

/// Quantize a Σ value at b bits over [-full_scale, full_scale].
pub fn quantize_sigma(s: f32, full_scale: f32, bits: Option<u32>) -> f32 {
    match bits {
        None => s,
        Some(b) => {
            let levels = ((1u64 << b) - 1) as f32;
            let step = 2.0 * full_scale / levels;
            (s / step).round() * step
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::orthogonality_error;
    use crate::util::prop::assert_close;

    #[test]
    fn ideal_ptc_starts_identity() {
        let mut rng = Rng::new(1);
        let mut ptc = Ptc::new(5, NoiseModel::IDEAL, &mut rng);
        assert_close(&ptc.realized_u().clone().data, &Mat::eye(5).data, 1e-6, 1e-6).unwrap();
        let w = ptc.realized_matrix();
        assert_close(&w.data, &Mat::eye(5).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn noisy_ptc_starts_scrambled_but_unitary() {
        let mut rng = Rng::new(2);
        let mut ptc = Ptc::new(9, NoiseModel::PAPER, &mut rng);
        let u = ptc.realized_u().clone();
        // Phase bias makes it far from identity...
        assert!(abs_identity_mse(&u) > 1e-2);
        // ...but it is still a (noisy) rotation product: orthogonal.
        assert!(orthogonality_error(&u) < 1e-4);
    }

    #[test]
    fn cache_invalidation_on_phase_write() {
        let mut rng = Rng::new(3);
        let mut ptc = Ptc::new(4, NoiseModel::IDEAL, &mut rng);
        let before = ptc.realized_u().clone();
        ptc.set_phase(Which::U, 0, 0.5);
        let after = ptc.realized_u().clone();
        assert!(before.sub(&after).fro_norm() > 1e-3);
        // V untouched.
        assert_close(&ptc.realized_v().clone().data, &Mat::eye(4).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn forward_matches_matrix() {
        let mut rng = Rng::new(4);
        let mut ptc = Ptc::new(6, NoiseModel::PAPER, &mut rng);
        ptc.set_sigma(&[0.9, -0.5, 0.3, 0.1, -0.2, 0.7]);
        let x = Mat::randn(6, 3, 1.0, &mut rng);
        let y = ptc.forward(&x);
        let w = ptc.realized_matrix();
        assert_close(&y.data, &matmul(&w, &x).data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn osp_is_optimal_given_unitaries() {
        // After OSP, no other Σ gives lower ‖UΣV* − W‖ (check via perturbation).
        let mut rng = Rng::new(5);
        let mut ptc = Ptc::new(5, NoiseModel::IDEAL, &mut rng);
        // Random unitaries via random phases.
        let rand_phases: Vec<f64> =
            (0..num_phases(5)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
        ptc.set_phases(Which::U, &rand_phases);
        let rand_phases2: Vec<f64> =
            (0..num_phases(5)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
        ptc.set_phases(Which::V, &rand_phases2);
        let target = Mat::randn(5, 5, 1.0, &mut rng);
        ptc.osp(&target);
        let base = ptc.mapping_loss(&target);
        for i in 0..5 {
            for delta in [-0.05f32, 0.05] {
                let mut s = ptc.sigma.clone();
                s[i] += delta;
                let mut alt = ptc.clone();
                alt.sigma = s; // bypass quantization to test pure optimality
                assert!(
                    alt.mapping_loss(&target) >= base - 1e-6,
                    "perturbed sigma beat OSP at i={i}"
                );
            }
        }
    }

    #[test]
    fn osp_exact_recovery_for_svd_triple() {
        // If W = U Σ V* exactly (ideal device), OSP recovers Σ.
        let mut rng = Rng::new(6);
        let mut ptc = Ptc::new(4, NoiseModel::IDEAL, &mut rng);
        let phases: Vec<f64> = (0..num_phases(4)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
        ptc.set_phases(Which::U, &phases);
        let phases2: Vec<f64> = (0..num_phases(4)).map(|_| rng.uniform_range(0.0, 6.28)).collect();
        ptc.set_phases(Which::V, &phases2);
        let true_sigma = [1.2f32, -0.4, 0.8, 0.05];
        ptc.set_sigma_scale(2.0);
        ptc.set_sigma(&true_sigma);
        let w = ptc.realized_matrix();
        // Scramble sigma, then OSP back.
        ptc.set_sigma(&[0.0; 4]);
        let rec = ptc.osp(&w);
        assert_close(&rec, &true_sigma, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn sigma_quantization_applies() {
        let mut rng = Rng::new(7);
        let noise = NoiseModel { sigma_bits: Some(4), ..NoiseModel::IDEAL };
        let mut ptc = Ptc::new(3, noise, &mut rng);
        ptc.set_sigma(&[0.33, -0.71, 0.99]);
        let step = 2.0 / ((1u64 << 4) - 1) as f32;
        for &s in &ptc.sigma {
            assert!((s / step - (s / step).round()).abs() < 1e-5, "{s} not on grid");
        }
    }

    #[test]
    fn identity_overlay_is_bitwise_neutral() {
        let mut rng = Rng::new(9);
        let mut ptc = Ptc::new(5, NoiseModel::PAPER, &mut rng);
        let before = ptc.realized_matrix();
        let m = num_phases(5);
        ptc.set_overlays(Some(PhaseOverlay::identity(m)), Some(PhaseOverlay::identity(m)));
        let with_identity = ptc.realized_matrix();
        assert_close(&before.data, &with_identity.data, 0.0, 0.0).unwrap();
        ptc.set_overlays(None, None);
        let cleared = ptc.realized_matrix();
        assert_close(&before.data, &cleared.data, 0.0, 0.0).unwrap();
    }

    #[test]
    fn overlay_composition_matches_sequential_apply() {
        let mut rng = Rng::new(11);
        let m = 12;
        let mut a = PhaseOverlay::identity(m);
        let mut b = PhaseOverlay::identity(m);
        for i in 0..m {
            a.gain[i] = 1.0 + 0.1 * rng.normal();
            a.delta[i] = 0.05 * rng.normal();
            b.gain[i] = 1.0 + 0.1 * rng.normal();
            b.delta[i] = 0.05 * rng.normal();
        }
        a.stuck.push((3, 0.9));
        b.stuck.push((7, -0.4));
        // b also re-freezes an index a froze: later overlay must win.
        b.stuck.push((3, 0.1));

        let phases: Vec<f64> = (0..m).map(|i| 0.2 * i as f64 - 1.0).collect();
        let mut sequential = phases.clone();
        a.apply(&mut sequential);
        b.apply(&mut sequential);
        let mut composed = phases;
        a.then(&b).apply(&mut composed);
        for (i, (s, c)) in sequential.iter().zip(&composed).enumerate() {
            // Affine composition agrees with sequential apply up to one
            // f64 rounding; stuck indices are forced, hence exact.
            assert!((s - c).abs() <= 1e-12, "index {i}: sequential {s} vs composed {c}");
        }
        assert_eq!(sequential[3], composed[3], "later stuck entry must win exactly");
        assert_eq!(sequential[7], composed[7]);

        // Composing with identity on either side is a no-op.
        let id = PhaseOverlay::identity(m);
        let mut left = vec![0.3; m];
        let mut right = vec![0.3; m];
        id.then(&a).apply(&mut left);
        a.then(&id).apply(&mut right);
        let mut want = vec![0.3; m];
        a.apply(&mut want);
        assert_eq!(left, want);
        assert_eq!(right, want);
    }

    #[test]
    fn overlay_perturbs_and_stuck_resists_programming() {
        let mut rng = Rng::new(10);
        let mut ptc = Ptc::new(4, NoiseModel::IDEAL, &mut rng);
        let m = num_phases(4);
        let mut ov = PhaseOverlay::identity(m);
        ov.delta[0] = 0.3;
        assert!(!ov.is_identity());
        ptc.set_overlays(Some(ov), None);
        let drifted = ptc.realized_u().clone();
        assert!(drifted.sub(&Mat::eye(4)).fro_norm() > 1e-3, "drift had no effect");

        // A stuck device ignores re-programming: changing the programmed
        // phase of the stuck index leaves the realized matrix unchanged.
        let mut stuck_ov = PhaseOverlay::identity(m);
        stuck_ov.stuck.push((1, 0.7));
        ptc.set_overlays(Some(stuck_ov), None);
        let a = ptc.realized_u().clone();
        ptc.set_phase(Which::U, 1, 2.0);
        let b = ptc.realized_u().clone();
        assert_close(&a.data, &b.data, 0.0, 0.0).unwrap();
        // ...while a non-stuck phase still responds.
        ptc.set_phase(Which::U, 0, 1.0);
        let c = ptc.realized_u().clone();
        assert!(b.sub(&c).fro_norm() > 1e-3);
    }

    #[test]
    fn reciprocal_ops_are_transposes() {
        let mut rng = Rng::new(8);
        let mut ptc = Ptc::new(5, NoiseModel::PAPER, &mut rng);
        let y = Mat::randn(5, 2, 1.0, &mut rng);
        let ut_y = ptc.apply_ut(&y);
        let u = ptc.realized_u().clone();
        assert_close(&ut_y.data, &matmul(&u.t(), &y).data, 1e-5, 1e-5).unwrap();
        let vx = ptc.apply_v(&y);
        let v = ptc.realized_v().clone();
        assert_close(&vx.data, &matmul(&v, &y).data, 1e-5, 1e-5).unwrap();
    }
}
