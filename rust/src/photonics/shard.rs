//! Sharded multi-chiplet mesh execution: one logical P×Q blocked mesh
//! partitioned across several independently owned `PtcMesh` shards — the
//! multi-core photonic fabrics of the related hardware work (the
//! butterfly-style chip of arXiv:2111.06705, the single-chip trained system
//! of arXiv:2208.01623), where a layer too large for one chiplet is split
//! over many small meshes with an electronic reduction network between them.
//!
//! Design contract — **sharding never changes a single bit**:
//!
//! * Construction carves the shards out of one logical `PtcMesh` built with
//!   the exact same RNG stream as the unsharded engine, so every PTC's
//!   device state is bit-identical to its unsharded twin at any shard count.
//! * Every hot path (forward, packed forward, feedback, σ-grad) walks the
//!   *logical* block grid in the exact order the unsharded mesh does and
//!   issues the identical kernel-call sequence — the owner table only
//!   redirects each block lookup to (shard, local index). Parallel work is
//!   partitioned by output region (row strips / column strips / column
//!   panels), never by shard, so no cross-shard partial sums are ever
//!   re-associated.
//!
//! Together those give: sharded == unsharded bitwise at every shard count,
//! every thread count, within each SIMD dispatch level — pinned by
//! `tests/shard_equivalence.rs`.
//!
//! What *does* change is the hardware accounting: each shard's `MeshStats`
//! is charged for its own blocks (energy) and its own sub-grid reduction
//! depth (latency), so total energy closes exactly against the unsharded
//! mesh while total latency grows with the extra cross-shard reductions —
//! the quantity a multi-chiplet placement study actually wants to see.

use super::mesh::{gather_cols_padded, padded_panel, MeshStats, PtcMesh};
use super::noise::NoiseModel;
use super::ptc::Ptc;
use crate::linalg::{gemm_acc_slices, gemm_at_b_acc_band, sigma_grad_block_slices, Mat};
use crate::util::json::Json;
use crate::util::pool::{self, Scratch, SendPtr, ThreadPool};
use crate::util::Rng;

/// How the logical block grid is placed onto shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Split the P block rows across shards (each shard spans all Q).
    Row,
    /// Split the Q block columns across shards (each shard spans all P).
    Col,
    /// Near-square factorization of the shard count over (P, Q).
    Grid,
}

impl ShardPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::Row => "row",
            ShardPolicy::Col => "col",
            ShardPolicy::Grid => "grid",
        }
    }

    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s {
            "row" => Some(ShardPolicy::Row),
            "col" => Some(ShardPolicy::Col),
            "grid" => Some(ShardPolicy::Grid),
            _ => None,
        }
    }
}

/// Per-job sharding configuration (absent = classic single-mesh engine).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardingConfig {
    /// Requested shard count (clamped to the block grid at construction).
    pub shards: usize,
    pub policy: ShardPolicy,
}

impl ShardingConfig {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("shards", Json::Num(self.shards as f64))
            .set("policy", Json::Str(self.policy.name().to_string()));
        o
    }

    /// Parse back; `None` on a malformed object (like
    /// `RobustnessConfig::from_json`).
    pub fn from_json(j: &Json) -> Option<ShardingConfig> {
        j.as_obj()?;
        let shards = j.get("shards")?.as_f64()? as usize;
        let policy = ShardPolicy::parse(j.get("policy")?.as_str()?)?;
        Some(ShardingConfig { shards, policy })
    }
}

/// One chiplet: a sub-mesh plus its offset in the logical block grid.
#[derive(Clone, Debug)]
pub struct Shard {
    pub mesh: PtcMesh,
    /// First logical block row owned by this shard.
    pub p0: usize,
    /// First logical block column owned by this shard.
    pub q0: usize,
}

/// A logical `rows`×`cols` mesh executed across several `PtcMesh` shards.
#[derive(Clone, Debug)]
pub struct ShardedMesh {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    /// Logical block grid: ceil(rows/k) × ceil(cols/k).
    pub p: usize,
    pub q: usize,
    pub policy: ShardPolicy,
    pub shards: Vec<Shard>,
    /// Logical block index → (shard index, shard-local block index).
    owners: Vec<(u32, u32)>,
}

/// Contiguous even split of `n` items into `parts` non-empty ranges.
fn ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    (0..parts).map(|i| (i * n / parts, (i + 1) * n / parts)).collect()
}

/// Near-square factorization gr×gc = s with gr ≤ gc.
fn grid_dims(s: usize) -> (usize, usize) {
    let mut gr = (s as f64).sqrt() as usize;
    gr = gr.max(1);
    while gr > 1 && s % gr != 0 {
        gr -= 1;
    }
    (gr, s / gr)
}

impl ShardedMesh {
    /// Build a sharded mesh consuming the RNG exactly like
    /// `PtcMesh::new(rows, cols, k, noise, rng)` — the shards are carved out
    /// of that logical mesh, so device state is bit-identical to the
    /// unsharded engine regardless of shard count or policy.
    pub fn new(
        rows: usize,
        cols: usize,
        k: usize,
        noise: NoiseModel,
        shards: usize,
        policy: ShardPolicy,
        rng: &mut Rng,
    ) -> ShardedMesh {
        let mesh = PtcMesh::new(rows, cols, k, noise, rng);
        ShardedMesh::from_mesh(mesh, shards, policy)
    }

    /// Partition an existing logical mesh into shards (PTCs move, nothing is
    /// re-realized). The requested shard count is clamped to the block grid;
    /// `shards == 1` yields a single shard covering the whole grid.
    pub fn from_mesh(mut mesh: PtcMesh, shards: usize, policy: ShardPolicy) -> ShardedMesh {
        let (rows, cols, k, p, q) = (mesh.rows, mesh.cols, mesh.k, mesh.p, mesh.q);
        let noise = mesh.noise;
        let want = shards.max(1);
        let (prs, qrs) = match policy {
            ShardPolicy::Row => (ranges(p, want.min(p)), vec![(0, q)]),
            ShardPolicy::Col => (vec![(0, p)], ranges(q, want.min(q))),
            ShardPolicy::Grid => {
                let (gr, gc) = grid_dims(want);
                (ranges(p, gr.min(p)), ranges(q, gc.min(q)))
            }
        };
        let mut slots: Vec<Option<Ptc>> =
            std::mem::take(&mut mesh.ptcs).into_iter().map(Some).collect();
        let mut out = Vec::with_capacity(prs.len() * qrs.len());
        let mut owners = vec![(0u32, 0u32); p * q];
        for &(pa, pb) in &prs {
            for &(qa, qb) in &qrs {
                let si = out.len();
                let mut ptcs = Vec::with_capacity((pb - pa) * (qb - qa));
                for pi in pa..pb {
                    for qi in qa..qb {
                        let bi = pi * q + qi;
                        owners[bi] = (si as u32, ptcs.len() as u32);
                        ptcs.push(slots[bi].take().expect("block owned twice"));
                    }
                }
                let srows = (pb * k).min(rows) - pa * k;
                let scols = (qb * k).min(cols) - qa * k;
                out.push(Shard {
                    mesh: PtcMesh::from_ptcs(srows, scols, k, ptcs, noise),
                    p0: pa,
                    q0: qa,
                });
            }
        }
        ShardedMesh { rows, cols, k, p, q, policy, shards: out, owners }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// (shard index, shard-local block index) owning logical block `bi`.
    #[inline]
    pub fn owner(&self, bi: usize) -> (usize, usize) {
        let (s, l) = self.owners[bi];
        (s as usize, l as usize)
    }

    /// Logical block index of shard `si`'s local block `lbi` — the identity
    /// that keys per-block ZO RNG streams so per-shard PM mapping and IC
    /// calibration are bitwise-equal to the unsharded stages.
    #[inline]
    pub fn logical_index(&self, si: usize, lbi: usize) -> usize {
        let s = &self.shards[si];
        let (lp, lq) = (lbi / s.mesh.q, lbi % s.mesh.q);
        (s.p0 + lp) * self.q + (s.q0 + lq)
    }

    /// Visit every PTC in logical block order (checkpoint serialization,
    /// phase-space baselines) — the same order `PtcMesh.ptcs` has, so state
    /// files are interchangeable with the unsharded engine.
    pub fn for_each_ptc_logical<F: FnMut(&Ptc)>(&self, mut f: F) {
        for bi in 0..self.p * self.q {
            let (si, lbi) = self.owner(bi);
            f(&self.shards[si].mesh.ptcs[lbi]);
        }
    }

    /// Mutable logical-order visitor; invalidates every shard's cache.
    pub fn for_each_ptc_logical_mut<F: FnMut(&mut Ptc)>(&mut self, mut f: F) {
        for bi in 0..self.p * self.q {
            let (si, lbi) = self.owner(bi);
            f(&mut self.shards[si].mesh.ptcs[lbi]);
        }
        self.invalidate();
    }

    /// Mutable access to one logical block's PTC, invalidating only the
    /// owning shard — the scoped-repair entry the lifecycle watchdog uses.
    pub fn ptc_logical_mut(&mut self, bi: usize) -> &mut Ptc {
        let (si, lbi) = self.owner(bi);
        self.shards[si].mesh.invalidate();
        &mut self.shards[si].mesh.ptcs[lbi]
    }

    /// Extract shard `si`'s [p_s][q_s] slice of a logical [p][q] mask.
    pub fn local_mask_pq(&self, si: usize, mask: &[bool]) -> Vec<bool> {
        let s = &self.shards[si];
        let (ps, qs) = (s.mesh.p, s.mesh.q);
        let mut local = Vec::with_capacity(ps * qs);
        for lp in 0..ps {
            for lq in 0..qs {
                local.push(mask[(s.p0 + lp) * self.q + (s.q0 + lq)]);
            }
        }
        local
    }

    /// Write shard `si`'s [p_s][q_s] mask slice back into the logical mask.
    pub fn store_local_mask_pq(&self, si: usize, local: &[bool], mask: &mut [bool]) {
        let s = &self.shards[si];
        let (ps, qs) = (s.mesh.p, s.mesh.q);
        assert_eq!(local.len(), ps * qs);
        for lp in 0..ps {
            for lq in 0..qs {
                mask[(s.p0 + lp) * self.q + (s.q0 + lq)] = local[lp * qs + lq];
            }
        }
    }

    /// Program every shard from one logical dense weight — bitwise the same
    /// per-block SVD + Reck decomposition as `PtcMesh::program_from_dense`.
    pub fn program_from_dense(&mut self, w: &Mat) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols), "program_from_dense shape");
        let k = self.k;
        for s in self.shards.iter_mut() {
            let sub = sub_matrix(w, s.p0 * k, s.mesh.rows, s.q0 * k, s.mesh.cols);
            s.mesh.program_from_dense(&sub);
        }
    }

    /// The realized dense weight W̃, assembled across shards.
    pub fn to_dense(&mut self) -> Mat {
        let k = self.k;
        let mut w = Mat::zeros(self.rows, self.cols);
        for s in self.shards.iter_mut() {
            s.mesh.ensure_cache(pool::global());
        }
        for s in &self.shards {
            let cache = s.mesh.cached_blocks();
            for lp in 0..s.mesh.p {
                for lq in 0..s.mesh.q {
                    w.set_block((s.p0 + lp) * k, (s.q0 + lq) * k, &cache[lp * s.mesh.q + lq]);
                }
            }
        }
        w
    }

    /// Relative realized error against a dense target (see
    /// `PtcMesh::rel_error`).
    pub fn rel_error(&mut self, target: &Mat) -> f32 {
        self.to_dense().rel_dist_sq(target)
    }

    /// Invalidate every shard's realized-weight cache.
    pub fn invalidate(&mut self) {
        for s in self.shards.iter_mut() {
            s.mesh.invalidate();
        }
    }

    /// Aggregate hardware-op statistics: the sum of every shard's counters.
    /// Energy closes exactly against the unsharded mesh (each block is
    /// charged once, by its owner); steps are ≥ the unsharded mesh's (each
    /// shard reduces over its own sub-grid, then the cross-shard reduction
    /// adds sequential depth).
    pub fn stats(&self) -> MeshStats {
        let mut acc = MeshStats::default();
        for s in &self.shards {
            acc.add(&s.mesh.stats);
        }
        acc
    }

    /// Reset every shard's statistics.
    pub fn reset_stats(&mut self) {
        for s in self.shards.iter_mut() {
            s.mesh.stats = MeshStats::default();
        }
    }

    /// Number of trainable subspace parameters (logical P·Q·k).
    pub fn n_sigma(&self) -> usize {
        self.p * self.q * self.k
    }

    /// Total number of MZI phases across all shards.
    pub fn n_phases(&self) -> usize {
        self.shards.iter().map(|s| s.mesh.n_phases()).sum()
    }

    /// Per-block squared Frobenius norms in *logical* block order (the
    /// btopk feedback sampler indexes this [p][q]).
    pub fn block_norms_sq(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.p * self.q);
        self.for_each_ptc_logical(|ptc| v.push(ptc.sigma.iter().map(|s| s * s).sum()));
        v
    }

    /// Flattened Σ view [p*q*k] in logical block order — same layout as
    /// `PtcMesh::sigma_flat`, so optimizer state is shard-count-invariant.
    pub fn sigma_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.n_sigma());
        self.for_each_ptc_logical(|ptc| v.extend_from_slice(&ptc.sigma));
        v
    }

    /// Program Σ from a flattened logical-order vector (inverse of
    /// `sigma_flat`), with the same attenuator rescale rule as
    /// `PtcMesh::set_sigma_flat`.
    pub fn set_sigma_flat(&mut self, sigma: &[f32]) {
        assert_eq!(sigma.len(), self.n_sigma());
        let k = self.k;
        let mut bi = 0usize;
        self.for_each_ptc_logical_mut(|ptc| {
            let blk = &sigma[bi * k..(bi + 1) * k];
            let maxabs = blk.iter().fold(0.0f32, |m, s| m.max(s.abs()));
            if maxabs > ptc.sigma_scale {
                ptc.set_sigma_scale(maxabs);
            }
            ptc.set_sigma(blk);
            bi += 1;
        });
    }

    /// Blocked forward Y = W̃ · X for X of shape [cols, B].
    pub fn forward(&mut self, x: &Mat) -> Mat {
        self.forward_masked(x, None, 1.0)
    }

    /// Forward with an optional logical [p][q] block keep-mask.
    pub fn forward_masked(&mut self, x: &Mat, block_keep: Option<&[bool]>, scale: f32) -> Mat {
        self.forward_masked_on(pool::global(), x, block_keep, scale)
    }

    /// Sharded `forward_masked` on an explicit pool. The strip loop is the
    /// unsharded one verbatim — logical pi strips in parallel, qi ascending
    /// inside each strip — with each block's realized matrix fetched from
    /// its owning shard, so the kernel-call sequence (and therefore every
    /// bit of Y) matches `PtcMesh::forward_masked_on`.
    pub fn forward_masked_on(
        &mut self,
        pool: &ThreadPool,
        x: &Mat,
        block_keep: Option<&[bool]>,
        scale: f32,
    ) -> Mat {
        assert_eq!(x.rows, self.cols, "sharded forward input rows");
        let (k, p, q, b) = (self.k, self.p, self.q, x.cols);
        for s in self.shards.iter_mut() {
            s.mesh.ensure_cache(pool);
        }
        let mut y = Mat::zeros(self.rows, b);
        {
            let owners = &self.owners;
            let caches: Vec<&[Mat]> =
                self.shards.iter().map(|s| s.mesh.cached_blocks()).collect();
            let mut xp_store: Option<Scratch> = None;
            let xp: &[f32] = padded_panel(x, q * k, &mut xp_store);
            let mut yp_store: Option<Scratch> = None;
            let ypp = if p * k == self.rows {
                SendPtr(y.data.as_mut_ptr())
            } else {
                SendPtr(yp_store.insert(Scratch::take(p * k * b)).as_mut_ptr())
            };
            pool.parallel_for_sized(p, 2 * p * q * k * k * b, |pi| {
                // Safety: strip pi writes rows [pi·k, (pi+1)·k) only.
                let strip =
                    unsafe { std::slice::from_raw_parts_mut(ypp.0.add(pi * k * b), k * b) };
                for qi in 0..q {
                    if let Some(mask) = block_keep {
                        if !mask[pi * q + qi] {
                            continue;
                        }
                    }
                    let (si, lbi) = owners[pi * q + qi];
                    let w = &caches[si as usize][lbi as usize];
                    gemm_acc_slices(&w.data, k, k, &xp[qi * k * b..(qi + 1) * k * b], b, strip);
                }
                if scale != 1.0 {
                    for v in strip.iter_mut() {
                        *v *= scale;
                    }
                }
            });
            if let Some(yp) = &yp_store {
                y.data.copy_from_slice(&yp[..self.rows * b]);
            }
        }
        self.note_forward_stats(b, block_keep);
        y
    }

    /// Fused packed-panel forward across shards — see
    /// `PtcMesh::forward_packed_on`; the panel loop (pi then qi ascending
    /// inside each fixed-width column panel) is identical, block lookups go
    /// through the owner table.
    pub fn forward_packed_on<P>(
        &mut self,
        pool: &ThreadPool,
        total_cols: usize,
        pack: &P,
        block_keep: Option<&[bool]>,
        scale: f32,
    ) -> Mat
    where
        P: Fn(usize, usize, &mut [f32]) + Sync,
    {
        let (k, p, q) = (self.k, self.p, self.q);
        for s in self.shards.iter_mut() {
            s.mesh.ensure_cache(pool);
        }
        let mut y = Mat::zeros(self.rows, total_cols);
        {
            let owners = &self.owners;
            let caches: Vec<&[Mat]> =
                self.shards.iter().map(|s| s.mesh.cached_blocks()).collect();
            let rows = self.rows;
            let yptr = SendPtr(y.data.as_mut_ptr());
            // Same tuned width as the unsharded path — the cross-shard
            // equivalence suite pins the two paths bitwise, so they must
            // always agree on the panel partition (any shared width works).
            let panel_cols = crate::linalg::tune::panel_cols();
            let panels = total_cols.div_ceil(panel_cols);
            pool.parallel_for_sized(panels, 2 * p * q * k * k * total_cols, |ti| {
                let c0 = ti * panel_cols;
                let c1 = (c0 + panel_cols).min(total_cols);
                let wpan = c1 - c0;
                let mut xbuf = Scratch::take(q * k * wpan);
                pack(c0, c1, &mut xbuf);
                let mut ybuf = Scratch::take(p * k * wpan);
                for pi in 0..p {
                    let strip = &mut ybuf[pi * k * wpan..(pi + 1) * k * wpan];
                    for qi in 0..q {
                        if let Some(mask) = block_keep {
                            if !mask[pi * q + qi] {
                                continue;
                            }
                        }
                        let (si, lbi) = owners[pi * q + qi];
                        let w = &caches[si as usize][lbi as usize];
                        gemm_acc_slices(
                            &w.data,
                            k,
                            k,
                            &xbuf[qi * k * wpan..(qi + 1) * k * wpan],
                            wpan,
                            strip,
                        );
                    }
                    if scale != 1.0 {
                        for v in strip.iter_mut() {
                            *v *= scale;
                        }
                    }
                }
                // Safety: panel ti owns columns [c0, c1) of every row of Y.
                unsafe {
                    crate::linalg::conv::scatter_panel(yptr, total_cols, c0, wpan, rows, &ybuf)
                };
            });
        }
        self.note_forward_stats(total_cols, block_keep);
        y
    }

    /// Per-shard forward accounting: each shard is charged for its own kept
    /// blocks (energy sums exactly to the unsharded figure) and its own
    /// sub-grid accumulation depth (latency, using the
    /// `PtcMesh::note_forward_stats` formula on the shard's sub-grid).
    fn note_forward_stats(&mut self, b: usize, block_keep: Option<&[bool]>) {
        match block_keep {
            None => {
                for s in self.shards.iter_mut() {
                    s.mesh.note_forward_stats(b, None);
                }
            }
            Some(mask) => {
                for si in 0..self.shards.len() {
                    let local = self.local_mask_pq(si, mask);
                    self.shards[si].mesh.note_forward_stats(b, Some(&local));
                }
            }
        }
    }

    /// In-situ subspace gradient (Eq. 5) across shards; logical block order,
    /// identical kernel sequence to `PtcMesh::sigma_grad_on`.
    pub fn sigma_grad(
        &mut self,
        x: &Mat,
        dy: &Mat,
        col_keep: Option<&[bool]>,
        scale: f32,
    ) -> Vec<f32> {
        self.sigma_grad_on(pool::global(), x, dy, col_keep, scale)
    }

    /// `sigma_grad` on an explicit pool.
    pub fn sigma_grad_on(
        &mut self,
        pool: &ThreadPool,
        x: &Mat,
        dy: &Mat,
        col_keep: Option<&[bool]>,
        scale: f32,
    ) -> Vec<f32> {
        assert_eq!(x.rows, self.cols);
        assert_eq!(dy.rows, self.rows);
        assert_eq!(x.cols, dy.cols);
        let (k, p, q) = (self.k, self.p, self.q);
        let mut xp_store: Option<Scratch> = None;
        let mut dyp_store: Option<Scratch> = None;
        let (xp, dyp, b): (&[f32], &[f32], usize) = match col_keep {
            None => (
                padded_panel(x, q * k, &mut xp_store),
                padded_panel(dy, p * k, &mut dyp_store),
                x.cols,
            ),
            Some(mask) => {
                assert_eq!(mask.len(), x.cols);
                let kept: Vec<usize> = (0..x.cols).filter(|&c| mask[c]).collect();
                let b = kept.len();
                xp_store = Some(gather_cols_padded(x, &kept, q * k));
                dyp_store = Some(gather_cols_padded(dy, &kept, p * k));
                (
                    &xp_store.as_ref().unwrap()[..],
                    &dyp_store.as_ref().unwrap()[..],
                    b,
                )
            }
        };
        let mut grad = vec![0.0f32; p * q * k];
        {
            let gptr = SendPtr(grad.as_mut_ptr());
            let owners = &self.owners;
            let pptrs: Vec<SendPtr<Ptc>> =
                self.shards.iter_mut().map(|s| SendPtr(s.mesh.ptcs.as_mut_ptr())).collect();
            pool.parallel_for_sized(p * q, 2 * p * q * k * k * b, |bi| {
                // Safety: block bi owns exactly one PTC (the owner table is a
                // bijection) and grad[bi·k .. bi·k+k].
                let (si, lbi) = owners[bi];
                let ptc = unsafe { &mut *pptrs[si as usize].0.add(lbi as usize) };
                let g = unsafe { std::slice::from_raw_parts_mut(gptr.0.add(bi * k), k) };
                let (pi, qi) = (bi / q, bi % q);
                let (u, v) = ptc.realized_uv();
                let mut scratch = Scratch::take(2 * k * b);
                let (ut_y, vx) = scratch.split_at_mut(k * b);
                sigma_grad_block_slices(
                    u,
                    v,
                    &dyp[pi * k * b..(pi + 1) * k * b],
                    &xp[qi * k * b..(qi + 1) * k * b],
                    b,
                    scale,
                    ut_y,
                    vx,
                    g,
                );
            });
        }
        // Each shard runs its own two reciprocal passes over its own blocks.
        let groups = b.div_ceil(k).max(1) as u64;
        for s in self.shards.iter_mut() {
            let owned = (s.mesh.p * s.mesh.q) as u64;
            s.mesh.stats.grad_block_cols += 2 * owned * groups;
            s.mesh.stats.grad_steps += 2 * groups + 1;
        }
        grad
    }

    /// Masked error feedback dX = Σ W̃ᵀ dY across shards (§3.4.2);
    /// `block_keep` is the logical [q][p] mask.
    pub fn feedback(&mut self, dy: &Mat, block_keep: Option<&[bool]>, scale: f32) -> Mat {
        self.feedback_on(pool::global(), dy, block_keep, scale)
    }

    /// `feedback` on an explicit pool — logical qi strips in parallel, pi
    /// ascending inside each strip, exactly like `PtcMesh::feedback_on`.
    pub fn feedback_on(
        &mut self,
        pool: &ThreadPool,
        dy: &Mat,
        block_keep: Option<&[bool]>,
        scale: f32,
    ) -> Mat {
        assert_eq!(dy.rows, self.rows, "sharded feedback dy rows");
        let (k, p, q, b) = (self.k, self.p, self.q, dy.cols);
        for s in self.shards.iter_mut() {
            s.mesh.ensure_cache(pool);
        }
        let mut dx = Mat::zeros(self.cols, b);
        {
            let owners = &self.owners;
            let caches: Vec<&[Mat]> =
                self.shards.iter().map(|s| s.mesh.cached_blocks()).collect();
            let mut dyp_store: Option<Scratch> = None;
            let dyp: &[f32] = padded_panel(dy, p * k, &mut dyp_store);
            let mut dxp_store: Option<Scratch> = None;
            let dpp = if q * k == self.cols {
                SendPtr(dx.data.as_mut_ptr())
            } else {
                SendPtr(dxp_store.insert(Scratch::take(q * k * b)).as_mut_ptr())
            };
            pool.parallel_for_sized(q, 2 * p * q * k * k * b, |qi| {
                // Safety: strip qi writes rows [qi·k, (qi+1)·k) only.
                let strip =
                    unsafe { std::slice::from_raw_parts_mut(dpp.0.add(qi * k * b), k * b) };
                for pi in 0..p {
                    if let Some(mask) = block_keep {
                        if !mask[qi * p + pi] {
                            continue;
                        }
                    }
                    let (si, lbi) = owners[pi * q + qi];
                    let wt = &caches[si as usize][lbi as usize];
                    gemm_at_b_acc_band(
                        &wt.data,
                        k,
                        k,
                        &dyp[pi * k * b..(pi + 1) * k * b],
                        b,
                        0,
                        k,
                        strip,
                    );
                }
                if scale != 1.0 {
                    for v in strip.iter_mut() {
                        *v *= scale;
                    }
                }
            });
            if let Some(dxp) = &dxp_store {
                dx.data.copy_from_slice(&dxp[..self.cols * b]);
            }
        }
        // Per-shard accounting with the unsharded formulas on each sub-grid.
        let groups = b.div_ceil(k).max(1) as u64;
        for si in 0..self.shards.len() {
            let (sp0, sq0) = (self.shards[si].p0, self.shards[si].q0);
            let (ps, qs) = (self.shards[si].mesh.p, self.shards[si].mesh.q);
            let kept = |lqi: usize, lpi: usize| match block_keep {
                None => true,
                Some(m) => m[(sq0 + lqi) * p + (sp0 + lpi)],
            };
            let mut kept_products = 0u64;
            let mut critical = 0u64;
            for lqi in 0..qs {
                let row_kept = (0..ps).filter(|&lpi| kept(lqi, lpi)).count() as u64;
                kept_products += row_kept;
                critical = critical.max(row_kept);
            }
            let st = &mut self.shards[si].mesh.stats;
            st.feedback_block_cols += kept_products * groups;
            st.feedback_steps += groups * (1 + critical);
        }
        dx
    }
}

/// Copy a rectangular sub-matrix (fully in bounds).
fn sub_matrix(w: &Mat, r0: usize, rows: usize, c0: usize, cols: usize) -> Mat {
    let mut out = Mat::zeros(rows, cols);
    for r in 0..rows {
        out.row_mut(r).copy_from_slice(&w.row(r0 + r)[c0..c0 + cols]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;

    #[test]
    fn partition_is_a_bijection_over_every_policy() {
        let mut rng = Rng::new(11);
        for policy in [ShardPolicy::Row, ShardPolicy::Col, ShardPolicy::Grid] {
            for shards in [1, 2, 3, 4, 7] {
                let sm =
                    ShardedMesh::new(22, 17, 4, NoiseModel::PAPER, shards, policy, &mut rng);
                let mut seen = vec![false; sm.p * sm.q];
                for bi in 0..sm.p * sm.q {
                    let (si, lbi) = sm.owner(bi);
                    assert!(si < sm.num_shards());
                    assert!(lbi < sm.shards[si].mesh.ptcs.len());
                    assert_eq!(sm.logical_index(si, lbi), bi);
                    assert!(!seen[bi]);
                    seen[bi] = true;
                }
                let total: usize = sm.shards.iter().map(|s| s.mesh.ptcs.len()).collect::<Vec<_>>().iter().sum();
                assert_eq!(total, sm.p * sm.q, "{policy:?}/{shards}");
            }
        }
    }

    #[test]
    fn construction_matches_unsharded_device_state() {
        // Same RNG stream in, bit-identical PTC sigma/dense weight out —
        // regardless of shard count.
        let w = {
            let mut rng = Rng::new(5);
            Mat::randn(18, 14, 0.5, &mut rng)
        };
        let mut rng1 = Rng::new(21);
        let mut mesh = PtcMesh::new(18, 14, 4, NoiseModel::PAPER, &mut rng1);
        mesh.program_from_dense(&w);
        let mut rng2 = Rng::new(21);
        let mut sm = ShardedMesh::new(18, 14, 4, NoiseModel::PAPER, 3, ShardPolicy::Grid, &mut rng2);
        sm.program_from_dense(&w);
        assert_eq!(mesh.sigma_flat(), sm.sigma_flat());
        assert_eq!(mesh.to_dense().data, sm.to_dense().data);
        assert_eq!(mesh.block_norms_sq(), sm.block_norms_sq());
        assert_eq!(mesh.n_sigma(), sm.n_sigma());
        assert_eq!(mesh.n_phases(), sm.n_phases());
    }

    #[test]
    fn sigma_roundtrip_is_logical_order() {
        let mut rng = Rng::new(31);
        let mut sm = ShardedMesh::new(12, 12, 4, NoiseModel::IDEAL, 4, ShardPolicy::Grid, &mut rng);
        let mut sig = sm.sigma_flat();
        for (i, s) in sig.iter_mut().enumerate() {
            *s = (i as f32) * 0.05 - 0.4;
        }
        sm.set_sigma_flat(&sig);
        assert_close(&sm.sigma_flat(), &sig, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn local_mask_roundtrip() {
        let mut rng = Rng::new(41);
        let sm = ShardedMesh::new(16, 16, 4, NoiseModel::IDEAL, 2, ShardPolicy::Row, &mut rng);
        let mask: Vec<bool> = (0..sm.p * sm.q).map(|i| i % 3 != 0).collect();
        let mut back = vec![false; sm.p * sm.q];
        for si in 0..sm.num_shards() {
            let local = sm.local_mask_pq(si, &mask);
            sm.store_local_mask_pq(si, &local, &mut back);
        }
        assert_eq!(mask, back);
    }

    #[test]
    fn sharding_config_json_roundtrip() {
        for policy in [ShardPolicy::Row, ShardPolicy::Col, ShardPolicy::Grid] {
            let sc = ShardingConfig { shards: 4, policy };
            let j = sc.to_json();
            let back = ShardingConfig::from_json(&j).expect("parses back");
            assert_eq!(sc, back);
            // Canonical dump stability (golden gate compares exact dumps).
            assert_eq!(j.dump(), back.to_json().dump());
        }
        assert_eq!(ShardingConfig::from_json(&Json::Num(1.0)), None);
        let mut bad = Json::obj();
        bad.set("shards", Json::Num(2.0)).set("policy", Json::Str("diagonal".into()));
        assert_eq!(ShardingConfig::from_json(&bad), None);
    }

    #[test]
    fn grid_dims_are_near_square() {
        assert_eq!(grid_dims(1), (1, 1));
        assert_eq!(grid_dims(2), (1, 2));
        assert_eq!(grid_dims(4), (2, 2));
        assert_eq!(grid_dims(6), (2, 3));
        assert_eq!(grid_dims(7), (1, 7));
        assert_eq!(grid_dims(12), (3, 4));
    }
}
