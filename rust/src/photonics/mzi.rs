//! Mach-Zehnder interferometer device physics (paper Appendix A.1).
//!
//! A 2×2 MZI with two 50:50 directional couplers and four phase shifters
//! realizes any SU(2); with the paper's operating point (θ_T=π/2, θ_L=3π/2,
//! ω̄=π, Δω=π−2φ) it reduces to the real planar rotator R(2) of Eq. 7:
//!
//! ```text
//! R(φ) = [ cos φ  −sin φ ]
//!        [ sin φ   cos φ ]
//! ```
//!
//! The full complex transfer function is kept here (used by the device-level
//! tests that verify the reduction); the mesh code works with the reduced
//! rotator.

/// Complex number — tiny local implementation (no external num-complex).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };
    pub const I: C64 = C64 { re: 0.0, im: 1.0 };

    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }
    /// e^{iθ}
    pub fn cis(theta: f64) -> C64 {
        C64 { re: theta.cos(), im: theta.sin() }
    }
    pub fn mul(self, o: C64) -> C64 {
        C64 { re: self.re * o.re - self.im * o.im, im: self.re * o.im + self.im * o.re }
    }
    pub fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
    pub fn scale(self, s: f64) -> C64 {
        C64 { re: self.re * s, im: self.im * s }
    }
    pub fn conj(self) -> C64 {
        C64 { re: self.re, im: -self.im }
    }
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// 2×2 complex matrix [[a,b],[c,d]].
#[derive(Clone, Copy, Debug)]
pub struct M2 {
    pub a: C64,
    pub b: C64,
    pub c: C64,
    pub d: C64,
}

impl M2 {
    pub fn mul(self, o: M2) -> M2 {
        M2 {
            a: self.a.mul(o.a).add(self.b.mul(o.c)),
            b: self.a.mul(o.b).add(self.b.mul(o.d)),
            c: self.c.mul(o.a).add(self.d.mul(o.c)),
            d: self.c.mul(o.b).add(self.d.mul(o.d)),
        }
    }

    /// Deviation from unitarity: ‖M†M − I‖∞.
    pub fn unitarity_error(self) -> f64 {
        let g = M2 {
            a: self.a.conj(),
            b: self.c.conj(),
            c: self.b.conj(),
            d: self.d.conj(),
        }
        .mul(self);
        let mut e: f64 = (g.a.re - 1.0).abs().max(g.a.im.abs());
        e = e.max(g.d.re - 1.0).max(g.d.im.abs());
        e = e.max(g.b.abs()).max(g.c.abs());
        e
    }
}

/// 50:50 directional coupler: t = k = √2/2, transfer [[t, kj],[kj, t]].
pub fn coupler_50_50() -> M2 {
    let t = std::f64::consts::FRAC_1_SQRT_2;
    M2 {
        a: C64::new(t, 0.0),
        b: C64::new(0.0, t),
        c: C64::new(0.0, t),
        d: C64::new(t, 0.0),
    }
}

/// Diagonal phase-shifter pair diag(e^{jα}, e^{jβ}).
pub fn phase_pair(alpha: f64, beta: f64) -> M2 {
    M2 { a: C64::cis(alpha), b: C64::ZERO, c: C64::ZERO, d: C64::cis(beta) }
}

/// Full physical MZI transfer function of Eq. 6 with the four phase
/// shifters θ_T, θ_L (input) and ω_P, ω_W (internal).
pub fn mzi_transfer(theta_t: f64, theta_l: f64, omega_p: f64, omega_w: f64) -> M2 {
    coupler_50_50()
        .mul(phase_pair(omega_p, omega_w))
        .mul(coupler_50_50())
        .mul(phase_pair(theta_t, theta_l))
}

/// Operating point of Eq. 7 mapping rotation angle φ to the four shifter
/// settings: θ_T=π/2, θ_L=3π/2, ω̄=π, Δω=π−2φ.
pub fn rotator_operating_point(phi: f64) -> (f64, f64, f64, f64) {
    use std::f64::consts::PI;
    let d_omega = PI - 2.0 * phi;
    let omega_p = PI + d_omega / 2.0;
    let omega_w = PI - d_omega / 2.0;
    (PI / 2.0, 3.0 * PI / 2.0, omega_p, omega_w)
}

/// The reduced real planar rotator entries (cos φ, −sin φ; sin φ, cos φ).
pub fn rotator(phi: f64) -> [[f64; 2]; 2] {
    let (c, s) = (phi.cos(), phi.sin());
    [[c, -s], [s, c]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn coupler_is_unitary() {
        assert!(coupler_50_50().unitarity_error() < 1e-12);
    }

    #[test]
    fn mzi_always_unitary() {
        let mut rng = crate::util::Rng::new(13);
        for _ in 0..200 {
            let m = mzi_transfer(
                rng.uniform_range(0.0, 2.0 * PI),
                rng.uniform_range(0.0, 2.0 * PI),
                rng.uniform_range(0.0, 2.0 * PI),
                rng.uniform_range(0.0, 2.0 * PI),
            );
            assert!(m.unitarity_error() < 1e-10);
        }
    }

    #[test]
    fn operating_point_reduces_to_planar_rotator() {
        // Eq. 7: at the operating point the MZI transfer equals R(φ) up to a
        // global phase that must be exactly removable.
        let mut rng = crate::util::Rng::new(17);
        for _ in 0..100 {
            let phi = rng.uniform_range(-PI, PI);
            let (tt, tl, op, ow) = rotator_operating_point(phi);
            let m = mzi_transfer(tt, tl, op, ow);
            let r = rotator(phi);
            // Find the global phase from the largest-magnitude entry.
            let entries = [(m.a, r[0][0]), (m.b, r[0][1]), (m.c, r[1][0]), (m.d, r[1][1])];
            let (mz, rv) = entries
                .iter()
                .max_by(|x, y| x.1.abs().partial_cmp(&y.1.abs()).unwrap())
                .unwrap();
            assert!(rv.abs() > 0.1);
            // global = mz / rv  (rv real)
            let g = C64::new(mz.re / rv, mz.im / rv);
            assert!((g.abs() - 1.0).abs() < 1e-9, "global phase not unit modulus");
            for (mzv, rvv) in entries {
                let expected = g.scale(rvv);
                assert!(
                    (mzv.re - expected.re).abs() < 1e-9 && (mzv.im - expected.im).abs() < 1e-9,
                    "phi={phi}: {mzv:?} vs {expected:?}"
                );
            }
        }
    }

    #[test]
    fn rotator_orthogonal() {
        for phi in [-1.0f64, 0.0, 0.3, PI / 2.0, 3.0] {
            let r = rotator(phi);
            let det = r[0][0] * r[1][1] - r[0][1] * r[1][0];
            assert!((det - 1.0).abs() < 1e-12);
        }
    }
}
