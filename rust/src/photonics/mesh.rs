//! The P×Q blocked photonic mesh realizing an M×N weight matrix out of k×k
//! PTCs (paper §3.1, Eq. 1). Implements blocked forward, the in-situ
//! subspace gradient of Eq. 5, masked error feedback (balanced feedback
//! sampling, §3.4.2), OSP-based mapping from a dense weight, and the
//! PTC-call statistics the Appendix-G cost model consumes.
//!
//! §Perf — every hot path routes through the shared compute engine:
//! block/strip work fans out over `util::pool` (row strips for forward,
//! column strips for feedback, PTC blocks for σ-grad and batch realization,
//! column panels for the fused packed forward), the inner products run on
//! the SIMD-dispatched register-tiled slice kernels of `linalg::gemm`
//! (`L2IGHT_SIMD`), and padded activations are fed to those kernels as
//! sub-panel slices. Ragged inputs are padded — and masked batch columns
//! gathered — into per-thread scratch-arena buffers, so the masked paths
//! allocate nothing per call. Work is partitioned by output region, so
//! results are identical at every thread count within a dispatch level —
//! `threads=1` reproduces the serial engine bit-for-bit.

use super::noise::NoiseModel;
use super::ptc::Ptc;
use super::unitary::ReckMesh;
use crate::linalg::{
    gemm_acc_slices, gemm_at_b_acc_band, sigma_grad_block_slices, svd_kxk, Mat,
};
use crate::util::pool::{self, Scratch, SendPtr, ThreadPool};
use crate::util::Rng;

/// Raw hardware-op counters (Appendix G cost model, measured not estimated):
/// `*_block_cols` are PTC calls — the normalized *energy* indicator —
/// and `*_steps` accumulate the longest sequential accumulation path — the
/// normalized *latency* indicator (k adders per PTC, sequential cross-PTC
/// reduction, massively parallel PTCs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MeshStats {
    /// Forward k×k·(k-column group) products issued (ℒ energy).
    pub fwd_block_cols: u64,
    /// Reciprocal PTC calls for σ-gradient acquisition — Eq. 5 needs 2 per
    /// block-column group (∇_Σℒ energy).
    pub grad_block_cols: u64,
    /// Feedback (Wᵀ·dy) block products issued after masking (∇_xℒ energy).
    pub feedback_block_cols: u64,
    /// Forward steps: per column group, 1 PTC call + Q sequential partial
    /// accumulations (parallel over P).
    pub fwd_steps: u64,
    /// σ-gradient steps: 2 reciprocal passes per kept column group + 1
    /// Hadamard step.
    pub grad_steps: u64,
    /// Feedback steps: per column group, 1 + longest kept accumulation row
    /// (the load-balance-critical quantity of Fig. 7).
    pub feedback_steps: u64,
}

impl MeshStats {
    pub fn add(&mut self, o: &MeshStats) {
        self.fwd_block_cols += o.fwd_block_cols;
        self.grad_block_cols += o.grad_block_cols;
        self.feedback_block_cols += o.feedback_block_cols;
        self.fwd_steps += o.fwd_steps;
        self.grad_steps += o.grad_steps;
        self.feedback_steps += o.feedback_steps;
    }

    /// Total PTC-call energy.
    pub fn total_energy(&self) -> u64 {
        self.fwd_block_cols + self.grad_block_cols + self.feedback_block_cols
    }

    /// Total accumulation-path steps.
    pub fn total_steps(&self) -> u64 {
        self.fwd_steps + self.grad_steps + self.feedback_steps
    }
}

/// A blocked photonic mesh for an `rows`×`cols` weight.
#[derive(Clone, Debug)]
pub struct PtcMesh {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    /// ceil(rows/k)
    pub p: usize,
    /// ceil(cols/k)
    pub q: usize,
    /// Row-major [p][q] PTC array.
    pub ptcs: Vec<Ptc>,
    pub noise: NoiseModel,
    pub stats: MeshStats,
    /// Cached realized block matrices (invalidated with the PTC caches).
    w_cache: Option<Vec<Mat>>,
}

impl PtcMesh {
    pub fn new(rows: usize, cols: usize, k: usize, noise: NoiseModel, rng: &mut Rng) -> PtcMesh {
        assert!(k >= 2, "block size must be ≥ 2");
        let p = rows.div_ceil(k);
        let q = cols.div_ceil(k);
        let ptcs = (0..p * q).map(|_| Ptc::new(k, noise, rng)).collect();
        PtcMesh { rows, cols, k, p, q, ptcs, noise, stats: MeshStats::default(), w_cache: None }
    }

    /// Assemble a mesh from pre-built PTCs (row-major [p][q] order). The
    /// sharding layer partitions one logical mesh's PTC array into sub-mesh
    /// shards with this, so every shard's device state is bit-identical to
    /// the unsharded mesh it was carved from.
    pub(crate) fn from_ptcs(
        rows: usize,
        cols: usize,
        k: usize,
        ptcs: Vec<Ptc>,
        noise: NoiseModel,
    ) -> PtcMesh {
        let p = rows.div_ceil(k);
        let q = cols.div_ceil(k);
        assert_eq!(ptcs.len(), p * q, "from_ptcs block count");
        PtcMesh { rows, cols, k, p, q, ptcs, noise, stats: MeshStats::default(), w_cache: None }
    }

    #[inline]
    pub fn ptc(&self, pi: usize, qi: usize) -> &Ptc {
        &self.ptcs[pi * self.q + qi]
    }

    #[inline]
    pub fn ptc_mut(&mut self, pi: usize, qi: usize) -> &mut Ptc {
        self.w_cache = None;
        &mut self.ptcs[pi * self.q + qi]
    }

    /// Invalidate realized-weight caches (call after any phase programming).
    pub fn invalidate(&mut self) {
        self.w_cache = None;
    }

    /// Program the mesh from a dense pretrained weight: per-block SVD,
    /// Reck-decompose the singular vectors into phases, program Σ. This is
    /// the *ideal-parametrization initialization* of Algorithm 1 step 1; with
    /// noise on, the realized mesh will deviate and PM refines it.
    pub fn program_from_dense(&mut self, w: &Mat) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols), "program_from_dense shape");
        let k = self.k;
        for pi in 0..self.p {
            for qi in 0..self.q {
                let blk = w.block(pi * k, qi * k, k);
                let svd = svd_kxk(&blk);
                // Eq. 8 parametrization: U = D·ΠR. The D diagonals are extra
                // output-side π shifters, programmed alongside the phases.
                let mu = ReckMesh::decompose(&svd.u);
                let mv = ReckMesh::decompose(&svd.vt);
                let maxabs = svd.s.iter().fold(0.0f32, |m, s| m.max(s.abs()));
                let ptc = self.ptc_mut(pi, qi);
                ptc.u_mesh.d = mu.d;
                ptc.v_mesh.d = mv.d;
                ptc.set_phases(super::ptc::Which::U, &mu.phases);
                ptc.set_phases(super::ptc::Which::V, &mv.phases);
                ptc.set_sigma_scale(maxabs.max(1e-6));
                ptc.set_sigma(&svd.s);
            }
        }
    }

    /// The realized dense weight W̃ (noisy).
    pub fn to_dense(&mut self) -> Mat {
        let k = self.k;
        let mut w = Mat::zeros(self.rows, self.cols);
        self.ensure_cache(pool::global());
        let cache = self.w_cache.as_ref().unwrap();
        for pi in 0..self.p {
            for qi in 0..self.q {
                w.set_block(pi * k, qi * k, &cache[pi * self.q + qi]);
            }
        }
        w
    }

    /// Batch-realize all PTC blocks (phases → noisy matrices) across the
    /// pool. This is the ZOO/noise-sim dominant cost — each block is
    /// independent.
    pub(crate) fn ensure_cache(&mut self, pool: &ThreadPool) {
        if self.w_cache.is_some() {
            return;
        }
        let n = self.ptcs.len();
        let k = self.k;
        let pptr = SendPtr(self.ptcs.as_mut_ptr());
        // Realization work per block ≈ O(k³) with a large constant (phase
        // synthesis); gate tiny meshes to the inline path.
        let blocks = if n > 1 && 8 * n * k * k * k >= pool::par_min_work() {
            pool.parallel_map(n, |i| {
                // Safety: each index realizes exactly one distinct PTC.
                let ptc = unsafe { &mut *pptr.0.add(i) };
                ptc.realized_matrix()
            })
        } else {
            self.ptcs.iter_mut().map(|ptc| ptc.realized_matrix()).collect()
        };
        self.w_cache = Some(blocks);
    }

    /// The realized block matrices (call `ensure_cache` first). Used by the
    /// sharding layer, which drives the block loop itself.
    pub(crate) fn cached_blocks(&self) -> &[Mat] {
        self.w_cache.as_ref().expect("cached_blocks: ensure_cache not called")
    }

    /// Blocked forward Y = W̃ · X for X of shape [cols, B].
    pub fn forward(&mut self, x: &Mat) -> Mat {
        self.forward_masked(x, None, 1.0)
    }

    /// Forward with an optional [p][q] block keep-mask (p-major) — used by
    /// the SWAT-U baseline, which sparsifies the *forward* weights too.
    /// Dropped blocks issue no PTC call.
    pub fn forward_masked(&mut self, x: &Mat, block_keep: Option<&[bool]>, scale: f32) -> Mat {
        self.forward_masked_on(pool::global(), x, block_keep, scale)
    }

    /// `forward_masked` on an explicit pool (the public entry point uses the
    /// process-global one; tests use this to prove thread-count invariance).
    pub fn forward_masked_on(
        &mut self,
        pool: &ThreadPool,
        x: &Mat,
        block_keep: Option<&[bool]>,
        scale: f32,
    ) -> Mat {
        assert_eq!(x.rows, self.cols, "mesh forward input rows");
        let (k, p, q, b) = (self.k, self.p, self.q, x.cols);
        self.ensure_cache(pool);
        let mut y = Mat::zeros(self.rows, b);
        {
            let cache = self.w_cache.as_ref().unwrap();
            // Borrow X when already k-aligned; pad into scratch otherwise
            // (§Perf: the q input panels are consumed as sub-slices, and the
            // pad buffer comes from the per-thread arena — no allocation).
            let mut xp_store: Option<Scratch> = None;
            let xp: &[f32] = padded_panel(x, q * k, &mut xp_store);
            // Ragged row counts accumulate into a scratch-arena panel and
            // crop in one copy-out; aligned ones write Y directly (§Perf:
            // the old Mat::zeros(p·k, b) + crop_rows clone pair is gone).
            let mut yp_store: Option<Scratch> = None;
            let ypp = if p * k == self.rows {
                SendPtr(y.data.as_mut_ptr())
            } else {
                SendPtr(yp_store.insert(Scratch::take(p * k * b)).as_mut_ptr())
            };
            // One task per output row strip; each strip accumulates its q
            // block products directly into its disjoint rows of Y.
            pool.parallel_for_sized(p, 2 * p * q * k * k * b, |pi| {
                // Safety: strip pi writes rows [pi·k, (pi+1)·k) only.
                let strip =
                    unsafe { std::slice::from_raw_parts_mut(ypp.0.add(pi * k * b), k * b) };
                for qi in 0..q {
                    if let Some(mask) = block_keep {
                        if !mask[pi * q + qi] {
                            continue;
                        }
                    }
                    let w = &cache[pi * q + qi];
                    gemm_acc_slices(&w.data, k, k, &xp[qi * k * b..(qi + 1) * k * b], b, strip);
                }
                if scale != 1.0 {
                    for v in strip.iter_mut() {
                        *v *= scale;
                    }
                }
            });
            if let Some(yp) = &yp_store {
                y.data.copy_from_slice(&yp[..self.rows * b]);
            }
        }
        self.note_forward_stats(b, block_keep);
        y
    }

    /// Fused packed-panel forward Y = W̃ · X for an X that is never
    /// materialized: `pack(c0, c1, dst)` fills column panel `[c0, c1)` of
    /// the logical `[cols × total_cols]` operand into pre-zeroed scratch
    /// with row stride `c1 − c0` (rows `cols..q·k` stay zero — the block
    /// padding is fused too). This is the §3.4.2 conv path: patch tiles go
    /// straight from the activation into the GEMM packing buffers. Within a
    /// SIMD dispatch level the result — and the `MeshStats` accounting — is
    /// bitwise identical to `forward_masked` on the materialized matrix;
    /// the panel width comes from the autotuner profile (never from the
    /// pool width — `linalg::tune::panel_cols`), and any width yields the
    /// same bits, so results are also thread-count-invariant.
    pub fn forward_packed_on<P>(
        &mut self,
        pool: &ThreadPool,
        total_cols: usize,
        pack: &P,
        block_keep: Option<&[bool]>,
        scale: f32,
    ) -> Mat
    where
        P: Fn(usize, usize, &mut [f32]) + Sync,
    {
        let (k, p, q) = (self.k, self.p, self.q);
        self.ensure_cache(pool);
        let mut y = Mat::zeros(self.rows, total_cols);
        {
            let cache = self.w_cache.as_ref().unwrap();
            let rows = self.rows;
            let yptr = SendPtr(y.data.as_mut_ptr());
            let panel_cols = crate::linalg::tune::panel_cols();
            let panels = total_cols.div_ceil(panel_cols);
            // One task per column panel; each panel packs its X tile, runs
            // the full P×Q block loop over it, and owns its Y columns.
            pool.parallel_for_sized(panels, 2 * p * q * k * k * total_cols, |ti| {
                let c0 = ti * panel_cols;
                let c1 = (c0 + panel_cols).min(total_cols);
                let wpan = c1 - c0;
                let mut xbuf = Scratch::take(q * k * wpan);
                pack(c0, c1, &mut xbuf);
                let mut ybuf = Scratch::take(p * k * wpan);
                for pi in 0..p {
                    let strip = &mut ybuf[pi * k * wpan..(pi + 1) * k * wpan];
                    for qi in 0..q {
                        if let Some(mask) = block_keep {
                            if !mask[pi * q + qi] {
                                continue;
                            }
                        }
                        let w = &cache[pi * q + qi];
                        gemm_acc_slices(
                            &w.data,
                            k,
                            k,
                            &xbuf[qi * k * wpan..(qi + 1) * k * wpan],
                            wpan,
                            strip,
                        );
                    }
                    if scale != 1.0 {
                        for v in strip.iter_mut() {
                            *v *= scale;
                        }
                    }
                }
                // Safety: panel ti owns columns [c0, c1) of every row of Y
                // (the row crop to `rows` is fused into the scatter).
                unsafe {
                    crate::linalg::conv::scatter_panel(yptr, total_cols, c0, wpan, rows, &ybuf)
                };
            });
        }
        self.note_forward_stats(total_cols, block_keep);
        y
    }

    /// Appendix-G forward accounting, shared by the eager and packed paths —
    /// one formula keeps the cost model independent of execution strategy.
    pub(crate) fn note_forward_stats(&mut self, b: usize, block_keep: Option<&[bool]>) {
        let (p, q) = (self.p, self.q);
        let kept = match block_keep {
            None => (p * q) as u64,
            Some(m) => m.iter().filter(|&&keep| keep).count() as u64,
        };
        let groups = b.div_ceil(self.k).max(1) as u64;
        self.stats.fwd_block_cols += kept * groups;
        // Latency: per column group 1 PTC call + sequential accumulation over
        // the deepest kept row (Q when dense).
        let max_row_depth = (0..p)
            .map(|pi| match block_keep {
                None => q,
                Some(m) => (0..q).filter(|&qi| m[pi * q + qi]).count(),
            })
            .max()
            .unwrap_or(0) as u64;
        self.stats.fwd_steps += groups * (1 + max_row_depth);
    }

    /// In-situ subspace gradient (Eq. 5), computed per block with the
    /// reciprocal ops: dΣ_pq[i] = Σ_batch (Uᵀ dY_p)[i,·] ⊙ (V* X_q)[i,·],
    /// with optional per-block feedback mask and column mask.
    ///
    /// * `x` — layer input [cols, B];
    /// * `dy` — upstream gradient [rows, B];
    /// * `col_keep` — optional boolean per batch column (column sampling);
    /// * `scale` — unbiasedness normalization applied to the result.
    ///
    /// Returns the flattened gradient [p*q*k] in block order.
    pub fn sigma_grad(
        &mut self,
        x: &Mat,
        dy: &Mat,
        col_keep: Option<&[bool]>,
        scale: f32,
    ) -> Vec<f32> {
        self.sigma_grad_on(pool::global(), x, dy, col_keep, scale)
    }

    /// `sigma_grad` on an explicit pool (see `forward_masked_on`).
    pub fn sigma_grad_on(
        &mut self,
        pool: &ThreadPool,
        x: &Mat,
        dy: &Mat,
        col_keep: Option<&[bool]>,
        scale: f32,
    ) -> Vec<f32> {
        assert_eq!(x.rows, self.cols);
        assert_eq!(dy.rows, self.rows);
        assert_eq!(x.cols, dy.cols);
        let (k, p, q) = (self.k, self.p, self.q);
        // §Perf: aligned unmasked inputs are borrowed (zero copies on the
        // common path); ragged ones pad into scratch, and the masked path
        // gathers kept columns + pads in one scratch pass — the old
        // select_cols/pad_rows clone-per-call pair is gone.
        let mut xp_store: Option<Scratch> = None;
        let mut dyp_store: Option<Scratch> = None;
        let (xp, dyp, b): (&[f32], &[f32], usize) = match col_keep {
            None => (
                padded_panel(x, q * k, &mut xp_store),
                padded_panel(dy, p * k, &mut dyp_store),
                x.cols,
            ),
            Some(mask) => {
                assert_eq!(mask.len(), x.cols);
                let kept: Vec<usize> = (0..x.cols).filter(|&c| mask[c]).collect();
                let b = kept.len();
                xp_store = Some(gather_cols_padded(x, &kept, q * k));
                dyp_store = Some(gather_cols_padded(dy, &kept, p * k));
                (
                    &xp_store.as_ref().unwrap()[..],
                    &dyp_store.as_ref().unwrap()[..],
                    b,
                )
            }
        };
        let mut grad = vec![0.0f32; p * q * k];
        {
            // Per block: A = Uᵀ·dy_p (k×B), C = V*·x_q (k×B), dσ_i = Σ_b A⊙C.
            // One task per PTC block: disjoint &mut PTC (realization cache)
            // and disjoint k-slice of the gradient; intermediates live in the
            // per-thread scratch arena (§Perf: no allocation per block).
            let gptr = SendPtr(grad.as_mut_ptr());
            let pptr = SendPtr(self.ptcs.as_mut_ptr());
            pool.parallel_for_sized(p * q, 2 * p * q * k * k * b, |bi| {
                // Safety: block bi owns ptcs[bi] and grad[bi·k .. bi·k+k].
                let ptc = unsafe { &mut *pptr.0.add(bi) };
                let g = unsafe { std::slice::from_raw_parts_mut(gptr.0.add(bi * k), k) };
                let (pi, qi) = (bi / q, bi % q);
                let (u, v) = ptc.realized_uv();
                let mut scratch = Scratch::take(2 * k * b);
                let (ut_y, vx) = scratch.split_at_mut(k * b);
                sigma_grad_block_slices(
                    u,
                    v,
                    &dyp[pi * k * b..(pi + 1) * k * b],
                    &xp[qi * k * b..(qi + 1) * k * b],
                    b,
                    scale,
                    ut_y,
                    vx,
                    g,
                );
            });
        }
        // 2 reciprocal PTC calls per block-column group (Appendix G.1)...
        let groups = b.div_ceil(k).max(1) as u64;
        self.stats.grad_block_cols += 2 * (p * q) as u64 * groups;
        // ...and 2 pipelined passes + 1 Hadamard step in latency.
        self.stats.grad_steps += 2 * groups + 1;
        grad
    }

    /// Masked error feedback dX = c_W · Σ_p [S_W(q,p)] W̃_pqᵀ dY_p
    /// (§3.4.2 balanced feedback sampling). `block_keep` is a [q][p] mask
    /// (None = dense), `scale` the unbiasedness factor c_W.
    pub fn feedback(&mut self, dy: &Mat, block_keep: Option<&[bool]>, scale: f32) -> Mat {
        self.feedback_on(pool::global(), dy, block_keep, scale)
    }

    /// `feedback` on an explicit pool (see `forward_masked_on`).
    pub fn feedback_on(
        &mut self,
        pool: &ThreadPool,
        dy: &Mat,
        block_keep: Option<&[bool]>,
        scale: f32,
    ) -> Mat {
        assert_eq!(dy.rows, self.rows, "feedback dy rows");
        let (k, p, q, b) = (self.k, self.p, self.q, dy.cols);
        self.ensure_cache(pool);
        let mut dx = Mat::zeros(self.cols, b);
        {
            let cache = self.w_cache.as_ref().unwrap();
            let mut dyp_store: Option<Scratch> = None;
            let dyp: &[f32] = padded_panel(dy, p * k, &mut dyp_store);
            // Same arena-backed crop fusion as `forward_masked_on`.
            let mut dxp_store: Option<Scratch> = None;
            let dpp = if q * k == self.cols {
                SendPtr(dx.data.as_mut_ptr())
            } else {
                SendPtr(dxp_store.insert(Scratch::take(q * k * b)).as_mut_ptr())
            };
            // One task per input-side strip qi: accumulates its p block
            // products W̃ᵀ·dy_p directly into its disjoint rows of dX.
            pool.parallel_for_sized(q, 2 * p * q * k * k * b, |qi| {
                // Safety: strip qi writes rows [qi·k, (qi+1)·k) only.
                let strip =
                    unsafe { std::slice::from_raw_parts_mut(dpp.0.add(qi * k * b), k * b) };
                for pi in 0..p {
                    if let Some(mask) = block_keep {
                        if !mask[qi * p + pi] {
                            continue;
                        }
                    }
                    // W̃ᵀ block product without materializing the transpose.
                    let wt = &cache[pi * q + qi];
                    gemm_at_b_acc_band(
                        &wt.data,
                        k,
                        k,
                        &dyp[pi * k * b..(pi + 1) * k * b],
                        b,
                        0,
                        k,
                        strip,
                    );
                }
                if scale != 1.0 {
                    for v in strip.iter_mut() {
                        *v *= scale;
                    }
                }
            });
            if let Some(dxp) = &dxp_store {
                dx.data.copy_from_slice(&dxp[..self.cols * b]);
            }
        }
        let kept_products = match block_keep {
            None => (p * q) as u64,
            Some(m) => m.iter().filter(|&&keep| keep).count() as u64,
        };
        let groups = b.div_ceil(k).max(1) as u64;
        self.stats.feedback_block_cols += kept_products * groups;
        // Latency is bottlenecked by the longest accumulation row of Wᵀ
        // (Fig. 7) — btopk's load balance shows up exactly here.
        let critical = (0..q)
            .map(|qi| match block_keep {
                None => p,
                Some(m) => (0..p).filter(|&pi| m[qi * p + pi]).count(),
            })
            .max()
            .unwrap_or(0) as u64;
        self.stats.feedback_steps += groups * (1 + critical);
        dx
    }

    /// Per-block squared Frobenius norms estimated the on-chip way:
    /// ‖W_pq‖²_F = Tr(|Σ_pq|²) (§3.4.2) — valid because U, V* are unitary.
    /// Returned as a [p*q] vector in block row-major order.
    pub fn block_norms_sq(&self) -> Vec<f32> {
        self.ptcs.iter().map(|ptc| ptc.sigma.iter().map(|s| s * s).sum()).collect()
    }

    /// Flattened Σ view [p*q*k] (block row-major) for the optimizer.
    pub fn sigma_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.p * self.q * self.k);
        for ptc in &self.ptcs {
            v.extend_from_slice(&ptc.sigma);
        }
        v
    }

    /// Program Σ from a flattened vector (inverse of `sigma_flat`).
    pub fn set_sigma_flat(&mut self, sigma: &[f32]) {
        assert_eq!(sigma.len(), self.p * self.q * self.k);
        let k = self.k;
        for (bi, ptc) in self.ptcs.iter_mut().enumerate() {
            // Keep the attenuator full-scale able to express the update.
            let blk = &sigma[bi * k..(bi + 1) * k];
            let maxabs = blk.iter().fold(0.0f32, |m, s| m.max(s.abs()));
            if maxabs > ptc.sigma_scale {
                ptc.set_sigma_scale(maxabs);
            }
            ptc.set_sigma(blk);
        }
        self.w_cache = None;
    }

    /// Number of trainable subspace parameters (P·Q·k singular values).
    pub fn n_sigma(&self) -> usize {
        self.p * self.q * self.k
    }

    /// Total number of MZI phases across all PTCs.
    pub fn n_phases(&self) -> usize {
        self.ptcs.iter().map(|ptc| ptc.n_phases()).sum()
    }

    /// Relative realized error ‖W̃−W‖²/‖W‖² against a dense target.
    pub fn rel_error(&mut self, target: &Mat) -> f32 {
        self.to_dense().rel_dist_sq(target)
    }
}

/// Borrow `x`'s storage when it already has `target` rows; otherwise
/// zero-pad into a scratch-arena buffer held by `store` and borrow that
/// (§Perf: the one unavoidable copy for ragged shapes reuses the arena —
/// no per-call allocation on the per-block-per-step masked paths).
pub(crate) fn padded_panel<'a>(
    x: &'a Mat,
    target: usize,
    store: &'a mut Option<Scratch>,
) -> &'a [f32] {
    if x.rows == target {
        &x.data
    } else {
        debug_assert!(target > x.rows);
        let mut s = Scratch::take(target * x.cols);
        s[..x.rows * x.cols].copy_from_slice(&x.data);
        &store.insert(s)[..]
    }
}

/// Gather the batch columns listed in `kept` and zero-pad the rows up to
/// `target_rows`, in one pass into a scratch-arena buffer — the masked
/// σ-grad path's replacement for the old select-then-pad clone pair.
pub(crate) fn gather_cols_padded(x: &Mat, kept: &[usize], target_rows: usize) -> Scratch {
    let b = kept.len();
    let mut s = Scratch::take(target_rows * b);
    for r in 0..x.rows {
        let src = x.row(r);
        let dst = &mut s[r * b..(r + 1) * b];
        for (j, &c) in kept.iter().enumerate() {
            dst[j] = src[c];
        }
    }
    s
}

/// Zero-pad a matrix's rows up to `target_rows`.
///
/// Reference/test helper: the hot paths no longer call this (or
/// `crop_rows`) per step — their shard-boundary pad/crop copies go through
/// the per-thread scratch arena (`padded_panel` + the fused crop-on-copy-out
/// in `forward_masked_on`/`feedback_on`), so nothing is freshly allocated
/// beyond the exact-size result.
pub fn pad_rows(x: &Mat, target_rows: usize) -> Mat {
    if x.rows == target_rows {
        return x.clone();
    }
    assert!(target_rows > x.rows);
    let mut out = Mat::zeros(target_rows, x.cols);
    out.data[..x.rows * x.cols].copy_from_slice(&x.data);
    out
}

/// Take `k` contiguous rows starting at `r0` as an owned panel.
pub fn slice_rows(x: &Mat, r0: usize, k: usize) -> Mat {
    let mut out = Mat::zeros(k, x.cols);
    out.data.copy_from_slice(&x.data[r0 * x.cols..(r0 + k) * x.cols]);
    out
}

/// Truncate a matrix to its first `rows` rows.
pub fn crop_rows(x: &Mat, rows: usize) -> Mat {
    if x.rows == rows {
        return x.clone();
    }
    Mat::from_slice(rows, x.cols, &x.data[..rows * x.cols])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::prop::{assert_close, quickcheck};

    #[test]
    fn map_and_reconstruct_ideal() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(10, 14, 0.5, &mut rng);
        let mut mesh = PtcMesh::new(10, 14, 4, NoiseModel::IDEAL, &mut rng);
        mesh.program_from_dense(&w);
        let w2 = mesh.to_dense();
        assert!(w2.rel_dist_sq(&w) < 1e-7, "rel err {}", w2.rel_dist_sq(&w));
    }

    #[test]
    fn forward_matches_dense() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(9, 13, 0.5, &mut rng);
        let mut mesh = PtcMesh::new(9, 13, 4, NoiseModel::PAPER, &mut rng);
        mesh.program_from_dense(&w);
        let x = Mat::randn(13, 7, 1.0, &mut rng);
        let y = mesh.forward(&x);
        let wd = mesh.to_dense();
        assert_close(&y.data, &matmul(&wd, &x).data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn noisy_mapping_has_bounded_error() {
        // With Q+CT+DV (no phase bias), ideal-parametrization programming
        // gives a small-but-nonzero relative error (Table 3 territory).
        let mut rng = Rng::new(3);
        let w = Mat::randn(27, 27, 0.5, &mut rng);
        let noise = NoiseModel { phase_bias: false, ..NoiseModel::PAPER };
        let mut mesh = PtcMesh::new(27, 27, 9, noise, &mut rng);
        mesh.program_from_dense(&w);
        let e = mesh.rel_error(&w);
        assert!(e > 1e-6, "noise should be visible, e={e}");
        assert!(e < 0.5, "Q+CT+DV should not destroy the mapping, e={e}");
    }

    #[test]
    fn unknown_phase_bias_destroys_direct_programming() {
        // With Φ_b ~ U(0, 2π) present, programming decomposed phases directly
        // is useless — the motivation for identity calibration (§3.2/Fig 1b).
        let mut rng = Rng::new(4);
        let w = Mat::randn(18, 18, 0.5, &mut rng);
        let mut mesh = PtcMesh::new(18, 18, 9, NoiseModel::PAPER, &mut rng);
        mesh.program_from_dense(&w);
        assert!(mesh.rel_error(&w) > 0.5);
    }

    #[test]
    fn feedback_dense_is_wt_dy() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(8, 12, 0.5, &mut rng);
        let mut mesh = PtcMesh::new(8, 12, 4, NoiseModel::IDEAL, &mut rng);
        mesh.program_from_dense(&w);
        let dy = Mat::randn(8, 5, 1.0, &mut rng);
        let dx = mesh.feedback(&dy, None, 1.0);
        let expect = matmul(&mesh.to_dense().t(), &dy);
        assert_close(&dx.data, &expect.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn feedback_mask_zeroes_blocks() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(8, 8, 0.5, &mut rng);
        let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::IDEAL, &mut rng);
        mesh.program_from_dense(&w);
        let dy = Mat::randn(8, 3, 1.0, &mut rng);
        // Drop every block: gradient must be exactly zero.
        let mask = vec![false; mesh.p * mesh.q];
        let dx = mesh.feedback(&dy, Some(&mask), 2.0);
        assert!(dx.fro_norm() == 0.0);
        // Keep all: same as dense up to the scale.
        let mask = vec![true; mesh.p * mesh.q];
        let dx = mesh.feedback(&dy, Some(&mask), 1.0);
        let expect = matmul(&mesh.to_dense().t(), &dy);
        assert_close(&dx.data, &expect.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn sigma_grad_matches_analytic() {
        // For the ideal mesh, dL/dσ_pq[i] with L = <dy, Wx> is
        // (Uᵀ dy)_i (V* x)_i summed over batch. Compare against finite
        // differences of the realized forward.
        let mut rng = Rng::new(6);
        let w = Mat::randn(8, 8, 0.5, &mut rng);
        let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::IDEAL, &mut rng);
        mesh.program_from_dense(&w);
        let x = Mat::randn(8, 3, 1.0, &mut rng);
        let dy = Mat::randn(8, 3, 1.0, &mut rng);
        let g = mesh.sigma_grad(&x, &dy, None, 1.0);
        // Finite differences on <dy, forward(x)> w.r.t. each sigma.
        let eps = 1e-3f32;
        let base_sigma = mesh.sigma_flat();
        for idx in 0..g.len() {
            let mut sp = base_sigma.clone();
            sp[idx] += eps;
            let mut m2 = mesh.clone();
            m2.set_sigma_flat(&sp);
            let yp = m2.forward(&x);
            let mut sm = base_sigma.clone();
            sm[idx] -= eps;
            let mut m3 = mesh.clone();
            m3.set_sigma_flat(&sm);
            let ym = m3.forward(&x);
            let fd: f32 = yp
                .data
                .iter()
                .zip(&ym.data)
                .zip(&dy.data)
                .map(|((a, b), d)| (a - b) / (2.0 * eps) * d)
                .sum();
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs analytic {}",
                g[idx]
            );
        }
    }

    #[test]
    fn prop_padding_roundtrip() {
        quickcheck(
            "pad/crop roundtrip",
            |rng, size| {
                let r = 1 + size % 20;
                let c = 1 + size % 7;
                (Mat::randn(r, c, 1.0, rng), r + size % 9)
            },
            |(m, target)| {
                let p = pad_rows(m, *target.max(&m.rows));
                let back = crop_rows(&p, m.rows);
                assert_close(&back.data, &m.data, 0.0, 0.0)
            },
        );
    }

    #[test]
    fn stats_count_ops() {
        let mut rng = Rng::new(7);
        let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::IDEAL, &mut rng);
        let x = Mat::randn(8, 8, 1.0, &mut rng);
        mesh.forward(&x); // p*q=4 blocks, 8 cols = 2 col groups
        assert_eq!(mesh.stats.fwd_block_cols, 8);
        let dy = Mat::randn(8, 8, 1.0, &mut rng);
        mesh.feedback(&dy, None, 1.0);
        assert_eq!(mesh.stats.feedback_block_cols, 8);
        mesh.sigma_grad(&x, &dy, None, 1.0);
        assert_eq!(mesh.stats.grad_block_cols, 16);
    }

    #[test]
    fn sigma_flat_roundtrip() {
        let mut rng = Rng::new(8);
        let mut mesh = PtcMesh::new(8, 8, 4, NoiseModel::IDEAL, &mut rng);
        let mut sig = mesh.sigma_flat();
        for (i, s) in sig.iter_mut().enumerate() {
            *s = (i as f32) * 0.1 - 0.7;
        }
        mesh.set_sigma_flat(&sig);
        assert_close(&mesh.sigma_flat(), &sig, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn hot_paths_identical_across_thread_counts() {
        // The work partition is by output region, so the serial pool and a
        // wide pool must produce bit-identical results.
        let mut rng = Rng::new(9);
        // Large enough that the sized gate takes the pooled path on `wide`.
        let w = Mat::randn(40, 27, 0.5, &mut rng);
        let mesh0 = {
            let mut m = PtcMesh::new(40, 27, 4, NoiseModel::PAPER, &mut rng);
            m.program_from_dense(&w);
            m
        };
        let x = Mat::randn(27, 24, 1.0, &mut rng);
        let dy = Mat::randn(40, 24, 1.0, &mut rng);
        let serial = ThreadPool::new(1);
        let wide = ThreadPool::new(4);
        let mut m1 = mesh0.clone();
        let mut m2 = mesh0;
        assert_close(
            &m1.forward_masked_on(&serial, &x, None, 1.0).data,
            &m2.forward_masked_on(&wide, &x, None, 1.0).data,
            0.0,
            0.0,
        )
        .unwrap();
        assert_close(
            &m1.sigma_grad_on(&serial, &x, &dy, None, 1.0),
            &m2.sigma_grad_on(&wide, &x, &dy, None, 1.0),
            0.0,
            0.0,
        )
        .unwrap();
        assert_close(
            &m1.feedback_on(&serial, &dy, None, 1.0).data,
            &m2.feedback_on(&wide, &dy, None, 1.0).data,
            0.0,
            0.0,
        )
        .unwrap();
    }
}
