//! Reck-style triangular MZI mesh parametrization of the real unitary
//! (orthogonal) group (paper Appendix A.2, Eq. 8):
//!
//! ```text
//! U(n) = D · Π_{i=n..2} Π_{j=1..i-1} R_ij(φ_ij)
//! ```
//!
//! where D is a ±1 diagonal and R_ij(φ) is the n-dim identity with the 2×2
//! planar rotator embedded at coordinates (i, j) (1-indexed):
//! entries (i,i)=cosφ, (i,j)=−sinφ, (j,i)=sinφ, (j,j)=cosφ.
//!
//! Provides: phases → unitary synthesis, unitary → phases decomposition
//! (Givens nulling in the Reck elimination order), and fast in-place
//! application of the rotation product to vectors — the ZOO inner loops are
//! phase-local, so synthesis cost dominates identity calibration and
//! parallel mapping.

use crate::linalg::Mat;

/// Index pairs (i, j), 1-indexed, in the exact product order of Eq. 8:
/// i from n down to 2, j from 1 to i-1.
pub fn pair_order(n: usize) -> Vec<(usize, usize)> {
    let mut pairs = Vec::with_capacity(n * (n - 1) / 2);
    for i in (2..=n).rev() {
        for j in 1..i {
            pairs.push((i, j));
        }
    }
    pairs
}

/// Number of MZI phases for an n×n unitary: n(n-1)/2.
pub fn num_phases(n: usize) -> usize {
    n * (n - 1) / 2
}

/// A Reck mesh: the phase vector (product order) and the diagonal D.
#[derive(Clone, Debug)]
pub struct ReckMesh {
    pub n: usize,
    /// φ_ij in `pair_order(n)` order.
    pub phases: Vec<f64>,
    /// ±1 diagonal.
    pub d: Vec<f32>,
}

impl ReckMesh {
    /// Identity-initialized mesh (all phases 0, D = +1).
    pub fn identity(n: usize) -> ReckMesh {
        ReckMesh { n, phases: vec![0.0; num_phases(n)], d: vec![1.0; n] }
    }

    /// Mesh with phases drawn U[0, 2π) — the unknown post-fab state.
    pub fn random(n: usize, rng: &mut crate::util::Rng) -> ReckMesh {
        let phases =
            (0..num_phases(n)).map(|_| rng.uniform_range(0.0, 2.0 * std::f64::consts::PI)).collect();
        ReckMesh { n, phases, d: vec![1.0; n] }
    }

    /// Synthesize the n×n orthogonal matrix U = D · Π R_ij(φ_ij) for an
    /// arbitrary *effective* phase vector (the caller applies noise first).
    pub fn synthesize_with(&self, effective_phases: &[f64]) -> Mat {
        assert_eq!(effective_phases.len(), self.phases.len());
        let n = self.n;
        let mut m = Mat::eye(n);
        // Product convention: U = D · R_{p_m} · … · R_{p_1} where p_t runs in
        // `pair_order` — i.e. the *reverse* of the elimination order used by
        // `decompose` (each factor peels from the right end there). This is
        // the transposed-ordering variant of Eq. 8's triangular mesh; both
        // orderings realize the same MZI triangle, just indexed from the
        // other corner.
        //
        // Right-multiplication by R_ij mixes columns (j-1) and (i-1); from
        // the embedding (i,i)=cos, (i,j)=−sin, (j,i)=sin, (j,j)=cos:
        //   col_j' = cosφ·col_j − sinφ·col_i
        //   col_i' = sinφ·col_j + cosφ·col_i
        for (&(i, j), &phi) in pair_order(n).iter().zip(effective_phases).rev() {
            apply_rotation_right(&mut m, i - 1, j - 1, phi);
        }
        // Left-multiplication by D scales rows.
        for r in 0..n {
            if self.d[r] < 0.0 {
                for v in m.row_mut(r) {
                    *v = -*v;
                }
            }
        }
        m
    }

    /// Synthesize with the stored (noise-free) phases.
    pub fn synthesize(&self) -> Mat {
        self.synthesize_with(&self.phases)
    }

    /// Decompose an orthogonal matrix into this parametrization. Returns the
    /// mesh; reconstruction satisfies `synthesize() ≈ u` to f32 accuracy.
    ///
    /// Algorithm: right-multiply U by R_ij(φ)ᵀ in `pair_order` (row n first,
    /// eliminating row i left-to-right: the rotation on columns (j, i)
    /// touches, within row i, only entries (i,j) and (i,i), and rows already
    /// reduced to ±e_r have zeros in both touched columns), choosing each φ
    /// to null entry (i, j). The problem recurses on the leading (i−1)-minor
    /// and what remains is the ±1 diagonal D. The synthesis product is the
    /// reverse of this elimination order.
    pub fn decompose(u: &Mat) -> ReckMesh {
        assert_eq!(u.rows, u.cols, "decompose expects square");
        let n = u.rows;
        // Work in f64.
        let mut m: Vec<f64> = u.data.iter().map(|&x| x as f64).collect();
        let idx = |r: usize, c: usize| r * n + c;
        let pairs = pair_order(n);
        let mut phases = vec![0.0f64; pairs.len()];
        for (t, &(i, j)) in pairs.iter().enumerate() {
            let (ri, cj, ci) = (i - 1, j - 1, i - 1);
            let a = m[idx(ri, cj)]; // entry to null (col j)
            let b = m[idx(ri, ci)]; // diagonal-ward entry (col i)
            // Right-multiplying by R(φ)ᵀ: col_j' = a·cosφ + b·sinφ;
            // null ⇒ φ = atan2(−a, b).
            let phi = (-a).atan2(b);
            phases[t] = phi;
            let (c, s) = (phi.cos(), phi.sin());
            for r in 0..n {
                let xj = m[idx(r, cj)];
                let xi = m[idx(r, ci)];
                // col_j' = cosφ·xj + sinφ·xi ; col_i' = −sinφ·xj + cosφ·xi
                m[idx(r, cj)] = c * xj + s * xi;
                m[idx(r, ci)] = -s * xj + c * xi;
            }
        }
        // Remaining matrix should be diag(±1).
        let mut d = vec![1.0f32; n];
        for r in 0..n {
            d[r] = if m[idx(r, r)] >= 0.0 { 1.0 } else { -1.0 };
        }
        ReckMesh { n, phases, d }
    }

    /// Apply U = D·ΠR to a vector in place without materializing U — used by
    /// hot loops that stream activations through the mesh. Cost O(n²).
    pub fn apply(&self, effective_phases: &[f64], x: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        // y = U x = D (R_{pm}·...·R_{p1}) x — apply factors right-to-left,
        // i.e. R_{p1} first (forward `pair_order`).
        for (&(i, j), &phi) in pair_order(self.n).iter().zip(effective_phases) {
            let (c, s) = (phi.cos() as f32, phi.sin() as f32);
            let (xi, xj) = (x[i - 1], x[j - 1]);
            // R embedding: row i: cos·x_i − sin·x_j ; row j: sin·x_i + cos·x_j
            x[i - 1] = c * xi - s * xj;
            x[j - 1] = s * xi + c * xj;
        }
        for r in 0..self.n {
            x[r] *= self.d[r];
        }
    }
}

/// In-place M := M · R_ij(φ) (0-indexed coordinates).
#[inline]
pub fn apply_rotation_right(m: &mut Mat, i: usize, j: usize, phi: f64) {
    let (c, s) = (phi.cos() as f32, phi.sin() as f32);
    let n = m.cols;
    for r in 0..m.rows {
        let row = &mut m.data[r * n..(r + 1) * n];
        let xj = row[j];
        let xi = row[i];
        row[j] = c * xj - s * xi;
        row[i] = s * xj + c * xi;
    }
}

/// Mean squared error to the *absolute* identity: ‖|U| − I‖²/n² — the paper's
/// observable IC quality metric MSEᵁ (§3.2; sign flips are unobservable).
pub fn abs_identity_mse(u: &Mat) -> f64 {
    let n = u.rows;
    let mut acc = 0.0f64;
    for r in 0..n {
        for c in 0..n {
            let target = if r == c { 1.0 } else { 0.0 };
            let d = u[(r, c)].abs() as f64 - target;
            acc += d * d;
        }
    }
    acc / (n * n) as f64
}

/// Whether U is a signed identity Ĩ (±1 diagonal) within tolerance.
pub fn is_signed_identity(u: &Mat, tol: f32) -> bool {
    for r in 0..u.rows {
        for c in 0..u.cols {
            let v = u[(r, c)];
            let ok = if r == c { (v.abs() - 1.0).abs() <= tol } else { v.abs() <= tol };
            if !ok {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::orthogonality_error;
    use crate::util::prop::{assert_close, quickcheck};
    use crate::util::Rng;

    #[test]
    fn pair_order_count() {
        assert_eq!(pair_order(9).len(), num_phases(9));
        assert_eq!(num_phases(9), 36);
        assert_eq!(pair_order(3), vec![(3, 1), (3, 2), (2, 1)]);
    }

    #[test]
    fn identity_mesh_is_identity() {
        let mesh = ReckMesh::identity(6);
        assert_close(&mesh.synthesize().data, &Mat::eye(6).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn synthesized_is_orthogonal() {
        let mut rng = Rng::new(21);
        for n in [2, 3, 5, 9, 16] {
            let mesh = ReckMesh::random(n, &mut rng);
            let u = mesh.synthesize();
            assert!(orthogonality_error(&u) < 1e-5, "n={n}");
        }
    }

    #[test]
    fn prop_decompose_roundtrip() {
        // Random orthogonal (from SVD of a random matrix) → phases → back.
        quickcheck(
            "reck decompose/synthesize roundtrip",
            |rng, size| {
                let n = 2 + size % 12;
                let a = Mat::randn(n, n, 1.0, rng);
                crate::linalg::svd_kxk(&a).u
            },
            |u| {
                let mesh = ReckMesh::decompose(u);
                let u2 = mesh.synthesize();
                assert_close(&u2.data, &u.data, 5e-4, 5e-4)
            },
        );
    }

    #[test]
    fn decompose_identity_gives_zero_phases() {
        let mesh = ReckMesh::decompose(&Mat::eye(5));
        for &p in &mesh.phases {
            assert!(p.abs() < 1e-9);
        }
        assert_eq!(mesh.d, vec![1.0; 5]);
    }

    #[test]
    fn decompose_captures_sign_flips() {
        let mut neg = Mat::eye(4);
        neg[(1, 1)] = -1.0;
        neg[(3, 3)] = -1.0;
        let mesh = ReckMesh::decompose(&neg);
        let u = mesh.synthesize();
        assert_close(&u.data, &neg.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn apply_matches_synthesize() {
        let mut rng = Rng::new(33);
        let mesh = ReckMesh::random(9, &mut rng);
        let u = mesh.synthesize();
        let mut x: Vec<f32> = (0..9).map(|i| (i as f32) - 4.0).collect();
        let expect = crate::linalg::matvec(&u, &x);
        mesh.apply(&mesh.phases, &mut x);
        assert_close(&x, &expect, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn abs_identity_metrics() {
        let eye = Mat::eye(5);
        assert!(abs_identity_mse(&eye) < 1e-12);
        let mut flip = Mat::eye(5);
        flip[(2, 2)] = -1.0;
        // Sign flips are invisible to the abs metric.
        assert!(abs_identity_mse(&flip) < 1e-12);
        assert!(is_signed_identity(&flip, 1e-6));
        let mut rng = Rng::new(5);
        let rand = crate::linalg::svd_kxk(&Mat::randn(5, 5, 1.0, &mut rng)).u;
        assert!(abs_identity_mse(&rand) > 1e-3);
        assert!(!is_signed_identity(&rand, 1e-2));
    }
}
