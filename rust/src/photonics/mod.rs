//! The photonic hardware substrate: MZI device physics, Reck-style unitary
//! meshes, the non-ideality models of Appendix A.3, single k×k photonic
//! tensor cores (PTCs), and the P×Q blocked mesh that realizes an M×N weight.
//!
//! Everything the paper's chip does in optics is simulated here in the same
//! restricted-operation terms: a PTC exposes only {apply U, apply U*, apply
//! V*, apply V, program phases, program Σ, read coherent output}. The
//! higher stages (`crate::stages`) are written against that restricted
//! interface, so the hardware constraints of §2 are honored by construction.

pub mod dispersion;
pub mod mzi;
pub mod unitary;
pub mod noise;
pub mod ptc;
pub mod mesh;
pub mod shard;

pub use mesh::PtcMesh;
pub use noise::NoiseModel;
pub use ptc::{PhaseOverlay, Ptc};
pub use shard::{ShardPolicy, ShardedMesh, ShardingConfig};
pub use unitary::ReckMesh;
