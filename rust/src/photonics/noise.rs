//! Optical circuit non-ideality models (paper Appendix A.3, Eq. 1/9/10).
//!
//! The effective phase a device realizes is
//!
//! ```text
//! Φ_eff = Ω · Γ · Q(Φ_programmed) + Φ_b
//! ```
//!
//! * `Q(·)` — b-bit uniform phase quantization over [0, 2π) (Eq. 9);
//! * `Γ`    — static multiplicative device variation, γᵢ ~ N(1, σ_γ²)
//!            (paper: Δγ ~ N(0, 0.002²));
//! * `Ω`    — thermal crosstalk: tridiagonal coupling between physically
//!            adjacent MZIs, self-coupling 1, neighbor coupling 0.005
//!            (Eq. 10, [31]);
//! * `Φ_b`  — unknown static phase bias from manufacturing, ~ U(0, 2π).
//!
//! Γ and Φ_b are frozen per device instance (they model *manufacturing*
//! outcomes); Q and Ω are deterministic functions of the programmed phases.
//!
//! **Lifecycle effects** (thermal drift, aging, stuck/dead devices) are *not*
//! part of this static model: they evolve over training steps and are
//! injected through the [`crate::photonics::PhaseOverlay`] hook on `Ptc`,
//! which perturbs the effective phases *after* this pipeline runs (i.e.
//! post-quantization, like any analog disturbance). See `crate::robustness`
//! for the drift processes, fault schedules, and the watchdog that detects
//! and recovers from them in situ.

use crate::util::Rng;

/// Configuration of the non-ideality models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NoiseModel {
    /// Phase control bit width for U/V* meshes; `None` = ideal analog control.
    pub phase_bits: Option<u32>,
    /// Bit width for the Σ attenuator controls (paper assumes it affordable
    /// to be larger); `None` = ideal.
    pub sigma_bits: Option<u32>,
    /// Std of the multiplicative γ variation (paper: 0.002).
    pub gamma_std: f64,
    /// Mutual coupling coefficient for adjacent MZIs (paper: 0.005).
    pub crosstalk: f64,
    /// Whether an unknown U(0, 2π) phase bias is present.
    pub phase_bias: bool,
}

impl NoiseModel {
    /// All non-idealities off.
    pub const IDEAL: NoiseModel = NoiseModel {
        phase_bits: None,
        sigma_bits: None,
        gamma_std: 0.0,
        crosstalk: 0.0,
        phase_bias: false,
    };

    /// The paper's default evaluation setting: 8-bit phases, 16-bit Σ,
    /// σ_γ = 0.002, crosstalk 0.005, unknown phase bias present.
    pub const PAPER: NoiseModel = NoiseModel {
        phase_bits: Some(8),
        sigma_bits: Some(16),
        gamma_std: 0.002,
        crosstalk: 0.005,
        phase_bias: true,
    };

    /// The paper's Table-3 setting: quantization + variation + crosstalk but
    /// no unknown phase bias (the chip is assumed calibrated — "phase shifter
    /// gamma noise std=0.002, crosstalk factor=0.005, quantization 8-bit").
    pub const PAPER_NO_BIAS: NoiseModel = NoiseModel {
        phase_bits: Some(8),
        sigma_bits: Some(16),
        gamma_std: 0.002,
        crosstalk: 0.005,
        phase_bias: false,
    };

    /// Only quantization (Fig. 1(b) "Q").
    pub fn quant_only(bits: u32) -> NoiseModel {
        NoiseModel { phase_bits: Some(bits), ..NoiseModel::IDEAL }
    }
    /// Only crosstalk (Fig. 1(b) "CT").
    pub fn crosstalk_only(ct: f64) -> NoiseModel {
        NoiseModel { crosstalk: ct, ..NoiseModel::IDEAL }
    }
    /// Only device variation (Fig. 1(b) "DV").
    pub fn variation_only(std: f64) -> NoiseModel {
        NoiseModel { gamma_std: std, ..NoiseModel::IDEAL }
    }
    /// Only phase bias (Fig. 1(b) "PB").
    pub fn bias_only() -> NoiseModel {
        NoiseModel { phase_bias: true, ..NoiseModel::IDEAL }
    }

    pub fn is_ideal(&self) -> bool {
        *self == NoiseModel::IDEAL
    }
}

/// Uniform b-bit quantization of a phase into [0, 2π) (Eq. 9).
pub fn quantize_phase(phi: f64, bits: u32) -> f64 {
    let two_pi = 2.0 * std::f64::consts::PI;
    let levels = (1u64 << bits) as f64 - 1.0;
    let wrapped = phi.rem_euclid(two_pi);
    (wrapped / (two_pi / levels)).round() * (two_pi / levels)
}

/// The smallest representable phase step at b bits — the ZOO step-size bound
/// used by Algorithm 1 (δφ = 2π/(2^b − 1)).
pub fn phase_resolution(bits: u32) -> f64 {
    2.0 * std::f64::consts::PI / ((1u64 << bits) as f64 - 1.0)
}

/// Frozen manufacturing outcome for one mesh of `n_phases` shifters.
#[derive(Clone, Debug)]
pub struct DeviceInstance {
    /// Multiplicative factors γᵢ (≈1).
    pub gamma: Vec<f64>,
    /// Static phase bias Φ_b.
    pub bias: Vec<f64>,
}

impl DeviceInstance {
    /// Sample a device: γᵢ ~ N(1, σ_γ²), bias ~ U(0, 2π) if enabled.
    pub fn sample(n_phases: usize, model: &NoiseModel, rng: &mut Rng) -> DeviceInstance {
        let gamma =
            (0..n_phases).map(|_| 1.0 + rng.normal_ms(0.0, model.gamma_std)).collect();
        let bias = if model.phase_bias {
            (0..n_phases).map(|_| rng.uniform_range(0.0, 2.0 * std::f64::consts::PI)).collect()
        } else {
            vec![0.0; n_phases]
        };
        DeviceInstance { gamma, bias }
    }

    /// An ideal (γ=1, bias=0) device.
    pub fn ideal(n_phases: usize) -> DeviceInstance {
        DeviceInstance { gamma: vec![1.0; n_phases], bias: vec![0.0; n_phases] }
    }

    /// Realize the effective phases: Φ_eff = Ω·Γ·Q(Φ) + Φ_b.
    /// Crosstalk couples chain-adjacent shifters (the triangular mesh is
    /// routed as a serpentine chain, so index adjacency = physical adjacency).
    pub fn effective_phases(&self, programmed: &[f64], model: &NoiseModel, out: &mut Vec<f64>) {
        let n = programmed.len();
        assert_eq!(self.gamma.len(), n, "device/phase count mismatch");
        out.clear();
        out.reserve(n);
        // Q then Γ.
        for (i, &phi) in programmed.iter().enumerate() {
            let q = match model.phase_bits {
                Some(b) => quantize_phase(phi, b),
                None => phi,
            };
            out.push(self.gamma[i] * q);
        }
        // Ω: tridiagonal coupling φᶜᵢ = φᵢ + ω·(φᵢ₋₁ + φᵢ₊₁).
        if model.crosstalk != 0.0 && n > 1 {
            let w = model.crosstalk;
            let prev_orig: Vec<f64> = out.clone();
            for i in 0..n {
                let mut v = prev_orig[i];
                if i > 0 {
                    v += w * prev_orig[i - 1];
                }
                if i + 1 < n {
                    v += w * prev_orig[i + 1];
                }
                out[i] = v;
            }
        }
        // Φ_b.
        for (o, &b) in out.iter_mut().zip(&self.bias) {
            *o += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_levels() {
        let b = 8;
        let step = phase_resolution(b);
        for phi in [0.0, 0.1, 1.0, 3.14, 6.0] {
            let q = quantize_phase(phi, b);
            // On-grid and within half a step.
            assert!((q / step - (q / step).round()).abs() < 1e-9);
            assert!((q - phi).abs() <= step / 2.0 + 1e-12, "phi={phi} q={q}");
        }
    }

    #[test]
    fn quantize_wraps() {
        let two_pi = 2.0 * std::f64::consts::PI;
        let q1 = quantize_phase(0.3, 8);
        let q2 = quantize_phase(0.3 + two_pi, 8);
        assert!((q1 - q2).abs() < 1e-9);
        let qn = quantize_phase(-0.3, 8);
        assert!((qn - quantize_phase(two_pi - 0.3, 8)).abs() < 1e-9);
    }

    #[test]
    fn ideal_device_identity() {
        let dev = DeviceInstance::ideal(5);
        let phases = vec![0.1, 0.2, 0.3, 0.4, 0.5];
        let mut out = Vec::new();
        dev.effective_phases(&phases, &NoiseModel::IDEAL, &mut out);
        assert_eq!(out, phases);
    }

    #[test]
    fn gamma_statistics() {
        let mut rng = Rng::new(1);
        let model = NoiseModel::variation_only(0.002);
        let dev = DeviceInstance::sample(10_000, &model, &mut rng);
        let mean: f64 = dev.gamma.iter().sum::<f64>() / 10_000.0;
        let var: f64 =
            dev.gamma.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / 10_000.0;
        assert!((mean - 1.0).abs() < 1e-4);
        assert!((var.sqrt() - 0.002).abs() < 2e-4);
        assert_eq!(dev.bias, vec![0.0; 10_000]);
    }

    #[test]
    fn crosstalk_tridiagonal() {
        let dev = DeviceInstance::ideal(3);
        let model = NoiseModel::crosstalk_only(0.01);
        let mut out = Vec::new();
        dev.effective_phases(&[1.0, 2.0, 3.0], &model, &mut out);
        assert!((out[0] - (1.0 + 0.01 * 2.0)).abs() < 1e-12);
        assert!((out[1] - (2.0 + 0.01 * (1.0 + 3.0))).abs() < 1e-12);
        assert!((out[2] - (3.0 + 0.01 * 2.0)).abs() < 1e-12);
    }

    #[test]
    fn bias_uniform() {
        let mut rng = Rng::new(2);
        let dev = DeviceInstance::sample(20_000, &NoiseModel::bias_only(), &mut rng);
        let mean: f64 = dev.bias.iter().sum::<f64>() / 20_000.0;
        assert!((mean - std::f64::consts::PI).abs() < 0.05, "mean {mean}");
        assert!(dev.bias.iter().all(|&b| (0.0..2.0 * std::f64::consts::PI).contains(&b)));
    }

    #[test]
    fn pipeline_order_matters() {
        // Bias must NOT be scaled by gamma or quantized (it is an additive
        // physical offset after control).
        let mut dev = DeviceInstance::ideal(1);
        dev.bias[0] = 0.123456;
        dev.gamma[0] = 2.0;
        let model = NoiseModel { phase_bits: Some(8), phase_bias: true, ..NoiseModel::IDEAL };
        let mut out = Vec::new();
        dev.effective_phases(&[1.0], &model, &mut out);
        let expect = 2.0 * quantize_phase(1.0, 8) + 0.123456;
        assert!((out[0] - expect).abs() < 1e-12);
    }
}
