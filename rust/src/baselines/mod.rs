//! Baselines the paper compares against.
//!
//! * **On-chip training protocols** (Fig. 10 / Table 1):
//!   [`flops_train`] — FLOPS [20], stochastic zeroth-order optimization of
//!   *all* MZI phases with multi-sample RGE; [`mixedtrn_train`] — MixedTrn
//!   [17], sparse mixed ZO training (a sparse subset of phases gets ZO
//!   updates each step). Both operate on the full phase space and therefore
//!   hit the curse of dimensionality exactly as the paper reports.
//! * **Sparse training methods** (Fig. 11 / Table 2), realized as `SlConfig`
//!   presets over the same subspace-learning loop: [`rad_config`] — RAD
//!   [36], spatial sampling of activations (saves memory, not PTC calls);
//!   [`swat_config`] — SWAT-U [38], shared forward/feedback weight
//!   sparsification plus spatial feature sampling; [`l2ight_sl_config`] —
//!   the proposed multi-level sampling (btopk feedback + column + data).

use crate::data::Dataset;
use crate::nn::{softmax_cross_entropy, Model, ProjEngine};
use crate::profiler::{forward_cost, CostBreakdown, LayerCost};
use crate::sampling::{
    ColumnSampler, DataSampler, FeedbackSampler, FeedbackStrategy, Normalization,
};
use crate::stages::sl::SlConfig;
use crate::util::Rng;

/// Result of a ZO protocol run (FLOPS / MixedTrn).
#[derive(Clone, Debug, Default)]
pub struct ZoTrainReport {
    pub final_test_acc: f32,
    pub best_test_acc: f32,
    /// Loss after each epoch.
    pub loss_trace: Vec<f32>,
    /// Test accuracy after each epoch (the evals the loop already runs —
    /// recording them adds no queries).
    pub epoch_test_acc: Vec<f32>,
    /// Cumulative query count at the end of each epoch; feeds the
    /// queries-to-target budget-parity metric.
    pub epoch_queries: Vec<u64>,
    /// Total forward queries issued (each is one full-model inference).
    pub queries: u64,
    /// Hardware cost: queries × per-batch forward cost.
    pub cost: CostBreakdown,
}

/// Shared configuration for the ZO training protocols.
#[derive(Clone, Copy, Debug)]
pub struct ZoTrainConfig {
    pub epochs: usize,
    pub batch: usize,
    pub lr: f64,
    /// RGE gradient samples per step (FLOPS; paper setting 5).
    pub grad_samples: usize,
    /// Smoothing radius for RGE.
    pub mu: f64,
    /// MixedTrn: fraction of phases updated per step (mixed-training
    /// sparsity 0.4 × parameter sparsity 0.1 in the paper's setting).
    pub phase_fraction: f64,
    pub seed: u64,
}

impl Default for ZoTrainConfig {
    fn default() -> Self {
        // Paper Appendix E settings for FLOPS (lr 2 is in *phase* units of
        // the author implementation; our loss scale wants smaller).
        ZoTrainConfig {
            epochs: 50,
            batch: 32,
            lr: 0.05,
            grad_samples: 5,
            mu: 0.02,
            phase_fraction: 0.04,
            seed: 0xf10b5,
        }
    }
}

/// Flattened view of every programmable phase in a model's photonic meshes.
struct PhaseSpace {
    /// (layer engine index, ptc index, which, phase index) per coordinate.
    coords: Vec<(usize, usize, crate::photonics::ptc::Which, usize)>,
}

impl PhaseSpace {
    fn build(model: &mut Model) -> PhaseSpace {
        use crate::photonics::ptc::Which;
        let mut coords = Vec::new();
        let mut ei = 0usize;
        model.for_each_layer(|l| match l.engine_mut() {
            Some(ProjEngine::Photonic { mesh, .. }) => {
                for (pi, ptc) in mesh.ptcs.iter().enumerate() {
                    let m = ptc.u_mesh.phases.len();
                    for which in [Which::U, Which::V] {
                        for i in 0..m {
                            coords.push((ei, pi, which, i));
                        }
                    }
                }
                ei += 1;
            }
            Some(ProjEngine::PhotonicSharded { mesh, .. }) => {
                // Logical block order — the same coordinate space as the
                // unsharded twin, whatever the shard layout.
                let mut pi = 0usize;
                mesh.for_each_ptc_logical(|ptc| {
                    let m = ptc.u_mesh.phases.len();
                    for which in [Which::U, Which::V] {
                        for i in 0..m {
                            coords.push((ei, pi, which, i));
                        }
                    }
                    pi += 1;
                });
                ei += 1;
            }
            _ => {}
        });
        PhaseSpace { coords }
    }

    fn len(&self) -> usize {
        self.coords.len()
    }

    /// Write a sparse set of coordinate deltas.
    fn nudge(&self, model: &mut Model, idx: &[usize], delta: &[f64]) {
        use crate::photonics::ptc::Which;
        // Group by engine to minimize invalidations.
        let mut ei = 0usize;
        model.for_each_layer(|l| match l.engine_mut() {
            Some(ProjEngine::Photonic { mesh, .. }) => {
                let mut touched = false;
                for (&ix, &d) in idx.iter().zip(delta) {
                    let (e, pi, which, i) = self.coords[ix];
                    if e != ei {
                        continue;
                    }
                    let ptc = &mut mesh.ptcs[pi];
                    let cur = ptc.phase(which, i);
                    ptc.set_phase(which, i, cur + d);
                    touched = true;
                    let _ = matches!(which, Which::U);
                }
                if touched {
                    mesh.invalidate();
                }
                ei += 1;
            }
            Some(ProjEngine::PhotonicSharded { mesh, .. }) => {
                for (&ix, &d) in idx.iter().zip(delta) {
                    let (e, pi, which, i) = self.coords[ix];
                    if e != ei {
                        continue;
                    }
                    // ptc_logical_mut invalidates the owning shard's cache.
                    let ptc = mesh.ptc_logical_mut(pi);
                    let cur = ptc.phase(which, i);
                    ptc.set_phase(which, i, cur + d);
                }
                ei += 1;
            }
            _ => {}
        });
    }
}

/// Mini-batch loss of the model on `idx` (one hardware query).
fn batch_loss(model: &mut Model, ds: &Dataset, idx: &[usize]) -> f32 {
    let (x, labels) = ds.gather(idx, None);
    let logits = model.forward(&x, true);
    let (loss, _) = softmax_cross_entropy(&logits.mat, &labels);
    model.clear_caches();
    loss
}

/// Per-query forward cost of the model (ZO protocols pay this per eval).
fn model_forward_cost(model: &mut Model, batch: usize) -> CostBreakdown {
    let mut layers: Vec<LayerCost> = Vec::new();
    model.for_each_layer(|l| match l.engine_mut() {
        Some(ProjEngine::Photonic { mesh, .. }) => {
            layers.push(LayerCost {
                p: mesh.p,
                q: mesh.q,
                k: mesh.k,
                out_cols: 1,
                in_cols: 1,
            });
        }
        Some(ProjEngine::PhotonicSharded { mesh, .. }) => {
            layers.push(LayerCost {
                p: mesh.p,
                q: mesh.q,
                k: mesh.k,
                out_cols: 1,
                in_cols: 1,
            });
        }
        _ => {}
    });
    forward_cost(&layers, batch)
}

/// FLOPS [20]: full-space stochastic zeroth-order training. Every step
/// estimates the phase gradient with `grad_samples` two-point RGE queries
/// and applies SGD on *all* phases.
pub fn flops_train(
    model: &mut Model,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &ZoTrainConfig,
) -> ZoTrainReport {
    let space = PhaseSpace::build(model);
    let n = space.len();
    let per_query = model_forward_cost(model, cfg.batch);
    let mut rng = Rng::with_stream(cfg.seed, 0);
    let mut report = ZoTrainReport::default();
    let all: Vec<usize> = (0..n).collect();
    let mut lr = cfg.lr;
    for _epoch in 0..cfg.epochs {
        let loader = crate::data::Loader::new(train_set.n, cfg.batch, &mut rng);
        let mut epoch_loss = 0.0f32;
        let mut batches = 0;
        for idx in loader {
            let f0 = batch_loss(model, train_set, &idx);
            report.queries += 1;
            // Averaged RGE over grad_samples random directions.
            let mut g = vec![0.0f64; n];
            for _ in 0..cfg.grad_samples {
                let u: Vec<f64> = (0..n).map(|_| rng.normal() * cfg.mu).collect();
                space.nudge(model, &all, &u);
                let fp = batch_loss(model, train_set, &idx);
                report.queries += 1;
                let neg: Vec<f64> = u.iter().map(|v| -v).collect();
                space.nudge(model, &all, &neg);
                let scale = (fp - f0) as f64 / (cfg.mu * cfg.mu * cfg.grad_samples as f64);
                for (gi, ui) in g.iter_mut().zip(&u) {
                    *gi += scale * ui;
                }
            }
            let step: Vec<f64> = g.iter().map(|gi| -lr * gi).collect();
            space.nudge(model, &all, &step);
            epoch_loss += f0;
            batches += 1;
        }
        lr *= 0.98;
        report.loss_trace.push(epoch_loss / batches.max(1) as f32);
        let acc = test_set.evaluate(model, cfg.batch);
        report.epoch_test_acc.push(acc);
        report.epoch_queries.push(report.queries);
        report.best_test_acc = report.best_test_acc.max(acc);
        report.final_test_acc = acc;
    }
    report.cost = per_query.scale(report.queries as f64);
    report
}

/// MixedTrn [17]: sparse mixed-training — per step, ZO coordinate updates on
/// a small random subset of phases (importance-weighted toward high-|σ|
/// blocks), leaving the rest frozen.
pub fn mixedtrn_train(
    model: &mut Model,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &ZoTrainConfig,
) -> ZoTrainReport {
    let space = PhaseSpace::build(model);
    let n = space.len();
    let per_query = model_forward_cost(model, cfg.batch);
    let mut rng = Rng::with_stream(cfg.seed, 1);
    let mut report = ZoTrainReport::default();
    let subset = ((n as f64 * cfg.phase_fraction).ceil() as usize).clamp(1, n);
    let mut step = cfg.lr;
    for _epoch in 0..cfg.epochs {
        let loader = crate::data::Loader::new(train_set.n, cfg.batch, &mut rng);
        let mut epoch_loss = 0.0f32;
        let mut batches = 0;
        for idx in loader {
            let mut f0 = batch_loss(model, train_set, &idx);
            report.queries += 1;
            epoch_loss += f0;
            batches += 1;
            // Coordinate-descent sweep over the sparse active set.
            let active = rng.choose_k(n, subset);
            for &c in &active {
                space.nudge(model, &[c], &[step]);
                let fp = batch_loss(model, train_set, &idx);
                report.queries += 1;
                if fp < f0 {
                    f0 = fp;
                    continue;
                }
                space.nudge(model, &[c], &[-2.0 * step]);
                let fm = batch_loss(model, train_set, &idx);
                report.queries += 1;
                if fm < f0 {
                    f0 = fm;
                } else {
                    space.nudge(model, &[c], &[step]);
                }
            }
        }
        step = (step * 0.95).max(1e-3);
        report.loss_trace.push(epoch_loss / batches.max(1) as f32);
        let acc = test_set.evaluate(model, cfg.batch);
        report.epoch_test_acc.push(acc);
        report.epoch_queries.push(report.queries);
        report.best_test_acc = report.best_test_acc.max(acc);
        report.final_test_acc = acc;
    }
    report.cost = per_query.scale(report.queries as f64);
    report
}

/// Convert a *keep* fraction α (the paper's Table-2 convention — App. D's
/// c_W = 1/α_W means α_W is the kept share) into the samplers' internal
/// *dropped* fraction. α ≥ 1 means dense/off.
fn drop_frac(alpha: f32) -> Option<f32> {
    if alpha >= 1.0 {
        None
    } else {
        Some((1.0 - alpha).clamp(0.0, 0.999))
    }
}

/// RAD [36] preset: uniform spatial activation sampling with
/// expectation-maintained normalization; dense feedback (the backward pass
/// stays unoptimized — the paper's criticism). `alpha_s` = keep fraction.
pub fn rad_config(alpha_s: f32, base: &SlConfig) -> SlConfig {
    let feature = match drop_frac(alpha_s) {
        Some(d) => ColumnSampler::spatial(d, true),
        None => ColumnSampler::OFF,
    };
    SlConfig { feature, feedback: None, ..base.clone() }
}

/// SWAT-U [38] preset: uniform weight-matrix sampling shared between forward
/// and feedback (set via [`apply_swat_forward_masks`] each epoch) plus
/// unnormalized spatial feature sampling. α values are keep fractions.
pub fn swat_config(alpha_w: f32, alpha_s: f32, base: &SlConfig) -> SlConfig {
    let feature = match drop_frac(alpha_s) {
        Some(d) => ColumnSampler::spatial(d, false),
        None => ColumnSampler::OFF,
    };
    SlConfig {
        feature,
        feedback: drop_frac(alpha_w).map(|d| {
            FeedbackSampler::new(FeedbackStrategy::Uniform, d, Normalization::Exp)
        }),
        ..base.clone()
    }
}

/// The proposed multi-level sampling preset (§3.4.2): btopk feedback with
/// exp normalization, column sampling (α_C scaling off per the paper), SMD.
/// `alpha_w`/`alpha_c` are keep fractions; `alpha_d` is the SMD skip
/// probability.
pub fn l2ight_sl_config(alpha_w: f32, alpha_c: f32, alpha_d: f32, base: &SlConfig) -> SlConfig {
    SlConfig {
        feedback: drop_frac(alpha_w).map(|d| {
            FeedbackSampler::new(FeedbackStrategy::BTopK, d, Normalization::Exp)
        }),
        feature: match drop_frac(alpha_c) {
            Some(d) => ColumnSampler::column(d),
            None => ColumnSampler::OFF,
        },
        data: DataSampler::new(alpha_d),
        ..base.clone()
    }
}

/// SWAT-U's forward sparsification: mask the lowest-magnitude weights (or
/// lowest-norm blocks) in every projection engine's *forward* path, keeping
/// fraction `alpha_w`. Call once per epoch (SWAT re-draws masks slowly).
pub fn apply_swat_forward_masks(model: &mut Model, alpha_w: f32) {
    model.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            match e {
                ProjEngine::Digital { w, fwd_mask, .. } => {
                    let n = w.data.len();
                    let keep = ((n as f32 * alpha_w).ceil() as usize).clamp(1, n);
                    let mut order: Vec<usize> = (0..n).collect();
                    order.sort_by(|&a, &b| {
                        w.data[b].abs().partial_cmp(&w.data[a].abs()).unwrap()
                    });
                    let mut mask = vec![false; n];
                    for &i in order.iter().take(keep) {
                        mask[i] = true;
                    }
                    *fwd_mask = Some(mask);
                }
                ProjEngine::Photonic { mesh, fwd_mask, .. } => {
                    let norms = mesh.block_norms_sq();
                    let n = norms.len();
                    let keep = ((n as f32 * alpha_w).ceil() as usize).clamp(1, n);
                    let mut order: Vec<usize> = (0..n).collect();
                    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
                    let mut mask = vec![false; n];
                    for &i in order.iter().take(keep) {
                        mask[i] = true;
                    }
                    *fwd_mask = Some((mask, 1.0 / alpha_w));
                }
                ProjEngine::PhotonicSharded { mesh, fwd_mask, .. } => {
                    // Logical-order block norms → the mask is bitwise the
                    // same as the unsharded engine's at any shard count.
                    let norms = mesh.block_norms_sq();
                    let n = norms.len();
                    let keep = ((n as f32 * alpha_w).ceil() as usize).clamp(1, n);
                    let mut order: Vec<usize> = (0..n).collect();
                    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
                    let mut mask = vec![false; n];
                    for &i in order.iter().take(keep) {
                        mask[i] = true;
                    }
                    *fwd_mask = Some((mask, 1.0 / alpha_w));
                }
            }
        }
    });
}

/// Clear SWAT forward masks (inference runs dense — Appendix E).
pub fn clear_forward_masks(model: &mut Model) {
    model.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            match e {
                ProjEngine::Digital { fwd_mask, .. } => *fwd_mask = None,
                ProjEngine::Photonic { fwd_mask, .. } => *fwd_mask = None,
                ProjEngine::PhotonicSharded { fwd_mask, .. } => *fwd_mask = None,
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, SynthSpec};
    use crate::nn::{build_model, EngineKind, ModelArch};
    use crate::photonics::NoiseModel;

    fn tiny_setup() -> (Model, Dataset, Dataset) {
        let mut rng = Rng::new(41);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let model = build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng);
        let (tr, te) =
            SynthSpec::quick(DatasetKind::VowelLike, 48, 24).with_difficulty(0.3).generate();
        (model, tr, te)
    }

    #[test]
    fn flops_improves_tiny_model() {
        let (mut model, tr, te) = tiny_setup();
        let before = te.evaluate(&mut model, 16);
        let cfg = ZoTrainConfig { epochs: 6, batch: 16, ..Default::default() };
        let r = flops_train(&mut model, &tr, &te, &cfg);
        assert!(r.queries > 0);
        assert!(r.cost.total_energy() > 0.0);
        assert!(
            r.best_test_acc >= before || r.loss_trace.last() < r.loss_trace.first(),
            "FLOPS made no progress: acc {} -> {}, loss {:?}",
            before,
            r.best_test_acc,
            r.loss_trace
        );
    }

    #[test]
    fn mixedtrn_improves_tiny_model() {
        let (mut model, tr, te) = tiny_setup();
        let cfg = ZoTrainConfig { epochs: 4, batch: 16, lr: 0.1, ..Default::default() };
        let r = mixedtrn_train(&mut model, &tr, &te, &cfg);
        assert!(r.queries > 0);
        assert!(
            r.loss_trace.last().unwrap() < r.loss_trace.first().unwrap(),
            "MixedTrn loss did not drop: {:?}",
            r.loss_trace
        );
    }

    #[test]
    fn zo_reports_carry_per_epoch_traces() {
        let (mut model, tr, te) = tiny_setup();
        let cfg = ZoTrainConfig { epochs: 3, batch: 16, ..Default::default() };
        let r = flops_train(&mut model, &tr, &te, &cfg);
        assert_eq!(r.epoch_test_acc.len(), 3);
        assert_eq!(r.epoch_queries.len(), 3);
        // Cumulative queries are nondecreasing and end at the total.
        for w in r.epoch_queries.windows(2) {
            assert!(w[1] >= w[0], "epoch queries must be cumulative: {:?}", r.epoch_queries);
        }
        assert_eq!(*r.epoch_queries.last().unwrap(), r.queries);
        assert_eq!(*r.epoch_test_acc.last().unwrap(), r.final_test_acc);
        let best = r.epoch_test_acc.iter().cloned().fold(0.0f32, f32::max);
        assert_eq!(best, r.best_test_acc);
    }

    #[test]
    fn zo_protocol_queries_price_forward_cost() {
        let (mut model, tr, te) = tiny_setup();
        let cfg = ZoTrainConfig { epochs: 1, batch: 16, grad_samples: 2, ..Default::default() };
        let r = flops_train(&mut model, &tr, &te, &cfg);
        let per_query = model_forward_cost(&mut model, cfg.batch);
        assert!(
            (r.cost.total_energy() - per_query.total_energy() * r.queries as f64).abs() < 1e-6
        );
    }

    #[test]
    fn swat_masks_sparsify_forward() {
        let (mut model, _, _) = tiny_setup();
        apply_swat_forward_masks(&mut model, 0.5);
        let mut found = false;
        model.for_each_layer(|l| {
            if let Some(ProjEngine::Photonic { fwd_mask, .. }) = l.engine_mut() {
                let (mask, scale) = fwd_mask.as_ref().expect("mask applied");
                let kept = mask.iter().filter(|&&k| k).count();
                assert!(kept < mask.len() || mask.len() == 1);
                assert!((*scale - 2.0).abs() < 1e-6);
                found = true;
            }
        });
        assert!(found);
        clear_forward_masks(&mut model);
        model.for_each_layer(|l| {
            if let Some(ProjEngine::Photonic { fwd_mask, .. }) = l.engine_mut() {
                assert!(fwd_mask.is_none());
            }
        });
    }

    #[test]
    fn presets_wire_expected_samplers() {
        let base = SlConfig::quick(1, 8);
        let rad = rad_config(0.85, &base);
        assert!(rad.feedback.is_none());
        let swat = swat_config(0.3, 0.6, &base);
        assert!(matches!(
            swat.feedback.as_ref().map(|f| f.strategy),
            Some(FeedbackStrategy::Uniform)
        ));
        let ours = l2ight_sl_config(0.6, 0.6, 0.5, &base);
        assert!(matches!(
            ours.feedback.as_ref().map(|f| f.strategy),
            Some(FeedbackStrategy::BTopK)
        ));
        // Keep fraction 0.6 -> drop fraction 0.4 inside the sampler.
        assert!((ours.feedback.unwrap().sparsity - 0.4).abs() < 1e-6);
        assert!(ours.data.sparsity > 0.0);
        // α = 1.0 means dense/off everywhere.
        let dense = l2ight_sl_config(1.0, 1.0, 0.0, &base);
        assert!(dense.feedback.is_none());
    }
}
