//! Zeroth-order optimizers for the hardware-restricted stages (IC and PM),
//! where analytic phase gradients are unobtainable in situ (Appendix B).
//!
//! * `Zgd` — ZO stochastic gradient descent with momentum [15]: random-
//!   direction gradient estimation (RGE) with a two-point query.
//! * `Zcd` — ZO coordinate descent [30]: Algorithm 1's inner loop — try
//!   ±δφ on one random coordinate, keep the better; δφ is bounded by the
//!   phase-control resolution and decays exponentially.
//! * `Ztp` — ZO three-point method [13]: evaluate f(x), f(x±δu) along a
//!   random direction, move to the argmin.
//!
//! Each supports best-solution recording (the "-B" variants of Fig. 4(b)).

use crate::util::Rng;

/// A zeroth-order optimization problem: evaluate the loss at the current
/// phase vector. The optimizer owns the query budget accounting.
pub trait ZoProblem {
    /// Number of optimization variables.
    fn dim(&self) -> usize;
    /// Loss at `phases` (one hardware query).
    fn eval(&mut self, phases: &[f64]) -> f64;
}

/// Result of a ZOO run.
#[derive(Clone, Debug)]
pub struct ZoReport {
    /// Best phases found.
    pub best_phases: Vec<f64>,
    /// Best loss.
    pub best_loss: f64,
    /// Loss after each outer iteration (for convergence plots, Fig. 4(b)).
    pub trace: Vec<f64>,
    /// Total number of `eval` queries issued (the energy proxy for ZO
    /// protocols, Appendix G).
    pub queries: u64,
}

/// Shared configuration.
#[derive(Clone, Copy, Debug)]
pub struct ZoConfig {
    pub iters: usize,
    /// Initial step / smoothing radius (bounded by phase resolution in PM).
    pub step: f64,
    /// Multiplicative step decay per outer iteration.
    pub decay: f64,
    /// Step floor (e.g. the minimum phase-control resolution).
    pub step_floor: f64,
    /// Record and return the best-so-far solution ("-B" variants).
    pub best_recording: bool,
}

impl Default for ZoConfig {
    fn default() -> Self {
        ZoConfig { iters: 200, step: 0.1, decay: 0.99, step_floor: 1e-4, best_recording: true }
    }
}

/// ZO gradient descent with momentum (ZGD).
pub fn zgd<P: ZoProblem>(
    problem: &mut P,
    init: &[f64],
    cfg: ZoConfig,
    momentum: f64,
    rng: &mut Rng,
) -> ZoReport {
    let n = problem.dim();
    assert_eq!(init.len(), n);
    let mut x = init.to_vec();
    let mut vel = vec![0.0f64; n];
    let mut queries = 0u64;
    let mut f0 = problem.eval(&x);
    queries += 1;
    let mut best = (x.clone(), f0);
    let mut trace = Vec::with_capacity(cfg.iters);
    let mut step = cfg.step;
    let mut xp = vec![0.0f64; n];
    for _ in 0..cfg.iters {
        // RGE: g ≈ (f(x + μu) − f(x)) / μ · u with u ~ N(0, I).
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        for i in 0..n {
            xp[i] = x[i] + step * u[i];
        }
        let fp = problem.eval(&xp);
        queries += 1;
        let gscale = (fp - f0) / step;
        for i in 0..n {
            vel[i] = momentum * vel[i] + gscale * u[i];
            x[i] -= step * vel[i];
        }
        f0 = problem.eval(&x);
        queries += 1;
        if f0 < best.1 {
            best = (x.clone(), f0);
        }
        trace.push(if cfg.best_recording { best.1 } else { f0 });
        step = (step * cfg.decay).max(cfg.step_floor);
    }
    finish(best, x, f0, trace, queries, cfg)
}

/// ZO coordinate descent (ZCD) — Algorithm 1's inner loop.
pub fn zcd<P: ZoProblem>(
    problem: &mut P,
    init: &[f64],
    cfg: ZoConfig,
    inner: usize,
    rng: &mut Rng,
) -> ZoReport {
    let n = problem.dim();
    assert_eq!(init.len(), n);
    let mut x = init.to_vec();
    let mut f0 = problem.eval(&x);
    let mut queries = 1u64;
    let mut best = (x.clone(), f0);
    let mut trace = Vec::with_capacity(cfg.iters);
    let mut step = cfg.step;
    for _ in 0..cfg.iters {
        for _ in 0..inner {
            let c = rng.below(n);
            let orig = x[c];
            // Try +δφ; if it does not improve, move −δφ (Algorithm 1 l.9-12).
            x[c] = orig + step;
            let fp = problem.eval(&x);
            queries += 1;
            if fp < f0 {
                f0 = fp;
            } else {
                x[c] = orig - step;
                let fm = problem.eval(&x);
                queries += 1;
                if fm < f0 {
                    f0 = fm;
                } else {
                    x[c] = orig;
                }
            }
        }
        if f0 < best.1 {
            best = (x.clone(), f0);
        }
        trace.push(if cfg.best_recording { best.1 } else { f0 });
        step = (step * cfg.decay).max(cfg.step_floor);
    }
    finish(best, x, f0, trace, queries, cfg)
}

/// ZO three-point method (ZTP).
pub fn ztp<P: ZoProblem>(
    problem: &mut P,
    init: &[f64],
    cfg: ZoConfig,
    rng: &mut Rng,
) -> ZoReport {
    let n = problem.dim();
    assert_eq!(init.len(), n);
    let mut x = init.to_vec();
    let mut f0 = problem.eval(&x);
    let mut queries = 1u64;
    let mut best = (x.clone(), f0);
    let mut trace = Vec::with_capacity(cfg.iters);
    let mut step = cfg.step;
    let mut xp = vec![0.0f64; n];
    let mut xm = vec![0.0f64; n];
    for _ in 0..cfg.iters {
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let norm = u.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
        for i in 0..n {
            xp[i] = x[i] + step * u[i] / norm;
            xm[i] = x[i] - step * u[i] / norm;
        }
        let fp = problem.eval(&xp);
        let fm = problem.eval(&xm);
        queries += 2;
        if fp < f0 && fp <= fm {
            x.copy_from_slice(&xp);
            f0 = fp;
        } else if fm < f0 {
            x.copy_from_slice(&xm);
            f0 = fm;
        }
        if f0 < best.1 {
            best = (x.clone(), f0);
        }
        trace.push(if cfg.best_recording { best.1 } else { f0 });
        step = (step * cfg.decay).max(cfg.step_floor);
    }
    finish(best, x, f0, trace, queries, cfg)
}

fn finish(
    best: (Vec<f64>, f64),
    x: Vec<f64>,
    f0: f64,
    trace: Vec<f64>,
    queries: u64,
    cfg: ZoConfig,
) -> ZoReport {
    let (bx, bf) = if cfg.best_recording { best } else { (x, f0) };
    ZoReport { best_phases: bx, best_loss: bf, trace, queries }
}

/// Which ZO optimizer to run (for the benchmark sweeps of Fig. 4/5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZoKind {
    Zgd,
    Zcd,
    Ztp,
}

impl ZoKind {
    pub fn name(&self) -> &'static str {
        match self {
            ZoKind::Zgd => "ZGD",
            ZoKind::Zcd => "ZCD",
            ZoKind::Ztp => "ZTP",
        }
    }

    /// Run the chosen optimizer with sensible per-kind defaults.
    pub fn run<P: ZoProblem>(
        &self,
        problem: &mut P,
        init: &[f64],
        cfg: ZoConfig,
        rng: &mut Rng,
    ) -> ZoReport {
        match self {
            ZoKind::Zgd => zgd(problem, init, cfg, 0.9, rng),
            ZoKind::Zcd => zcd(problem, init, cfg, problem.dim(), rng),
            ZoKind::Ztp => ztp(problem, init, cfg, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smooth convex test problem: ‖x − c‖².
    struct Quad {
        c: Vec<f64>,
    }

    impl ZoProblem for Quad {
        fn dim(&self) -> usize {
            self.c.len()
        }
        fn eval(&mut self, x: &[f64]) -> f64 {
            x.iter().zip(&self.c).map(|(a, b)| (a - b) * (a - b)).sum()
        }
    }

    fn quad() -> Quad {
        Quad { c: vec![0.4, -0.3, 0.8, 0.1, -0.6] }
    }

    #[test]
    fn zcd_solves_quadratic() {
        let mut rng = Rng::new(1);
        let cfg = ZoConfig { iters: 300, step: 0.2, decay: 0.98, ..Default::default() };
        let r = zcd(&mut quad(), &[0.0; 5], cfg, 5, &mut rng);
        assert!(r.best_loss < 1e-2, "loss {}", r.best_loss);
        assert!(r.queries > 300);
    }

    #[test]
    fn ztp_solves_quadratic() {
        let mut rng = Rng::new(2);
        let cfg = ZoConfig { iters: 2000, step: 0.3, decay: 0.999, ..Default::default() };
        let r = ztp(&mut quad(), &[0.0; 5], cfg, &mut rng);
        assert!(r.best_loss < 5e-2, "loss {}", r.best_loss);
    }

    #[test]
    fn zgd_improves_quadratic() {
        let mut rng = Rng::new(3);
        let cfg = ZoConfig { iters: 1500, step: 0.02, decay: 0.9995, ..Default::default() };
        let r = zgd(&mut quad(), &[0.0; 5], cfg, 0.5, &mut rng);
        let initial: f64 = quad().eval(&[0.0; 5]);
        assert!(r.best_loss < initial * 0.6, "loss {} vs {initial}", r.best_loss);
    }

    #[test]
    fn best_recording_is_monotone() {
        let mut rng = Rng::new(4);
        let cfg = ZoConfig { iters: 100, step: 0.5, decay: 1.0, ..Default::default() };
        let r = ztp(&mut quad(), &[0.0; 5], cfg, &mut rng);
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "best-recording trace must be monotone");
        }
    }

    #[test]
    fn trace_length_matches_iters() {
        let mut rng = Rng::new(5);
        let cfg = ZoConfig { iters: 37, ..Default::default() };
        let r = zcd(&mut quad(), &[0.0; 5], cfg, 2, &mut rng);
        assert_eq!(r.trace.len(), 37);
    }

    #[test]
    fn step_floor_respected() {
        // With a huge decay, the step clamps at the floor and still queries.
        let mut rng = Rng::new(6);
        let cfg = ZoConfig {
            iters: 50,
            step: 0.1,
            decay: 0.01,
            step_floor: 0.05,
            best_recording: true,
        };
        let r = zcd(&mut quad(), &[0.0; 5], cfg, 1, &mut rng);
        assert!(r.best_loss.is_finite());
    }
}
