//! Golden-metrics regression gate.
//!
//! A *golden* is simply a checked-in scenario-matrix report (see
//! `report`). [`diff_reports`] compares a freshly produced report against
//! it: rows are matched by name, each row's `config` must match exactly
//! (a config drift silently invalidates every number, so it fails loudly),
//! and every numeric leaf under `metrics` is compared with a per-metric
//! tolerance. `threads`, `wall_secs`, and `stage_secs` are ignored —
//! wall-clock is not a reproduction claim.
//!
//! Bootstrapping: a golden containing `"placeholder": true` has never been
//! blessed; the gate reports [`GoldenOutcome::Unblessed`] and callers skip
//! it (CI stays green until someone runs `l2ight matrix --tier quick
//! --golden golden/matrix_quick.json --bless` on the gate platform and
//! commits the result).
//!
//! Tolerances exist for cross-platform libm drift (`sin`/`ln` differ at
//! the ulp level between libc implementations, and tiny-run training
//! amplifies that); on one platform the engine is bit-deterministic, which
//! is what [`Tolerances::STRICT`] asserts for the thread-invariance gate.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Per-metric-family allowances. Keys are classified by name: accuracies
/// get an absolute band, IC/PM fidelities a relative band, hardware cost a
/// (tight) relative band, and integer-valued counters must match exactly.
#[derive(Clone, Copy, Debug)]
pub struct Tolerances {
    /// Absolute band for `*acc*` metrics.
    pub acc_abs: f64,
    /// Relative band for `*mse*` / `*err*` metrics.
    pub fid_rel: f64,
    /// Relative band for `cost.*` energy/step counters.
    pub cost_rel: f64,
    /// Absolute band for integer counters (`*queries*`, `*params*`).
    pub count_abs: f64,
}

impl Tolerances {
    /// Zero tolerance everywhere — bitwise metric equality. Used for the
    /// thread-invariance gate (same binary, same platform).
    pub const STRICT: Tolerances =
        Tolerances { acc_abs: 0.0, fid_rel: 0.0, cost_rel: 0.0, count_abs: 0.0 };

    /// The CI golden gate: absorbs libm-level drift, still catches any
    /// real regression (an accuracy drop, a fidelity loss, a cost change).
    pub fn gate() -> Tolerances {
        Tolerances { acc_abs: 0.02, fid_rel: 0.10, cost_rel: 1e-6, count_abs: 0.0 }
    }

    /// Allowed |got − want| for metric `key` with golden value `want`.
    fn allowed(&self, key: &str, want: f64) -> f64 {
        if key.contains("acc") {
            self.acc_abs
        } else if key.contains("mse") || key.contains("err") {
            self.fid_rel * want.abs()
        } else if key.contains("queries") || key.contains("params") {
            self.count_abs
        } else {
            self.cost_rel * want.abs()
        }
    }
}

/// Forward-compatibility exemptions for scenario families added *after*
/// the checked-in golden was last blessed. An armed gate with exemptions
/// still holds every blessed row/metric to its tolerance, but tolerates
/// (a) report rows whose name starts with an exempted family prefix that
/// the golden has never seen, and (b) metric keys that exist only on the
/// report side. It never excuses the reverse direction — a golden row or
/// metric that disappears from the report stays a failure.
#[derive(Clone, Debug, Default)]
pub struct Exemptions {
    /// Row-name prefixes of families newer than the golden.
    pub new_row_prefixes: Vec<String>,
    /// Dotted metric-path prefixes newer than the golden.
    pub new_metric_keys: Vec<String>,
}

impl Exemptions {
    /// The standing exemption list for this revision: the families and
    /// metric keys added since the last bless. Shrink it back to empty when
    /// the goldens are re-blessed with the new rows included.
    pub fn current() -> Exemptions {
        Exemptions {
            new_row_prefixes: vec!["variation/".into(), "wdm/".into()],
            new_metric_keys: vec![
                "zo_to_target_queries".into(),
                "variation.".into(),
                "wdm.".into(),
            ],
        }
    }

    fn row_is_new(&self, name: &str) -> bool {
        self.new_row_prefixes.iter().any(|p| name.starts_with(p.as_str()))
    }

    fn metric_is_new(&self, key: &str) -> bool {
        self.new_metric_keys
            .iter()
            .any(|p| key == p.trim_end_matches('.') || key.starts_with(p.as_str()))
    }
}

/// One discrepancy between a report and its golden.
#[derive(Clone, Debug)]
pub struct GoldenDiff {
    /// Row name (or `<report>` for document-level problems).
    pub row: String,
    /// Dotted metric path (`cost.fwd_energy`), or `config` / `tier` / `row`.
    pub metric: String,
    pub got: String,
    pub want: String,
    pub detail: String,
}

/// Outcome of a golden comparison.
#[derive(Clone, Debug)]
pub enum GoldenOutcome {
    /// The golden is an unblessed placeholder; the gate is skipped.
    Unblessed,
    /// Every row and metric within tolerance.
    Match { rows: usize },
    /// At least one discrepancy (most severe first is not guaranteed;
    /// order follows row name / metric path).
    Mismatch(Vec<GoldenDiff>),
}

/// Read and parse a report / golden file.
pub fn load(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Flatten the numeric leaves of a `metrics` object into dotted paths.
/// `null` leaves are kept (as `None`) so presence is part of the contract.
fn flatten(j: &Json, path: &str, out: &mut BTreeMap<String, Option<f64>>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                flatten(v, &p, out);
            }
        }
        Json::Num(n) => {
            out.insert(path.to_string(), Some(*n));
        }
        Json::Null => {
            out.insert(path.to_string(), None);
        }
        _ => {}
    }
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "null".to_string(),
    }
}

fn diff_row(
    name: &str,
    got: &Json,
    want: &Json,
    tol: &Tolerances,
    ex: &Exemptions,
    out: &mut Vec<GoldenDiff>,
) {
    // Config drift makes every golden number meaningless — compare the
    // canonical (sorted-key) dumps exactly.
    let gc = got.get("config").map(|c| c.dump()).unwrap_or_default();
    let wc = want.get("config").map(|c| c.dump()).unwrap_or_default();
    if gc != wc {
        out.push(GoldenDiff {
            row: name.to_string(),
            metric: "config".to_string(),
            got: gc,
            want: wc,
            detail: "row config changed — re-bless the golden".to_string(),
        });
        return;
    }
    let mut gm = BTreeMap::new();
    let mut wm = BTreeMap::new();
    if let Some(j) = got.get("metrics") {
        flatten(j, "", &mut gm);
    }
    if let Some(j) = want.get("metrics") {
        flatten(j, "", &mut wm);
    }
    let keys: std::collections::BTreeSet<&String> = gm.keys().chain(wm.keys()).collect();
    for key in keys {
        let g = gm.get(key).copied();
        let w = wm.get(key).copied();
        match (g, w) {
            (Some(Some(g)), Some(Some(w))) => {
                let allowed = tol.allowed(key, w);
                let delta = (g - w).abs();
                // NaN/∞ deltas must fail, so check finiteness explicitly.
                let within = delta.is_finite() && delta <= allowed;
                if !within {
                    out.push(GoldenDiff {
                        row: name.to_string(),
                        metric: key.clone(),
                        got: format!("{g}"),
                        want: format!("{w}"),
                        detail: format!("|Δ| {delta} > allowed {allowed}"),
                    });
                }
            }
            (Some(None), Some(None)) => {}
            (g, w) => {
                // A key the golden has never seen is excusable when it is
                // on the standing new-metric exemption list (awaiting a
                // re-bless); a key that *vanished* from the report never is.
                if w.is_none() && g.is_some() && ex.metric_is_new(key) {
                    continue;
                }
                out.push(GoldenDiff {
                    row: name.to_string(),
                    metric: key.clone(),
                    got: fmt_opt(g.flatten()),
                    want: fmt_opt(w.flatten()),
                    detail: if g.is_none() || w.is_none() {
                        "metric present on one side only".to_string()
                    } else {
                        "metric null on one side only".to_string()
                    },
                });
            }
        }
    }
}

/// Compare a fresh report (`got`) against a golden (`want`) with no
/// exemptions — every row and metric key must be known to the golden.
pub fn diff_reports(got: &Json, want: &Json, tol: &Tolerances) -> GoldenOutcome {
    diff_reports_with(got, want, tol, &Exemptions::default())
}

/// Compare with a standing [`Exemptions`] list for not-yet-blessed
/// families (see `Exemptions::current`).
pub fn diff_reports_with(
    got: &Json,
    want: &Json,
    tol: &Tolerances,
    ex: &Exemptions,
) -> GoldenOutcome {
    if want.get("placeholder").and_then(|v| v.as_bool()) == Some(true) {
        return GoldenOutcome::Unblessed;
    }
    let mut diffs = Vec::new();
    let gt = got.get("tier").and_then(|v| v.as_str()).unwrap_or("");
    let wt = want.get("tier").and_then(|v| v.as_str()).unwrap_or("");
    if gt != wt {
        diffs.push(GoldenDiff {
            row: "<report>".to_string(),
            metric: "tier".to_string(),
            got: gt.to_string(),
            want: wt.to_string(),
            detail: "tier mismatch".to_string(),
        });
    }
    let empty: Vec<Json> = Vec::new();
    let g_rows = got.get("rows").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let w_rows = want.get("rows").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let by_name = |rows: &[Json]| -> BTreeMap<String, Json> {
        rows.iter()
            .filter_map(|r| {
                r.get("name").and_then(|n| n.as_str()).map(|n| (n.to_string(), r.clone()))
            })
            .collect()
    };
    let gmap = by_name(g_rows);
    let wmap = by_name(w_rows);
    for (name, wrow) in &wmap {
        match gmap.get(name) {
            None => diffs.push(GoldenDiff {
                row: name.clone(),
                metric: "row".to_string(),
                got: "<missing>".to_string(),
                want: "present".to_string(),
                detail: "golden row missing from report".to_string(),
            }),
            Some(grow) => diff_row(name, grow, wrow, tol, ex, &mut diffs),
        }
    }
    for name in gmap.keys() {
        if !wmap.contains_key(name) {
            if ex.row_is_new(name) {
                continue;
            }
            diffs.push(GoldenDiff {
                row: name.clone(),
                metric: "row".to_string(),
                got: "present".to_string(),
                want: "<missing>".to_string(),
                detail: "report row not in golden — re-bless after adding rows".to_string(),
            });
        }
    }
    if diffs.is_empty() {
        GoldenOutcome::Match { rows: wmap.len() }
    } else {
        GoldenOutcome::Mismatch(diffs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(rows: &[(&str, &[(&str, Option<f64>)])]) -> Json {
        let mut root = Json::obj();
        root.set("schema", Json::Num(1.0)).set("tier", Json::Str("quick".into()));
        let mut arr = Vec::new();
        for (name, metrics) in rows {
            let mut m = Json::obj();
            for (k, v) in *metrics {
                m.set(
                    k,
                    match v {
                        Some(x) => Json::Num(*x),
                        None => Json::Null,
                    },
                );
            }
            let mut row = Json::obj();
            row.set("name", Json::Str((*name).into()))
                .set("config", Json::obj())
                .set("metrics", m)
                .set("wall_secs", Json::Num(1.0));
            arr.push(row);
        }
        root.set("rows", Json::Arr(arr));
        root
    }

    #[test]
    fn identical_reports_match_strictly() {
        let a = report(&[("r1", &[("final_acc", Some(0.8)), ("ic_mse", None)])]);
        match diff_reports(&a, &a, &Tolerances::STRICT) {
            GoldenOutcome::Match { rows } => assert_eq!(rows, 1),
            other => panic!("expected match, got {other:?}"),
        }
    }

    #[test]
    fn wall_time_and_threads_are_ignored() {
        let mut a = report(&[("r1", &[("final_acc", Some(0.8))])]);
        let mut b = report(&[("r1", &[("final_acc", Some(0.8))])]);
        a.set("threads", Json::Num(1.0));
        b.set("threads", Json::Num(8.0));
        assert!(matches!(
            diff_reports(&a, &b, &Tolerances::STRICT),
            GoldenOutcome::Match { .. }
        ));
    }

    #[test]
    fn drift_beyond_tolerance_is_caught() {
        let want = report(&[("r1", &[("final_acc", Some(0.80))])]);
        let ok = report(&[("r1", &[("final_acc", Some(0.81))])]);
        let bad = report(&[("r1", &[("final_acc", Some(0.90))])]);
        assert!(matches!(
            diff_reports(&ok, &want, &Tolerances::gate()),
            GoldenOutcome::Match { .. }
        ));
        match diff_reports(&bad, &want, &Tolerances::gate()) {
            GoldenOutcome::Mismatch(ds) => {
                assert_eq!(ds.len(), 1);
                assert_eq!(ds[0].metric, "final_acc");
            }
            other => panic!("expected mismatch, got {other:?}"),
        }
        // STRICT rejects even the 0.01 drift.
        assert!(matches!(
            diff_reports(&ok, &want, &Tolerances::STRICT),
            GoldenOutcome::Mismatch(_)
        ));
    }

    #[test]
    fn null_vs_number_is_a_mismatch() {
        let want = report(&[("r1", &[("ic_mse", None)])]);
        let got = report(&[("r1", &[("ic_mse", Some(0.5))])]);
        assert!(matches!(
            diff_reports(&got, &want, &Tolerances::gate()),
            GoldenOutcome::Mismatch(_)
        ));
    }

    #[test]
    fn missing_and_extra_rows_are_mismatches() {
        let want = report(&[("r1", &[("final_acc", Some(0.5))])]);
        let got = report(&[("r2", &[("final_acc", Some(0.5))])]);
        match diff_reports(&got, &want, &Tolerances::gate()) {
            GoldenOutcome::Mismatch(ds) => assert_eq!(ds.len(), 2),
            other => panic!("expected mismatch, got {other:?}"),
        }
    }

    #[test]
    fn integer_counters_are_exact_even_in_gate_mode() {
        let want = report(&[("r1", &[("zo_queries", Some(100.0))])]);
        let got = report(&[("r1", &[("zo_queries", Some(101.0))])]);
        assert!(matches!(
            diff_reports(&got, &want, &Tolerances::gate()),
            GoldenOutcome::Mismatch(_)
        ));
    }

    #[test]
    fn config_drift_fails_loudly() {
        let mut want = report(&[("r1", &[("final_acc", Some(0.5))])]);
        let got = want.clone();
        // Mutate the golden row's config.
        if let Json::Obj(root) = &mut want {
            if let Some(Json::Arr(rows)) = root.get_mut("rows") {
                rows[0].set("config", {
                    let mut c = Json::obj();
                    c.set("k", Json::Num(9.0));
                    c
                });
            }
        }
        match diff_reports(&got, &want, &Tolerances::gate()) {
            GoldenOutcome::Mismatch(ds) => assert_eq!(ds[0].metric, "config"),
            other => panic!("expected mismatch, got {other:?}"),
        }
    }

    #[test]
    fn placeholder_golden_skips_the_gate() {
        let got = report(&[("r1", &[("final_acc", Some(0.5))])]);
        let mut gold = Json::obj();
        gold.set("placeholder", Json::Bool(true));
        assert!(matches!(
            diff_reports(&got, &gold, &Tolerances::gate()),
            GoldenOutcome::Unblessed
        ));
    }

    #[test]
    fn exemptions_tolerate_new_families_but_not_regressions() {
        let ex = Exemptions::current();
        let want = report(&[("l2ight/r1", &[("final_acc", Some(0.5))])]);
        // A new variation/ row plus a new metric key on a blessed row: both
        // excused under the standing exemptions, both fatal without them.
        let got = report(&[
            ("l2ight/r1", &[("final_acc", Some(0.5)), ("zo_to_target_queries", Some(9.0))]),
            ("variation/r2", &[("final_acc", Some(0.4))]),
            ("wdm/r3", &[("final_acc", Some(0.4))]),
        ]);
        assert!(matches!(
            diff_reports_with(&got, &want, &Tolerances::gate(), &ex),
            GoldenOutcome::Match { .. }
        ));
        match diff_reports(&got, &want, &Tolerances::gate()) {
            GoldenOutcome::Mismatch(ds) => assert_eq!(ds.len(), 3),
            other => panic!("expected mismatch without exemptions, got {other:?}"),
        }
        // Exemptions never excuse the reverse direction: a blessed row or
        // metric vanishing from the report stays a failure.
        let missing_row = report(&[("l2ight/r1", &[("final_acc", Some(0.5))])]);
        let want_two = report(&[
            ("l2ight/r1", &[("final_acc", Some(0.5))]),
            ("variation/r2", &[("final_acc", Some(0.4))]),
        ]);
        assert!(matches!(
            diff_reports_with(&missing_row, &want_two, &Tolerances::gate(), &ex),
            GoldenOutcome::Mismatch(_)
        ));
        let lost_metric = report(&[("l2ight/r1", &[("final_acc", Some(0.5))])]);
        let want_metric = report(&[(
            "l2ight/r1",
            &[("final_acc", Some(0.5)), ("zo_to_target_queries", Some(9.0))],
        )]);
        assert!(matches!(
            diff_reports_with(&lost_metric, &want_metric, &Tolerances::gate(), &ex),
            GoldenOutcome::Mismatch(_)
        ));
        // An exempted-family row the golden *does* know is still compared.
        let drifted = report(&[("variation/r2", &[("final_acc", Some(0.9))])]);
        let want_var = report(&[("variation/r2", &[("final_acc", Some(0.4))])]);
        assert!(matches!(
            diff_reports_with(&drifted, &want_var, &Tolerances::gate(), &ex),
            GoldenOutcome::Mismatch(_)
        ));
    }

    #[test]
    fn nan_never_matches() {
        let want = report(&[("r1", &[("final_acc", Some(0.5))])]);
        let got = report(&[("r1", &[("final_acc", Some(f64::NAN))])]);
        assert!(matches!(
            diff_reports(&got, &want, &Tolerances::gate()),
            GoldenOutcome::Mismatch(_)
        ));
    }
}
