//! Parallel scenario runner.
//!
//! Rows fan out over a [`ThreadPool`] (normally the process-wide pool from
//! `util::pool::global`, sized by `L2IGHT_THREADS`). Each row gets its own
//! in-memory `MetricSink` and runs `run_job` to completion on whichever
//! worker claims it; nested parallel regions inside the job (mesh strips,
//! GEMM bands, IC/PM block sweeps) then inline on that worker, so the pool
//! is never oversubscribed.
//!
//! Determinism: a row's result is a pure function of its `JobConfig`
//! (see `coordinator::driver`), rows share no mutable state, and
//! `parallel_map` returns results in row order — so the produced
//! `Vec<RowResult>` is bitwise identical (wall times aside) at every
//! thread count and under any execution interleaving.

use crate::coordinator::driver::{run_job, JobSummary};
use crate::coordinator::metrics::MetricSink;
use crate::scenarios::matrix::ScenarioRow;
use crate::util::pool::ThreadPool;

/// One executed row: the scenario plus its measured outcome.
#[derive(Clone, Debug)]
pub struct RowResult {
    pub row: ScenarioRow,
    pub summary: JobSummary,
    /// End-to-end wall time of the row on its worker (diagnostic only —
    /// excluded from golden-metric comparisons).
    pub wall_secs: f64,
}

/// Run every row, fanning out across `pool`. Blocks until all rows are
/// done; results come back in row order regardless of completion order.
pub fn run_matrix(rows: &[ScenarioRow], pool: &ThreadPool) -> Vec<RowResult> {
    pool.parallel_map(rows.len(), |i| {
        let row = rows[i].clone();
        let mut sink = MetricSink::memory();
        let t0 = std::time::Instant::now();
        let summary = run_job(&row.cfg, &mut sink);
        RowResult { row, summary, wall_secs: t0.elapsed().as_secs_f64() }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Protocol;
    use crate::data::DatasetKind;
    use crate::nn::ModelArch;
    use crate::photonics::NoiseModel;

    fn tiny_row(name: &str, protocol: Protocol, seed: u64) -> ScenarioRow {
        ScenarioRow {
            name: name.to_string(),
            cfg: crate::coordinator::config::JobConfig {
                arch: ModelArch::MlpVowel,
                dataset: DatasetKind::VowelLike,
                protocol,
                k: 4,
                noise: NoiseModel::quant_only(8),
                width: 0.5,
                n_train: 48,
                n_test: 24,
                pretrain_epochs: 2,
                epochs: 1,
                batch: 16,
                alpha_w: 0.6,
                alpha_c: 1.0,
                alpha_d: 0.0,
                zo_budget: 0.1,
                seed,
                robustness: None,
                sharding: None,
                variation: None,
            },
        }
    }

    #[test]
    fn results_come_back_in_row_order() {
        let rows = vec![
            tiny_row("a", Protocol::L2ightSlScratch, 1),
            tiny_row("b", Protocol::Rad, 2),
            tiny_row("c", Protocol::L2ightSlScratch, 3),
        ];
        let pool = ThreadPool::new(3);
        let out = run_matrix(&rows, &pool);
        assert_eq!(out.len(), 3);
        for (r, o) in rows.iter().zip(&out) {
            assert_eq!(r.name, o.row.name);
            assert!(o.summary.final_acc.is_finite());
            assert!(o.wall_secs >= 0.0);
        }
    }

    #[test]
    fn empty_matrix_is_noop() {
        let pool = ThreadPool::new(2);
        assert!(run_matrix(&[], &pool).is_empty());
    }
}
