//! Declarative scenario-matrix expander.
//!
//! A *scenario row* is one fully seeded `JobConfig` with a stable,
//! human-readable name of the form
//! `protocol/arch/dataset/noise/aw<α_W>-ac<α_C>-ad<α_D>`. The expander
//! enumerates the crossed axes the paper's breadth claim rests on —
//! architecture × dataset × noise level × sampling sparsity × training
//! protocol — in two tiers:
//!
//! * **quick** — tiny models/datasets, every axis represented at least
//!   once. Cheap enough for CI and for the determinism tests; its metrics
//!   are pinned by `golden/matrix_quick.json`.
//! * **full** — the paper-shaped sweep (all protocols on MLP/CNN-S, the
//!   noise and sparsity ladders, and the small-width vision models). Run
//!   on demand, not in CI.
//!
//! Seeds are assigned **before** filtering, by [`job_seed`]`(base, index)`
//! over the enumeration index, so a row's seed — and therefore its result —
//! is identical whether it runs alone (`--filter`), in the full matrix, or
//! at any thread count.

use crate::coordinator::config::{JobConfig, Protocol};
use crate::coordinator::driver::job_seed;
use crate::data::DatasetKind;
use crate::nn::ModelArch;
use crate::photonics::{NoiseModel, ShardPolicy, ShardingConfig};
use crate::robustness::{RobustnessConfig, VariationConfig};

/// Which slice of the scenario space to enumerate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Quick,
    Full,
}

impl Tier {
    pub fn parse(s: &str) -> Option<Tier> {
        Some(match s {
            "quick" => Tier::Quick,
            "full" => Tier::Full,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Tier::Quick => "quick",
            Tier::Full => "full",
        }
    }
}

/// One row of the scenario matrix: a named, fully seeded job.
#[derive(Clone, Debug)]
pub struct ScenarioRow {
    /// Stable id (`protocol/arch/dataset/noise/sparsity`); unique per tier.
    pub name: String,
    pub cfg: JobConfig,
}

/// Matrix expansion parameters.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub tier: Tier,
    /// Base seed; per-row seeds derive via `job_seed(base, row_index)`.
    pub base_seed: u64,
    /// Substring filters over row names; a row is kept when any filter
    /// matches (empty = keep everything).
    pub filters: Vec<String>,
}

impl MatrixSpec {
    pub fn new(tier: Tier) -> MatrixSpec {
        MatrixSpec { tier, base_seed: 42, filters: Vec::new() }
    }
}

/// The noise ladder rows are named after.
fn noise_tag(n: &NoiseModel) -> &'static str {
    if *n == NoiseModel::IDEAL {
        "ideal"
    } else if *n == NoiseModel::PAPER {
        "paper"
    } else if *n == NoiseModel::quant_only(8) {
        "quant8"
    } else {
        "custom"
    }
}

fn row_name(cfg: &JobConfig) -> String {
    // Lifecycle rows get their own family prefix (deliberately NOT the
    // protocol name, so protocol-substring filters keep selecting exactly
    // the clean-chip rows they always did).
    if let Some(rc) = &cfg.robustness {
        let recovery = rc.watchdog.map(|w| w.max_recoveries > 0).unwrap_or(false);
        return format!(
            "lifecycle/{}/{}/{}/drift{}-rec{}",
            cfg.arch.name(),
            cfg.dataset.name(),
            noise_tag(&cfg.noise),
            rc.drift.is_some() as u8,
            recovery as u8,
        );
    }
    // Sharded rows likewise get their own family prefix, invisible to the
    // protocol-substring filters.
    if let Some(sc) = &cfg.sharding {
        return format!(
            "shard/{}/{}/{}/{}{}",
            cfg.arch.name(),
            cfg.dataset.name(),
            noise_tag(&cfg.noise),
            sc.policy.name(),
            sc.shards,
        );
    }
    // Variation rows: `wdm/` for pure wavelength sweeps, `variation/` for
    // perturbed-chip rows. The protocol rides as a *suffix* (no trailing
    // slash), so the CI's `l2ight/`-style protocol filters skip them.
    if let Some(vc) = &cfg.variation {
        if vc.is_wdm_only() {
            return format!(
                "wdm/{}/{}/{}/d{}",
                cfg.arch.name(),
                cfg.dataset.name(),
                noise_tag(&cfg.noise),
                vc.wdm_max_drift,
            );
        }
        return format!(
            "variation/{}/{}/{}/s{}-x{}-{}",
            cfg.arch.name(),
            cfg.dataset.name(),
            noise_tag(&cfg.noise),
            vc.gamma_std,
            vc.sample,
            cfg.protocol.name(),
        );
    }
    format!(
        "{}/{}/{}/{}/aw{}-ac{}-ad{}",
        cfg.protocol.name(),
        cfg.arch.name(),
        cfg.dataset.name(),
        noise_tag(&cfg.noise),
        cfg.alpha_w,
        cfg.alpha_c,
        cfg.alpha_d
    )
}

/// Quick-tier base: the smallest job that still exercises the whole
/// three-stage flow (mirrors the driver's own test fixture).
fn quick_base() -> JobConfig {
    JobConfig {
        arch: ModelArch::MlpVowel,
        dataset: DatasetKind::VowelLike,
        protocol: Protocol::L2ight,
        k: 4,
        noise: NoiseModel::quant_only(8),
        width: 0.5,
        n_train: 96,
        n_test: 48,
        pretrain_epochs: 4,
        epochs: 3,
        batch: 16,
        alpha_w: 0.6,
        alpha_c: 1.0,
        alpha_d: 0.0,
        zo_budget: 0.1,
        seed: 0, // assigned by expand()
        robustness: None,
        sharding: None,
        variation: None,
    }
}

/// A uniform-σ chip-instance config (the CLI's `sigma=` shorthand).
fn sigma_variation(sigma: f64, sample: u64) -> VariationConfig {
    VariationConfig {
        gamma_std: sigma,
        coupler_std: sigma,
        loss_db_std: sigma,
        wdm_max_drift: 0.0,
        sample,
    }
}

/// Full-tier base: paper-scale MLP job (still synthetic-data sized).
fn full_base() -> JobConfig {
    JobConfig {
        arch: ModelArch::MlpVowel,
        dataset: DatasetKind::VowelLike,
        protocol: Protocol::L2ight,
        k: 9,
        noise: NoiseModel::PAPER,
        width: 1.0,
        n_train: 512,
        n_test: 256,
        pretrain_epochs: 10,
        epochs: 10,
        batch: 32,
        alpha_w: 0.6,
        alpha_c: 1.0,
        alpha_d: 0.0,
        zo_budget: 1.0,
        seed: 0,
        robustness: None,
        sharding: None,
        variation: None,
    }
}

const ALL_PROTOCOLS: [Protocol; 6] = [
    Protocol::L2ight,
    Protocol::L2ightSlScratch,
    Protocol::Flops,
    Protocol::MixedTrn,
    Protocol::Rad,
    Protocol::SwatU,
];

fn quick_rows() -> Vec<JobConfig> {
    let base = quick_base();
    let mut rows = Vec::new();
    // Protocol axis: every protocol on the tiny MLP. ZO baselines pay per
    // query, so they get a single epoch (the matrix tracks their query
    // count, not their convergence).
    for p in ALL_PROTOCOLS {
        let mut c = base.clone();
        c.protocol = p;
        if matches!(p, Protocol::Flops | Protocol::MixedTrn) {
            c.epochs = 1;
            c.n_train = 48;
        }
        rows.push(c);
    }
    // Noise axis: the L2ight flow under the noise ladder (quant8 is the
    // protocol-axis row above).
    for noise in [NoiseModel::IDEAL, NoiseModel::PAPER] {
        let mut c = base.clone();
        c.noise = noise;
        rows.push(c);
    }
    // Sparsity axis: subspace learning from scratch across (α_W, α_C, α_D).
    for (aw, ac, ad) in [(1.0, 1.0, 0.0), (0.6, 0.7, 0.0), (0.4, 0.5, 0.3)] {
        let mut c = base.clone();
        c.protocol = Protocol::L2ightSlScratch;
        c.alpha_w = aw;
        c.alpha_c = ac;
        c.alpha_d = ad;
        rows.push(c);
    }
    // Architecture axis: one tiny CNN row so conv plumbing is gated too.
    let mut cnn = base.clone();
    cnn.arch = ModelArch::CnnS;
    cnn.dataset = DatasetKind::MnistLike;
    cnn.width = 0.25;
    cnn.n_train = 64;
    cnn.n_test = 32;
    cnn.pretrain_epochs = 2;
    cnn.epochs = 2;
    rows.push(cnn);
    // Lifecycle axis: the L2ight flow on an aging chip — drift on/off ×
    // recovery on/off. Appended last so the seeds of every pre-existing row
    // are untouched. A slightly longer SL run (4 epochs = 24 steps) gives
    // the step-8 fault schedule room to fire, be detected, and recover.
    for (drift, recovery) in [(false, false), (false, true), (true, false), (true, true)] {
        let mut c = base.clone();
        c.epochs = 4;
        c.robustness = Some(RobustnessConfig::lifecycle_row(drift, recovery));
        rows.push(c);
    }
    // Sharding axis: the L2ight flow partitioned across chiplets — shard
    // count × placement policy. Appended after everything above so the
    // seeds of every pre-existing row are untouched.
    for (shards, policy) in
        [(2, ShardPolicy::Row), (2, ShardPolicy::Col), (4, ShardPolicy::Grid)]
    {
        let mut c = base.clone();
        c.sharding = Some(ShardingConfig { shards, policy });
        rows.push(c);
    }
    // Variation axis: σ sweep × protocol on perturbed chip instances, plus a
    // second Monte-Carlo sample at the mid σ. Appended after everything
    // above so the seeds of every pre-existing row are untouched.
    for (sigma, sample, proto) in [
        (0.002, 0, Protocol::L2ight),
        (0.01, 0, Protocol::L2ight),
        (0.01, 1, Protocol::L2ight),
        (0.01, 0, Protocol::L2ightSlScratch),
    ] {
        let mut c = base.clone();
        c.protocol = proto;
        c.variation = Some(sigma_variation(sigma, sample));
        rows.push(c);
    }
    // WDM axis: pure wavelength sweeps (no device perturbation) at two
    // dispersion spans — the paper's conservative 2% and a tighter 0.5%.
    for drift in [0.005, 0.02] {
        let mut c = base.clone();
        c.variation = Some(VariationConfig { wdm_max_drift: drift, ..Default::default() });
        rows.push(c);
    }
    rows
}

fn full_rows() -> Vec<JobConfig> {
    let base = full_base();
    let mut rows = Vec::new();
    // Protocol axis × {MLP/vowel, CNN-S/mnist}.
    for p in ALL_PROTOCOLS {
        for arch in [ModelArch::MlpVowel, ModelArch::CnnS] {
            let mut c = base.clone();
            c.protocol = p;
            if arch == ModelArch::CnnS {
                c.arch = ModelArch::CnnS;
                c.dataset = DatasetKind::MnistLike;
                c.width = 0.5;
                c.n_train = 256;
                c.n_test = 128;
                c.pretrain_epochs = 5;
                c.epochs = 5;
            }
            if matches!(p, Protocol::Flops | Protocol::MixedTrn) {
                c.epochs = 2;
            }
            rows.push(c);
        }
    }
    // Noise ladder on the full flow.
    for noise in [NoiseModel::IDEAL, NoiseModel::quant_only(8), NoiseModel::PAPER_NO_BIAS] {
        let mut c = base.clone();
        c.noise = noise;
        rows.push(c);
    }
    // Sparsity grid on scratch subspace learning.
    for aw in [1.0, 0.6, 0.3] {
        for ac in [1.0, 0.5] {
            let mut c = base.clone();
            c.protocol = Protocol::L2ightSlScratch;
            c.alpha_w = aw;
            c.alpha_c = ac;
            rows.push(c);
        }
    }
    // Data-sampling (SMD) axis.
    for ad in [0.3, 0.6] {
        let mut c = base.clone();
        c.protocol = Protocol::L2ightSlScratch;
        c.alpha_d = ad;
        rows.push(c);
    }
    // Vision models at CPU-budget widths.
    for (arch, ds, width) in [
        (ModelArch::CnnL, DatasetKind::FashionLike, 0.25),
        (ModelArch::Vgg8, DatasetKind::Cifar10Like, 0.125),
        (ModelArch::ResNet18, DatasetKind::Cifar10Like, 0.125),
    ] {
        let mut c = base.clone();
        c.arch = arch;
        c.dataset = ds;
        c.width = width;
        c.n_train = 128;
        c.n_test = 64;
        c.pretrain_epochs = 2;
        c.epochs = 2;
        rows.push(c);
    }
    // A many-class row (CIFAR-100 shape).
    let mut c100 = base.clone();
    c100.protocol = Protocol::L2ightSlScratch;
    c100.arch = ModelArch::Vgg8;
    c100.dataset = DatasetKind::Cifar100Like;
    c100.width = 0.125;
    c100.n_train = 200;
    c100.n_test = 100;
    c100.epochs = 2;
    rows.push(c100);
    // Sharding axis at paper scale (appended last; see quick_rows).
    for (shards, policy) in [(2, ShardPolicy::Row), (4, ShardPolicy::Grid)] {
        let mut c = base.clone();
        c.sharding = Some(ShardingConfig { shards, policy });
        rows.push(c);
    }
    // Variation σ-ladder × protocol at paper scale (appended after the
    // shard rows; see quick_rows for the seed-stability rule).
    for sigma in [0.002, 0.005, 0.01, 0.02] {
        let mut c = base.clone();
        c.variation = Some(sigma_variation(sigma, 0));
        rows.push(c);
    }
    for (sample, proto) in [(1, Protocol::L2ight), (0, Protocol::L2ightSlScratch)] {
        let mut c = base.clone();
        c.protocol = proto;
        c.variation = Some(sigma_variation(0.01, sample));
        rows.push(c);
    }
    // WDM dispersion ladder at paper scale (k = 9, the paper's setting).
    for drift in [0.005, 0.01, 0.02] {
        let mut c = base.clone();
        c.variation = Some(VariationConfig { wdm_max_drift: drift, ..Default::default() });
        rows.push(c);
    }
    rows
}

/// Enumerate the matrix for `spec`: name every row, assign pre-filter
/// seeds, drop duplicate names (first wins), then apply the filters.
pub fn expand(spec: &MatrixSpec) -> Vec<ScenarioRow> {
    let cfgs = match spec.tier {
        Tier::Quick => quick_rows(),
        Tier::Full => full_rows(),
    };
    let mut seen = std::collections::BTreeSet::new();
    let mut rows = Vec::new();
    for (i, mut cfg) in cfgs.into_iter().enumerate() {
        cfg.seed = job_seed(spec.base_seed, i as u64);
        let name = row_name(&cfg);
        if !seen.insert(name.clone()) {
            continue;
        }
        rows.push(ScenarioRow { name, cfg });
    }
    if !spec.filters.is_empty() {
        rows.retain(|r| spec.filters.iter().any(|f| r.name.contains(f.as_str())));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_tier_covers_every_axis() {
        let rows = expand(&MatrixSpec::new(Tier::Quick));
        assert!(rows.len() >= 10, "quick tier too small: {}", rows.len());
        // Every protocol appears.
        for p in ALL_PROTOCOLS {
            assert!(
                rows.iter().any(|r| r.cfg.protocol == p),
                "protocol {p:?} missing from quick tier"
            );
        }
        // Noise ladder appears.
        for tag in ["ideal", "quant8", "paper"] {
            assert!(rows.iter().any(|r| r.name.contains(tag)), "noise {tag} missing");
        }
        // A conv architecture appears.
        assert!(rows.iter().any(|r| r.cfg.arch == ModelArch::CnnS));
        // A sparsified row appears.
        assert!(rows.iter().any(|r| r.cfg.alpha_c < 1.0 && r.cfg.alpha_w < 1.0));
        // The lifecycle family appears: all four drift × recovery corners.
        for tag in ["drift0-rec0", "drift0-rec1", "drift1-rec0", "drift1-rec1"] {
            assert!(
                rows.iter().any(|r| r.name.starts_with("lifecycle/") && r.name.ends_with(tag)),
                "lifecycle corner {tag} missing"
            );
        }
        // The shard family appears: both counts and all three policies.
        for tag in ["row2", "col2", "grid4"] {
            assert!(
                rows.iter().any(|r| r.name.starts_with("shard/") && r.name.ends_with(tag)),
                "shard corner {tag} missing"
            );
        }
        // The variation family appears: σ sweep, a second MC sample, and a
        // second protocol; the WDM family appears at both spans.
        for tag in ["s0.002-x0-l2ight", "s0.01-x0-l2ight", "s0.01-x1-l2ight", "s0.01-x0-l2ight-sl"]
        {
            assert!(
                rows.iter().any(|r| r.name.starts_with("variation/") && r.name.ends_with(tag)),
                "variation corner {tag} missing"
            );
        }
        for tag in ["d0.005", "d0.02"] {
            assert!(
                rows.iter().any(|r| r.name.starts_with("wdm/") && r.name.ends_with(tag)),
                "wdm corner {tag} missing"
            );
        }
    }

    #[test]
    fn variation_rows_do_not_collide_with_other_families() {
        let rows = expand(&MatrixSpec::new(Tier::Quick));
        let varied: Vec<_> = rows
            .iter()
            .filter(|r| r.name.starts_with("variation/") || r.name.starts_with("wdm/"))
            .collect();
        assert!(!varied.is_empty());
        for r in &varied {
            let vc = r.cfg.variation.expect("variation row lost its config");
            assert!(vc.active(), "{}: inactive variation config", r.name);
            assert_eq!(
                r.name.starts_with("wdm/"),
                vc.is_wdm_only(),
                "{}: family/confg mismatch",
                r.name
            );
            // Invisible to the CI's protocol/lifecycle/shard substring
            // filters (protocol names ride as suffixes without a slash).
            for f in ["l2ight/", "rad/", "flops/", "swat-u/", "mixedtrn/", "lifecycle/", "shard/"]
            {
                assert!(!r.name.contains(f), "{} matches filter {f}", r.name);
            }
        }
        // And conversely: no other family carries a variation config.
        for r in rows
            .iter()
            .filter(|r| !r.name.starts_with("variation/") && !r.name.starts_with("wdm/"))
        {
            assert!(r.cfg.variation.is_none(), "{}: unexpected variation config", r.name);
        }
    }

    #[test]
    fn shard_rows_do_not_collide_with_other_families() {
        let rows = expand(&MatrixSpec::new(Tier::Quick));
        let shard: Vec<_> = rows.iter().filter(|r| r.name.starts_with("shard/")).collect();
        assert!(!shard.is_empty());
        for r in &shard {
            let sc = r.cfg.sharding.expect("shard row lost its config");
            assert!(sc.shards > 1, "{}: trivial shard count", r.name);
            for f in ["l2ight/", "rad/", "flops/", "swat-u/", "mixedtrn/", "lifecycle/"] {
                assert!(!r.name.contains(f), "{} matches filter {f}", r.name);
            }
        }
        // And conversely: no other row carries a sharding config.
        for r in rows.iter().filter(|r| !r.name.starts_with("shard/")) {
            assert!(r.cfg.sharding.is_none(), "{}: unexpected sharding config", r.name);
        }
    }

    #[test]
    fn lifecycle_rows_do_not_collide_with_protocol_filters() {
        // The CI determinism leg filters by protocol-name substrings and
        // asserts an exact row count; lifecycle names must stay invisible
        // to those filters.
        let rows = expand(&MatrixSpec::new(Tier::Quick));
        for r in rows.iter().filter(|r| r.name.starts_with("lifecycle/")) {
            assert!(r.cfg.robustness.is_some(), "{}: lifecycle row lost its config", r.name);
            for f in ["l2ight/", "rad/", "flops/", "swat-u/", "mixedtrn/"] {
                assert!(!r.name.contains(f), "{} matches protocol filter {f}", r.name);
            }
        }
        // And conversely: protocol rows never carry a robustness config.
        for r in rows.iter().filter(|r| !r.name.starts_with("lifecycle/")) {
            assert!(r.cfg.robustness.is_none(), "{}: unexpected robustness config", r.name);
        }
    }

    #[test]
    fn names_and_seeds_are_unique() {
        for tier in [Tier::Quick, Tier::Full] {
            let rows = expand(&MatrixSpec::new(tier));
            let names: std::collections::BTreeSet<&str> =
                rows.iter().map(|r| r.name.as_str()).collect();
            assert_eq!(names.len(), rows.len(), "{tier:?} has duplicate names");
            let seeds: std::collections::BTreeSet<u64> =
                rows.iter().map(|r| r.cfg.seed).collect();
            assert_eq!(seeds.len(), rows.len(), "{tier:?} has duplicate seeds");
        }
    }

    #[test]
    fn filtering_preserves_row_identity() {
        // A filtered row must keep the exact seed/config it has in the full
        // enumeration — results may never depend on what else was selected.
        let all = expand(&MatrixSpec::new(Tier::Quick));
        let spec = MatrixSpec {
            filters: vec!["l2ight/".to_string()],
            ..MatrixSpec::new(Tier::Quick)
        };
        let filtered = expand(&spec);
        assert!(!filtered.is_empty());
        assert!(filtered.len() < all.len());
        for f in &filtered {
            let full = all.iter().find(|r| r.name == f.name).expect("row vanished");
            assert_eq!(full.cfg.seed, f.cfg.seed, "{}: seed changed under filter", f.name);
        }
    }

    #[test]
    fn base_seed_changes_every_row_seed() {
        let a = expand(&MatrixSpec { base_seed: 1, ..MatrixSpec::new(Tier::Quick) });
        let b = expand(&MatrixSpec { base_seed: 2, ..MatrixSpec::new(Tier::Quick) });
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.name, rb.name);
            assert_ne!(ra.cfg.seed, rb.cfg.seed, "{}", ra.name);
        }
    }

    #[test]
    fn tier_parse_roundtrip() {
        for t in [Tier::Quick, Tier::Full] {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("nope"), None);
    }
}
