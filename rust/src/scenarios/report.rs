//! Machine-readable scenario-matrix report (`SCENARIOS_matrix.json`).
//!
//! Layout (schema 1):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "tier": "quick",
//!   "threads": 4,
//!   "simd": "avx2",
//!   "rows": [
//!     {
//!       "name": "l2ight/mlp-vowel/vowel/quant8/aw0.6-ac1-ad0",
//!       "config": { ...JobConfig::to_json()... },
//!       "metrics": {
//!         "final_acc": 0.83, "best_acc": 0.85,
//!         "pretrain_acc": 0.87, "mapped_acc": 0.79,
//!         "ic_mse": 1.2e-3, "pm_err": 4.0e-2,
//!         "zo_queries": 96, "trainable_params": 128, "total_params": 420,
//!         "cost": {"fwd_energy": ..., "wgrad_energy": ..., "fbk_energy": ...,
//!                  "fwd_steps": ..., "wgrad_steps": ..., "fbk_steps": ...},
//!         "lifecycle": null | {"drift": 1, "faults": 2, "trigger_step": 8,
//!                              "detect_latency_steps": 0, "recoveries": 1,
//!                              "recovered_blocks": 1, "dead_blocks": 0,
//!                              "recovery_queries": 40, "probe_queries": 16}
//!       },
//!       "skipped_stages": [],
//!       "stage_secs": {"pretrain": 0.1, "ic": 0.2, "pm": 0.3, "sl": 0.4},
//!       "wall_secs": 1.0
//!     }
//!   ]
//! }
//! ```
//!
//! Everything under `metrics` is deterministic per row (independent of
//! thread count and execution order) and is what `golden` compares;
//! `threads`, `simd`, `wall_secs`, and `stage_secs` are diagnostics and are
//! ignored by the gate. Metrics that a protocol does not produce (e.g.
//! `ic_mse` for baselines) are emitted as `null` so presence itself is
//! golden-checked.

use std::path::Path;

use crate::scenarios::matrix::Tier;
use crate::scenarios::runner::RowResult;
use crate::util::json::Json;

/// Report schema version.
pub const SCHEMA: f64 = 1.0;

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

/// The deterministic per-row metric object.
fn metrics_json(r: &RowResult) -> Json {
    let s = &r.summary;
    let mut m = Json::obj();
    m.set("final_acc", Json::Num(s.final_acc as f64))
        .set("best_acc", Json::Num(s.best_acc as f64))
        .set("pretrain_acc", opt_num(s.pretrain_acc.map(|v| v as f64)))
        .set("mapped_acc", opt_num(s.mapped_acc.map(|v| v as f64)))
        .set("ic_mse", opt_num(s.ic_mse))
        .set("pm_err", opt_num(s.pm_err))
        .set("zo_queries", Json::Num(s.zo_queries as f64))
        .set("trainable_params", Json::Num(s.trainable_params as f64))
        .set("total_params", Json::Num(s.total_params as f64));
    let c = &s.cost;
    let mut cost = Json::obj();
    cost.set("fwd_energy", Json::Num(c.fwd_energy))
        .set("wgrad_energy", Json::Num(c.wgrad_energy))
        .set("fbk_energy", Json::Num(c.fbk_energy))
        .set("fwd_steps", Json::Num(c.fwd_steps))
        .set("wgrad_steps", Json::Num(c.wgrad_steps))
        .set("fbk_steps", Json::Num(c.fbk_steps));
    m.set("cost", cost);
    // Lifecycle counters (robustness rows): deterministic only — recovery
    // wall time is reported through `stage_secs` instead.
    m.set(
        "lifecycle",
        match &s.lifecycle {
            None => Json::Null,
            Some(l) => {
                let mut lj = Json::obj();
                lj.set("drift", Json::Num(if l.drift { 1.0 } else { 0.0 }))
                    .set("faults", Json::Num(l.faults as f64))
                    .set("trigger_step", opt_num(l.trigger_step.map(|t| t as f64)))
                    .set(
                        "detect_latency_steps",
                        opt_num(l.detect_latency_steps.map(|t| t as f64)),
                    )
                    .set("recoveries", Json::Num(l.recoveries as f64))
                    .set("recovered_blocks", Json::Num(l.recovered_blocks as f64))
                    .set("dead_blocks", Json::Num(l.dead_blocks as f64))
                    .set("recovery_queries", Json::Num(l.recovery_queries as f64))
                    .set("probe_queries", Json::Num(l.probe_queries as f64));
                lj
            }
        },
    );
    // ZO budget-parity metric: queries to reach 0.9×best accuracy. The key
    // contains "queries" so the golden gate holds it exactly.
    m.set("zo_to_target_queries", opt_num(s.zo_to_target_queries.map(|q| q as f64)));
    // Process-variation outcome (variation rows): the per-row deterministic
    // slice only — full N-sample yield statistics live in `l2ight yield`.
    m.set(
        "variation",
        match &s.variation {
            None => Json::Null,
            Some(v) => {
                let mut vj = Json::obj();
                vj.set("power_penalty_db", Json::Num(v.power_penalty_db))
                    .set("blocks", Json::Num(v.blocks as f64));
                vj
            }
        },
    );
    // WDM dispersion sweep (wdm/ rows and any variation row that asked).
    m.set(
        "wdm",
        match &s.wdm {
            None => Json::Null,
            Some(w) => {
                let mut wj = Json::obj();
                wj.set("max_drift", Json::Num(w.max_drift))
                    .set("blocks", Json::Num(w.blocks as f64))
                    .set("worst_rel_err", Json::Num(w.worst_rel_err))
                    .set("mean_rel_err", Json::Num(w.mean_rel_err))
                    .set("worst_mse", Json::Num(w.worst_mse));
                wj
            }
        },
    );
    m
}

/// One report row.
pub fn row_json(r: &RowResult) -> Json {
    let mut stages = Json::obj();
    for (stage, secs) in &r.summary.stage_secs {
        stages.set(stage, Json::Num(*secs));
    }
    let mut row = Json::obj();
    row.set("name", Json::Str(r.row.name.clone()))
        .set("config", r.row.cfg.to_json())
        .set("metrics", metrics_json(r))
        .set(
            "skipped_stages",
            Json::Arr(
                r.summary.skipped_stages.iter().map(|s| Json::Str((*s).into())).collect(),
            ),
        )
        .set("stage_secs", stages)
        .set("wall_secs", Json::Num(r.wall_secs));
    row
}

/// Assemble the full report document. `simd` records the kernel dispatch
/// level the run executed at (`linalg::simd::active().name()`) — like
/// `threads` it is a diagnostic, ignored by the golden gate, but it tells
/// a reader which numerics family (scalar vs FMA) an artifact carries.
pub fn report_json(tier: Tier, threads: usize, simd: &str, results: &[RowResult]) -> Json {
    let mut root = Json::obj();
    root.set("schema", Json::Num(SCHEMA))
        .set("tier", Json::Str(tier.name().into()))
        .set("threads", Json::Num(threads as f64))
        .set("simd", Json::Str(simd.to_string()))
        .set("rows", Json::Arr(results.iter().map(row_json).collect()));
    root
}

/// Write a report (pretty-printed, trailing newline), creating parent
/// directories as needed.
pub fn write_report(path: &Path, report: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, report.pretty() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{JobConfig, Protocol};
    use crate::coordinator::driver::JobSummary;
    use crate::profiler::CostBreakdown;
    use crate::scenarios::matrix::ScenarioRow;

    fn fake_result(name: &str, acc: f32) -> RowResult {
        RowResult {
            row: ScenarioRow { name: name.into(), cfg: JobConfig::default() },
            summary: JobSummary {
                protocol: Protocol::L2ight,
                trainable_params: 8,
                total_params: 64,
                final_acc: acc,
                best_acc: acc,
                pretrain_acc: Some(0.5),
                mapped_acc: None,
                ic_mse: Some(1e-3),
                pm_err: None,
                cost: CostBreakdown::default(),
                zo_queries: 7,
                sl: None,
                lifecycle: None,
                variation: None,
                wdm: None,
                zo_to_target_queries: Some(7),
                skipped_stages: Vec::new(),
                stage_secs: vec![("ic", 0.25)],
            },
            wall_secs: 1.5,
        }
    }

    #[test]
    fn report_roundtrips_through_json() {
        let results = vec![fake_result("a", 0.75), fake_result("b", 0.5)];
        let rep = report_json(Tier::Quick, 4, "scalar", &results);
        let back = Json::parse(&rep.pretty()).unwrap();
        assert_eq!(back, rep);
        assert_eq!(back.get("tier").unwrap().as_str(), Some("quick"));
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        let m = rows[0].get("metrics").unwrap();
        assert_eq!(m.get("final_acc").unwrap().as_f64(), Some(0.75));
        assert_eq!(m.get("mapped_acc"), Some(&Json::Null));
        assert_eq!(m.get("zo_queries").unwrap().as_f64(), Some(7.0));
        assert!(m.get("cost").unwrap().get("fwd_energy").is_some());
        // Lifecycle is null (presence golden-checked) on non-robustness rows.
        assert_eq!(m.get("lifecycle"), Some(&Json::Null));
        // Variation/WDM are null on clean-chip rows; the budget-parity
        // metric is a number when the protocol defines it.
        assert_eq!(m.get("variation"), Some(&Json::Null));
        assert_eq!(m.get("wdm"), Some(&Json::Null));
        assert_eq!(m.get("zo_to_target_queries").unwrap().as_f64(), Some(7.0));
        assert_eq!(rows[0].get("skipped_stages").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(rows[0].get("stage_secs").unwrap().get("ic").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn write_report_creates_parent_dirs() {
        let dir = std::env::temp_dir()
            .join(format!("l2ight_report_{}", std::process::id()));
        let path = dir.join("nested").join("out.json");
        let rep = report_json(Tier::Quick, 1, "scalar", &[fake_result("a", 0.1)]);
        write_report(&path, &rep).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(text.trim()).unwrap(), rep);
        std::fs::remove_dir_all(&dir).ok();
    }
}
