//! Scenario-matrix engine: the breadth gate of the reproduction.
//!
//! The paper's headline claim is that the three-stage flow holds up across
//! models, datasets, noise levels, sampling sparsities, and against every
//! baseline protocol. This subsystem turns that claim into a regression
//! artifact:
//!
//! * [`matrix`] — declarative expander: tiered scenario rows over
//!   arch × dataset × noise × sparsity × protocol, each a fully seeded
//!   `JobConfig` (seeds derive from `(base_seed, row_index)`, never from
//!   execution order);
//! * [`runner`] — fans rows out over the shared thread pool; results are
//!   independent of thread count and completion order;
//! * [`report`] — one machine-readable `SCENARIOS_matrix.json` with
//!   per-row accuracy/fidelity/cost metrics;
//! * [`golden`] — diffs a report against a checked-in golden fixture with
//!   per-metric tolerances (the CI gate), plus the zero-tolerance mode the
//!   thread-invariance check uses.
//!
//! CLI entry points: `l2ight matrix` and `l2ight matrix-diff` (src/main.rs).

pub mod golden;
pub mod matrix;
pub mod report;
pub mod runner;

pub use golden::{diff_reports, GoldenDiff, GoldenOutcome, Tolerances};
pub use matrix::{expand, MatrixSpec, ScenarioRow, Tier};
pub use report::{report_json, write_report};
pub use runner::{run_matrix, RowResult};
