//! The batched async serving engine: admission queue → replica workers →
//! tagged responses, with checkpoint hot-reload and graceful shutdown.
//!
//! ```text
//!   clients ──try_submit──▶ AdmissionQueue (bounded, deadline-batching)
//!                               │ next_batch (N workers contend)
//!                    ┌──────────┴──────────┐
//!               Replica 0   …         Replica N-1      (model clones)
//!                    │   forward_packed panels  │       on util::pool
//!                    └──────────┬──────────────┘
//!                        ServeResponse {output, version, batch_seq}
//!
//!   poller thread: fingerprints the checkpoint file; workers reload
//!   *between* batches, so one batch serves exactly one parameter version.
//! ```
//!
//! Backpressure is explicit: a full queue sheds (`ServeError::Saturated`,
//! the 429 of this API) instead of blocking the caller or growing without
//! bound. Shutdown is graceful: admitted requests are served before the
//! workers exit.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime};

use super::admission::{AdmissionConfig, AdmissionQueue};
use super::replica::Replica;
use super::stats::{ServeStats, StatsCollector};
use crate::coordinator::checkpoint::load_model_state;
use crate::nn::Model;

/// Serving policy.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Model replicas (= concurrent batch executors).
    pub replicas: usize,
    /// Flush a batch at this many requests…
    pub max_batch: usize,
    /// …or when the oldest admitted request has waited this long.
    pub max_wait: Duration,
    /// Admission-queue depth beyond which submissions are shed.
    pub queue_cap: usize,
    /// Optional checkpoint hot-reload.
    pub reload: Option<ReloadConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            replicas: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
            reload: None,
        }
    }
}

/// Poll `path` every `poll`; on any metadata change, bump the published
/// parameter version. Checkpoints are written via atomic rename
/// (`coordinator::checkpoint::save_model_state`), so the path never holds
/// a partial file.
#[derive(Clone, Debug)]
pub struct ReloadConfig {
    pub path: PathBuf,
    pub poll: Duration,
}

/// One served inference, tagged with enough provenance to audit batching
/// and hot-reload behavior (`tests/serve_equivalence.rs` leans on this).
#[derive(Clone, Debug)]
pub struct ServeResponse {
    /// Logits column for this request.
    pub output: Vec<f32>,
    /// Parameter version that produced it (0 = starting parameters).
    pub version: u64,
    /// Globally unique id of the executed batch.
    pub batch_seq: u64,
    /// How many requests shared that batch.
    pub batch_size: usize,
    /// Which replica executed it.
    pub replica: usize,
}

/// Why a request got no response.
#[derive(Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission queue at capacity — request shed. Retry later.
    Saturated,
    /// Engine shut down before the response was produced.
    Closed,
    /// Input length does not match the model's input shape.
    BadRequest { got: usize, want: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Saturated => write!(f, "admission queue saturated (shed)"),
            ServeError::Closed => write!(f, "serving engine closed"),
            ServeError::BadRequest { got, want } => {
                write!(f, "bad request: {got} input values, model expects {want}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

struct Pending {
    input: Vec<f32>,
    resp: Sender<ServeResponse>,
}

struct ReloadShared {
    path: PathBuf,
    /// Versions published by the poller; replicas catch up between batches.
    published: AtomicU64,
}

/// The running engine. Dropping (or `shutdown`) closes admission, drains
/// queued requests, and joins every thread.
pub struct ServeEngine {
    queue: AdmissionQueue<Pending>,
    workers: Vec<JoinHandle<()>>,
    poller: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    stats: Arc<StatsCollector>,
    input_len: usize,
}

impl ServeEngine {
    /// Spin up replicas (clones of `template`) and, if configured, the
    /// hot-reload poller. `shape` is the per-sample (channels, height,
    /// width). If the reload checkpoint already exists it is loaded into
    /// the template first, so a restarted engine serves the latest
    /// parameters as version 0.
    pub fn start(template: Model, shape: (usize, usize, usize), cfg: ServeConfig) -> ServeEngine {
        let mut template = template;
        let reload = cfg.reload.as_ref().map(|rl| {
            if rl.path.exists() {
                if let Err(e) = load_model_state(&mut template, &rl.path) {
                    crate::warn!(
                        "serve: could not load initial checkpoint {}: {e}; serving the template",
                        rl.path.display()
                    );
                }
            }
            Arc::new(ReloadShared { path: rl.path.clone(), published: AtomicU64::new(0) })
        });

        let queue: AdmissionQueue<Pending> = AdmissionQueue::new(AdmissionConfig {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            queue_cap: cfg.queue_cap,
        });
        let stats = Arc::new(StatsCollector::new(cfg.max_batch));
        let batch_seq = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let input_len = shape.0 * shape.1 * shape.2;

        let workers = (0..cfg.replicas.max(1))
            .map(|id| {
                let queue = queue.clone();
                let stats = Arc::clone(&stats);
                let batch_seq = Arc::clone(&batch_seq);
                let reload = reload.clone();
                let replica = Replica::new(id, template.clone(), shape);
                std::thread::Builder::new()
                    .name(format!("l2ight-serve-{id}"))
                    .spawn(move || worker_loop(replica, queue, stats, batch_seq, reload))
                    .expect("spawn serve worker")
            })
            .collect();

        let poller = cfg.reload.as_ref().map(|rl| {
            let shared = reload.as_ref().expect("reload shared state").clone();
            let stop = Arc::clone(&stop);
            let poll = rl.poll;
            std::thread::Builder::new()
                .name("l2ight-serve-reload".to_string())
                .spawn(move || poll_loop(shared, poll, stop))
                .expect("spawn reload poller")
        });

        ServeEngine { queue, workers, poller, stop, stats, input_len }
    }

    /// Async submit: returns the response channel immediately, or the
    /// shed/validation error. Never blocks on a saturated queue.
    pub fn submit(&self, input: Vec<f32>) -> Result<Receiver<ServeResponse>, ServeError> {
        if input.len() != self.input_len {
            return Err(ServeError::BadRequest { got: input.len(), want: self.input_len });
        }
        let (tx, rx) = channel();
        match self.queue.try_submit(Pending { input, resp: tx }) {
            Ok(()) => Ok(rx),
            Err(_) => Err(ServeError::Saturated),
        }
    }

    /// Submit one request and block for its response.
    pub fn infer(&self, input: Vec<f32>) -> Result<ServeResponse, ServeError> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| ServeError::Closed)
    }

    /// Live snapshot (admission counters + replica-side telemetry).
    pub fn stats(&self) -> ServeStats {
        self.stats.snapshot(&self.queue.counters())
    }

    /// Close admission, serve everything already queued, join all
    /// threads, and return the final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop_threads();
        self.stats.snapshot(&self.queue.counters())
    }

    fn stop_threads(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stop.store(true, Ordering::Relaxed);
        if let Some(p) = self.poller.take() {
            let _ = p.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

fn worker_loop(
    mut replica: Replica,
    queue: AdmissionQueue<Pending>,
    stats: Arc<StatsCollector>,
    batch_seq: Arc<AtomicU64>,
    reload: Option<Arc<ReloadShared>>,
) {
    while let Some(batch) = queue.next_batch() {
        // Hot-reload strictly between batches: the version is read once
        // per batch, so its requests cannot mix parameter versions.
        if let Some(shared) = &reload {
            let published = shared.published.load(Ordering::SeqCst);
            if published != replica.version {
                match replica.reload(&shared.path) {
                    Ok(()) => {
                        replica.version = published;
                        stats.note_reload();
                    }
                    Err(e) => crate::warn!(
                        "serve replica {}: hot-reload of {} failed: {e}; keeping version {}",
                        replica.id,
                        shared.path.display(),
                        replica.version
                    ),
                }
            }
        }
        let seq = batch_seq.fetch_add(1, Ordering::SeqCst);
        let inputs: Vec<&[f32]> = batch.iter().map(|r| r.payload.input.as_slice()).collect();
        let outputs = replica.infer_batch(&inputs);
        let done = Instant::now();
        stats.note_batch(batch.len(), batch.iter().map(|r| done.duration_since(r.enqueued)));
        let size = batch.len();
        for (req, output) in batch.into_iter().zip(outputs) {
            // The receiver may have hung up; that's the caller's choice.
            let _ = req.payload.resp.send(ServeResponse {
                output,
                version: replica.version,
                batch_seq: seq,
                batch_size: size,
                replica: replica.id,
            });
        }
    }
}

/// Cheap change detector for the checkpoint path. Atomic-rename writes
/// mean the file is always complete; (len, mtime) changes on every swap
/// (tmpfs/ext4 keep nanosecond mtimes, and a same-length same-instant
/// rewrite is not a case the trainer can produce between poll ticks).
fn fingerprint(path: &Path) -> Option<(u64, Option<SystemTime>)> {
    std::fs::metadata(path).ok().map(|m| (m.len(), m.modified().ok()))
}

fn poll_loop(shared: Arc<ReloadShared>, poll: Duration, stop: Arc<AtomicBool>) {
    let mut last = fingerprint(&shared.path);
    let tick = poll.min(Duration::from_millis(20)).max(Duration::from_millis(1));
    let mut since_poll = Duration::ZERO;
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(tick);
        since_poll += tick;
        if since_poll < poll {
            continue;
        }
        since_poll = Duration::ZERO;
        let now = fingerprint(&shared.path);
        if now.is_some() && now != last {
            last = now;
            shared.published.fetch_add(1, Ordering::SeqCst);
        }
    }
}
