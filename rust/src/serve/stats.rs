//! Serving telemetry: per-request latency percentiles, batch-occupancy
//! histogram, and loop-closure counters.
//!
//! The collector is written to by every replica worker (batch completion)
//! and read by `ServeEngine::stats`/`shutdown`, which folds in the
//! admission queue's counters so one snapshot closes the loop:
//! `submitted == served` (+ every shed accounted) when the stream drained.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::admission::QueueCounters;
use crate::util::json::Json;

/// One snapshot of the serving loop.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests accepted into the admission queue.
    pub submitted: u64,
    /// Requests that received a response.
    pub served: u64,
    /// Requests rejected at admission (queue full).
    pub shed: u64,
    /// Batches executed across all replicas.
    pub batches: u64,
    /// Successful checkpoint hot-reloads across all replicas.
    pub reloads: u64,
    /// Deepest the admission queue ever got.
    pub queue_high_water: usize,
    /// `occupancy[i]` = number of executed batches of size `i + 1`.
    pub occupancy: Vec<u64>,
    /// Per-request latency (admission → response ready), sorted, ms.
    pub latency_ms: Vec<f64>,
    /// Wall time since the engine started, seconds.
    pub wall_secs: f64,
}

impl ServeStats {
    /// Latency percentile in ms (`NaN` when nothing was served yet).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latency_ms.is_empty() {
            return f64::NAN;
        }
        let idx = (p / 100.0 * (self.latency_ms.len() - 1) as f64).round() as usize;
        self.latency_ms[idx.min(self.latency_ms.len() - 1)]
    }

    pub fn mean_batch(&self) -> f64 {
        self.served as f64 / self.batches.max(1) as f64
    }

    /// Batches that actually coalesced more than one request.
    pub fn multi_request_batches(&self) -> u64 {
        self.occupancy.iter().skip(1).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.served as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// The `results` object of a `BENCH_serve.json` run. Non-finite
    /// percentiles (nothing served) become `null`, keeping the file
    /// machine-parseable.
    pub fn to_json(&self) -> Json {
        fn num_or_null(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        let mut o = Json::obj();
        o.set("submitted", Json::Num(self.submitted as f64));
        o.set("served", Json::Num(self.served as f64));
        o.set("shed", Json::Num(self.shed as f64));
        o.set("batches", Json::Num(self.batches as f64));
        o.set("reloads", Json::Num(self.reloads as f64));
        o.set("queue_high_water", Json::Num(self.queue_high_water as f64));
        o.set("p50_ms", num_or_null(self.percentile_ms(50.0)));
        o.set("p95_ms", num_or_null(self.percentile_ms(95.0)));
        o.set("p99_ms", num_or_null(self.percentile_ms(99.0)));
        o.set("mean_batch", Json::Num(self.mean_batch()));
        o.set(
            "occupancy",
            Json::Arr(self.occupancy.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        o.set("throughput_rps", Json::Num(self.throughput_rps()));
        o.set("wall_secs", Json::Num(self.wall_secs));
        o
    }
}

struct CollectorState {
    served: u64,
    batches: u64,
    reloads: u64,
    occupancy: Vec<u64>,
    latency_ms: Vec<f64>,
}

/// Shared, thread-safe accumulator behind `ServeStats`.
pub struct StatsCollector {
    start: Instant,
    state: Mutex<CollectorState>,
}

impl StatsCollector {
    /// `max_batch` sizes the occupancy histogram (one bin per batch size).
    pub fn new(max_batch: usize) -> StatsCollector {
        StatsCollector {
            start: Instant::now(),
            state: Mutex::new(CollectorState {
                served: 0,
                batches: 0,
                reloads: 0,
                occupancy: vec![0; max_batch.max(1)],
                latency_ms: Vec::new(),
            }),
        }
    }

    /// Record one executed batch and its per-request latencies.
    pub fn note_batch<I: IntoIterator<Item = Duration>>(&self, size: usize, latencies: I) {
        let mut st = self.state.lock().unwrap();
        st.served += size as u64;
        st.batches += 1;
        let bin = size.saturating_sub(1).min(st.occupancy.len() - 1);
        st.occupancy[bin] += 1;
        st.latency_ms.extend(latencies.into_iter().map(|d| d.as_secs_f64() * 1e3));
    }

    pub fn note_reload(&self) {
        self.state.lock().unwrap().reloads += 1;
    }

    /// Fold in the admission counters and produce a sorted snapshot.
    pub fn snapshot(&self, counters: &QueueCounters) -> ServeStats {
        let st = self.state.lock().unwrap();
        let mut latency_ms = st.latency_ms.clone();
        latency_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ServeStats {
            submitted: counters.submitted,
            served: st.served,
            shed: counters.shed,
            batches: st.batches,
            reloads: st.reloads,
            queue_high_water: counters.depth_high_water,
            occupancy: st.occupancy.clone(),
            latency_ms,
            wall_secs: self.start.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_percentiles() {
        let c = StatsCollector::new(4);
        c.note_batch(1, [Duration::from_millis(1)]);
        c.note_batch(3, (0..3).map(|i| Duration::from_millis(2 + i)));
        c.note_reload();
        let s = c.snapshot(&QueueCounters { submitted: 4, shed: 2, depth_high_water: 3 });
        assert_eq!(s.served, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.reloads, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.occupancy, vec![1, 0, 1, 0]);
        assert_eq!(s.multi_request_batches(), 1);
        assert!((s.mean_batch() - 2.0).abs() < 1e-12);
        assert!(s.percentile_ms(0.0) <= s.percentile_ms(99.0));
        assert!(s.percentile_ms(99.0) <= 4.5);
    }

    #[test]
    fn empty_snapshot_is_null_safe() {
        let c = StatsCollector::new(8);
        let s = c.snapshot(&QueueCounters::default());
        assert!(s.percentile_ms(50.0).is_nan());
        let j = s.to_json();
        assert!(matches!(j.get("p99_ms"), Some(Json::Null)));
        // The JSON text must stay parseable even with no traffic.
        assert!(Json::parse(&j.pretty()).is_ok());
    }

    #[test]
    fn oversize_batches_clamp_into_last_bin() {
        let c = StatsCollector::new(2);
        c.note_batch(5, std::iter::empty());
        let s = c.snapshot(&QueueCounters::default());
        assert_eq!(s.occupancy, vec![0, 1]);
    }
}
