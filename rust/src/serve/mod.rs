//! Batched async inference serving over the native engine.
//!
//! L2ight's deployment pitch is latency (photonic cores execute a
//! projection in near-constant optical time), and latency is judged at
//! the *service* boundary: concurrent single-sample requests, not offline
//! batches. This module turns the simulator into that service:
//!
//! * [`admission`] — bounded, deadline-aware batching queue (generalizes
//!   `coordinator::Batcher`). Saturation sheds instead of blocking.
//! * [`replica`] — N model clones executing coalesced batches; feature
//!   inputs take a packed fast path straight into
//!   `ProjEngine::forward_packed` panels, bitwise identical to per-sample
//!   forwards within a SIMD dispatch level.
//! * [`engine`] — the worker/reload orchestration: responses tagged with
//!   parameter version + batch id; checkpoint hot-reload between batches
//!   (atomic-rename checkpoints are safe to poll).
//! * [`stats`] — latency percentiles, batch-occupancy histogram, and
//!   loop-closure counters (`submitted == served + in-flight`, shed
//!   accounted).
//! * [`bench`] — open-loop load generator behind `l2ight serve-bench`
//!   and `benches/serve_latency.rs`, emitting `BENCH_serve.json`.
//!
//! See `rust/README.md` § "Serving" for the architecture sketch and
//! `tests/serve_equivalence.rs` for the determinism contract.

pub mod admission;
pub mod bench;
pub mod engine;
pub mod replica;
pub mod stats;

pub use admission::{AdmissionConfig, AdmissionQueue, QueueCounters, Request};
pub use engine::{ReloadConfig, ServeConfig, ServeEngine, ServeError, ServeResponse};
pub use replica::Replica;
pub use stats::{ServeStats, StatsCollector};
