//! Open-loop load generator for the serving engine, shared by the
//! `l2ight serve-bench` subcommand and `benches/serve_latency.rs`.
//!
//! Arrivals are *open-loop*: request i is submitted at `t0 + i/qps`
//! regardless of how fast responses come back (a closed loop would hide
//! queueing collapse — the coordinated-omission trap). Latency is
//! measured admission → response-ready inside the engine, so percentiles
//! include queueing, batching wait, and execution.
//!
//! Results append to `BENCH_serve.json` with the same history/git-rev
//! schema as `BENCH_perf_hotpath.json`: `{bench, schema, runs: [...]}`,
//! last 50 runs kept, each run stamped with git rev, thread count, SIMD
//! level, and wall-clock time.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use super::engine::{ServeConfig, ServeEngine, ServeResponse};
use super::stats::ServeStats;
use crate::data::{Dataset, DatasetKind, SynthSpec};
use crate::linalg::simd;
use crate::nn::{build_model, Act, EngineKind, Model, ModelArch};
use crate::photonics::NoiseModel;
use crate::util::bench::{git_rev, unix_time};
use crate::util::json::Json;
use crate::util::{pool, Rng};

/// Everything one bench run needs: the model under load + the load shape.
#[derive(Clone, Debug)]
pub struct ServeBenchConfig {
    pub arch: ModelArch,
    pub engine: EngineKind,
    /// Human-readable engine descriptor recorded in the JSON run
    /// (e.g. `photonic-k4/paper`).
    pub engine_label: String,
    pub width: f32,
    pub seed: u64,
    pub replicas: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    /// Open-loop arrival rate at the primary level.
    pub qps: f64,
    /// Requests per level.
    pub requests: usize,
    /// Also run a 1×/2×/4×/8× QPS ladder to find saturation throughput.
    pub sweep: bool,
    pub quick: bool,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig {
            arch: ModelArch::MlpVowel,
            engine: EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER },
            engine_label: "photonic-k4/paper".to_string(),
            width: 1.0,
            seed: 42,
            replicas: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            qps: 1500.0,
            requests: 3000,
            sweep: false,
            quick: false,
        }
    }
}

impl ServeBenchConfig {
    /// The CI smoke preset (~2 s of load): low QPS, a generous queue (the
    /// serve-smoke leg asserts zero shed), and a batching window wide
    /// enough that coalescing demonstrably happens.
    pub fn quick() -> ServeBenchConfig {
        ServeBenchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            queue_cap: 8192,
            qps: 500.0,
            requests: 1000,
            sweep: false,
            quick: true,
            ..ServeBenchConfig::default()
        }
    }
}

/// The synthetic dataset whose sample shape feeds `arch`.
pub fn dataset_kind_for(arch: ModelArch) -> DatasetKind {
    match arch {
        ModelArch::MlpVowel => DatasetKind::VowelLike,
        ModelArch::CnnS => DatasetKind::MnistLike,
        ModelArch::CnnL => DatasetKind::FashionLike,
        ModelArch::Vgg8 | ModelArch::ResNet18 => DatasetKind::Cifar10Like,
    }
}

/// One rung of the saturation ladder.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub qps: f64,
    pub served_rps: f64,
    pub shed_frac: f64,
    pub p99_ms: f64,
}

/// Outcome of `run_serve_bench`.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Final stats of the primary (target-QPS) level.
    pub stats: ServeStats,
    pub target_qps: f64,
    /// Served throughput actually achieved at the primary level.
    pub achieved_rps: f64,
    /// Submit attempts at the primary level (admitted + shed).
    pub sent: u64,
    pub sweep: Vec<SweepPoint>,
    /// Peak served throughput observed across the ladder (None w/o sweep).
    pub saturation_rps: Option<f64>,
}

/// Build the model, warm its realization + the pool, then drive the load.
pub fn run_serve_bench(cfg: &ServeBenchConfig) -> BenchResult {
    let kind = dataset_kind_for(cfg.arch);
    let (ds, _) = SynthSpec::quick(kind, 256, 1).generate();
    let mut rng = Rng::new(cfg.seed);
    let mut template = build_model(cfg.arch, cfg.engine, ds.classes, cfg.width, &mut rng);
    // One untimed forward realizes the mesh caches and spins up the pool,
    // so replica clones start warm and the clock measures serving only.
    let x0 = Act::from_nchw(ds.sample(0), 1, ds.c, ds.h, ds.w);
    let _ = template.forward(&x0, false);
    template.clear_caches();

    let (stats, wall, sent) = run_level(&template, &ds, cfg, cfg.qps);
    let achieved_rps = if wall > 0.0 { stats.served as f64 / wall } else { 0.0 };

    let mut sweep = Vec::new();
    let mut saturation_rps = None;
    if cfg.sweep {
        for mult in [1.0, 2.0, 4.0, 8.0] {
            let qps = cfg.qps * mult;
            let (s, w, _) = run_level(&template, &ds, cfg, qps);
            let served_rps = if w > 0.0 { s.served as f64 / w } else { 0.0 };
            let attempts = (s.submitted + s.shed).max(1);
            let shed_frac = s.shed as f64 / attempts as f64;
            sweep.push(SweepPoint { qps, served_rps, shed_frac, p99_ms: s.percentile_ms(99.0) });
            if served_rps > saturation_rps.unwrap_or(0.0) {
                saturation_rps = Some(served_rps);
            }
            if shed_frac > 0.5 {
                break; // far past the knee; higher rungs only shed more
            }
        }
    }

    BenchResult { stats, target_qps: cfg.qps, achieved_rps, sent, sweep, saturation_rps }
}

/// Drive one open-loop level against a fresh engine; returns (final
/// stats, wall seconds over submit+drain, submit attempts).
fn run_level(
    template: &Model,
    ds: &Dataset,
    cfg: &ServeBenchConfig,
    qps: f64,
) -> (ServeStats, f64, u64) {
    let engine = ServeEngine::start(
        template.clone(),
        (ds.c, ds.h, ds.w),
        ServeConfig {
            replicas: cfg.replicas,
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            queue_cap: cfg.queue_cap,
            reload: None,
        },
    );
    // The drainer owns every response channel so the pacer never waits on
    // results (open loop); it just counts completions.
    let (hand_tx, hand_rx) = channel::<Receiver<ServeResponse>>();
    let drainer = std::thread::spawn(move || {
        let mut served = 0u64;
        while let Ok(rx) = hand_rx.recv() {
            if rx.recv().is_ok() {
                served += 1;
            }
        }
        served
    });

    let t0 = Instant::now();
    for i in 0..cfg.requests {
        let target = t0 + Duration::from_secs_f64(i as f64 / qps);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        // Behind schedule: submit immediately (open-loop catch-up).
        let sample = ds.sample(i % ds.n).to_vec();
        if let Ok(rx) = engine.submit(sample) {
            hand_tx.send(rx).expect("drainer alive");
        }
    }
    drop(hand_tx);
    let drained = drainer.join().expect("drainer");
    let wall = t0.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    assert_eq!(stats.served, drained, "every admitted request must be drained");
    (stats, wall, cfg.requests as u64)
}

/// Assemble one `runs[]` entry (perf_hotpath schema: git rev, threads,
/// SIMD level, quick flag, unix time + config/results objects).
pub fn bench_run_json(cfg: &ServeBenchConfig, res: &BenchResult) -> Json {
    let mut run = Json::obj();
    run.set("git_rev", Json::Str(git_rev()));
    run.set("threads", Json::Num(pool::global().threads() as f64));
    run.set("simd", Json::Str(simd::active().name().to_string()));
    run.set("quick", Json::Bool(cfg.quick));
    run.set("unix_time", Json::Num(unix_time()));

    let mut c = Json::obj();
    c.set("arch", Json::Str(cfg.arch.name().to_string()));
    c.set("engine", Json::Str(cfg.engine_label.clone()));
    c.set("width", Json::Num(cfg.width as f64));
    c.set("seed", Json::Num(cfg.seed as f64));
    c.set("replicas", Json::Num(cfg.replicas as f64));
    c.set("max_batch", Json::Num(cfg.max_batch as f64));
    c.set("max_wait_ms", Json::Num(cfg.max_wait.as_secs_f64() * 1e3));
    c.set("queue_cap", Json::Num(cfg.queue_cap as f64));
    c.set("qps", Json::Num(cfg.qps));
    c.set("requests", Json::Num(cfg.requests as f64));
    run.set("config", c);

    let mut results = res.stats.to_json();
    results.set("target_qps", Json::Num(res.target_qps));
    results.set("achieved_rps", Json::Num(res.achieved_rps));
    results.set("sent", Json::Num(res.sent as f64));
    results.set(
        "saturation_rps",
        res.saturation_rps.map(Json::Num).unwrap_or(Json::Null),
    );
    let sweep = res
        .sweep
        .iter()
        .map(|p| {
            let mut o = Json::obj();
            o.set("qps", Json::Num(p.qps));
            o.set("served_rps", Json::Num(p.served_rps));
            o.set("shed_frac", Json::Num(p.shed_frac));
            o.set("p99_ms", if p.p99_ms.is_finite() { Json::Num(p.p99_ms) } else { Json::Null });
            o
        })
        .collect();
    results.set("sweep", Json::Arr(sweep));
    run.set("results", results);
    run
}

/// Append `run` to the history file at `path` (creating it if needed),
/// keeping the last 50 runs — same mechanics as `BENCH_perf_hotpath.json`.
pub fn append_history(path: &Path, run: Json) -> std::io::Result<()> {
    let mut runs: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|src| Json::parse(&src).ok())
        .and_then(|root| root.get("runs").and_then(|r| r.as_arr()).map(|r| r.to_vec()))
        .unwrap_or_default();
    runs.push(run);
    let keep = runs.len().saturating_sub(50);
    let runs = runs.split_off(keep);
    let mut root = Json::obj();
    root.set("bench", Json::Str("serve".to_string()));
    root.set("schema", Json::Num(1.0));
    root.set("runs", Json::Arr(runs));
    std::fs::write(path, root.pretty() + "\n")
}

/// Human-readable report, shared by the CLI and the bench binary.
pub fn print_summary(cfg: &ServeBenchConfig, res: &BenchResult) {
    let s = &res.stats;
    println!(
        "\nserve-bench: {} / {} · {} replicas · max_batch {} · max_wait {:.1} ms",
        cfg.arch.name(),
        cfg.engine_label,
        cfg.replicas,
        cfg.max_batch,
        cfg.max_wait.as_secs_f64() * 1e3
    );
    println!(
        "load           : target {:.0} qps open-loop, {} sent, {} admitted, {} shed",
        res.target_qps, res.sent, s.submitted, s.shed
    );
    println!(
        "served         : {} in {:.2} s  ({:.0} req/s achieved)",
        s.served, s.wall_secs, res.achieved_rps
    );
    println!(
        "batches        : {} (mean size {:.2}, {} multi-request, queue high-water {})",
        s.batches,
        s.mean_batch(),
        s.multi_request_batches(),
        s.queue_high_water
    );
    let occ: Vec<String> = s
        .occupancy
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(i, &n)| format!("{}×{}", i + 1, n))
        .collect();
    println!("occupancy      : {}", occ.join("  "));
    println!("latency p50    : {:.2} ms", s.percentile_ms(50.0));
    println!("latency p95    : {:.2} ms", s.percentile_ms(95.0));
    println!("latency p99    : {:.2} ms", s.percentile_ms(99.0));
    for p in &res.sweep {
        println!(
            "sweep {:>7.0} qps: {:>7.0} served/s  shed {:>5.1}%  p99 {:.2} ms",
            p.qps,
            p.served_rps,
            p.shed_frac * 100.0,
            p.p99_ms
        );
    }
    if let Some(sat) = res.saturation_rps {
        println!("saturation     : {sat:.0} req/s");
    }
}
