//! A serving replica: one private `Model` clone executing coalesced
//! batches on the shared compute pool.
//!
//! Replicas share nothing mutable: each owns its model (and therefore its
//! realized-mesh caches), while all heavy math lands on the global
//! `util::pool` — per-replica *work* is serialized inside one batch, but
//! GEMM/mesh panels still band across every pool thread, and the pool's
//! per-thread scratch arenas double as the per-replica packing buffers
//! (a panel is packed and consumed on the same pool thread).
//!
//! **Determinism contract.** Feature-shaped requests (`h == w == 1`) whose
//! model opens with a `Linear` layer take the packed fast path: the
//! admitted single-sample columns are gathered straight into
//! `ProjEngine::forward_packed` GEMM panels without materializing the
//! `[features, batch]` matrix. Because every kernel accumulates each
//! output element in a fixed k-order independent of the panel's column
//! count (see `linalg::simd`), a coalesced batch is **bitwise identical**
//! to per-sample forwards — at every batch size, replica count, thread
//! count, and partition, within one SIMD dispatch level. Image-shaped
//! requests gather into a normal NCHW activation and run the fused conv
//! path, which carries the same per-element invariance.

use std::path::Path;

use crate::coordinator::checkpoint::load_model_state;
use crate::nn::model::forward_nodes;
use crate::nn::{Act, Layer, Model, Node};

/// One model replica plus the parameter-version tag it is serving.
pub struct Replica {
    pub id: usize,
    /// Monotone checkpoint version: 0 = the engine's starting parameters,
    /// bumped once per applied hot-reload. Read once per batch, so a batch
    /// can never mix parameter versions.
    pub version: u64,
    model: Model,
    /// Input sample shape (channels, height, width).
    shape: (usize, usize, usize),
}

impl Replica {
    pub fn new(id: usize, model: Model, shape: (usize, usize, usize)) -> Replica {
        Replica { id, version: 0, model, shape }
    }

    /// Values per input sample.
    pub fn input_len(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    /// Run one coalesced batch in eval mode; returns one logits vector per
    /// input, in order.
    pub fn infer_batch(&mut self, inputs: &[&[f32]]) -> Vec<Vec<f32>> {
        let b = inputs.len();
        let (c, h, w) = self.shape;
        for x in inputs {
            assert_eq!(x.len(), self.input_len(), "replica input length");
        }
        let linear_first =
            matches!(self.model.nodes.first(), Some(Node::Plain(Layer::Linear(_))));
        let y = if h == 1 && w == 1 && linear_first {
            // Packed fast path: admitted columns go straight into the
            // first projection's GEMM panels; the rest of the graph runs
            // on the resulting feature activation.
            let (head, rest) = self.model.nodes.split_at_mut(1);
            let a = match &mut head[0] {
                Node::Plain(Layer::Linear(lin)) => lin.forward_gathered(inputs),
                _ => unreachable!("guarded by the matches! above"),
            };
            forward_nodes(rest, &a, false)
        } else {
            // Image-shaped (or non-Linear-first) models: gather into one
            // NCHW activation and run the normal fused forward.
            let mut flat = Vec::with_capacity(b * c * h * w);
            for x in inputs {
                flat.extend_from_slice(x);
            }
            let x = Act::from_nchw(&flat, b, c, h, w);
            self.model.forward(&x, false)
        };
        // Eval-mode forwards still stash activation caches in some layers;
        // a serving replica never runs backward, so drop them.
        self.model.clear_caches();
        assert_eq!(y.mat.cols, b, "logits column count");
        let (rows, cols) = (y.mat.rows, y.mat.cols);
        (0..b)
            .map(|j| (0..rows).map(|r| y.mat.data[r * cols + j]).collect())
            .collect()
    }

    /// Swap in a checkpoint (atomic-rename files from
    /// `coordinator::checkpoint`, so a partial write is never visible).
    /// On error the previous parameters stay live.
    pub fn reload(&mut self, path: &Path) -> std::io::Result<()> {
        load_model_state(&mut self.model, path)
    }
}
