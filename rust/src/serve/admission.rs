//! Request admission: the bounded, deadline-aware batching queue at the
//! front door of the serving engine.
//!
//! `AdmissionQueue` is the generalization of the original
//! `coordinator::Batcher` channel loop into a standalone primitive:
//!
//! * **Bounded** — `try_submit` never blocks. When the queue holds
//!   `queue_cap` requests the submission is *shed* (the 429 of this API)
//!   and the payload handed back to the caller, so saturation degrades
//!   into explicit rejects instead of unbounded memory growth or client
//!   head-of-line stalls.
//! * **Deadline-aware coalescing** — a consumer calling `next_batch`
//!   collects requests until either `max_batch` are queued or the *oldest*
//!   queued request has waited `max_wait`. The deadline belongs to the
//!   request, not the poll: a request admitted under light load leaves
//!   after at most `max_wait`, while a burst flushes immediately.
//! * **Multi-consumer** — any number of workers (serve replicas) may call
//!   `next_batch` concurrently; the mutex serializes drains so each
//!   request is handed to exactly one worker and FIFO order is preserved
//!   within a batch.
//!
//! `coordinator::Batcher` now runs its single worker over this queue with
//! an unbounded cap (its legacy contract); the serving engine runs N
//! replica workers over a bounded one.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Admission policy.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Flush a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// …or when the oldest queued request has waited this long.
    pub max_wait: Duration,
    /// Queue depth at which new submissions are shed. `usize::MAX`
    /// effectively disables backpressure (the legacy `Batcher` contract).
    pub queue_cap: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        }
    }
}

/// One admitted request: the caller's payload plus its admission time
/// (the batching deadline and latency accounting both key off it).
#[derive(Debug)]
pub struct Request<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Admission-side counters, folded into `ServeStats` at snapshot time.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueCounters {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests rejected because the queue was at capacity.
    pub shed: u64,
    /// Deepest the queue ever got.
    pub depth_high_water: usize,
}

struct QState<T> {
    queue: VecDeque<Request<T>>,
    closed: bool,
    counters: QueueCounters,
}

struct Inner<T> {
    state: Mutex<QState<T>>,
    /// Signaled on submit and close; batching workers also use it as the
    /// deadline timer via `wait_timeout`.
    nonempty: Condvar,
    cfg: AdmissionConfig,
}

/// A cloneable handle to one shared admission queue.
pub struct AdmissionQueue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for AdmissionQueue<T> {
    fn clone(&self) -> Self {
        AdmissionQueue { inner: Arc::clone(&self.inner) }
    }
}

impl<T> AdmissionQueue<T> {
    pub fn new(cfg: AdmissionConfig) -> AdmissionQueue<T> {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        AdmissionQueue {
            inner: Arc::new(Inner {
                state: Mutex::new(QState {
                    queue: VecDeque::new(),
                    closed: false,
                    counters: QueueCounters::default(),
                }),
                nonempty: Condvar::new(),
                cfg,
            }),
        }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.inner.cfg
    }

    /// Admit one request, or shed it. Never blocks: a full (or closed)
    /// queue returns the payload to the caller immediately.
    pub fn try_submit(&self, payload: T) -> Result<(), T> {
        let mut st = self.inner.state.lock().unwrap();
        if st.closed || st.queue.len() >= self.inner.cfg.queue_cap {
            if !st.closed {
                st.counters.shed += 1;
            }
            return Err(payload);
        }
        st.queue.push_back(Request { payload, enqueued: Instant::now() });
        st.counters.submitted += 1;
        let depth = st.queue.len();
        st.counters.depth_high_water = st.counters.depth_high_water.max(depth);
        drop(st);
        // notify_all, not _one: besides idle workers, a worker mid-
        // accumulation must wake to notice the batch just filled up.
        self.inner.nonempty.notify_all();
        Ok(())
    }

    /// Block until a batch is ready and hand it over (FIFO within the
    /// batch). Returns `None` once the queue is closed *and* drained —
    /// requests admitted before `close` are still served.
    pub fn next_batch(&self) -> Option<Vec<Request<T>>> {
        let cfg = &self.inner.cfg;
        let mut st = self.inner.state.lock().unwrap();
        'refill: loop {
            while st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.inner.nonempty.wait(st).unwrap();
            }
            // Accumulate until the batch is full, the oldest request's
            // deadline passes, or the queue closes. The condvar wait
            // releases the lock, so submissions (and rival workers)
            // proceed while we wait.
            while st.queue.len() < cfg.max_batch && !st.closed {
                let deadline = st.queue.front().unwrap().enqueued + cfg.max_wait;
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    self.inner.nonempty.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if st.queue.is_empty() {
                    // Another worker drained the queue while we waited.
                    continue 'refill;
                }
                if timeout.timed_out() {
                    break;
                }
            }
            let take = st.queue.len().min(cfg.max_batch);
            return Some(st.queue.drain(..take).collect());
        }
    }

    /// Stop admitting. Queued requests are still handed out by
    /// `next_batch`; once drained, workers see `None` and exit.
    pub fn close(&self) {
        self.inner.state.lock().unwrap().closed = true;
        self.inner.nonempty.notify_all();
    }

    pub fn counters(&self) -> QueueCounters {
        self.inner.state.lock().unwrap().counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_wait_ms: u64, cap: usize) -> AdmissionConfig {
        AdmissionConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap: cap,
        }
    }

    #[test]
    fn full_queue_sheds_immediately() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(4, 1, 4));
        let t0 = Instant::now();
        for i in 0..4 {
            assert!(q.try_submit(i).is_ok());
        }
        // Fifth must be shed, returning the payload, without blocking.
        assert_eq!(q.try_submit(99), Err(99));
        assert!(t0.elapsed() < Duration::from_secs(2), "submit blocked");
        let c = q.counters();
        assert_eq!(c.submitted, 4);
        assert_eq!(c.shed, 1);
        assert_eq!(c.depth_high_water, 4);
    }

    #[test]
    fn deadline_flushes_partial_batch_fifo() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(8, 30, 64));
        for i in 0..5 {
            q.try_submit(i).unwrap();
        }
        let batch = q.next_batch().unwrap();
        let got: Vec<u32> = batch.into_iter().map(|r| r.payload).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4], "FIFO order broken");
    }

    #[test]
    fn full_batch_flushes_without_waiting() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(4, 5_000, 64));
        for i in 0..10 {
            q.try_submit(i).unwrap();
        }
        // max_wait is 5 s, but a full batch must not wait for it.
        let t0 = Instant::now();
        assert_eq!(q.next_batch().unwrap().len(), 4);
        assert_eq!(q.next_batch().unwrap().len(), 4);
        assert!(t0.elapsed() < Duration::from_secs(4), "full batches waited on deadline");
    }

    #[test]
    fn close_drains_then_ends() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(4, 1, 64));
        for i in 0..6 {
            q.try_submit(i).unwrap();
        }
        q.close();
        assert_eq!(q.try_submit(7), Err(7), "closed queue must not admit");
        assert_eq!(q.next_batch().unwrap().len(), 4);
        assert_eq!(q.next_batch().unwrap().len(), 2);
        assert!(q.next_batch().is_none());
        // A shed on a closed queue is not counted as saturation.
        assert_eq!(q.counters().shed, 0);
    }

    #[test]
    fn concurrent_workers_partition_the_stream() {
        // Every request is handed to exactly one of two workers.
        let q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(4, 1, 1024));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut seen = Vec::new();
                    while let Some(batch) = q.next_batch() {
                        seen.extend(batch.into_iter().map(|r| r.payload));
                    }
                    seen
                })
            })
            .collect();
        for i in 0..200u32 {
            q.try_submit(i).unwrap();
        }
        q.close();
        let mut all: Vec<u32> =
            workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<u32>>());
    }
}
