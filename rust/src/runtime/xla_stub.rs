//! Stub of the `xla` PJRT bindings' API surface, used when the native
//! `xla_extension` crate is not vendored (the default for a clean checkout —
//! tier-1 builds with zero external dependencies).
//!
//! Every entry point type-checks against `runtime::Runtime`'s usage but
//! fails at `PjRtClient::cpu()` with a clear message, so the PJRT-gated
//! paths (pjrt_integration tests, `l2ight infer`, `serve_infer`) degrade to
//! their existing "artifacts unavailable" handling instead of breaking the
//! build. Re-point `runtime/mod.rs` at the real crate to restore execution.

use crate::anyhow;
use crate::util::error::{Error, Result};

fn unavailable() -> Error {
    anyhow!(
        "PJRT/XLA backend not compiled into this build (the `xla` native crate is not \
         vendored); the native simulator paths are unaffected"
    )
}

/// Stub PJRT client — construction always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Stub computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}
