//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the rust hot path. Python never runs here — `make artifacts` is the only
//! python invocation, at build time.
//!
//! * `Manifest` — parses `artifacts/manifest.json` (names, arg shapes/
//!   dtypes, output arity) with the in-repo JSON parser.
//! * `Runtime` — one `PjRtClient::cpu()`, compiling each HLO-text module on
//!   first use and caching the loaded executable (one compiled executable
//!   per model variant).
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §3).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, bail, Context, Result};
use crate::util::json::Json;

/// PJRT bindings: the in-repo stub unless the native `xla` crate is wired
/// back in (see `xla_stub.rs`). The whole `Runtime` API stays identical —
/// only `PjRtClient::cpu()` succeeds or fails differently.
mod xla_stub;
use xla_stub as xla;

/// Element type of one artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported artifact dtype {other}"),
        }
    }
}

/// Declared shape/dtype of one artifact argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&src).map_err(|e| anyhow!("manifest parse: {e:?}"))?;
        let format = root.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if format != "hlo-text" {
            bail!("manifest format {format:?}, expected \"hlo-text\"");
        }
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("artifact {name} missing outputs"))?;
            let mut args = Vec::new();
            for arg in a.get("args").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                let dtype = DType::parse(
                    arg.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32"),
                )?;
                let shape: Vec<usize> = arg
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                    .unwrap_or_default();
                args.push(ArgSpec { shape, dtype });
            }
            artifacts.push(ArtifactSpec { name, file, args, outputs });
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// An input value for one artifact argument.
#[derive(Clone, Debug)]
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl ArgValue<'_> {
    fn len(&self) -> usize {
        match self {
            ArgValue::F32(v) => v.len(),
            ArgValue::I32(v) => v.len(),
        }
    }
}

/// Output buffers of one execution.
#[derive(Clone, Debug)]
pub enum OutValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl OutValue {
    /// Borrow as f32 (errors if the output is integer).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            OutValue::F32(v) => Ok(v),
            OutValue::I32(_) => bail!("output is i32, expected f32"),
        }
    }
}

/// The PJRT runtime: client + per-artifact compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with `args`; returns the flattened outputs.
    ///
    /// Arguments are validated against the manifest (arity, length, dtype)
    /// before anything touches the device.
    pub fn call(&mut self, name: &str, args: &[ArgValue]) -> Result<Vec<OutValue>> {
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        if args.len() != spec.args.len() {
            bail!("artifact {name} expects {} args, got {}", spec.args.len(), args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (a, s)) in args.iter().zip(&spec.args).enumerate() {
            if a.len() != s.numel() {
                bail!(
                    "artifact {name} arg {i}: expected {} elements for shape {:?}, got {}",
                    s.numel(),
                    s.shape,
                    a.len()
                );
            }
            let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
            let lit = match (a, s.dtype) {
                (ArgValue::F32(v), DType::F32) => xla::Literal::vec1(v).reshape(&dims)?,
                (ArgValue::I32(v), DType::I32) => xla::Literal::vec1(v).reshape(&dims)?,
                _ => bail!("artifact {name} arg {i}: dtype mismatch (want {:?})", s.dtype),
            };
            literals.push(lit);
        }
        self.ensure_compiled(name)?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: root is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs {
            bail!("artifact {name}: manifest says {} outputs, got {}", spec.outputs, parts.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            // Try f32 first (the dominant type), fall back to i32.
            match part.to_vec::<f32>() {
                Ok(v) => out.push(OutValue::F32(v)),
                Err(_) => out.push(OutValue::I32(part.to_vec::<i32>()?)),
            }
        }
        Ok(out)
    }

    /// Convenience: single-f32-output call.
    pub fn call1_f32(&mut self, name: &str, args: &[ArgValue]) -> Result<Vec<f32>> {
        let mut outs = self.call(name, args)?;
        if outs.len() != 1 {
            bail!("artifact {name} has {} outputs, expected 1", outs.len());
        }
        match outs.pop().unwrap() {
            OutValue::F32(v) => Ok(v),
            OutValue::I32(_) => bail!("artifact {name} output is i32"),
        }
    }
}

/// Default artifact directory: `$L2IGHT_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("L2IGHT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
          "format": "hlo-text",
          "artifacts": [
            {"name": "a", "file": "a.hlo.txt",
             "args": [{"shape": [2, 3], "dtype": "f32"},
                      {"shape": [4], "dtype": "i32"}],
             "outputs": 2}
          ]
        }"#
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("l2ight_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("a").unwrap();
        assert_eq!(a.args.len(), 2);
        assert_eq!(a.args[0].shape, vec![2, 3]);
        assert_eq!(a.args[0].numel(), 6);
        assert_eq!(a.args[1].dtype, DType::I32);
        assert_eq!(a.outputs, 2);
        assert!(m.find("b").is_none());
    }

    #[test]
    fn manifest_rejects_bad_format() {
        let dir = std::env::temp_dir().join("l2ight_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"format": "proto", "artifacts": []}"#)
            .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_error_with_hint() {
        let dir = std::env::temp_dir().join("l2ight_manifest_none");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
