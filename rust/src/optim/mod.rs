//! First-order optimizers for the digital pretraining and the on-chip
//! subspace-learning stage (§3.4: AdamW on Σ, lr 0.002, wd 0.01), plus the
//! LR schedules the paper uses (cosine annealing for SL, exponential decay
//! inside the ZOO stages).

use std::collections::HashMap;

/// A keyed, slice-oriented optimizer. Keys identify parameter tensors
/// (stable traversal order from `Model::step`).
pub trait Optimizer {
    /// One update of `param` given `grad`; `decay` gates weight decay.
    fn step(&mut self, key: usize, param: &mut [f32], grad: &[f32], decay: bool);
    fn set_lr(&mut self, lr: f32);
    fn lr(&self) -> f32;
    /// Advance internal iteration counters (call once per optimizer step, not
    /// per tensor) — only AdamW's bias correction cares.
    fn tick(&mut self) {}
}

/// SGD with classical momentum and L2 weight decay.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: HashMap<usize, Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, weight_decay: f32) -> Sgd {
        Sgd { lr, momentum, weight_decay, velocity: HashMap::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, key: usize, param: &mut [f32], grad: &[f32], decay: bool) {
        assert_eq!(param.len(), grad.len(), "sgd grad size");
        let v = self.velocity.entry(key).or_insert_with(|| vec![0.0; param.len()]);
        assert_eq!(v.len(), param.len(), "sgd state size changed");
        let wd = if decay { self.weight_decay } else { 0.0 };
        for i in 0..param.len() {
            let g = grad[i] + wd * param[i];
            v[i] = self.momentum * v[i] + g;
            param[i] -= self.lr * v[i];
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.lr
    }
}

/// AdamW (decoupled weight decay) — the paper's subspace-learning optimizer.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    t: i32,
    m: HashMap<usize, Vec<f32>>,
    v: HashMap<usize, Vec<f32>>,
}

impl AdamW {
    pub fn new(lr: f32, weight_decay: f32) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            t: 0,
            m: HashMap::new(),
            v: HashMap::new(),
        }
    }

    /// The paper's SL-from-scratch setting (lr 0.002, wd 0.01).
    pub fn paper_scratch() -> AdamW {
        AdamW::new(0.002, 0.01)
    }

    /// The paper's SL-after-mapping setting (lr 0.0002).
    pub fn paper_mapped() -> AdamW {
        AdamW::new(0.0002, 0.01)
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, key: usize, param: &mut [f32], grad: &[f32], decay: bool) {
        assert_eq!(param.len(), grad.len(), "adamw grad size");
        let t = (self.t.max(1)) as f32;
        let m = self.m.entry(key).or_insert_with(|| vec![0.0; param.len()]);
        let v = self.v.entry(key).or_insert_with(|| vec![0.0; param.len()]);
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let wd = if decay { self.weight_decay } else { 0.0 };
        for i in 0..param.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * grad[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            param[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + wd * param[i]);
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn tick(&mut self) {
        self.t += 1;
    }
}

/// Learning-rate schedules.
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant,
    /// Cosine annealing from lr0 to eta_min over total_steps.
    Cosine { lr0: f32, eta_min: f32, total_steps: usize },
    /// lr0 · decay^step.
    Exponential { lr0: f32, decay: f32, floor: f32 },
}

impl LrSchedule {
    pub fn at(&self, step: usize, current: f32) -> f32 {
        match *self {
            LrSchedule::Constant => current,
            LrSchedule::Cosine { lr0, eta_min, total_steps } => {
                let t = (step as f32 / total_steps.max(1) as f32).min(1.0);
                eta_min + 0.5 * (lr0 - eta_min) * (1.0 + (std::f32::consts::PI * t).cos())
            }
            LrSchedule::Exponential { lr0, decay, floor } => {
                (lr0 * decay.powi(step as i32)).max(floor)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(x) = ½‖x − c‖² with each optimizer; both must converge.
    fn quad_converges(opt: &mut dyn Optimizer) -> f32 {
        let c = [3.0f32, -2.0, 0.5];
        let mut x = [0.0f32; 3];
        for _ in 0..500 {
            opt.tick();
            let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
            opt.step(0, &mut x, &g, false);
        }
        x.iter().zip(&c).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut opt = Sgd::new(0.1, 0.9, 0.0);
        assert!(quad_converges(&mut opt) < 1e-3);
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        let mut opt = AdamW::new(0.05, 0.0);
        assert!(quad_converges(&mut opt) < 1e-2);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = Sgd::new(0.1, 0.0, 0.5);
        let mut x = [1.0f32];
        opt.step(0, &mut x, &[0.0], true);
        assert!(x[0] < 1.0);
        let mut y = [1.0f32];
        opt.step(1, &mut y, &[0.0], false); // decay gated off
        assert_eq!(y[0], 1.0);
    }

    #[test]
    fn adamw_decoupled_decay() {
        // With zero gradient, AdamW still decays the weight by lr·wd·w.
        let mut opt = AdamW::new(0.01, 0.1);
        opt.tick();
        let mut x = [2.0f32];
        opt.step(0, &mut x, &[0.0], true);
        assert!((x[0] - (2.0 - 0.01 * 0.1 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let s = LrSchedule::Cosine { lr0: 1.0, eta_min: 0.1, total_steps: 100 };
        assert!((s.at(0, 0.0) - 1.0).abs() < 1e-6);
        assert!((s.at(100, 0.0) - 0.1).abs() < 1e-6);
        assert!(s.at(50, 0.0) < 1.0 && s.at(50, 0.0) > 0.1);
    }

    #[test]
    fn exponential_schedule_floors() {
        let s = LrSchedule::Exponential { lr0: 1.0, decay: 0.5, floor: 0.1 };
        assert_eq!(s.at(0, 0.0), 1.0);
        assert_eq!(s.at(1, 0.0), 0.5);
        assert_eq!(s.at(10, 0.0), 0.1);
    }

    #[test]
    fn distinct_keys_have_distinct_state() {
        let mut opt = Sgd::new(0.1, 0.9, 0.0);
        let mut a = [0.0f32];
        let mut b = [0.0f32];
        opt.step(0, &mut a, &[1.0], false);
        opt.step(1, &mut b, &[-1.0], false);
        assert!(a[0] < 0.0 && b[0] > 0.0);
    }
}
