//! Monte-Carlo yield estimation over process-variation samples.
//!
//! The template is SNIPPETS.md snippet 2's `OptimizationConstraints` /
//! `yield_estimate`: run N chip instances of one configuration, check each
//! against accuracy and optical-power constraints, and report the
//! pass-rate plus per-metric mean/std/worst-case. Each sample is a full
//! `run_job` with `variation.sample = i` — the whole L2ight flow on that
//! chip instance — so the yield number answers the deployment question
//! "what fraction of fabricated chips does this protocol rescue?".
//!
//! Determinism: samples fan out over the shared pool with `parallel_map`
//! (results in sample order), each sample is a pure function of its
//! config, and all aggregation is sequential scalar f64 — so the report
//! is bitwise-identical at any thread count and shard count within a
//! SIMD level (pinned by `tests/variation_determinism.rs`).

use super::variation::VariationConfig;
use crate::coordinator::config::JobConfig;
use crate::coordinator::driver::run_job;
use crate::coordinator::metrics::MetricSink;
use crate::profiler::CostBreakdown;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;

/// Pass/fail constraints a chip instance must meet to count toward yield.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct YieldConstraints {
    /// Minimum final test accuracy.
    pub min_acc: f64,
    /// Maximum worst-tile optical power penalty, dB.
    pub max_power_penalty_db: f64,
}

impl Default for YieldConstraints {
    fn default() -> Self {
        YieldConstraints { min_acc: 0.25, max_power_penalty_db: 3.0 }
    }
}

/// Mean / population-std / worst-case of one metric across samples.
/// "Worst" is metric-directional: lowest accuracy, highest penalty.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct YieldStat {
    pub mean: f64,
    pub std: f64,
    pub worst: f64,
}

/// Whether larger values of a metric are worse (penalties, query counts)
/// or better (accuracies).
enum Worst {
    Min,
    Max,
}

fn stat(values: &[f64], dir: Worst) -> YieldStat {
    if values.is_empty() {
        return YieldStat::default();
    }
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    let worst = values
        .iter()
        .copied()
        .fold(values[0], |a, b| match dir {
            Worst::Min => a.min(b),
            Worst::Max => a.max(b),
        });
    YieldStat { mean, std: var.sqrt(), worst }
}

impl YieldStat {
    fn to_json(self) -> Json {
        let mut o = Json::obj();
        o.set("mean", Json::Num(self.mean));
        o.set("std", Json::Num(self.std));
        o.set("worst", Json::Num(self.worst));
        o
    }
}

/// One chip instance's outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleOutcome {
    pub sample: u64,
    pub final_acc: f64,
    pub best_acc: f64,
    pub power_penalty_db: f64,
    /// ZO queries spent when the run first reached its accuracy target
    /// (`None`: never reached — see `driver::ZO_TARGET_FRACTION`).
    pub zo_to_target_queries: Option<u64>,
    pub pass: bool,
}

/// The full yield report for one configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct YieldReport {
    pub samples: usize,
    pub passed: usize,
    /// passed / samples.
    pub pass_rate: f64,
    pub constraints: YieldConstraints,
    pub final_acc: YieldStat,
    pub best_acc: YieldStat,
    pub power_penalty_db: YieldStat,
    /// Samples whose run reached the ZO accuracy target.
    pub zo_target_reached: usize,
    /// Stats over `zo_to_target_queries` of the samples that reached it.
    pub zo_to_target_queries: Option<YieldStat>,
    /// Total measured hardware cost across every sample, folded together.
    pub cost: CostBreakdown,
    pub per_sample: Vec<SampleOutcome>,
}

impl YieldReport {
    /// Deterministic JSON (BTreeMap key order + canonical float formatting).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("schema", Json::Num(1.0));
        o.set("samples", Json::Num(self.samples as f64));
        o.set("passed", Json::Num(self.passed as f64));
        o.set("pass_rate", Json::Num(self.pass_rate));
        let mut cons = Json::obj();
        cons.set("min_acc", Json::Num(self.constraints.min_acc));
        cons.set("max_power_penalty_db", Json::Num(self.constraints.max_power_penalty_db));
        o.set("constraints", cons);
        o.set("final_acc", self.final_acc.to_json());
        o.set("best_acc", self.best_acc.to_json());
        o.set("power_penalty_db", self.power_penalty_db.to_json());
        o.set("zo_target_reached", Json::Num(self.zo_target_reached as f64));
        o.set(
            "zo_to_target_queries",
            match self.zo_to_target_queries {
                Some(s) => s.to_json(),
                None => Json::Null,
            },
        );
        let mut cost = Json::obj();
        cost.set("fwd_energy", Json::Num(self.cost.fwd_energy));
        cost.set("wgrad_energy", Json::Num(self.cost.wgrad_energy));
        cost.set("fbk_energy", Json::Num(self.cost.fbk_energy));
        cost.set("fwd_steps", Json::Num(self.cost.fwd_steps));
        cost.set("wgrad_steps", Json::Num(self.cost.wgrad_steps));
        cost.set("fbk_steps", Json::Num(self.cost.fbk_steps));
        o.set("cost", cost);
        let rows: Vec<Json> = self
            .per_sample
            .iter()
            .map(|s| {
                let mut r = Json::obj();
                r.set("sample", Json::Num(s.sample as f64));
                r.set("final_acc", Json::Num(s.final_acc));
                r.set("best_acc", Json::Num(s.best_acc));
                r.set("power_penalty_db", Json::Num(s.power_penalty_db));
                r.set(
                    "zo_to_target_queries",
                    match s.zo_to_target_queries {
                        Some(q) => Json::Num(q as f64),
                        None => Json::Null,
                    },
                );
                r.set("pass", Json::Bool(s.pass));
                r
            })
            .collect();
        o.set("per_sample", Json::Arr(rows));
        o
    }
}

/// Run `samples` chip instances of `base` (its `variation` must be active;
/// sample indices 0..N override `variation.sample`) and fold the outcomes
/// into a yield report.
pub fn estimate_yield(
    base: &JobConfig,
    constraints: &YieldConstraints,
    samples: usize,
    pool: &ThreadPool,
) -> YieldReport {
    let var = base.variation.unwrap_or_default();
    let outs = pool.parallel_map(samples, |i| {
        let mut cfg = base.clone();
        cfg.variation = Some(VariationConfig { sample: i as u64, ..var });
        let mut sink = MetricSink::memory();
        run_job(&cfg, &mut sink)
    });

    let mut per_sample = Vec::with_capacity(samples);
    let mut cost = CostBreakdown::default();
    let (mut finals, mut bests, mut pens) = (Vec::new(), Vec::new(), Vec::new());
    let mut zo_vals = Vec::new();
    let mut passed = 0usize;
    for (i, s) in outs.iter().enumerate() {
        let penalty = s.variation.map(|v| v.power_penalty_db).unwrap_or(0.0);
        let pass = (s.final_acc as f64) >= constraints.min_acc
            && penalty <= constraints.max_power_penalty_db;
        passed += pass as usize;
        cost.add(&s.cost);
        finals.push(s.final_acc as f64);
        bests.push(s.best_acc as f64);
        pens.push(penalty);
        if let Some(q) = s.zo_to_target_queries {
            zo_vals.push(q as f64);
        }
        per_sample.push(SampleOutcome {
            sample: i as u64,
            final_acc: s.final_acc as f64,
            best_acc: s.best_acc as f64,
            power_penalty_db: penalty,
            zo_to_target_queries: s.zo_to_target_queries,
            pass,
        });
    }
    YieldReport {
        samples,
        passed,
        pass_rate: if samples > 0 { passed as f64 / samples as f64 } else { 0.0 },
        constraints: *constraints,
        final_acc: stat(&finals, Worst::Min),
        best_acc: stat(&bests, Worst::Min),
        power_penalty_db: stat(&pens, Worst::Max),
        zo_target_reached: zo_vals.len(),
        zo_to_target_queries: if zo_vals.is_empty() {
            None
        } else {
            Some(stat(&zo_vals, Worst::Max))
        },
        cost,
        per_sample,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Protocol;
    use crate::data::DatasetKind;
    use crate::nn::ModelArch;
    use crate::photonics::NoiseModel;

    fn tiny_cfg() -> JobConfig {
        JobConfig {
            arch: ModelArch::MlpVowel,
            dataset: DatasetKind::VowelLike,
            protocol: Protocol::L2ightSlScratch,
            k: 4,
            noise: NoiseModel::quant_only(8),
            width: 0.5,
            n_train: 48,
            n_test: 24,
            pretrain_epochs: 0,
            epochs: 1,
            batch: 16,
            alpha_w: 0.6,
            alpha_c: 1.0,
            alpha_d: 0.0,
            zo_budget: 0.1,
            seed: 42,
            robustness: None,
            sharding: None,
            variation: Some(VariationConfig {
                gamma_std: 0.01,
                coupler_std: 0.01,
                loss_db_std: 0.01,
                ..Default::default()
            }),
        }
    }

    #[test]
    fn stats_and_pass_rate_are_sane() {
        let pool = ThreadPool::new(2);
        let rep = estimate_yield(&tiny_cfg(), &YieldConstraints::default(), 3, &pool);
        assert_eq!(rep.samples, 3);
        assert_eq!(rep.per_sample.len(), 3);
        assert!((0.0..=1.0).contains(&rep.pass_rate));
        assert_eq!(rep.passed, rep.per_sample.iter().filter(|s| s.pass).count());
        assert!(rep.power_penalty_db.worst >= rep.power_penalty_db.mean);
        assert!(rep.final_acc.worst <= rep.final_acc.mean);
        assert!(rep.cost.total_energy() > 0.0, "sample cost not folded in");
        // Samples are distinct chips: the penalty spread is nonzero.
        assert!(rep.power_penalty_db.std > 0.0, "samples did not vary");
    }

    #[test]
    fn report_is_deterministic_across_pool_sizes() {
        let a = estimate_yield(&tiny_cfg(), &YieldConstraints::default(), 2, &ThreadPool::new(1));
        let b = estimate_yield(&tiny_cfg(), &YieldConstraints::default(), 2, &ThreadPool::new(4));
        assert_eq!(a.to_json().dump(), b.to_json().dump());
    }

    #[test]
    fn stat_helper_directions() {
        let s = stat(&[1.0, 2.0, 3.0], Worst::Min);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.worst, 1.0);
        let s = stat(&[1.0, 2.0, 3.0], Worst::Max);
        assert_eq!(s.worst, 3.0);
        assert_eq!(stat(&[], Worst::Min), YieldStat::default());
    }
}
