//! Deterministic Monte-Carlo process-variation sampling (Appendix A.3's
//! static fabrication errors, promoted from fixed noise knobs to a seeded
//! per-chip-instance sampler).
//!
//! Each Monte-Carlo *sample* is one fabricated chip instance: per-device
//! draws of phase-shifter γ error (multiplicative), directional-coupler
//! splitting-ratio error (first-order equivalent to an additive phase
//! offset of twice the ratio deviation), and insertion loss. The phase-
//! domain effects are injected at realization time through the `Ptc`
//! `PhaseOverlay` seam — the same seam the lifecycle subsystem uses — so
//! a variation sample perturbs the realized unitaries exactly once,
//! survives re-programming, and composes with drift/fault overlays via
//! `PhaseOverlay::then`. Insertion loss is amplitude-domain and cannot be
//! expressed through a (unitary) phase overlay; lossy devices are instead
//! tracked as a worst-tile optical power penalty that feeds the yield
//! estimator's power constraint.
//!
//! Determinism contract: every draw comes from a fresh
//! `Rng::with_stream(seed ⊕ tag ⊕ mix(sample), 2·block | which)` keyed by
//! the *logical* block index in model traversal order — a pure function of
//! (config, seed), independent of thread count, SIMD level, and shard
//! count (sharded meshes are visited through the logical-order iterator).

use crate::nn::{Model, ProjEngine};
use crate::photonics::dispersion::{self, DispersionModel, DispersionReport, WdmSummary};
use crate::photonics::ptc::{PhaseOverlay, Ptc};
use crate::util::json::Json;
use crate::util::Rng;

/// Stream tag for variation draws (disjoint from the lifecycle tags).
const VARIATION_TAG: u64 = 0xfab5eed;

/// SplitMix64 increment, used to spread the sample index across the seed.
const SAMPLE_MIX: u64 = 0x9e37_79b9_7f4a_7c15;

/// One process-variation scenario: per-device perturbation scales plus the
/// Monte-Carlo sample index selecting a chip instance. All-zero scales with
/// `wdm_max_drift == 0` is "disabled" and must be bitwise-neutral.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VariationConfig {
    /// Std of the extra multiplicative phase-shifter γ error (1 + N(0,σ)).
    pub gamma_std: f64,
    /// Std of the coupler splitting-ratio error; maps to an additive phase
    /// offset of 2× the draw (first-order MZI equivalence).
    pub coupler_std: f64,
    /// Std of per-device insertion loss in dB (draws are folded to |·|;
    /// loss is amplitude-domain, tracked as a power penalty, not a phase).
    pub loss_db_std: f64,
    /// WDM wavelength-sweep span for post-training dispersion analysis
    /// (`DispersionModel::max_drift`); 0 disables the sweep.
    pub wdm_max_drift: f64,
    /// Monte-Carlo chip-instance index (each sample is a different chip).
    pub sample: u64,
}

impl VariationConfig {
    /// Whether the config does anything at all (overlay or WDM sweep).
    pub fn active(&self) -> bool {
        self.has_variation() || self.wdm_max_drift > 0.0
    }

    /// Whether any per-device draw has nonzero scale (i.e. an overlay is
    /// actually installed).
    pub fn has_variation(&self) -> bool {
        self.gamma_std > 0.0 || self.coupler_std > 0.0 || self.loss_db_std > 0.0
    }

    /// Whether this is a pure WDM-sweep row (no device perturbation).
    pub fn is_wdm_only(&self) -> bool {
        self.wdm_max_drift > 0.0 && !self.has_variation()
    }

    /// Parse a CLI spec: comma-separated `key=value` with keys
    /// `sigma` (shorthand: sets gamma+coupler+loss), `gamma`, `coupler`,
    /// `loss`, `wdm`, `sample`. Unknown or malformed tokens are a hard
    /// error carrying the accepted grammar — never silently dropped.
    pub fn parse_spec(spec: &str) -> Result<VariationConfig, String> {
        const GRAMMAR: &str = "expected comma-separated key=value with keys \
             sigma=<f64> (shorthand for gamma+coupler+loss), gamma=<f64>, \
             coupler=<f64>, loss=<f64 dB>, wdm=<f64>, sample=<u64> \
             (e.g. --variation sigma=0.01,sample=3)";
        let mut cfg = VariationConfig::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(format!("empty token in variation spec {spec:?}: {GRAMMAR}"));
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("bad variation token {part:?} (no '='): {GRAMMAR}"))?;
            let bad = |what: &str| format!("bad {what} value {val:?} in {part:?}: {GRAMMAR}");
            let num = |what: &str| -> Result<f64, String> {
                let v: f64 = val.trim().parse().map_err(|_| bad(what))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(bad(what));
                }
                Ok(v)
            };
            match key.trim() {
                "sigma" => {
                    let s = num("sigma")?;
                    cfg.gamma_std = s;
                    cfg.coupler_std = s;
                    cfg.loss_db_std = s;
                }
                "gamma" => cfg.gamma_std = num("gamma")?,
                "coupler" => cfg.coupler_std = num("coupler")?,
                "loss" => cfg.loss_db_std = num("loss")?,
                "wdm" => cfg.wdm_max_drift = num("wdm")?,
                "sample" => cfg.sample = val.trim().parse().map_err(|_| bad("sample"))?,
                other => {
                    return Err(format!(
                        "unknown variation key {other:?} in {part:?}: {GRAMMAR}"
                    ));
                }
            }
        }
        if !cfg.active() {
            return Err(format!(
                "variation spec {spec:?} enables nothing (all scales zero): {GRAMMAR}"
            ));
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("gamma_std", Json::Num(self.gamma_std));
        o.set("coupler_std", Json::Num(self.coupler_std));
        o.set("loss_db_std", Json::Num(self.loss_db_std));
        o.set("wdm_max_drift", Json::Num(self.wdm_max_drift));
        o.set("sample", Json::Num(self.sample as f64));
        o
    }

    /// Parse from a config-dump object; `None` when absent or malformed.
    pub fn from_json(j: &Json) -> Option<VariationConfig> {
        j.as_obj()?;
        let num = |k: &str, d: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
        Some(VariationConfig {
            gamma_std: num("gamma_std", 0.0),
            coupler_std: num("coupler_std", 0.0),
            loss_db_std: num("loss_db_std", 0.0),
            wdm_max_drift: num("wdm_max_drift", 0.0),
            sample: num("sample", 0.0) as u64,
        })
    }
}

/// What `apply_variation` did to the model: block count and the worst-tile
/// optical power penalty (the yield estimator's power-constraint input).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VariationOutcome {
    /// Worst per-block insertion-loss penalty along a k-mode Reck path, dB.
    pub power_penalty_db: f64,
    /// Photonic blocks perturbed.
    pub blocks: usize,
}

/// Draw one mesh's overlay: per-device (γ gain, coupler phase, loss) in
/// fixed device order from a stream keyed by (sample, logical block, U/V).
/// Returns the overlay plus the mean per-device insertion loss in dB.
fn sample_mesh(cfg: &VariationConfig, seed: u64, stream: u64, m: usize) -> (PhaseOverlay, f64) {
    let mixed = seed ^ VARIATION_TAG ^ cfg.sample.wrapping_mul(SAMPLE_MIX);
    let mut rng = Rng::with_stream(mixed, stream);
    let mut ov = PhaseOverlay::identity(m);
    let mut loss_sum = 0.0f64;
    for i in 0..m {
        ov.gain[i] = 1.0 + cfg.gamma_std * rng.normal();
        ov.delta[i] = 2.0 * cfg.coupler_std * rng.normal();
        loss_sum += (cfg.loss_db_std * rng.normal()).abs();
    }
    (ov, if m > 0 { loss_sum / m as f64 } else { 0.0 })
}

/// Install one block's variation overlays (composing over any overlay that
/// is already present, variation-first) and return its path power penalty.
fn install_block(cfg: &VariationConfig, seed: u64, block: u64, ptc: &mut Ptc) -> f64 {
    let m = ptc.n_phases() / 2;
    let (var_u, u_db) = sample_mesh(cfg, seed, 2 * block, m);
    let (var_v, v_db) = sample_mesh(cfg, seed, 2 * block + 1, m);
    let (cur_u, cur_v) = {
        let (u, v) = ptc.overlays();
        (u.cloned(), v.cloned())
    };
    let u = match cur_u {
        Some(later) => var_u.then(&later),
        None => var_u,
    };
    let v = match cur_v {
        Some(later) => var_v.then(&later),
        None => var_v,
    };
    ptc.set_overlays(Some(u), Some(v));
    // Longest Reck path traverses 2k−3 MZIs per mesh; light crosses both
    // the U and the V* mesh of the tile.
    let depth = (2 * ptc.k).saturating_sub(3).max(1) as f64;
    (u_db + v_db) * depth
}

/// Sample chip instance `cfg.sample` and install its overlays on every
/// photonic block of the model, in logical block order (bitwise-identical
/// at any shard count). Serial scalar f64 throughout — thread- and
/// SIMD-level-neutral by construction.
pub fn apply_variation(model: &mut Model, cfg: &VariationConfig, seed: u64) -> VariationOutcome {
    if !cfg.has_variation() {
        return VariationOutcome::default();
    }
    let mut block = 0u64;
    let mut worst = 0.0f64;
    model.for_each_layer(|l| match l.engine_mut() {
        Some(ProjEngine::Photonic { mesh, .. }) => {
            for ptc in mesh.ptcs.iter_mut() {
                worst = worst.max(install_block(cfg, seed, block, ptc));
                block += 1;
            }
            mesh.invalidate();
        }
        Some(ProjEngine::PhotonicSharded { mesh, .. }) => {
            mesh.for_each_ptc_logical_mut(|ptc| {
                worst = worst.max(install_block(cfg, seed, block, ptc));
                block += 1;
            });
        }
        _ => {}
    });
    VariationOutcome { power_penalty_db: worst, blocks: block as usize }
}

/// Post-training WDM sweep: run the dispersion analysis over every photonic
/// block in logical order and fold the per-block reports into one
/// [`WdmSummary`]. Reads programmed phases only — the model's realized
/// state is untouched (sharded caches may recompute, bitwise-identically).
pub fn analyze_wdm(model: &mut Model, max_drift: f64) -> WdmSummary {
    let dm = DispersionModel { max_drift };
    let mut reports: Vec<DispersionReport> = Vec::new();
    model.for_each_layer(|l| match l.engine_mut() {
        Some(ProjEngine::Photonic { mesh, .. }) => {
            for ptc in mesh.ptcs.iter() {
                reports.push(dispersion::analyze(ptc, dm));
            }
        }
        Some(ProjEngine::PhotonicSharded { mesh, .. }) => {
            mesh.for_each_ptc_logical_mut(|ptc| reports.push(dispersion::analyze(ptc, dm)));
        }
        _ => {}
    });
    WdmSummary::from_reports(max_drift, &reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{build_model, Act, EngineKind, ModelArch};
    use crate::photonics::NoiseModel;
    use crate::util::prop::assert_close;

    fn model(kind: EngineKind) -> Model {
        let mut rng = Rng::new(77);
        build_model(ModelArch::MlpVowel, kind, 4, 0.5, &mut rng)
    }

    fn sigma(s: f64) -> VariationConfig {
        VariationConfig { gamma_std: s, coupler_std: s, loss_db_std: s, ..Default::default() }
    }

    #[test]
    fn parse_spec_accepts_grammar_and_rejects_junk() {
        let v = VariationConfig::parse_spec("sigma=0.01,sample=3").unwrap();
        assert_eq!(v.gamma_std, 0.01);
        assert_eq!(v.coupler_std, 0.01);
        assert_eq!(v.loss_db_std, 0.01);
        assert_eq!(v.sample, 3);
        let v = VariationConfig::parse_spec("gamma=0.02,wdm=0.005").unwrap();
        assert_eq!(v.gamma_std, 0.02);
        assert_eq!(v.coupler_std, 0.0);
        assert_eq!(v.wdm_max_drift, 0.005);
        for bad in [
            "sigma",           // no '='
            "sigma=zebra",     // not a number
            "sigma=-0.1",      // negative scale
            "chaos=0.1",       // unknown key
            "sigma=0.1,,",     // empty token
            "sample=2",        // enables nothing
            "",                // empty spec
        ] {
            let err = VariationConfig::parse_spec(bad).unwrap_err();
            assert!(err.contains("sigma=<f64>"), "{bad:?} error lacks grammar: {err}");
        }
    }

    #[test]
    fn json_roundtrip_and_absent_is_none() {
        let v = VariationConfig {
            gamma_std: 0.01,
            coupler_std: 0.002,
            loss_db_std: 0.1,
            wdm_max_drift: 0.02,
            sample: 9,
        };
        let back = VariationConfig::from_json(&v.to_json()).unwrap();
        assert_eq!(v, back);
        assert!(VariationConfig::from_json(&Json::Num(1.0)).is_none());
    }

    #[test]
    fn sampler_is_deterministic_and_sample_indexed() {
        let (a, la) = sample_mesh(&sigma(0.01), 42, 3, 16);
        let (b, lb) = sample_mesh(&sigma(0.01), 42, 3, 16);
        assert_eq!(a, b, "same (seed, sample, stream) must redraw identically");
        assert_eq!(la, lb);
        let (c, _) = sample_mesh(&sigma(0.01), 42, 4, 16);
        assert_ne!(a, c, "different stream must differ");
        let mut other = sigma(0.01);
        other.sample = 1;
        let (d, _) = sample_mesh(&other, 42, 3, 16);
        assert_ne!(a, d, "different sample index must be a different chip");
    }

    #[test]
    fn variation_perturbs_forward_and_is_shard_invariant() {
        let x = crate::linalg::Mat::randn(8, 3, 1.0, &mut Rng::new(1));
        let act = Act::from_features(x, 3);
        let kinds = [
            EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) },
            EngineKind::PhotonicSharded {
                k: 4,
                noise: NoiseModel::quant_only(8),
                shards: 2,
                policy: crate::photonics::ShardPolicy::Row,
            },
        ];
        let mut outs = Vec::new();
        for kind in kinds {
            let mut m = model(kind);
            let clean = m.forward(&act, false);
            let out = apply_variation(&mut m, &sigma(0.02), 42);
            assert!(out.blocks > 0);
            assert!(out.power_penalty_db > 0.0);
            let varied = m.forward(&act, false);
            let diff: f32 = clean
                .mat
                .data
                .iter()
                .zip(&varied.mat.data)
                .map(|(a, b)| (a - b).abs())
                .sum();
            assert!(diff > 1e-4, "variation had no effect on the forward pass");
            outs.push((out, varied.mat.data.clone()));
        }
        // Same chip instance at shard counts 1 and 2: bitwise-equal forward.
        assert_eq!(outs[0].0, outs[1].0, "power penalty must be shard-count-invariant");
        assert_close(&outs[0].1, &outs[1].1, 0.0, 0.0).unwrap();
    }

    #[test]
    fn wdm_sweep_is_shard_invariant_and_read_only() {
        let x = crate::linalg::Mat::randn(8, 3, 1.0, &mut Rng::new(1));
        let act = Act::from_features(x, 3);
        let kinds = [
            EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) },
            EngineKind::PhotonicSharded {
                k: 4,
                noise: NoiseModel::quant_only(8),
                shards: 2,
                policy: crate::photonics::ShardPolicy::Row,
            },
        ];
        let mut summaries = Vec::new();
        for kind in kinds {
            let mut m = model(kind);
            let before = m.forward(&act, false);
            let s = analyze_wdm(&mut m, 0.02);
            assert!(s.blocks > 0);
            assert!(s.worst_rel_err > 0.0, "a programmed mesh must show some dispersion");
            assert!(s.mean_rel_err <= s.worst_rel_err);
            let after = m.forward(&act, false);
            assert_close(&before.mat.data, &after.mat.data, 0.0, 0.0).unwrap();
            summaries.push(s);
        }
        assert_eq!(summaries[0], summaries[1], "WDM summary must be shard-count-invariant");
    }

    #[test]
    fn disabled_variation_is_bitwise_neutral() {
        let x = crate::linalg::Mat::randn(8, 3, 1.0, &mut Rng::new(1));
        let act = Act::from_features(x, 3);
        let mut m = model(EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) });
        let before = m.forward(&act, false);
        let out = apply_variation(&mut m, &VariationConfig::default(), 42);
        assert_eq!(out, VariationOutcome::default());
        let after = m.forward(&act, false);
        assert_close(&before.mat.data, &after.mat.data, 0.0, 0.0).unwrap();
    }
}
