//! Deterministic lifecycle fault injection: thermal phase drift and scheduled
//! device failures, both pure functions of `(seed, block, step)`.
//!
//! The determinism contract matters more than the physics here: every draw is
//! taken from a **fresh** RNG stream keyed by `(seed, block, step)` in a fixed
//! per-device order, so the injected state at step *t* is identical whether
//! the process was advanced in one call or across a run/resume boundary, and
//! is untouched by thread count or SIMD level (all scalar f64 math, no shared
//! RNG state). `Rng::normal()` caches a Box–Muller spare, which is exactly why
//! a fresh RNG per `(block, step)` is required for purity.

use crate::photonics::ptc::PhaseOverlay;
use crate::util::Rng;

/// Stream tags for the injection RNG families (xor'ed into the job seed).
const DRIFT_TAG: u64 = 0xd21f7;
const AMBIENT_TAG: u64 = 0xa3b1e;
const FAULT_TAG: u64 = 0xfa17;

/// Knobs of the per-device drift process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftConfig {
    /// Std of the per-step thermal phase random walk (rad).
    pub walk_std: f64,
    /// Amplitude of the sinusoidal ambient (e.g. HVAC) phase term (rad).
    pub ambient_amp: f64,
    /// Period of the ambient term, in training steps.
    pub ambient_period: f64,
    /// Std of the per-step multiplicative γ aging increment.
    pub aging_std: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { walk_std: 0.01, ambient_amp: 0.05, ambient_period: 16.0, aging_std: 0.001 }
    }
}

/// Seed-derived drift state for one mesh of one block (U or V).
///
/// `advance_to(t)` is idempotent and resume-safe: the cumulative walk at
/// step *t* is the same bitwise f64 no matter how the interval `[0, t]` was
/// split across calls, because each step's increments come from a fresh
/// `(seed, stream, step)` RNG and are accumulated in device order.
#[derive(Clone, Debug)]
pub struct DriftProcess {
    pub cfg: DriftConfig,
    seed: u64,
    /// Stream id: `2*block` for the U mesh, `2*block + 1` for V.
    stream: u64,
    /// Devices per mesh.
    m: usize,
    /// Last step the walk/gain state was advanced to.
    pub step: u64,
    /// Cumulative random-walk phase offset per device (rad).
    pub walk: Vec<f64>,
    /// Cumulative multiplicative γ aging per device.
    pub gain: Vec<f64>,
    /// Per-device phase offset of the ambient sinusoid (frozen at init).
    ambient_phase: Vec<f64>,
}

impl DriftProcess {
    pub fn new(cfg: DriftConfig, seed: u64, stream: u64, m: usize) -> DriftProcess {
        let mut init = Rng::with_stream(seed ^ AMBIENT_TAG, stream);
        let ambient_phase =
            (0..m).map(|_| init.uniform_range(0.0, std::f64::consts::TAU)).collect();
        DriftProcess {
            cfg,
            seed,
            stream,
            m,
            step: 0,
            walk: vec![0.0; m],
            gain: vec![1.0; m],
            ambient_phase,
        }
    }

    /// Advance the walk/gain state to step `t` (no-op if already there).
    pub fn advance_to(&mut self, t: u64) {
        while self.step < t {
            self.step += 1;
            // Fresh RNG per (block-mesh, step): draws are a pure function of
            // (seed, stream, step) — the resume-safety linchpin.
            let mut rng =
                Rng::with_stream(self.seed ^ DRIFT_TAG, (self.stream << 32) ^ self.step);
            for i in 0..self.m {
                self.walk[i] += self.cfg.walk_std * rng.normal();
                self.gain[i] *= 1.0 + self.cfg.aging_std * rng.normal();
            }
        }
    }

    /// Build the overlay for the current step: cumulative walk plus the
    /// analytic ambient sinusoid (no RNG — exact at any t).
    pub fn overlay(&self) -> PhaseOverlay {
        let t = self.step as f64;
        let omega = std::f64::consts::TAU / self.cfg.ambient_period;
        let delta = (0..self.m)
            .map(|i| self.walk[i] + self.cfg.ambient_amp * (omega * t + self.ambient_phase[i]).sin())
            .collect();
        PhaseOverlay { delta, gain: self.gain.clone(), stuck: Vec::new() }
    }
}

/// What breaks when a scheduled fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Phase shifter frozen at a random phase (heater driver latch-up).
    StuckPhase,
    /// MZI dead: phase stuck at 0 — the device passes light unmodulated.
    DeadMzi,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::StuckPhase => "stuck",
            FaultKind::DeadMzi => "dead",
        }
    }

    pub fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "stuck" => Some(FaultKind::StuckPhase),
            "dead" => Some(FaultKind::DeadMzi),
            _ => None,
        }
    }
}

/// A scheduled fault: *what* fails and *when*; *where* is seed-derived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// Training step at which the fault fires.
    pub step: u64,
    pub kind: FaultKind,
}

impl FaultSpec {
    /// Parse a CLI fault list: comma-separated `kind@step` with kinds
    /// `stuck` | `dead` (e.g. `stuck@8,dead@12`). Every malformed token is
    /// a hard error carrying the accepted grammar — unknown kinds, missing
    /// or non-numeric steps, and empty tokens are never silently dropped.
    pub fn parse_list(spec: &str) -> Result<Vec<FaultSpec>, String> {
        const GRAMMAR: &str =
            "expected comma-separated kind@step with kind one of stuck|dead \
             and step a non-negative integer (e.g. --faults stuck@8,dead@12)";
        let mut out = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(format!("empty token in fault spec {spec:?}: {GRAMMAR}"));
            }
            let (kind, step) = part
                .split_once('@')
                .ok_or_else(|| format!("bad fault token {part:?} (no '@'): {GRAMMAR}"))?;
            let kind = FaultKind::parse(kind.trim())
                .ok_or_else(|| format!("unknown fault kind {kind:?} in {part:?}: {GRAMMAR}"))?;
            let step: u64 = step
                .trim()
                .parse()
                .map_err(|_| format!("bad fault step {step:?} in {part:?}: {GRAMMAR}"))?;
            out.push(FaultSpec { step, kind });
        }
        Ok(out)
    }
}

/// A resolved fault: concrete placement of a `FaultSpec`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub step: u64,
    /// Flat block index into the mesh's row-major [p][q] PTC array.
    pub block: usize,
    /// Struck mesh: false = U, true = V.
    pub which_v: bool,
    /// Device (phase) index within the mesh.
    pub device: usize,
    /// Frozen phase value.
    pub value: f64,
    /// Whether the device is dead (unrecoverable by definition).
    pub dead: bool,
}

/// The resolved fault schedule for one photonic mesh.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Place each spec onto a (block, mesh, device) drawn from a fresh
    /// per-spec RNG stream — deterministic in `(specs, seed, n_blocks, m)`.
    pub fn resolve(specs: &[FaultSpec], seed: u64, n_blocks: usize, m: usize) -> FaultPlan {
        let events = specs
            .iter()
            .enumerate()
            .map(|(idx, spec)| {
                let mut rng = Rng::with_stream(seed ^ FAULT_TAG, idx as u64);
                let block = rng.below(n_blocks);
                let which_v = rng.bernoulli(0.5);
                let device = rng.below(m);
                let (value, dead) = match spec.kind {
                    FaultKind::StuckPhase => {
                        (rng.uniform_range(-std::f64::consts::PI, std::f64::consts::PI), false)
                    }
                    FaultKind::DeadMzi => (0.0, true),
                };
                FaultEvent { step: spec.step, block, which_v, device, value, dead }
            })
            .collect();
        FaultPlan { events }
    }

    /// Faults active on `(block, mesh)` at or before step `t`, as overlay
    /// stuck entries, in schedule order.
    pub fn stuck_at(&self, block: usize, which_v: bool, t: u64) -> Vec<(usize, f64)> {
        self.events
            .iter()
            .filter(|e| e.block == block && e.which_v == which_v && e.step <= t)
            .map(|e| (e.device, e.value))
            .collect()
    }

    /// First scheduled fault step at or before `t`, if any fired yet.
    pub fn first_fired(&self, t: u64) -> Option<u64> {
        self.events.iter().map(|e| e.step).filter(|&s| s <= t).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quickcheck;

    #[test]
    fn fault_list_parses_grammar_and_rejects_junk_loudly() {
        let specs = FaultSpec::parse_list("stuck@8, dead@12").unwrap();
        assert_eq!(
            specs,
            vec![
                FaultSpec { step: 8, kind: FaultKind::StuckPhase },
                FaultSpec { step: 12, kind: FaultKind::DeadMzi },
            ]
        );
        for bad in ["stuck", "gremlin@3", "stuck@x", "stuck@-1", "stuck@3,,dead@4", ""] {
            let err = FaultSpec::parse_list(bad).unwrap_err();
            assert!(err.contains("stuck|dead"), "{bad:?} error lacks grammar: {err}");
        }
    }

    #[test]
    fn prop_drift_split_advance_is_bitwise_identical() {
        quickcheck(
            "drift: advance in pieces == advance in one go",
            |rng, size| {
                let t = 1 + size as u64;
                let split = 1 + (rng.below(t as usize)) as u64;
                let m = 1 + size % 12;
                (t, split, m, rng.next_u64())
            },
            |&(t, split, m, seed)| {
                let cfg = DriftConfig::default();
                let mut one = DriftProcess::new(cfg, seed, 7, m);
                one.advance_to(t);
                let mut two = DriftProcess::new(cfg, seed, 7, m);
                two.advance_to(split);
                two.advance_to(t); // resume boundary
                if one.walk != two.walk {
                    return Err(format!("walk diverged: {:?} vs {:?}", one.walk, two.walk));
                }
                if one.gain != two.gain {
                    return Err(format!("gain diverged: {:?} vs {:?}", one.gain, two.gain));
                }
                let (oa, ob) = (one.overlay(), two.overlay());
                if oa != ob {
                    return Err("overlay diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn drift_streams_are_independent_per_mesh() {
        let cfg = DriftConfig::default();
        let mut u = DriftProcess::new(cfg, 42, 0, 6);
        let mut v = DriftProcess::new(cfg, 42, 1, 6);
        u.advance_to(5);
        v.advance_to(5);
        assert_ne!(u.walk, v.walk, "U and V meshes must drift independently");
    }

    #[test]
    fn fault_plan_is_deterministic_and_in_range() {
        let specs = [
            FaultSpec { step: 8, kind: FaultKind::StuckPhase },
            FaultSpec { step: 8, kind: FaultKind::DeadMzi },
            FaultSpec { step: 20, kind: FaultKind::StuckPhase },
        ];
        let a = FaultPlan::resolve(&specs, 42, 4, 6);
        let b = FaultPlan::resolve(&specs, 42, 4, 6);
        assert_eq!(a, b);
        for e in &a.events {
            assert!(e.block < 4);
            assert!(e.device < 6);
            assert!(e.value.abs() <= std::f64::consts::PI);
        }
        assert!(a.events[1].dead && a.events[1].value == 0.0);
        assert_eq!(a.first_fired(7), None);
        assert_eq!(a.first_fired(8), Some(8));
        assert_eq!(a.first_fired(100), Some(8));
        // Different seed ⇒ (almost surely) different placement.
        let c = FaultPlan::resolve(&specs, 43, 4, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn stuck_at_respects_schedule_and_location() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent { step: 3, block: 1, which_v: false, device: 2, value: 0.5, dead: false },
                FaultEvent { step: 9, block: 1, which_v: true, device: 0, value: 0.0, dead: true },
            ],
        };
        assert!(plan.stuck_at(1, false, 2).is_empty());
        assert_eq!(plan.stuck_at(1, false, 3), vec![(2, 0.5)]);
        assert!(plan.stuck_at(1, true, 3).is_empty());
        assert_eq!(plan.stuck_at(1, true, 9), vec![(0, 0.0)]);
        assert!(plan.stuck_at(0, false, 100).is_empty());
    }

    #[test]
    fn fault_kind_name_parse_roundtrip() {
        for k in [FaultKind::StuckPhase, FaultKind::DeadMzi] {
            assert_eq!(FaultKind::parse(k.name()), Some(k));
        }
        assert_eq!(FaultKind::parse("nope"), None);
    }
}
