//! Lifecycle robustness: drift & fault injection with closed-loop in-situ
//! recalibration and graceful degradation.
//!
//! The paper's three-stage flow calibrates once and assumes the chip then
//! holds still. Real photonic hardware does not: phases drift thermally,
//! devices age, and phase shifters die mid-run. This subsystem makes that
//! lifecycle a first-class, *deterministic* part of a job:
//!
//! * [`inject`] — seed-derived [`DriftProcess`] (thermal random walk +
//!   sinusoidal ambient term + γ aging) and [`FaultPlan`] (stuck-at-phase and
//!   dead-MZI events at scheduled steps), applied through the
//!   `PhaseOverlay` realization hook on [`crate::photonics::Ptc`]. Same seed
//!   + same step ⇒ bitwise-identical injected state at every thread count
//!   and SIMD level.
//! * [`watchdog`] — [`LifecycleRuntime`]: detection from in-situ observables
//!   only (loss spikes + periodic Σ-independent intensity probes), scoped
//!   per-block ZO recovery with budget accounting, and masking of
//!   beyond-repair blocks via the engine's masked-forward path.
//!
//! Wire-up: set [`crate::coordinator::JobConfig::robustness`]; the SL stage
//! drives the runtime via `stages::sl::train_with_lifecycle`. With the
//! config absent every existing metric is bitwise-unchanged — the hooks are
//! `Option` checks and no RNG stream is touched.
//!
//! Static fabrication-time variation lives next door:
//!
//! * [`variation`] — seed-derived Monte-Carlo process-variation sampler
//!   (per-device γ, coupler splitting ratio, insertion loss) installed as a
//!   base `PhaseOverlay` that lifecycle drift/faults compose on top of.
//! * [`yield_est`] — N-sample yield estimation (pass-rate under
//!   accuracy/power constraints, per-metric mean/std/worst-case).

pub mod inject;
pub mod variation;
pub mod watchdog;
pub mod yield_est;

pub use inject::{DriftConfig, DriftProcess, FaultKind, FaultPlan, FaultSpec};
pub use variation::{analyze_wdm, apply_variation, VariationConfig, VariationOutcome};
pub use watchdog::{LifecycleReport, LifecycleRuntime, WatchdogConfig};
pub use yield_est::{estimate_yield, YieldConstraints, YieldReport, YieldStat};

use crate::util::json::Json;

/// Optional per-job lifecycle configuration: what to inject and whether the
/// watchdog supervises the run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RobustnessConfig {
    /// Continuous drift injection; `None` = phases hold still.
    pub drift: Option<DriftConfig>,
    /// Scheduled fault events (placement is seed-derived).
    pub faults: Vec<FaultSpec>,
    /// Detection/recovery supervision; `None` = nothing watches the chip.
    pub watchdog: Option<WatchdogConfig>,
}

impl RobustnessConfig {
    /// Whether the config does anything at all.
    pub fn active(&self) -> bool {
        self.drift.is_some() || !self.faults.is_empty() || self.watchdog.is_some()
    }

    /// The scenario-matrix lifecycle row family: faults always fire and the
    /// watchdog always observes (so detection metrics exist on every row);
    /// the axes are drift on/off and recovery budget on/off.
    pub fn lifecycle_row(drift: bool, recovery: bool) -> RobustnessConfig {
        RobustnessConfig {
            drift: drift.then(DriftConfig::default),
            faults: vec![
                FaultSpec { step: 8, kind: FaultKind::StuckPhase },
                FaultSpec { step: 8, kind: FaultKind::DeadMzi },
            ],
            watchdog: Some(WatchdogConfig {
                probe_every: 2,
                probe_tol: 1e-3,
                max_recoveries: if recovery { 4 } else { 0 },
                ..WatchdogConfig::default()
            }),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        if let Some(d) = &self.drift {
            let mut dj = Json::obj();
            dj.set("walk_std", Json::Num(d.walk_std))
                .set("ambient_amp", Json::Num(d.ambient_amp))
                .set("ambient_period", Json::Num(d.ambient_period))
                .set("aging_std", Json::Num(d.aging_std));
            o.set("drift", dj);
        }
        let faults: Vec<Json> = self
            .faults
            .iter()
            .map(|f| {
                let mut fj = Json::obj();
                fj.set("step", Json::Num(f.step as f64))
                    .set("kind", Json::Str(f.kind.name().to_string()));
                fj
            })
            .collect();
        o.set("faults", Json::Arr(faults));
        if let Some(w) = &self.watchdog {
            let mut wj = Json::obj();
            wj.set("probe_every", Json::Num(w.probe_every as f64))
                .set("spike_factor", Json::Num(w.spike_factor))
                .set("loss_window", Json::Num(w.loss_window as f64))
                .set("probe_tol", Json::Num(w.probe_tol))
                .set("dead_tol", Json::Num(w.dead_tol))
                .set("recovery_iters", Json::Num(w.recovery_iters as f64))
                .set("max_recoveries", Json::Num(w.max_recoveries as f64));
            o.set("watchdog", wj);
        }
        o
    }

    /// Parse back; `None` on a malformed object (missing fields fall back to
    /// the documented defaults, like `JobConfig::from_json`).
    pub fn from_json(j: &Json) -> Option<RobustnessConfig> {
        j.as_obj()?;
        let drift = j.get("drift").and_then(|dj| {
            dj.as_obj()?;
            let d = DriftConfig::default();
            let num = |k: &str, dflt: f64| dj.get(k).and_then(Json::as_f64).unwrap_or(dflt);
            Some(DriftConfig {
                walk_std: num("walk_std", d.walk_std),
                ambient_amp: num("ambient_amp", d.ambient_amp),
                ambient_period: num("ambient_period", d.ambient_period),
                aging_std: num("aging_std", d.aging_std),
            })
        });
        let faults = match j.get("faults").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|fj| {
                    let step = fj.get("step")?.as_f64()? as u64;
                    let kind = FaultKind::parse(fj.get("kind")?.as_str()?)?;
                    Some(FaultSpec { step, kind })
                })
                .collect::<Option<Vec<FaultSpec>>>()?,
            None => Vec::new(),
        };
        let watchdog = j.get("watchdog").and_then(|wj| {
            wj.as_obj()?;
            let w = WatchdogConfig::default();
            let num = |k: &str, dflt: f64| wj.get(k).and_then(Json::as_f64).unwrap_or(dflt);
            Some(WatchdogConfig {
                probe_every: num("probe_every", w.probe_every as f64) as u64,
                spike_factor: num("spike_factor", w.spike_factor),
                loss_window: num("loss_window", w.loss_window as f64) as usize,
                probe_tol: num("probe_tol", w.probe_tol),
                dead_tol: num("dead_tol", w.dead_tol),
                recovery_iters: num("recovery_iters", w.recovery_iters as f64) as usize,
                max_recoveries: num("max_recoveries", w.max_recoveries as f64) as usize,
            })
        });
        Some(RobustnessConfig { drift, faults, watchdog })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_everything() {
        for (drift, recovery) in [(false, false), (false, true), (true, false), (true, true)] {
            let rc = RobustnessConfig::lifecycle_row(drift, recovery);
            let j = rc.to_json();
            let back = RobustnessConfig::from_json(&j).expect("parses back");
            assert_eq!(rc, back);
            // Canonical dump is stable (the golden gate compares configs
            // by exact dump equality).
            assert_eq!(j.dump(), back.to_json().dump());
        }
    }

    #[test]
    fn empty_config_is_inactive_and_roundtrips() {
        let rc = RobustnessConfig::default();
        assert!(!rc.active());
        let back = RobustnessConfig::from_json(&rc.to_json()).unwrap();
        assert_eq!(rc, back);
        assert!(RobustnessConfig::lifecycle_row(true, true).active());
    }

    #[test]
    fn malformed_json_is_rejected() {
        assert_eq!(RobustnessConfig::from_json(&Json::Num(3.0)), None);
        let mut bad = Json::obj();
        let mut f = Json::obj();
        f.set("step", Json::Num(3.0)).set("kind", Json::Str("gremlin".into()));
        bad.set("faults", Json::Arr(vec![f]));
        assert_eq!(RobustnessConfig::from_json(&bad), None);
    }
}
