//! Closed-loop lifecycle supervision: detect degradation from in-situ
//! observables only, recover flagged blocks by re-calibrating against
//! deployment-time intensity references, and mask blocks that stay broken.
//!
//! The watchdog never peeks at oracle weights. Its two signals are
//!
//! * the training loss stream (a spike vs the trailing window), and
//! * periodic cheap *intensity probes*: shine the k basis vectors through
//!   each mesh and compare |U| / |V| magnitudes against references captured
//!   at deployment (post-IC/PM). Magnitudes are Σ-independent, so ordinary
//!   subspace learning — which only moves Σ — never trips the probe.
//!
//! Recovery re-runs ZO calibration per flagged block with the *deviation
//! from the reference magnitudes* as the loss: the same restricted hardware
//! measurement IC uses, so the loop stays physically in-situ. Blocks whose
//! post-recovery probe still exceeds `dead_tol` are remapped around via the
//! engine's masked-forward path instead of crashing the run.
//!
//! All probe and recovery hardware queries are charged to the mesh's op
//! counters, so they fold into the existing `CostBreakdown` epoch deltas.

use super::inject::{DriftProcess, FaultPlan};
use super::RobustnessConfig;
use crate::nn::{Model, ProjEngine};
use crate::photonics::ptc::{PhaseOverlay, Ptc, Which};
use crate::photonics::PtcMesh;
use crate::util::Rng;
use crate::zoo::{ZoConfig, ZoKind, ZoProblem};

/// Stream tag for the recovery ZO optimizer RNG.
const RECOVERY_TAG: u64 = 0x7ec0;

/// Watchdog thresholds and recovery budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WatchdogConfig {
    /// Probe the mesh every this many training steps (0 = loss spikes only).
    pub probe_every: u64,
    /// Loss spike trigger: loss > factor × mean(trailing window).
    pub spike_factor: f64,
    /// Trailing-loss window length (steps) for the spike baseline.
    pub loss_window: usize,
    /// Per-block |U|/|V| probe-MSE threshold that flags a block for recovery.
    pub probe_tol: f64,
    /// Post-recovery probe MSE above which a block is declared dead.
    pub dead_tol: f64,
    /// ZO iterations per flagged block per recovery round.
    pub recovery_iters: usize,
    /// Maximum recovery rounds per run (0 = detect only, never recover).
    pub max_recoveries: usize,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            probe_every: 4,
            spike_factor: 2.5,
            loss_window: 8,
            probe_tol: 0.01,
            dead_tol: 0.25,
            recovery_iters: 40,
            max_recoveries: 4,
        }
    }
}

/// End-of-run lifecycle outcome, folded into `JobSummary` and the scenario
/// report. Everything except `recovery_secs` is a deterministic counter.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LifecycleReport {
    /// Whether drift injection was enabled.
    pub drift: bool,
    /// Number of scheduled fault events.
    pub faults: u64,
    /// Step at which the watchdog first fired, if it did.
    pub trigger_step: Option<u64>,
    /// Steps from the first fired fault to the first trigger.
    pub detect_latency_steps: Option<u64>,
    /// Recovery rounds executed.
    pub recoveries: u64,
    /// Successful block recoveries (a marginal block re-flagged in a later
    /// round counts each time it is brought back under `dead_tol`).
    pub recovered_blocks: u64,
    /// Blocks masked out as beyond repair.
    pub dead_blocks: u64,
    /// Extra ZO hardware queries spent on recovery.
    pub recovery_queries: u64,
    /// Hardware queries spent on watchdog probes.
    pub probe_queries: u64,
    /// Wall time spent in recovery (nondeterministic; reported via
    /// stage timings, never golden-gated metrics).
    pub recovery_secs: f64,
}

/// Per-block lifecycle state: deployment references + drift processes.
#[derive(Clone, Debug)]
struct BlockState {
    /// Index of the owning photonic mesh in model traversal order.
    mesh_idx: usize,
    /// Flat [p][q] block index within that mesh.
    local: usize,
    /// Programmable phases per constituent mesh (k(k−1)/2).
    m: usize,
    k: usize,
    /// |realized U| captured at deployment (post-IC/PM), the probe reference.
    ref_u_abs: Vec<f32>,
    ref_v_abs: Vec<f32>,
    /// Overlays already installed at deployment (e.g. a process-variation
    /// chip instance). Lifecycle overlays compose on top of these instead
    /// of overwriting them; `None` keeps the historical install path
    /// bitwise-unchanged.
    base_u: Option<PhaseOverlay>,
    base_v: Option<PhaseOverlay>,
    drift_u: Option<DriftProcess>,
    drift_v: Option<DriftProcess>,
    dead: bool,
}

/// Recovery objective: magnitude deviation from the deployment references,
/// measured through the (possibly faulted) hardware — overlays included.
struct RefCalProblem<'a> {
    ptc: &'a mut Ptc,
    ref_u: &'a [f32],
    ref_v: &'a [f32],
    m: usize,
}

impl ZoProblem for RefCalProblem<'_> {
    fn dim(&self) -> usize {
        2 * self.m
    }

    fn eval(&mut self, phases: &[f64]) -> f64 {
        self.ptc.set_phases(Which::U, &phases[..self.m]);
        self.ptc.set_phases(Which::V, &phases[self.m..]);
        probe_mse(self.ptc, self.ref_u, self.ref_v)
    }
}

/// Intensity-probe MSE: mean squared |·| deviation over both unitaries.
fn probe_mse(ptc: &mut Ptc, ref_u: &[f32], ref_v: &[f32]) -> f64 {
    let (u, v) = ptc.realized_uv();
    let du: f64 = u
        .data
        .iter()
        .zip(ref_u)
        .map(|(&a, &r)| {
            let d = (a.abs() - r) as f64;
            d * d
        })
        .sum();
    let dv: f64 = v
        .data
        .iter()
        .zip(ref_v)
        .map(|(&a, &r)| {
            let d = (a.abs() - r) as f64;
            d * d
        })
        .sum();
    (du + dv) / (ref_u.len() + ref_v.len()) as f64
}

/// Visit every photonic mesh of the model in stable traversal order.
///
/// A sharded engine is visited once per shard — each shard is its own
/// physical chiplet with its own probe references, recalibration scope, and
/// op counters. The callback sees the shard's *local* forward-mask view;
/// changes are folded back into the engine's logical [p][q] mask so masked
/// inference stays shard-count-agnostic.
fn for_each_photonic<F>(model: &mut Model, mut f: F)
where
    F: FnMut(usize, &mut PtcMesh, &mut Option<(Vec<bool>, f32)>),
{
    let mut idx = 0usize;
    model.for_each_layer(|l| match l.engine_mut() {
        Some(ProjEngine::Photonic { mesh, fwd_mask, .. }) => {
            f(idx, mesh, fwd_mask);
            idx += 1;
        }
        Some(ProjEngine::PhotonicSharded { mesh, fwd_mask, .. }) => {
            for si in 0..mesh.num_shards() {
                let mut local: Option<(Vec<bool>, f32)> =
                    fwd_mask.as_ref().map(|(m, s)| (mesh.local_mask_pq(si, m), *s));
                f(idx, &mut mesh.shards[si].mesh, &mut local);
                if let Some((lm, s)) = local {
                    let nb = mesh.p * mesh.q;
                    let (keep, scale) = fwd_mask.get_or_insert((vec![true; nb], 1.0));
                    *scale = s;
                    mesh.store_local_mask_pq(si, &lm, keep);
                }
                idx += 1;
            }
        }
        _ => {}
    });
}

/// The closed-loop lifecycle supervisor driving injection, detection, and
/// recovery across a training run. Owned by the SL loop via
/// `stages::sl::train_with_lifecycle`; all of its work is serial scalar
/// math, so it cannot perturb thread/SIMD determinism.
pub struct LifecycleRuntime {
    seed: u64,
    drift_on: bool,
    watchdog: Option<WatchdogConfig>,
    plan: FaultPlan,
    blocks: Vec<BlockState>,
    /// Executed training steps (skipped data-sampler iterations excluded).
    step: u64,
    /// Trailing losses for spike detection.
    losses: Vec<f64>,
    trigger_step: Option<u64>,
    detect_latency: Option<u64>,
    recoveries: u64,
    recovered_blocks: u64,
    dead_blocks: u64,
    recovery_queries: u64,
    probe_queries: u64,
    recovery_secs: f64,
}

impl LifecycleRuntime {
    /// Capture deployment references and resolve the fault schedule.
    /// Call after IC/PM (or initial programming) so references describe the
    /// healthy deployed state.
    pub fn new(cfg: &RobustnessConfig, model: &mut Model, seed: u64) -> LifecycleRuntime {
        let mut blocks = Vec::new();
        for_each_photonic(model, |mi, mesh, _| {
            for local in 0..mesh.ptcs.len() {
                let gi = blocks.len();
                let ptc = &mut mesh.ptcs[local];
                let m = ptc.n_phases() / 2;
                let k = ptc.k;
                let (u, v) = ptc.realized_uv();
                let ref_u_abs = u.data.iter().map(|a| a.abs()).collect();
                let ref_v_abs = v.data.iter().map(|a| a.abs()).collect();
                let (base_u, base_v) = {
                    let (bu, bv) = ptc.overlays();
                    (bu.cloned(), bv.cloned())
                };
                let (drift_u, drift_v) = match cfg.drift {
                    Some(dc) => (
                        Some(DriftProcess::new(dc, seed, 2 * gi as u64, m)),
                        Some(DriftProcess::new(dc, seed, 2 * gi as u64 + 1, m)),
                    ),
                    None => (None, None),
                };
                blocks.push(BlockState {
                    mesh_idx: mi,
                    local,
                    m,
                    k,
                    ref_u_abs,
                    ref_v_abs,
                    base_u,
                    base_v,
                    drift_u,
                    drift_v,
                    dead: false,
                });
            }
        });
        let m = blocks.first().map(|b| b.m).unwrap_or(1);
        let plan = FaultPlan::resolve(&cfg.faults, seed, blocks.len().max(1), m);
        LifecycleRuntime {
            seed,
            drift_on: cfg.drift.is_some(),
            watchdog: cfg.watchdog,
            plan,
            blocks,
            step: 0,
            losses: Vec::new(),
            trigger_step: None,
            detect_latency: None,
            recoveries: 0,
            recovered_blocks: 0,
            dead_blocks: 0,
            recovery_queries: 0,
            probe_queries: 0,
            recovery_secs: 0.0,
        }
    }

    /// Advance lifecycle time by one executed training step and install the
    /// step-t overlays. With drift off, overlays only change at fault steps
    /// (installed once; they persist on the PTC), so quiet steps are a no-op
    /// and the caches stay warm.
    pub fn begin_step(&mut self, model: &mut Model) {
        self.step += 1;
        let t = self.step;
        let new_faults = self.plan.events.iter().any(|e| e.step == t);
        if !self.drift_on && !new_faults {
            return;
        }
        let blocks = &mut self.blocks;
        let plan = &self.plan;
        for_each_photonic(model, |mi, mesh, _| {
            let mut touched = false;
            for (gi, blk) in blocks.iter_mut().enumerate() {
                if blk.mesh_idx != mi {
                    continue;
                }
                let mut u_ov = match &mut blk.drift_u {
                    Some(d) => {
                        d.advance_to(t);
                        d.overlay()
                    }
                    None => PhaseOverlay::identity(blk.m),
                };
                let mut v_ov = match &mut blk.drift_v {
                    Some(d) => {
                        d.advance_to(t);
                        d.overlay()
                    }
                    None => PhaseOverlay::identity(blk.m),
                };
                u_ov.stuck = plan.stuck_at(gi, false, t);
                v_ov.stuck = plan.stuck_at(gi, true, t);
                // Lifecycle acts on top of whatever was installed at
                // deployment (process variation); without a base this is
                // the historical direct install, bitwise-unchanged.
                let u_inst = match &blk.base_u {
                    Some(b) => b.then(&u_ov),
                    None => u_ov,
                };
                let v_inst = match &blk.base_v {
                    Some(b) => b.then(&v_ov),
                    None => v_ov,
                };
                mesh.ptcs[blk.local].set_overlays(Some(u_inst), Some(v_inst));
                touched = true;
            }
            if touched {
                mesh.invalidate();
            }
        });
    }

    /// Feed the post-step training loss; run detection and (budget allowing)
    /// recovery when a probe is due or the loss spikes.
    pub fn observe(&mut self, model: &mut Model, loss: f64) {
        let Some(wd) = self.watchdog else { return };
        let spike = self.losses.len() >= wd.loss_window && {
            let mean: f64 = self.losses.iter().sum::<f64>() / self.losses.len() as f64;
            mean.is_finite() && loss > wd.spike_factor * mean
        };
        self.losses.push(loss);
        if self.losses.len() > wd.loss_window.max(1) {
            self.losses.remove(0);
        }
        let probe_due = wd.probe_every > 0 && self.step % wd.probe_every == 0;
        if !spike && !probe_due {
            return;
        }

        // Probe pass: flag live blocks whose magnitudes left the reference.
        let mut flagged: Vec<usize> = Vec::new();
        {
            let blocks = &self.blocks;
            let probe_queries = &mut self.probe_queries;
            for_each_photonic(model, |mi, mesh, _| {
                for (gi, blk) in blocks.iter().enumerate() {
                    if blk.mesh_idx != mi || blk.dead {
                        continue;
                    }
                    let mse = probe_mse(&mut mesh.ptcs[blk.local], &blk.ref_u_abs, &blk.ref_v_abs);
                    mesh.stats.fwd_block_cols += 2 * blk.k as u64;
                    mesh.stats.fwd_steps += 2;
                    *probe_queries += 2 * blk.k as u64;
                    if mse > wd.probe_tol {
                        flagged.push(gi);
                    }
                }
            });
        }
        if flagged.is_empty() {
            return;
        }
        if self.trigger_step.is_none() {
            self.trigger_step = Some(self.step);
            self.detect_latency = self.plan.first_fired(self.step).map(|f| self.step - f);
        }
        if self.recoveries >= wd.max_recoveries as u64 {
            return;
        }
        self.recoveries += 1;
        let round = self.recoveries;

        // Recovery pass: per flagged block, re-calibrate toward the
        // deployment references through the faulted hardware, then either
        // accept the block back or mask it out of the forward path.
        let t0 = std::time::Instant::now();
        let seed = self.seed;
        let blocks = &mut self.blocks;
        let recovery_queries = &mut self.recovery_queries;
        let recovered_blocks = &mut self.recovered_blocks;
        let dead_blocks = &mut self.dead_blocks;
        for_each_photonic(model, |mi, mesh, fwd_mask| {
            let mut touched = false;
            for &gi in &flagged {
                if blocks[gi].mesh_idx != mi {
                    continue;
                }
                let (local, m, k) = (blocks[gi].local, blocks[gi].m, blocks[gi].k);
                let queries;
                let healed;
                {
                    let blk = &blocks[gi];
                    let ptc = &mut mesh.ptcs[local];
                    let mut init = Vec::with_capacity(2 * m);
                    init.extend_from_slice(&ptc.u_mesh.phases);
                    init.extend_from_slice(&ptc.v_mesh.phases);
                    let mut prob =
                        RefCalProblem { ptc, ref_u: &blk.ref_u_abs, ref_v: &blk.ref_v_abs, m };
                    let zcfg = ZoConfig {
                        iters: wd.recovery_iters,
                        step: 0.1,
                        decay: 0.97,
                        step_floor: 2e-3,
                        best_recording: true,
                    };
                    let mut rng =
                        Rng::with_stream(seed ^ RECOVERY_TAG, ((gi as u64) << 32) ^ round);
                    let rep = ZoKind::Zcd.run(&mut prob, &init, zcfg, &mut rng);
                    prob.ptc.set_phases(Which::U, &rep.best_phases[..m]);
                    prob.ptc.set_phases(Which::V, &rep.best_phases[m..]);
                    queries = rep.queries;
                    // +1 query: the post-recovery acceptance probe.
                    healed = probe_mse(prob.ptc, &blk.ref_u_abs, &blk.ref_v_abs) <= wd.dead_tol;
                }
                mesh.stats.fwd_block_cols += (queries + 1) * 2 * k as u64;
                mesh.stats.fwd_steps += queries + 1;
                *recovery_queries += queries + 1;
                if healed {
                    *recovered_blocks += 1;
                } else {
                    // Graceful degradation: mask the block out of the
                    // forward path instead of letting a dead device poison
                    // every inference.
                    blocks[gi].dead = true;
                    *dead_blocks += 1;
                    let nb = mesh.ptcs.len();
                    let (keep, _) = fwd_mask.get_or_insert((vec![true; nb], 1.0));
                    keep[local] = false;
                }
                touched = true;
            }
            if touched {
                mesh.invalidate();
            }
        });
        self.recovery_secs += t0.elapsed().as_secs_f64();
    }

    /// Executed lifecycle steps so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// Fold the run into a report.
    pub fn finish(&self) -> LifecycleReport {
        LifecycleReport {
            drift: self.drift_on,
            faults: self.plan.events.len() as u64,
            trigger_step: self.trigger_step,
            detect_latency_steps: self.detect_latency,
            recoveries: self.recoveries,
            recovered_blocks: self.recovered_blocks,
            dead_blocks: self.dead_blocks,
            recovery_queries: self.recovery_queries,
            probe_queries: self.probe_queries,
            recovery_secs: self.recovery_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{build_model, EngineKind, ModelArch};
    use crate::photonics::NoiseModel;
    use crate::robustness::inject::{DriftConfig, FaultKind, FaultSpec};
    use crate::util::Rng;

    fn tiny_photonic_model() -> Model {
        let mut rng = Rng::new(77);
        build_model(
            ModelArch::MlpVowel,
            EngineKind::Photonic { k: 4, noise: NoiseModel::quant_only(8) },
            4,
            0.5,
            &mut rng,
        )
    }

    fn cfg(drift: bool, faults: bool, wd: Option<WatchdogConfig>) -> RobustnessConfig {
        RobustnessConfig {
            drift: drift.then(DriftConfig::default),
            faults: if faults {
                vec![FaultSpec { step: 2, kind: FaultKind::StuckPhase }]
            } else {
                Vec::new()
            },
            watchdog: wd,
        }
    }

    #[test]
    fn quiet_runtime_is_a_no_op() {
        let mut model = tiny_photonic_model();
        let mut rt = LifecycleRuntime::new(&cfg(false, false, None), &mut model, 42);
        let x = crate::linalg::Mat::randn(8, 3, 1.0, &mut Rng::new(1));
        let a = crate::nn::Act::from_features(x, 3);
        let before = model.forward(&a, false);
        for _ in 0..4 {
            rt.begin_step(&mut model);
            rt.observe(&mut model, 1.0);
        }
        let after = model.forward(&a, false);
        crate::util::prop::assert_close(&before.mat.data, &after.mat.data, 0.0, 0.0).unwrap();
        let rep = rt.finish();
        assert_eq!(rep, LifecycleReport::default());
        assert_eq!(rt.steps(), 4);
    }

    #[test]
    fn fault_trips_probe_and_watchdog_recovers() {
        let wd = WatchdogConfig { probe_every: 1, probe_tol: 1e-4, ..Default::default() };
        let mut model = tiny_photonic_model();
        let mut rt = LifecycleRuntime::new(&cfg(false, true, Some(wd)), &mut model, 42);
        for _ in 0..4 {
            rt.begin_step(&mut model);
            rt.observe(&mut model, 1.0);
        }
        let rep = rt.finish();
        assert_eq!(rep.faults, 1);
        assert_eq!(rep.trigger_step, Some(2), "probe missed the step-2 fault");
        assert_eq!(rep.detect_latency_steps, Some(0));
        assert!(rep.recoveries >= 1);
        assert!(rep.recovery_queries > 0);
        assert!(rep.probe_queries > 0);
        assert!(rep.recovered_blocks + rep.dead_blocks >= 1, "flagged block unaccounted");
    }

    #[test]
    fn detection_only_when_recovery_budget_is_zero() {
        let wd = WatchdogConfig {
            probe_every: 1,
            probe_tol: 1e-4,
            max_recoveries: 0,
            ..Default::default()
        };
        let mut model = tiny_photonic_model();
        let mut rt = LifecycleRuntime::new(&cfg(false, true, Some(wd)), &mut model, 42);
        for _ in 0..4 {
            rt.begin_step(&mut model);
            rt.observe(&mut model, 1.0);
        }
        let rep = rt.finish();
        assert_eq!(rep.trigger_step, Some(2));
        assert_eq!(rep.recoveries, 0);
        assert_eq!(rep.recovery_queries, 0);
    }

    #[test]
    fn lifecycle_composes_over_variation_base_overlay() {
        use crate::robustness::variation::{apply_variation, VariationConfig};
        let mut model = tiny_photonic_model();
        let vcfg = VariationConfig {
            gamma_std: 0.01,
            coupler_std: 0.01,
            loss_db_std: 0.01,
            ..Default::default()
        };
        apply_variation(&mut model, &vcfg, 42);
        let mut base_gains: Vec<Vec<f64>> = Vec::new();
        for_each_photonic(&mut model, |_, mesh, _| {
            for ptc in mesh.ptcs.iter() {
                base_gains.push(ptc.overlays().0.expect("variation installed").gain.clone());
            }
        });

        // Faults only, no drift: the lifecycle overlay is affine-identity
        // plus stuck entries, so the composed gain must still be exactly
        // the variation gain after the fault step installs overlays.
        let mut rt = LifecycleRuntime::new(&cfg(false, true, None), &mut model, 42);
        for _ in 0..3 {
            rt.begin_step(&mut model);
        }
        let mut seen = 0usize;
        let mut stuck_seen = 0usize;
        for_each_photonic(&mut model, |_, mesh, _| {
            for ptc in mesh.ptcs.iter() {
                let (u, v) = ptc.overlays();
                let u = u.expect("overlay dropped by lifecycle install");
                assert_eq!(u.gain, base_gains[seen], "variation gain lost in composition");
                stuck_seen += u.stuck.len() + v.map_or(0, |o| o.stuck.len());
                seen += 1;
            }
        });
        assert_eq!(seen, base_gains.len());
        assert!(stuck_seen >= 1, "the step-2 fault never landed in a composed overlay");
    }

    #[test]
    fn lifecycle_is_deterministic_across_instances() {
        let run = || {
            let mut model = tiny_photonic_model();
            let wd = WatchdogConfig { probe_every: 2, probe_tol: 1e-4, ..Default::default() };
            let mut rt = LifecycleRuntime::new(&cfg(true, true, Some(wd)), &mut model, 42);
            for _ in 0..6 {
                rt.begin_step(&mut model);
                rt.observe(&mut model, 1.0);
            }
            let mut rep = rt.finish();
            rep.recovery_secs = 0.0; // wall time is the one nondeterministic field
            rep
        };
        assert_eq!(run(), run());
    }
}
