//! # L2ight — on-chip learning for optical neural networks
//!
//! Rust reproduction of *"L2ight: Enabling On-Chip Learning for Optical Neural
//! Networks via Efficient in-situ Subspace Optimization"* (NeurIPS 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas photonic-tensor-core kernels (`python/compile/kernels/`),
//! * **L2** — JAX compute graphs AOT-lowered to HLO text (`python/compile/`),
//! * **L3** — this crate: the photonic-chip simulator substrate, the three-stage
//!   L2ight training protocol (identity calibration → parallel mapping →
//!   multi-level sparse subspace learning), the baselines, the Appendix-G cost
//!   profiler, and a PJRT runtime that executes the AOT artifacts.
//!
//! ## The compute engine
//!
//! Every simulator hot path runs on one shared engine:
//! [`util::pool`] — a persistent scoped thread pool (std-only, sized by
//! `L2IGHT_THREADS` or `available_parallelism`) with a per-thread scratch
//! arena — and [`linalg::gemm`] — register-tiled GEMM microkernels for all
//! four transpose cases that band large products across that pool. The
//! blocked mesh ([`photonics::mesh`]) fans its PTC grid out over the pool
//! (row strips for forward, column strips for feedback, blocks for the
//! Eq. 5 σ-gradient and batch realization), and the IC/PM stages reuse the
//! same pool for their per-block ZO sweeps. Work is partitioned by output
//! region, so results are bit-identical at every thread count; see
//! `rust/README.md` § "Performance & threading".
//!
//! ## The scenario matrix
//!
//! [`scenarios`] turns the paper's breadth claim into a CI artifact: a
//! declarative matrix of arch × dataset × noise × sparsity × protocol rows
//! runs in parallel over the same pool, emits `SCENARIOS_matrix.json`, and
//! is diffed against golden fixtures with per-metric tolerances
//! (`l2ight matrix --tier quick`).
//!
//! ## Serving
//!
//! [`serve`] is the deployment-shaped front door: a bounded admission
//! queue coalesces concurrent single-sample requests into column panels
//! for `ProjEngine::forward_packed`, N model replicas drain it on the
//! shared pool, checkpoints hot-reload between batches, and saturation
//! sheds instead of blocking (`l2ight serve-bench` drives open-loop load
//! and emits `BENCH_serve.json`).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod util;
pub mod linalg;
pub mod photonics;
pub mod nn;
pub mod optim;
pub mod zoo;
pub mod sampling;
pub mod stages;
pub mod robustness;
pub mod baselines;
pub mod profiler;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod scenarios;
pub mod serve;
