//! Training-time augmentation (§4.1: random crop, flip, color jitter on
//! CIFAR-10/100 and TinyImagenet). Operates in place on one CHW sample.

use crate::util::Rng;

/// Augmentation policy.
#[derive(Clone, Copy, Debug)]
pub struct Augment {
    /// Zero-pad by `crop_pad` then random-crop back to the original side.
    pub crop_pad: usize,
    /// Horizontal flip with probability 0.5.
    pub flip: bool,
    /// Per-channel multiplicative jitter std (0 = off).
    pub jitter: f32,
}

impl Augment {
    /// No augmentation.
    pub const NONE: Augment = Augment { crop_pad: 0, flip: false, jitter: 0.0 };

    /// The paper's CIFAR policy: crop(pad 4) + flip + color jitter.
    pub const CIFAR: Augment = Augment { crop_pad: 4, flip: true, jitter: 0.1 };

    pub fn is_none(&self) -> bool {
        self.crop_pad == 0 && !self.flip && self.jitter == 0.0
    }

    /// Apply in place to one CHW sample.
    pub fn apply(&self, x: &mut [f32], c: usize, h: usize, w: usize, rng: &mut Rng) {
        if self.is_none() || h * w <= 1 {
            return;
        }
        if self.crop_pad > 0 {
            let p = self.crop_pad;
            // Offsets into the virtual padded image; equal p ⇒ identity.
            let oy = rng.below(2 * p + 1);
            let ox = rng.below(2 * p + 1);
            if oy != p || ox != p {
                let mut out = vec![0.0f32; x.len()];
                for ch in 0..c {
                    let src = &x[ch * h * w..(ch + 1) * h * w];
                    let dst = &mut out[ch * h * w..(ch + 1) * h * w];
                    for y in 0..h {
                        // Source row in the padded frame.
                        let sy = y as isize + oy as isize - p as isize;
                        if sy < 0 || sy >= h as isize {
                            continue; // stays zero (pad region)
                        }
                        for xx in 0..w {
                            let sx = xx as isize + ox as isize - p as isize;
                            if sx < 0 || sx >= w as isize {
                                continue;
                            }
                            dst[y * w + xx] = src[sy as usize * w + sx as usize];
                        }
                    }
                }
                x.copy_from_slice(&out);
            }
        }
        if self.flip && rng.bernoulli(0.5) {
            for ch in 0..c {
                let plane = &mut x[ch * h * w..(ch + 1) * h * w];
                for y in 0..h {
                    plane[y * w..(y + 1) * w].reverse();
                }
            }
        }
        if self.jitter > 0.0 {
            for ch in 0..c {
                let g = 1.0 + self.jitter * rng.normal() as f32;
                for v in &mut x[ch * h * w..(ch + 1) * h * w] {
                    *v *= g;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..27).map(|i| i as f32).collect();
        let mut x = orig.clone();
        Augment::NONE.apply(&mut x, 3, 3, 3, &mut rng);
        assert_eq!(x, orig);
    }

    #[test]
    fn flip_only_reverses_rows() {
        let mut rng = Rng::new(0);
        // Find a seed state where the flip fires by trying until it does.
        let aug = Augment { crop_pad: 0, flip: true, jitter: 0.0 };
        let orig: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let mut flipped_seen = false;
        for _ in 0..32 {
            let mut x = orig.clone();
            aug.apply(&mut x, 1, 3, 3, &mut rng);
            if x != orig {
                assert_eq!(x, vec![2.0, 1.0, 0.0, 5.0, 4.0, 3.0, 8.0, 7.0, 6.0]);
                flipped_seen = true;
            }
        }
        assert!(flipped_seen, "flip never triggered in 32 draws");
    }

    #[test]
    fn crop_preserves_shape_and_energy_bound() {
        let mut rng = Rng::new(3);
        let aug = Augment { crop_pad: 2, flip: false, jitter: 0.0 };
        let orig = vec![1.0f32; 64];
        for _ in 0..16 {
            let mut x = orig.clone();
            aug.apply(&mut x, 1, 8, 8, &mut rng);
            assert_eq!(x.len(), 64);
            // Crop can only remove mass (pad is zero).
            assert!(x.iter().sum::<f32>() <= 64.0 + 1e-6);
        }
    }

    #[test]
    fn vector_samples_untouched() {
        let mut rng = Rng::new(4);
        let mut x = vec![1.0f32, 2.0, 3.0];
        Augment::CIFAR.apply(&mut x, 3, 1, 1, &mut rng);
        assert_eq!(x, vec![1.0, 2.0, 3.0]);
    }
}
