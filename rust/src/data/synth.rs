//! Class-conditional procedural dataset generators (DESIGN.md §4).
//!
//! A class template is a mixture of low-frequency 2-D cosines with random
//! frequency, phase, and per-channel amplitude; samples add isotropic noise
//! scaled by `difficulty` and a small random circular shift. The signal is
//! spatially smooth, so convolution + pooling extract it better than flat
//! projections and spatial augmentation is label-preserving — the structural
//! properties the paper's CNN experiments rely on.

use super::Dataset;
use crate::util::Rng;

/// Shape-faithful stand-ins for the paper's benchmark datasets (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Deterding vowel data as used by the MLP (8 features, 4 classes) [17].
    VowelLike,
    /// 1×28×28, 10 classes.
    MnistLike,
    /// 1×28×28, 10 classes, harder texture statistics.
    FashionLike,
    /// 3×32×32, 10 classes.
    Cifar10Like,
    /// 3×32×32, 100 classes.
    Cifar100Like,
    /// 3×64×64, 200 classes (TinyImagenet shape).
    TinyLike,
}

impl DatasetKind {
    pub fn parse(name: &str) -> Option<DatasetKind> {
        Some(match name {
            "vowel" => DatasetKind::VowelLike,
            "mnist" => DatasetKind::MnistLike,
            "fashion" | "fashionmnist" => DatasetKind::FashionLike,
            "cifar10" | "cifar-10" => DatasetKind::Cifar10Like,
            "cifar100" | "cifar-100" => DatasetKind::Cifar100Like,
            "tiny" | "tinyimagenet" => DatasetKind::TinyLike,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::VowelLike => "vowel",
            DatasetKind::MnistLike => "mnist",
            DatasetKind::FashionLike => "fashion",
            DatasetKind::Cifar10Like => "cifar10",
            DatasetKind::Cifar100Like => "cifar100",
            DatasetKind::TinyLike => "tiny",
        }
    }

    /// (channels, side, classes) of the real dataset this stands in for.
    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::VowelLike => (8, 1, 4),
            DatasetKind::MnistLike => (1, 28, 10),
            DatasetKind::FashionLike => (1, 28, 10),
            DatasetKind::Cifar10Like => (3, 32, 10),
            DatasetKind::Cifar100Like => (3, 32, 100),
            DatasetKind::TinyLike => (3, 64, 200),
        }
    }

    /// Default difficulty (noise-to-signal) tuned so task orderings match
    /// the paper's relative accuracies (harder: fashion < cifar < tiny).
    pub fn default_difficulty(&self) -> f32 {
        match self {
            DatasetKind::VowelLike => 0.5,
            DatasetKind::MnistLike => 0.8,
            DatasetKind::FashionLike => 1.1,
            DatasetKind::Cifar10Like => 1.3,
            DatasetKind::Cifar100Like => 1.5,
            DatasetKind::TinyLike => 1.6,
        }
    }
}

/// Full specification of a synthetic dataset instance.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub kind: DatasetKind,
    pub n_train: usize,
    pub n_test: usize,
    /// Noise std relative to unit template power.
    pub difficulty: f32,
    /// Template seed — **shared across tasks that should be related**.
    /// Fig. 14's transfer experiment uses CIFAR-100-like and CIFAR-10-like
    /// specs with the same `template_seed`, so the transfer source really
    /// contains features of the target.
    pub template_seed: u64,
    /// Sampling seed (train/test splits fork from it).
    pub sample_seed: u64,
    /// Optional class-count override (e.g. width-scaled Tiny runs).
    pub classes_override: Option<usize>,
    /// Optional side override (downscale images for CPU-budget runs).
    pub side_override: Option<usize>,
}

impl SynthSpec {
    pub fn new(kind: DatasetKind, n_train: usize, n_test: usize) -> SynthSpec {
        SynthSpec {
            kind,
            n_train,
            n_test,
            difficulty: kind.default_difficulty(),
            template_seed: 0x5eed_0000 + kind as u64,
            sample_seed: 42,
            classes_override: None,
            side_override: None,
        }
    }

    /// Small split for tests and quick examples.
    pub fn quick(kind: DatasetKind, n_train: usize, n_test: usize) -> SynthSpec {
        SynthSpec::new(kind, n_train, n_test)
    }

    pub fn with_difficulty(mut self, d: f32) -> SynthSpec {
        self.difficulty = d;
        self
    }

    pub fn with_seeds(mut self, template: u64, sample: u64) -> SynthSpec {
        self.template_seed = template;
        self.sample_seed = sample;
        self
    }

    pub fn with_classes(mut self, classes: usize) -> SynthSpec {
        self.classes_override = Some(classes);
        self
    }

    pub fn with_side(mut self, side: usize) -> SynthSpec {
        self.side_override = Some(side);
        self
    }

    /// Resolved (c, h=w, classes).
    pub fn resolved_shape(&self) -> (usize, usize, usize) {
        let (c, side, classes) = self.kind.shape();
        (
            c,
            self.side_override.unwrap_or(side),
            self.classes_override.unwrap_or(classes),
        )
    }

    /// Generate the (train, test) pair.
    pub fn generate(&self) -> (Dataset, Dataset) {
        let (c, side, classes) = self.resolved_shape();
        let templates = ClassTemplates::build(c, side, classes, self.template_seed);
        let train = templates.sample_set(
            self,
            self.n_train,
            Rng::with_stream(self.sample_seed, 1),
            "train",
        );
        let test = templates.sample_set(
            self,
            self.n_test,
            Rng::with_stream(self.sample_seed, 2),
            "test",
        );
        (train, test)
    }
}

/// Per-class smooth templates.
struct ClassTemplates {
    /// [classes][c·side·side]
    templates: Vec<Vec<f32>>,
    c: usize,
    side: usize,
}

impl ClassTemplates {
    fn build(c: usize, side: usize, classes: usize, seed: u64) -> ClassTemplates {
        let mut templates = Vec::with_capacity(classes);
        for cls in 0..classes {
            let mut rng = Rng::with_stream(seed, cls as u64);
            templates.push(make_template(c, side, &mut rng));
        }
        ClassTemplates { templates, c, side }
    }

    fn sample_set(&self, spec: &SynthSpec, n: usize, mut rng: Rng, split: &str) -> Dataset {
        let classes = self.templates.len();
        let sample_len = self.c * self.side * self.side;
        let mut x = Vec::with_capacity(n * sample_len);
        let mut labels = Vec::with_capacity(n);
        let mut buf = vec![0.0f32; sample_len];
        for i in 0..n {
            // Balanced classes with a shuffled tail.
            let cls = if i < (n / classes) * classes { i % classes } else { rng.below(classes) };
            labels.push(cls);
            self.draw(cls, spec.difficulty, &mut buf, &mut rng);
            x.extend_from_slice(&buf);
        }
        Dataset {
            x,
            labels,
            n,
            c: self.c,
            h: if self.side == 1 { 1 } else { self.side },
            w: if self.side == 1 { 1 } else { self.side },
            classes,
            name: format!("{}-{split}", spec.kind.name()),
        }
    }

    /// One sample: shifted template + noise, normalized to ~unit std.
    fn draw(&self, cls: usize, difficulty: f32, out: &mut [f32], rng: &mut Rng) {
        let t = &self.templates[cls];
        let side = self.side;
        if side == 1 {
            // Feature-vector task: template + noise, no spatial structure.
            for (o, &tv) in out.iter_mut().zip(t.iter()) {
                *o = tv + difficulty * rng.normal() as f32;
            }
            return;
        }
        // Random circular shift (≤ side/8 pixels) keeps the task
        // translation-tolerant, the same role jitter plays in real data.
        let max_shift = (side / 8).max(1);
        let dy = rng.below(2 * max_shift + 1) as isize - max_shift as isize;
        let dx = rng.below(2 * max_shift + 1) as isize - max_shift as isize;
        let amp = 1.0 + 0.2 * rng.normal() as f32; // per-sample contrast
        for ch in 0..self.c {
            let tch = &t[ch * side * side..(ch + 1) * side * side];
            let och = &mut out[ch * side * side..(ch + 1) * side * side];
            for y in 0..side {
                let sy = (y as isize + dy).rem_euclid(side as isize) as usize;
                for xx in 0..side {
                    let sx = (xx as isize + dx).rem_euclid(side as isize) as usize;
                    och[y * side + xx] =
                        amp * tch[sy * side + sx] + difficulty * rng.normal() as f32;
                }
            }
        }
    }
}

/// Low-frequency cosine mixture, normalized to unit RMS per channel.
fn make_template(c: usize, side: usize, rng: &mut Rng) -> Vec<f32> {
    let mut t = vec![0.0f32; c * side * side];
    if side == 1 {
        // Feature vector: a random unit-norm direction scaled to RMS 1.
        rng.fill_normal(&mut t, 0.0, 1.0);
        let rms = (t.iter().map(|v| v * v).sum::<f32>() / t.len() as f32).sqrt().max(1e-6);
        for v in &mut t {
            *v /= rms;
        }
        return t;
    }
    let n_modes = 6;
    for ch in 0..c {
        let tch = &mut t[ch * side * side..(ch + 1) * side * side];
        for _ in 0..n_modes {
            // Frequencies up to 3 cycles across the image → smooth blobs.
            let fy = rng.uniform_range(0.5, 3.0) * std::f64::consts::TAU / side as f64;
            let fx = rng.uniform_range(0.5, 3.0) * std::f64::consts::TAU / side as f64;
            let py = rng.uniform_range(0.0, std::f64::consts::TAU);
            let px = rng.uniform_range(0.0, std::f64::consts::TAU);
            let a = rng.normal() as f32 / (n_modes as f32).sqrt();
            for y in 0..side {
                let wy = (fy * y as f64 + py).cos();
                for x in 0..side {
                    tch[y * side + x] += a * (wy * (fx * x as f64 + px).cos()) as f32;
                }
            }
        }
        let rms =
            (tch.iter().map(|v| v * v).sum::<f32>() / tch.len() as f32).sqrt().max(1e-6);
        for v in tch.iter_mut() {
            *v /= rms;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_kind() {
        for kind in [
            DatasetKind::VowelLike,
            DatasetKind::MnistLike,
            DatasetKind::Cifar10Like,
        ] {
            let (train, test) = SynthSpec::quick(kind, 24, 12).generate();
            let (c, side, classes) = kind.shape();
            assert_eq!(train.c, c);
            assert_eq!(train.h * train.w, side * side);
            assert_eq!(train.classes, classes);
            assert_eq!(train.n, 24);
            assert_eq!(test.n, 12);
            assert_eq!(train.x.len(), 24 * train.sample_len());
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = SynthSpec::quick(DatasetKind::MnistLike, 8, 4).generate().0;
        let b = SynthSpec::quick(DatasetKind::MnistLike, 8, 4).generate().0;
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn class_balance_is_even() {
        let (train, _) = SynthSpec::quick(DatasetKind::Cifar10Like, 100, 10).generate();
        let mut counts = vec![0usize; 10];
        for &l in &train.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "counts {counts:?}");
    }

    #[test]
    fn same_template_seed_shares_structure() {
        // The transfer setup: two datasets with shared templates (first 10
        // classes) must be more similar than two with different seeds.
        // Average many zero-noise samples of class 0: the shift averages
        // into a smoothed template that still identifies the template seed.
        let gen = |tseed: u64, sseed: u64| {
            let ds = SynthSpec::quick(DatasetKind::Cifar10Like, 40, 1)
                .with_seeds(tseed, sseed)
                .with_difficulty(0.0)
                .generate()
                .0;
            let s = ds.sample_len();
            let mut mean = vec![0.0f32; s];
            let mut n = 0.0f32;
            for i in 0..ds.n {
                if ds.labels[i] == 0 {
                    for (m, v) in mean.iter_mut().zip(ds.sample(i)) {
                        *m += v;
                    }
                    n += 1.0;
                }
            }
            for m in &mut mean {
                *m /= n;
            }
            mean
        };
        let a = gen(7, 1);
        let b = gen(7, 2);
        let c = gen(8, 3);
        let corr = |x: &[f32], y: &[f32]| {
            let n = x.len() as f32;
            let (mx, my) = (
                x.iter().sum::<f32>() / n,
                y.iter().sum::<f32>() / n,
            );
            let mut num = 0.0;
            let mut dx = 0.0;
            let mut dy = 0.0;
            for (a, b) in x.iter().zip(y) {
                num += (a - mx) * (b - my);
                dx += (a - mx) * (a - mx);
                dy += (b - my) * (b - my);
            }
            num / (dx.sqrt() * dy.sqrt()).max(1e-9)
        };
        let same = corr(&a, &b).abs();
        let diff = corr(&a, &c).abs();
        assert!(same > diff, "shared templates should correlate: {same} vs {diff}");
    }

    #[test]
    fn difficulty_increases_noise() {
        let easy = SynthSpec::quick(DatasetKind::MnistLike, 4, 1)
            .with_difficulty(0.1)
            .generate()
            .0;
        let hard = SynthSpec::quick(DatasetKind::MnistLike, 4, 1)
            .with_difficulty(2.0)
            .generate()
            .0;
        // Same labels; compare within-class sample variance proxy: distance
        // between two samples of the same class.
        let d = |ds: &Dataset| {
            let (mut i, mut j) = (0, 0);
            'outer: for a in 0..ds.n {
                for b in a + 1..ds.n {
                    if ds.labels[a] == ds.labels[b] {
                        i = a;
                        j = b;
                        break 'outer;
                    }
                }
            }
            ds.sample(i)
                .iter()
                .zip(ds.sample(j))
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
        };
        assert!(d(&hard) > d(&easy));
    }

    #[test]
    fn overrides_apply() {
        let spec = SynthSpec::quick(DatasetKind::TinyLike, 4, 2).with_classes(20).with_side(16);
        let (train, _) = spec.generate();
        assert_eq!(train.classes, 20);
        assert_eq!(train.h, 16);
    }
}
