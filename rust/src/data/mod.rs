//! Synthetic dataset substrate.
//!
//! The paper evaluates on Vowel / MNIST / FashionMNIST / CIFAR-10/100 /
//! TinyImagenet. This environment is offline, so we build procedural
//! class-conditional generators with **identical tensor shapes and class
//! counts** and a controllable difficulty knob (DESIGN.md §4): every L2ight
//! claim is *relative* (sampling strategy A vs B, mapped vs scratch), and
//! those orderings are preserved under a synthetic task of matched shape.
//!
//! Each class owns a smooth random template (low-frequency Fourier mixture);
//! a sample is `template + difficulty·noise` plus a random shift, so nearby
//! pixels stay correlated (CNNs beat MLPs, crops/flips help — the qualitative
//! structure augmentation relies on).

pub mod augment;
pub mod synth;

pub use augment::Augment;
pub use synth::{DatasetKind, SynthSpec};

use crate::nn::Act;
use crate::util::Rng;

/// An in-memory labelled dataset in NCHW layout (H=W=1 for feature vectors).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Flat NCHW sample data, `n · c · h · w` values.
    pub x: Vec<f32>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub classes: usize,
    pub name: String,
}

impl Dataset {
    /// Values per sample.
    pub fn sample_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Borrow sample `i` as a flat CHW slice.
    pub fn sample(&self, i: usize) -> &[f32] {
        let s = self.sample_len();
        &self.x[i * s..(i + 1) * s]
    }

    /// Gather `idx` into a batch activation, optionally augmenting each
    /// sample on the fly.
    pub fn gather(&self, idx: &[usize], augment: Option<(&Augment, &mut Rng)>) -> (Act, Vec<usize>) {
        let s = self.sample_len();
        let mut flat = Vec::with_capacity(idx.len() * s);
        let mut labels = Vec::with_capacity(idx.len());
        match augment {
            None => {
                for &i in idx {
                    flat.extend_from_slice(self.sample(i));
                    labels.push(self.labels[i]);
                }
            }
            Some((aug, rng)) => {
                let mut buf = vec![0.0f32; s];
                for &i in idx {
                    buf.copy_from_slice(self.sample(i));
                    aug.apply(&mut buf, self.c, self.h, self.w, rng);
                    flat.extend_from_slice(&buf);
                    labels.push(self.labels[i]);
                }
            }
        }
        (Act::from_nchw(&flat, idx.len(), self.c, self.h, self.w), labels)
    }

    /// Evaluate classification accuracy of `model` over the whole set in
    /// batches of `batch` (no augmentation, eval mode).
    pub fn evaluate(&self, model: &mut crate::nn::Model, batch: usize) -> f32 {
        let mut correct = 0.0f32;
        let mut seen = 0usize;
        let mut i = 0;
        while i < self.n {
            let hi = (i + batch).min(self.n);
            let idx: Vec<usize> = (i..hi).collect();
            let (x, labels) = self.gather(&idx, None);
            let logits = model.forward(&x, false);
            correct += crate::nn::accuracy(&logits.mat, &labels) * labels.len() as f32;
            seen += labels.len();
            i = hi;
        }
        model.clear_caches();
        correct / seen.max(1) as f32
    }
}

/// Shuffled mini-batch index iterator over one epoch.
#[derive(Clone, Debug)]
pub struct Loader {
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
}

impl Loader {
    /// New epoch over `n` samples with batch size `batch`, shuffled by `rng`.
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> Loader {
        assert!(batch > 0, "batch size must be positive");
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Loader { order, batch, cursor: 0 }
    }

    /// Number of batches in the epoch.
    pub fn len(&self) -> usize {
        self.order.len().div_ceil(self.batch)
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

impl Iterator for Loader {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let hi = (self.cursor + self.batch).min(self.order.len());
        let b = self.order[self.cursor..hi].to_vec();
        self.cursor = hi;
        Some(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loader_covers_every_index_once() {
        let mut rng = Rng::new(7);
        let l = Loader::new(23, 5, &mut rng);
        assert_eq!(l.len(), 5);
        let mut seen = vec![false; 23];
        for batch in l {
            for i in batch {
                assert!(!seen[i], "index {i} repeated");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gather_shapes_and_labels() {
        let spec = SynthSpec::quick(DatasetKind::Cifar10Like, 32, 16);
        let (train, _) = spec.generate();
        let (act, labels) = train.gather(&[0, 5, 9], None);
        assert_eq!(act.batch, 3);
        assert_eq!(act.channels(), 3);
        assert_eq!((act.h, act.w), (32, 32));
        assert_eq!(labels, vec![train.labels[0], train.labels[5], train.labels[9]]);
    }
}
