//! GEMM micro-kernels — the native simulator's compute engine.
//!
//! Four layers:
//!
//! * **SIMD dispatch** ([`super::simd`]) — every slice kernel resolves to
//!   one of the kernel families (`scalar` | `scalar-fma` | `avx2` |
//!   `avx512` | `neon`), picked once per process from `L2IGHT_SIMD`. The
//!   `*_at` variants take an explicit [`SimdLevel`] so tests, benches, and
//!   CI legs can pin a level; the unsuffixed entry points use
//!   [`simd::active`]. Families that the current target architecture
//!   cannot compile fall through to the scalar kernels (unreachable in
//!   practice: [`simd::active`] only selects detected levels).
//! * **Slice kernels** (`gemm_acc_slices*`, `gemm_at_b_acc_band*`,
//!   `gemm_a_bt_acc_slices*`) — register-tiled inner loops over raw
//!   row-major storage. The A·B and Aᵀ·B kernels process 4 rows per pass so
//!   each loaded B row (or C row) is reused 4×, and the inner j-loops are
//!   independent-lane updates (auto-vectorized in the scalar kernels,
//!   explicit 8/16/4-lane FMA in the AVX2/AVX-512/NEON ones). The A·Bᵀ
//!   kernel tiles 4 dot products per A-row load (4 independent accumulator
//!   chains for ILP) and skips all-zero A rows (ReLU-sparse upstream
//!   gradients). Operating on slices lets the mesh hot paths feed
//!   sub-panels of padded activations directly — no per-call `Vec<Mat>`
//!   panel slicing.
//! * **Cache blocking** ([`matmul_acc_with_blocking`]) — for operands that
//!   exceed the per-level [`tune::GemmBlocking`] panels, the A·B wrapper
//!   packs B into NC-column panels and A into MC×KC blocks so the hot
//!   inner kernels run on L2-resident operands. Blocking is bitwise-safe
//!   by construction (see "blocking rules" below), so tile sizes are pure
//!   performance knobs owned by the autotuner ([`super::tune`]).
//! * **`Mat` wrappers** (`matmul*`) — shape-checked entry points that band
//!   the output rows across the shared thread pool (`util::pool`) when the
//!   product is large enough to amortize a pool wakeup. Banding partitions
//!   output elements, so per-element accumulation order — and therefore the
//!   result — is identical at every thread count *within a dispatch level*.
//!
//! §Blocking rules (the bitwise contract). Splitting work can never change
//! per-element accumulation order:
//!
//! * **A·B** (`gemm_acc_slices*`): one fused update per element per inner
//!   step `l`, in body and tail alike — K may split at *any* boundary and
//!   column panels at any width. Row bands/blocks must be multiples of 4 so
//!   the 4-row zero-skip quads group rows identically to the unsplit run.
//! * **Aᵀ·B** (`gemm_at_b_acc_band*`): inner steps are consumed in quads
//!   whose 4 fused updates chain in fixed order — K may split only at
//!   multiples of 4 ([`tune::GemmBlocking`] enforces `kc % 4 == 0`).
//! * **A·Bᵀ** (`gemm_a_bt_acc_slices*`): each output element is one
//!   whole-K accumulator chain — K must **not** split. Its wrapper keeps
//!   the M-banded path only (the dW += dy·xᵀ use sites have small K).
//!
//! Packing and the C panel gather/scatter are pure copies and never touch
//! numerics.

use super::mat::Mat;
use super::simd::{self, SimdLevel};
use super::tune::{self, GemmBlocking};
use crate::util::pool::{self, par_min_work, Scratch, SendPtr};

// ---------------------------------------------------------------------------
// Slice kernels — scalar reference implementations
// ---------------------------------------------------------------------------

/// Portable scalar C[m×n] += A[m×kk] · B[kk×n] over raw row-major slices.
/// Register-tiled: 4 C rows per pass share each loaded B row. Bitwise
/// identical to the seed-era engine (pre-SIMD numerics).
pub fn gemm_acc_slices_scalar(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
    debug_assert!(a.len() >= m * kk && b.len() >= kk * n && c.len() >= m * n);
    let mut i = 0;
    while i + 4 <= m {
        let rows = &mut c[i * n..(i + 4) * n];
        let (c0, rows) = rows.split_at_mut(n);
        let (c1, rows) = rows.split_at_mut(n);
        let (c2, c3) = rows.split_at_mut(n);
        let a0 = &a[i * kk..(i + 1) * kk];
        let a1 = &a[(i + 1) * kk..(i + 2) * kk];
        let a2 = &a[(i + 2) * kk..(i + 3) * kk];
        let a3 = &a[(i + 3) * kk..(i + 4) * kk];
        for l in 0..kk {
            let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
            if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                continue; // structured-sparsity fast path (masked weights)
            }
            let br = &b[l * n..(l + 1) * n];
            for j in 0..n {
                let v = br[j];
                c0[j] += x0 * v;
                c1[j] += x1 * v;
                c2[j] += x2 * v;
                c3[j] += x3 * v;
            }
        }
        i += 4;
    }
    for r in i..m {
        let ar = &a[r * kk..(r + 1) * kk];
        let cr = &mut c[r * n..(r + 1) * n];
        for (l, &x) in ar.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let br = &b[l * n..(l + 1) * n];
            for j in 0..n {
                cr[j] += x * br[j];
            }
        }
    }
}

/// Portable scalar C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] where A is [kk×m] and
/// B is [kk×n], writing into `c_band` (rows `i0..i1` only — the unit of
/// pool banding). 4 A/B row pairs per pass so each C row is touched kk/4
/// times.
#[allow(clippy::too_many_arguments)]
pub fn gemm_at_b_acc_band_scalar(
    a: &[f32],
    kk: usize,
    m: usize,
    b: &[f32],
    n: usize,
    i0: usize,
    i1: usize,
    c_band: &mut [f32],
) {
    debug_assert!(a.len() >= kk * m && b.len() >= kk * n);
    debug_assert!(i1 <= m && c_band.len() >= (i1 - i0) * n);
    let mut l = 0;
    while l + 4 <= kk {
        let a0 = &a[l * m..(l + 1) * m];
        let a1 = &a[(l + 1) * m..(l + 2) * m];
        let a2 = &a[(l + 2) * m..(l + 3) * m];
        let a3 = &a[(l + 3) * m..(l + 4) * m];
        let b0 = &b[l * n..(l + 1) * n];
        let b1 = &b[(l + 1) * n..(l + 2) * n];
        let b2 = &b[(l + 2) * n..(l + 3) * n];
        let b3 = &b[(l + 3) * n..(l + 4) * n];
        for i in i0..i1 {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                continue;
            }
            let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
            for j in 0..n {
                cr[j] += x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
            }
        }
        l += 4;
    }
    for ll in l..kk {
        let ar = &a[ll * m..(ll + 1) * m];
        let br = &b[ll * n..(ll + 1) * n];
        for i in i0..i1 {
            let x = ar[i];
            if x == 0.0 {
                continue;
            }
            let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
            for j in 0..n {
                cr[j] += x * br[j];
            }
        }
    }
}

/// Portable scalar C[m×p] += A[m×kk] · B[p×kk]ᵀ (dot-product layout).
/// Tiles 4 B rows per A-row pass (4 independent accumulator chains) and
/// skips all-zero A rows — the zero-skip fast path for ReLU-sparse
/// upstream gradients.
pub fn gemm_a_bt_acc_slices_scalar(
    a: &[f32],
    m: usize,
    kk: usize,
    b: &[f32],
    p: usize,
    c: &mut [f32],
) {
    debug_assert!(a.len() >= m * kk && b.len() >= p * kk && c.len() >= m * p);
    for i in 0..m {
        let ar = &a[i * kk..(i + 1) * kk];
        if ar.iter().all(|&v| v == 0.0) {
            continue;
        }
        let cr = &mut c[i * p..(i + 1) * p];
        let mut j = 0;
        while j + 4 <= p {
            let b0 = &b[j * kk..(j + 1) * kk];
            let b1 = &b[(j + 1) * kk..(j + 2) * kk];
            let b2 = &b[(j + 2) * kk..(j + 3) * kk];
            let b3 = &b[(j + 3) * kk..(j + 4) * kk];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for l in 0..kk {
                let av = ar[l];
                s0 += av * b0[l];
                s1 += av * b1[l];
                s2 += av * b2[l];
                s3 += av * b3[l];
            }
            cr[j] += s0;
            cr[j + 1] += s1;
            cr[j + 2] += s2;
            cr[j + 3] += s3;
            j += 4;
        }
        for jj in j..p {
            let br = &b[jj * kk..(jj + 1) * kk];
            let mut s = 0.0f32;
            for (x, y) in ar.iter().zip(br) {
                s += x * y;
            }
            cr[jj] += s;
        }
    }
}

fn dot_mul_scalar(x: &[f32], y: &[f32], len: usize) -> f32 {
    let mut s = 0.0f32;
    for (p, q) in x[..len].iter().zip(&y[..len]) {
        s += p * q;
    }
    s
}

// ---------------------------------------------------------------------------
// Slice kernels — SIMD dispatch
// ---------------------------------------------------------------------------

/// C[m×n] += A[m×kk] · B[kk×n] at an explicit dispatch level. Pinning a
/// vector level on a CPU without the ISA is the caller's bug — check
/// [`SimdLevel::available`] first (the unsuffixed entry points go through
/// [`simd::active`], which only selects detected levels). Levels the
/// target architecture cannot compile fall through to scalar.
pub fn gemm_acc_slices_at(
    level: SimdLevel,
    a: &[f32],
    m: usize,
    kk: usize,
    b: &[f32],
    n: usize,
    c: &mut [f32],
) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // Safety: vector levels are only reachable after runtime feature
        // detection (see the doc contract above).
        SimdLevel::Avx2 => unsafe { simd::avx2::gemm_acc(a, m, kk, b, n, c) },
        #[cfg(target_arch = "x86_64")]
        // Safety: as above (avx512f detected).
        SimdLevel::Avx512 => unsafe { simd::avx512::gemm_acc(a, m, kk, b, n, c) },
        #[cfg(target_arch = "aarch64")]
        // Safety: AdvSIMD is mandatory on aarch64.
        SimdLevel::Neon => unsafe { simd::neon::gemm_acc(a, m, kk, b, n, c) },
        SimdLevel::ScalarFma => simd::scalar_fma::gemm_acc(a, m, kk, b, n, c),
        _ => gemm_acc_slices_scalar(a, m, kk, b, n, c),
    }
}

/// C[m×n] += A[m×kk] · B[kk×n] at the process-wide dispatch level.
pub fn gemm_acc_slices(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
    gemm_acc_slices_at(simd::active(), a, m, kk, b, n, c)
}

/// Banded Aᵀ·B accumulate at an explicit dispatch level (see
/// [`gemm_acc_slices_at`] for the level contract).
#[allow(clippy::too_many_arguments)]
pub fn gemm_at_b_acc_band_at(
    level: SimdLevel,
    a: &[f32],
    kk: usize,
    m: usize,
    b: &[f32],
    n: usize,
    i0: usize,
    i1: usize,
    c_band: &mut [f32],
) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // Safety: vector levels are only reachable after runtime feature detection.
        SimdLevel::Avx2 => unsafe { simd::avx2::gemm_at_b_band(a, kk, m, b, n, i0, i1, c_band) },
        #[cfg(target_arch = "x86_64")]
        // Safety: as above (avx512f detected).
        SimdLevel::Avx512 => unsafe { simd::avx512::gemm_at_b_band(a, kk, m, b, n, i0, i1, c_band) },
        #[cfg(target_arch = "aarch64")]
        // Safety: AdvSIMD is mandatory on aarch64.
        SimdLevel::Neon => unsafe { simd::neon::gemm_at_b_band(a, kk, m, b, n, i0, i1, c_band) },
        SimdLevel::ScalarFma => simd::scalar_fma::gemm_at_b_band(a, kk, m, b, n, i0, i1, c_band),
        _ => gemm_at_b_acc_band_scalar(a, kk, m, b, n, i0, i1, c_band),
    }
}

/// C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] at the process-wide dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn gemm_at_b_acc_band(
    a: &[f32],
    kk: usize,
    m: usize,
    b: &[f32],
    n: usize,
    i0: usize,
    i1: usize,
    c_band: &mut [f32],
) {
    gemm_at_b_acc_band_at(simd::active(), a, kk, m, b, n, i0, i1, c_band)
}

/// A·Bᵀ accumulate at an explicit dispatch level (see
/// [`gemm_acc_slices_at`] for the level contract).
pub fn gemm_a_bt_acc_slices_at(
    level: SimdLevel,
    a: &[f32],
    m: usize,
    kk: usize,
    b: &[f32],
    p: usize,
    c: &mut [f32],
) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // Safety: vector levels are only reachable after runtime feature detection.
        SimdLevel::Avx2 => unsafe { simd::avx2::gemm_a_bt(a, m, kk, b, p, c) },
        #[cfg(target_arch = "x86_64")]
        // Safety: as above (avx512f detected).
        SimdLevel::Avx512 => unsafe { simd::avx512::gemm_a_bt(a, m, kk, b, p, c) },
        #[cfg(target_arch = "aarch64")]
        // Safety: AdvSIMD is mandatory on aarch64.
        SimdLevel::Neon => unsafe { simd::neon::gemm_a_bt(a, m, kk, b, p, c) },
        SimdLevel::ScalarFma => simd::scalar_fma::gemm_a_bt(a, m, kk, b, p, c),
        _ => gemm_a_bt_acc_slices_scalar(a, m, kk, b, p, c),
    }
}

/// C[m×p] += A[m×kk] · B[p×kk]ᵀ at the process-wide dispatch level.
pub fn gemm_a_bt_acc_slices(a: &[f32], m: usize, kk: usize, b: &[f32], p: usize, c: &mut [f32]) {
    gemm_a_bt_acc_slices_at(simd::active(), a, m, kk, b, p, c)
}

/// Σ_j x[j]·y[j] over `len` elements at an explicit dispatch level — the
/// Eq. 5 Hadamard reduction (scalar: seed-order sequential sum).
pub fn dot_mul_at(level: SimdLevel, x: &[f32], y: &[f32], len: usize) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        // Safety: vector levels are only reachable after runtime feature detection.
        SimdLevel::Avx2 => unsafe { simd::avx2::dot_mul(x, y, len) },
        #[cfg(target_arch = "x86_64")]
        // Safety: as above (avx512f detected).
        SimdLevel::Avx512 => unsafe { simd::avx512::dot_mul(x, y, len) },
        #[cfg(target_arch = "aarch64")]
        // Safety: AdvSIMD is mandatory on aarch64.
        SimdLevel::Neon => unsafe { simd::neon::dot_mul(x, y, len) },
        SimdLevel::ScalarFma => simd::scalar_fma::dot_mul(x, y, len),
        _ => dot_mul_scalar(x, y, len),
    }
}

/// Rows per band when splitting `rows` of `work_per_row` flops across the
/// pool. Depends only on the problem size — never on the pool width — and
/// is a multiple of 4 so every band starts on a 4-row tile boundary: the
/// banded computation groups rows exactly like the unbanded one, making
/// results bit-identical at every thread count (including `threads=1`,
/// where the same bands simply run inline).
fn band_rows(work_per_row: usize) -> usize {
    let by_work = (par_min_work() / work_per_row.max(1)).max(8);
    by_work.div_ceil(4) * 4
}

// ---------------------------------------------------------------------------
// Mat wrappers
// ---------------------------------------------------------------------------

/// C = A · B.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_acc(a, b, &mut c);
    c
}

/// C += A · B into preallocated storage, pool-banded, at an explicit
/// dispatch level — the bench/CI hook for before/after SIMD comparisons.
/// Operands that exceed the level's tuned cache panels take the packed
/// blocked path (bitwise identical to the banded one — see the blocking
/// rules in the module doc).
pub fn matmul_acc_at(level: SimdLevel, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "matmul_acc inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_acc out shape");
    let (m, kk, n) = (a.rows, a.cols, b.cols);
    let blk = tune::gemm_blocking(level);
    if (kk > blk.kc || n > blk.nc) && m * kk * n >= par_min_work() {
        matmul_acc_with_blocking(level, blk, a, b, c);
    } else if m > 4 && m * kk * n >= par_min_work() {
        let band = band_rows(kk * n);
        let chunks = m.div_ceil(band);
        let cptr = SendPtr(c.data.as_mut_ptr());
        pool::global().parallel_for(chunks, |ci| {
            let r0 = ci * band;
            let r1 = (r0 + band).min(m);
            // Safety: bands partition C's rows; chunk ci touches only its band.
            let cb = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), (r1 - r0) * n) };
            gemm_acc_slices_at(level, &a.data[r0 * kk..r1 * kk], r1 - r0, kk, &b.data, n, cb);
        });
    } else {
        gemm_acc_slices_at(level, &a.data, m, kk, &b.data, n, &mut c.data);
    }
}

/// C += A · B through the cache-blocked engine at an explicit blocking —
/// the autotuner's forced entry point (it must not consult the profile it
/// is producing). `blk` is clamped onto the determinism-safe grid; any
/// blocking on that grid yields bitwise-identical results at every thread
/// count within a dispatch level.
///
/// Structure: for each NC-column panel of B, pack the panel once
/// (serially), then split C's rows into MC bands (multiples of 4) across
/// the pool; each band gathers its C panel into scratch, walks K in KC
/// blocks packing the matching A sub-block, runs the register-tiled kernel
/// on the packed operands, and scatters the C panel back. Every operand
/// the inner kernel touches is a dense pack sized to stay cache-resident.
pub fn matmul_acc_with_blocking(level: SimdLevel, blk: GemmBlocking, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "matmul_acc inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_acc out shape");
    let (m, kk, n) = (a.rows, a.cols, b.cols);
    if m == 0 || kk == 0 || n == 0 {
        return;
    }
    // No small-product shortcut: this is a forced entry point (dispatch
    // size-gates before routing here), and tests/the tuner rely on it
    // always exercising the blocked engine.
    let blk = blk.validated();
    let (mc, kc, nc) = (blk.mc, blk.kc, blk.nc);
    let bands = m.div_ceil(mc);
    let cptr = SendPtr(c.data.as_mut_ptr());
    let mut bpack = vec![0.0f32; kk * nc.min(n)];
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + nc).min(n);
        let wpan = j1 - j0;
        // Pack B columns [j0, j1) row-major (pure copy; KC sub-ranges of
        // the pack stay contiguous at row offset l·wpan).
        for l in 0..kk {
            bpack[l * wpan..(l + 1) * wpan].copy_from_slice(&b.data[l * n + j0..l * n + j1]);
        }
        let bpanel = &bpack[..kk * wpan];
        pool::global().parallel_for(bands, |bi| {
            let r0 = bi * mc;
            let r1 = (r0 + mc).min(m);
            let rows = r1 - r0;
            // Gather this band's C panel into scratch (pure copy).
            // Safety: bands partition C's rows; band bi touches only
            // rows [r0, r1) within column panel [j0, j1).
            let mut ybuf = Scratch::take(rows * wpan);
            for (ri, r) in (r0..r1).enumerate() {
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        cptr.0.add(r * n + j0).cast_const(),
                        ybuf.as_mut_ptr().add(ri * wpan),
                        wpan,
                    );
                }
            }
            // Walk K in KC blocks: one fused update per element per inner
            // step regardless of where a block boundary falls, so this is
            // bitwise equal to streaming the whole K extent.
            let mut abuf = Scratch::take(rows * kc.min(kk));
            let mut l0 = 0;
            while l0 < kk {
                let l1 = (l0 + kc).min(kk);
                let kcur = l1 - l0;
                for (ri, r) in (r0..r1).enumerate() {
                    abuf[ri * kcur..(ri + 1) * kcur]
                        .copy_from_slice(&a.data[r * kk + l0..r * kk + l1]);
                }
                gemm_acc_slices_at(
                    level,
                    &abuf,
                    rows,
                    kcur,
                    &bpanel[l0 * wpan..l1 * wpan],
                    wpan,
                    &mut ybuf,
                );
                l0 = l1;
            }
            // Scatter the finished C panel back (pure copy).
            for (ri, r) in (r0..r1).enumerate() {
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        ybuf.as_ptr().add(ri * wpan),
                        cptr.0.add(r * n + j0),
                        wpan,
                    );
                }
            }
        });
        j0 = j1;
    }
}

/// C += A · B into preallocated storage (C must be zeroed by the caller if a
/// fresh product is wanted).
pub fn matmul_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    matmul_acc_at(simd::active(), a, b, c)
}

/// C = A · B into preallocated storage at an explicit dispatch level.
pub fn matmul_into_at(level: SimdLevel, a: &Mat, b: &Mat, c: &mut Mat) {
    c.data.fill(0.0);
    matmul_acc_at(level, a, b, c);
}

/// C = A · B into preallocated storage.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    matmul_into_at(simd::active(), a, b, c)
}

/// C = Aᵀ · B without forming Aᵀ.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.cols, b.cols);
    matmul_at_b_into(a, b, &mut c);
    c
}

/// C = Aᵀ · B into preallocated storage (hot path of Eq. 5 — avoids one
/// allocation per PTC block per iteration). When K exceeds the tuned KC
/// panel, the contraction walks K in KC blocks over naturally contiguous
/// sub-slices of A's `[kk×m]` and B's `[kk×n]` storage — no packing
/// needed, and bitwise-safe because KC is a multiple of 4 (the kernel's
/// inner-step quads stay aligned; see the blocking rules in the module
/// doc).
pub fn matmul_at_b_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows, b.rows, "matmul_at_b inner dim");
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "matmul_at_b out shape");
    let level = simd::active();
    let (kk, m, n) = (a.rows, a.cols, b.cols);
    let kc = tune::gemm_blocking(level).kc;
    debug_assert_eq!(kc % 4, 0, "KC must stay on the quad grid");
    let at_b_blocked = |r0: usize, r1: usize, cb: &mut [f32]| {
        cb.fill(0.0);
        let mut l0 = 0;
        while l0 < kk {
            let l1 = (l0 + kc).min(kk);
            gemm_at_b_acc_band_at(
                level,
                &a.data[l0 * m..l1 * m],
                l1 - l0,
                m,
                &b.data[l0 * n..l1 * n],
                n,
                r0,
                r1,
                cb,
            );
            l0 = l1;
        }
    };
    if m > 4 && m * kk * n >= par_min_work() {
        let band = band_rows(kk * n);
        let chunks = m.div_ceil(band);
        let cptr = SendPtr(c.data.as_mut_ptr());
        pool::global().parallel_for(chunks, |ci| {
            let r0 = ci * band;
            let r1 = (r0 + band).min(m);
            let cb = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), (r1 - r0) * n) };
            at_b_blocked(r0, r1, cb);
        });
    } else {
        at_b_blocked(0, m, &mut c.data);
    }
}

/// C = A · Bᵀ without forming Bᵀ.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_a_bt inner dim");
    let mut c = Mat::zeros(a.rows, b.rows);
    matmul_a_bt_acc(a, b, &mut c);
    c
}

/// C = A · Bᵀ into preallocated storage.
pub fn matmul_a_bt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt_into inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_a_bt_into out shape");
    c.data.fill(0.0);
    matmul_a_bt_acc(a, b, c);
}

/// C += A · Bᵀ into preallocated storage — the weight-gradient accumulator
/// (dW += dy·xᵀ) without the per-step temporary. Deliberately *not*
/// K-blocked: each output element is one whole-K accumulator chain in the
/// kernel, so splitting K would change the summation order (and the use
/// sites contract over small batch dimensions anyway). M-banding remains
/// bitwise-safe.
pub fn matmul_a_bt_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt_acc inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_a_bt_acc out shape");
    let level = simd::active();
    let (m, kk, p) = (a.rows, a.cols, b.rows);
    if m > 4 && m * kk * p >= par_min_work() {
        let band = band_rows(kk * p);
        let chunks = m.div_ceil(band);
        let cptr = SendPtr(c.data.as_mut_ptr());
        pool::global().parallel_for(chunks, |ci| {
            let r0 = ci * band;
            let r1 = (r0 + band).min(m);
            let cb = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * p), (r1 - r0) * p) };
            gemm_a_bt_acc_slices_at(level, &a.data[r0 * kk..r1 * kk], r1 - r0, kk, &b.data, p, cb);
        });
    } else {
        gemm_a_bt_acc_slices_at(level, &a.data, m, kk, &b.data, p, &mut c.data);
    }
}

/// Eq. 5 inner kernel over raw k×B panels at an explicit dispatch level:
/// acc[i] += scale · Σ_b (Uᵀ·dy)[i,b] ⊙ (V·x)[i,b], with caller-provided
/// scratch for the two intermediate k×B products.
#[allow(clippy::too_many_arguments)]
pub fn sigma_grad_block_slices_at(
    level: SimdLevel,
    u: &Mat,
    v: &Mat,
    dy_panel: &[f32],
    x_panel: &[f32],
    b: usize,
    scale: f32,
    ut_y: &mut [f32],
    vx: &mut [f32],
    acc: &mut [f32],
) {
    let k = u.rows;
    debug_assert!(dy_panel.len() >= k * b && x_panel.len() >= k * b);
    debug_assert!(ut_y.len() >= k * b && vx.len() >= k * b && acc.len() >= k);
    ut_y[..k * b].fill(0.0);
    gemm_at_b_acc_band_at(level, &u.data, k, k, dy_panel, b, 0, k, ut_y);
    vx[..k * b].fill(0.0);
    gemm_acc_slices_at(level, &v.data, k, k, x_panel, b, vx);
    for (i, g) in acc.iter_mut().enumerate().take(k) {
        let s = dot_mul_at(level, &ut_y[i * b..(i + 1) * b], &vx[i * b..(i + 1) * b], b);
        *g += s * scale;
    }
}

/// Eq. 5 inner kernel at the process-wide dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn sigma_grad_block_slices(
    u: &Mat,
    v: &Mat,
    dy_panel: &[f32],
    x_panel: &[f32],
    b: usize,
    scale: f32,
    ut_y: &mut [f32],
    vx: &mut [f32],
    acc: &mut [f32],
) {
    sigma_grad_block_slices_at(simd::active(), u, v, dy_panel, x_panel, b, scale, ut_y, vx, acc)
}

/// Hot-path helper for Eq. 5 with `Mat` scratch (kept for compatibility —
/// see `sigma_grad_block_slices` for the allocation-free panel form).
#[allow(clippy::too_many_arguments)]
pub fn sigma_grad_block(
    u: &Mat,
    v: &Mat,
    y: &Mat,
    x: &Mat,
    scale: f32,
    ut_y: &mut Mat,
    vx: &mut Mat,
    acc: &mut [f32],
) {
    let b = y.cols;
    sigma_grad_block_slices(u, v, &y.data, &x.data, b, scale, &mut ut_y.data, &mut vx.data, acc);
}

/// y = A · x for a dense vector.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len(), "matvec dim");
    let mut y = vec![0.0f32; a.rows];
    for i in 0..a.rows {
        let row = a.row(i);
        let mut s = 0.0f32;
        for (r, v) in row.iter().zip(x) {
            s += r * v;
        }
        y[i] = s;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, quickcheck};
    use crate::util::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn small_exact() {
        let a = Mat::from_slice(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_slice(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(6, 6, 1.0, &mut rng);
        let c = matmul(&a, &Mat::eye(6));
        assert_close(&c.data, &a.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn prop_matches_naive() {
        quickcheck(
            "matmul == naive",
            |rng, size| {
                let m = 1 + size % 12;
                let k = 1 + (size / 2) % 9;
                let n = 1 + (size / 3) % 14;
                (Mat::randn(m, k, 1.0, rng), Mat::randn(k, n, 1.0, rng))
            },
            |(a, b)| {
                assert_close(&matmul(a, b).data, &naive(a, b).data, 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn prop_transposed_variants() {
        quickcheck(
            "at_b and a_bt match explicit transpose",
            |rng, size| {
                let m = 1 + size % 10;
                let k = 1 + (size / 2) % 10;
                let n = 1 + (size / 3) % 10;
                (Mat::randn(k, m, 1.0, rng), Mat::randn(k, n, 1.0, rng), Mat::randn(m, n, 1.0, rng))
            },
            |(a, b, d)| {
                assert_close(&matmul_at_b(a, b).data, &matmul(&a.t(), b).data, 1e-4, 1e-4)?;
                assert_close(&matmul_a_bt(d, b).data, &matmul(d, &b.t()).data, 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn large_products_match_naive() {
        // Big enough to take the pool-banded path at any thread count.
        let mut rng = Rng::new(77);
        let a = Mat::randn(97, 53, 1.0, &mut rng);
        let b = Mat::randn(53, 61, 1.0, &mut rng);
        assert_close(&matmul(&a, &b).data, &naive(&a, &b).data, 1e-3, 1e-3).unwrap();
        let at = Mat::randn(53, 97, 1.0, &mut rng);
        assert_close(&matmul_at_b(&at, &b).data, &matmul(&at.t(), &b).data, 1e-3, 1e-3).unwrap();
        let bt = Mat::randn(61, 53, 1.0, &mut rng);
        assert_close(&matmul_a_bt(&a, &bt).data, &matmul(&a, &bt.t()).data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        let x: Vec<f32> = (0..5).map(|i| i as f32 - 2.0).collect();
        let xm = Mat::from_slice(5, 1, &x);
        let y = matvec(&a, &x);
        assert_close(&y, &matmul(&a, &xm).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn acc_accumulates() {
        let a = Mat::eye(3);
        let b = Mat::eye(3);
        let mut c = Mat::eye(3);
        matmul_acc(&a, &b, &mut c);
        assert_eq!(c.diagonal(), vec![2.0; 3]);
    }

    #[test]
    fn at_b_into_matches_fresh() {
        let mut rng = Rng::new(31);
        let a = Mat::randn(5, 4, 1.0, &mut rng);
        let b = Mat::randn(5, 3, 1.0, &mut rng);
        let fresh = matmul_at_b(&a, &b);
        let mut c = Mat::zeros(4, 3);
        c.data.fill(7.0);
        matmul_at_b_into(&a, &b, &mut c);
        assert_close(&fresh.data, &c.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn a_bt_into_and_acc_match_fresh() {
        let mut rng = Rng::new(33);
        let a = Mat::randn(6, 5, 1.0, &mut rng);
        let b = Mat::randn(4, 5, 1.0, &mut rng);
        let fresh = matmul_a_bt(&a, &b);
        let mut c = Mat::zeros(6, 4);
        c.data.fill(3.0);
        matmul_a_bt_into(&a, &b, &mut c);
        assert_close(&fresh.data, &c.data, 1e-6, 1e-6).unwrap();
        // acc: run twice over zeros == 2× the fresh product.
        let mut c2 = Mat::zeros(6, 4);
        matmul_a_bt_acc(&a, &b, &mut c2);
        matmul_a_bt_acc(&a, &b, &mut c2);
        let twice: Vec<f32> = fresh.data.iter().map(|v| 2.0 * v).collect();
        assert_close(&twice, &c2.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn a_bt_zero_rows_are_skipped_exactly() {
        let mut rng = Rng::new(34);
        let mut a = Mat::randn(5, 7, 1.0, &mut rng);
        for v in a.row_mut(2) {
            *v = 0.0;
        }
        let b = Mat::randn(6, 7, 1.0, &mut rng);
        let c = matmul_a_bt(&a, &b);
        assert!(c.row(2).iter().all(|&v| v == 0.0));
        assert_close(&c.data, &matmul(&a, &b.t()).data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn sigma_grad_block_matches_naive() {
        let mut rng = Rng::new(32);
        let (k, b) = (4, 6);
        let u = Mat::randn(k, k, 1.0, &mut rng);
        let v = Mat::randn(k, k, 1.0, &mut rng);
        let y = Mat::randn(k, b, 1.0, &mut rng);
        let x = Mat::randn(k, b, 1.0, &mut rng);
        let ut_y_ref = matmul_at_b(&u, &y);
        let vx_ref = matmul(&v, &x);
        let mut want = vec![0.5f32; k];
        for i in 0..k {
            let mut s = 0.0;
            for bb in 0..b {
                s += ut_y_ref[(i, bb)] * vx_ref[(i, bb)];
            }
            want[i] += 2.0 * s;
        }
        let mut got = vec![0.5f32; k];
        let mut s1 = Mat::zeros(k, b);
        let mut s2 = Mat::zeros(k, b);
        sigma_grad_block(&u, &v, &y, &x, 2.0, &mut s1, &mut s2, &mut got);
        assert_close(&want, &got, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn slice_kernels_respect_band_windows() {
        let mut rng = Rng::new(35);
        let (kk, m, n) = (9, 13, 8);
        let a = Mat::randn(kk, m, 1.0, &mut rng);
        let b = Mat::randn(kk, n, 1.0, &mut rng);
        let full = matmul_at_b(&a, &b);
        // Reassemble from two bands.
        let mid = 5;
        let mut lo = vec![0.0f32; mid * n];
        let mut hi = vec![0.0f32; (m - mid) * n];
        gemm_at_b_acc_band(&a.data, kk, m, &b.data, n, 0, mid, &mut lo);
        gemm_at_b_acc_band(&a.data, kk, m, &b.data, n, mid, m, &mut hi);
        let mut joined = lo;
        joined.extend_from_slice(&hi);
        assert_close(&joined, &full.data, 1e-6, 1e-6).unwrap();
    }

    // ---------------------------------------------------------------
    // SIMD dispatch
    // ---------------------------------------------------------------

    /// Random shapes that cover pure-tail (< 8 lanes), mixed, and
    /// multi-lane bodies plus odd row counts around the 4-row tiles.
    fn simd_case(rng: &mut Rng, size: usize) -> (Mat, Mat, Mat) {
        let m = 1 + size % 13;
        let k = 1 + (size / 2) % 21;
        let n = 1 + (size / 3) % 19;
        (Mat::randn(m, k, 1.0, rng), Mat::randn(k, n, 1.0, rng), Mat::randn(n, k, 1.0, rng))
    }

    /// Every level that can run on this host, scalar excluded.
    fn other_levels() -> Vec<SimdLevel> {
        SimdLevel::ALL
            .into_iter()
            .filter(|l| *l != SimdLevel::Scalar && l.available())
            .collect()
    }

    #[test]
    fn prop_vector_kernels_match_scalar() {
        let levels = other_levels();
        if levels.is_empty() {
            return; // nothing to compare on this CPU
        }
        quickcheck(
            "non-scalar kernels ≈ scalar kernels",
            |rng, size| simd_case(rng, size),
            |(a, b, bt)| {
                let (m, k, n) = (a.rows, a.cols, b.cols);
                for &level in &other_levels() {
                    let tag = level.name();
                    // A·B
                    let mut cs = vec![0.1f32; m * n];
                    let mut cv = vec![0.1f32; m * n];
                    gemm_acc_slices_at(SimdLevel::Scalar, &a.data, m, k, &b.data, n, &mut cs);
                    gemm_acc_slices_at(level, &a.data, m, k, &b.data, n, &mut cv);
                    assert_close(&cs, &cv, 1e-4, 1e-4).map_err(|e| format!("[{tag}] A·B: {e}"))?;
                    // Aᵀ·B: reinterpret a's [m·k] storage as a [k×m] operand
                    // so it contracts against b's k rows (output rows 0..m).
                    let mut ds = vec![0.2f32; m * n];
                    let mut dv = vec![0.2f32; m * n];
                    gemm_at_b_acc_band_at(
                        SimdLevel::Scalar,
                        &a.data,
                        k,
                        m,
                        &b.data,
                        n,
                        0,
                        m,
                        &mut ds,
                    );
                    gemm_at_b_acc_band_at(level, &a.data, k, m, &b.data, n, 0, m, &mut dv);
                    assert_close(&ds, &dv, 1e-4, 1e-4)
                        .map_err(|e| format!("[{tag}] Aᵀ·B: {e}"))?;
                    // A·Bᵀ
                    let p = bt.rows;
                    let mut es = vec![0.3f32; m * p];
                    let mut ev = vec![0.3f32; m * p];
                    gemm_a_bt_acc_slices_at(
                        SimdLevel::Scalar,
                        &a.data,
                        m,
                        k,
                        &bt.data,
                        p,
                        &mut es,
                    );
                    gemm_a_bt_acc_slices_at(level, &a.data, m, k, &bt.data, p, &mut ev);
                    assert_close(&es, &ev, 1e-4, 1e-4)
                        .map_err(|e| format!("[{tag}] A·Bᵀ: {e}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_level_preserves_zero_skip_exactness() {
        let mut rng = Rng::new(36);
        let mut a = Mat::randn(6, 9, 1.0, &mut rng);
        for v in a.row_mut(3) {
            *v = 0.0;
        }
        let b = Mat::randn(5, 9, 1.0, &mut rng);
        for level in SimdLevel::ALL {
            if !level.available() {
                continue;
            }
            let mut c = vec![0.0f32; 6 * 5];
            gemm_a_bt_acc_slices_at(level, &a.data, 6, 9, &b.data, 5, &mut c);
            assert!(
                c[3 * 5..4 * 5].iter().all(|&v| v == 0.0),
                "[{}] zero row must be skipped",
                level.name()
            );
        }
    }

    #[test]
    fn dot_mul_levels_agree() {
        let x: Vec<f32> = (0..23).map(|i| 0.5 - 0.1 * i as f32).collect();
        let y: Vec<f32> = (0..23).map(|i| 0.2 * i as f32 - 1.0).collect();
        let s = dot_mul_at(SimdLevel::Scalar, &x, &y, 23);
        for level in other_levels() {
            let v = dot_mul_at(level, &x, &y, 23);
            assert!((s - v).abs() < 1e-4 * (1.0 + s.abs()), "[{}] {s} vs {v}", level.name());
        }
        // Scalar path is the exact sequential sum.
        let mut want = 0.0f32;
        for (a, b) in x.iter().zip(&y) {
            want += a * b;
        }
        assert_eq!(s, want);
    }

    // ---------------------------------------------------------------
    // Cache blocking
    // ---------------------------------------------------------------

    #[test]
    fn prop_blocked_matmul_is_bitwise_equal_to_direct() {
        // Deliberately tiny panels so even modest shapes split into many
        // MC/KC/NC blocks; the packed blocked engine must reproduce the
        // one-shot kernel bit for bit at every available level.
        let blockings = [
            GemmBlocking { mc: 8, kc: 8, nc: 16 },
            GemmBlocking { mc: 12, kc: 20, nc: 32 },
            GemmBlocking { mc: 64, kc: 256, nc: 256 },
        ];
        quickcheck(
            "blocked == direct (bitwise)",
            |rng, size| {
                let m = 1 + size % 23;
                let k = 1 + (size / 2) % 37;
                let n = 1 + (size / 3) % 29;
                (Mat::randn(m, k, 1.0, rng), Mat::randn(k, n, 1.0, rng))
            },
            |(a, b)| {
                let (m, k, n) = (a.rows, a.cols, b.cols);
                for level in SimdLevel::ALL {
                    if !level.available() {
                        continue;
                    }
                    let mut direct = Mat::zeros(m, n);
                    direct.data.fill(0.25);
                    gemm_acc_slices_at(level, &a.data, m, k, &b.data, n, &mut direct.data);
                    for blk in blockings {
                        let mut blocked = Mat::zeros(m, n);
                        blocked.data.fill(0.25);
                        matmul_acc_with_blocking(level, blk, &a, &b, &mut blocked);
                        if blocked.data != direct.data {
                            return Err(format!(
                                "[{}] blocking {blk:?} changed bits at {m}x{k}x{n}",
                                level.name()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn blocked_path_forced_large_is_bitwise_equal() {
        // Big enough that matmul_acc_with_blocking really takes the packed
        // parallel path (above par_min_work) and splits on all three axes.
        let mut rng = Rng::new(41);
        let (m, k, n) = (70, 90, 110);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let mut direct = Mat::zeros(m, n);
        gemm_acc_slices_at(SimdLevel::Scalar, &a.data, m, k, &b.data, n, &mut direct.data);
        let mut blocked = Mat::zeros(m, n);
        let blk = GemmBlocking { mc: 16, kc: 32, nc: 48 };
        matmul_acc_with_blocking(SimdLevel::Scalar, blk, &a, &b, &mut blocked);
        assert_eq!(blocked.data, direct.data, "blocked scalar engine must keep seed numerics");
    }

    #[test]
    fn at_b_kc_blocking_is_bitwise_safe() {
        // matmul_at_b_into walks K in KC blocks when K exceeds the tuned
        // panel; reassembling from any multiple-of-4 split must reproduce
        // the unsplit kernel bit for bit (quads stay aligned).
        let mut rng = Rng::new(42);
        let (kk, m, n) = (37, 11, 9);
        let a = Mat::randn(kk, m, 1.0, &mut rng);
        let b = Mat::randn(kk, n, 1.0, &mut rng);
        for level in SimdLevel::ALL {
            if !level.available() {
                continue;
            }
            let mut full = vec![0.0f32; m * n];
            gemm_at_b_acc_band_at(level, &a.data, kk, m, &b.data, n, 0, m, &mut full);
            for kc in [4usize, 8, 16, 24] {
                let mut split = vec![0.0f32; m * n];
                let mut l0 = 0;
                while l0 < kk {
                    let l1 = (l0 + kc).min(kk);
                    gemm_at_b_acc_band_at(
                        level,
                        &a.data[l0 * m..l1 * m],
                        l1 - l0,
                        m,
                        &b.data[l0 * n..l1 * n],
                        n,
                        0,
                        m,
                        &mut split,
                    );
                    l0 = l1;
                }
                assert_eq!(split, full, "[{}] kc={kc} changed Aᵀ·B bits", level.name());
            }
        }
    }
}
