//! GEMM micro-kernels. `matmul` is the native simulator's hot path: it uses a
//! cache-blocked loop order (i-k-j) with the inner j-loop auto-vectorizable,
//! which is the standard roofline-friendly layout for row-major operands.
//! Variants for Aᵀ·B and A·Bᵀ avoid materializing transposes on the
//! backward pass.

use super::mat::Mat;

/// C = A · B.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C += A · B into preallocated storage (C must be zeroed by the caller if a
/// fresh product is wanted).
pub fn matmul_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "matmul_acc inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_acc out shape");
    let n = b.cols;
    for i in 0..a.rows {
        let a_row = a.row(i);
        let c_row = c.row_mut(i);
        for (kk, &aik) in a_row.iter().enumerate() {
            if aik == 0.0 {
                continue; // structured sparsity fast path (masked feedback)
            }
            let b_row = &b.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                c_row[j] += aik * b_row[j];
            }
        }
    }
}

/// C = A · B into preallocated storage.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    c.data.fill(0.0);
    matmul_acc(a, b, c);
}

/// C = Aᵀ · B without forming Aᵀ.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.cols, b.cols);
    matmul_at_b_into(a, b, &mut c);
    c
}

/// C = Aᵀ · B into preallocated storage (hot path of Eq. 5 — avoids one
/// allocation per PTC block per iteration).
pub fn matmul_at_b_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows, b.rows, "matmul_at_b inner dim");
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "matmul_at_b out shape");
    c.data.fill(0.0);
    let n = b.cols;
    for kk in 0..a.rows {
        let a_row = a.row(kk);
        let b_row = b.row(kk);
        for (i, &aki) in a_row.iter().enumerate() {
            if aki == 0.0 {
                continue;
            }
            let c_row = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                c_row[j] += aki * b_row[j];
            }
        }
    }
}

/// C = A · Bᵀ without forming Bᵀ.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_a_bt inner dim");
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let a_row = a.row(i);
        for j in 0..b.rows {
            let b_row = b.row(j);
            let mut s = 0.0f32;
            for (x, y) in a_row.iter().zip(b_row) {
                s += x * y;
            }
            c[(i, j)] = s;
        }
    }
    c
}

/// Hot-path helper for Eq. 5: acc[i] += scale · Σ_b (Aᵀ·Y)[i,b] ⊙ (V·X)[i,b]
/// computed with preallocated scratch (`ut_y`, `vx`).
pub fn sigma_grad_block(
    u: &Mat,
    v: &Mat,
    y: &Mat,
    x: &Mat,
    scale: f32,
    ut_y: &mut Mat,
    vx: &mut Mat,
    acc: &mut [f32],
) {
    matmul_at_b_into(u, y, ut_y);
    matmul_into(v, x, vx);
    let b = y.cols;
    for (i, g) in acc.iter_mut().enumerate() {
        let ar = &ut_y.data[i * b..(i + 1) * b];
        let cr = &vx.data[i * b..(i + 1) * b];
        let mut s = 0.0f32;
        for (p, q) in ar.iter().zip(cr) {
            s += p * q;
        }
        *g += s * scale;
    }
}

/// y = A · x for a dense vector.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len(), "matvec dim");
    let mut y = vec![0.0f32; a.rows];
    for i in 0..a.rows {
        let row = a.row(i);
        let mut s = 0.0f32;
        for (r, v) in row.iter().zip(x) {
            s += r * v;
        }
        y[i] = s;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, quickcheck};
    use crate::util::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn small_exact() {
        let a = Mat::from_slice(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_slice(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(6, 6, 1.0, &mut rng);
        let c = matmul(&a, &Mat::eye(6));
        assert_close(&c.data, &a.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn prop_matches_naive() {
        quickcheck(
            "matmul == naive",
            |rng, size| {
                let m = 1 + size % 12;
                let k = 1 + (size / 2) % 9;
                let n = 1 + (size / 3) % 14;
                (Mat::randn(m, k, 1.0, rng), Mat::randn(k, n, 1.0, rng))
            },
            |(a, b)| {
                assert_close(&matmul(a, b).data, &naive(a, b).data, 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn prop_transposed_variants() {
        quickcheck(
            "at_b and a_bt match explicit transpose",
            |rng, size| {
                let m = 1 + size % 10;
                let k = 1 + (size / 2) % 10;
                let n = 1 + (size / 3) % 10;
                (Mat::randn(k, m, 1.0, rng), Mat::randn(k, n, 1.0, rng), Mat::randn(m, n, 1.0, rng))
            },
            |(a, b, d)| {
                assert_close(&matmul_at_b(a, b).data, &matmul(&a.t(), b).data, 1e-4, 1e-4)?;
                assert_close(&matmul_a_bt(d, b).data, &matmul(d, &b.t()).data, 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        let x: Vec<f32> = (0..5).map(|i| i as f32 - 2.0).collect();
        let xm = Mat::from_slice(5, 1, &x);
        let y = matvec(&a, &x);
        assert_close(&y, &matmul(&a, &xm).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn acc_accumulates() {
        let a = Mat::eye(3);
        let b = Mat::eye(3);
        let mut c = Mat::eye(3);
        matmul_acc(&a, &b, &mut c);
        assert_eq!(c.diagonal(), vec![2.0; 3]);
    }

    #[test]
    fn at_b_into_matches_fresh() {
        let mut rng = Rng::new(31);
        let a = Mat::randn(5, 4, 1.0, &mut rng);
        let b = Mat::randn(5, 3, 1.0, &mut rng);
        let fresh = matmul_at_b(&a, &b);
        let mut c = Mat::zeros(4, 3);
        c.data.fill(7.0);
        matmul_at_b_into(&a, &b, &mut c);
        assert_close(&fresh.data, &c.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn sigma_grad_block_matches_naive() {
        let mut rng = Rng::new(32);
        let (k, b) = (4, 6);
        let u = Mat::randn(k, k, 1.0, &mut rng);
        let v = Mat::randn(k, k, 1.0, &mut rng);
        let y = Mat::randn(k, b, 1.0, &mut rng);
        let x = Mat::randn(k, b, 1.0, &mut rng);
        let ut_y_ref = matmul_at_b(&u, &y);
        let vx_ref = matmul(&v, &x);
        let mut want = vec![0.5f32; k];
        for i in 0..k {
            let mut s = 0.0;
            for bb in 0..b {
                s += ut_y_ref[(i, bb)] * vx_ref[(i, bb)];
            }
            want[i] += 2.0 * s;
        }
        let mut got = vec![0.5f32; k];
        let mut s1 = Mat::zeros(k, b);
        let mut s2 = Mat::zeros(k, b);
        sigma_grad_block(&u, &v, &y, &x, 2.0, &mut s1, &mut s2, &mut got);
        assert_close(&want, &got, 1e-5, 1e-5).unwrap();
    }
}
