//! One-sided Jacobi SVD for the small k×k photonic blocks (k ≤ 32 in all
//! experiments — Appendix F block-size study). One-sided Jacobi is the right
//! tool here: simple, branch-light, and accurate to ~1e-6 for tiny
//! well-scaled matrices, with no external LAPACK available offline.
//!
//! Returns W = U · diag(s) · Vᵀ with U, V orthogonal (real unitary) and
//! s ≥ 0 sorted descending — the convention the PTC parametrization expects.

use super::gemm::matmul;
use super::mat::Mat;

/// SVD factors.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub vt: Mat,
}

impl Svd {
    /// Reconstruct U · diag(s) · Vᵀ.
    pub fn reconstruct(&self) -> Mat {
        let mut us = self.u.clone();
        for r in 0..us.rows {
            for c in 0..us.cols {
                us[(r, c)] *= self.s[c];
            }
        }
        matmul(&us, &self.vt)
    }
}

/// One-sided Jacobi SVD of a square matrix.
///
/// Works on A's columns: rotates column pairs until all pairs are orthogonal;
/// then column norms are the singular values, normalized columns are U, and
/// the accumulated rotations are V.
pub fn svd_kxk(a: &Mat) -> Svd {
    assert_eq!(a.rows, a.cols, "svd_kxk expects square blocks");
    let n = a.rows;
    // Work in f64 for the rotations: the k×k blocks can be ill-conditioned
    // after noise injection and f32 Jacobi stalls near convergence.
    let mut w: Vec<f64> = a.data.iter().map(|&x| x as f64).collect(); // row-major
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for r in 0..n {
                    let wp = w[r * n + p];
                    let wq = w[r * n + q];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation that annihilates the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for r in 0..n {
                    let wp = w[r * n + p];
                    let wq = w[r * n + q];
                    w[r * n + p] = c * wp - s * wq;
                    w[r * n + q] = s * wp + c * wq;
                    let vp = v[r * n + p];
                    let vq = v[r * n + q];
                    v[r * n + p] = c * vp - s * vq;
                    v[r * n + q] = s * vp + c * vq;
                }
            }
        }
        if off.sqrt() < 1e-13 {
            break;
        }
    }

    // Column norms -> singular values; normalize columns -> U.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigma = vec![0.0f64; n];
    for (j, sj) in sigma.iter_mut().enumerate() {
        *sj = (0..n).map(|r| w[r * n + j] * w[r * n + j]).sum::<f64>().sqrt();
    }
    order.sort_by(|&i, &j| sigma[j].partial_cmp(&sigma[i]).unwrap());

    let mut u = Mat::zeros(n, n);
    let mut vt = Mat::zeros(n, n);
    let mut s = vec![0.0f32; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let sj = sigma[old_j];
        s[new_j] = sj as f32;
        if sj > 1e-100 {
            for r in 0..n {
                u[(r, new_j)] = (w[r * n + old_j] / sj) as f32;
            }
        } else {
            // Null column: complete to an orthonormal basis below.
            u[(new_j.min(n - 1), new_j)] = 1.0;
        }
        for r in 0..n {
            vt[(new_j, r)] = v[r * n + old_j] as f32;
        }
    }
    // Re-orthonormalize U against earlier columns in the rank-deficient case
    // (modified Gram-Schmidt; a no-op for full-rank inputs).
    gram_schmidt_columns(&mut u);
    Svd { u, s, vt }
}

fn gram_schmidt_columns(m: &mut Mat) {
    let n = m.rows;
    for j in 0..n {
        for i in 0..j {
            let dot: f32 = (0..n).map(|r| m[(r, i)] * m[(r, j)]).sum();
            if dot.abs() > 1e-6 {
                for r in 0..n {
                    let mi = m[(r, i)];
                    m[(r, j)] -= dot * mi;
                }
            }
        }
        let norm: f32 = (0..n).map(|r| m[(r, j)] * m[(r, j)]).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for r in 0..n {
                m[(r, j)] /= norm;
            }
        } else {
            // Choose any vector orthogonal to the previous columns.
            for cand in 0..n {
                for r in 0..n {
                    m[(r, j)] = if r == cand { 1.0 } else { 0.0 };
                }
                for i in 0..j {
                    let dot: f32 = (0..n).map(|r| m[(r, i)] * m[(r, j)]).sum();
                    for r in 0..n {
                        let mi = m[(r, i)];
                        m[(r, j)] -= dot * mi;
                    }
                }
                let nn: f32 = (0..n).map(|r| m[(r, j)] * m[(r, j)]).sum::<f32>().sqrt();
                if nn > 1e-6 {
                    for r in 0..n {
                        m[(r, j)] /= nn;
                    }
                    break;
                }
            }
        }
    }
}

/// Check a square matrix for orthogonality: ‖MᵀM − I‖∞.
pub fn orthogonality_error(m: &Mat) -> f32 {
    let g = super::gemm::matmul_at_b(m, m);
    let mut err = 0.0f32;
    for r in 0..g.rows {
        for c in 0..g.cols {
            let target = if r == c { 1.0 } else { 0.0 };
            err = err.max((g[(r, c)] - target).abs());
        }
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, quickcheck};
    use crate::util::Rng;

    #[test]
    fn svd_identity() {
        let svd = svd_kxk(&Mat::eye(5));
        assert_close(&svd.s, &[1.0; 5], 1e-6, 1e-6).unwrap();
        assert_close(&svd.reconstruct().data, &Mat::eye(5).data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn svd_diagonal_sorted() {
        let a = Mat::diag(&[2.0, 5.0, 1.0]);
        let svd = svd_kxk(&a);
        assert_close(&svd.s, &[5.0, 2.0, 1.0], 1e-5, 1e-5).unwrap();
        assert_close(&svd.reconstruct().data, &a.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn prop_reconstruction_and_orthogonality() {
        quickcheck(
            "svd reconstructs and factors are orthogonal",
            |rng, size| {
                let n = 2 + size % 15; // up to 16, covers the paper's 9
                Mat::randn(n, n, 1.0, rng)
            },
            |a| {
                let svd = svd_kxk(a);
                assert_close(&svd.reconstruct().data, &a.data, 2e-4, 2e-4)?;
                if orthogonality_error(&svd.u) > 1e-4 {
                    return Err(format!("U not orthogonal: {}", orthogonality_error(&svd.u)));
                }
                if orthogonality_error(&svd.vt) > 1e-4 {
                    return Err(format!("Vt not orthogonal: {}", orthogonality_error(&svd.vt)));
                }
                for w in svd.s.windows(2) {
                    if w[0] < w[1] - 1e-6 {
                        return Err(format!("singular values not sorted: {:?}", svd.s));
                    }
                }
                if svd.s.iter().any(|&s| s < -1e-7) {
                    return Err("negative singular value".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rank_deficient() {
        // Rank-1 matrix: one nonzero singular value, U still orthogonal.
        let mut a = Mat::zeros(4, 4);
        for r in 0..4 {
            for c in 0..4 {
                a[(r, c)] = ((r + 1) * (c + 1)) as f32;
            }
        }
        let svd = svd_kxk(&a);
        assert!(svd.s[0] > 1.0);
        assert!(svd.s[1].abs() < 1e-4, "s = {:?}", svd.s);
        assert!(orthogonality_error(&svd.u) < 1e-4);
        assert_close(&svd.reconstruct().data, &a.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn k9_block_accuracy() {
        // The exact configuration used everywhere in the paper.
        let mut rng = Rng::new(99);
        for _ in 0..20 {
            let a = Mat::randn(9, 9, 0.3, &mut rng);
            let svd = svd_kxk(&a);
            let err = svd.reconstruct().sub(&a).fro_norm() / a.fro_norm();
            assert!(err < 1e-5, "relative error {err}");
        }
    }
}
