//! Per-host autotuner for the GEMM engine's cache-blocking and the fused
//! conv path's panel width.
//!
//! The cache-blocked wrappers in [`super::gemm`] and the packed-panel conv
//! path in [`super::conv`] are **bitwise invariant to their tile sizes**
//! within a SIMD dispatch level (see the blocking rules in `gemm`'s module
//! doc), which makes tile choice a pure performance knob — safe to vary
//! per host without touching goldens or the determinism suites. This
//! module owns that knob:
//!
//! * [`GemmBlocking`] — the (MC, KC, NC) panel sizes consulted by
//!   `matmul_acc_at` / `matmul_at_b_into`, clamped to the
//!   determinism-safe grid (MC and KC multiples of 4).
//! * A JSON **profile** (`L2IGHT_TUNE_PROFILE`, default
//!   `l2ight_tune.json` in the working directory) holding one tuning per
//!   level, loaded lazily at the first dispatch consult. No file → the
//!   compiled-in defaults. `L2IGHT_TUNE=auto` additionally runs a quick
//!   tune at first use and saves the profile.
//! * [`tune_host`] — the tuner itself: times the `perf_hotpath`
//!   square-GEMM ladder shape and the fused-conv microbench under
//!   candidate blockings/panel widths per available level (through the
//!   forced-blocking entry points, so tuning never consults the profile
//!   it is producing) and returns the winning profile plus a
//!   machine-readable report for `BENCH_perf_hotpath.json`. Driven by
//!   `l2ight tune [--quick]`.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;
use std::time::Instant;

use super::conv::{conv2d_forward_packed_with, Conv2dShape, PANEL_COLS};
use super::gemm::matmul_acc_with_blocking;
use super::mat::Mat;
use super::simd::{self, SimdLevel};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::Rng;

/// Env var naming the profile file consulted by dispatch.
pub const PROFILE_ENV: &str = "L2IGHT_TUNE_PROFILE";

/// Default profile file name (working directory) when the env var is unset.
pub const DEFAULT_PROFILE_FILE: &str = "l2ight_tune.json";

/// Cache-blocking panel sizes for the A·B wrapper: C is computed in
/// MC-row × NC-column tiles, contracting KC inner steps per packed pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmBlocking {
    /// Row-band height (A block rows). Multiple of 4 — the kernels tile 4
    /// C rows per pass, and band starts must stay on tile boundaries.
    pub mc: usize,
    /// Inner-dimension panel depth. Multiple of 4 — the Aᵀ·B kernel
    /// consumes quads of inner steps, and splitting K mid-quad would
    /// change its accumulation chains.
    pub kc: usize,
    /// Column-panel width of packed B. Any positive size: every kernel
    /// applies one fused op per element per inner step regardless of where
    /// the vector body ends, so column splits never move numerics.
    pub nc: usize,
}

impl Default for GemmBlocking {
    fn default() -> GemmBlocking {
        GemmBlocking { mc: 64, kc: 256, nc: 256 }
    }
}

impl GemmBlocking {
    /// Clamp onto the determinism-safe grid: `mc`/`kc` to multiples of 4
    /// (≥ 8), `nc` ≥ 16. Out-of-grid profile values are usable after this —
    /// the caller warns, we never reject a profile outright.
    pub fn validated(self) -> GemmBlocking {
        GemmBlocking {
            mc: (self.mc.max(8) / 4) * 4,
            kc: (self.kc.max(8) / 4) * 4,
            nc: self.nc.max(16),
        }
    }

    /// True when the blocking already sits on the determinism-safe grid.
    pub fn is_valid(self) -> bool {
        self == self.validated()
    }
}

/// One level's tuning: GEMM blocking plus the packed-conv panel width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LevelTuning {
    pub blocking: GemmBlocking,
    pub panel_cols: usize,
}

impl Default for LevelTuning {
    fn default() -> LevelTuning {
        LevelTuning { blocking: GemmBlocking::default(), panel_cols: PANEL_COLS }
    }
}

/// A per-host tuning profile: one optional [`LevelTuning`] per
/// [`SimdLevel`], plus the pool work-split threshold. Untuned levels fall
/// back to the compiled-in defaults.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    /// Pool width the profile was tuned at (diagnostic only).
    pub threads: usize,
    /// Whether the quick candidate grid produced this profile.
    pub quick: bool,
    /// Override for `pool::par_min_work` (None → compiled-in default).
    pub par_min_work: Option<usize>,
    levels: [Option<LevelTuning>; SimdLevel::ALL.len()],
}

fn level_idx(level: SimdLevel) -> usize {
    SimdLevel::ALL.iter().position(|&l| l == level).expect("level in ALL")
}

impl Profile {
    /// The tuning recorded for `level`, if any.
    pub fn level(&self, level: SimdLevel) -> Option<LevelTuning> {
        self.levels[level_idx(level)]
    }

    /// Record a tuning for `level` (clamped to the safe grid).
    pub fn set_level(&mut self, level: SimdLevel, t: LevelTuning) {
        let t = LevelTuning { blocking: t.blocking.validated(), panel_cols: t.panel_cols.max(8) };
        self.levels[level_idx(level)] = Some(t);
    }

    /// Push process-wide knobs (the pool threshold) from this profile.
    fn apply_process_knobs(&self) {
        if let Some(w) = self.par_min_work {
            pool::set_par_min_work(w);
        }
    }

    /// Serialize (stable key order via `util::json`).
    pub fn to_json(&self) -> Json {
        let mut levels = Json::obj();
        for level in SimdLevel::ALL {
            if let Some(t) = self.level(level) {
                let mut o = Json::obj();
                o.set("mc", t.blocking.mc.into())
                    .set("kc", t.blocking.kc.into())
                    .set("nc", t.blocking.nc.into())
                    .set("panel_cols", t.panel_cols.into());
                levels.set(level.name(), o);
            }
        }
        let mut root = Json::obj();
        root.set("schema", 1usize.into())
            .set("tuner", "l2ight tune".into())
            .set("quick", self.quick.into())
            .set("threads", self.threads.into())
            .set("levels", levels);
        if let Some(w) = self.par_min_work {
            root.set("par_min_work", w.into());
        }
        root
    }

    /// Deserialize, clamping out-of-grid blockings (with a warning) rather
    /// than rejecting — a hand-edited profile should degrade gracefully.
    pub fn from_json(v: &Json) -> Result<Profile, String> {
        let schema = v.get("schema").and_then(Json::as_usize).unwrap_or(0);
        if schema != 1 {
            return Err(format!("unsupported tune profile schema {schema} (want 1)"));
        }
        let mut p = Profile {
            threads: v.get("threads").and_then(Json::as_usize).unwrap_or(0),
            quick: v.get("quick").and_then(Json::as_bool).unwrap_or(false),
            par_min_work: v.get("par_min_work").and_then(Json::as_usize),
            levels: Default::default(),
        };
        let levels = v.get("levels").and_then(Json::as_obj).ok_or("missing levels object")?;
        for (name, o) in levels {
            let Some(level) = SimdLevel::parse(name) else {
                crate::warn!("tune profile: ignoring unknown level {name:?}");
                continue;
            };
            let field = |k: &str, dflt: usize| o.get(k).and_then(Json::as_usize).unwrap_or(dflt);
            let d = GemmBlocking::default();
            let blocking =
                GemmBlocking { mc: field("mc", d.mc), kc: field("kc", d.kc), nc: field("nc", d.nc) };
            if !blocking.is_valid() {
                crate::warn!(
                    "tune profile: {} blocking {:?} off the determinism-safe grid; clamping to {:?}",
                    level.name(),
                    blocking,
                    blocking.validated()
                );
            }
            p.set_level(
                level,
                LevelTuning { blocking, panel_cols: field("panel_cols", PANEL_COLS) },
            );
        }
        Ok(p)
    }
}

/// The profile file consulted by dispatch: `$L2IGHT_TUNE_PROFILE`, else
/// `l2ight_tune.json` in the working directory.
pub fn profile_path() -> PathBuf {
    match std::env::var(PROFILE_ENV) {
        Ok(p) if !p.trim().is_empty() => PathBuf::from(p),
        _ => PathBuf::from(DEFAULT_PROFILE_FILE),
    }
}

/// Load a profile from `path`.
pub fn load_profile(path: &Path) -> Result<Profile, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let v = Json::parse(&text).map_err(|e| format!("parse {path:?}: {e}"))?;
    Profile::from_json(&v)
}

/// Save a profile to `path` (pretty-printed, stable key order).
pub fn save_profile(p: &Profile, path: &Path) -> Result<(), String> {
    let mut text = p.to_json().pretty();
    text.push('\n');
    std::fs::write(path, text).map_err(|e| format!("write {path:?}: {e}"))
}

/// The process-wide installed profile, resolved once: the profile file if
/// present, else (with `L2IGHT_TUNE=auto`) a fresh quick tune saved back to
/// the file, else compiled-in defaults. Every kernel call inside the tuner
/// goes through the forced-blocking entry points, so first-use tuning never
/// re-enters this initializer.
pub fn installed() -> &'static Profile {
    static PROFILE: OnceLock<Profile> = OnceLock::new();
    PROFILE.get_or_init(|| {
        let path = profile_path();
        if path.exists() {
            match load_profile(&path) {
                Ok(p) => {
                    p.apply_process_knobs();
                    return p;
                }
                Err(e) => {
                    crate::warn!("ignoring tune profile: {e}; using default blocking");
                    return Profile::default();
                }
            }
        }
        let auto = std::env::var("L2IGHT_TUNE")
            .map(|v| v.trim().eq_ignore_ascii_case("auto"))
            .unwrap_or(false);
        if auto {
            crate::warn!(
                "L2IGHT_TUNE=auto and no profile at {path:?}: running quick tune (one-time)"
            );
            let (p, _report) = tune_host(true);
            if let Err(e) = save_profile(&p, &path) {
                crate::warn!("could not save tune profile: {e}");
            }
            p.apply_process_knobs();
            return p;
        }
        Profile::default()
    })
}

/// GEMM blocking for `level`: the installed profile's choice, or defaults.
pub fn gemm_blocking(level: SimdLevel) -> GemmBlocking {
    installed().level(level).map(|t| t.blocking).unwrap_or_default()
}

/// Packed-path panel width for `level`: profile choice, or [`PANEL_COLS`].
pub fn panel_cols_for(level: SimdLevel) -> usize {
    installed().level(level).map(|t| t.panel_cols).unwrap_or(PANEL_COLS)
}

/// Packed-path panel width at the process-wide dispatch level — the value
/// the mesh/shard/conv packed paths consume.
pub fn panel_cols() -> usize {
    panel_cols_for(simd::active())
}

// ---------------------------------------------------------------------------
// The tuner
// ---------------------------------------------------------------------------

/// The fused-conv microbench shape (`benches/perf_hotpath.rs` "conv fwd
/// fused b8c16x16 k3").
fn conv_bench_shape() -> Conv2dShape {
    Conv2dShape {
        batch: 8,
        in_ch: 16,
        in_h: 16,
        in_w: 16,
        out_ch: 32,
        kernel: 3,
        stride: 1,
        padding: 1,
    }
}

fn blocking_candidates(quick: bool) -> Vec<GemmBlocking> {
    let mut c = vec![
        GemmBlocking { mc: 32, kc: 128, nc: 256 },
        GemmBlocking { mc: 64, kc: 256, nc: 256 },
        GemmBlocking { mc: 64, kc: 256, nc: 512 },
        GemmBlocking { mc: 128, kc: 256, nc: 256 },
        GemmBlocking { mc: 64, kc: 512, nc: 256 },
    ];
    if !quick {
        c.push(GemmBlocking { mc: 128, kc: 512, nc: 512 });
        c.push(GemmBlocking { mc: 256, kc: 128, nc: 512 });
        c.push(GemmBlocking { mc: 32, kc: 512, nc: 128 });
    }
    c
}

fn panel_candidates(quick: bool) -> Vec<usize> {
    if quick {
        vec![64, 128, 256]
    } else {
        vec![48, 64, 128, 192, 256, 384]
    }
}

/// Median wall time of `reps` calls to `f`, after one warm-up call.
fn median_ns<F: FnMut()>(reps: usize, mut f: F) -> u64 {
    f();
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    samples[samples.len() / 2]
}

/// Tune every available level on this host and return the winning profile
/// plus a report object for `BENCH_perf_hotpath.json`. `quick` shrinks the
/// ladder shape and candidate grid (CI smoke); the full grid is what
/// `l2ight tune` runs on a bench host.
pub fn tune_host(quick: bool) -> (Profile, Json) {
    let threads = pool::global().threads();
    let mut profile = Profile {
        threads,
        quick,
        par_min_work: Some(pool::par_min_work()),
        levels: Default::default(),
    };

    let s = if quick { 256 } else { 512 };
    let reps = if quick { 3 } else { 5 };
    let mut rng = Rng::new(0x7u64);
    let a = Mat::randn(s, s, 1.0, &mut rng);
    let b = Mat::randn(s, s, 1.0, &mut rng);
    let mut c = Mat::zeros(s, s);

    let sh = conv_bench_shape();
    let n_in = sh.batch * sh.in_ch * sh.in_h * sh.in_w;
    let input: Vec<f32> = (0..n_in).map(|_| rng.normal() as f32).collect();
    let w = Mat::randn(sh.out_ch, sh.patch_rows(), 0.5, &mut rng);

    let mut report_levels = Json::obj();
    let mut hot_paths = Vec::new();
    for level in SimdLevel::ALL {
        if !level.available() {
            continue;
        }
        // GEMM: time the default blocking (the "before"), then every
        // candidate; keep the fastest.
        let default_ns = median_ns(reps, || {
            c.data.fill(0.0);
            matmul_acc_with_blocking(level, GemmBlocking::default(), &a, &b, &mut c);
        });
        let mut best = (GemmBlocking::default(), default_ns);
        for cand in blocking_candidates(quick) {
            let ns = median_ns(reps, || {
                c.data.fill(0.0);
                matmul_acc_with_blocking(level, cand, &a, &b, &mut c);
            });
            if ns < best.1 {
                best = (cand, ns);
            }
        }

        // Conv panel width: default first, then candidates.
        let conv_default_ns = median_ns(reps, || {
            let _ = conv2d_forward_packed_with(level, pool::global(), PANEL_COLS, &w, &input, &sh);
        });
        let mut best_panel = (PANEL_COLS, conv_default_ns);
        for pc in panel_candidates(quick) {
            let ns = median_ns(reps, || {
                let _ = conv2d_forward_packed_with(level, pool::global(), pc, &w, &input, &sh);
            });
            if ns < best_panel.1 {
                best_panel = (pc, ns);
            }
        }

        profile.set_level(level, LevelTuning { blocking: best.0, panel_cols: best_panel.0 });

        let mut gemm_rep = Json::obj();
        gemm_rep
            .set("default_ns", (default_ns as usize).into())
            .set("tuned_ns", (best.1 as usize).into())
            .set("mc", best.0.mc.into())
            .set("kc", best.0.kc.into())
            .set("nc", best.0.nc.into());
        let mut conv_rep = Json::obj();
        conv_rep
            .set("default_ns", (conv_default_ns as usize).into())
            .set("tuned_ns", (best_panel.1 as usize).into())
            .set("panel_cols", best_panel.0.into());
        let mut lv = Json::obj();
        lv.set("gemm", gemm_rep).set("conv", conv_rep);
        report_levels.set(level.name(), lv);

        for (name, ns) in [
            (format!("tune gemm {s}x{s}x{s} default [{}]", level.name()), default_ns),
            (format!("tune gemm {s}x{s}x{s} tuned [{}]", level.name()), best.1),
            (format!("tune conv fwd fused b8c16x16 k3 default [{}]", level.name()), conv_default_ns),
            (format!("tune conv fwd fused b8c16x16 k3 tuned [{}]", level.name()), best_panel.1),
        ] {
            let mut hp = Json::obj();
            hp.set("name", name.into()).set("median_ns", (ns as usize).into());
            hot_paths.push(hp);
        }
    }

    let mut report = Json::obj();
    report
        .set("event", "tune".into())
        .set("quick", quick.into())
        .set("threads", threads.into())
        .set("simd", simd::active().name().into())
        .set("gemm_shape", s.into())
        .set("levels", report_levels)
        .set("hot_paths", Json::Arr(hot_paths));
    (profile, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocking_validation_clamps_to_safe_grid() {
        let b = GemmBlocking { mc: 7, kc: 130, nc: 3 }.validated();
        assert_eq!(b, GemmBlocking { mc: 8, kc: 128, nc: 16 });
        assert_eq!(b.mc % 4, 0);
        assert_eq!(b.kc % 4, 0);
        assert!(GemmBlocking::default().is_valid());
    }

    #[test]
    fn profile_json_round_trips() {
        let mut p = Profile { threads: 4, quick: true, par_min_work: Some(4096), ..Default::default() };
        p.set_level(
            SimdLevel::Avx2,
            LevelTuning { blocking: GemmBlocking { mc: 128, kc: 512, nc: 256 }, panel_cols: 192 },
        );
        p.set_level(SimdLevel::Scalar, LevelTuning::default());
        let back = Profile::from_json(&Json::parse(&p.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back.threads, 4);
        assert!(back.quick);
        assert_eq!(back.par_min_work, Some(4096));
        assert_eq!(back.level(SimdLevel::Avx2), p.level(SimdLevel::Avx2));
        assert_eq!(back.level(SimdLevel::Scalar), p.level(SimdLevel::Scalar));
        assert_eq!(back.level(SimdLevel::Neon), None);
    }

    #[test]
    fn profile_clamps_bad_values_instead_of_failing() {
        let text = r#"{"schema": 1, "levels": {"scalar": {"mc": 6, "kc": 10, "nc": 1, "panel_cols": 2}, "not-a-level": {"mc": 4}}}"#;
        let p = Profile::from_json(&Json::parse(text).unwrap()).unwrap();
        let t = p.level(SimdLevel::Scalar).unwrap();
        assert_eq!(t.blocking.mc % 4, 0);
        assert_eq!(t.blocking.kc % 4, 0);
        assert!(t.blocking.nc >= 16 && t.panel_cols >= 8);
    }

    #[test]
    fn unknown_schema_is_rejected() {
        let v = Json::parse(r#"{"schema": 9, "levels": {}}"#).unwrap();
        assert!(Profile::from_json(&v).is_err());
    }

    #[test]
    fn untuned_levels_fall_back_to_defaults() {
        let p = Profile::default();
        assert_eq!(p.level(SimdLevel::Avx512), None);
        // Accessors never panic for any level.
        for level in SimdLevel::ALL {
            let _ = gemm_blocking(level);
            let _ = panel_cols_for(level);
        }
        assert!(panel_cols() >= 8);
    }
}
