//! im2col / col2im lowering for the convolution layers. The ONN executes
//! convolutions as blocked matrix multiplications over flattened patches
//! (paper §3.4.2 Figure 9), so the sampling machinery (column sampling CS vs
//! spatial sampling SS) operates directly on the im2col layout produced here.

use super::mat::Mat;

/// Static shape of a conv2d: NCHW input, OIHW kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dShape {
    pub batch: usize,
    pub in_ch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_ch: usize,
    pub kernel: usize,
    pub stride: usize,
    pub padding: usize,
}

impl Conv2dShape {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.padding - self.kernel) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.padding - self.kernel) / self.stride + 1
    }
    /// Rows of the im2col patch matrix: Cin·K².
    pub fn patch_rows(&self) -> usize {
        self.in_ch * self.kernel * self.kernel
    }
    /// Columns of the im2col patch matrix: B·H'·W'.
    pub fn patch_cols(&self) -> usize {
        self.batch * self.out_h() * self.out_w()
    }
}

/// Unfold an NCHW input (flattened) into the patch matrix X of shape
/// [Cin·K², B·H'·W']; column index is b·(H'·W') + oh·W' + ow.
pub fn im2col(input: &[f32], sh: &Conv2dShape) -> Mat {
    assert_eq!(input.len(), sh.batch * sh.in_ch * sh.in_h * sh.in_w, "im2col input size");
    let (oh, ow) = (sh.out_h(), sh.out_w());
    let mut x = Mat::zeros(sh.patch_rows(), sh.patch_cols());
    let hw = sh.in_h * sh.in_w;
    for b in 0..sh.batch {
        for c in 0..sh.in_ch {
            let plane = &input[(b * sh.in_ch + c) * hw..(b * sh.in_ch + c + 1) * hw];
            for kr in 0..sh.kernel {
                for kc in 0..sh.kernel {
                    let row = (c * sh.kernel + kr) * sh.kernel + kc;
                    for o_r in 0..oh {
                        let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                        for o_c in 0..ow {
                            let ic = (o_c * sh.stride + kc) as isize - sh.padding as isize;
                            let col = b * (oh * ow) + o_r * ow + o_c;
                            let v = if ir >= 0
                                && (ir as usize) < sh.in_h
                                && ic >= 0
                                && (ic as usize) < sh.in_w
                            {
                                plane[ir as usize * sh.in_w + ic as usize]
                            } else {
                                0.0
                            };
                            x[(row, col)] = v;
                        }
                    }
                }
            }
        }
    }
    x
}

/// Fold the patch-matrix gradient back to the NCHW input gradient
/// (adjoint of `im2col`: overlapping patches accumulate).
pub fn col2im(cols: &Mat, sh: &Conv2dShape) -> Vec<f32> {
    assert_eq!(cols.rows, sh.patch_rows(), "col2im rows");
    assert_eq!(cols.cols, sh.patch_cols(), "col2im cols");
    let (oh, ow) = (sh.out_h(), sh.out_w());
    let hw = sh.in_h * sh.in_w;
    let mut out = vec![0.0f32; sh.batch * sh.in_ch * hw];
    for b in 0..sh.batch {
        for c in 0..sh.in_ch {
            let base = (b * sh.in_ch + c) * hw;
            for kr in 0..sh.kernel {
                for kc in 0..sh.kernel {
                    let row = (c * sh.kernel + kr) * sh.kernel + kc;
                    for o_r in 0..oh {
                        let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                        if ir < 0 || ir as usize >= sh.in_h {
                            continue;
                        }
                        for o_c in 0..ow {
                            let ic = (o_c * sh.stride + kc) as isize - sh.padding as isize;
                            if ic < 0 || ic as usize >= sh.in_w {
                                continue;
                            }
                            let col = b * (oh * ow) + o_r * ow + o_c;
                            out[base + ir as usize * sh.in_w + ic as usize] += cols[(row, col)];
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quickcheck;
    use crate::util::Rng;

    fn shape(b: usize, c: usize, h: usize, k: usize, s: usize, p: usize) -> Conv2dShape {
        Conv2dShape { batch: b, in_ch: c, in_h: h, in_w: h, out_ch: 1, kernel: k, stride: s, padding: p }
    }

    #[test]
    fn identity_1x1() {
        // 1x1 kernel stride 1: im2col is a reshape.
        let sh = shape(1, 2, 3, 1, 1, 0);
        let input: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let x = im2col(&input, &sh);
        assert_eq!(x.rows, 2);
        assert_eq!(x.cols, 9);
        assert_eq!(x.row(0), &input[0..9]);
        assert_eq!(x.row(1), &input[9..18]);
    }

    #[test]
    fn known_3x3() {
        // Single 3x3 plane, 2x2 kernel, stride 1, no padding -> 4 patches.
        let sh = shape(1, 1, 3, 2, 1, 0);
        let input: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let x = im2col(&input, &sh);
        assert_eq!((x.rows, x.cols), (4, 4));
        // Patch at (0,0) is [1,2,4,5] read down the column.
        let col0: Vec<f32> = (0..4).map(|r| x[(r, 0)]).collect();
        assert_eq!(col0, vec![1.0, 2.0, 4.0, 5.0]);
        let col3: Vec<f32> = (0..4).map(|r| x[(r, 3)]).collect();
        assert_eq!(col3, vec![5.0, 6.0, 8.0, 9.0]);
    }

    #[test]
    fn padding_zeroes_border() {
        let sh = shape(1, 1, 2, 3, 1, 1);
        let input = vec![1.0, 2.0, 3.0, 4.0];
        let x = im2col(&input, &sh);
        assert_eq!((x.rows, x.cols), (9, 4));
        // Top-left patch centered at (0,0): first row/col of the 3x3 window
        // falls outside -> zeros.
        assert_eq!(x[(0, 0)], 0.0);
        assert_eq!(x[(4, 0)], 1.0); // center
    }

    #[test]
    fn conv_as_gemm_matches_direct() {
        // conv(input, kern) via im2col+GEMM == direct nested-loop conv.
        let mut rng = Rng::new(7);
        let sh = Conv2dShape {
            batch: 2, in_ch: 3, in_h: 5, in_w: 5, out_ch: 4, kernel: 3, stride: 2, padding: 1,
        };
        let input: Vec<f32> = (0..sh.batch * sh.in_ch * 25).map(|_| rng.normal() as f32).collect();
        let kern: Vec<f32> =
            (0..sh.out_ch * sh.in_ch * 9).map(|_| rng.normal() as f32).collect();
        let x = im2col(&input, &sh);
        let w = Mat::from_slice(sh.out_ch, sh.patch_rows(), &kern);
        let y = crate::linalg::matmul(&w, &x);
        // Direct conv.
        let (oh, ow) = (sh.out_h(), sh.out_w());
        for b in 0..sh.batch {
            for oc in 0..sh.out_ch {
                for o_r in 0..oh {
                    for o_c in 0..ow {
                        let mut s = 0.0f32;
                        for ic in 0..sh.in_ch {
                            for kr in 0..3 {
                                for kc in 0..3 {
                                    let ir = (o_r * 2 + kr) as isize - 1;
                                    let icol = (o_c * 2 + kc) as isize - 1;
                                    if ir >= 0 && ir < 5 && icol >= 0 && icol < 5 {
                                        s += input[((b * sh.in_ch + ic) * 5 + ir as usize) * 5
                                            + icol as usize]
                                            * kern[((oc * sh.in_ch + ic) * 3 + kr) * 3 + kc];
                                    }
                                }
                            }
                        }
                        let col = b * (oh * ow) + o_r * ow + o_c;
                        assert!((y[(oc, col)] - s).abs() < 1e-4, "mismatch at {b},{oc},{o_r},{o_c}");
                    }
                }
            }
        }
    }

    #[test]
    fn prop_col2im_is_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> — the defining adjoint property.
        quickcheck(
            "col2im adjoint of im2col",
            |rng, size| {
                let h = 3 + size % 5;
                let k = 1 + size % 3;
                let sh = Conv2dShape {
                    batch: 1 + size % 2,
                    in_ch: 1 + size % 3,
                    in_h: h,
                    in_w: h,
                    out_ch: 1,
                    kernel: k.min(h),
                    stride: 1 + size % 2,
                    padding: size % 2,
                };
                let n_in = sh.batch * sh.in_ch * h * h;
                let x: Vec<f32> = (0..n_in).map(|_| rng.normal() as f32).collect();
                let y = Mat::randn(sh.patch_rows(), sh.patch_cols(), 1.0, rng);
                (sh, x, y)
            },
            |(sh, x, y)| {
                let xi = im2col(x, sh);
                let lhs: f32 = xi.data.iter().zip(&y.data).map(|(a, b)| a * b).sum();
                let back = col2im(y, sh);
                let rhs: f32 = x.iter().zip(&back).map(|(a, b)| a * b).sum();
                if (lhs - rhs).abs() > 1e-3 * (1.0 + lhs.abs()) {
                    return Err(format!("adjoint mismatch {lhs} vs {rhs}"));
                }
                Ok(())
            },
        );
    }
}
