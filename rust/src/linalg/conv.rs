//! im2col / col2im lowering for the convolution layers. The ONN executes
//! convolutions as blocked matrix multiplications over flattened patches
//! (paper §3.4.2 Figure 9), so the sampling machinery (column sampling CS vs
//! spatial sampling SS) operates directly on the im2col layout produced here.
//!
//! §Perf — two execution strategies share one layout:
//!
//! * **Fused packed-panel** ([`PatchExtractor`] + [`gemm_packed_panels`],
//!   [`conv2d_forward_packed`], `PtcMesh::forward_packed_on`) — the forward
//!   path. Fixed-width column panels of the logical patch matrix are
//!   extracted *directly into pool scratch GEMM packing buffers* and
//!   consumed immediately by the tiled kernels: the `[Cin·K², B·H'·W']`
//!   intermediate is never materialized. Panels have a fixed width
//!   ([`PANEL_COLS`]), independent of the pool, so results are bitwise
//!   thread-count-invariant; within a SIMD dispatch level the values equal
//!   the eager `im2col` + GEMM reference (the per-element accumulation
//!   order over the inner dimension is identical).
//! * **Eager pooled** ([`im2col_pooled`] / [`col2im_pooled`]) — the
//!   backward path, where the σ-gradient API consumes a whole patch matrix.
//!   Parallel pack / per-plane parallel fold, bitwise identical to the
//!   serial [`im2col`] / [`col2im`] reference (pure gather; per-plane
//!   accumulation order preserved). The patch matrix exists only for the
//!   lifetime of one backward call.

use super::gemm::gemm_acc_slices_at;
use super::mat::Mat;
use super::simd::{self, SimdLevel};
use crate::util::pool::{self, Scratch, SendPtr, ThreadPool};

/// Default column-panel width of the fused packed-panel path. The width
/// actually used may come from the autotuner profile
/// ([`super::tune::panel_cols_for`]) but is never derived from the pool
/// width, so the panel partition is identical at every thread count — and
/// the kernels apply one fused op per element per inner step regardless of
/// where a panel boundary falls, so *any* width yields bitwise-identical
/// results within a dispatch level (pinned by
/// `tests/fused_conv_equivalence.rs`).
pub const PANEL_COLS: usize = 128;

/// Static shape of a conv2d: NCHW input, OIHW kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dShape {
    pub batch: usize,
    pub in_ch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_ch: usize,
    pub kernel: usize,
    pub stride: usize,
    pub padding: usize,
}

impl Conv2dShape {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.padding - self.kernel) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.padding - self.kernel) / self.stride + 1
    }
    /// Rows of the im2col patch matrix: Cin·K².
    pub fn patch_rows(&self) -> usize {
        self.in_ch * self.kernel * self.kernel
    }
    /// Columns of the im2col patch matrix: B·H'·W'.
    pub fn patch_cols(&self) -> usize {
        self.batch * self.out_h() * self.out_w()
    }
}

/// Unfold an NCHW input (flattened) into the patch matrix X of shape
/// [Cin·K², B·H'·W']; column index is b·(H'·W') + oh·W' + ow.
///
/// Serial reference implementation — the hot paths use [`im2col_pooled`]
/// (bitwise identical) or skip materialization entirely via
/// [`PatchExtractor`].
pub fn im2col(input: &[f32], sh: &Conv2dShape) -> Mat {
    assert_eq!(input.len(), sh.batch * sh.in_ch * sh.in_h * sh.in_w, "im2col input size");
    let (oh, ow) = (sh.out_h(), sh.out_w());
    let mut x = Mat::zeros(sh.patch_rows(), sh.patch_cols());
    let hw = sh.in_h * sh.in_w;
    for b in 0..sh.batch {
        for c in 0..sh.in_ch {
            let plane = &input[(b * sh.in_ch + c) * hw..(b * sh.in_ch + c + 1) * hw];
            for kr in 0..sh.kernel {
                for kc in 0..sh.kernel {
                    let row = (c * sh.kernel + kr) * sh.kernel + kc;
                    for o_r in 0..oh {
                        let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                        for o_c in 0..ow {
                            let ic = (o_c * sh.stride + kc) as isize - sh.padding as isize;
                            let col = b * (oh * ow) + o_r * ow + o_c;
                            let v = if ir >= 0
                                && (ir as usize) < sh.in_h
                                && ic >= 0
                                && (ic as usize) < sh.in_w
                            {
                                plane[ir as usize * sh.in_w + ic as usize]
                            } else {
                                0.0
                            };
                            x[(row, col)] = v;
                        }
                    }
                }
            }
        }
    }
    x
}

/// Fold the patch-matrix gradient back to the NCHW input gradient
/// (adjoint of `im2col`: overlapping patches accumulate).
///
/// Serial reference implementation — the hot paths use [`col2im_pooled`]
/// (bitwise identical).
pub fn col2im(cols: &Mat, sh: &Conv2dShape) -> Vec<f32> {
    assert_eq!(cols.rows, sh.patch_rows(), "col2im rows");
    assert_eq!(cols.cols, sh.patch_cols(), "col2im cols");
    let (oh, ow) = (sh.out_h(), sh.out_w());
    let hw = sh.in_h * sh.in_w;
    let mut out = vec![0.0f32; sh.batch * sh.in_ch * hw];
    for b in 0..sh.batch {
        for c in 0..sh.in_ch {
            let base = (b * sh.in_ch + c) * hw;
            for kr in 0..sh.kernel {
                for kc in 0..sh.kernel {
                    let row = (c * sh.kernel + kr) * sh.kernel + kc;
                    for o_r in 0..oh {
                        let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                        if ir < 0 || ir as usize >= sh.in_h {
                            continue;
                        }
                        for o_c in 0..ow {
                            let ic = (o_c * sh.stride + kc) as isize - sh.padding as isize;
                            if ic < 0 || ic as usize >= sh.in_w {
                                continue;
                            }
                            let col = b * (oh * ow) + o_r * ow + o_c;
                            out[base + ir as usize * sh.in_w + ic as usize] += cols[(row, col)];
                        }
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fused packed-panel path
// ---------------------------------------------------------------------------

/// Scatter a packed row-major panel (`rows` rows of width `wpan`) into
/// columns `[c0, c0+wpan)` of a row-major destination with row stride
/// `total_cols` — the single home of the packed paths' column-scatter
/// (used by [`gemm_packed_panels_at`], [`im2col_pooled_on`], and
/// `PtcMesh::forward_packed_on`).
///
/// # Safety
/// The caller must own columns `[c0, c0+wpan)` of every destination row
/// exclusively for the duration of the call (the panel-partition argument
/// of the packed paths), and the destination allocation must cover
/// `rows · total_cols` elements.
pub(crate) unsafe fn scatter_panel(
    dst: SendPtr<f32>,
    total_cols: usize,
    c0: usize,
    wpan: usize,
    rows: usize,
    src: &[f32],
) {
    debug_assert!(src.len() >= rows * wpan && c0 + wpan <= total_cols);
    for r in 0..rows {
        std::ptr::copy_nonoverlapping(
            src[r * wpan..].as_ptr(),
            dst.0.add(r * total_cols + c0),
            wpan,
        );
    }
}

/// On-demand patch-panel extractor: produces column sub-panels of the
/// logical im2col matrix without ever materializing it. The values written
/// are exactly those of [`im2col`] restricted to the requested column range
/// (a pure gather — no arithmetic), so every consumer inherits im2col's
/// numerics verbatim.
pub struct PatchExtractor<'a> {
    input: &'a [f32],
    sh: Conv2dShape,
}

impl<'a> PatchExtractor<'a> {
    pub fn new(input: &'a [f32], sh: &Conv2dShape) -> PatchExtractor<'a> {
        assert_eq!(
            input.len(),
            sh.batch * sh.in_ch * sh.in_h * sh.in_w,
            "PatchExtractor input size"
        );
        PatchExtractor { input, sh: *sh }
    }

    /// Write columns `[c0, c1)` of the patch matrix into `dst`, row-major
    /// with row stride `c1 - c0`. `dst` must be pre-zeroed (the extractor
    /// only writes in-bounds input values; padding positions — and any rows
    /// past `patch_rows` in an over-tall buffer — stay zero, which is how
    /// the mesh path fuses its `q·k` row padding for free).
    ///
    /// Iteration is grouped into runs of output pixels sharing `(b, o_r)`,
    /// so stride-1 convolutions degrade to `copy_from_slice` per kernel
    /// tap — patch extraction is memcpy-bound, not index-arithmetic-bound.
    pub fn pack_into(&self, c0: usize, c1: usize, dst: &mut [f32]) {
        let sh = &self.sh;
        let (oh, ow) = (sh.out_h(), sh.out_w());
        let ohw = oh * ow;
        let wpan = c1 - c0;
        debug_assert!(c1 <= sh.patch_cols() && dst.len() >= sh.patch_rows() * wpan);
        let hw = sh.in_h * sh.in_w;
        let kk = sh.kernel;
        let mut col = c0;
        while col < c1 {
            let b = col / ohw;
            let rem = col - b * ohw;
            let o_r = rem / ow;
            let o_c0 = rem - o_r * ow;
            // Columns [col, col+run) share (b, o_r) and walk o_c contiguously.
            let run = (ow - o_c0).min(c1 - col);
            let d0 = col - c0;
            for c in 0..sh.in_ch {
                let plane = &self.input[(b * sh.in_ch + c) * hw..(b * sh.in_ch + c + 1) * hw];
                for kr in 0..kk {
                    let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                    if ir < 0 || ir as usize >= sh.in_h {
                        continue; // whole tap row out of bounds → stays zero
                    }
                    let irow = &plane[ir as usize * sh.in_w..(ir as usize + 1) * sh.in_w];
                    for kc in 0..kk {
                        let row = (c * kk + kr) * kk + kc;
                        let drow = &mut dst[row * wpan + d0..row * wpan + d0 + run];
                        if sh.stride == 1 {
                            // ic = o_c0 + j + kc - padding: one contiguous
                            // in-bounds segment per (kr, kc).
                            let ic0 = o_c0 as isize + kc as isize - sh.padding as isize;
                            let j_lo = (-ic0).max(0) as usize;
                            let j_hi = (sh.in_w as isize - ic0).clamp(0, run as isize) as usize;
                            if j_lo < j_hi {
                                let s0 = (ic0 + j_lo as isize) as usize;
                                drow[j_lo..j_hi]
                                    .copy_from_slice(&irow[s0..s0 + (j_hi - j_lo)]);
                            }
                        } else {
                            for (j, d) in drow.iter_mut().enumerate() {
                                let ic = ((o_c0 + j) * sh.stride + kc) as isize
                                    - sh.padding as isize;
                                if ic >= 0 && (ic as usize) < sh.in_w {
                                    *d = irow[ic as usize];
                                }
                            }
                        }
                    }
                }
            }
            col += run;
        }
    }
}

/// Y = W · X for a packed X that is never materialized: `pack(c0, c1, dst)`
/// fills column panel `[c0, c1)` of the logical `[kk × total_cols]` operand
/// (row stride `c1 - c0`, pre-zeroed scratch). Panels are GEMMed in pool
/// scratch and scattered into Y's columns — the fused im2col-GEMM engine
/// for digital conv layers. Within a dispatch level results are bitwise
/// equal to `matmul(w, x_full)` at every thread count.
pub fn gemm_packed_panels_at<P>(
    level: SimdLevel,
    pool: &ThreadPool,
    w: &Mat,
    total_cols: usize,
    pack: &P,
) -> Mat
where
    P: Fn(usize, usize, &mut [f32]) + Sync,
{
    gemm_packed_panels_with(level, pool, super::tune::panel_cols_for(level), w, total_cols, pack)
}

/// [`gemm_packed_panels_at`] at an explicit panel width — the forced entry
/// point the autotuner times candidate widths through (it must not consult
/// the profile it is producing). Panel width is a pure performance knob:
/// results are bitwise identical at every width within a dispatch level.
pub fn gemm_packed_panels_with<P>(
    level: SimdLevel,
    pool: &ThreadPool,
    panel_cols: usize,
    w: &Mat,
    total_cols: usize,
    pack: &P,
) -> Mat
where
    P: Fn(usize, usize, &mut [f32]) + Sync,
{
    let panel_cols = panel_cols.max(8);
    let (m, kk) = (w.rows, w.cols);
    let mut y = Mat::zeros(m, total_cols);
    if m == 0 || total_cols == 0 {
        return y;
    }
    let panels = total_cols.div_ceil(panel_cols);
    let yptr = SendPtr(y.data.as_mut_ptr());
    pool.parallel_for_sized(panels, 2 * m * kk * total_cols, |ti| {
        let c0 = ti * panel_cols;
        let c1 = (c0 + panel_cols).min(total_cols);
        let wpan = c1 - c0;
        let mut xbuf = Scratch::take(kk * wpan);
        pack(c0, c1, &mut xbuf);
        let mut ybuf = Scratch::take(m * wpan);
        gemm_acc_slices_at(level, &w.data, m, kk, &xbuf, wpan, &mut ybuf);
        // Safety: panel ti owns columns [c0, c1) of every row of Y.
        unsafe { scatter_panel(yptr, total_cols, c0, wpan, m, &ybuf) };
    });
    y
}

/// [`gemm_packed_panels_at`] at the process-wide dispatch level.
pub fn gemm_packed_panels<P>(pool: &ThreadPool, w: &Mat, total_cols: usize, pack: &P) -> Mat
where
    P: Fn(usize, usize, &mut [f32]) + Sync,
{
    gemm_packed_panels_at(simd::active(), pool, w, total_cols, pack)
}

/// Fused conv forward Y = W · im2col(input) without materializing the
/// patch matrix, at an explicit dispatch level (tests pin levels here).
pub fn conv2d_forward_packed_at(
    level: SimdLevel,
    pool: &ThreadPool,
    w: &Mat,
    input: &[f32],
    sh: &Conv2dShape,
) -> Mat {
    assert_eq!(w.cols, sh.patch_rows(), "conv2d_forward_packed weight cols");
    let ex = PatchExtractor::new(input, sh);
    gemm_packed_panels_at(level, pool, w, sh.patch_cols(), &|c0, c1, dst: &mut [f32]| {
        ex.pack_into(c0, c1, dst)
    })
}

/// Fused conv forward at an explicit dispatch level *and* panel width —
/// the autotuner's forced entry point for timing candidate widths.
pub fn conv2d_forward_packed_with(
    level: SimdLevel,
    pool: &ThreadPool,
    panel_cols: usize,
    w: &Mat,
    input: &[f32],
    sh: &Conv2dShape,
) -> Mat {
    assert_eq!(w.cols, sh.patch_rows(), "conv2d_forward_packed weight cols");
    let ex = PatchExtractor::new(input, sh);
    gemm_packed_panels_with(level, pool, panel_cols, w, sh.patch_cols(), &|c0, c1, dst: &mut [f32]| {
        ex.pack_into(c0, c1, dst)
    })
}

/// Fused conv forward at the process-wide dispatch level and global pool.
pub fn conv2d_forward_packed(w: &Mat, input: &[f32], sh: &Conv2dShape) -> Mat {
    conv2d_forward_packed_at(simd::active(), pool::global(), w, input, sh)
}

// ---------------------------------------------------------------------------
// Pooled eager materialization (backward path)
// ---------------------------------------------------------------------------

/// Parallel [`im2col`] on an explicit pool: fixed-width column panels are
/// packed into scratch and scattered into the full matrix. A pure gather,
/// bitwise identical to the serial reference at every thread count.
pub fn im2col_pooled_on(pool: &ThreadPool, input: &[f32], sh: &Conv2dShape) -> Mat {
    let (rows, cols) = (sh.patch_rows(), sh.patch_cols());
    let mut x = Mat::zeros(rows, cols);
    if rows == 0 || cols == 0 {
        return x;
    }
    let ex = PatchExtractor::new(input, sh);
    let panels = cols.div_ceil(PANEL_COLS);
    let xptr = SendPtr(x.data.as_mut_ptr());
    pool.parallel_for_sized(panels, rows * cols, |ti| {
        let c0 = ti * PANEL_COLS;
        let c1 = (c0 + PANEL_COLS).min(cols);
        let wpan = c1 - c0;
        let mut buf = Scratch::take(rows * wpan);
        ex.pack_into(c0, c1, &mut buf);
        // Safety: panel ti owns columns [c0, c1) of every row of X.
        unsafe { scatter_panel(xptr, cols, c0, wpan, rows, &buf) };
    });
    x
}

/// [`im2col_pooled_on`] over the global pool.
pub fn im2col_pooled(input: &[f32], sh: &Conv2dShape) -> Mat {
    im2col_pooled_on(pool::global(), input, sh)
}

/// Parallel [`col2im`] on an explicit pool: one task per (batch, channel)
/// input plane, preserving the serial per-plane accumulation order — the
/// fold is bitwise identical to the reference at every thread count.
pub fn col2im_pooled_on(pool: &ThreadPool, cols: &Mat, sh: &Conv2dShape) -> Vec<f32> {
    assert_eq!(cols.rows, sh.patch_rows(), "col2im rows");
    assert_eq!(cols.cols, sh.patch_cols(), "col2im cols");
    let (oh, ow) = (sh.out_h(), sh.out_w());
    let hw = sh.in_h * sh.in_w;
    let planes = sh.batch * sh.in_ch;
    let mut out = vec![0.0f32; planes * hw];
    if planes == 0 {
        return out;
    }
    let optr = SendPtr(out.as_mut_ptr());
    pool.parallel_for_sized(planes, sh.patch_rows() * sh.patch_cols(), |pl| {
        let b = pl / sh.in_ch;
        let c = pl % sh.in_ch;
        // Safety: plane pl owns out[pl·hw .. (pl+1)·hw] exclusively.
        let plane = unsafe { std::slice::from_raw_parts_mut(optr.0.add(pl * hw), hw) };
        for kr in 0..sh.kernel {
            for kc in 0..sh.kernel {
                let row = (c * sh.kernel + kr) * sh.kernel + kc;
                for o_r in 0..oh {
                    let ir = (o_r * sh.stride + kr) as isize - sh.padding as isize;
                    if ir < 0 || ir as usize >= sh.in_h {
                        continue;
                    }
                    for o_c in 0..ow {
                        let ic = (o_c * sh.stride + kc) as isize - sh.padding as isize;
                        if ic < 0 || ic as usize >= sh.in_w {
                            continue;
                        }
                        let col = b * (oh * ow) + o_r * ow + o_c;
                        plane[ir as usize * sh.in_w + ic as usize] += cols[(row, col)];
                    }
                }
            }
        }
    });
    out
}

/// [`col2im_pooled_on`] over the global pool.
pub fn col2im_pooled(cols: &Mat, sh: &Conv2dShape) -> Vec<f32> {
    col2im_pooled_on(pool::global(), cols, sh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quickcheck;
    use crate::util::Rng;

    fn shape(b: usize, c: usize, h: usize, k: usize, s: usize, p: usize) -> Conv2dShape {
        Conv2dShape { batch: b, in_ch: c, in_h: h, in_w: h, out_ch: 1, kernel: k, stride: s, padding: p }
    }

    #[test]
    fn identity_1x1() {
        // 1x1 kernel stride 1: im2col is a reshape.
        let sh = shape(1, 2, 3, 1, 1, 0);
        let input: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let x = im2col(&input, &sh);
        assert_eq!(x.rows, 2);
        assert_eq!(x.cols, 9);
        assert_eq!(x.row(0), &input[0..9]);
        assert_eq!(x.row(1), &input[9..18]);
    }

    #[test]
    fn known_3x3() {
        // Single 3x3 plane, 2x2 kernel, stride 1, no padding -> 4 patches.
        let sh = shape(1, 1, 3, 2, 1, 0);
        let input: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let x = im2col(&input, &sh);
        assert_eq!((x.rows, x.cols), (4, 4));
        // Patch at (0,0) is [1,2,4,5] read down the column.
        let col0: Vec<f32> = (0..4).map(|r| x[(r, 0)]).collect();
        assert_eq!(col0, vec![1.0, 2.0, 4.0, 5.0]);
        let col3: Vec<f32> = (0..4).map(|r| x[(r, 3)]).collect();
        assert_eq!(col3, vec![5.0, 6.0, 8.0, 9.0]);
    }

    #[test]
    fn padding_zeroes_border() {
        let sh = shape(1, 1, 2, 3, 1, 1);
        let input = vec![1.0, 2.0, 3.0, 4.0];
        let x = im2col(&input, &sh);
        assert_eq!((x.rows, x.cols), (9, 4));
        // Top-left patch centered at (0,0): first row/col of the 3x3 window
        // falls outside -> zeros.
        assert_eq!(x[(0, 0)], 0.0);
        assert_eq!(x[(4, 0)], 1.0); // center
    }

    #[test]
    fn conv_as_gemm_matches_direct() {
        // conv(input, kern) via im2col+GEMM == direct nested-loop conv.
        let mut rng = Rng::new(7);
        let sh = Conv2dShape {
            batch: 2, in_ch: 3, in_h: 5, in_w: 5, out_ch: 4, kernel: 3, stride: 2, padding: 1,
        };
        let input: Vec<f32> = (0..sh.batch * sh.in_ch * 25).map(|_| rng.normal() as f32).collect();
        let kern: Vec<f32> =
            (0..sh.out_ch * sh.in_ch * 9).map(|_| rng.normal() as f32).collect();
        let x = im2col(&input, &sh);
        let w = Mat::from_slice(sh.out_ch, sh.patch_rows(), &kern);
        let y = crate::linalg::matmul(&w, &x);
        // Direct conv.
        let (oh, ow) = (sh.out_h(), sh.out_w());
        for b in 0..sh.batch {
            for oc in 0..sh.out_ch {
                for o_r in 0..oh {
                    for o_c in 0..ow {
                        let mut s = 0.0f32;
                        for ic in 0..sh.in_ch {
                            for kr in 0..3 {
                                for kc in 0..3 {
                                    let ir = (o_r * 2 + kr) as isize - 1;
                                    let icol = (o_c * 2 + kc) as isize - 1;
                                    if ir >= 0 && ir < 5 && icol >= 0 && icol < 5 {
                                        s += input[((b * sh.in_ch + ic) * 5 + ir as usize) * 5
                                            + icol as usize]
                                            * kern[((oc * sh.in_ch + ic) * 3 + kr) * 3 + kc];
                                    }
                                }
                            }
                        }
                        let col = b * (oh * ow) + o_r * ow + o_c;
                        assert!((y[(oc, col)] - s).abs() < 1e-4, "mismatch at {b},{oc},{o_r},{o_c}");
                    }
                }
            }
        }
    }

    #[test]
    fn prop_col2im_is_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> — the defining adjoint property.
        quickcheck(
            "col2im adjoint of im2col",
            |rng, size| {
                let h = 3 + size % 5;
                let k = 1 + size % 3;
                let sh = Conv2dShape {
                    batch: 1 + size % 2,
                    in_ch: 1 + size % 3,
                    in_h: h,
                    in_w: h,
                    out_ch: 1,
                    kernel: k.min(h),
                    stride: 1 + size % 2,
                    padding: size % 2,
                };
                let n_in = sh.batch * sh.in_ch * h * h;
                let x: Vec<f32> = (0..n_in).map(|_| rng.normal() as f32).collect();
                let y = Mat::randn(sh.patch_rows(), sh.patch_cols(), 1.0, rng);
                (sh, x, y)
            },
            |(sh, x, y)| {
                let xi = im2col(x, sh);
                let lhs: f32 = xi.data.iter().zip(&y.data).map(|(a, b)| a * b).sum();
                let back = col2im(y, sh);
                let rhs: f32 = x.iter().zip(&back).map(|(a, b)| a * b).sum();
                if (lhs - rhs).abs() > 1e-3 * (1.0 + lhs.abs()) {
                    return Err(format!("adjoint mismatch {lhs} vs {rhs}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_patch_extractor_matches_im2col_bitwise() {
        // Every panel split of the extractor reproduces the eager patch
        // matrix exactly — strides, padding (including padding ≥ kernel),
        // non-square inputs, 1×1 kernels.
        quickcheck(
            "pack_into == im2col columns",
            |rng, size| {
                let h = 2 + size % 6;
                let w = 2 + (size / 2) % 7; // non-square
                let k = 1 + size % 3;
                let sh = Conv2dShape {
                    batch: 1 + size % 3,
                    in_ch: 1 + size % 2,
                    in_h: h,
                    in_w: w,
                    out_ch: 1,
                    kernel: k.min(h).min(w),
                    stride: 1 + size % 3,
                    padding: size % 4, // can exceed the kernel
                };
                let n_in = sh.batch * sh.in_ch * h * w;
                let input: Vec<f32> = (0..n_in).map(|_| rng.normal() as f32).collect();
                let width = 1 + size % 5; // deliberately odd panel widths
                (sh, input, width)
            },
            |(sh, input, width)| {
                let eager = im2col(input, sh);
                let ex = PatchExtractor::new(input, sh);
                let rows = sh.patch_rows();
                let cols = sh.patch_cols();
                let mut c0 = 0;
                while c0 < cols {
                    let c1 = (c0 + width).min(cols);
                    let wpan = c1 - c0;
                    let mut buf = vec![0.0f32; rows * wpan];
                    ex.pack_into(c0, c1, &mut buf);
                    for r in 0..rows {
                        for j in 0..wpan {
                            let (got, want) = (buf[r * wpan + j], eager[(r, c0 + j)]);
                            if got != want {
                                return Err(format!(
                                    "({r},{}) got {got} want {want} (panel {c0}..{c1})",
                                    c0 + j
                                ));
                            }
                        }
                    }
                    c0 = c1;
                }
                Ok(())
            },
        );
    }
}
