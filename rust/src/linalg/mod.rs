//! Dense linear algebra substrate: a row-major f32 matrix type, a
//! register-tiled + cache-blocked + pool-parallel + SIMD-dispatched GEMM
//! engine (the native-simulator hot path — see DESIGN.md §8, `gemm`'s and
//! `simd`'s module docs), a per-host autotuner for its blocking (`tune`),
//! one-sided Jacobi SVD for the k×k photonic blocks, and the
//! im2col/col2im conv lowering with its fused packed-panel execution path.

pub mod mat;
pub mod simd;
pub mod tune;
pub mod gemm;
pub mod svd;
pub mod conv;

pub use conv::{
    col2im, col2im_pooled, col2im_pooled_on, conv2d_forward_packed, conv2d_forward_packed_at,
    conv2d_forward_packed_with, gemm_packed_panels, gemm_packed_panels_at, gemm_packed_panels_with,
    im2col, im2col_pooled, im2col_pooled_on, Conv2dShape, PatchExtractor, PANEL_COLS,
};
pub use gemm::{
    dot_mul_at, gemm_a_bt_acc_slices, gemm_a_bt_acc_slices_at, gemm_a_bt_acc_slices_scalar,
    gemm_acc_slices, gemm_acc_slices_at, gemm_acc_slices_scalar, gemm_at_b_acc_band,
    gemm_at_b_acc_band_at, gemm_at_b_acc_band_scalar, matmul, matmul_a_bt, matmul_a_bt_acc,
    matmul_a_bt_into, matmul_acc, matmul_acc_at, matmul_acc_with_blocking, matmul_at_b,
    matmul_at_b_into, matmul_into, matmul_into_at, matvec, sigma_grad_block,
    sigma_grad_block_slices, sigma_grad_block_slices_at,
};
pub use mat::Mat;
pub use simd::SimdLevel;
pub use svd::{svd_kxk, Svd};
pub use tune::GemmBlocking;
