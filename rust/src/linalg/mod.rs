//! Dense linear algebra substrate: a row-major f32 matrix type, a
//! register-tiled + pool-parallel GEMM engine (the native-simulator hot
//! path — see DESIGN.md §8 and `gemm`'s module docs), one-sided Jacobi SVD
//! for the k×k photonic blocks, and im2col/col2im for the convolution
//! layers.

pub mod mat;
pub mod gemm;
pub mod svd;
pub mod conv;

pub use conv::{col2im, im2col, Conv2dShape};
pub use gemm::{
    gemm_a_bt_acc_slices, gemm_acc_slices, gemm_at_b_acc_band, matmul, matmul_a_bt,
    matmul_a_bt_acc, matmul_a_bt_into, matmul_acc, matmul_at_b, matmul_at_b_into, matmul_into,
    matvec, sigma_grad_block, sigma_grad_block_slices,
};
pub use mat::Mat;
pub use svd::{svd_kxk, Svd};
