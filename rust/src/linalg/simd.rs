//! Runtime-dispatched SIMD layer under the GEMM engine.
//!
//! Five kernel families sit behind one dispatch switch (the level lattice):
//!
//! * **Portable scalar** — the seed-era auto-vectorizable loops in
//!   [`super::gemm`] itself; always available and bitwise-identical to the
//!   pre-SIMD engine on every platform.
//! * **Scalar-FMA** (`scalar_fma`) — the same loop structure with every
//!   multiply-accumulate contracted through `f32::mul_add`, so hosts
//!   without a vector family still get the fast-numerics (fused) rounding
//!   semantics. Always available, but opt-in only: without hardware FMA,
//!   `mul_add` lowers to a libm call and is *slower* than scalar.
//! * **AVX2 + FMA** (`avx2`, x86_64 only) — 8-lane fused-multiply-add
//!   versions of every slice microkernel, runtime feature-detected.
//! * **AVX-512** (`avx512`, x86_64 only) — 16-lane FMA versions, selected
//!   when the CPU reports `avx512f`.
//! * **NEON** (`neon`, aarch64 only) — 4-lane `vfmaq_f32` versions.
//!   AdvSIMD is architecturally mandatory on aarch64, so this is the
//!   default level there.
//!
//! The level is resolved **once per process** from `L2IGHT_SIMD`
//! (`auto` | `scalar` | `scalar-fma` | `avx2` | `avx512` | `neon`, default
//! `auto` = best available: avx512 → avx2 → neon → scalar) by [`active`];
//! every hot-path kernel call dispatches on it. Requesting a level the
//! host lacks warns and falls back to scalar; an unknown value warns and
//! behaves like `auto` — parsing round-trips with [`SimdLevel::name`].
//!
//! ## Determinism contract
//!
//! Within one dispatch level, lane order and accumulation order are fixed:
//! the accumulate-into-memory kernels (`gemm_acc`, `gemm_at_b_band`) apply
//! one FMA per element per inner step regardless of where the vector body
//! ends and the scalar tail begins, and the reduction kernels (`gemm_a_bt`,
//! `dot_mul`) split lanes by the (fixed) inner dimension only. Combined
//! with the pool's partition-by-output-region banding and the cache-blocked
//! wrappers' tile rules (see `super::gemm`), results are **bitwise
//! thread-count-, panel-partition-, and blocking-invariant at every
//! level**. Across levels the FMA contraction (and lane width) changes
//! rounding, which is why switching `L2IGHT_SIMD` moves numerics at the ulp
//! scale (and why the scenario golden carries a per-numerics-family bless —
//! see `rust/README.md` § "SIMD dispatch").

use std::sync::OnceLock;

/// Instruction-set level the slice kernels run at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels — bitwise identical to the seed-era engine.
    Scalar,
    /// Portable `f32::mul_add`-contracted kernels (FMA rounding semantics
    /// without a vector ISA). Always available; never chosen by `auto`.
    ScalarFma,
    /// AVX2 + FMA 8-lane kernels (x86_64 only, runtime-detected).
    Avx2,
    /// AVX-512 16-lane kernels (x86_64 only, runtime-detected `avx512f`).
    Avx512,
    /// NEON 4-lane `vfmaq_f32` kernels (aarch64 only; AdvSIMD is mandatory
    /// there).
    Neon,
}

impl SimdLevel {
    /// Every level, in lattice order. The dispatch-level axis for tests,
    /// the autotuner, and CI strategy matrices.
    pub const ALL: [SimdLevel; 5] = [
        SimdLevel::Scalar,
        SimdLevel::ScalarFma,
        SimdLevel::Avx2,
        SimdLevel::Avx512,
        SimdLevel::Neon,
    ];

    /// Stable lowercase name (reports, bench JSON, logs, `L2IGHT_SIMD`).
    /// Round-trips through [`SimdLevel::parse`].
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::ScalarFma => "scalar-fma",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Neon => "neon",
        }
    }

    /// Parse a level name (the inverse of [`SimdLevel::name`]; also accepts
    /// the `scalar_fma` spelling). `auto` is not a level — resolve it via
    /// [`auto_level`].
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(SimdLevel::Scalar),
            "scalar-fma" | "scalar_fma" => Some(SimdLevel::ScalarFma),
            "avx2" => Some(SimdLevel::Avx2),
            "avx512" => Some(SimdLevel::Avx512),
            "neon" => Some(SimdLevel::Neon),
            _ => None,
        }
    }

    /// True when this host can execute the level's kernels.
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Scalar | SimdLevel::ScalarFma => true,
            SimdLevel::Avx2 => avx2_available(),
            SimdLevel::Avx512 => avx512_available(),
            SimdLevel::Neon => neon_available(),
        }
    }
}

/// True when the CPU supports the AVX2+FMA kernels.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// True when the CPU supports the AVX-512 kernels (`avx512f` covers every
/// intrinsic the kernels use: loads/stores, broadcast, and FMA).
pub fn avx512_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// True when the NEON kernels can run — AdvSIMD is architecturally
/// mandatory on aarch64, so this is a compile-target fact, not a runtime
/// detection.
pub fn neon_available() -> bool {
    cfg!(target_arch = "aarch64")
}

/// The best available level on this host: avx512 → avx2 → neon → scalar.
/// `ScalarFma` is deliberately never auto-selected — without hardware FMA,
/// `f32::mul_add` is a libm call and loses to the plain scalar loops.
pub fn auto_level() -> SimdLevel {
    if avx512_available() {
        SimdLevel::Avx512
    } else if avx2_available() {
        SimdLevel::Avx2
    } else if neon_available() {
        SimdLevel::Neon
    } else {
        SimdLevel::Scalar
    }
}

/// The process-wide dispatch level, resolved once from `L2IGHT_SIMD`.
/// Requesting a level this host lacks warns and falls back to scalar; an
/// unknown value warns and behaves like `auto`.
pub fn active() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("L2IGHT_SIMD") {
        Err(_) => auto_level(),
        Ok(raw) => {
            let t = raw.trim();
            if t.is_empty() || t.eq_ignore_ascii_case("auto") {
                return auto_level();
            }
            match SimdLevel::parse(t) {
                Some(level) if level.available() => level,
                Some(level) => {
                    crate::warn!(
                        "L2IGHT_SIMD={} requested but unavailable on this host; using scalar kernels",
                        level.name()
                    );
                    SimdLevel::Scalar
                }
                None => {
                    crate::warn!(
                        "ignoring unknown L2IGHT_SIMD={t:?} (want auto|scalar|scalar-fma|avx2|avx512|neon); using auto"
                    );
                    auto_level()
                }
            }
        }
    })
}

/// Portable FMA-contracted slice kernels: the scalar loop structure with
/// every multiply-accumulate routed through `f32::mul_add`. Numerics match
/// the vector families' *semantics* (one fused op per element per step, the
/// same fixed chain order in the Aᵀ·B quads) while staying lane-free, so
/// non-x86 hosts get a fast-numerics family with the full determinism
/// contract. Safe to call everywhere — no ISA requirement.
pub mod scalar_fma {
    /// C[m×n] += A[m×kk] · B[kk×n] over raw row-major slices — the
    /// `mul_add` version of `gemm::gemm_acc_slices_scalar`, same 4-row
    /// register tiling and all-zero-quad skip. One fused op per element per
    /// inner step, so the result does not depend on n or panel boundaries.
    pub fn gemm_acc(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= kk * n && c.len() >= m * n);
        let mut i = 0;
        while i + 4 <= m {
            let rows = &mut c[i * n..(i + 4) * n];
            let (c0, rows) = rows.split_at_mut(n);
            let (c1, rows) = rows.split_at_mut(n);
            let (c2, c3) = rows.split_at_mut(n);
            let a0 = &a[i * kk..(i + 1) * kk];
            let a1 = &a[(i + 1) * kk..(i + 2) * kk];
            let a2 = &a[(i + 2) * kk..(i + 3) * kk];
            let a3 = &a[(i + 3) * kk..(i + 4) * kk];
            for l in 0..kk {
                let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue; // structured-sparsity fast path (masked weights)
                }
                let br = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    let v = br[j];
                    c0[j] = x0.mul_add(v, c0[j]);
                    c1[j] = x1.mul_add(v, c1[j]);
                    c2[j] = x2.mul_add(v, c2[j]);
                    c3[j] = x3.mul_add(v, c3[j]);
                }
            }
            i += 4;
        }
        for r in i..m {
            let ar = &a[r * kk..(r + 1) * kk];
            let cr = &mut c[r * n..(r + 1) * n];
            for (l, &x) in ar.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let br = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                }
            }
        }
    }

    /// C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] for A [kk×m], B [kk×n] — the
    /// `mul_add` version of `gemm::gemm_at_b_acc_band_scalar`. The four
    /// fused ops per element chain in fixed order (x0 first), identical to
    /// the vector families' tail semantics.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_at_b_band(
        a: &[f32],
        kk: usize,
        m: usize,
        b: &[f32],
        n: usize,
        i0: usize,
        i1: usize,
        c_band: &mut [f32],
    ) {
        debug_assert!(a.len() >= kk * m && b.len() >= kk * n);
        debug_assert!(i1 <= m && c_band.len() >= (i1 - i0) * n);
        let mut l = 0;
        while l + 4 <= kk {
            let a0 = &a[l * m..(l + 1) * m];
            let a1 = &a[(l + 1) * m..(l + 2) * m];
            let a2 = &a[(l + 2) * m..(l + 3) * m];
            let a3 = &a[(l + 3) * m..(l + 4) * m];
            let b0 = &b[l * n..(l + 1) * n];
            let b1 = &b[(l + 1) * n..(l + 2) * n];
            let b2 = &b[(l + 2) * n..(l + 3) * n];
            let b3 = &b[(l + 3) * n..(l + 4) * n];
            for i in i0..i1 {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                for j in 0..n {
                    let mut s = cr[j];
                    s = x0.mul_add(b0[j], s);
                    s = x1.mul_add(b1[j], s);
                    s = x2.mul_add(b2[j], s);
                    s = x3.mul_add(b3[j], s);
                    cr[j] = s;
                }
            }
            l += 4;
        }
        for ll in l..kk {
            let ar = &a[ll * m..(ll + 1) * m];
            let br = &b[ll * n..(ll + 1) * n];
            for i in i0..i1 {
                let x = ar[i];
                if x == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                for j in 0..n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                }
            }
        }
    }

    /// C[m×p] += A[m×kk] · B[p×kk]ᵀ (dot-product layout) — the `mul_add`
    /// version of `gemm::gemm_a_bt_acc_slices_scalar`, same 4-dot tiling
    /// and all-zero A-row skip. Each dot product is one sequential fused
    /// chain over kk, identical in the quad and remainder paths.
    pub fn gemm_a_bt(a: &[f32], m: usize, kk: usize, b: &[f32], p: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= p * kk && c.len() >= m * p);
        for i in 0..m {
            let ar = &a[i * kk..(i + 1) * kk];
            if ar.iter().all(|&v| v == 0.0) {
                continue;
            }
            let cr = &mut c[i * p..(i + 1) * p];
            let mut j = 0;
            while j + 4 <= p {
                let b0 = &b[j * kk..(j + 1) * kk];
                let b1 = &b[(j + 1) * kk..(j + 2) * kk];
                let b2 = &b[(j + 2) * kk..(j + 3) * kk];
                let b3 = &b[(j + 3) * kk..(j + 4) * kk];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for l in 0..kk {
                    let av = ar[l];
                    s0 = av.mul_add(b0[l], s0);
                    s1 = av.mul_add(b1[l], s1);
                    s2 = av.mul_add(b2[l], s2);
                    s3 = av.mul_add(b3[l], s3);
                }
                cr[j] += s0;
                cr[j + 1] += s1;
                cr[j + 2] += s2;
                cr[j + 3] += s3;
                j += 4;
            }
            for jj in j..p {
                let br = &b[jj * kk..(jj + 1) * kk];
                let mut s = 0.0f32;
                for (x, y) in ar.iter().zip(br) {
                    s = x.mul_add(*y, s);
                }
                cr[jj] += s;
            }
        }
    }

    /// Σ_j x[j]·y[j] over `len` elements — the Eq. 5 Hadamard reduction as
    /// one sequential fused chain.
    pub fn dot_mul(x: &[f32], y: &[f32], len: usize) -> f32 {
        debug_assert!(x.len() >= len && y.len() >= len);
        let mut s = 0.0f32;
        for (p, q) in x[..len].iter().zip(&y[..len]) {
            s = p.mul_add(*q, s);
        }
        s
    }
}

/// AVX2+FMA slice kernels. Every function here requires AVX2 **and** FMA at
/// runtime; the dispatcher in `super::gemm` only routes here after
/// [`avx2_available`] (or an explicit, caller-checked level override).
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::{
        __m256, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };

    /// Fixed-order horizontal sum of the 8 lanes (deterministic tree:
    /// lane pairs (0,4)(1,5)(2,6)(3,7), then two rounds of adjacent adds).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut t = [0.0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), v);
        ((t[0] + t[4]) + (t[1] + t[5])) + ((t[2] + t[6]) + (t[3] + t[7]))
    }

    /// C[m×n] += A[m×kk] · B[kk×n] over raw row-major slices — the 8-lane
    /// FMA version of `gemm::gemm_acc_slices_scalar`, with the same 4-row
    /// register tiling and all-zero-quad skip. Per output element each
    /// inner step is one FMA (vector body and scalar tail alike), so the
    /// result does not depend on n or on panel boundaries.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_acc(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= kk * n && c.len() >= m * n);
        let mut i = 0;
        while i + 4 <= m {
            let rows = &mut c[i * n..(i + 4) * n];
            let (c0, rows) = rows.split_at_mut(n);
            let (c1, rows) = rows.split_at_mut(n);
            let (c2, c3) = rows.split_at_mut(n);
            let a0 = &a[i * kk..(i + 1) * kk];
            let a1 = &a[(i + 1) * kk..(i + 2) * kk];
            let a2 = &a[(i + 2) * kk..(i + 3) * kk];
            let a3 = &a[(i + 3) * kk..(i + 4) * kk];
            for l in 0..kk {
                let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue; // structured-sparsity fast path (masked weights)
                }
                let br = &b[l * n..(l + 1) * n];
                let v0 = _mm256_set1_ps(x0);
                let v1 = _mm256_set1_ps(x1);
                let v2 = _mm256_set1_ps(x2);
                let v3 = _mm256_set1_ps(x3);
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(j));
                    _mm256_storeu_ps(
                        c0.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v0, bv, _mm256_loadu_ps(c0.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(
                        c1.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v1, bv, _mm256_loadu_ps(c1.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(
                        c2.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v2, bv, _mm256_loadu_ps(c2.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(
                        c3.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v3, bv, _mm256_loadu_ps(c3.as_ptr().add(j))),
                    );
                    j += 8;
                }
                while j < n {
                    let v = br[j];
                    c0[j] = x0.mul_add(v, c0[j]);
                    c1[j] = x1.mul_add(v, c1[j]);
                    c2[j] = x2.mul_add(v, c2[j]);
                    c3[j] = x3.mul_add(v, c3[j]);
                    j += 1;
                }
            }
            i += 4;
        }
        for r in i..m {
            let ar = &a[r * kk..(r + 1) * kk];
            let cr = &mut c[r * n..(r + 1) * n];
            for (l, &x) in ar.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let br = &b[l * n..(l + 1) * n];
                let xv = _mm256_set1_ps(x);
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(j));
                    _mm256_storeu_ps(
                        cr.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(xv, bv, _mm256_loadu_ps(cr.as_ptr().add(j))),
                    );
                    j += 8;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] for A [kk×m], B [kk×n], writing
    /// rows `i0..i1` into `c_band` — the 8-lane FMA version of
    /// `gemm::gemm_at_b_acc_band_scalar` with the same 4-pair tiling.
    /// The four FMAs per element chain in fixed order (x0 first).
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_at_b_band(
        a: &[f32],
        kk: usize,
        m: usize,
        b: &[f32],
        n: usize,
        i0: usize,
        i1: usize,
        c_band: &mut [f32],
    ) {
        debug_assert!(a.len() >= kk * m && b.len() >= kk * n);
        debug_assert!(i1 <= m && c_band.len() >= (i1 - i0) * n);
        let mut l = 0;
        while l + 4 <= kk {
            let a0 = &a[l * m..(l + 1) * m];
            let a1 = &a[(l + 1) * m..(l + 2) * m];
            let a2 = &a[(l + 2) * m..(l + 3) * m];
            let a3 = &a[(l + 3) * m..(l + 4) * m];
            let b0 = &b[l * n..(l + 1) * n];
            let b1 = &b[(l + 1) * n..(l + 2) * n];
            let b2 = &b[(l + 2) * n..(l + 3) * n];
            let b3 = &b[(l + 3) * n..(l + 4) * n];
            for i in i0..i1 {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let v0 = _mm256_set1_ps(x0);
                let v1 = _mm256_set1_ps(x1);
                let v2 = _mm256_set1_ps(x2);
                let v3 = _mm256_set1_ps(x3);
                let mut j = 0;
                while j + 8 <= n {
                    let mut acc = _mm256_loadu_ps(cr.as_ptr().add(j));
                    acc = _mm256_fmadd_ps(v0, _mm256_loadu_ps(b0.as_ptr().add(j)), acc);
                    acc = _mm256_fmadd_ps(v1, _mm256_loadu_ps(b1.as_ptr().add(j)), acc);
                    acc = _mm256_fmadd_ps(v2, _mm256_loadu_ps(b2.as_ptr().add(j)), acc);
                    acc = _mm256_fmadd_ps(v3, _mm256_loadu_ps(b3.as_ptr().add(j)), acc);
                    _mm256_storeu_ps(cr.as_mut_ptr().add(j), acc);
                    j += 8;
                }
                while j < n {
                    let mut s = cr[j];
                    s = x0.mul_add(b0[j], s);
                    s = x1.mul_add(b1[j], s);
                    s = x2.mul_add(b2[j], s);
                    s = x3.mul_add(b3[j], s);
                    cr[j] = s;
                    j += 1;
                }
            }
            l += 4;
        }
        for ll in l..kk {
            let ar = &a[ll * m..(ll + 1) * m];
            let br = &b[ll * n..(ll + 1) * n];
            for i in i0..i1 {
                let x = ar[i];
                if x == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let xv = _mm256_set1_ps(x);
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(j));
                    _mm256_storeu_ps(
                        cr.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(xv, bv, _mm256_loadu_ps(cr.as_ptr().add(j))),
                    );
                    j += 8;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[m×p] += A[m×kk] · B[p×kk]ᵀ (dot-product layout) — the 8-lane FMA
    /// version of `gemm::gemm_a_bt_acc_slices_scalar` with the same 4-dot
    /// tiling and all-zero A-row skip. Each dot product accumulates the
    /// 8-lane body in vector lanes (reduced by the fixed [`hsum`] tree),
    /// then appends the scalar tail; the split depends only on `kk`.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_a_bt(a: &[f32], m: usize, kk: usize, b: &[f32], p: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= p * kk && c.len() >= m * p);
        for i in 0..m {
            let ar = &a[i * kk..(i + 1) * kk];
            if ar.iter().all(|&v| v == 0.0) {
                continue;
            }
            let cr = &mut c[i * p..(i + 1) * p];
            let mut j = 0;
            while j + 4 <= p {
                let b0 = &b[j * kk..(j + 1) * kk];
                let b1 = &b[(j + 1) * kk..(j + 2) * kk];
                let b2 = &b[(j + 2) * kk..(j + 3) * kk];
                let b3 = &b[(j + 3) * kk..(j + 4) * kk];
                let mut s0 = _mm256_setzero_ps();
                let mut s1 = _mm256_setzero_ps();
                let mut s2 = _mm256_setzero_ps();
                let mut s3 = _mm256_setzero_ps();
                let mut l = 0;
                while l + 8 <= kk {
                    let av = _mm256_loadu_ps(ar.as_ptr().add(l));
                    s0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.as_ptr().add(l)), s0);
                    s1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.as_ptr().add(l)), s1);
                    s2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.as_ptr().add(l)), s2);
                    s3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.as_ptr().add(l)), s3);
                    l += 8;
                }
                let mut t0 = hsum(s0);
                let mut t1 = hsum(s1);
                let mut t2 = hsum(s2);
                let mut t3 = hsum(s3);
                while l < kk {
                    let av = ar[l];
                    t0 = av.mul_add(b0[l], t0);
                    t1 = av.mul_add(b1[l], t1);
                    t2 = av.mul_add(b2[l], t2);
                    t3 = av.mul_add(b3[l], t3);
                    l += 1;
                }
                cr[j] += t0;
                cr[j + 1] += t1;
                cr[j + 2] += t2;
                cr[j + 3] += t3;
                j += 4;
            }
            for jj in j..p {
                let br = &b[jj * kk..(jj + 1) * kk];
                let mut sv = _mm256_setzero_ps();
                let mut l = 0;
                while l + 8 <= kk {
                    sv = _mm256_fmadd_ps(
                        _mm256_loadu_ps(ar.as_ptr().add(l)),
                        _mm256_loadu_ps(br.as_ptr().add(l)),
                        sv,
                    );
                    l += 8;
                }
                let mut s = hsum(sv);
                while l < kk {
                    s = ar[l].mul_add(br[l], s);
                    l += 1;
                }
                cr[jj] += s;
            }
        }
    }

    /// Σ_j x[j]·y[j] over `len` elements — the Eq. 5 Hadamard reduction
    /// (8-lane FMA body, fixed [`hsum`] tree, scalar FMA tail).
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_mul(x: &[f32], y: &[f32], len: usize) -> f32 {
        debug_assert!(x.len() >= len && y.len() >= len);
        let mut acc = _mm256_setzero_ps();
        let mut l = 0;
        while l + 8 <= len {
            acc = _mm256_fmadd_ps(
                _mm256_loadu_ps(x.as_ptr().add(l)),
                _mm256_loadu_ps(y.as_ptr().add(l)),
                acc,
            );
            l += 8;
        }
        let mut s = hsum(acc);
        while l < len {
            s = x[l].mul_add(y[l], s);
            l += 1;
        }
        s
    }
}

/// AVX-512 slice kernels — the 16-lane siblings of [`avx2`], same tiling,
/// zero-skips, and per-element FMA semantics. Every function requires
/// `avx512f` at runtime; the dispatcher only routes here after
/// [`avx512_available`] (or an explicit, caller-checked level override).
#[cfg(target_arch = "x86_64")]
pub mod avx512 {
    use std::arch::x86_64::{
        __m512, _mm512_fmadd_ps, _mm512_loadu_ps, _mm512_set1_ps, _mm512_setzero_ps,
        _mm512_storeu_ps,
    };

    /// Fixed-order horizontal sum of the 16 lanes: fold lane pairs
    /// (i, i+8), then the avx2 tree over the 8 partials — deterministic
    /// regardless of how the compiler schedules the loads.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn hsum(v: __m512) -> f32 {
        let mut t = [0.0f32; 16];
        _mm512_storeu_ps(t.as_mut_ptr(), v);
        let mut u = [0.0f32; 8];
        for (i, ui) in u.iter_mut().enumerate() {
            *ui = t[i] + t[i + 8];
        }
        ((u[0] + u[4]) + (u[1] + u[5])) + ((u[2] + u[6]) + (u[3] + u[7]))
    }

    /// C[m×n] += A[m×kk] · B[kk×n] — 16-lane FMA, 4-row register tiling,
    /// all-zero-quad skip; one FMA per element per inner step (body and
    /// tail alike), so the result is panel-boundary-independent.
    ///
    /// # Safety
    /// The CPU must support AVX-512F (`simd::avx512_available`).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn gemm_acc(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= kk * n && c.len() >= m * n);
        let mut i = 0;
        while i + 4 <= m {
            let rows = &mut c[i * n..(i + 4) * n];
            let (c0, rows) = rows.split_at_mut(n);
            let (c1, rows) = rows.split_at_mut(n);
            let (c2, c3) = rows.split_at_mut(n);
            let a0 = &a[i * kk..(i + 1) * kk];
            let a1 = &a[(i + 1) * kk..(i + 2) * kk];
            let a2 = &a[(i + 2) * kk..(i + 3) * kk];
            let a3 = &a[(i + 3) * kk..(i + 4) * kk];
            for l in 0..kk {
                let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue; // structured-sparsity fast path (masked weights)
                }
                let br = &b[l * n..(l + 1) * n];
                let v0 = _mm512_set1_ps(x0);
                let v1 = _mm512_set1_ps(x1);
                let v2 = _mm512_set1_ps(x2);
                let v3 = _mm512_set1_ps(x3);
                let mut j = 0;
                while j + 16 <= n {
                    let bv = _mm512_loadu_ps(br.as_ptr().add(j));
                    _mm512_storeu_ps(
                        c0.as_mut_ptr().add(j),
                        _mm512_fmadd_ps(v0, bv, _mm512_loadu_ps(c0.as_ptr().add(j))),
                    );
                    _mm512_storeu_ps(
                        c1.as_mut_ptr().add(j),
                        _mm512_fmadd_ps(v1, bv, _mm512_loadu_ps(c1.as_ptr().add(j))),
                    );
                    _mm512_storeu_ps(
                        c2.as_mut_ptr().add(j),
                        _mm512_fmadd_ps(v2, bv, _mm512_loadu_ps(c2.as_ptr().add(j))),
                    );
                    _mm512_storeu_ps(
                        c3.as_mut_ptr().add(j),
                        _mm512_fmadd_ps(v3, bv, _mm512_loadu_ps(c3.as_ptr().add(j))),
                    );
                    j += 16;
                }
                while j < n {
                    let v = br[j];
                    c0[j] = x0.mul_add(v, c0[j]);
                    c1[j] = x1.mul_add(v, c1[j]);
                    c2[j] = x2.mul_add(v, c2[j]);
                    c3[j] = x3.mul_add(v, c3[j]);
                    j += 1;
                }
            }
            i += 4;
        }
        for r in i..m {
            let ar = &a[r * kk..(r + 1) * kk];
            let cr = &mut c[r * n..(r + 1) * n];
            for (l, &x) in ar.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let br = &b[l * n..(l + 1) * n];
                let xv = _mm512_set1_ps(x);
                let mut j = 0;
                while j + 16 <= n {
                    let bv = _mm512_loadu_ps(br.as_ptr().add(j));
                    _mm512_storeu_ps(
                        cr.as_mut_ptr().add(j),
                        _mm512_fmadd_ps(xv, bv, _mm512_loadu_ps(cr.as_ptr().add(j))),
                    );
                    j += 16;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] — 16-lane FMA, 4-pair tiling,
    /// fixed x0-first chain order per element (body and tail alike).
    ///
    /// # Safety
    /// The CPU must support AVX-512F (`simd::avx512_available`).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn gemm_at_b_band(
        a: &[f32],
        kk: usize,
        m: usize,
        b: &[f32],
        n: usize,
        i0: usize,
        i1: usize,
        c_band: &mut [f32],
    ) {
        debug_assert!(a.len() >= kk * m && b.len() >= kk * n);
        debug_assert!(i1 <= m && c_band.len() >= (i1 - i0) * n);
        let mut l = 0;
        while l + 4 <= kk {
            let a0 = &a[l * m..(l + 1) * m];
            let a1 = &a[(l + 1) * m..(l + 2) * m];
            let a2 = &a[(l + 2) * m..(l + 3) * m];
            let a3 = &a[(l + 3) * m..(l + 4) * m];
            let b0 = &b[l * n..(l + 1) * n];
            let b1 = &b[(l + 1) * n..(l + 2) * n];
            let b2 = &b[(l + 2) * n..(l + 3) * n];
            let b3 = &b[(l + 3) * n..(l + 4) * n];
            for i in i0..i1 {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let v0 = _mm512_set1_ps(x0);
                let v1 = _mm512_set1_ps(x1);
                let v2 = _mm512_set1_ps(x2);
                let v3 = _mm512_set1_ps(x3);
                let mut j = 0;
                while j + 16 <= n {
                    let mut acc = _mm512_loadu_ps(cr.as_ptr().add(j));
                    acc = _mm512_fmadd_ps(v0, _mm512_loadu_ps(b0.as_ptr().add(j)), acc);
                    acc = _mm512_fmadd_ps(v1, _mm512_loadu_ps(b1.as_ptr().add(j)), acc);
                    acc = _mm512_fmadd_ps(v2, _mm512_loadu_ps(b2.as_ptr().add(j)), acc);
                    acc = _mm512_fmadd_ps(v3, _mm512_loadu_ps(b3.as_ptr().add(j)), acc);
                    _mm512_storeu_ps(cr.as_mut_ptr().add(j), acc);
                    j += 16;
                }
                while j < n {
                    let mut s = cr[j];
                    s = x0.mul_add(b0[j], s);
                    s = x1.mul_add(b1[j], s);
                    s = x2.mul_add(b2[j], s);
                    s = x3.mul_add(b3[j], s);
                    cr[j] = s;
                    j += 1;
                }
            }
            l += 4;
        }
        for ll in l..kk {
            let ar = &a[ll * m..(ll + 1) * m];
            let br = &b[ll * n..(ll + 1) * n];
            for i in i0..i1 {
                let x = ar[i];
                if x == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let xv = _mm512_set1_ps(x);
                let mut j = 0;
                while j + 16 <= n {
                    let bv = _mm512_loadu_ps(br.as_ptr().add(j));
                    _mm512_storeu_ps(
                        cr.as_mut_ptr().add(j),
                        _mm512_fmadd_ps(xv, bv, _mm512_loadu_ps(cr.as_ptr().add(j))),
                    );
                    j += 16;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[m×p] += A[m×kk] · B[p×kk]ᵀ — 16-lane FMA dot products, 4-dot
    /// tiling, all-zero A-row skip; lane split depends only on `kk`.
    ///
    /// # Safety
    /// The CPU must support AVX-512F (`simd::avx512_available`).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn gemm_a_bt(a: &[f32], m: usize, kk: usize, b: &[f32], p: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= p * kk && c.len() >= m * p);
        for i in 0..m {
            let ar = &a[i * kk..(i + 1) * kk];
            if ar.iter().all(|&v| v == 0.0) {
                continue;
            }
            let cr = &mut c[i * p..(i + 1) * p];
            let mut j = 0;
            while j + 4 <= p {
                let b0 = &b[j * kk..(j + 1) * kk];
                let b1 = &b[(j + 1) * kk..(j + 2) * kk];
                let b2 = &b[(j + 2) * kk..(j + 3) * kk];
                let b3 = &b[(j + 3) * kk..(j + 4) * kk];
                let mut s0 = _mm512_setzero_ps();
                let mut s1 = _mm512_setzero_ps();
                let mut s2 = _mm512_setzero_ps();
                let mut s3 = _mm512_setzero_ps();
                let mut l = 0;
                while l + 16 <= kk {
                    let av = _mm512_loadu_ps(ar.as_ptr().add(l));
                    s0 = _mm512_fmadd_ps(av, _mm512_loadu_ps(b0.as_ptr().add(l)), s0);
                    s1 = _mm512_fmadd_ps(av, _mm512_loadu_ps(b1.as_ptr().add(l)), s1);
                    s2 = _mm512_fmadd_ps(av, _mm512_loadu_ps(b2.as_ptr().add(l)), s2);
                    s3 = _mm512_fmadd_ps(av, _mm512_loadu_ps(b3.as_ptr().add(l)), s3);
                    l += 16;
                }
                let mut t0 = hsum(s0);
                let mut t1 = hsum(s1);
                let mut t2 = hsum(s2);
                let mut t3 = hsum(s3);
                while l < kk {
                    let av = ar[l];
                    t0 = av.mul_add(b0[l], t0);
                    t1 = av.mul_add(b1[l], t1);
                    t2 = av.mul_add(b2[l], t2);
                    t3 = av.mul_add(b3[l], t3);
                    l += 1;
                }
                cr[j] += t0;
                cr[j + 1] += t1;
                cr[j + 2] += t2;
                cr[j + 3] += t3;
                j += 4;
            }
            for jj in j..p {
                let br = &b[jj * kk..(jj + 1) * kk];
                let mut sv = _mm512_setzero_ps();
                let mut l = 0;
                while l + 16 <= kk {
                    sv = _mm512_fmadd_ps(
                        _mm512_loadu_ps(ar.as_ptr().add(l)),
                        _mm512_loadu_ps(br.as_ptr().add(l)),
                        sv,
                    );
                    l += 16;
                }
                let mut s = hsum(sv);
                while l < kk {
                    s = ar[l].mul_add(br[l], s);
                    l += 1;
                }
                cr[jj] += s;
            }
        }
    }

    /// Σ_j x[j]·y[j] over `len` elements — 16-lane FMA body, fixed
    /// [`hsum`] tree, scalar FMA tail.
    ///
    /// # Safety
    /// The CPU must support AVX-512F (`simd::avx512_available`).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot_mul(x: &[f32], y: &[f32], len: usize) -> f32 {
        debug_assert!(x.len() >= len && y.len() >= len);
        let mut acc = _mm512_setzero_ps();
        let mut l = 0;
        while l + 16 <= len {
            acc = _mm512_fmadd_ps(
                _mm512_loadu_ps(x.as_ptr().add(l)),
                _mm512_loadu_ps(y.as_ptr().add(l)),
                acc,
            );
            l += 16;
        }
        let mut s = hsum(acc);
        while l < len {
            s = x[l].mul_add(y[l], s);
            l += 1;
        }
        s
    }
}

/// NEON (AdvSIMD) slice kernels — the 4-lane siblings of [`avx2`], same
/// tiling, zero-skips, and per-element FMA semantics, built on
/// `vfmaq_f32` (acc + a·b, fused). AdvSIMD is mandatory on aarch64, so no
/// runtime detection is needed — only the compile target gates this.
#[cfg(target_arch = "aarch64")]
pub mod neon {
    use std::arch::aarch64::{float32x4_t, vdupq_n_f32, vfmaq_f32, vld1q_f32, vst1q_f32};

    /// Fixed-order horizontal sum of the 4 lanes: (t0+t2) + (t1+t3) — the
    /// same fold shape as the wider families' trees.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn hsum(v: float32x4_t) -> f32 {
        let mut t = [0.0f32; 4];
        vst1q_f32(t.as_mut_ptr(), v);
        (t[0] + t[2]) + (t[1] + t[3])
    }

    /// C[m×n] += A[m×kk] · B[kk×n] — 4-lane FMA, 4-row register tiling,
    /// all-zero-quad skip; one FMA per element per inner step (body and
    /// tail alike), so the result is panel-boundary-independent.
    ///
    /// # Safety
    /// aarch64 target only (`simd::neon_available`).
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_acc(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= kk * n && c.len() >= m * n);
        let mut i = 0;
        while i + 4 <= m {
            let rows = &mut c[i * n..(i + 4) * n];
            let (c0, rows) = rows.split_at_mut(n);
            let (c1, rows) = rows.split_at_mut(n);
            let (c2, c3) = rows.split_at_mut(n);
            let a0 = &a[i * kk..(i + 1) * kk];
            let a1 = &a[(i + 1) * kk..(i + 2) * kk];
            let a2 = &a[(i + 2) * kk..(i + 3) * kk];
            let a3 = &a[(i + 3) * kk..(i + 4) * kk];
            for l in 0..kk {
                let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue; // structured-sparsity fast path (masked weights)
                }
                let br = &b[l * n..(l + 1) * n];
                let v0 = vdupq_n_f32(x0);
                let v1 = vdupq_n_f32(x1);
                let v2 = vdupq_n_f32(x2);
                let v3 = vdupq_n_f32(x3);
                let mut j = 0;
                while j + 4 <= n {
                    let bv = vld1q_f32(br.as_ptr().add(j));
                    vst1q_f32(
                        c0.as_mut_ptr().add(j),
                        vfmaq_f32(vld1q_f32(c0.as_ptr().add(j)), bv, v0),
                    );
                    vst1q_f32(
                        c1.as_mut_ptr().add(j),
                        vfmaq_f32(vld1q_f32(c1.as_ptr().add(j)), bv, v1),
                    );
                    vst1q_f32(
                        c2.as_mut_ptr().add(j),
                        vfmaq_f32(vld1q_f32(c2.as_ptr().add(j)), bv, v2),
                    );
                    vst1q_f32(
                        c3.as_mut_ptr().add(j),
                        vfmaq_f32(vld1q_f32(c3.as_ptr().add(j)), bv, v3),
                    );
                    j += 4;
                }
                while j < n {
                    let v = br[j];
                    c0[j] = x0.mul_add(v, c0[j]);
                    c1[j] = x1.mul_add(v, c1[j]);
                    c2[j] = x2.mul_add(v, c2[j]);
                    c3[j] = x3.mul_add(v, c3[j]);
                    j += 1;
                }
            }
            i += 4;
        }
        for r in i..m {
            let ar = &a[r * kk..(r + 1) * kk];
            let cr = &mut c[r * n..(r + 1) * n];
            for (l, &x) in ar.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let br = &b[l * n..(l + 1) * n];
                let xv = vdupq_n_f32(x);
                let mut j = 0;
                while j + 4 <= n {
                    let bv = vld1q_f32(br.as_ptr().add(j));
                    vst1q_f32(
                        cr.as_mut_ptr().add(j),
                        vfmaq_f32(vld1q_f32(cr.as_ptr().add(j)), bv, xv),
                    );
                    j += 4;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] — 4-lane FMA, 4-pair tiling,
    /// fixed x0-first chain order per element (body and tail alike).
    ///
    /// # Safety
    /// aarch64 target only (`simd::neon_available`).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_at_b_band(
        a: &[f32],
        kk: usize,
        m: usize,
        b: &[f32],
        n: usize,
        i0: usize,
        i1: usize,
        c_band: &mut [f32],
    ) {
        debug_assert!(a.len() >= kk * m && b.len() >= kk * n);
        debug_assert!(i1 <= m && c_band.len() >= (i1 - i0) * n);
        let mut l = 0;
        while l + 4 <= kk {
            let a0 = &a[l * m..(l + 1) * m];
            let a1 = &a[(l + 1) * m..(l + 2) * m];
            let a2 = &a[(l + 2) * m..(l + 3) * m];
            let a3 = &a[(l + 3) * m..(l + 4) * m];
            let b0 = &b[l * n..(l + 1) * n];
            let b1 = &b[(l + 1) * n..(l + 2) * n];
            let b2 = &b[(l + 2) * n..(l + 3) * n];
            let b3 = &b[(l + 3) * n..(l + 4) * n];
            for i in i0..i1 {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let v0 = vdupq_n_f32(x0);
                let v1 = vdupq_n_f32(x1);
                let v2 = vdupq_n_f32(x2);
                let v3 = vdupq_n_f32(x3);
                let mut j = 0;
                while j + 4 <= n {
                    let mut acc = vld1q_f32(cr.as_ptr().add(j));
                    acc = vfmaq_f32(acc, vld1q_f32(b0.as_ptr().add(j)), v0);
                    acc = vfmaq_f32(acc, vld1q_f32(b1.as_ptr().add(j)), v1);
                    acc = vfmaq_f32(acc, vld1q_f32(b2.as_ptr().add(j)), v2);
                    acc = vfmaq_f32(acc, vld1q_f32(b3.as_ptr().add(j)), v3);
                    vst1q_f32(cr.as_mut_ptr().add(j), acc);
                    j += 4;
                }
                while j < n {
                    let mut s = cr[j];
                    s = x0.mul_add(b0[j], s);
                    s = x1.mul_add(b1[j], s);
                    s = x2.mul_add(b2[j], s);
                    s = x3.mul_add(b3[j], s);
                    cr[j] = s;
                    j += 1;
                }
            }
            l += 4;
        }
        for ll in l..kk {
            let ar = &a[ll * m..(ll + 1) * m];
            let br = &b[ll * n..(ll + 1) * n];
            for i in i0..i1 {
                let x = ar[i];
                if x == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let xv = vdupq_n_f32(x);
                let mut j = 0;
                while j + 4 <= n {
                    let bv = vld1q_f32(br.as_ptr().add(j));
                    vst1q_f32(
                        cr.as_mut_ptr().add(j),
                        vfmaq_f32(vld1q_f32(cr.as_ptr().add(j)), bv, xv),
                    );
                    j += 4;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[m×p] += A[m×kk] · B[p×kk]ᵀ — 4-lane FMA dot products, 4-dot
    /// tiling, all-zero A-row skip; lane split depends only on `kk`.
    ///
    /// # Safety
    /// aarch64 target only (`simd::neon_available`).
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_a_bt(a: &[f32], m: usize, kk: usize, b: &[f32], p: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= p * kk && c.len() >= m * p);
        for i in 0..m {
            let ar = &a[i * kk..(i + 1) * kk];
            if ar.iter().all(|&v| v == 0.0) {
                continue;
            }
            let cr = &mut c[i * p..(i + 1) * p];
            let mut j = 0;
            while j + 4 <= p {
                let b0 = &b[j * kk..(j + 1) * kk];
                let b1 = &b[(j + 1) * kk..(j + 2) * kk];
                let b2 = &b[(j + 2) * kk..(j + 3) * kk];
                let b3 = &b[(j + 3) * kk..(j + 4) * kk];
                let mut s0 = vdupq_n_f32(0.0);
                let mut s1 = vdupq_n_f32(0.0);
                let mut s2 = vdupq_n_f32(0.0);
                let mut s3 = vdupq_n_f32(0.0);
                let mut l = 0;
                while l + 4 <= kk {
                    let av = vld1q_f32(ar.as_ptr().add(l));
                    s0 = vfmaq_f32(s0, av, vld1q_f32(b0.as_ptr().add(l)));
                    s1 = vfmaq_f32(s1, av, vld1q_f32(b1.as_ptr().add(l)));
                    s2 = vfmaq_f32(s2, av, vld1q_f32(b2.as_ptr().add(l)));
                    s3 = vfmaq_f32(s3, av, vld1q_f32(b3.as_ptr().add(l)));
                    l += 4;
                }
                let mut t0 = hsum(s0);
                let mut t1 = hsum(s1);
                let mut t2 = hsum(s2);
                let mut t3 = hsum(s3);
                while l < kk {
                    let av = ar[l];
                    t0 = av.mul_add(b0[l], t0);
                    t1 = av.mul_add(b1[l], t1);
                    t2 = av.mul_add(b2[l], t2);
                    t3 = av.mul_add(b3[l], t3);
                    l += 1;
                }
                cr[j] += t0;
                cr[j + 1] += t1;
                cr[j + 2] += t2;
                cr[j + 3] += t3;
                j += 4;
            }
            for jj in j..p {
                let br = &b[jj * kk..(jj + 1) * kk];
                let mut sv = vdupq_n_f32(0.0);
                let mut l = 0;
                while l + 4 <= kk {
                    sv = vfmaq_f32(sv, vld1q_f32(ar.as_ptr().add(l)), vld1q_f32(br.as_ptr().add(l)));
                    l += 4;
                }
                let mut s = hsum(sv);
                while l < kk {
                    s = ar[l].mul_add(br[l], s);
                    l += 1;
                }
                cr[jj] += s;
            }
        }
    }

    /// Σ_j x[j]·y[j] over `len` elements — 4-lane FMA body, fixed
    /// [`hsum`] fold, scalar FMA tail.
    ///
    /// # Safety
    /// aarch64 target only (`simd::neon_available`).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_mul(x: &[f32], y: &[f32], len: usize) -> f32 {
        debug_assert!(x.len() >= len && y.len() >= len);
        let mut acc = vdupq_n_f32(0.0);
        let mut l = 0;
        while l + 4 <= len {
            acc = vfmaq_f32(acc, vld1q_f32(x.as_ptr().add(l)), vld1q_f32(y.as_ptr().add(l)));
            l += 4;
        }
        let mut s = hsum(acc);
        while l < len {
            s = x[l].mul_add(y[l], s);
            l += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_is_stable_and_consistent_with_detection() {
        // Whatever the env says, the resolved level must be available on
        // this CPU, and repeated calls must agree (OnceLock).
        let l1 = active();
        let l2 = active();
        assert_eq!(l1, l2);
        assert!(l1.available(), "active() picked {} on a host without it", l1.name());
    }

    #[test]
    fn level_names_round_trip() {
        for level in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(level.name()), Some(level), "{}", level.name());
        }
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::ScalarFma.name(), "scalar-fma");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
        assert_eq!(SimdLevel::Avx512.name(), "avx512");
        assert_eq!(SimdLevel::Neon.name(), "neon");
        // Ergonomic alias and rejection of junk.
        assert_eq!(SimdLevel::parse("scalar_fma"), Some(SimdLevel::ScalarFma));
        assert_eq!(SimdLevel::parse(" AVX512 "), Some(SimdLevel::Avx512));
        assert_eq!(SimdLevel::parse("auto"), None);
        assert_eq!(SimdLevel::parse("sse9"), None);
    }

    #[test]
    fn auto_never_picks_an_unavailable_or_soft_fma_level() {
        let auto = auto_level();
        assert!(auto.available());
        assert_ne!(auto, SimdLevel::ScalarFma, "scalar-fma is opt-in only");
    }

    #[test]
    fn portable_levels_are_always_available() {
        assert!(SimdLevel::Scalar.available());
        assert!(SimdLevel::ScalarFma.available());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn dot_mul_matches_scalar_sum() {
        if !avx2_available() {
            return;
        }
        // 19 elements: 2 full lanes + a 3-element tail.
        let x: Vec<f32> = (0..19).map(|i| 0.25 * i as f32 - 2.0).collect();
        let y: Vec<f32> = (0..19).map(|i| 1.0 - 0.125 * i as f32).collect();
        let want: f64 = x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let got = unsafe { avx2::dot_mul(&x, &y, 19) };
        assert!((got as f64 - want).abs() < 1e-4 * (1.0 + want.abs()), "{got} vs {want}");
    }

    #[test]
    fn scalar_fma_dot_matches_exact_sum() {
        let x: Vec<f32> = (0..23).map(|i| 0.5 - 0.1 * i as f32).collect();
        let y: Vec<f32> = (0..23).map(|i| 0.2 * i as f32 - 1.0).collect();
        let got = scalar_fma::dot_mul(&x, &y, 23);
        let want: f64 = x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((got as f64 - want).abs() < 1e-4 * (1.0 + want.abs()), "{got} vs {want}");
    }
}
