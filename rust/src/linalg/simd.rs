//! Runtime-dispatched SIMD layer under the GEMM engine.
//!
//! Two kernel families sit behind one dispatch switch:
//!
//! * **AVX2 + FMA** (`avx2`, x86_64 only) — 8-lane fused-multiply-add
//!   versions of every slice microkernel in [`super::gemm`], selected at
//!   runtime via CPU feature detection.
//! * **Portable scalar** — the seed-era auto-vectorizable loops in
//!   [`super::gemm`] itself; always available and bitwise-identical to the
//!   pre-SIMD engine on every platform.
//!
//! The level is resolved **once per process** from `L2IGHT_SIMD`
//! (`auto` | `avx2` | `scalar`, default `auto` = best available) by
//! [`active`]; every hot-path kernel call dispatches on it.
//!
//! ## Determinism contract
//!
//! Within one dispatch level, lane order and accumulation order are fixed:
//! the accumulate-into-memory kernels (`gemm_acc`, `gemm_at_b_band`) apply
//! one FMA per element per inner step regardless of where the 8-lane body
//! ends and the scalar tail begins, and the reduction kernels (`gemm_a_bt`,
//! `dot_mul`) split lanes by the (fixed) inner dimension only. Combined
//! with the pool's partition-by-output-region banding, results are
//! **bitwise thread-count-invariant at every level**. Across levels the
//! FMA contraction changes rounding, which is why switching `L2IGHT_SIMD`
//! moves numerics at the ulp scale (and why the scenario golden carries a
//! per-level bless — see `rust/README.md` § "SIMD dispatch").

use std::sync::OnceLock;

/// Instruction-set level the slice kernels run at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels — bitwise identical to the seed-era engine.
    Scalar,
    /// AVX2 + FMA 8-lane kernels (x86_64 only, runtime-detected).
    Avx2,
}

impl SimdLevel {
    /// Stable lowercase name (reports, bench JSON, logs).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// True when the CPU supports the AVX2+FMA kernels.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The process-wide dispatch level, resolved once from `L2IGHT_SIMD`.
/// Requesting `avx2` on a CPU without it warns and falls back to scalar;
/// an unknown value warns and behaves like `auto`.
pub fn active() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let auto = if avx2_available() { SimdLevel::Avx2 } else { SimdLevel::Scalar };
        match std::env::var("L2IGHT_SIMD") {
            Err(_) => auto,
            Ok(raw) => match raw.trim().to_ascii_lowercase().as_str() {
                "" | "auto" => auto,
                "scalar" => SimdLevel::Scalar,
                "avx2" => {
                    if avx2_available() {
                        SimdLevel::Avx2
                    } else {
                        crate::warn!(
                            "L2IGHT_SIMD=avx2 requested but the CPU lacks AVX2+FMA; using scalar kernels"
                        );
                        SimdLevel::Scalar
                    }
                }
                other => {
                    crate::warn!(
                        "ignoring unknown L2IGHT_SIMD={other:?} (want auto|avx2|scalar); using auto"
                    );
                    auto
                }
            },
        }
    })
}

/// AVX2+FMA slice kernels. Every function here requires AVX2 **and** FMA at
/// runtime; the dispatcher in `super::gemm` only routes here after
/// [`avx2_available`] (or an explicit, caller-checked level override).
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::{
        __m256, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };

    /// Fixed-order horizontal sum of the 8 lanes (deterministic tree:
    /// lane pairs (0,4)(1,5)(2,6)(3,7), then two rounds of adjacent adds).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut t = [0.0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), v);
        ((t[0] + t[4]) + (t[1] + t[5])) + ((t[2] + t[6]) + (t[3] + t[7]))
    }

    /// C[m×n] += A[m×kk] · B[kk×n] over raw row-major slices — the 8-lane
    /// FMA version of `gemm::gemm_acc_slices_scalar`, with the same 4-row
    /// register tiling and all-zero-quad skip. Per output element each
    /// inner step is one FMA (vector body and scalar tail alike), so the
    /// result does not depend on n or on panel boundaries.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_acc(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= kk * n && c.len() >= m * n);
        let mut i = 0;
        while i + 4 <= m {
            let rows = &mut c[i * n..(i + 4) * n];
            let (c0, rows) = rows.split_at_mut(n);
            let (c1, rows) = rows.split_at_mut(n);
            let (c2, c3) = rows.split_at_mut(n);
            let a0 = &a[i * kk..(i + 1) * kk];
            let a1 = &a[(i + 1) * kk..(i + 2) * kk];
            let a2 = &a[(i + 2) * kk..(i + 3) * kk];
            let a3 = &a[(i + 3) * kk..(i + 4) * kk];
            for l in 0..kk {
                let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue; // structured-sparsity fast path (masked weights)
                }
                let br = &b[l * n..(l + 1) * n];
                let v0 = _mm256_set1_ps(x0);
                let v1 = _mm256_set1_ps(x1);
                let v2 = _mm256_set1_ps(x2);
                let v3 = _mm256_set1_ps(x3);
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(j));
                    _mm256_storeu_ps(
                        c0.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v0, bv, _mm256_loadu_ps(c0.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(
                        c1.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v1, bv, _mm256_loadu_ps(c1.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(
                        c2.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v2, bv, _mm256_loadu_ps(c2.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(
                        c3.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(v3, bv, _mm256_loadu_ps(c3.as_ptr().add(j))),
                    );
                    j += 8;
                }
                while j < n {
                    let v = br[j];
                    c0[j] = x0.mul_add(v, c0[j]);
                    c1[j] = x1.mul_add(v, c1[j]);
                    c2[j] = x2.mul_add(v, c2[j]);
                    c3[j] = x3.mul_add(v, c3[j]);
                    j += 1;
                }
            }
            i += 4;
        }
        for r in i..m {
            let ar = &a[r * kk..(r + 1) * kk];
            let cr = &mut c[r * n..(r + 1) * n];
            for (l, &x) in ar.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let br = &b[l * n..(l + 1) * n];
                let xv = _mm256_set1_ps(x);
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(j));
                    _mm256_storeu_ps(
                        cr.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(xv, bv, _mm256_loadu_ps(cr.as_ptr().add(j))),
                    );
                    j += 8;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[i0..i1, n] += (Aᵀ·B)[i0..i1, n] for A [kk×m], B [kk×n], writing
    /// rows `i0..i1` into `c_band` — the 8-lane FMA version of
    /// `gemm::gemm_at_b_acc_band_scalar` with the same 4-pair tiling.
    /// The four FMAs per element chain in fixed order (x0 first).
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_at_b_band(
        a: &[f32],
        kk: usize,
        m: usize,
        b: &[f32],
        n: usize,
        i0: usize,
        i1: usize,
        c_band: &mut [f32],
    ) {
        debug_assert!(a.len() >= kk * m && b.len() >= kk * n);
        debug_assert!(i1 <= m && c_band.len() >= (i1 - i0) * n);
        let mut l = 0;
        while l + 4 <= kk {
            let a0 = &a[l * m..(l + 1) * m];
            let a1 = &a[(l + 1) * m..(l + 2) * m];
            let a2 = &a[(l + 2) * m..(l + 3) * m];
            let a3 = &a[(l + 3) * m..(l + 4) * m];
            let b0 = &b[l * n..(l + 1) * n];
            let b1 = &b[(l + 1) * n..(l + 2) * n];
            let b2 = &b[(l + 2) * n..(l + 3) * n];
            let b3 = &b[(l + 3) * n..(l + 4) * n];
            for i in i0..i1 {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let v0 = _mm256_set1_ps(x0);
                let v1 = _mm256_set1_ps(x1);
                let v2 = _mm256_set1_ps(x2);
                let v3 = _mm256_set1_ps(x3);
                let mut j = 0;
                while j + 8 <= n {
                    let mut acc = _mm256_loadu_ps(cr.as_ptr().add(j));
                    acc = _mm256_fmadd_ps(v0, _mm256_loadu_ps(b0.as_ptr().add(j)), acc);
                    acc = _mm256_fmadd_ps(v1, _mm256_loadu_ps(b1.as_ptr().add(j)), acc);
                    acc = _mm256_fmadd_ps(v2, _mm256_loadu_ps(b2.as_ptr().add(j)), acc);
                    acc = _mm256_fmadd_ps(v3, _mm256_loadu_ps(b3.as_ptr().add(j)), acc);
                    _mm256_storeu_ps(cr.as_mut_ptr().add(j), acc);
                    j += 8;
                }
                while j < n {
                    let mut s = cr[j];
                    s = x0.mul_add(b0[j], s);
                    s = x1.mul_add(b1[j], s);
                    s = x2.mul_add(b2[j], s);
                    s = x3.mul_add(b3[j], s);
                    cr[j] = s;
                    j += 1;
                }
            }
            l += 4;
        }
        for ll in l..kk {
            let ar = &a[ll * m..(ll + 1) * m];
            let br = &b[ll * n..(ll + 1) * n];
            for i in i0..i1 {
                let x = ar[i];
                if x == 0.0 {
                    continue;
                }
                let cr = &mut c_band[(i - i0) * n..(i - i0 + 1) * n];
                let xv = _mm256_set1_ps(x);
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_ps(br.as_ptr().add(j));
                    _mm256_storeu_ps(
                        cr.as_mut_ptr().add(j),
                        _mm256_fmadd_ps(xv, bv, _mm256_loadu_ps(cr.as_ptr().add(j))),
                    );
                    j += 8;
                }
                while j < n {
                    cr[j] = x.mul_add(br[j], cr[j]);
                    j += 1;
                }
            }
        }
    }

    /// C[m×p] += A[m×kk] · B[p×kk]ᵀ (dot-product layout) — the 8-lane FMA
    /// version of `gemm::gemm_a_bt_acc_slices_scalar` with the same 4-dot
    /// tiling and all-zero A-row skip. Each dot product accumulates the
    /// 8-lane body in vector lanes (reduced by the fixed [`hsum`] tree),
    /// then appends the scalar tail; the split depends only on `kk`.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_a_bt(a: &[f32], m: usize, kk: usize, b: &[f32], p: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * kk && b.len() >= p * kk && c.len() >= m * p);
        for i in 0..m {
            let ar = &a[i * kk..(i + 1) * kk];
            if ar.iter().all(|&v| v == 0.0) {
                continue;
            }
            let cr = &mut c[i * p..(i + 1) * p];
            let mut j = 0;
            while j + 4 <= p {
                let b0 = &b[j * kk..(j + 1) * kk];
                let b1 = &b[(j + 1) * kk..(j + 2) * kk];
                let b2 = &b[(j + 2) * kk..(j + 3) * kk];
                let b3 = &b[(j + 3) * kk..(j + 4) * kk];
                let mut s0 = _mm256_setzero_ps();
                let mut s1 = _mm256_setzero_ps();
                let mut s2 = _mm256_setzero_ps();
                let mut s3 = _mm256_setzero_ps();
                let mut l = 0;
                while l + 8 <= kk {
                    let av = _mm256_loadu_ps(ar.as_ptr().add(l));
                    s0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.as_ptr().add(l)), s0);
                    s1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.as_ptr().add(l)), s1);
                    s2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.as_ptr().add(l)), s2);
                    s3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.as_ptr().add(l)), s3);
                    l += 8;
                }
                let mut t0 = hsum(s0);
                let mut t1 = hsum(s1);
                let mut t2 = hsum(s2);
                let mut t3 = hsum(s3);
                while l < kk {
                    let av = ar[l];
                    t0 = av.mul_add(b0[l], t0);
                    t1 = av.mul_add(b1[l], t1);
                    t2 = av.mul_add(b2[l], t2);
                    t3 = av.mul_add(b3[l], t3);
                    l += 1;
                }
                cr[j] += t0;
                cr[j + 1] += t1;
                cr[j + 2] += t2;
                cr[j + 3] += t3;
                j += 4;
            }
            for jj in j..p {
                let br = &b[jj * kk..(jj + 1) * kk];
                let mut sv = _mm256_setzero_ps();
                let mut l = 0;
                while l + 8 <= kk {
                    sv = _mm256_fmadd_ps(
                        _mm256_loadu_ps(ar.as_ptr().add(l)),
                        _mm256_loadu_ps(br.as_ptr().add(l)),
                        sv,
                    );
                    l += 8;
                }
                let mut s = hsum(sv);
                while l < kk {
                    s = ar[l].mul_add(br[l], s);
                    l += 1;
                }
                cr[jj] += s;
            }
        }
    }

    /// Σ_j x[j]·y[j] over `len` elements — the Eq. 5 Hadamard reduction
    /// (8-lane FMA body, fixed [`hsum`] tree, scalar FMA tail).
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (`simd::avx2_available`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_mul(x: &[f32], y: &[f32], len: usize) -> f32 {
        debug_assert!(x.len() >= len && y.len() >= len);
        let mut acc = _mm256_setzero_ps();
        let mut l = 0;
        while l + 8 <= len {
            acc = _mm256_fmadd_ps(
                _mm256_loadu_ps(x.as_ptr().add(l)),
                _mm256_loadu_ps(y.as_ptr().add(l)),
                acc,
            );
            l += 8;
        }
        let mut s = hsum(acc);
        while l < len {
            s = x[l].mul_add(y[l], s);
            l += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_is_stable_and_consistent_with_detection() {
        // Whatever the env says, the resolved level must be available on
        // this CPU, and repeated calls must agree (OnceLock).
        let l1 = active();
        let l2 = active();
        assert_eq!(l1, l2);
        if l1 == SimdLevel::Avx2 {
            assert!(avx2_available(), "active() picked avx2 on a CPU without it");
        }
    }

    #[test]
    fn level_names() {
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn dot_mul_matches_scalar_sum() {
        if !avx2_available() {
            return;
        }
        // 19 elements: 2 full lanes + a 3-element tail.
        let x: Vec<f32> = (0..19).map(|i| 0.25 * i as f32 - 2.0).collect();
        let y: Vec<f32> = (0..19).map(|i| 1.0 - 0.125 * i as f32).collect();
        let want: f64 = x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let got = unsafe { avx2::dot_mul(&x, &y, 19) };
        assert!((got as f64 - want).abs() < 1e-4 * (1.0 + want.abs()), "{got} vs {want}");
    }
}
