//! Row-major f32 matrix. Deliberately minimal: the simulator needs exactly
//! owned storage, views by row, transpose, Frobenius norms, and elementwise
//! combinators. Shapes are checked with assertions (debug + release) because
//! a silent shape slip invalidates an entire experiment.

use crate::util::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_slice size");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// From an owned row-major vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_vec size");
        Mat { rows, cols, data }
    }

    /// Matrix with i.i.d. N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 0.0, std);
        m
    }

    /// Matrix with i.i.d. U[lo, hi) entries.
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f32]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// The diagonal as a vector (min(rows, cols) long).
    pub fn diagonal(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>()
    }

    /// Elementwise a - b.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "sub shape");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise a + b.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "add shape");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise Hadamard product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "hadamard shape");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Copy a k×k block out of a larger matrix, zero-padded at the edges.
    pub fn block(&self, r0: usize, c0: usize, k: usize) -> Mat {
        let mut b = Mat::zeros(k, k);
        for r in 0..k.min(self.rows.saturating_sub(r0)) {
            for c in 0..k.min(self.cols.saturating_sub(c0)) {
                b[(r, c)] = self[(r0 + r, c0 + c)];
            }
        }
        b
    }

    /// Write a k×k block into a larger matrix (clipped at the edges).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        for r in 0..b.rows.min(self.rows.saturating_sub(r0)) {
            for c in 0..b.cols.min(self.cols.saturating_sub(c0)) {
                self[(r0 + r, c0 + c)] = b[(r, c)];
            }
        }
    }

    /// Relative squared distance ‖A−B‖²/‖B‖² — the paper's "normalized matrix
    /// distance" fidelity metric (Fig. 5, Fig. 8).
    pub fn rel_dist_sq(&self, target: &Mat) -> f32 {
        let denom = target.fro_norm_sq().max(1e-20);
        self.sub(target).fro_norm_sq() / denom
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Cosine (angular) similarity between two flattened tensors — the paper's
/// gradient-fidelity metric (Fig. 8, "average gradient angular similarity").
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na < 1e-20 || nb < 1e-20 {
        return 0.0;
    }
    dot / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        m[(2, 3)] = 5.0;
        assert_eq!(m[(2, 3)], 5.0);
        assert_eq!(m.row(2)[3], 5.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t()[(3, 2)], m[(2, 3)]);
    }

    #[test]
    fn eye_diag() {
        let i = Mat::eye(4);
        assert_eq!(i.diagonal(), vec![1.0; 4]);
        assert_eq!(i.fro_norm_sq(), 4.0);
    }

    #[test]
    fn block_get_set_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(9, 9, 1.0, &mut rng);
        let b = m.block(3, 3, 4);
        let mut m2 = Mat::zeros(9, 9);
        m2.set_block(3, 3, &b);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(m2[(3 + r, 3 + c)], m[(3 + r, 3 + c)]);
            }
        }
    }

    #[test]
    fn block_zero_pads() {
        let m = Mat::eye(3);
        let b = m.block(2, 2, 4);
        assert_eq!(b[(0, 0)], 1.0);
        assert_eq!(b[(1, 1)], 0.0);
        assert_eq!(b[(3, 3)], 0.0);
    }

    #[test]
    fn arithmetic() {
        let a = Mat::from_slice(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_slice(2, 2, &[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).data, vec![5.0; 4]);
        assert_eq!(a.sub(&b).data, vec![-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(a.hadamard(&b).data, vec![4.0, 6.0, 6.0, 4.0]);
    }

    #[test]
    fn cosine_extremes() {
        assert!((cosine_similarity(&[1.0, 0.0], &[2.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((cosine_similarity(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine_similarity(&[1.0, 0.0], &[-3.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine_similarity(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn rel_dist_zero_for_equal() {
        let mut rng = Rng::new(3);
        let m = Mat::randn(4, 4, 1.0, &mut rng);
        assert!(m.rel_dist_sq(&m) < 1e-12);
    }
}
