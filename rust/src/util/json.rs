//! Minimal JSON parser and emitter. Used for the AOT artifact manifest
//! (`artifacts/manifest.json`), experiment configs, checkpoints metadata, and
//! metric dumps. Supports the full JSON grammar except surrogate-pair escapes
//! beyond the BMP (sufficient for machine-generated manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept ordered (BTreeMap) so emission is
/// deterministic — useful for golden tests and diffable metric dumps.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Emit compact JSON.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Emit pretty-printed JSON with 2-space indents.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.src.len());
                    let chunk = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": 3.5e-2}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), 0.035);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(arr[2].get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_nested() {
        let mut o = Json::obj();
        o.set("name", "ptc_forward".into())
            .set("args", vec![4usize, 4, 9].into())
            .set("tuple", true.into())
            .set("scale", 0.125f64.into());
        let text = o.pretty();
        assert_eq!(Json::parse(&text).unwrap(), o);
        let compact = o.dump();
        assert_eq!(Json::parse(&compact).unwrap(), o);
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
    }
}
