//! A small declarative CLI argument parser (the vendored crate set has no
//! clap). Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options with defaults, and generated `--help` text.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// A declarative argument parser for one (sub)command.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    command: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>,
}

impl ArgSpec {
    pub fn new(command: &str, about: &str) -> Self {
        ArgSpec { command: command.into(), about: about.into(), opts: vec![], positionals: vec![] }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// Declare `--name <value>` that is required.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec { name: name.into(), help: help.into(), default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: Some("false".into()),
            is_flag: true,
        });
        self
    }

    /// Declare a positional argument.
    pub fn pos(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.command, self.about, self.command);
        for (p, _) in &self.positionals {
            s += &format!(" <{p}>");
        }
        s += " [OPTIONS]\n";
        if !self.positionals.is_empty() {
            s += "\nARGS:\n";
            for (p, h) in &self.positionals {
                s += &format!("  <{p}>  {h}\n");
            }
        }
        s += "\nOPTIONS:\n";
        for o in &self.opts {
            let val = if o.is_flag { String::new() } else { " <v>".into() };
            let def = match (&o.default, o.is_flag) {
                (Some(d), false) => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s += &format!("  --{}{val}  {}{def}\n", o.name, o.help);
        }
        s
    }

    /// Parse a raw argument list (without the program/subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Args, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut pos_vals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help_text()))?;
                let val = if spec.is_flag {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i).cloned().ok_or_else(|| format!("--{name} needs a value"))?
                };
                values.insert(name, val);
            } else {
                pos_vals.push(a.clone());
            }
            i += 1;
        }
        if pos_vals.len() < self.positionals.len() {
            return Err(format!(
                "missing positional <{}>\n\n{}",
                self.positionals[pos_vals.len()].0,
                self.help_text()
            ));
        }
        for o in &self.opts {
            if !values.contains_key(&o.name) {
                return Err(format!("missing required --{}", o.name));
            }
        }
        for (idx, (name, _)) in self.positionals.iter().enumerate() {
            values.insert(format!("@{name}"), pos_vals[idx].clone());
        }
        Ok(Args { values })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
}

impl Args {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .or_else(|| self.values.get(&format!("@{name}")))
            .unwrap_or_else(|| panic!("undeclared arg {name}"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.str(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.str(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.f64(name) as f32
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.str(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.str(name), "true" | "1" | "yes")
    }

    /// Comma-separated list of usize.
    pub fn usize_list(&self, name: &str) -> Vec<usize> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad int '{s}'")))
            .collect()
    }

    /// Comma-separated list of f64.
    pub fn f64_list(&self, name: &str) -> Vec<f64> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad float '{s}'")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("train", "train a model")
            .opt("epochs", "10", "number of epochs")
            .opt("lr", "0.002", "learning rate")
            .flag("verbose", "chatty output")
            .req("model", "model name")
            .pos("dataset", "dataset name")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = spec().parse(&sv(&["mnist", "--model", "cnn-s", "--epochs=3"])).unwrap();
        assert_eq!(a.usize("epochs"), 3);
        assert_eq!(a.f64("lr"), 0.002);
        assert!(!a.bool("verbose"));
        assert_eq!(a.str("model"), "cnn-s");
        assert_eq!(a.str("dataset"), "mnist");
    }

    #[test]
    fn flags() {
        let a = spec().parse(&sv(&["d", "--model", "m", "--verbose"])).unwrap();
        assert!(a.bool("verbose"));
    }

    #[test]
    fn missing_required() {
        assert!(spec().parse(&sv(&["d"])).unwrap_err().contains("--model"));
    }

    #[test]
    fn missing_positional() {
        assert!(spec().parse(&sv(&["--model", "m"])).unwrap_err().contains("dataset"));
    }

    #[test]
    fn unknown_option() {
        assert!(spec().parse(&sv(&["d", "--model", "m", "--nope", "1"])).is_err());
    }

    #[test]
    fn lists() {
        let s = ArgSpec::new("x", "y").opt("sizes", "8,9,12", "block sizes");
        let a = s.parse(&[]).unwrap();
        assert_eq!(a.usize_list("sizes"), vec![8, 9, 12]);
    }

    #[test]
    fn help_is_error_path() {
        let e = spec().parse(&sv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--epochs"));
    }
}
