//! Criterion-free micro/macro benchmark harness used by `rust/benches/*`
//! (declared with `harness = false`). Provides warmup, adaptive iteration
//! counts, robust statistics, and a uniform report format so every paper
//! table/figure bench prints comparable rows.

use std::time::{Duration, Instant};

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration (sorted samples).
    pub samples_ns: Vec<f64>,
}

impl Measurement {
    pub fn median_ns(&self) -> f64 {
        percentile(&self.samples_ns, 50.0)
    }
    pub fn p10_ns(&self) -> f64 {
        percentile(&self.samples_ns, 10.0)
    }
    pub fn p90_ns(&self) -> f64 {
        percentile(&self.samples_ns, 90.0)
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Benchmark runner with a fixed measurement budget per target.
pub struct Bencher {
    /// Wall-clock budget for the measurement phase of each target.
    pub budget: Duration,
    /// Number of sample groups to collect.
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget: Duration::from_millis(600), samples: 20, results: vec![] }
    }
}

impl Bencher {
    pub fn new(budget_ms: u64, samples: usize) -> Self {
        Bencher { budget: Duration::from_millis(budget_ms), samples, results: vec![] }
    }

    /// Benchmark `f`, returning median ns/iter. `f` should perform one unit of
    /// work; the harness picks the per-sample iteration count adaptively.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // Warmup + calibration: find iters so one sample ≈ budget/samples.
        let t0 = Instant::now();
        f();
        let one = t0.elapsed().as_secs_f64().max(1e-9);
        let per_sample = self.budget.as_secs_f64() / self.samples as f64;
        let iters = ((per_sample / one).ceil() as usize).clamp(1, 1_000_000);
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement { name: name.to_string(), samples_ns: samples };
        let med = m.median_ns();
        self.results.push(m);
        med
    }

    /// All collected measurements.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print a formatted summary table of all measurements.
    pub fn report(&self, title: &str) {
        println!("\n## {title}");
        println!("{:<48} {:>14} {:>14} {:>14}", "benchmark", "p10", "median", "p90");
        for m in &self.results {
            println!(
                "{:<48} {:>14} {:>14} {:>14}",
                m.name,
                fmt_ns(m.p10_ns()),
                fmt_ns(m.median_ns()),
                fmt_ns(m.p90_ns())
            );
        }
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding a value (stable `black_box` shim).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Short git revision for bench/report provenance: `GITHUB_SHA` when set
/// (CI), else `git rev-parse --short HEAD`, else `"unknown"`. Shared by
/// every `BENCH_*.json` emitter so runs are diffable across commits.
pub fn git_rev() -> String {
    if let Ok(rev) = std::env::var("GITHUB_SHA") {
        if !rev.is_empty() {
            return rev.chars().take(12).collect();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Seconds since the Unix epoch (0.0 if the clock is unavailable).
pub fn unix_time() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Markdown-style table printer for paper-table reproductions.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_ref(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}");
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s += &format!(" {:<w$} |", c, w = widths[i]);
            }
            s
        };
        println!("{}", line(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep += &format!("{:-<w$}|", "", w = w + 2);
        }
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(30, 5);
        let med = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(med > 0.0);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].p10_ns() <= b.results()[0].p90_ns());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows_ref().len(), 1);
        t.print("test"); // smoke: must not panic
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
