//! Cross-cutting substrates built from scratch for the offline environment:
//! a deterministic PRNG, a minimal JSON parser/emitter, a CLI argument
//! parser, a criterion-free benchmark harness, a seeded property-testing
//! helper, the shared compute thread pool (`pool`, sized by
//! `L2IGHT_THREADS`), and a std-only error/context type (`error`). See
//! DESIGN.md §2 (the vendored crate set has no
//! rand/serde/clap/criterion/proptest/anyhow/rayon, so these are in-repo).

pub mod rng;
pub mod json;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod pool;
pub mod error;

pub use pool::ThreadPool;
pub use rng::Rng;

/// Simple stderr logger with runtime level control.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1);

/// Set the global log level (0=debug .. 3=error).
pub fn set_log_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether messages at `level` are currently emitted.
pub fn log_enabled(level: Level) -> bool {
    level as u8 >= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Emit a log line to stderr if `level` is enabled.
pub fn log(level: Level, msg: &str) {
    if log_enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Warn, &format!($($arg)*)) };
}

/// Format a float with engineering-style compactness for tables.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if a >= 1e4 || a < 1e-3 {
        format!("{v:.*e}", digits.saturating_sub(1))
    } else {
        let lead = a.log10().floor() as i64 + 1; // digits before the point (≤0 for a<1)
        let frac = (digits as i64 - lead).clamp(0, 12) as usize;
        format!("{v:.*}", frac)
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_sig_ranges() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1234.4, 4), "1234");
        assert_eq!(fmt_sig(0.01234, 3), "0.0123");
        assert!(fmt_sig(1.0e9, 3).contains('e'));
        assert!(fmt_sig(1.0e-9, 3).contains('e'));
    }

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
    }

    #[test]
    fn log_level_gating() {
        set_log_level(Level::Warn);
        assert!(!log_enabled(Level::Info));
        assert!(log_enabled(Level::Error));
        set_log_level(Level::Info);
        assert!(log_enabled(Level::Info));
    }
}
