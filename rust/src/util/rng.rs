//! Deterministic PCG32 pseudo-random number generator plus the sampling
//! helpers the simulator needs (uniform, normal via Box-Muller, permutation,
//! Bernoulli masks). Seeded everywhere for reproducible experiments — the
//! paper's simulator reports ±σ over repeated runs, which we reproduce by
//! seeding each repetition.

/// PCG32 (Melissa O'Neill, PCG-XSH-RR 64/32) with a 64-bit state and stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create a generator with an explicit stream id (distinct streams are
    /// statistically independent).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1, gauss_spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::with_stream(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag | 1)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in [0, n) (Lemire's method).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box-Muller with caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Choose k distinct indices from 0..n (k <= n), unsorted.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher-Yates.
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Fill a slice with N(mean, std) samples (f32).
    pub fn fill_normal(&mut self, xs: &mut [f32], mean: f32, std: f32) {
        for x in xs.iter_mut() {
            *x = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with U[lo, hi) samples (f32).
    pub fn fill_uniform(&mut self, xs: &mut [f32], lo: f32, hi: f32) {
        for x in xs.iter_mut() {
            *x = self.uniform_range(lo as f64, hi as f64) as f32;
        }
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Rng::new(2);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn below_unbiased() {
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).unsigned_abs() < 600, "counts {counts:?}");
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let ks = rng.choose_k(20, 8);
            assert_eq!(ks.len(), 8);
            let mut sorted = ks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "duplicates in {ks:?}");
            assert!(ks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Rng::new(6);
        let p = rng.permutation(50);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut a = Rng::new(7);
        let mut c1 = a.fork(1);
        let mut c2 = a.fork(2);
        // Not a statistical test, just "not identical streams".
        let v1: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let v2: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Rng::new(8);
        let hits = (0..50_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 50_000.0 - 0.3).abs() < 0.01);
    }
}
